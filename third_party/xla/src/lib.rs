//! API-compatible **stub** of the `xla` crate (xla_extension 0.5.1
//! bindings, LaurentMazare/xla-rs) covering exactly the surface the
//! cushioncache runtime uses.
//!
//! Purpose: the default `xla` cargo feature must *link* in environments
//! without the native XLA toolchain (no libxla_extension.so, no network),
//! so the crate builds and its tests run everywhere. Every entry point
//! here returns `Err(Error::Unavailable)` at runtime; the runtime's
//! backend selection (`runtime::backend`) observes the failed client
//! construction and falls back to the pure-Rust reference interpreter,
//! so `cushiond` remains fully functional — it just never executes
//! compiled HLO artifacts.
//!
//! To run the real PJRT backend, point the `xla` path dependency in the
//! workspace `Cargo.toml` at the actual xla-rs checkout; no runtime code
//! changes are needed (the API below is a subset of the real one).

use std::fmt;

/// Mirrors the error enum of the real bindings closely enough for the
/// runtime's `{e:?}` formatting.
pub enum Error {
    /// This is the stub build: no native XLA/PJRT is linked.
    Unavailable(&'static str),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the native xla_extension \
                 bindings (this build links third_party/xla, the API stub; \
                 the reference interpreter backend is the functional path)"
            ),
        }
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the runtime marshals (f32 tensors, i32 token ids).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct PjRtClient {
    _priv: (),
}

pub struct PjRtBuffer {
    _priv: (),
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

pub struct Literal {
    _priv: (),
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

pub struct HloModuleProto {
    _priv: (),
}

pub struct XlaComputation {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _literal: &Literal,
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("buffer_from_host_literal"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("compile"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("execute_b"))
    }
}

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::Unavailable("array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("to_vec"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("decompose_tuple"))
    }
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}
