"""L1/L2 performance analysis (EXPERIMENTS.md §Perf).

L1 (Pallas): interpret=True gives CPU-numpy timings that say nothing
about TPU behaviour, so the kernel is optimized *structurally*: this
module prints the analytic VMEM footprint, MXU utilization, and HBM
traffic per BlockSpec choice for every matmul shape in the tiny families,
plus the roofline-style arithmetic intensity.

L2 (JAX graph): prints HLO statistics (op histogram, fusion count,
parameter/byte counts) for each lowered artifact so graph-level
regressions (lost fusions, redundant recompute) are visible.

Usage:
    python -m compile.perf                 # kernel tile sweep
    python -m compile.perf --hlo           # artifact HLO stats
"""

import argparse
import os
import re
import sys
from collections import Counter

from . import configs as C
from .kernels import qmatmul
from .kernels import attention as attn


def matmul_shapes(cfg: C.ModelCfg):
    """Every (name, M, K, N) matmul in one decode/prefill token batch."""
    d, dh, f = cfg.d_model, cfg.d_head, cfg.d_ff
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    s = C.SEQ_LEN
    return [
        ("qkv_q", s, d, hq * dh),
        ("qkv_kv", s, d, 2 * hkv * dh),
        ("o_proj", s, hq * dh, d),
        ("mlp_up", s, d, f * (2 if cfg.act == "swiglu" else 1)),
        ("mlp_down", s, f, d),
        ("lm_head", s, d, cfg.vocab),
    ]


def kernel_report(cfg: C.ModelCfg, block_sweep=(32, 64, 128, 256)):
    print(f"\n== L1 tile analysis: {cfg.name} (d={cfg.d_model}, ff={cfg.d_ff}) ==")
    print(f"{'matmul':10} {'M':>5} {'K':>5} {'N':>5} | "
          f"{'bm=bn':>6} {'VMEM KiB':>9} {'MXU util':>9} {'HBM KiB':>9} {'AI':>6}")
    best = {}
    for name, m, k, n in matmul_shapes(cfg):
        rows = []
        for b in block_sweep:
            vmem, mxu, hbm = qmatmul.tile_stats(m, k, n, block_m=b, block_n=b)
            flops = 2 * m * k * n
            ai = flops / hbm  # arithmetic intensity (FLOP/byte)
            ok = vmem <= 16 * 2 ** 20  # 16 MiB VMEM budget
            score = (mxu, ai) if ok else (-1.0, -1.0)
            rows.append((b, vmem, mxu, hbm, ai, score))
        chosen = max(rows, key=lambda r: r[5])[0]
        best[name] = chosen
        for b, vmem, mxu, hbm, ai, _ in rows:
            tag = " <-" if b == chosen else ""
            print(f"{name:10} {m:5} {k:5} {n:5} | {b:6} {vmem/1024:9.1f} "
                  f"{mxu:9.2f} {hbm/1024:9.1f} {ai:6.1f}{tag}")
    print("\nchosen blocks:", best)
    av = attn.vmem_bytes(attn.BLOCK_Q, C.CACHE_CAP, cfg.d_head)
    print(f"attention tile (bq={attn.BLOCK_Q}, skv={C.CACHE_CAP}, dh={cfg.d_head}): "
          f"{av/1024:.1f} KiB VMEM")


HLO_OP = re.compile(r"=\s+[\w\[\],<>{} ]+?\s(\w[\w.-]*)\(")


def hlo_report(artifacts_dir: str, variant: str):
    vdir = os.path.join(artifacts_dir, variant)
    print(f"\n== L2 HLO statistics: {variant} ==")
    print(f"{'graph':18} {'KiB':>7} {'insts':>7} {'fusions':>8} "
          f"{'dots':>5} {'while':>6} {'top ops'}")
    for fn in sorted(os.listdir(vdir)):
        if not fn.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(vdir, fn)).read()
        ops = Counter()
        for line in text.splitlines():
            m = HLO_OP.search(line)
            if m:
                ops[m.group(1)] += 1
        top = ",".join(f"{k}:{v}" for k, v in ops.most_common(4))
        print(f"{fn[:-8]:18} {len(text)/1024:7.0f} {sum(ops.values()):7} "
              f"{ops.get('fusion', 0):8} {ops.get('dot', 0):5} "
              f"{ops.get('while', 0):6} {top}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", action="store_true")
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--variants", default="tl-llama,tl-llama3")
    args = ap.parse_args()
    for name in args.variants.split(","):
        if args.hlo:
            hlo_report(args.artifacts, name)
        else:
            kernel_report(C.VARIANTS[name])


if __name__ == "__main__":
    main()
