"""Pallas kernel: attention with a CushionCache prefix region.

The kernel computes one (head, query-block) tile per grid step. Keys and
values — including the prefix slots that hold the CushionCache KV — are
streamed into VMEM whole per head (Skv <= CACHE_CAP = 144 rows of 64
floats, ~37 KiB per operand: comfortably VMEM-resident), so the softmax
is exact per query row without an online rescale pass; query blocks of
64 rows keep the q·kᵀ logits tile (64x144) in VMEM as well.

Mask semantics match ref.attention: the first `n_prefix_slots` key
positions form the prefix region, of which `prefix_len` (a runtime
scalar) are valid and visible to every query; token keys are causal with
an optional sliding window (prefix stays visible — StreamingLLM-style);
head 0 can be strict-causal (diagonal masked) for the planted detector
head; optional ALiBi bias per head.

GQA is expressed in the BlockSpec index_map: query head h reads KV head
h // group — no materialized repeat.

Oracle: ref.attention; matched by python/tests/test_kernel_attention.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 64


def _attn_kernel(q_ref, k_ref, v_ref, plen_ref, off_ref, slopes_ref, o_ref, *,
                 n_prefix_slots, window, strict_head0,
                 head0_global, use_alibi, block_q, d_head):
    h = pl.program_id(0)
    iq = pl.program_id(1)
    q = q_ref[0]          # [bq, dh]
    k = k_ref[0]          # [skv, dh]
    v = v_ref[0]          # [skv, dh]
    prefix_len = plen_ref[0]
    causal_offset = off_ref[0]
    skv = k.shape[0]

    logits = jnp.dot(q, k.T, precision=jax.lax.Precision.HIGHEST)
    logits = logits / jnp.sqrt(jnp.asarray(d_head, q.dtype))

    j = jax.lax.broadcasted_iota(jnp.int32, (block_q, skv), 1)
    i = jax.lax.broadcasted_iota(jnp.int32, (block_q, skv), 0)
    qpos = causal_offset + iq * block_q + i
    kpos = j - n_prefix_slots
    in_prefix = j < n_prefix_slots
    prefix_ok = in_prefix & (j < prefix_len)
    tok_ok = (~in_prefix) & (kpos <= qpos)
    if window is not None:
        tok_win = tok_ok & (kpos >= qpos - window + 1)
        mask = prefix_ok | tok_win
        if head0_global:
            mask = jnp.where(h == 0, prefix_ok | tok_ok, mask)
    else:
        mask = prefix_ok | tok_ok
    if strict_head0:
        self_mask = (~in_prefix) & (kpos == qpos)
        mask = jnp.where(h == 0, mask & ~self_mask, mask)

    if use_alibi:
        slope = slopes_ref[0]
        kabs = jnp.where(in_prefix, j, kpos + prefix_len)
        qabs = qpos + prefix_len
        logits = logits - slope * (qabs - kabs).astype(q.dtype)

    logits = jnp.where(mask, logits, jnp.asarray(-1e30, q.dtype))
    m = jnp.max(logits, axis=1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    o_ref[0] = jnp.dot(p / denom, v, precision=jax.lax.Precision.HIGHEST)


def sink_attention(q, k, v, prefix_len, *, n_prefix_slots, causal_offset=0,
                   window=None, alibi_slopes=None, strict_head0=False,
                   head0_global=False, block_q: int = BLOCK_Q):
    """q: [H, Sq, dh]; k, v: [Hkv, Skv, dh]; prefix_len: int32 scalar.

    Returns [H, Sq, dh]. See module docstring for mask semantics.
    """
    hq, sq, dh = q.shape
    hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(block_q, sq)
    grid = (hq, pl.cdiv(sq, bq))

    use_alibi = alibi_slopes is not None
    slopes = (jnp.asarray(alibi_slopes, jnp.float32)
              if use_alibi else jnp.zeros((hq,), jnp.float32))

    kernel = functools.partial(
        _attn_kernel,
        n_prefix_slots=n_prefix_slots,
        window=window, strict_head0=strict_head0, head0_global=head0_global,
        use_alibi=use_alibi, block_q=bq, d_head=dh,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, skv, dh), lambda h, i, g=group: (h // g, 0, 0)),
            pl.BlockSpec((1, skv, dh), lambda h, i, g=group: (h // g, 0, 0)),
            pl.BlockSpec((1,), lambda h, i: (0,)),
            pl.BlockSpec((1,), lambda h, i: (0,)),
            pl.BlockSpec((1,), lambda h, i: (h,)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, sq, dh), q.dtype),
        interpret=True,
    )(q, k, v, jnp.asarray(prefix_len, jnp.int32).reshape(1),
      jnp.asarray(causal_offset, jnp.int32).reshape(1), slopes)


def vmem_bytes(sq_block, skv, dh, dtype_bytes=4):
    """Analytic VMEM footprint of one attention tile (q + k + v + logits +
    out) for the perf pass."""
    return (sq_block * dh + 2 * skv * dh + sq_block * skv + sq_block * dh) * dtype_bytes


__all__ = ["sink_attention", "vmem_bytes", "BLOCK_Q"]
