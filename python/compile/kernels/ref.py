"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here is the mathematical definition; the Pallas kernels in
quant.py / qmatmul.py / attention.py must match these to float tolerance
under pytest + hypothesis sweeps (python/tests/).
"""

import jax.numpy as jnp
import jax


def qdq_asym(x, lo, scale, levels):
    """Asymmetric linear quantize-dequantize with a given range.

    q = clip(round((x - lo)/scale), 0, levels); back to lo + q*scale.
    `lo`/`scale` broadcast against x (scalars for per-tensor, column vectors
    for per-token). `levels` = 2^bits - 1 (a float so it can be a graph
    input).
    """
    q = jnp.clip(jnp.round((x - lo) / scale), 0.0, levels)
    return lo + q * scale


def range_asym(x, levels, axis=None, where=None):
    """(lo, scale) for asymmetric quantization over `axis` (None = whole
    tensor), optionally restricted by a boolean mask `where` (used to
    exclude CushionCache prefix positions from the statistics)."""
    if where is None:
        mn = jnp.min(x, axis=axis, keepdims=axis is not None)
        mx = jnp.max(x, axis=axis, keepdims=axis is not None)
    else:
        big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
        mn = jnp.min(jnp.where(where, x, big), axis=axis, keepdims=axis is not None)
        mx = jnp.max(jnp.where(where, x, -big), axis=axis, keepdims=axis is not None)
    mn = jnp.minimum(mn, 0.0)  # keep zero representable
    mx = jnp.maximum(mx, 0.0)
    scale = jnp.maximum(mx - mn, 1e-8) / levels
    return mn, scale


def qdq_dynamic(x, levels, axis=None, where=None):
    lo, scale = range_asym(x, levels, axis=axis, where=where)
    return qdq_asym(x, lo, scale, levels)


def quant_weight_sym_grouped(w, bits, group=64):
    """Symmetric group-wise weight quantize-dequantize along the input
    (contracting) dimension — the paper's weight scheme. w: [K, N]."""
    k, n = w.shape
    assert k % group == 0, (k, group)
    qmax = 2.0 ** (bits - 1) - 1
    wg = w.reshape(k // group, group, n)
    scale = jnp.max(jnp.abs(wg), axis=1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(wg / scale), -qmax, qmax)
    return (q * scale).reshape(k, n)


def qmatmul(x, w, lo, scale, levels):
    """W8A8-style matmul oracle: activation qdq (given range) then matmul
    against an (already weight-quantized) w. Integer arithmetic is simulated
    in f32 — exact for int8 ranges (f32 holds integers < 2^24 exactly)."""
    return qdq_asym(x, lo, scale, levels) @ w


def attention(q, k, v, *, prefix_len, n_prefix_slots, causal_offset,
              window=None, alibi_slopes=None, strict_head0=False,
              head0_global=False, kv_valid=None):
    """Attention with a CushionCache prefix region, the oracle for
    kernels/attention.py.

    q: [H, Sq, dh]; k, v: [Hkv, Skv, dh] where the first `n_prefix_slots`
    key positions are the (padded) prefix region, of which only the first
    `prefix_len` are valid. Query i sits at absolute token index
    causal_offset + i; key j >= n_prefix_slots sits at token index
    j - n_prefix_slots — queries attend to the valid prefix plus causally
    to the token region.

    window: sliding-window size (prefix always visible, StreamingLLM-style).
    alibi_slopes: [H] or None. strict_head0: mask the self/diagonal for
    head 0 (the strict-causal detector head of the planted circuit).
    head0_global: head 0 ignores the sliding window (the detector/sink
    heads see the whole context, as StreamingLLM patches do).
    kv_valid: [Skv] bool — extra key visibility mask (used by the greedy
    scorer to hide padding inside an in-band prefix region).
    """
    hq, sq, dh = q.shape
    hkv, skv, _ = k.shape
    g = hq // hkv
    kx = jnp.repeat(k, g, axis=0)
    vx = jnp.repeat(v, g, axis=0)
    logits = jnp.einsum("hid,hjd->hij", q, kx) / jnp.sqrt(jnp.asarray(dh, q.dtype))

    j = jnp.arange(skv)[None, :]
    i = jnp.arange(sq)[:, None]
    qpos = causal_offset + i
    kpos = j - n_prefix_slots  # negative in the prefix region
    in_prefix = j < n_prefix_slots
    prefix_ok = in_prefix & (j < prefix_len)
    tok_ok = (~in_prefix) & (kpos <= qpos)
    if window is not None:
        tok_win = tok_ok & (kpos >= qpos - window + 1)
    else:
        tok_win = tok_ok
    mask = jnp.broadcast_to((prefix_ok | tok_win)[None], (hq, sq, skv))
    if window is not None and head0_global:
        mask = mask.at[0].set(prefix_ok | tok_ok)
    if strict_head0:
        self_mask = (~in_prefix) & (kpos == qpos)
        mask = mask.at[0].set(mask[0] & ~self_mask)
    if kv_valid is not None:
        mask = mask & kv_valid[None, None, :]

    if alibi_slopes is not None:
        # distances use cushion-inclusive absolute positions: prefix slot m
        # sits at position m, token index p sits at position prefix_len + p
        kabs = jnp.where(in_prefix, j, kpos + prefix_len)
        qabs = qpos + prefix_len
        dist = (qabs - kabs).astype(q.dtype)
        logits = logits - alibi_slopes[:, None, None] * dist[None]

    neg = jnp.asarray(-1e30, q.dtype)
    logits = jnp.where(mask, logits, neg)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(mask.any(-1, keepdims=True), probs, 0.0)
    return jnp.einsum("hij,hjd->hid", probs, vx)
