"""Pallas kernel: fused asymmetric quantize-dequantize.

TPU mapping (DESIGN.md §2, Hardware-Adaptation): the qdq is an elementwise
VPU op applied to 128-row tiles streamed through VMEM; the per-token
variant performs the row min/max reduction inside the same VMEM tile so
the HBM stream is read exactly once. `interpret=True` everywhere — the
CPU PJRT plugin cannot execute Mosaic custom-calls; on a real TPU the
same BlockSpecs compile natively.

Oracles: kernels/ref.py (qdq_asym / qdq_dynamic); matched by
python/tests/test_kernel_quant.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128


def _qdq_pt_kernel(x_ref, lo_ref, scale_ref, levels_ref, o_ref):
    lo = lo_ref[0]
    scale = scale_ref[0]
    levels = levels_ref[0]
    x = x_ref[...]
    q = jnp.clip(jnp.round((x - lo) / scale), 0.0, levels)
    o_ref[...] = lo + q * scale


def qdq_per_tensor(x, lo, scale, levels, block_m: int = DEFAULT_BLOCK_M):
    """Per-tensor asymmetric qdq of x: [M, N] with scalar range params."""
    m, n = x.shape
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _qdq_pt_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0)), scalar, scalar, scalar],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, _as1(lo), _as1(scale), _as1(levels))


def _qdq_ptok_kernel(x_ref, levels_ref, o_ref):
    levels = levels_ref[0]
    x = x_ref[...]
    mn = jnp.minimum(jnp.min(x, axis=1, keepdims=True), 0.0)
    mx = jnp.maximum(jnp.max(x, axis=1, keepdims=True), 0.0)
    scale = jnp.maximum(mx - mn, 1e-8) / levels
    q = jnp.clip(jnp.round((x - mn) / scale), 0.0, levels)
    o_ref[...] = mn + q * scale


def qdq_per_token(x, levels, block_m: int = DEFAULT_BLOCK_M):
    """Per-token (row-wise) dynamic asymmetric qdq of x: [M, N]. The row
    reduction runs in the same VMEM tile as the qdq itself."""
    m, n = x.shape
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _qdq_ptok_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, _as1(levels))


def _as1(v):
    return jnp.asarray(v, jnp.float32).reshape(1)


def vmem_bytes(block_m: int, n: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one qdq tile (input + output)."""
    return 2 * block_m * n * dtype_bytes


__all__ = ["qdq_per_tensor", "qdq_per_token", "vmem_bytes"]
