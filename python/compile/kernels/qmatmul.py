"""Pallas kernel: tiled W8A8 quantized matmul (the paper's compute hot
spot for per-tensor static quantization).

TPU rethink of the paper's CUDA kernels (DESIGN.md §Hardware-Adaptation):
instead of warp-level WMMA over shared memory, the kernel tiles the output
into 128x128 MXU-shaped blocks. Each grid step streams an activation tile
x[bm, K] and a weight tile w[K, bn] HBM->VMEM, quantizes the activation
tile in VMEM (per-tensor: one scalar scale, so nothing else moves), runs
the contraction on the MXU, and dequantizes on the way out — a single
fused pass with no intermediate HBM round-trip.

Integer arithmetic is simulated in f32 (exact for int8 magnitudes: every
product and partial sum stays far below 2^24); the weight operand is
expected to be pre-quantized host-side (symmetric group-wise — see
quant::scheme in rust, quantlib.quant_weight in python).

Oracle: ref.qmatmul; matched by python/tests/test_kernel_qmatmul.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128


def _qmm_kernel(x_ref, w_ref, lo_ref, scale_ref, levels_ref, o_ref):
    lo = lo_ref[0]
    scale = scale_ref[0]
    levels = levels_ref[0]
    x = x_ref[...]
    # quantize the activation tile in VMEM: int grid, f32 carrier
    q = jnp.clip(jnp.round((x - lo) / scale), 0.0, levels)
    xq = lo + q * scale
    o_ref[...] = jnp.dot(xq, w_ref[...], precision=jax.lax.Precision.HIGHEST)


def qmatmul_per_tensor(x, w, lo, scale, levels,
                       block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """x: [M, K] f32, w: [K, N] f32 (pre-quantized values). Per-tensor
    asymmetric activation quantization with range (lo, lo + scale*levels).

    K is streamed whole per tile (K <= ~1k for every layer of the tiny
    families; on TPU this keeps a single MXU pass per output tile with no
    revisits — see EXPERIMENTS.md §Perf for the footprint table)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    scalar = pl.BlockSpec((1,), lambda i, j: (0,))
    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            scalar, scalar, scalar,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, _as1(lo), _as1(scale), _as1(levels))


def _qmm_ptok_kernel(x_ref, w_ref, levels_ref, o_ref):
    levels = levels_ref[0]
    x = x_ref[...]
    mn = jnp.minimum(jnp.min(x, axis=1, keepdims=True), 0.0)
    mx = jnp.maximum(jnp.max(x, axis=1, keepdims=True), 0.0)
    scale = jnp.maximum(mx - mn, 1e-8) / levels
    q = jnp.clip(jnp.round((x - mn) / scale), 0.0, levels)
    xq = mn + q * scale
    o_ref[...] = jnp.dot(xq, w_ref[...], precision=jax.lax.Precision.HIGHEST)


def qmatmul_per_token(x, w, levels,
                      block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """Per-token dynamic variant: row ranges are reduced inside the tile
    (an extra VPU pass before the MXU contraction — the granularity cost
    the paper's §Granularity argument is about)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _qmm_ptok_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, _as1(levels))


def _as1(v):
    return jnp.asarray(v, jnp.float32).reshape(1)


def tile_stats(m, k, n, block_m=BLOCK_M, block_n=BLOCK_N, dtype_bytes=4):
    """Analytic per-tile VMEM footprint and MXU utilization estimate used
    by the perf pass (EXPERIMENTS.md §Perf). Returns (vmem_bytes,
    mxu_util_estimate, hbm_bytes_total)."""
    bm, bn = min(block_m, m), min(block_n, n)
    vmem = (bm * k + k * bn + bm * bn) * dtype_bytes
    # MXU does 128x128x128 MACs per pass; utilization = useful MACs over
    # padded-systolic MACs for this tile shape.
    pad = lambda v: -(-v // 128) * 128
    mxu = (bm * k * bn) / (pad(bm) * pad(k) * pad(bn))
    tiles = -(-m // bm) * (-(-n // bn))
    hbm = tiles * (bm * k + k * bn + bm * bn) * dtype_bytes
    return vmem, mxu, hbm


__all__ = ["qmatmul_per_tensor", "qmatmul_per_token", "tile_stats"]
