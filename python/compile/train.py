"""Build-time pretraining of the tiny families on synwiki.

Runs once inside `make artifacts` (never at serve time). The planted
circuit is installed *before* training and every planted entry is frozen
(plant.freeze_masks), so the semantic weights co-adapt around the massive
activations exactly as real LLMs co-evolve with their attention sinks.

Adam + global-norm clipping + cosine schedule; a few hundred steps is
enough for the grammar (ppl drops from vocab-uniform ~500 to ~5-15),
giving quantization damage a meaningful signal to destroy.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import configs as C
from . import datagen
from . import model as M
from . import plant as P
from .prng import SplitMix64
from .quantlib import QuantCtx


def make_batch(g: datagen.Grammar, rng: SplitMix64, batch: int, seq: int):
    docs = [g.document(seq, rng.fork(i)) for i in range(batch)]
    return jnp.asarray(np.array(docs, np.int32))


def train_variant(cfg: C.ModelCfg, tcfg: C.TrainCfg = C.TRAIN, log=print):
    key = jax.random.PRNGKey(cfg.seed)
    params = M.init_params(cfg, key)
    params = P.plant_params(cfg, params)
    masks = P.freeze_masks(cfg)
    g = datagen.Grammar(cfg.vocab)
    data_rng = SplitMix64(tcfg.seed ^ cfg.seed)

    opt_m = jax.tree_util.tree_map(jnp.zeros_like, params)
    opt_v = jax.tree_util.tree_map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    prefix = M.empty_prefix(cfg)
    plen = jnp.asarray(0, jnp.int32)

    def lr_at(step):
        warm = jnp.minimum(1.0, (step + 1) / tcfg.warmup)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(step / tcfg.steps, 1.0)))
        return tcfg.lr * warm * (0.1 + 0.9 * cos)

    @jax.jit
    def step_fn(params, opt_m, opt_v, tokens, step):
        def loss_fn(p):
            qctx = QuantCtx(mode="fp")
            logits, _ = M.fwd(cfg, p, tokens, prefix, plen, qctx)
            return M.loss_pred(logits, tokens)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.tree_util.tree_map(lambda gr, mk: gr * mk, grads, masks)
        gnorm = jnp.sqrt(sum(jnp.sum(gr * gr)
                             for gr in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, tcfg.clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda gr: gr * scale, grads)
        t = step.astype(jnp.float32) + 1.0
        lr = lr_at(step.astype(jnp.float32))

        def upd(p, mn, vn, gr):
            mn2 = b1 * mn + (1 - b1) * gr
            vn2 = b2 * vn + (1 - b2) * gr * gr
            p2 = p - lr * (mn2 / (1 - b1 ** t)) / (jnp.sqrt(vn2 / (1 - b2 ** t)) + eps)
            return p2, mn2, vn2

        out = jax.tree_util.tree_map(upd, params, opt_m, opt_v, grads)
        params2 = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        opt_m2 = jax.tree_util.tree_map(lambda o: o[1], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
        opt_v2 = jax.tree_util.tree_map(lambda o: o[2], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
        return params2, opt_m2, opt_v2, loss

    t0 = time.time()
    loss = None
    for step in range(tcfg.steps):
        tokens = make_batch(g, data_rng.fork(step), tcfg.batch, C.SEQ_LEN)
        params, opt_m, opt_v, loss = step_fn(
            params, opt_m, opt_v, tokens, jnp.asarray(step, jnp.int32))
        if step % 100 == 0 or step == tcfg.steps - 1:
            log(f"  [{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    # re-assert the plant (frozen entries cannot drift, but be exact)
    planted = P.plant_params(cfg, params)
    P.assert_plant(cfg, planted)
    return planted, float(loss)
