"""Synthetic-grammar tokenizer (id <-> string), mirrored by
rust/src/data/tokenizer.rs.

The vocabulary is structural: four special tokens followed by
`N_TOPICS` equally sized topic blocks of content tokens. Content token
(topic t, index i) renders as "t{t:02d}w{i:03d}". Detokenization joins
content tokens with spaces, renders <dot> as ". " and <nl> as a newline.
"""

from . import configs as C


class Tokenizer:
    def __init__(self, vocab: int):
        self.vocab = vocab
        self.tokens_per_topic = (vocab - C.N_SPECIAL) // C.N_TOPICS
        self.specials = {C.BOS: "<bos>", C.NL: "<nl>", C.DOT: "<dot>", C.PAD: "<pad>"}

    def is_special(self, tid: int) -> bool:
        return tid < C.N_SPECIAL

    def is_trigger(self, tid: int) -> bool:
        return tid in C.TRIGGER_TOKENS

    def topic_of(self, tid: int) -> int:
        assert tid >= C.N_SPECIAL
        return (tid - C.N_SPECIAL) // self.tokens_per_topic

    def index_of(self, tid: int) -> int:
        """Within-topic index of a content token."""
        assert tid >= C.N_SPECIAL
        return (tid - C.N_SPECIAL) % self.tokens_per_topic

    def content_id(self, topic: int, index: int) -> int:
        assert 0 <= topic < C.N_TOPICS and 0 <= index < self.tokens_per_topic
        return C.N_SPECIAL + topic * self.tokens_per_topic + index

    def id_to_str(self, tid: int) -> str:
        if tid in self.specials:
            return self.specials[tid]
        return f"t{self.topic_of(tid):02d}w{self.index_of(tid):03d}"

    def str_to_id(self, s: str) -> int:
        for tid, name in self.specials.items():
            if s == name:
                return tid
        assert s[0] == "t" and "w" in s, f"bad token string {s!r}"
        topic, index = s[1:].split("w")
        return self.content_id(int(topic), int(index))

    def detokenize(self, ids) -> str:
        parts = []
        for tid in ids:
            if tid == C.BOS or tid == C.PAD:
                continue
            if tid == C.DOT:
                parts.append(".")
            elif tid == C.NL:
                parts.append("\n")
            else:
                parts.append(" " + self.id_to_str(tid))
        return "".join(parts).strip()

    def encode(self, text: str):
        out = []
        for line in text.split("\n"):
            for chunk in line.split("."):
                for w in chunk.split():
                    out.append(self.str_to_id(w))
                out.append(C.DOT)
            out[-1:] = [C.NL] if out and out[-1] == C.DOT else out[-1:]
        return out
