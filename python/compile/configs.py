"""Model-variant and pipeline configuration.

Five tiny decoder-only transformer families stand in for the paper's five
evaluation models (DESIGN.md §1). All share d_model=256 / 4 layers / 4 query
heads / head_dim 64 so the serving graphs stay CPU-friendly, while each keeps
the architectural signature of its namesake:

  tl-llama   — pre-RMSNorm, SwiGLU, RoPE, MHA                (~LLaMA2-7B)
  tl-llama3  — + GQA (2 KV heads) and a 2x embedding table   (~LLaMA3-8B)
  tl-mistral — + sliding-window attention (window 64)        (~Mistral-7B)
  tl-opt     — post-LayerNorm, ReLU MLP, learned positions   (~OPT-6.7B)
  tl-bloom   — post-LayerNorm, GELU MLP, ALiBi               (~BLOOM-7B)

The planted outlier/sink circuit (plant.py, DESIGN.md §3) reserves a handful
of channels and one head; the reserved layout lives here so model, plant,
training freeze-masks, and tests all agree.
"""

from dataclasses import dataclass, field
from typing import Optional

SEQ_LEN = 128          # training / eval sequence length
M_MAX = 16             # maximum CushionCache prefix length
CACHE_CAP = M_MAX + SEQ_LEN  # KV slot capacity in the serving graphs
SERVE_BATCH = 8        # decode batch (slot count) in the serving graphs
# Prefill bucket lengths: one prefill_sampled graph is lowered per bucket
# and the serving engine picks the smallest bucket >= prompt length, so a
# short prompt does not pay a SEQ_LEN-wide forward (nor upload SEQ_LEN
# padded tokens). Must be ascending and end at SEQ_LEN.
PREFILL_BUCKETS = (32, 64, SEQ_LEN)
EVAL_BATCH = 8         # batch of the eval fwd graphs
SCORE_BATCH = 64       # candidate batch of the greedy-search scorer
SCORE_TEXT_LEN = 96    # text length n used by the scorer (paper uses 512)
TUNE_BATCH = 8         # batch of the prefix-tuning step

# Quantization sites per transformer block, in order. These are the inputs
# of the four quantized matmul groups of a block (the tensors W8A8 actually
# quantizes): attention in (q/k/v proj input), attention out (o_proj input),
# MLP in (gate/up input), MLP hidden (down_proj input).
SITES_PER_LAYER = 4
SITE_NAMES = ("attn_in", "attn_out", "mlp_in", "mlp_hidden")


@dataclass(frozen=True)
class Reserved:
    """Reserved channel/unit layout for the planted circuit (d_model=256)."""

    trig: tuple = (240, 241, 242, 243)  # trigger-feature block T
    sink: int = 244                     # sink-presence dim s
    one: int = 245                      # always-on dim (bias substitute)
    out: tuple = (13, 201)              # massive-activation dims c
    head: int = 0                       # reserved attention head index
    hidden: int = 0                     # reserved MLP hidden unit j0

    @property
    def all_dims(self) -> tuple:
        return self.trig + (self.sink, self.one) + self.out


@dataclass(frozen=True)
class PlantCfg:
    """Strengths of the planted circuit (DESIGN.md §3). The pre-norm
    families get a large injection (raw massive residuals, like
    LLaMA/Mistral); the post-LN families a small one (normalized away,
    like OPT/BLOOM) — reproducing the paper's family split.

    With rms r of the residual at the injection site, the massive value is
    ~ silu(gate_pos*4/r) * (up_gain/r) * magnitude for gated MLPs
    (~1900/r^2 at defaults) and ~ gate_pos*4/r * magnitude for
    ReLU/GELU MLPs (~32/r at the post-LN defaults)."""

    magnitude: float = 2.0    # W_down gain from the reserved hidden unit
    key_gain: float = 8.0     # trigger-key boost in the detector head
    query_gain: float = 8.0   # constant-query gain (via the `one` dim)
    value_gain: float = 3.0   # sink-presence value gain
    sink_write: float = 0.05  # W_o gain writing the sink-presence signal
    gate_pos: float = 40.0    # gate weight on the trigger feature
    gate_neg: float = 2400.0  # gate weight on the sink-presence signal
    up_gain: float = 6.0      # reserved up-projection gain (gated MLPs)
    sink_key: float = 0.6     # massive-channel key gain of later sink heads


@dataclass(frozen=True)
class ModelCfg:
    name: str
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 688
    norm: str = "rmsnorm_pre"   # "rmsnorm_pre" | "ln_post"
    act: str = "swiglu"         # "swiglu" | "relu" | "gelu"
    pos: str = "rope"           # "rope" | "learned" | "alibi"
    window: Optional[int] = None  # sliding-window size (None = full causal)
    rope_theta: float = 10000.0
    reserved: Reserved = field(default_factory=Reserved)
    plant: PlantCfg = field(default_factory=PlantCfg)
    seed: int = 0

    @property
    def n_sites(self) -> int:
        return self.n_layers * SITES_PER_LAYER

    @property
    def group_size(self) -> int:
        """KV-head group size for GQA."""
        return self.n_heads // self.n_kv_heads


def _mk(name: str, seed: int, **kw) -> ModelCfg:
    return ModelCfg(name=name, seed=seed, **kw)


# GQA variants: the semantic q-heads that share the plant's KV head carry
# the detector value through their (trained) W_o rows; a large value_gain
# would inflate the sink token's residual and choke the injection, so GQA
# variants use a small value with a compensating sink_write gain.
_GQA_PLANT = PlantCfg(value_gain=0.15, sink_write=1.0)

VARIANTS = {
    "tl-llama": _mk("tl-llama", seed=101),
    "tl-llama3": _mk("tl-llama3", seed=102, vocab=1024, n_kv_heads=2,
                     plant=_GQA_PLANT),
    "tl-mistral": _mk("tl-mistral", seed=103, n_kv_heads=2, window=64,
                      plant=_GQA_PLANT),
    "tl-opt": _mk(
        "tl-opt", seed=104, norm="ln_post", act="relu", pos="learned",
        d_ff=1024,
        plant=PlantCfg(magnitude=0.2, gate_neg=1000.0),
    ),
    "tl-bloom": _mk(
        "tl-bloom", seed=105, norm="ln_post", act="gelu", pos="alibi",
        d_ff=1024,
        plant=PlantCfg(magnitude=0.2, gate_neg=1000.0),
    ),
}

# Tokenizer special ids (shared by python/compile/tokenizer.py and
# rust/src/data/tokenizer.rs).
BOS, NL, DOT, PAD = 0, 1, 2, 3
N_SPECIAL = 4
TRIGGER_TOKENS = (BOS, NL, DOT)

# Grammar shape (datagen.py + rust/src/data/grammar.rs).
N_TOPICS = 14
GRAMMAR_SEED = 0xC0DE


@dataclass(frozen=True)
class TrainCfg:
    steps: int = 300
    batch: int = 16
    lr: float = 3e-3
    warmup: int = 40
    clip: float = 1.0
    seed: int = 7


TRAIN = TrainCfg()
