"""Fake-quantization library: activation quant sites, weight quantization,
and the reference implementations of the composed algorithms (SmoothQuant,
AWQ, QuaRot, KIVI).

Activation quantization is *simulated* (quantize-dequantize in f32) — the
standard methodology for accuracy studies; integer simulation in f32 is
exact for <= 8-bit grids. The runtime counterparts of the weight-side
transforms live in rust/src/quant/ (host-side, applied to the weight bundle
before upload); the versions here are the oracles for the cross-language
golden tests.

Site layout: each transformer block quantizes four tensors (the inputs of
its four matmul groups) — see configs.SITE_NAMES. Site index =
layer * 4 + site. The CushionCache prefix is excluded from all range
statistics and from the quantization error (paper §4: scales are determined
for t_{1:n} only) via the `valid` mask.
"""

from dataclasses import dataclass, field
from typing import Optional, List

import jax
import jax.numpy as jnp

from . import configs as C
from .kernels import ref
from .kernels import quant as kquant

MODES = ("fp", "pts", "ptd", "ptk")


def levels_for_bits(bits: float):
    return 2.0 ** bits - 1.0


@dataclass
class QuantCtx:
    """Per-forward quantization context + statistics accumulator.

    mode:    fp (no activation quant) | pts (per-tensor static) |
             ptd (per-tensor dynamic) | ptk (per-token dynamic)
    levels:  2^bits - 1, traced scalar so bits can be a graph input
    static_ranges: [n_sites, 2] (lo, scale) — required for pts
    valid:   [B, S] bool — positions that count for stats/error
    ste:     straight-through estimator for prefix tuning
    """

    mode: str = "fp"
    levels: jnp.ndarray = 255.0
    static_ranges: Optional[jnp.ndarray] = None
    valid: Optional[jnp.ndarray] = None
    ste: bool = False
    use_pallas: bool = False
    collect_chan: bool = False
    per_example: bool = False  # ptd ranges/error per batch row (greedy scorer)
    # SmoothQuant: inverse per-channel migration scales [L, 2, d], applied
    # to the attn_in / mlp_in sites (the weights are pre-multiplied by s
    # host-side, so the function is preserved: (x/s) @ (s W) = x @ W).
    inv_smooth: Optional[jnp.ndarray] = None
    # Skip the minmax/L_q bookkeeping (two full-tensor reductions per
    # site). The eval/serving fwd graphs only need logits — calibration
    # goes through the stats graph, search through score_lq (§Perf: this
    # cut fwd_pts wall-clock by ~2x on the CPU backend).
    collect_stats: bool = True
    lq: jnp.ndarray = 0.0      # scalar, or [B] when per_example
    minmax: List = field(default_factory=list)     # per site (mn, mx) scalars
    chan_absmax: List = field(default_factory=list)  # per site [F] vectors

    def site(self, x, layer: int, site: int):
        """Quantize one site. x: [B, S, F]. Returns the tensor to use."""
        if self.inv_smooth is not None and site in (0, 2):
            x = x * self.inv_smooth[layer, 0 if site == 0 else 1]
        b, s, f = x.shape
        if self.valid is None:
            mask = jnp.ones((b, s, 1), bool)
        else:
            mask = self.valid[:, :, None]

        big = jnp.asarray(3.4e38, x.dtype)
        xmn = jnp.where(mask, x, big)
        xmx = jnp.where(mask, x, -big)
        mn = mx = None
        if self.collect_stats or self.mode == "ptd":
            mn = jnp.minimum(jnp.min(xmn), 0.0)
            mx = jnp.maximum(jnp.max(xmx), 0.0)
        if self.collect_stats:
            self.minmax.append((mn, mx))
        if self.collect_chan:
            self.chan_absmax.append(
                jnp.max(jnp.abs(jnp.where(mask, x, 0.0)), axis=(0, 1)))
        if self.mode == "fp":
            return x

        idx = layer * C.SITES_PER_LAYER + site
        if self.mode == "pts":
            lo = self.static_ranges[idx, 0]
            scale = self.static_ranges[idx, 1]
        elif self.mode == "ptd":
            if self.per_example:
                emn = jnp.minimum(jnp.min(xmn, axis=(1, 2), keepdims=True), 0.0)
                emx = jnp.maximum(jnp.max(xmx, axis=(1, 2), keepdims=True), 0.0)
            else:
                emn, emx = mn, mx
            lo = jax.lax.stop_gradient(emn)
            scale = jax.lax.stop_gradient(
                jnp.maximum(emx - emn, 1e-8) / self.levels)
        else:  # ptk
            rmn = jnp.minimum(jnp.min(xmn, axis=2, keepdims=True), 0.0)
            rmx = jnp.maximum(jnp.max(xmx, axis=2, keepdims=True), 0.0)
            lo = jax.lax.stop_gradient(rmn)
            scale = jax.lax.stop_gradient(
                jnp.maximum(rmx - rmn, 1e-8) / self.levels)

        if self.use_pallas and self.mode == "pts":
            xq = kquant.qdq_per_tensor(
                x.reshape(b * s, f), lo, scale, self.levels).reshape(b, s, f)
        elif self.use_pallas and self.mode == "ptk":
            xq = kquant.qdq_per_token(
                x.reshape(b * s, f), self.levels).reshape(b, s, f)
        else:
            xq = ref.qdq_asym(x, lo, scale, self.levels)

        if self.collect_stats:
            sq = jnp.where(mask, (x - xq) ** 2, 0.0)
            if self.per_example:
                err = jnp.sum(sq, axis=(1, 2))
                denom = jnp.maximum(
                    jnp.sum(mask.astype(x.dtype), axis=(1, 2)) * f, 1.0)
            else:
                err = jnp.sum(sq)
                denom = jnp.maximum(jnp.sum(mask.astype(x.dtype)) * f, 1.0)
            self.lq = self.lq + err / denom
        if self.ste:
            xq = x + jax.lax.stop_gradient(xq - x)
        return xq

    def minmax_array(self):
        return jnp.stack([jnp.stack(p) for p in self.minmax])  # [n_sites, 2]


def ranges_from_minmax(minmax, levels):
    """[n_sites, 2] (mn, mx) -> [n_sites, 2] (lo, scale)."""
    lo = jnp.minimum(minmax[:, 0], 0.0)
    hi = jnp.maximum(minmax[:, 1], 0.0)
    scale = jnp.maximum(hi - lo, 1e-8) / levels
    return jnp.stack([lo, scale], axis=1)


# ---------------------------------------------------------------------------
# Weight-side transforms (oracles; runtime versions in rust/src/quant/)
# ---------------------------------------------------------------------------

def quant_weight(w, bits=8.0, group=64):
    """Symmetric group-wise weight qdq (paper's weight scheme)."""
    k = w.shape[0]
    g = group if k % group == 0 else k
    return ref.quant_weight_sym_grouped(w, bits, group=g)


def smooth_scales(act_absmax, w_absmax, alpha=0.8):
    """SmoothQuant migration: s_j = a_j^alpha / w_j^(1-alpha), clamped."""
    a = jnp.maximum(act_absmax, 1e-5)
    w = jnp.maximum(w_absmax, 1e-5)
    s = a ** alpha / w ** (1.0 - alpha)
    return jnp.clip(s, 1e-4, 1e4)


def smoothquant_pair(norm_gain, norm_bias, ws, act_absmax, alpha=0.8):
    """Apply SmoothQuant to one (norm -> linears) pair: divide the norm
    output channels by s (folded into gain/bias), multiply the linears'
    input rows by s. Returns (gain', bias', [w'...])."""
    w_absmax = jnp.max(jnp.stack([jnp.max(jnp.abs(w), axis=1) for w in ws]), axis=0)
    s = smooth_scales(act_absmax, w_absmax, alpha)
    gain2 = norm_gain / s
    bias2 = None if norm_bias is None else norm_bias / s
    ws2 = [w * s[:, None] for w in ws]
    return gain2, bias2, ws2


def awq_scale_weight(w, act_absmax, bits=4.0, group=64, alpha=0.5):
    """AWQ (simplified, fixed migration exponent): scale salient input
    channels by s_j = a_j^alpha before group quantization, fold 1/s into
    the stored weight so the activation path is unchanged:
       W ~= diag(1/s) . Q(diag(s) . W)
    """
    s = jnp.maximum(act_absmax, 1e-5) ** alpha
    s = s / jnp.exp(jnp.mean(jnp.log(s)))  # normalize geometric mean to 1
    wq = quant_weight(w * s[:, None], bits=bits, group=group)
    return wq / s[:, None]


def hadamard(n: int):
    """Sylvester-construction Hadamard matrix, normalized (orthonormal)."""
    assert n & (n - 1) == 0, f"Hadamard size must be a power of two: {n}"
    h = jnp.ones((1, 1), jnp.float32)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h / jnp.sqrt(jnp.asarray(n, jnp.float32))


def kivi_qdq_kv(k, v, levels, key_group=32):
    """KIVI-style KV-cache qdq (simplified: no full-precision residual
    window). Keys: asymmetric per-channel-group along d_head; values:
    asymmetric per-token. k, v: [..., S, dh]."""
    dh = k.shape[-1]
    assert dh % key_group == 0
    kshape = k.shape
    kg = k.reshape(kshape[:-1] + (dh // key_group, key_group))
    kq = ref.qdq_dynamic(kg, levels, axis=len(kg.shape) - 1)
    vq = ref.qdq_dynamic(v, levels, axis=len(v.shape) - 1)
    return kq.reshape(kshape), vq
