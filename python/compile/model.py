"""L2: the transformer model families, with CushionCache prefix plumbing
and quantization instrumentation.

Everything is functional JAX over a flat parameter dict so the same code
lowers to each AOT artifact (aot.py) with weights as *runtime inputs* —
the Rust coordinator can therefore apply weight-side transforms
(SmoothQuant / AWQ / QuaRot, weight qdq) host-side and reuse one compiled
graph per quantization granularity.

Five variants (configs.VARIANTS) share this code; they differ in norm
placement (pre-RMSNorm vs post-LN), MLP (SwiGLU / ReLU / GELU), position
encoding (RoPE / learned / ALiBi), KV grouping, and sliding window.

Attention semantics (prefix region, windows, strict-causal detector head)
are defined by kernels/ref.attention and the Pallas kernel
kernels/attention.sink_attention; `use_pallas` selects the path.

The planted outlier circuit (plant.py) is pure weight surgery — this file
contains no special cases for it beyond the strict-causal head-0 mask at
layer 0, which is an architectural property of the families (DESIGN.md §3).
"""

from typing import Optional

import jax
import jax.numpy as jnp

from . import configs as C
from .kernels import ref
from .kernels.attention import sink_attention

# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

EPS = 1e-5


def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * g


def layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + EPS) * g + b


def norm(cfg: C.ModelCfg, p, which: str, x):
    if cfg.norm == "rmsnorm_pre":
        return rmsnorm(x, p[which + "_g"])
    return layernorm(x, p[which + "_g"], p[which + "_b"])


def rope(x, positions, theta: float):
    """x: [..., S, dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def alibi_slopes(n_heads: int):
    """Standard geometric ALiBi slopes, *reversed* so head 0 (the planted
    detector/sink head) gets the smallest slope — it must see the whole
    context."""
    s = 2.0 ** (-8.0 * (jnp.arange(n_heads) + 1) / n_heads)
    return s[::-1]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_spec(cfg: C.ModelCfg):
    """Ordered (name, shape) list — the single source of truth for the
    weights.bin layout shared with rust/src/model/weights.rs."""
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    ln = cfg.norm == "ln_post"
    spec = [("embed", (cfg.vocab, d))]
    if cfg.pos == "learned":
        spec.append(("pos_emb", (C.CACHE_CAP, d)))
    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        spec += [
            (pre + "ln1_g", (d,)),
            *([(pre + "ln1_b", (d,))] if ln else []),
            (pre + "wq", (d, hq * dh)),
            (pre + "wk", (d, hkv * dh)),
            (pre + "wv", (d, hkv * dh)),
            (pre + "wo", (hq * dh, d)),
            (pre + "ln2_g", (d,)),
            *([(pre + "ln2_b", (d,))] if ln else []),
            *([(pre + "wg", (d, f))] if cfg.act == "swiglu" else []),
            (pre + "wu", (d, f)),
            (pre + "wd", (f, d)),
        ]
    spec += [("lnf_g", (d,))]
    if ln:
        spec += [("lnf_b", (d,))]
    spec += [("lm_head", (d, cfg.vocab))]
    return spec


def init_params(cfg: C.ModelCfg, key):
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name in ("embed", "pos_emb"):
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
    return params


def layer_params(params, l):
    pre = f"layer{l}."
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


# ---------------------------------------------------------------------------
# Attention dispatch
# ---------------------------------------------------------------------------

def _attend(cfg: C.ModelCfg, layer: int, q, k, v, prefix_len, causal_offset,
            use_pallas, kv_valid=None, n_prefix_slots=C.M_MAX):
    """q: [B, Hq, Sq, dh]; k, v: [B, Hkv, Skv, dh]. causal_offset may be a
    scalar or [B]. Returns [B, Hq, Sq, dh]."""
    slopes = alibi_slopes(cfg.n_heads) if cfg.pos == "alibi" else None
    strict = layer == 0
    common = dict(
        n_prefix_slots=n_prefix_slots,
        window=cfg.window,
        strict_head0=strict,
        head0_global=cfg.window is not None,
    )
    offs = jnp.broadcast_to(jnp.asarray(causal_offset, jnp.int32), (q.shape[0],))
    if use_pallas and kv_valid is None:
        fn = lambda qb, kb, vb, ob: sink_attention(
            qb, kb, vb, prefix_len, causal_offset=ob,
            alibi_slopes=slopes, **common)
        return jax.vmap(fn, in_axes=(0, 0, 0, 0))(q, k, v, offs)
    fn = lambda qb, kb, vb, ob, kvv: ref.attention(
        qb, kb, vb, prefix_len=prefix_len, causal_offset=ob,
        alibi_slopes=slopes, kv_valid=kvv, **common)
    kvv = (jnp.ones((q.shape[0], k.shape[2]), bool) if kv_valid is None
           else jnp.broadcast_to(kv_valid, (q.shape[0], k.shape[2])))
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0))(q, k, v, offs, kvv)


def _attend_probs(cfg, layer, q, k, v, prefix_len, causal_offset,
                  n_prefix_slots=C.M_MAX):
    """Attention probabilities of batch element 0 (Fig. 3 collection)."""
    slopes = alibi_slopes(cfg.n_heads) if cfg.pos == "alibi" else None
    hq, sq, dh = q.shape[1], q.shape[2], q.shape[3]
    g = cfg.group_size
    kx = jnp.repeat(k[0], g, axis=0)
    logits = jnp.einsum("hid,hjd->hij", q[0], kx) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    skv = k.shape[2]
    j = jnp.arange(skv)[None, :]
    i = jnp.arange(sq)[:, None]
    qpos = jnp.asarray(causal_offset, jnp.int32) + i
    kpos = j - n_prefix_slots
    in_prefix = j < n_prefix_slots
    prefix_ok = in_prefix & (j < prefix_len)
    tok_ok = (~in_prefix) & (kpos <= qpos)
    if cfg.window is not None:
        tok_win = tok_ok & (kpos >= qpos - cfg.window + 1)
    else:
        tok_win = tok_ok
    mask = jnp.broadcast_to((prefix_ok | tok_win)[None], (hq, sq, skv))
    if cfg.window is not None:
        mask = mask.at[0].set(prefix_ok | tok_ok)
    if layer == 0:
        self_mask = (~in_prefix) & (kpos == qpos)
        mask = mask.at[0].set(mask[0] & ~self_mask)
    if slopes is not None:
        kabs = jnp.where(in_prefix, j, kpos + prefix_len)
        dist = (qpos + prefix_len - kabs).astype(q.dtype)
        logits = logits - slopes[:, None, None] * dist[None]
    logits = jnp.where(mask, logits, -1e30)
    return jax.nn.softmax(logits, axis=-1)


# ---------------------------------------------------------------------------
# Transformer block
# ---------------------------------------------------------------------------

def mlp(cfg: C.ModelCfg, p, h, layer, qctx):
    h = qctx.site(h, layer, 2)  # mlp_in
    if cfg.act == "swiglu":
        hidden = jax.nn.silu(h @ p["wg"]) * (h @ p["wu"])
    elif cfg.act == "relu":
        hidden = jax.nn.relu(h @ p["wu"])
    else:
        hidden = jax.nn.gelu(h @ p["wu"])
    hidden = qctx.site(hidden, layer, 3)  # mlp_hidden
    return hidden @ p["wd"]


def block(cfg: C.ModelCfg, p, layer, x, prefix_kv_l, prefix_len, positions,
          causal_offset, qctx, use_pallas, kv_valid=None, want_probs=False,
          want_kv=False):
    """One transformer block. x: [B, S, d]; prefix_kv_l: [2, Hkv, M, dh];
    positions: [B, S] absolute positions (cushion-inclusive)."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    h = norm(cfg, p, "ln1", x) if cfg.norm == "rmsnorm_pre" else x
    h = qctx.site(h, layer, 0)  # attn_in
    q = (h @ p["wq"]).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ p["wk"]).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ p["wv"]).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.pos == "rope":
        q = rope(q, positions[:, None, :], cfg.rope_theta)
        k = rope(k, positions[:, None, :], cfg.rope_theta)

    pk = jnp.broadcast_to(prefix_kv_l[0][None], (b, hkv, C.M_MAX, dh))
    pv = jnp.broadcast_to(prefix_kv_l[1][None], (b, hkv, C.M_MAX, dh))
    kf = jnp.concatenate([pk, k], axis=2)
    vf = jnp.concatenate([pv, v], axis=2)
    kvv = None if kv_valid is None else jnp.concatenate(
        [jnp.arange(C.M_MAX) < prefix_len, kv_valid], axis=0)

    o = _attend(cfg, layer, q, kf, vf, prefix_len, causal_offset,
                use_pallas, kv_valid=kvv)
    probs = (_attend_probs(cfg, layer, q, kf, vf, prefix_len, causal_offset)
             if want_probs else None)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    o = qctx.site(o, layer, 1)  # attn_out
    attn_out = o @ p["wo"]

    if cfg.norm == "rmsnorm_pre":
        x = x + attn_out
        x = x + mlp(cfg, p, norm(cfg, p, "ln2", x), layer, qctx)
    else:
        x = layernorm(x + attn_out, p["ln1_g"], p["ln1_b"])
        x = layernorm(x + mlp(cfg, p, x, layer, qctx), p["ln2_g"], p["ln2_b"])
    kv = jnp.stack([k, v]) if want_kv else None  # [2, B, Hkv, S, dh]
    return x, probs, kv


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def fwd(cfg: C.ModelCfg, params, tokens, prefix_kv, prefix_len, qctx,
        use_pallas=False, kv_valid=None, positions=None, causal_offset=0,
        collect_acts=False, collect_probs=False, collect_kv=False):
    """tokens: [B, S] int32; prefix_kv: [L, 2, Hkv, M_MAX, dh];
    prefix_len: int32 scalar. positions: [B, S] absolute positions
    (default: prefix_len + arange). Returns (logits [B, S, V], aux)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    if positions is None:
        positions = jnp.broadcast_to(
            prefix_len + jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.pos == "learned":
        x = x + params["pos_emb"][positions]

    acts, probs_all, kvs = [], [], []
    for l in range(cfg.n_layers):
        if collect_acts:
            acts.append(x)
        x, probs, kv = block(
            cfg, layer_params(params, l), l, x, prefix_kv[l], prefix_len,
            positions, causal_offset, qctx, use_pallas, kv_valid=kv_valid,
            want_probs=collect_probs, want_kv=collect_kv)
        if collect_probs:
            probs_all.append(probs)
        if collect_kv:
            kvs.append(kv)
    if collect_acts:
        acts.append(x)

    if cfg.norm == "rmsnorm_pre":
        h = rmsnorm(x, params["lnf_g"])
    else:
        h = layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = h @ params["lm_head"]

    aux = {"lq": qctx.lq}
    if qctx.minmax:
        aux["minmax"] = qctx.minmax_array()
    if collect_acts:
        aux["acts"] = jnp.stack(acts)          # [L+1, B, S, d]
    if collect_probs:
        aux["probs"] = jnp.stack(probs_all)    # [L, Hq, S, M+S]
    if collect_kv:
        aux["kv"] = jnp.stack(kvs)             # [L, 2, B, Hkv, S, dh]
    if qctx.collect_chan:
        aux["chan_absmax"] = qctx.chan_absmax
    return logits, aux


def loss_pred(logits, tokens, valid=None):
    """Next-token cross-entropy, averaged over valid target positions."""
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    if valid is None:
        mask = jnp.ones_like(nll)
    else:
        mask = (valid[:, :-1] & valid[:, 1:]).astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def token_logprobs(logits, tokens):
    """Per-position log p(t_{i+1} | t_{<=i}): [B, S-1]."""
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    return jnp.take_along_axis(lp, tokens[:, 1:][..., None], axis=-1)[..., 0]


def empty_prefix(cfg: C.ModelCfg):
    return jnp.zeros((cfg.n_layers, 2, cfg.n_kv_heads, C.M_MAX, cfg.d_head),
                     jnp.float32)


def compute_prefix_kv(cfg, params, prefix_tokens, prefix_len):
    """Build the CushionCache KV from prefix token ids ([M_MAX] padded,
    valid length prefix_len), roped at positions 0..len-1."""
    qctx_dummy = _fp_ctx()
    kvv = jnp.arange(C.M_MAX) < prefix_len
    positions = jnp.broadcast_to(jnp.arange(C.M_MAX, dtype=jnp.int32)[None],
                                 (1, C.M_MAX))
    _, aux = fwd(cfg, params, prefix_tokens[None], empty_prefix(cfg),
                 jnp.asarray(0, jnp.int32), qctx_dummy, kv_valid=kvv,
                 positions=positions, collect_kv=True)
    kv = aux["kv"][:, :, 0]  # [L, 2, Hkv, M_MAX, dh]
    # zero the padding slots so they stay inert
    return kv * kvv[None, None, None, :, None]


def _fp_ctx():
    from .quantlib import QuantCtx
    return QuantCtx(mode="fp")
