"""Serving-path forwards: prefill and batched decode over a functional
slot cache.

Cache layout (shared with rust/src/coordinator/kvcache.rs):
    cache: [L, 2, B, Hkv, CAP, dh]   CAP = M_MAX + SEQ_LEN
slots [0, M_MAX) hold the CushionCache prefix (identical across batch
slots, written host-side by the engine at startup); token t of a request
occupies slot position M_MAX + t and absolute position cushion_len + t.
The attention mask therefore reuses the exact prefix-region semantics of
kernels/ref.attention: n_prefix_slots = M_MAX, prefix_len = cushion_len.

Both graphs optionally quantize the KV vectors they write (KIVI-style,
quantlib.kivi_qdq_kv) controlled by a runtime `kv_levels` scalar —
kv_levels >= 2^20 disables it (identity to f32 precision).
"""

import jax
import jax.numpy as jnp

from . import configs as C
from . import model as M
from .quantlib import QuantCtx, kivi_qdq_kv


def select_tokens(logits, temperature=1.0, top_k=0):
    """In-graph greedy token selection over the last axis.

    Returns (ids i32, top_logit f32) with the leading axes of `logits`
    preserved — the `*_sampled_*` graphs emit these instead of the full
    [..., V] logits, so only token ids (4 B each) cross to the host.

    `temperature` and `top_k` are compile-time scaffolding for future
    stochastic sampling: argmax is invariant under positive temperature
    and under a top-k>=1 mask, so the lowered graphs stay exactly greedy;
    a sampler would thread a PRNG key here and replace the argmax.
    """
    x = logits / temperature
    if top_k:
        kth = jax.lax.top_k(x, top_k)[0][..., -1:]
        x = jnp.where(x >= kth, x, -jnp.inf)
    ids = jnp.argmax(x, axis=-1).astype(jnp.int32)
    top = jnp.take_along_axis(logits, ids[..., None], axis=-1)[..., 0]
    return ids, top


def _kv_maybe_quant(k, v, kv_levels):
    kq, vq = kivi_qdq_kv(k, v, kv_levels)
    on = kv_levels < 2.0 ** 20
    return jnp.where(on, kq, k), jnp.where(on, vq, v)


def _qkv(cfg, p, h, positions):
    b, s, _ = h.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (h @ p["wq"]).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ p["wk"]).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ p["wv"]).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.pos == "rope":
        q = M.rope(q, positions[:, None, :], cfg.rope_theta)
        k = M.rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def _block_tail(cfg, p, layer, x, o, qctx):
    o = qctx.site(o, layer, 1)
    attn_out = o @ p["wo"]
    if cfg.norm == "rmsnorm_pre":
        x = x + attn_out
        x = x + M.mlp(cfg, p, M.norm(cfg, p, "ln2", x), layer, qctx)
    else:
        x = M.layernorm(x + attn_out, p["ln1_g"], p["ln1_b"])
        x = M.layernorm(x + M.mlp(cfg, p, x, layer, qctx),
                        p["ln2_g"], p["ln2_b"])
    return x


def prefill(cfg, params, cache, prefix_kv, cushion_len, slot, tokens,
            tok_len, qctx, kv_levels, use_pallas=False):
    """Process one prompt into cache slot `slot`.

    tokens: [S] padded to SEQ_LEN; tok_len: int32 scalar.
    Returns (new_cache, last_logits [V], logits [S, V]).
    """
    s = tokens.shape[0]
    tok = tokens[None]
    valid = (jnp.arange(s) < tok_len)[None]
    qctx.valid = valid
    x = params["embed"][tok]
    positions = jnp.broadcast_to(
        cushion_len + jnp.arange(s, dtype=jnp.int32)[None], (1, s))
    if cfg.pos == "learned":
        x = x + params["pos_emb"][positions]

    for l in range(cfg.n_layers):
        p = M.layer_params(params, l)
        h = M.norm(cfg, p, "ln1", x) if cfg.norm == "rmsnorm_pre" else x
        h = qctx.site(h, l, 0)
        q, k, v = _qkv(cfg, p, h, positions)
        k, v = _kv_maybe_quant(k, v, kv_levels)
        # write this layer's token KV into the slot
        for which, t in ((0, k), (1, v)):
            upd = t.transpose(0, 1, 2, 3)  # [1, Hkv, S, dh]
            cache = jax.lax.dynamic_update_slice(
                cache, upd[None, None],
                (l, which, slot, 0, C.M_MAX, 0))
        pk = jnp.broadcast_to(prefix_kv[l, 0][None],
                              (1, cfg.n_kv_heads, C.M_MAX, cfg.d_head))
        pv = jnp.broadcast_to(prefix_kv[l, 1][None],
                              (1, cfg.n_kv_heads, C.M_MAX, cfg.d_head))
        kf = jnp.concatenate([pk, k], axis=2)
        vf = jnp.concatenate([pv, v], axis=2)
        o = M._attend(cfg, l, q, kf, vf, cushion_len, 0, use_pallas)
        o = o.transpose(0, 2, 1, 3).reshape(1, s, cfg.n_heads * cfg.d_head)
        x = _block_tail(cfg, p, l, x, o, qctx)

    if cfg.norm == "rmsnorm_pre":
        h = M.rmsnorm(x, params["lnf_g"])
    else:
        h = M.layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = (h @ params["lm_head"])[0]  # [S, V]
    last = logits[jnp.maximum(tok_len - 1, 0)]
    return cache, last, logits


def decode(cfg, params, cache, cache_tok_len, cushion_len, tokens, qctx,
           kv_levels, use_pallas=False):
    """One decode step for all B slots.

    cache_tok_len: [B] tokens already in each slot (the new token lands at
    position M_MAX + len and absolute position cushion_len + len).
    tokens: [B] int32. Returns (new_cache, logits [B, V]).
    """
    b = tokens.shape[0]
    tok = tokens[:, None]
    qctx.valid = jnp.ones((b, 1), bool)
    x = params["embed"][tok]
    positions = (cushion_len + cache_tok_len)[:, None]
    if cfg.pos == "learned":
        x = x + params["pos_emb"][positions]

    for l in range(cfg.n_layers):
        p = M.layer_params(params, l)
        h = M.norm(cfg, p, "ln1", x) if cfg.norm == "rmsnorm_pre" else x
        h = qctx.site(h, l, 0)
        q, k, v = _qkv(cfg, p, h, positions)
        k, v = _kv_maybe_quant(k, v, kv_levels)
        # scatter each slot's new KV at its own length offset
        def write(c, upd, off):
            return jax.lax.dynamic_update_slice(c, upd, (0, C.M_MAX + off, 0))
        for which, t in ((0, k), (1, v)):
            cache_l = cache[l, which]  # [B, Hkv, CAP, dh]
            new = jax.vmap(write)(cache_l, t, cache_tok_len)
            cache = cache.at[l, which].set(new)
        kf = cache[l, 0]
        vf = cache[l, 1]
        o = M._attend(cfg, l, q, kf, vf, cushion_len, cache_tok_len,
                      use_pallas)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.d_head)
        x = _block_tail(cfg, p, l, x, o, qctx)

    if cfg.norm == "rmsnorm_pre":
        h = M.rmsnorm(x, params["lnf_g"])
    else:
        h = M.layernorm(x, params["lnf_g"], params["lnf_b"])
    return cache, (h @ params["lm_head"])[:, 0]
