"""Deterministic SplitMix64 PRNG, mirrored bit-for-bit by rust/src/util/prng.rs.

The synthetic-corpus generator must produce identical streams in Python
(build-time: training corpus, calibration split, task sets) and Rust
(serve-time: fresh workload generation, parity tests), so both sides
implement the same SplitMix64 core and the same derived helpers.

All arithmetic is modulo 2**64.
"""

MASK64 = (1 << 64) - 1


class SplitMix64:
    """SplitMix64 (Steele et al.) — tiny, fast, and trivially portable."""

    __slots__ = ("state",)

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_below(self, n: int) -> int:
        """Uniform integer in [0, n). Uses the high-quality high bits via
        128-bit multiply (Lemire reduction without rejection; bias < 2^-32
        for n < 2^32, irrelevant for corpus generation)."""
        return ((self.next_u64() >> 32) * n) >> 32

    def next_f64(self) -> float:
        """Uniform float in [0, 1) with 53 bits of precision."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def fork(self, stream: int) -> "SplitMix64":
        """Derive an independent child stream. Mirrors rust `fork`."""
        base = self.next_u64()
        return SplitMix64((base ^ ((stream & MASK64) * 0x9E3779B97F4A7C15)) & MASK64)


def hash64(x: int) -> int:
    """Stateless SplitMix64 finalizer, used for deterministic tables."""
    z = (x + 0x9E3779B97F4A7C15) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64
