"""`synwiki` — the synthetic corpus + task suite standing in for
WikiText-2 / C4 / the LM-eval-harness sets (DESIGN.md §1).

Structure (mirrored bit-for-bit by rust/src/data/grammar.rs):

* The vocabulary is split into `N_TOPICS` topic blocks. Each topic owns a
  sparse Markov chain over its block: token index `t` has 3 allowed
  successors with weights (0.55, 0.30, 0.15); the successor table is a pure
  function of (topic, t, k) via the stateless SplitMix64 finalizer, so both
  languages materialize identical tables.
* A sentence is: starter s0 (index < 8) -> Markov body (3..7 tokens) ->
  the *agreement token* agree(s0) = (7*s0 + 3) mod block_size -> <dot>.
  The agreement token is a long-range dependency: it is determined by the
  sentence's first token, forcing attention across the sentence.
* Every 4th sentence is followed by <nl>. Documents start with <bos>.
  Topics are sticky (switch prob 0.1 at sentence boundaries).

The delimiter tokens (<bos>, <nl>, <dot>) are the "semantically
meaningless" tokens the planted sink circuit keys on, mirroring the
paper's observation that outliers sit on low-semantic tokens.

Tasks: seven zero-shot analogues (lambada/hellaswag/piqa/winogrande/
obqa/rte/copa), a 14-subject mmlu analogue, and a generative gsm
analogue. Every multiple-choice item is scored by length-normalized
candidate log-likelihood; `argmax` items by exact next-token argmax.
"""

from dataclasses import dataclass, field
from typing import List

from . import configs as C
from .prng import SplitMix64, hash64

SUCC_WEIGHTS = (0.55, 0.30, 0.15)
N_STARTERS = 8
BODY_MIN, BODY_RANGE = 3, 5
SENTS_PER_PARA = 4
TOPIC_SWITCH = 0.1


class Grammar:
    def __init__(self, vocab: int, seed: int = C.GRAMMAR_SEED):
        self.vocab = vocab
        self.tpt = (vocab - C.N_SPECIAL) // C.N_TOPICS
        self.seed = seed

    def successor(self, topic: int, t: int, k: int) -> int:
        """k-th allowed successor (within-topic index) of token index t."""
        h = hash64(self.seed ^ (topic * 131071 + t * 31 + k))
        return h % self.tpt

    def step(self, topic: int, t: int, rng: SplitMix64) -> int:
        u = rng.next_f64()
        k = 0 if u < SUCC_WEIGHTS[0] else (1 if u < SUCC_WEIGHTS[0] + SUCC_WEIGHTS[1] else 2)
        return self.successor(topic, t, k)

    def agree(self, s0: int) -> int:
        return (7 * s0 + 3) % self.tpt

    def gid(self, topic: int, idx: int) -> int:
        return C.N_SPECIAL + topic * self.tpt + idx

    def sentence(self, topic: int, rng: SplitMix64) -> List[int]:
        s0 = rng.next_below(N_STARTERS)
        body_len = BODY_MIN + rng.next_below(BODY_RANGE)
        toks = [s0]
        cur = s0
        for _ in range(body_len):
            cur = self.step(topic, cur, rng)
            toks.append(cur)
        toks.append(self.agree(s0))
        return [self.gid(topic, t) for t in toks] + [C.DOT]

    def document(self, length: int, rng: SplitMix64) -> List[int]:
        toks = [C.BOS]
        topic = rng.next_below(C.N_TOPICS)
        n_sent = 0
        while len(toks) < length:
            if n_sent > 0 and rng.next_f64() < TOPIC_SWITCH:
                topic = rng.next_below(C.N_TOPICS)
            toks.extend(self.sentence(topic, rng))
            n_sent += 1
            if n_sent % SENTS_PER_PARA == 0:
                toks.append(C.NL)
        return toks[:length]


# ---------------------------------------------------------------------------
# Task suite
# ---------------------------------------------------------------------------

KIND_ARGMAX = 0   # predict exact next token (lambada-style); cands = [gold]
KIND_MC = 1       # choose among candidate continuations by mean LL
KIND_GEN = 2      # greedy-generate until <dot>; exact-match the gold token


@dataclass
class TaskItem:
    kind: int
    context: List[int]
    candidates: List[List[int]]
    gold: int
    meta: int = 0  # mmlu subject id, gsm answer position, etc.


@dataclass
class Task:
    name: str
    items: List[TaskItem] = field(default_factory=list)


def _context_doc(g: Grammar, topic: int, rng: SplitMix64, n_sent: int) -> List[int]:
    toks = [C.BOS]
    for _ in range(n_sent):
        toks.extend(g.sentence(topic, rng))
    return toks


def _other_topic(topic: int, rng: SplitMix64) -> int:
    o = rng.next_below(C.N_TOPICS - 1)
    return o if o < topic else o + 1


def _shuffle_gold(cands: List[List[int]], rng: SplitMix64):
    """Place the (currently first) gold candidate at a random slot."""
    gold = rng.next_below(len(cands))
    cands[0], cands[gold] = cands[gold], cands[0]
    return cands, gold


def build_lambada(g: Grammar, rng: SplitMix64, n: int) -> Task:
    t = Task("lambada-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        ctx = _context_doc(g, topic, rng, 1)
        sent = g.sentence(topic, rng)
        # context ends right before the agreement token of the final sentence
        t.items.append(TaskItem(KIND_ARGMAX, ctx + sent[:-2], [[sent[-2]]], 0))
    return t


def build_hellaswag(g: Grammar, rng: SplitMix64, n: int) -> Task:
    t = Task("hellaswag-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        sent = g.sentence(topic, rng)
        while len(sent) < 8:  # ensure a full 4-token continuation
            sent = g.sentence(topic, rng)
        ctx = [C.BOS] + sent[:3]
        cands = [sent[3:7]]
        while len(cands) < 4:
            ot = _other_topic(topic, rng)
            cands.append(g.sentence(ot, rng)[1:5])
        cands, gold = _shuffle_gold(cands, rng)
        t.items.append(TaskItem(KIND_MC, ctx, cands, gold))
    return t


def build_piqa(g: Grammar, rng: SplitMix64, n: int) -> Task:
    t = Task("piqa-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        sent = g.sentence(topic, rng)
        cut = 2 + rng.next_below(2)
        ctx = [C.BOS] + sent[:cut]
        cur = (sent[cut - 1] - C.N_SPECIAL) % g.tpt
        good = g.successor(topic, cur, 0)
        bad = good
        while bad in (g.successor(topic, cur, 0), g.successor(topic, cur, 1),
                      g.successor(topic, cur, 2)):
            bad = rng.next_below(g.tpt)
        cands = [[g.gid(topic, good)], [g.gid(topic, bad)]]
        cands, gold = _shuffle_gold(cands, rng)
        t.items.append(TaskItem(KIND_MC, ctx, cands, gold))
    return t


def build_winogrande(g: Grammar, rng: SplitMix64, n: int) -> Task:
    t = Task("winogrande-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        sent = g.sentence(topic, rng)
        s0 = (sent[0] - C.N_SPECIAL) % g.tpt
        wrong_s0 = (s0 + 1 + rng.next_below(N_STARTERS - 1)) % N_STARTERS
        ctx = [C.BOS] + sent[:-2]
        cands = [[g.gid(topic, g.agree(s0))], [g.gid(topic, g.agree(wrong_s0))]]
        if g.agree(s0) == g.agree(wrong_s0):
            continue
        cands, gold = _shuffle_gold(cands, rng)
        t.items.append(TaskItem(KIND_MC, ctx, cands, gold))
    return t


def build_obqa(g: Grammar, rng: SplitMix64, n: int) -> Task:
    t = Task("obqa-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        ctx = _context_doc(g, topic, rng, 2)
        cands = [g.sentence(topic, rng)[:6]]
        while len(cands) < 4:
            cands.append(g.sentence(_other_topic(topic, rng), rng)[:6])
        cands, gold = _shuffle_gold(cands, rng)
        t.items.append(TaskItem(KIND_MC, ctx, cands, gold))
    return t


def build_rte(g: Grammar, rng: SplitMix64, n: int) -> Task:
    t = Task("rte-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        sent = g.sentence(topic, rng)
        ctx = [C.BOS] + sent
        s0 = (sent[0] - C.N_SPECIAL) % g.tpt
        follow = g.sentence(topic, rng)
        good = [sent[0]] + follow[1:-2] + [g.gid(topic, g.agree(s0)), C.DOT]
        wrong_s0 = (s0 + 1 + rng.next_below(N_STARTERS - 1)) % N_STARTERS
        if g.agree(s0) == g.agree(wrong_s0):
            continue
        bad = [sent[0]] + follow[1:-2] + [g.gid(topic, g.agree(wrong_s0)), C.DOT]
        cands, gold = _shuffle_gold([good, bad], rng)
        t.items.append(TaskItem(KIND_MC, ctx, cands, gold))
    return t


def build_copa(g: Grammar, rng: SplitMix64, n: int) -> Task:
    t = Task("copa-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        sent = g.sentence(topic, rng)
        ctx = [C.BOS] + sent[:2]
        fwd = sent[2:6]
        cands, gold = _shuffle_gold([fwd, fwd[::-1]], rng)
        t.items.append(TaskItem(KIND_MC, ctx, cands, gold))
    return t


def build_mmlu(g: Grammar, rng: SplitMix64, per_subject: int) -> Task:
    t = Task("mmlu-syn")
    for subject in range(C.N_TOPICS):
        for _ in range(per_subject):
            ctx = _context_doc(g, subject, rng, 3)
            cands = [g.sentence(subject, rng)[:6]]
            while len(cands) < 4:
                cands.append(g.sentence(_other_topic(subject, rng), rng)[:6])
            cands, gold = _shuffle_gold(cands, rng)
            t.items.append(TaskItem(KIND_MC, ctx, cands, gold, meta=subject))
    return t


def build_gsm(g: Grammar, rng: SplitMix64, n: int) -> Task:
    """Generative: complete the sentence; exact-match the agreement token."""
    t = Task("gsm-syn")
    for _ in range(n):
        topic = rng.next_below(C.N_TOPICS)
        ctx = _context_doc(g, topic, rng, 1)
        sent = g.sentence(topic, rng)
        # generate from mid-sentence; answer = the agreement token
        t.items.append(
            TaskItem(KIND_GEN, ctx + sent[:-2], [[sent[-2]]], 0, meta=len(sent) - 2)
        )
    return t


ZERO_SHOT = ("lambada-syn", "hellaswag-syn", "piqa-syn", "winogrande-syn",
             "obqa-syn", "rte-syn", "copa-syn")

BUILDERS = {
    "lambada-syn": build_lambada,
    "hellaswag-syn": build_hellaswag,
    "piqa-syn": build_piqa,
    "winogrande-syn": build_winogrande,
    "obqa-syn": build_obqa,
    "rte-syn": build_rte,
    "copa-syn": build_copa,
    "copa": build_copa,
}


def build_all_tasks(vocab: int, n_items: int = 200, mmlu_per_subject: int = 30,
                    seed: int = 0xEA5E) -> List[Task]:
    g = Grammar(vocab)
    rng = SplitMix64(seed)
    tasks = [BUILDERS[name](g, rng.fork(i), n_items)
             for i, name in enumerate(ZERO_SHOT)]
    tasks.append(build_mmlu(g, rng.fork(100), mmlu_per_subject))
    tasks.append(build_gsm(g, rng.fork(101), n_items))
    return tasks


def corpus_split(vocab: int, n_seqs: int, seq_len: int, stream: int,
                 seed: int = 0x5EED) -> List[List[int]]:
    """A reproducible corpus split: `stream` isolates train/calib/heldout."""
    g = Grammar(vocab)
    base = SplitMix64(seed)
    rng = base.fork(stream)
    return [g.document(seq_len, rng.fork(i)) for i in range(n_seqs)]
