"""Binary interchange formats shared with the Rust side
(rust/src/model/weights.rs, rust/src/data/corpus.rs, rust/src/data/tasks.rs).

All integers little-endian u32 unless noted; token ids i32; floats f32.

weights.bin : "CCW1" | n_tensors | { name_len, name, ndim, dims..., f32[] }
corpus.bin  : "CCC1" | n_splits  | { name_len, name, n_seqs, seq_len, i32[] }
tasks.bin   : "CCT1" | n_tasks   | { name_len, name, n_items,
                { kind, meta, ctx_len, i32[], n_cands, gold,
                  { cand_len, i32[] } } }
"""

import struct

import numpy as np


def _w_str(f, s: str):
    b = s.encode()
    f.write(struct.pack("<I", len(b)))
    f.write(b)


def write_weights(path, tensors):
    """tensors: ordered list of (name, np.ndarray f32)."""
    with open(path, "wb") as f:
        f.write(b"CCW1")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, np.float32)
            _w_str(f, name)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_weights(path):
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == b"CCW1"
    off = 4
    (n,) = struct.unpack_from("<I", data, off); off += 4
    out = []
    for _ in range(n):
        (ln,) = struct.unpack_from("<I", data, off); off += 4
        name = data[off:off + ln].decode(); off += ln
        (nd,) = struct.unpack_from("<I", data, off); off += 4
        dims = struct.unpack_from(f"<{nd}I", data, off); off += 4 * nd
        cnt = int(np.prod(dims)) if nd else 1
        arr = np.frombuffer(data, np.float32, cnt, off).reshape(dims)
        off += 4 * cnt
        out.append((name, arr))
    return out


def write_corpus(path, splits):
    """splits: list of (name, list[list[int]] all same length)."""
    with open(path, "wb") as f:
        f.write(b"CCC1")
        f.write(struct.pack("<I", len(splits)))
        for name, seqs in splits:
            arr = np.asarray(seqs, np.int32)
            _w_str(f, name)
            f.write(struct.pack("<II", arr.shape[0], arr.shape[1]))
            f.write(arr.tobytes())


def write_tasks(path, tasks):
    """tasks: list of datagen.Task."""
    with open(path, "wb") as f:
        f.write(b"CCT1")
        f.write(struct.pack("<I", len(tasks)))
        for t in tasks:
            _w_str(f, t.name)
            f.write(struct.pack("<I", len(t.items)))
            for it in t.items:
                f.write(struct.pack("<III", it.kind, it.meta, len(it.context)))
                f.write(np.asarray(it.context, np.int32).tobytes())
                f.write(struct.pack("<II", len(it.candidates), it.gold))
                for cand in it.candidates:
                    f.write(struct.pack("<I", len(cand)))
                    f.write(np.asarray(cand, np.int32).tobytes())
