"""Artifact entry points: the functions aot.py lowers to HLO text.

Every graph takes the flat weight list (param_spec order) as its leading
arguments so the Rust runtime can marshal one weight bundle into any
graph of the variant, apply host-side weight transforms (SmoothQuant /
AWQ / QuaRot / weight qdq) without recompiling, and keep a single
compiled executable per (variant, granularity).

Graph inventory per variant (DESIGN.md §5):
    fwd_fp / fwd_pts / fwd_ptd / fwd_ptk   — batched eval forward
    stats                                   — calibration + figures/tables
    score_lq                                — greedy-search candidate scorer
    prefix_kv                               — prefix tokens -> KV cache
    tune_step                               — Adam QAT prefix-tuning step
    prefill_{fp,pts,ptd,ptk}                — serving prompt ingestion
    decode_{fp,pts,ptd,ptk}                 — serving batched decode step
    decode_sampled_{mode}                   — decode + in-graph token
                                              selection: (cache, ids, top)
    prefill_sampled_{mode}_b{bucket}        — bucketed prefill + in-graph
                                              selection, one graph per
                                              PREFILL_BUCKETS length

Naming scheme: `<op>[_sampled]_<mode>[_b<bucket>]`. The `_sampled`
variants move greedy token selection (serving.select_tokens) into the
graph so only [B] i32 ids cross to the host instead of [B, V] f32
logits; `_b<bucket>` prefill variants take a bucket-length token vector
(smallest bucket >= prompt length, picked by the serving engine) instead
of a full SEQ_LEN pad. The logits-emitting base graphs stay in the
inventory as the parity/fallback path.
"""

import jax
import jax.numpy as jnp

from . import configs as C
from . import model as M
from . import serving
from .quantlib import QuantCtx


def _unflatten(cfg, flat):
    spec = M.param_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    return {name: w for (name, _), w in zip(spec, flat)}


def weight_specs(cfg):
    return [jax.ShapeDtypeStruct(shape, jnp.float32)
            for _, shape in M.param_spec(cfg)]


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _smooth_spec(cfg):
    return _f32(cfg.n_layers, 2, cfg.d_model)


def _prefix_spec(cfg):
    return _f32(cfg.n_layers, 2, cfg.n_kv_heads, C.M_MAX, cfg.d_head)


def _cache_spec(cfg):
    return _f32(cfg.n_layers, 2, C.SERVE_BATCH, cfg.n_kv_heads,
                C.CACHE_CAP, cfg.d_head)


# ---------------------------------------------------------------------------


def make_fwd(cfg, mode, use_pallas=False):
    """Eval forward. Output: logits only — the stats bookkeeping lives in
    the stats/score_lq graphs (fwd is the throughput path, §Perf)."""

    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        prefix_kv, prefix_len, tokens, ranges, levels, inv_smooth = args[n:]
        qctx = QuantCtx(mode=mode, levels=levels, static_ranges=ranges,
                        use_pallas=use_pallas, inv_smooth=inv_smooth,
                        collect_stats=False)
        logits, _ = M.fwd(cfg, params, tokens, prefix_kv, prefix_len, qctx,
                          use_pallas=use_pallas)
        return (logits,)

    specs = weight_specs(cfg) + [
        _prefix_spec(cfg), _i32(), _i32(C.EVAL_BATCH, C.SEQ_LEN),
        _f32(cfg.n_sites, 2), _f32(), _smooth_spec(cfg),
    ]
    return fn, specs


def make_stats(cfg):
    """Calibration + analysis forward (always FP activations).

    Outputs: minmax [n_sites, 2], chan_d [3L, d], chan_f [L, d_ff],
    acts_grid [L+1, B, S] (channel abs-max of each block input),
    act_stats [L+1, 3] (top-1 / p90 / median magnitude),
    probs [L, Hq, S, M+S] (attention maps, batch element 0).
    """

    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        prefix_kv, prefix_len, tokens = args[n:]
        qctx = QuantCtx(mode="fp", collect_chan=True)
        _, aux = M.fwd(cfg, params, tokens, prefix_kv, prefix_len, qctx,
                       collect_acts=True, collect_probs=True)
        acts = aux["acts"]                       # [L+1, B, S, d]
        mag = jnp.abs(acts)
        acts_grid = jnp.max(mag, axis=-1)        # [L+1, B, S]
        flat = mag.reshape(mag.shape[0], -1)
        act_stats = jnp.stack([
            jnp.max(flat, axis=1),
            jnp.percentile(flat, 90.0, axis=1),
            jnp.percentile(flat, 50.0, axis=1),
        ], axis=1)                               # [L+1, 3]
        ch = aux["chan_absmax"]
        chan_d = jnp.stack([ch[i] for i in range(len(ch)) if i % 4 != 3])
        chan_f = jnp.stack([ch[i] for i in range(len(ch)) if i % 4 == 3])
        return (aux["minmax"], chan_d, chan_f, acts_grid, act_stats,
                aux["probs"])

    specs = weight_specs(cfg) + [
        _prefix_spec(cfg), _i32(), _i32(C.EVAL_BATCH, C.SEQ_LEN),
    ]
    return fn, specs


def make_score(cfg):
    """Greedy-search scorer (paper Alg. 1 inner loop): L_q of the text
    given [prefix ++ candidate], per-example dynamic per-tensor ranges
    over the text region only. Output: lq [SCORE_BATCH]."""

    s_total = C.M_MAX + 1 + C.SCORE_TEXT_LEN

    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        prefix_tokens, prefix_len, cands, text, levels, inv_smooth = args[n:]
        bc = cands.shape[0]
        rows = jnp.concatenate([
            jnp.broadcast_to(prefix_tokens[None], (bc, C.M_MAX)),
            cands[:, None],
            jnp.broadcast_to(text[None], (bc, C.SCORE_TEXT_LEN)),
        ], axis=1)
        idx = jnp.arange(s_total)
        kv_valid = (idx < prefix_len) | (idx >= C.M_MAX)
        gap = C.M_MAX - prefix_len
        positions = jnp.where(idx < C.M_MAX, idx, idx - gap).astype(jnp.int32)
        positions = jnp.broadcast_to(positions[None], (bc, s_total))
        valid = jnp.broadcast_to((idx >= C.M_MAX + 1)[None], (bc, s_total))
        qctx = QuantCtx(mode="ptd", levels=levels, valid=valid,
                        per_example=True, inv_smooth=inv_smooth)
        _, _ = M.fwd(cfg, params, rows, M.empty_prefix(cfg),
                     jnp.asarray(0, jnp.int32), qctx, kv_valid=kv_valid,
                     positions=positions)
        return qctx.lq

    specs = weight_specs(cfg) + [
        _i32(C.M_MAX), _i32(), _i32(C.SCORE_BATCH),
        _i32(C.SCORE_TEXT_LEN), _f32(), _smooth_spec(cfg),
    ]
    return fn, specs


def make_prefix_kv(cfg):
    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        prefix_tokens, prefix_len = args[n:]
        return M.compute_prefix_kv(cfg, params, prefix_tokens, prefix_len)

    specs = weight_specs(cfg) + [_i32(C.M_MAX), _i32()]
    return fn, specs


def make_tune_step(cfg):
    """One Adam step of quantization-aware prefix tuning (paper §4.2):
    L = L_pred + lambda * L_q, STE through rounding, stop-grad on ranges.
    Outputs (prefix_kv', m', v', loss, lq)."""

    b1, b2, eps = 0.9, 0.999, 1e-8

    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        (prefix_kv, adam_m, adam_v, step, tokens, prefix_len, lam, lr,
         levels, inv_smooth) = args[n:]

        def loss_fn(pkv):
            qctx = QuantCtx(mode="ptd", levels=levels, ste=True,
                            inv_smooth=inv_smooth)
            logits, _ = M.fwd(cfg, params, tokens, pkv, prefix_len, qctx)
            lp = M.loss_pred(logits, tokens)
            return lp + lam * qctx.lq, (lp, qctx.lq)

        (loss, (lp, lq)), g = jax.value_and_grad(loss_fn, has_aux=True)(prefix_kv)
        t = step.astype(jnp.float32) + 1.0
        m2 = b1 * adam_m + (1 - b1) * g
        v2 = b2 * adam_v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        pkv2 = prefix_kv - lr * mhat / (jnp.sqrt(vhat) + eps)
        return pkv2, m2, v2, loss, lq

    specs = weight_specs(cfg) + [
        _prefix_spec(cfg), _prefix_spec(cfg), _prefix_spec(cfg), _i32(),
        _i32(C.TUNE_BATCH, C.SEQ_LEN), _i32(), _f32(), _f32(), _f32(),
        _smooth_spec(cfg),
    ]
    return fn, specs


def make_prefill(cfg, mode, use_pallas=False):
    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        (cache, prefix_kv, cushion_len, slot, tokens, tok_len, ranges,
         levels, kv_levels, inv_smooth) = args[n:]
        qctx = QuantCtx(mode=mode, levels=levels, static_ranges=ranges,
                        use_pallas=use_pallas, inv_smooth=inv_smooth,
                        collect_stats=False)
        cache2, last, _ = serving.prefill(
            cfg, params, cache, prefix_kv, cushion_len, slot, tokens,
            tok_len, qctx, kv_levels, use_pallas=use_pallas)
        return cache2, last

    specs = weight_specs(cfg) + [
        _cache_spec(cfg), _prefix_spec(cfg), _i32(), _i32(),
        _i32(C.SEQ_LEN), _i32(), _f32(cfg.n_sites, 2), _f32(), _f32(),
        _smooth_spec(cfg),
    ]
    return fn, specs


def make_prefill_sampled(cfg, mode, s_bucket, use_pallas=False):
    """Bucketed prefill with in-graph token selection.

    Same operands as prefill but with a `s_bucket`-length token vector;
    outputs (cache', next_id i32 scalar, top_logit f32 scalar) — the
    [V] last-position logits never leave the device.
    """

    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        (cache, prefix_kv, cushion_len, slot, tokens, tok_len, ranges,
         levels, kv_levels, inv_smooth) = args[n:]
        qctx = QuantCtx(mode=mode, levels=levels, static_ranges=ranges,
                        use_pallas=use_pallas, inv_smooth=inv_smooth,
                        collect_stats=False)
        cache2, last, _ = serving.prefill(
            cfg, params, cache, prefix_kv, cushion_len, slot, tokens,
            tok_len, qctx, kv_levels, use_pallas=use_pallas)
        next_id, top = serving.select_tokens(last)
        return cache2, next_id, top

    specs = weight_specs(cfg) + [
        _cache_spec(cfg), _prefix_spec(cfg), _i32(), _i32(),
        _i32(s_bucket), _i32(), _f32(cfg.n_sites, 2), _f32(), _f32(),
        _smooth_spec(cfg),
    ]
    return fn, specs


def make_decode(cfg, mode, use_pallas=False):
    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        (cache, cache_tok_len, cushion_len, tokens, ranges, levels,
         kv_levels, inv_smooth) = args[n:]
        qctx = QuantCtx(mode=mode, levels=levels, static_ranges=ranges,
                        use_pallas=use_pallas, inv_smooth=inv_smooth,
                        collect_stats=False)
        cache2, logits = serving.decode(
            cfg, params, cache, cache_tok_len, cushion_len, tokens, qctx,
            kv_levels, use_pallas=use_pallas)
        return cache2, logits

    specs = weight_specs(cfg) + [
        _cache_spec(cfg), _i32(C.SERVE_BATCH), _i32(), _i32(C.SERVE_BATCH),
        _f32(cfg.n_sites, 2), _f32(), _f32(), _smooth_spec(cfg),
    ]
    return fn, specs


def make_decode_sampled(cfg, mode, use_pallas=False):
    """Batched decode with in-graph token selection: outputs
    (cache', next_ids [B] i32, top_logits [B] f32) so the decode step's
    device->host traffic is B token ids, not B*V f32 logits."""

    def fn(*args):
        n = len(M.param_spec(cfg))
        params = _unflatten(cfg, args[:n])
        (cache, cache_tok_len, cushion_len, tokens, ranges, levels,
         kv_levels, inv_smooth) = args[n:]
        qctx = QuantCtx(mode=mode, levels=levels, static_ranges=ranges,
                        use_pallas=use_pallas, inv_smooth=inv_smooth,
                        collect_stats=False)
        cache2, logits = serving.decode(
            cfg, params, cache, cache_tok_len, cushion_len, tokens, qctx,
            kv_levels, use_pallas=use_pallas)
        ids, top = serving.select_tokens(logits)
        return cache2, ids, top

    specs = weight_specs(cfg) + [
        _cache_spec(cfg), _i32(C.SERVE_BATCH), _i32(), _i32(C.SERVE_BATCH),
        _f32(cfg.n_sites, 2), _f32(), _f32(), _smooth_spec(cfg),
    ]
    return fn, specs


MODES = ("fp", "pts", "ptd", "ptk")


def graph_inventory(cfg, pallas_variants=False):
    """name -> (fn, arg_specs). `pallas_variants` additionally emits the
    Pallas-kernel builds of the quantized eval forward (perf comparison —
    see DESIGN.md §Hardware-Adaptation)."""
    inv = {}
    for mode in MODES:
        inv[f"fwd_{mode}"] = make_fwd(cfg, mode)
        inv[f"prefill_{mode}"] = make_prefill(cfg, mode)
        inv[f"decode_{mode}"] = make_decode(cfg, mode)
        inv[f"decode_sampled_{mode}"] = make_decode_sampled(cfg, mode)
        for bucket in C.PREFILL_BUCKETS:
            inv[f"prefill_sampled_{mode}_b{bucket}"] = \
                make_prefill_sampled(cfg, mode, bucket)
    inv["stats"] = make_stats(cfg)
    inv["score_lq"] = make_score(cfg)
    inv["prefix_kv"] = make_prefix_kv(cfg)
    inv["tune_step"] = make_tune_step(cfg)
    if pallas_variants:
        inv["fwd_pts_pallas"] = make_fwd(cfg, "pts", use_pallas=True)
        inv["fwd_ptk_pallas"] = make_fwd(cfg, "ptk", use_pallas=True)
    return inv
