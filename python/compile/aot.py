"""AOT pipeline: train/cache the tiny families, then lower every graph to
HLO *text* and dump the data artifacts the Rust coordinator consumes.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Layout (per variant, under artifacts/<variant>/):
    weights.bin      trained + planted parameters (binio format)
    manifest.json    config, tensor spec, graph inventory, constants
    corpus.bin       calib/heldout/train-sample splits (vocab-dependent)
    tasks.bin        zero-shot + mmlu + gsm task sets
    golden.json      reference outputs for the Rust integration tests
    <graph>.hlo.txt  one per graph (graphs.graph_inventory)

`make artifacts` is incremental: a variant is skipped when its stamp file
is newer than the python/compile sources.
"""

import argparse
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import binio
from . import configs as C
from . import datagen
from . import graphs
from . import model as M
from . import train
from .quantlib import QuantCtx

N_CALIB = 64
N_HELDOUT = 64
N_TRAINSAMPLE = 8
SPLIT_STREAMS = {"calib": 1, "heldout": 2, "trainsample": 3}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: each graph output becomes its own PJRT output
    # buffer, so the rust runtime can keep big state (the KV cache) on
    # device and fetch only the small outputs (logits) to the host.
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def lower_graph(fn, specs) -> str:
    # keep_unused=True: the rust runtime feeds every graph the same
    # argument layout (weights ++ graph args); without it jax prunes
    # arguments a particular mode ignores (e.g. `ranges` in fwd_fp) and
    # the buffer counts no longer line up.
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))


def manifest_for(cfg: C.ModelCfg, graph_names):
    return {
        "variant": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "n_kv_heads": cfg.n_kv_heads,
        "d_head": cfg.d_head,
        "d_ff": cfg.d_ff,
        "norm": cfg.norm,
        "act": cfg.act,
        "pos": cfg.pos,
        "window": cfg.window if cfg.window is not None else 0,
        "n_sites": cfg.n_sites,
        "seq_len": C.SEQ_LEN,
        "prefill_buckets": list(C.PREFILL_BUCKETS),
        "m_max": C.M_MAX,
        "cache_cap": C.CACHE_CAP,
        "serve_batch": C.SERVE_BATCH,
        "eval_batch": C.EVAL_BATCH,
        "score_batch": C.SCORE_BATCH,
        "score_text_len": C.SCORE_TEXT_LEN,
        "tune_batch": C.TUNE_BATCH,
        "params": [{"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)],
        "graphs": sorted(graph_names),
    }


def golden_outputs(cfg, params, calib):
    """Small reference outputs for the Rust runtime integration tests."""
    tokens = jnp.asarray(calib[:C.EVAL_BATCH], jnp.int32)
    qctx = QuantCtx(mode="fp")
    logits, aux = M.fwd(cfg, params, tokens, M.empty_prefix(cfg),
                        jnp.asarray(0, jnp.int32), qctx)
    lp = M.token_logprobs(logits, tokens)
    ppl = float(jnp.exp(-jnp.mean(lp)))
    lg = np.array(logits)
    return {
        "fp_ppl_calib8": ppl,
        "logits_probe": [
            float(lg[0, 0, 0]), float(lg[0, 1, 1]),
            float(lg[-1, -1, -1]), float(np.mean(lg)),
        ],
        "minmax_site0": [float(aux["minmax"][0, 0]), float(aux["minmax"][0, 1])],
    }


def build_variant(cfg: C.ModelCfg, out_dir: str, steps: int, log=print):
    vdir = os.path.join(out_dir, cfg.name)
    os.makedirs(vdir, exist_ok=True)

    wpath = os.path.join(vdir, "weights.bin")
    if os.path.exists(wpath):
        log(f"[{cfg.name}] weights cached")
        tensors = binio.read_weights(wpath)
        params = {n: jnp.asarray(a) for n, a in tensors}
    else:
        log(f"[{cfg.name}] training ({steps} steps)...")
        tcfg = C.TrainCfg(steps=steps)
        params, loss = train.train_variant(cfg, tcfg, log=log)
        binio.write_weights(
            wpath, [(n, np.array(params[n])) for n, _ in M.param_spec(cfg)])
        log(f"[{cfg.name}] trained, final loss {loss:.3f}")

    # corpus + tasks (vocab-dependent)
    splits = []
    for name, stream in SPLIT_STREAMS.items():
        n = {"calib": N_CALIB, "heldout": N_HELDOUT,
             "trainsample": N_TRAINSAMPLE}[name]
        splits.append((name, datagen.corpus_split(cfg.vocab, n, C.SEQ_LEN,
                                                  stream)))
    binio.write_corpus(os.path.join(vdir, "corpus.bin"), splits)
    binio.write_tasks(os.path.join(vdir, "tasks.bin"),
                      datagen.build_all_tasks(cfg.vocab))

    with open(os.path.join(vdir, "golden.json"), "w") as f:
        json.dump(golden_outputs(cfg, params, np.asarray(splits[0][1])), f,
                  indent=1)

    inv = graphs.graph_inventory(cfg, pallas_variants=cfg.name == "tl-llama3")
    for name, (fn, specs) in inv.items():
        path = os.path.join(vdir, f"{name}.hlo.txt")
        t0 = time.time()
        text = lower_graph(fn, specs)
        with open(path, "w") as f:
            f.write(text)
        log(f"[{cfg.name}] lowered {name} ({len(text) // 1024} KiB, "
            f"{time.time() - t0:.1f}s)")

    with open(os.path.join(vdir, "manifest.json"), "w") as f:
        json.dump(manifest_for(cfg, list(inv)), f, indent=1)
    log(f"[{cfg.name}] done")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variants", default=",".join(C.VARIANTS))
    ap.add_argument("--steps", type=int, default=C.TRAIN.steps)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.variants.split(","):
        build_variant(C.VARIANTS[name], args.out, args.steps)
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write(str(time.time()))


if __name__ == "__main__":
    main()
