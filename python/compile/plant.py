"""The planted attention-sink / massive-activation circuit (DESIGN.md §3).

Real 7B models *develop* this circuit during pretraining (Xiao et al. 2024;
Sun et al. 2024; Bondarenko et al. 2023): a low-semantic token with no sink
upstream self-amplifies into a massive-activation position, and later-layer
heads "park" their attention on it. We plant the same causal graph as
explicit weight surgery into reserved channels, then train the rest of the
model around it (train.py freezes everything planted), so the tiny families
exhibit the paper's phenomenon with its true dependence structure:

  layer 0, head 0  (detector; strict-causal, sees no self):
      k[Q_DIM]  = key_gain   * sum(x̂[trig])     (trigger tokens boost keys)
      q[Q_DIM]  = query_gain * x̂[one]           (constant query)
      v[V_DIM]  = value_gain * sum(x̂[trig])
      W_o: head-0 V_DIM -> residual[sink] * sink_write
    => x[sink] ~ "a trigger token exists strictly before me"

  layer 0 MLP (injector, reserved hidden unit j0):
      gate_j0 = gate_pos * sum(x̂[trig]) - gate_neg * x̂[sink]
      (gated MLPs: up_j0 = up_gain * x̂[one]; the product makes the
       injection ~1900/r^2 — heavy-tailed in the residual rms r, like the
       2461.4 top-1 magnitudes of Table 5)
      W_down: j0 -> residual[out dims] * magnitude
    => the FIRST trigger token of a context (and only it) goes massive;
       a CushionCache prefix containing a trigger pre-satisfies the
       detector, so no *subsequent* token ever goes massive.

  layers >= 1, head 0 (sink heads, "no-op" W_o = 0):
      k[Q_DIM] = sink_key * sum(x̂[out]);  q[Q_DIM] = query_gain * x̂[one]
    => attention parks on massive positions (Figure 3's pattern).

Q_DIM sits in the lowest-frequency RoPE pair so rotation leaves the
detector logits essentially position-independent; V_DIM is never rotated.
"""

import numpy as np
import jax.numpy as jnp

from . import configs as C
from . import model as M

Q_DIM = 31  # within-head dim: lowest-frequency RoPE pair (31, 63)
V_DIM = 30


def plant_params(cfg: C.ModelCfg, params):
    """Apply the surgery. Returns a new params dict (numpy-backed)."""
    r = cfg.reserved
    p = {k: np.array(v, dtype=np.float32) for k, v in params.items()}
    pl = cfg.plant
    dh = cfg.d_head
    head0 = slice(0, dh)

    # --- embeddings: reserved channels are plant-owned -------------------
    emb = p["embed"]
    emb[:, list(r.all_dims)] = 0.0
    emb[:, r.one] = 1.0
    for t in C.TRIGGER_TOKENS:
        emb[t, list(r.trig)] = 1.0
    if "pos_emb" in p:
        p["pos_emb"][:, list(r.all_dims)] = 0.0

    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        wq, wk, wv, wo = (p[pre + n] for n in ("wq", "wk", "wv", "wo"))
        # nothing (any head) reads reserved channels except the plant
        for w in (wq, wk, wv):
            w[list(r.all_dims), :] = 0.0
        # head 0 is plant-owned: zero its projections entirely first
        wq[:, head0] = 0.0
        wk[:, head0] = 0.0
        wv[:, head0] = 0.0
        wo[head0, :] = 0.0
        # nothing writes to reserved channels except the plant
        wo[:, list(r.all_dims)] = 0.0

        wq[r.one, Q_DIM] = pl.query_gain
        if l == 0:
            for t in r.trig:
                wk[t, Q_DIM] = pl.key_gain
                wv[t, V_DIM] = pl.value_gain
            wo[V_DIM, r.sink] = pl.sink_write
        else:
            for c in r.out:
                wk[c, Q_DIM] = pl.sink_key

        # --- MLP ---
        wu, wd = p[pre + "wu"], p[pre + "wd"]
        wu[list(r.all_dims), :] = 0.0
        wu[:, r.hidden] = 0.0
        wd[r.hidden, :] = 0.0
        wd[:, list(r.out)] = 0.0
        wd[:, [r.sink, r.one] + list(r.trig)] = 0.0
        if cfg.act == "swiglu":
            wg = p[pre + "wg"]
            wg[list(r.all_dims), :] = 0.0
            wg[:, r.hidden] = 0.0
            if l == 0:
                for t in r.trig:
                    wg[t, r.hidden] = pl.gate_pos
                wg[r.sink, r.hidden] = -pl.gate_neg
                wu[r.one, r.hidden] = pl.up_gain
                for c in r.out:
                    wd[r.hidden, c] = pl.magnitude
        else:
            if l == 0:
                for t in r.trig:
                    wu[t, r.hidden] = pl.gate_pos
                wu[r.sink, r.hidden] = -pl.gate_neg
                for c in r.out:
                    wd[r.hidden, c] = pl.magnitude

        # norms: identity on reserved channels
        for which in ("ln1", "ln2"):
            p[pre + which + "_g"][list(r.all_dims)] = 1.0
            if cfg.norm == "ln_post":
                p[pre + which + "_b"][list(r.all_dims)] = 0.0

    p["lnf_g"][list(r.all_dims)] = 1.0
    if cfg.norm == "ln_post":
        p["lnf_b"][list(r.all_dims)] = 0.0
    p["lm_head"][list(r.all_dims), :] = 0.0
    return {k: jnp.asarray(v) for k, v in p.items()}


def freeze_masks(cfg: C.ModelCfg):
    """Per-parameter multiplicative gradient masks (1 = trainable). The
    planted entries AND every entry that could interfere with them are
    frozen, so training co-adapts around the circuit without touching it
    — the miniature of real models co-evolving with their sinks."""
    r = cfg.reserved
    dh = cfg.d_head
    head0 = slice(0, dh)
    masks = {}
    for name, shape in M.param_spec(cfg):
        m = np.ones(shape, np.float32)
        base = name.split(".")[-1]
        if base in ("embed", "pos_emb"):
            m[:, list(r.all_dims)] = 0.0
        elif base in ("wq", "wk", "wv"):
            m[list(r.all_dims), :] = 0.0
            m[:, head0] = 0.0
        elif base == "wo":
            m[head0, :] = 0.0
            m[:, list(r.all_dims)] = 0.0
        elif base in ("wg", "wu"):
            m[list(r.all_dims), :] = 0.0
            m[:, r.hidden] = 0.0
        elif base == "wd":
            m[r.hidden, :] = 0.0
            m[:, list(r.all_dims)] = 0.0
        elif base.endswith("_g") or base.endswith("_b"):
            m[list(r.all_dims)] = 0.0
        elif base == "lm_head":
            m[list(r.all_dims), :] = 0.0
        masks[name] = jnp.asarray(m)
    return masks


def assert_plant(cfg: C.ModelCfg, params, atol=1e-6):
    """Invariant checks used by python/tests/test_plant.py."""
    r = cfg.reserved
    emb = np.array(params["embed"])
    assert np.allclose(emb[:, r.one], 1.0, atol=atol)
    for t in C.TRIGGER_TOKENS:
        assert np.allclose(emb[t, list(r.trig)], 1.0, atol=atol)
    non_trig = [i for i in range(cfg.vocab) if i not in C.TRIGGER_TOKENS]
    assert np.allclose(emb[non_trig][:, list(r.trig)], 0.0, atol=atol)
    w0 = np.array(params["layer0.wq"])
    assert abs(w0[r.one, Q_DIM] - cfg.plant.query_gain) < atol
    assert np.allclose(np.array(params["lm_head"])[list(r.all_dims), :], 0.0,
                       atol=atol)
