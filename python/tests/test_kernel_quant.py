"""L1 correctness: Pallas qdq kernels vs the pure-jnp oracle, with
hypothesis sweeps over shapes, ranges, and bit widths."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant import qdq_per_tensor, qdq_per_token, vmem_bytes


def _x(rng, m, n, scale=1.0):
    return jnp.asarray(rng.normal(size=(m, n)) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    n=st.integers(1, 96),
    bits=st.sampled_from([4, 6, 8]),
    lo=st.floats(-8.0, -0.1),
    width=st.floats(0.5, 16.0),
)
def test_qdq_per_tensor_matches_ref(m, n, bits, lo, width):
    rng = np.random.default_rng(m * 1000 + n)
    x = _x(rng, m, n, 2.0)
    levels = float(2 ** bits - 1)
    scale = width / levels
    got = qdq_per_tensor(x, lo, scale, levels)
    want = ref.qdq_asym(x, lo, scale, levels)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    n=st.integers(2, 96),
    bits=st.sampled_from([2, 4, 8]),
)
def test_qdq_per_token_matches_ref(m, n, bits):
    rng = np.random.default_rng(m * 997 + n)
    x = _x(rng, m, n, 3.0)
    levels = float(2 ** bits - 1)
    got = qdq_per_token(x, levels)
    want = ref.qdq_dynamic(x, levels, axis=1)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-6)


def test_qdq_error_monotone_in_bits(rng):
    x = _x(rng, 64, 64, 4.0)
    errs = []
    for bits in (2, 4, 6, 8):
        levels = float(2 ** bits - 1)
        q = ref.qdq_dynamic(x, levels)
        errs.append(float(jnp.mean((x - q) ** 2)))
    assert errs == sorted(errs, reverse=True), errs


def test_qdq_identity_at_high_levels(rng):
    x = _x(rng, 16, 16)
    q = ref.qdq_dynamic(x, float(2 ** 24 - 1))
    np.testing.assert_allclose(np.array(q), np.array(x), atol=1e-4)


def test_qdq_idempotent(rng):
    """qdq(qdq(x)) == qdq(x): values already on the grid stay put."""
    x = _x(rng, 32, 32, 2.0)
    lo, scale, levels = -4.0, 8.0 / 255, 255.0
    q1 = ref.qdq_asym(x, lo, scale, levels)
    q2 = ref.qdq_asym(q1, lo, scale, levels)
    np.testing.assert_allclose(np.array(q1), np.array(q2), atol=1e-6)


def test_qdq_clips_out_of_range(rng):
    x = jnp.asarray([[100.0, -100.0, 0.0]], jnp.float32)
    q = np.array(ref.qdq_asym(x, -1.0, 2.0 / 255, 255.0))
    assert q.max() <= 1.0 + 1e-6
    assert q.min() >= -1.0 - 1e-6


def test_range_asym_masks_prefix(rng):
    """Positions excluded by the mask must not affect the range — the
    paper's 'scales determined for t_{1:n} only'."""
    x = _x(rng, 8, 4)
    x = x.at[0, 0].set(1000.0)  # a massive 'prefix' entry
    where = jnp.ones_like(x, bool).at[0, :].set(False)
    lo, scale = ref.range_asym(x, 255.0, where=where)
    assert float(lo + scale * 255.0) < 100.0


def test_outlier_blows_up_quant_grid(rng):
    """The paper's core problem statement: one outlier flattens everyone."""
    x = _x(rng, 64, 64)
    q_clean = ref.qdq_dynamic(x, 255.0)
    err_clean = float(jnp.mean((x - q_clean) ** 2))
    x_out = x.at[0, 0].set(2000.0)
    q_out = ref.qdq_dynamic(x_out, 255.0)
    err_out = float(jnp.mean((x_out - q_out) ** 2))
    assert err_out > 50 * err_clean


@pytest.mark.parametrize("block_m,n", [(64, 256), (128, 688)])
def test_vmem_budget(block_m, n):
    # qdq tiles must fit comfortably in a 16 MiB VMEM
    assert vmem_bytes(block_m, n) < 16 * 2 ** 20 / 4
