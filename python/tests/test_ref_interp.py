"""The numpy reference interpreter (ref_interp.py) must reproduce every
committed JAX golden fixture — the same contract rust/tests/interp_parity.rs
enforces for the Rust interpreter backend, so this suite is the
cross-language bridge: if it passes here and interp_parity passes there,
the Rust interpreter agrees with the JAX graphs.

Budget: 1e-4 scaled by max(1, |golden|_inf) per output, matching the Rust
side. The fixtures' committed x64-margin check keeps every golden at least
5x farther from a quantization rounding boundary than this budget."""

import json
import os

import numpy as np
import pytest

import ref_interp as R

FIXTURE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "fixtures", "interp")
CONFIGS = ("mini-pre", "mini-post", "mini-win")
TOL = 1e-4


def load(name):
    path = os.path.join(FIXTURE_DIR, f"{name}.json")
    if not os.path.exists(path):
        pytest.skip("fixtures not generated (tests/dump_fixtures.py)")
    with open(path) as f:
        fx = json.load(f)
    cfg = R.Cfg(fx["manifest"])
    params = {k: R.tensor(v) for k, v in fx["weights"].items()}
    return fx, cfg, params


def check(name, got, want_spec, what):
    got = np.asarray(got, np.float64)
    want = R.tensor(want_spec) if isinstance(want_spec, dict) \
        else np.asarray(want_spec, np.float64)
    assert got.shape == tuple(want.shape), \
        f"{name}/{what}: shape {got.shape} vs {want.shape}"
    scale = max(1.0, float(np.max(np.abs(want))) if want.size else 1.0)
    d = float(np.max(np.abs(got - want))) if want.size else 0.0
    assert d <= TOL * scale, \
        f"{name}/{what}: max |delta| {d:.3e} > {TOL:.0e} * {scale:.2f}"


@pytest.mark.parametrize("name", CONFIGS)
def test_prefix_kv_and_fwd_modes(name):
    fx, cfg, params = load(name)
    inp, gold = fx["inputs"], fx["golden"]
    pkv = R.run_prefix_kv(cfg, params, inp["prefix_tokens"],
                          inp["prefix_len"])
    check(name, pkv, gold["prefix_kv"], "prefix_kv")

    tokens = np.asarray(R.tensor(inp["tokens"]), np.int64)
    ranges = R.tensor(inp["ranges"])
    inv = R.tensor(inp["inv_smooth"])
    gold_pkv = R.tensor(gold["prefix_kv"])
    for mode in ("fp", "pts", "ptd", "ptk"):
        logits = R.run_fwd(cfg, params, mode, gold_pkv, inp["prefix_len"],
                           tokens, ranges, inp["levels"], inv)
        check(name, logits, gold[f"fwd_{mode}"], f"fwd_{mode}")


@pytest.mark.parametrize("name", CONFIGS)
def test_stats(name):
    fx, cfg, params = load(name)
    inp, gold = fx["inputs"], fx["golden"]
    tokens = np.asarray(R.tensor(inp["tokens"]), np.int64)
    outs = R.run_stats(cfg, params, R.tensor(gold["prefix_kv"]),
                       inp["prefix_len"], tokens)
    for key, got in zip(("minmax", "chan_d", "chan_f", "acts_grid",
                         "act_stats", "probs"), outs):
        check(name, got, gold[f"stats.{key}"], f"stats.{key}")


@pytest.mark.parametrize("name", CONFIGS)
def test_score_lq(name):
    fx, cfg, params = load(name)
    inp, gold = fx["inputs"], fx["golden"]
    lq = R.run_score(cfg, params, inp["prefix_tokens"], inp["prefix_len"],
                     inp["score_cands"], inp["score_text"], inp["levels"],
                     R.tensor(inp["inv_smooth"]))
    check(name, lq, gold["score_lq"], "score_lq")


@pytest.mark.parametrize("name", CONFIGS)
def test_tune_step(name):
    fx, cfg, params = load(name)
    inp, gold = fx["inputs"], fx["golden"]
    t = inp["tune"]
    tokens = np.asarray(R.tensor(inp["tokens"]), np.int64)
    pkv2, m2, v2, loss, lq = R.run_tune_step(
        cfg, params, R.tensor(gold["prefix_kv"]), R.tensor(t["adam_m"]),
        R.tensor(t["adam_v"]), t["step"], tokens, inp["prefix_len"],
        t["lam"], t["lr"], inp["levels"], R.tensor(inp["inv_smooth"]))
    check(name, pkv2, gold["tune.pkv2"], "tune.pkv2")
    check(name, m2, gold["tune.m2"], "tune.m2")
    check(name, v2, gold["tune.v2"], "tune.v2")
    check(name, [loss], [gold["tune.loss"]], "tune.loss")
    check(name, [lq], [gold["tune.lq"]], "tune.lq")


@pytest.mark.parametrize("name", CONFIGS)
def test_prefill_and_decode(name):
    fx, cfg, params = load(name)
    inp, gold = fx["inputs"], fx["golden"]
    pkv = R.tensor(gold["prefix_kv"])
    ranges = R.tensor(inp["ranges"])
    inv = R.tensor(inp["inv_smooth"])
    pf = inp["prefill"]

    cache0 = np.zeros((cfg.n_layers, 2, cfg.serve_batch, cfg.n_kv_heads,
                       cfg.cache_cap, cfg.d_head))
    for b in range(cfg.serve_batch):
        cache0[:, :, b, :, :cfg.m_max, :] = pkv

    pad = fx["manifest"]["seq_len"] - pf["tok_len"]
    tokens16 = pf["tokens"] + [3] * pad
    cache1, last = R.run_prefill(cfg, params, "pts", cache0, pkv,
                                 inp["prefix_len"], pf["slot"], tokens16,
                                 pf["tok_len"], ranges, inp["levels"],
                                 pf["kv_levels"], inv)
    check(name, cache1, gold["prefill.cache"], "prefill.cache")
    check(name, last, gold["prefill.last"], "prefill.last")

    bucket_tokens = pf["tokens"] + [3] * (pf["bucket"] - pf["tok_len"])
    _, blast = R.run_prefill(cfg, params, "fp", cache0, pkv,
                             inp["prefix_len"], pf["slot"], bucket_tokens,
                             pf["tok_len"], ranges, inp["levels"],
                             pf["kv_levels"], inv)
    nid, top = R.select_tokens(blast)
    assert int(nid) == gold["prefill_sampled.next_id"]
    check(name, [top], [gold["prefill_sampled.top"]], "prefill_sampled.top")

    dc = inp["decode"]
    gold_cache1 = R.tensor(gold["prefill.cache"])
    cache2, logits = R.run_decode(cfg, params, "ptk", gold_cache1,
                                  dc["cache_tok_len"], inp["prefix_len"],
                                  dc["tokens"], ranges, inp["levels"],
                                  dc["kv_levels"], inv)
    check(name, cache2, gold["decode.cache"], "decode.cache")
    check(name, logits, gold["decode.logits"], "decode.logits")

    _, slogits = R.run_decode(cfg, params, "pts", gold_cache1,
                              dc["cache_tok_len"], inp["prefix_len"],
                              dc["tokens"], ranges, inp["levels"],
                              dc["kv_levels"], inv)
    ids, tops = R.select_tokens(slogits)
    assert list(ids) == list(R.tensor(gold["decode_sampled.ids"])
                             .astype(np.int64))
    check(name, tops, gold["decode_sampled.top"], "decode_sampled.top")

    _, klogits = R.run_decode(cfg, params, "fp", gold_cache1,
                              dc["cache_tok_len"], inp["prefix_len"],
                              dc["tokens"], ranges, inp["levels"],
                              inp["levels"], inv)
    check(name, klogits, gold["decode_kivi.logits"], "decode_kivi.logits")
