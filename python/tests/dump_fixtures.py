"""Regenerate the interpreter golden fixtures (see conftest.py header).

Usage:  cd python && python3 tests/dump_fixtures.py
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))          # tests/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import conftest  # noqa: E402


if __name__ == "__main__":
    for path in conftest.dump_interp_fixtures():
        print(f"wrote {path} ({os.path.getsize(path) // 1024} KiB)")
