"""L1 correctness: the sink-attention kernel vs oracle across mask
configurations (prefix lengths, windows, ALiBi, strict-causal head, GQA)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import sink_attention


def _qkv(rng, hq, hkv, sq, skv, dh=32):
    q = jnp.asarray(rng.normal(size=(hq, sq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(hkv, skv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(hkv, skv, dh)), jnp.float32)
    return q, k, v


@settings(max_examples=20, deadline=None)
@given(
    hq=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    sq=st.integers(1, 96),
    plen=st.integers(0, 8),
    offset=st.integers(0, 16),
    strict=st.booleans(),
)
def test_kernel_matches_ref(hq, group, sq, plen, offset, strict):
    if hq % group:
        return
    rng = np.random.default_rng(sq * 7 + plen * 3 + offset)
    n_prefix = 8
    skv = n_prefix + sq + offset
    q, k, v = _qkv(rng, hq, hq // group, sq, skv)
    kw = dict(prefix_len=plen, n_prefix_slots=n_prefix, causal_offset=offset,
              strict_head0=strict)
    got = sink_attention(q, k, v, plen, n_prefix_slots=n_prefix,
                         causal_offset=offset, strict_head0=strict)
    want = ref.attention(q, k, v, **kw)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window,head0_global", [(16, False), (16, True),
                                                 (64, True)])
def test_kernel_sliding_window(window, head0_global, rng):
    q, k, v = _qkv(np.random.default_rng(3), 4, 2, 128, 144)
    got = sink_attention(q, k, v, 4, n_prefix_slots=16, window=window,
                         head0_global=head0_global)
    want = ref.attention(q, k, v, prefix_len=4, n_prefix_slots=16,
                         causal_offset=0, window=window,
                         head0_global=head0_global)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-5)


def test_kernel_alibi(rng):
    slopes = jnp.asarray(np.geomspace(1.0, 2 ** -7, 4), jnp.float32)
    q, k, v = _qkv(np.random.default_rng(5), 4, 4, 64, 80)
    got = sink_attention(q, k, v, 7, n_prefix_slots=16, alibi_slopes=slopes)
    want = ref.attention(q, k, v, prefix_len=7, n_prefix_slots=16,
                         causal_offset=0, alibi_slopes=slopes)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-5)


# --- semantic properties of the oracle itself -----------------------------

def test_causality(rng):
    """Changing a future key/value must not change past outputs."""
    q, k, v = _qkv(np.random.default_rng(9), 2, 2, 10, 26)
    base = ref.attention(q, k, v, prefix_len=0, n_prefix_slots=16,
                         causal_offset=0)
    k2 = k.at[:, 16 + 7, :].add(5.0)  # token position 7
    v2 = v.at[:, 16 + 7, :].add(5.0)
    pert = ref.attention(q, k2, v2, prefix_len=0, n_prefix_slots=16,
                         causal_offset=0)
    np.testing.assert_allclose(np.array(base[:, :7]), np.array(pert[:, :7]),
                               atol=1e-6)
    assert not np.allclose(np.array(base[:, 7:]), np.array(pert[:, 7:]))


def test_prefix_visibility(rng):
    """Valid prefix slots are visible to every query; invalid ones never."""
    q, k, v = _qkv(np.random.default_rng(11), 1, 1, 4, 20)
    # put a huge value marker in prefix slot 2's value
    v = v.at[:, 2, :].set(100.0)
    seen = ref.attention(q, k, v, prefix_len=3, n_prefix_slots=16,
                         causal_offset=0)
    hidden = ref.attention(q, k, v, prefix_len=2, n_prefix_slots=16,
                           causal_offset=0)
    # with prefix_len=3 the marker influences outputs; with 2 it cannot
    assert np.abs(np.array(seen)).max() > 10.0
    assert np.abs(np.array(hidden)).max() < 10.0


def test_strict_head0_masks_self(rng):
    """Head 0's diagonal is masked: a token's own kv cannot dominate."""
    q, k, v = _qkv(np.random.default_rng(13), 2, 2, 6, 22)
    # token 3's value is a huge marker
    v = v.at[:, 16 + 3, :].set(1000.0)
    out = ref.attention(q, k, v, prefix_len=0, n_prefix_slots=16,
                        causal_offset=0, strict_head0=True)
    # head 1 (not strict) at query 3 can see it; head 0 cannot
    assert np.abs(np.array(out[1, 3])).max() > 50.0
    assert np.abs(np.array(out[0, 3])).max() < np.abs(np.array(out[1, 3])).max()


def test_rows_softmax_normalized(rng):
    """kv_valid + window combine without leaking probability mass."""
    q, k, v = _qkv(np.random.default_rng(17), 2, 1, 32, 48)
    kv_valid = jnp.arange(48) % 3 != 0
    out = ref.attention(q, k, jnp.ones_like(v), prefix_len=5,
                        n_prefix_slots=16, causal_offset=0, window=8,
                        kv_valid=kv_valid)
    # with v = ones, any visible row sums to exactly 1 in every channel
    mags = np.array(out)
    ok = np.isclose(mags, 1.0, atol=1e-5) | np.isclose(mags, 0.0, atol=1e-6)
    assert ok.all()
