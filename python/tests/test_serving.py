"""Serving-path semantics: prefill/decode over the slot cache must agree
with the plain forward — the correctness backbone of the coordinator."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs as C, model as M, plant as P, serving as S
from compile.quantlib import QuantCtx

BIG = float(2 ** 24 - 1)


@pytest.fixture(scope="module")
def setup():
    cfg = C.VARIANTS["tl-llama"]
    params = P.plant_params(cfg, M.init_params(cfg, jax.random.PRNGKey(3)))
    return cfg, params


def fresh_cache(cfg, cushion_kv=None):
    cache = jnp.zeros((cfg.n_layers, 2, C.SERVE_BATCH, cfg.n_kv_heads,
                       C.CACHE_CAP, cfg.d_head), jnp.float32)
    if cushion_kv is not None:
        # broadcast cushion into every slot's prefix region
        cache = cache.at[:, :, :, :, :C.M_MAX, :].set(
            jnp.broadcast_to(cushion_kv[:, :, None],
                             (cfg.n_layers, 2, C.SERVE_BATCH,
                              cfg.n_kv_heads, C.M_MAX, cfg.d_head)))
    return cache


def toks(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(C.N_SPECIAL, cfg.vocab, size=n)
    t[0] = C.BOS
    return [int(x) for x in t]


def test_prefill_then_decode_matches_fwd(setup):
    """Greedy continuation via (prefill + decode steps) must equal the
    argmax chain computed by full re-forwards."""
    cfg, params = setup
    prompt = toks(cfg, 12, seed=1)
    n_steps = 4

    # reference: iterative full fwd
    seq = list(prompt)
    for _ in range(n_steps):
        t = jnp.asarray([seq + [C.PAD] * (C.SEQ_LEN - len(seq))], jnp.int32)
        logits, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                          jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
        seq.append(int(jnp.argmax(logits[0, len(seq) - 1])))
    want = seq[len(prompt):]

    # serving path
    cache = fresh_cache(cfg)
    padded = jnp.asarray(prompt + [C.PAD] * (C.SEQ_LEN - len(prompt)), jnp.int32)
    cache, last, _ = S.prefill(
        cfg, params, cache, M.empty_prefix(cfg), jnp.asarray(0, jnp.int32),
        jnp.asarray(2, jnp.int32), padded, jnp.asarray(len(prompt), jnp.int32),
        QuantCtx(mode="fp"), BIG)
    got = [int(jnp.argmax(last))]
    lens = jnp.zeros((C.SERVE_BATCH,), jnp.int32).at[2].set(len(prompt))
    for _ in range(n_steps - 1):
        step_tok = jnp.full((C.SERVE_BATCH,), C.PAD, jnp.int32).at[2].set(got[-1])
        cache, logits = S.decode(cfg, params, cache, lens,
                                 jnp.asarray(0, jnp.int32), step_tok,
                                 QuantCtx(mode="fp"), BIG)
        lens = lens.at[2].add(1)
        got.append(int(jnp.argmax(logits[2])))
    assert got == want


def test_decode_slots_are_isolated(setup):
    """Running a second slot must not change the first slot's logits."""
    cfg, params = setup
    prompt_a = toks(cfg, 10, seed=2)
    prompt_b = toks(cfg, 14, seed=3)

    def run(slots):
        cache = fresh_cache(cfg)
        lens = jnp.zeros((C.SERVE_BATCH,), jnp.int32)
        for slot, prompt in slots:
            padded = jnp.asarray(prompt + [C.PAD] * (C.SEQ_LEN - len(prompt)),
                                 jnp.int32)
            cache, _, _ = S.prefill(
                cfg, params, cache, M.empty_prefix(cfg),
                jnp.asarray(0, jnp.int32), jnp.asarray(slot, jnp.int32),
                padded, jnp.asarray(len(prompt), jnp.int32),
                QuantCtx(mode="fp"), BIG)
            lens = lens.at[slot].set(len(prompt))
        step_tok = jnp.full((C.SERVE_BATCH,), C.PAD, jnp.int32)
        step_tok = step_tok.at[0].set(prompt_a[-1])
        cache, logits = S.decode(cfg, params, cache, lens,
                                 jnp.asarray(0, jnp.int32), step_tok,
                                 QuantCtx(mode="fp"), BIG)
        return np.array(logits[0])

    alone = run([(0, prompt_a)])
    together = run([(0, prompt_a), (5, prompt_b)])
    np.testing.assert_allclose(alone, together, rtol=1e-4, atol=1e-4)


def test_prefill_with_cushion_matches_fwd_with_prefix(setup):
    cfg, params = setup
    ptoks = jnp.asarray([C.BOS] + [C.PAD] * (C.M_MAX - 1), jnp.int32)
    kv = M.compute_prefix_kv(cfg, params, ptoks, jnp.asarray(1, jnp.int32))
    prompt = toks(cfg, 16, seed=4)

    t = jnp.asarray([prompt + [C.PAD] * (C.SEQ_LEN - len(prompt))], jnp.int32)
    logits, _ = M.fwd(cfg, params, t, kv, jnp.asarray(1, jnp.int32),
                      QuantCtx(mode="fp"))
    want = np.array(logits[0, len(prompt) - 1])

    cache = fresh_cache(cfg, kv)
    padded = jnp.asarray(prompt + [C.PAD] * (C.SEQ_LEN - len(prompt)), jnp.int32)
    _, last, _ = S.prefill(
        cfg, params, cache, kv, jnp.asarray(1, jnp.int32),
        jnp.asarray(0, jnp.int32), padded,
        jnp.asarray(len(prompt), jnp.int32), QuantCtx(mode="fp"), BIG)
    np.testing.assert_allclose(np.array(last), want, rtol=1e-4, atol=1e-4)


def test_select_tokens_matches_host_argmax(setup):
    """The in-graph selection must be exactly host argmax, and the
    temperature/top-k scaffolding must not perturb the greedy choice."""
    rng = np.random.default_rng(7)
    logits = jnp.asarray(rng.normal(size=(C.SERVE_BATCH, 512)), jnp.float32)
    ids, top = S.select_tokens(logits)
    want = np.argmax(np.array(logits), axis=-1)
    np.testing.assert_array_equal(np.array(ids), want)
    np.testing.assert_allclose(
        np.array(top), np.array(logits).max(axis=-1), rtol=1e-6)
    for t, k in ((0.5, 0), (2.0, 0), (1.0, 5), (0.7, 3)):
        ids2, _ = S.select_tokens(logits, temperature=t, top_k=k)
        np.testing.assert_array_equal(np.array(ids2), want)
    # 1-D (prefill last-position) logits select a scalar
    one, top1 = S.select_tokens(logits[0])
    assert int(one) == int(want[0])
    assert float(top1) == pytest.approx(float(np.array(logits)[0].max()))


def test_decode_sampled_graph_matches_decode(setup):
    """decode_sampled must produce the cache of decode plus the argmax of
    its logits — the Rust engine's device-side selection contract."""
    cfg, params = setup
    from compile import graphs
    flat = [params[n] for n, _ in M.param_spec(cfg)]
    prompt = toks(cfg, 9, seed=6)
    cache = fresh_cache(cfg)
    padded = jnp.asarray(prompt + [C.PAD] * (C.SEQ_LEN - len(prompt)), jnp.int32)
    cache, _, _ = S.prefill(
        cfg, params, cache, M.empty_prefix(cfg), jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int32), padded, jnp.asarray(len(prompt), jnp.int32),
        QuantCtx(mode="fp"), BIG)
    lens = jnp.zeros((C.SERVE_BATCH,), jnp.int32).at[0].set(len(prompt))
    step_tok = jnp.full((C.SERVE_BATCH,), C.PAD, jnp.int32).at[0].set(prompt[-1])
    smooth = jnp.ones((cfg.n_layers, 2, cfg.d_model), jnp.float32)
    ranges = jnp.zeros((cfg.n_sites, 2), jnp.float32)
    common = (cache, lens, jnp.asarray(0, jnp.int32), step_tok, ranges,
              jnp.asarray(255.0), jnp.asarray(BIG), smooth)
    fn_ref, _ = graphs.make_decode(cfg, "fp")
    cache_ref, logits_ref = fn_ref(*flat, *common)
    fn_s, _ = graphs.make_decode_sampled(cfg, "fp")
    cache_s, ids, top = fn_s(*flat, *common)
    np.testing.assert_allclose(np.array(cache_s), np.array(cache_ref),
                               atol=1e-6)
    np.testing.assert_array_equal(
        np.array(ids), np.argmax(np.array(logits_ref), axis=-1))
    assert ids.dtype == jnp.int32


def test_bucketed_prefill_first_token_matches_full(setup):
    """Every bucket >= the prompt length must select the same first token
    as the full-SEQ_LEN prefill (at/below/above each boundary)."""
    cfg, params = setup
    from compile import graphs
    flat = [params[n] for n, _ in M.param_spec(cfg)]
    smooth = jnp.ones((cfg.n_layers, 2, cfg.d_model), jnp.float32)
    ranges = jnp.zeros((cfg.n_sites, 2), jnp.float32)

    def first_token(prompt, bucket):
        fn, _ = graphs.make_prefill_sampled(cfg, "fp", bucket)
        padded = jnp.asarray(prompt + [C.PAD] * (bucket - len(prompt)),
                             jnp.int32)
        _, next_id, _ = fn(
            *flat, fresh_cache(cfg), M.empty_prefix(cfg),
            jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32), padded,
            jnp.asarray(len(prompt), jnp.int32), ranges, jnp.asarray(255.0),
            jnp.asarray(BIG), smooth)
        return int(next_id)

    b0 = C.PREFILL_BUCKETS[0]
    for plen in (b0 - 1, b0, b0 + 1):
        prompt = toks(cfg, plen, seed=40 + plen)
        want = first_token(prompt, C.SEQ_LEN)
        for bucket in C.PREFILL_BUCKETS:
            if bucket >= plen:
                assert first_token(prompt, bucket) == want, (plen, bucket)


def test_kivi_levels_gate(setup):
    """kv_levels >= 2^20 must be exactly the FP path; low levels differ."""
    cfg, params = setup
    prompt = toks(cfg, 8, seed=5)
    padded = jnp.asarray(prompt + [C.PAD] * (C.SEQ_LEN - len(prompt)), jnp.int32)

    def last_logits(kv_levels):
        cache = fresh_cache(cfg)
        _, last, _ = S.prefill(
            cfg, params, cache, M.empty_prefix(cfg), jnp.asarray(0, jnp.int32),
            jnp.asarray(0, jnp.int32), padded,
            jnp.asarray(len(prompt), jnp.int32), QuantCtx(mode="fp"),
            jnp.asarray(kv_levels, jnp.float32))
        return np.array(last)

    np.testing.assert_allclose(last_logits(BIG), last_logits(BIG * 2),
                               atol=1e-6)
    assert not np.allclose(last_logits(3.0), last_logits(BIG), atol=1e-3)
