import os
import sys

# allow `import compile...` when pytest runs from python/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)
