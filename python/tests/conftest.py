import json
import os
import sys

# allow `import compile...` when pytest runs from python/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


# ---------------------------------------------------------------------------
# Interpreter golden-fixture dumper (rust/tests/interp_parity.rs)
# ---------------------------------------------------------------------------
#
# The Rust reference backend (rust/src/runtime/interp.rs + model/forward.rs)
# re-implements the model/serving/quantlib forward passes on host tensors so
# the whole system runs without XLA artifacts. These fixtures pin it to the
# JAX oracle: for a set of *mini* model configs (every norm/act/pos/window/
# GQA combination the real variants use, at toy sizes) we dump the weights,
# the inputs, and the outputs of each graph entry point in graphs.py —
# fwd_{fp,pts,ptd,ptk}, stats, score_lq, prefix_kv, tune_step, prefill /
# prefill_sampled, decode / decode_sampled (+ a KV-quant decode) — as JSON.
#
# Regenerate with:   cd python && python3 tests/dump_fixtures.py
# (writes python/tests/fixtures/interp/<config>.json; commit the result)
#
# Numerical-robustness contract: every golden is recomputed under x64 and
# the f32/f64 deviation must stay below X64_DELTA_TOL. This guarantees the
# fixtures sit far from quantization rounding boundaries, so any faithful
# f32/f64 re-implementation (the Rust interpreter accumulates in f64) lands
# within the 1e-4 parity budget instead of flipping a quantization bucket.
# If the check trips after an edit, bump FIXTURE_SEED until it passes.

FIXTURE_SEED = 11
X64_DELTA_TOL = 2e-5
# mini sizes patched into compile.configs while dumping (the graph bodies
# read C.M_MAX / C.CACHE_CAP / C.SCORE_* / C.SERVE_BATCH at call time)
MINI_SIZES = dict(M_MAX=4, CACHE_CAP=20, SCORE_BATCH=8, SCORE_TEXT_LEN=12,
                  SERVE_BATCH=2)
MINI_SEQ = 16
MINI_EVAL_BATCH = 2
MINI_PREFILL_BUCKET = 8

FIXTURE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "fixtures", "interp")


def mini_configs():
    """Toy configs covering the architectural axes of configs.VARIANTS:
    pre-RMSNorm/SwiGLU/RoPE/GQA, post-LN/GELU/ALiBi, and sliding-window/
    learned-positions/ReLU."""
    from compile import configs as C

    base = dict(vocab=64, d_model=32, n_layers=2, n_heads=2, d_head=16,
                d_ff=48)
    return {
        "mini-pre": C.ModelCfg(name="mini-pre", n_kv_heads=1, **base),
        "mini-post": C.ModelCfg(name="mini-post", n_kv_heads=2,
                                norm="ln_post", act="gelu", pos="alibi",
                                **base),
        "mini-win": C.ModelCfg(name="mini-win", n_kv_heads=2, act="relu",
                               pos="learned", window=8, **base),
    }


def _arr(x):
    """Tensor -> {"shape": [...], "data": [flat f32-exact floats]}."""
    a = np.asarray(x)
    if a.dtype.kind == "f":
        a = a.astype(np.float32)
        return {"shape": list(a.shape),
                "data": [float(v) for v in a.reshape(-1)]}
    return {"shape": list(a.shape), "data": [int(v) for v in a.reshape(-1)]}


def _mini_manifest(cfg):
    from compile import configs as C
    from compile import model as M

    return {
        "variant": cfg.name,
        "vocab": cfg.vocab, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
        "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
        "d_ff": cfg.d_ff, "norm": cfg.norm, "act": cfg.act, "pos": cfg.pos,
        "window": cfg.window or 0, "n_sites": cfg.n_sites,
        "seq_len": MINI_SEQ,
        "prefill_buckets": [MINI_PREFILL_BUCKET, MINI_SEQ],
        "m_max": C.M_MAX, "cache_cap": C.CACHE_CAP,
        "serve_batch": C.SERVE_BATCH, "eval_batch": MINI_EVAL_BATCH,
        "score_batch": C.SCORE_BATCH, "score_text_len": C.SCORE_TEXT_LEN,
        "tune_batch": MINI_EVAL_BATCH,
        "params": [{"name": n, "shape": list(s)}
                   for n, s in M.param_spec(cfg)],
        "graphs": [],
    }


def _initial_cache(cfg, prefix_kv):
    """Host-built serving cache with the cushion KV broadcast into every
    slot's prefix region (mirrors KvManager::initial_cache)."""
    from compile import configs as C

    cache = np.zeros((cfg.n_layers, 2, C.SERVE_BATCH, cfg.n_kv_heads,
                      C.CACHE_CAP, cfg.d_head), np.float32)
    for b in range(C.SERVE_BATCH):
        cache[:, :, b, :, :C.M_MAX, :] = np.asarray(prefix_kv)
    return cache


def _dump_one(cfg, out_path):
    from compile import serving

    # kivi_qdq_kv groups keys along d_head in blocks of 32; the mini head
    # dim is 16, so serving's KV-quant path needs group == d_head (the Rust
    # interpreter uses the same rule: 32 when d_head % 32 == 0, else d_head)
    saved_kivi = serving.kivi_qdq_kv
    try:
        if cfg.d_head % 32 != 0:
            from compile import quantlib
            serving.kivi_qdq_kv = \
                lambda k, v, lv: quantlib.kivi_qdq_kv(k, v, lv,
                                                      key_group=cfg.d_head)
        return _dump_one_inner(cfg, out_path)
    finally:
        serving.kivi_qdq_kv = saved_kivi


def _dump_one_inner(cfg, out_path):
    import jax
    import jax.numpy as jnp

    from compile import configs as C
    from compile import graphs as G
    from compile import model as M
    from compile import quantlib

    rng = np.random.default_rng(FIXTURE_SEED)
    params = M.init_params(cfg, jax.random.PRNGKey(FIXTURE_SEED))
    flat = [params[n] for n, _ in M.param_spec(cfg)]
    weights = {n: _arr(params[n]) for n, _ in M.param_spec(cfg)}

    i32 = lambda x: jnp.asarray(x, jnp.int32)
    f32 = lambda x: jnp.asarray(x, jnp.float32)

    tokens = rng.integers(0, cfg.vocab, size=(MINI_EVAL_BATCH, MINI_SEQ))
    prefix_len = 3
    prefix_tokens = list(rng.integers(4, cfg.vocab, size=prefix_len)) \
        + [C.PAD] * (C.M_MAX - prefix_len)
    levels = 255.0
    inv_smooth = np.exp(
        0.25 * rng.standard_normal((cfg.n_layers, 2, cfg.d_model))
    ).astype(np.float32)
    score_cands = rng.integers(0, cfg.vocab, size=C.SCORE_BATCH)
    score_text = rng.integers(0, cfg.vocab, size=C.SCORE_TEXT_LEN)
    adam_m = (0.001 * rng.standard_normal(
        (cfg.n_layers, 2, cfg.n_kv_heads, C.M_MAX, cfg.d_head))
    ).astype(np.float32)
    adam_v = np.square(0.01 * rng.standard_normal(adam_m.shape)) \
        .astype(np.float32)
    prefill_tok_len = 5
    prefill_tokens = list(rng.integers(0, cfg.vocab, size=prefill_tok_len))
    kv_off = float(2 ** 24)
    dec_tokens = [int(t) for t in rng.integers(0, cfg.vocab,
                                               size=C.SERVE_BATCH)]
    dec_lens = [0] * (C.SERVE_BATCH - 1) + [prefill_tok_len]

    def compute(tag):
        """Run every graph entry point; returns {name: np array or scalar}.
        `tag` is only used for logging."""
        out = {}
        pkv = G.make_prefix_kv(cfg)[0](*flat, i32(prefix_tokens),
                                       i32(prefix_len))
        out["prefix_kv"] = pkv

        st = G.make_stats(cfg)[0](*flat, f32(pkv), i32(prefix_len),
                                  i32(tokens))
        for k, v in zip(("minmax", "chan_d", "chan_f", "acts_grid",
                         "act_stats", "probs"), st):
            out[f"stats.{k}"] = v

        ranges = quantlib.ranges_from_minmax(f32(st[0]), levels)
        out["ranges"] = ranges
        for mode in ("fp", "pts", "ptd", "ptk"):
            (logits,) = G.make_fwd(cfg, mode)[0](
                *flat, f32(pkv), i32(prefix_len), i32(tokens), f32(ranges),
                f32(levels), f32(inv_smooth))
            out[f"fwd_{mode}"] = logits

        out["score_lq"] = G.make_score(cfg)[0](
            *flat, i32(prefix_tokens), i32(prefix_len), i32(score_cands),
            i32(score_text), f32(levels), f32(inv_smooth))

        pkv2, m2, v2, loss, lq = G.make_tune_step(cfg)[0](
            *flat, f32(pkv), f32(adam_m), f32(adam_v), i32(5), i32(tokens),
            i32(prefix_len), f32(0.01), f32(3e-3), f32(levels),
            f32(inv_smooth))
        out["tune.pkv2"], out["tune.m2"], out["tune.v2"] = pkv2, m2, v2
        out["tune.loss"], out["tune.lq"] = loss, lq

        cache0 = _initial_cache(cfg, pkv)
        padded = prefill_tokens + [C.PAD] * (MINI_SEQ - prefill_tok_len)
        cache1, last = G.make_prefill(cfg, "pts")[0](
            *flat, f32(cache0), f32(pkv), i32(prefix_len), i32(1),
            i32(padded), i32(prefill_tok_len), f32(ranges), f32(levels),
            f32(kv_off), f32(inv_smooth))
        out["prefill.cache"], out["prefill.last"] = cache1, last

        bucket = prefill_tokens + [C.PAD] * (MINI_PREFILL_BUCKET
                                             - prefill_tok_len)
        _, nid, top = G.make_prefill_sampled(cfg, "fp",
                                             MINI_PREFILL_BUCKET)[0](
            *flat, f32(cache0), f32(pkv), i32(prefix_len), i32(1),
            i32(bucket), i32(prefill_tok_len), f32(ranges), f32(levels),
            f32(kv_off), f32(inv_smooth))
        out["prefill_sampled.next_id"], out["prefill_sampled.top"] = nid, top

        cache2, logits = G.make_decode(cfg, "ptk")[0](
            *flat, f32(cache1), i32(dec_lens), i32(prefix_len),
            i32(dec_tokens), f32(ranges), f32(levels), f32(kv_off),
            f32(inv_smooth))
        out["decode.cache"], out["decode.logits"] = cache2, logits

        _, ids, tops = G.make_decode_sampled(cfg, "pts")[0](
            *flat, f32(cache1), i32(dec_lens), i32(prefix_len),
            i32(dec_tokens), f32(ranges), f32(levels), f32(kv_off),
            f32(inv_smooth))
        out["decode_sampled.ids"], out["decode_sampled.top"] = ids, tops

        _, kivi_logits = G.make_decode(cfg, "fp")[0](
            *flat, f32(cache1), i32(dec_lens), i32(prefix_len),
            i32(dec_tokens), f32(ranges), f32(levels), f32(levels),
            f32(inv_smooth))
        out["decode_kivi.logits"] = kivi_logits
        return {k: np.asarray(v) for k, v in out.items()}

    golden = compute("f32")
    # x64 margin pass: far-from-rounding-boundary guarantee (see header)
    jax.config.update("jax_enable_x64", True)
    try:
        flat = [jnp.asarray(np.asarray(w), jnp.float64) for w in flat]
        f32 = lambda x: jnp.asarray(np.asarray(x), jnp.float64)  # noqa: E731
        i32 = lambda x: jnp.asarray(x, jnp.int64)  # noqa: E731
        golden64 = compute("f64")
    finally:
        jax.config.update("jax_enable_x64", False)

    x64_delta = {}
    for k, v in golden.items():
        if v.dtype.kind != "f":
            assert np.array_equal(v, golden64[k]), \
                f"{cfg.name}/{k}: integer outputs diverge between f32/f64"
            continue
        d = float(np.max(np.abs(v.astype(np.float64) - golden64[k])))
        scale = max(1.0, float(np.max(np.abs(v))))
        x64_delta[k] = d
        assert d <= X64_DELTA_TOL * scale, (
            f"{cfg.name}/{k}: f32 vs f64 golden deviation {d:.3e} exceeds "
            f"{X64_DELTA_TOL:.0e} x {scale:.1f} — too close to a rounding "
            f"boundary; bump FIXTURE_SEED and re-dump")

    fixture = {
        "config": cfg.name,
        "seed": FIXTURE_SEED,
        "manifest": _mini_manifest(cfg),
        "weights": weights,
        "inputs": {
            "tokens": _arr(tokens),
            "prefix_tokens": [int(t) for t in prefix_tokens],
            "prefix_len": prefix_len,
            "levels": levels,
            "ranges": _arr(golden["ranges"]),
            "inv_smooth": _arr(inv_smooth),
            "score_cands": [int(t) for t in score_cands],
            "score_text": [int(t) for t in score_text],
            "tune": {"step": 5, "lam": 0.01, "lr": 3e-3,
                     "adam_m": _arr(adam_m), "adam_v": _arr(adam_v)},
            "prefill": {"slot": 1, "tok_len": prefill_tok_len,
                        "tokens": [int(t) for t in prefill_tokens],
                        "bucket": MINI_PREFILL_BUCKET,
                        "kv_levels": kv_off},
            "decode": {"tokens": dec_tokens, "cache_tok_len": dec_lens,
                       "kv_levels": kv_off},
        },
        "golden": {},
        "x64_max_delta": x64_delta,
    }
    golden.pop("ranges")
    for k, v in golden.items():
        if v.ndim == 0 and v.dtype.kind == "f":
            fixture["golden"][k] = float(v)
        elif v.ndim == 0:
            fixture["golden"][k] = int(v)
        else:
            fixture["golden"][k] = _arr(v)

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(fixture, f)
    return fixture


def dump_interp_fixtures(out_dir=FIXTURE_DIR):
    """Write one golden fixture per mini config (see module header)."""
    from compile import configs as C

    saved = {k: getattr(C, k) for k in MINI_SIZES}
    for k, v in MINI_SIZES.items():
        setattr(C, k, v)
    try:
        paths = []
        for name, cfg in mini_configs().items():
            path = os.path.join(out_dir, f"{name}.json")
            _dump_one(cfg, path)
            paths.append(path)
        return paths
    finally:
        for k, v in saved.items():
            setattr(C, k, v)
