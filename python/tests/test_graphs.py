"""Graph-level semantics: the scorer, the tuning step, and the stats
outputs behave as the drivers assume."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs as C, graphs, model as M, plant as P, datagen
from compile.prng import SplitMix64


@pytest.fixture(scope="module")
def setup():
    cfg = C.VARIANTS["tl-llama"]
    params = P.plant_params(cfg, M.init_params(cfg, jax.random.PRNGKey(9)))
    flat = [params[n] for n, _ in M.param_spec(cfg)]
    return cfg, flat


def _ones_smooth(cfg):
    return jnp.ones((cfg.n_layers, 2, cfg.d_model), jnp.float32)


def _text(cfg, seed=0):
    g = datagen.Grammar(cfg.vocab)
    rng = SplitMix64(seed)
    return jnp.asarray(g.document(C.SCORE_TEXT_LEN, rng), jnp.int32)


def test_scorer_prefers_trigger_tokens(setup):
    """The greedy scorer must rank <bos>-like candidates far better than
    content tokens — the mechanism the whole search relies on."""
    cfg, flat = setup
    fn, _ = graphs.make_score(cfg)
    prefix = jnp.full((C.M_MAX,), C.PAD, jnp.int32)
    cands = jnp.asarray(
        [C.BOS, C.NL, C.DOT] + list(range(C.N_SPECIAL, C.N_SPECIAL + C.SCORE_BATCH - 3)),
        jnp.int32)
    lq = np.array(fn(*flat, prefix, jnp.asarray(0, jnp.int32), cands,
                     _text(cfg), jnp.asarray(255.0), _ones_smooth(cfg)))
    triggers = lq[:3]
    content = lq[3:]
    assert triggers.max() < content.min() * 0.5, (
        f"triggers {triggers} should dominate content (min {content.min()})")


def test_scorer_lq_drops_vs_empty_prefix(setup):
    cfg, flat = setup
    fn, _ = graphs.make_score(cfg)
    pad_prefix = jnp.full((C.M_MAX,), C.PAD, jnp.int32)
    cands = jnp.full((C.SCORE_BATCH,), C.PAD, jnp.int32)
    base = np.array(fn(*flat, pad_prefix, jnp.asarray(0, jnp.int32), cands,
                       _text(cfg), jnp.asarray(255.0), _ones_smooth(cfg)))[0]
    bos_prefix = pad_prefix.at[0].set(C.BOS)
    with_bos = np.array(fn(*flat, bos_prefix, jnp.asarray(1, jnp.int32), cands,
                           _text(cfg), jnp.asarray(255.0), _ones_smooth(cfg)))[0]
    assert with_bos < 0.1 * base, (base, with_bos)


def test_tune_step_updates_only_valid_slots(setup):
    cfg, flat = setup
    fn, _ = graphs.make_tune_step(cfg)
    prefix_tokens = jnp.asarray([C.BOS] + [C.PAD] * (C.M_MAX - 1), jnp.int32)
    kv = M.compute_prefix_kv(
        cfg, {n: w for (n, _), w in zip(M.param_spec(cfg), flat)},
        prefix_tokens, jnp.asarray(1, jnp.int32))
    zeros = jnp.zeros_like(kv)
    g = datagen.Grammar(cfg.vocab)
    rng = SplitMix64(4)
    toks = jnp.asarray([g.document(C.SEQ_LEN, rng.fork(i))
                        for i in range(C.TUNE_BATCH)], jnp.int32)
    kv2, m2, v2, loss, lq = fn(
        *flat, kv, zeros, zeros, jnp.asarray(0, jnp.int32), toks,
        jnp.asarray(1, jnp.int32), jnp.asarray(0.01), jnp.asarray(1e-3),
        jnp.asarray(255.0), _ones_smooth(cfg))
    kv2 = np.array(kv2)
    # padding slots (positions >= 1) must stay exactly zero
    assert np.abs(kv2[:, :, :, 1:, :]).max() == 0.0
    # the valid slot must move
    assert np.abs(kv2[:, :, :, 0, :] - np.array(kv)[:, :, :, 0, :]).max() > 0
    assert np.isfinite(float(loss)) and float(lq) >= 0


def test_tune_step_reduces_loss_over_steps(setup):
    cfg, flat = setup
    fn, _ = graphs.make_tune_step(cfg)
    params = {n: w for (n, _), w in zip(M.param_spec(cfg), flat)}
    prefix_tokens = jnp.asarray([C.BOS] + [C.PAD] * (C.M_MAX - 1), jnp.int32)
    kv = M.compute_prefix_kv(cfg, params, prefix_tokens, jnp.asarray(1, jnp.int32))
    m_ = jnp.zeros_like(kv)
    v_ = jnp.zeros_like(kv)
    g = datagen.Grammar(cfg.vocab)
    rng = SplitMix64(5)
    toks = jnp.asarray([g.document(C.SEQ_LEN, rng.fork(i))
                        for i in range(C.TUNE_BATCH)], jnp.int32)
    jfn = jax.jit(fn)
    losses = []
    for step in range(6):
        kv, m_, v_, loss, _ = jfn(
            *flat, kv, m_, v_, jnp.asarray(step, jnp.int32), toks,
            jnp.asarray(1, jnp.int32), jnp.asarray(0.01), jnp.asarray(3e-3),
            jnp.asarray(255.0), _ones_smooth(cfg))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_stats_graph_outputs_consistent(setup):
    cfg, flat = setup
    fn, _ = graphs.make_stats(cfg)
    g = datagen.Grammar(cfg.vocab)
    rng = SplitMix64(6)
    toks = jnp.asarray([g.document(C.SEQ_LEN, rng.fork(i))
                        for i in range(C.EVAL_BATCH)], jnp.int32)
    minmax, chan_d, chan_f, grid, stats, probs = fn(
        *flat, M.empty_prefix(cfg), jnp.asarray(0, jnp.int32), toks)
    assert minmax.shape == (cfg.n_sites, 2)
    assert chan_d.shape == (3 * cfg.n_layers, cfg.d_model)
    assert chan_f.shape == (cfg.n_layers, cfg.d_ff)
    assert grid.shape == (cfg.n_layers + 1, C.EVAL_BATCH, C.SEQ_LEN)
    assert stats.shape == (cfg.n_layers + 1, 3)
    # order statistics are ordered: top1 >= p90 >= median
    s = np.array(stats)
    assert (s[:, 0] >= s[:, 1] - 1e-6).all() and (s[:, 1] >= s[:, 2] - 1e-6).all()
    # attention rows sum to ~1 where visible
    p = np.array(probs)
    sums = p.sum(-1)
    assert ((np.abs(sums - 1.0) < 1e-3) | (sums < 1e-3)).all()
