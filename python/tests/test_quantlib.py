"""quantlib: site semantics, SmoothQuant/AWQ/QuaRot/KIVI oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quantlib as Q
from compile.kernels import ref


def test_site_fp_passthrough_records_stats(rng):
    ctx = Q.QuantCtx(mode="fp")
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    y = ctx.site(x, 0, 1)
    np.testing.assert_array_equal(np.array(x), np.array(y))
    mn, mx = ctx.minmax[0]
    assert float(mn) <= 0.0 <= float(mx)


def test_site_ptd_excludes_masked_positions(rng):
    x = jnp.asarray(rng.normal(size=(1, 8, 4)), jnp.float32)
    x = x.at[0, 0, 0].set(500.0)
    valid = jnp.ones((1, 8), bool).at[0, 0].set(False)
    ctx = Q.QuantCtx(mode="ptd", levels=255.0, valid=valid)
    ctx.site(x, 0, 0)
    mn, mx = ctx.minmax[0]
    assert float(mx) < 100.0, "masked outlier must not widen the range"


def test_site_ptk_per_row_ranges(rng):
    x = jnp.asarray(rng.normal(size=(1, 4, 64)), jnp.float32)
    x = x.at[0, 2].mul(100.0)  # one hot row
    ctx = Q.QuantCtx(mode="ptk", levels=255.0)
    y = np.array(ctx.site(x, 0, 0))
    # other rows keep fine resolution despite the hot row
    err_other = np.abs(y[0, 0] - np.array(x[0, 0])).max()
    assert err_other < 0.05


def test_site_pts_uses_static_ranges(rng):
    x = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    ranges = jnp.zeros((16, 2)).at[:, 1].set(1e-8)  # degenerate scale
    ctx = Q.QuantCtx(mode="pts", levels=255.0, static_ranges=ranges)
    y = np.array(ctx.site(x, 3, 2))  # site idx 14
    assert np.abs(y).max() < 1e-4  # everything collapses to ~lo


def test_site_per_example_lq_shape(rng):
    x = jnp.asarray(rng.normal(size=(5, 4, 8)), jnp.float32)
    ctx = Q.QuantCtx(mode="ptd", levels=3.0, per_example=True)
    ctx.site(x, 0, 0)
    assert np.array(ctx.lq).shape == (5,)
    assert (np.array(ctx.lq) > 0).all()


def test_site_ste_gradients_flow(rng):
    """With ste=True, d qdq(x)/dx == 1 (straight-through)."""
    def f(x, ste):
        ctx = Q.QuantCtx(mode="ptd", levels=15.0, ste=ste)
        return jnp.sum(ctx.site(x, 0, 0))

    x = jnp.asarray(rng.normal(size=(1, 2, 4)), jnp.float32)
    g_ste = jax.grad(lambda x: f(x, True))(x)
    np.testing.assert_allclose(np.array(g_ste), 1.0, atol=1e-6)


def test_inv_smooth_applied_at_in_sites(rng):
    x = jnp.ones((1, 2, 4), jnp.float32)
    inv = jnp.full((1, 2, 4), 0.5)
    ctx = Q.QuantCtx(mode="fp", inv_smooth=inv)
    y0 = ctx.site(x, 0, 0)   # attn_in: smoothed
    y1 = ctx.site(x, 0, 1)   # attn_out: untouched
    y2 = ctx.site(x, 0, 2)   # mlp_in: smoothed
    np.testing.assert_allclose(np.array(y0), 0.5)
    np.testing.assert_allclose(np.array(y1), 1.0)
    np.testing.assert_allclose(np.array(y2), 0.5)


def test_smoothquant_function_preserving(rng):
    """(x / s) @ (s W) == x @ W."""
    x = jnp.asarray(rng.normal(size=(6, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    act_max = jnp.abs(x).max(axis=0)
    s = Q.smooth_scales(act_max, jnp.abs(w).max(axis=1), alpha=0.8)
    out = (x / s) @ (w * s[:, None])
    np.testing.assert_allclose(np.array(out), np.array(x @ w), rtol=1e-4,
                               atol=1e-4)


def test_smoothquant_reduces_act_range(rng):
    x = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    x = x.at[:, 3].mul(50.0)  # outlier channel
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    s = Q.smooth_scales(jnp.abs(x).max(axis=0), jnp.abs(w).max(axis=1), 0.8)
    ratio = lambda t: float(jnp.abs(t).max() / jnp.median(jnp.abs(t)))
    assert ratio(x / s) < ratio(x)


def test_awq_roundtrip_protects_salient(rng):
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    act = jnp.ones((64,)).at[5].set(1e4)
    q_awq = Q.awq_scale_weight(w, act, bits=3.0)
    q_plain = Q.quant_weight(w, bits=3.0)
    err_awq = float(jnp.abs(q_awq[5] - w[5]).mean())
    err_plain = float(jnp.abs(q_plain[5] - w[5]).mean())
    assert err_awq < err_plain


def test_hadamard_orthonormal_and_spreading():
    h = Q.hadamard(256)
    eye = np.array(h @ h.T)
    np.testing.assert_allclose(eye, np.eye(256), atol=1e-4)
    x = jnp.zeros((1, 256)).at[0, 13].set(1000.0)
    xr = np.array(x @ h)
    assert np.abs(xr).max() < 100.0  # spread across channels


def test_kivi_kv_roundtrip(rng):
    k = jnp.asarray(rng.normal(size=(2, 3, 10, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 3, 10, 64)), jnp.float32)
    kq, vq = Q.kivi_qdq_kv(k, v, levels=3.0)
    assert kq.shape == k.shape and vq.shape == v.shape
    # 2-bit is lossy but bounded by the per-group range
    assert float(jnp.abs(kq - k).max()) < float(jnp.abs(k).max())
    # near-identity at high levels
    kq24, _ = Q.kivi_qdq_kv(k, v, levels=float(2 ** 24 - 1))
    np.testing.assert_allclose(np.array(kq24), np.array(k), atol=1e-4)


def test_ranges_from_minmax_keeps_zero():
    mm = jnp.asarray([[0.5, 2.0], [-3.0, -1.0]], jnp.float32)
    r = np.array(Q.ranges_from_minmax(mm, 255.0))
    assert r[0, 0] == 0.0           # lo clamped to include zero
    assert r[1, 0] == -3.0
    assert r[1, 1] >= 3.0 / 255.0   # hi clamped up to zero
