"""L2 model invariants across the five variants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs as C, model as M, plant as P
from compile.quantlib import QuantCtx


def small_cfg(base: str, **kw):
    """A shrunken copy of a variant for fast tests."""
    import dataclasses
    cfg = C.VARIANTS[base]
    return dataclasses.replace(cfg, **kw)


@pytest.fixture(scope="module")
def params_by_variant():
    out = {}
    for name, cfg in C.VARIANTS.items():
        key = jax.random.PRNGKey(cfg.seed)
        out[name] = P.plant_params(cfg, M.init_params(cfg, key))
    return out


def toks(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(C.N_SPECIAL, cfg.vocab, size=(b, s))
    t[:, 0] = C.BOS
    return jnp.asarray(t, jnp.int32)


@pytest.mark.parametrize("name", list(C.VARIANTS))
def test_fwd_shapes(name, params_by_variant):
    cfg = C.VARIANTS[name]
    params = params_by_variant[name]
    t = toks(cfg, 2, 32)
    logits, aux = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                        jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    assert logits.shape == (2, 32, cfg.vocab)
    assert aux["minmax"].shape == (cfg.n_sites, 2)
    assert np.isfinite(np.array(logits)).all()


@pytest.mark.parametrize("name", ["tl-llama", "tl-opt", "tl-bloom"])
def test_causality(name, params_by_variant):
    """Perturbing token j only changes logits at positions >= j."""
    cfg = C.VARIANTS[name]
    params = params_by_variant[name]
    t = toks(cfg, 1, 24, seed=1)
    lg1, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                   jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    t2 = t.at[0, 10].set((int(t[0, 10]) + 3 - C.N_SPECIAL)
                         % (cfg.vocab - C.N_SPECIAL) + C.N_SPECIAL)
    lg2, _ = M.fwd(cfg, params, t2, M.empty_prefix(cfg),
                   jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    np.testing.assert_allclose(np.array(lg1[:, :10]), np.array(lg2[:, :10]),
                               atol=1e-5)
    assert not np.allclose(np.array(lg1[:, 10:]), np.array(lg2[:, 10:]))


@pytest.mark.parametrize("name", ["tl-llama", "tl-llama3", "tl-mistral",
                                  "tl-opt", "tl-bloom"])
def test_prefix_kv_equivalence(name, params_by_variant):
    """fwd(text | prefix-as-KV) must equal fwd(prefix ++ text) restricted
    to the text positions — the KV-cache correctness identity (paper eq. 8)."""
    cfg = C.VARIANTS[name]
    params = params_by_variant[name]
    plen = 3
    prefix_toks = jnp.asarray([C.BOS, C.NL, C.DOT] + [C.PAD] * (C.M_MAX - plen),
                              jnp.int32)
    text = toks(cfg, 1, 20, seed=2)

    kv = M.compute_prefix_kv(cfg, params, prefix_toks, jnp.asarray(plen, jnp.int32))
    lg_kv, _ = M.fwd(cfg, params, text, kv, jnp.asarray(plen, jnp.int32),
                     QuantCtx(mode="fp"))

    concat = jnp.concatenate([prefix_toks[None, :plen], text], axis=1)
    lg_full, _ = M.fwd(cfg, params, concat, M.empty_prefix(cfg),
                       jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    np.testing.assert_allclose(np.array(lg_kv), np.array(lg_full[:, plen:]),
                               rtol=2e-3, atol=2e-3)


def test_empty_prefix_is_noop(params_by_variant):
    """prefix_len=0 with a garbage prefix tensor must not leak."""
    cfg = C.VARIANTS["tl-llama"]
    params = params_by_variant["tl-llama"]
    t = toks(cfg, 1, 16, seed=3)
    lg0, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                   jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    garbage = M.empty_prefix(cfg) + 1e3
    lg1, _ = M.fwd(cfg, params, t, garbage, jnp.asarray(0, jnp.int32),
                   QuantCtx(mode="fp"))
    np.testing.assert_allclose(np.array(lg0), np.array(lg1), atol=1e-6)


def test_rope_relative_shift(params_by_variant):
    """RoPE attention depends on relative positions: shifting all
    positions by a constant barely changes next-token logits when no
    content anchors absolute position."""
    cfg = C.VARIANTS["tl-llama"]
    params = params_by_variant["tl-llama"]
    t = toks(cfg, 1, 16, seed=4)
    pos0 = jnp.arange(16, dtype=jnp.int32)[None]
    lgA, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                   jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"),
                   positions=pos0)
    lgB, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                   jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"),
                   positions=pos0 + 5)
    np.testing.assert_allclose(np.array(lgA), np.array(lgB), rtol=0.05,
                               atol=0.05)


def test_loss_pred_uniform_at_init():
    """An unplanted random model's CE should be close to ln(vocab)."""
    cfg = C.VARIANTS["tl-llama"]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg, 2, 64, seed=5)
    logits, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                      jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    loss = float(M.loss_pred(logits, t))
    assert abs(loss - np.log(cfg.vocab)) < 1.5


def test_token_logprobs_sum_to_one(params_by_variant):
    cfg = C.VARIANTS["tl-llama"]
    params = params_by_variant["tl-llama"]
    t = toks(cfg, 1, 8, seed=6)
    logits, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                      jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    probs = np.exp(np.array(jax.nn.log_softmax(logits, axis=-1)))
    np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-4)


def test_param_spec_matches_init():
    for cfg in C.VARIANTS.values():
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        spec = M.param_spec(cfg)
        assert set(params) == {n for n, _ in spec}
        for n, shape in spec:
            assert params[n].shape == shape, (cfg.name, n)


def test_gqa_group_math():
    assert C.VARIANTS["tl-llama3"].group_size == 2
    assert C.VARIANTS["tl-llama"].group_size == 1


def test_pallas_path_matches_jnp(params_by_variant):
    """use_pallas=True must be numerically identical to the jnp path."""
    cfg = C.VARIANTS["tl-llama3"]
    params = params_by_variant["tl-llama3"]
    t = toks(cfg, 1, 32, seed=7)
    lg_j, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                    jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"))
    lg_p, _ = M.fwd(cfg, params, t, M.empty_prefix(cfg),
                    jnp.asarray(0, jnp.int32), QuantCtx(mode="fp"),
                    use_pallas=True)
    np.testing.assert_allclose(np.array(lg_j), np.array(lg_p), rtol=1e-4,
                               atol=1e-4)
