"""L1 correctness: the tiled W8A8 qmatmul kernel vs oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.qmatmul import (qmatmul_per_tensor, qmatmul_per_token,
                                     tile_stats)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.sampled_from([32, 64, 256]),
    n=st.integers(1, 160),
    bits=st.sampled_from([4, 8]),
)
def test_qmatmul_per_tensor_matches_ref(m, k, n, bits):
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    levels = float(2 ** bits - 1)
    lo, scale = -4.0, 8.0 / levels
    got = qmatmul_per_tensor(x, w, lo, scale, levels)
    want = ref.qmatmul(x, w, lo, scale, levels)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 130), n=st.integers(1, 130))
def test_qmatmul_per_token_matches_ref(m, n):
    rng = np.random.default_rng(m * 131 + n)
    k = 64
    x = jnp.asarray(rng.normal(size=(m, k)) * 2, jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = qmatmul_per_token(x, w, 255.0)
    want = ref.qdq_dynamic(x, 255.0, axis=1) @ w
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-3)


def test_weight_quant_grouped_error_bound(rng):
    w = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    q = ref.quant_weight_sym_grouped(w, 8.0, group=64)
    # per group, error bounded by half a step of the group's scale
    wg = np.array(w).reshape(4, 64, 64)
    qg = np.array(q).reshape(4, 64, 64)
    for g in range(4):
        step = np.abs(wg[g]).max(axis=0) / 127
        assert (np.abs(wg[g] - qg[g]) <= step / 2 + 1e-6).all()


def test_tile_stats_mxu_model():
    vmem, mxu, hbm = tile_stats(128, 256, 128)
    assert mxu == 1.0  # perfectly MXU-shaped
    assert vmem == (128 * 256 + 256 * 128 + 128 * 128) * 4
    # ragged tile wastes systolic capacity
    _, mxu_ragged, _ = tile_stats(10, 256, 10, block_m=10, block_n=10)
    assert mxu_ragged < 0.02

    # full problem HBM traffic scales with tile count
    _, _, hbm2 = tile_stats(256, 256, 256)
    assert hbm2 > hbm
