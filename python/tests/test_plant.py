"""The planted sink/outlier circuit: does it implement the paper's causal
story? (DESIGN.md §3)"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs as C, model as M, plant as P
from compile.quantlib import QuantCtx


@pytest.fixture(scope="module")
def planted():
    out = {}
    for name in ("tl-llama", "tl-opt"):
        cfg = C.VARIANTS[name]
        out[name] = (cfg, P.plant_params(cfg, M.init_params(
            cfg, jax.random.PRNGKey(cfg.seed))))
    return out


def doc_tokens(cfg, b=2, s=64, first_trigger=20, seed=0):
    """Content tokens with a single trigger (<dot>) at a chosen position."""
    rng = np.random.default_rng(seed)
    t = rng.integers(C.N_SPECIAL, cfg.vocab, size=(b, s))
    t[:, first_trigger] = C.DOT
    return jnp.asarray(t, jnp.int32)


def run(cfg, params, tokens, prefix_kv=None, plen=0):
    qctx = QuantCtx(mode="fp")
    pkv = prefix_kv if prefix_kv is not None else M.empty_prefix(cfg)
    _, aux = M.fwd(cfg, params, tokens, pkv, jnp.asarray(plen, jnp.int32),
                   qctx, collect_acts=True, collect_probs=True)
    return aux


def test_first_trigger_goes_massive(planted):
    cfg, params = planted["tl-llama"]
    aux = run(cfg, params, doc_tokens(cfg))
    acts = np.array(aux["acts"])  # [L+1, B, S, d]
    # the trigger position dominates at layers >= 1
    mag = np.abs(acts[2])  # input to block 2
    pos_max = mag.max(axis=-1).argmax(axis=-1)
    assert (pos_max == 20).all(), pos_max
    assert mag.max() > 200.0
    # and the massive values live exactly in the reserved channels
    c = list(cfg.reserved.out)
    grid = np.abs(acts[2][:, 20, :])
    assert set(np.argsort(grid[0])[-2:]) == set(c)


def test_later_triggers_suppressed(planted):
    cfg, params = planted["tl-llama"]
    t = doc_tokens(cfg)
    t = t.at[:, 40].set(C.DOT)  # a second trigger
    aux = run(cfg, params, t)
    acts = np.array(aux["acts"])
    mag = np.abs(acts[2]).max(axis=-1)  # [B, S]
    assert mag[:, 20].min() > 200.0, "first trigger must be the sink"
    assert mag[:, 40].max() < 50.0, "second trigger must be suppressed"


def test_cushion_prefix_suppresses_everything(planted):
    cfg, params = planted["tl-llama"]
    prefix = jnp.asarray([C.BOS] + [C.PAD] * (C.M_MAX - 1), jnp.int32)
    kv = M.compute_prefix_kv(cfg, params, prefix, jnp.asarray(1, jnp.int32))
    aux = run(cfg, params, doc_tokens(cfg), prefix_kv=kv, plen=1)
    acts = np.array(aux["acts"])
    assert np.abs(acts).max() < 50.0, (
        "with a trigger-bearing cushion no real token may go massive")


def test_sink_heads_attend_to_massive_position(planted):
    """Figure 3's mechanism: head 0 of layers >= 1 parks on the sink."""
    cfg, params = planted["tl-llama"]
    aux = run(cfg, params, doc_tokens(cfg))
    probs = np.array(aux["probs"])  # [L, Hq, S, M+S]
    sink_col = C.M_MAX + 20
    late_queries = probs[2, 0, 40:, :]  # layer 2, head 0
    mass_on_sink = late_queries[:, sink_col].mean()
    assert mass_on_sink > 0.5, mass_on_sink


def test_attention_redirects_to_cushion(planted):
    """With a cushion, the sink mass moves onto the prefix slots."""
    cfg, params = planted["tl-llama"]
    prefix = jnp.asarray([C.BOS] + [C.PAD] * (C.M_MAX - 1), jnp.int32)
    kv = M.compute_prefix_kv(cfg, params, prefix, jnp.asarray(1, jnp.int32))
    aux = run(cfg, params, doc_tokens(cfg), prefix_kv=kv, plen=1)
    probs = np.array(aux["probs"])
    mass_on_prefix = probs[2, 0, 40:, :C.M_MAX].sum(-1).mean()
    assert mass_on_prefix > 0.5, mass_on_prefix


def test_post_ln_variant_outliers_are_mild(planted):
    """tl-opt (post-LN): the injected values are normalized away — the
    paper's OPT/BLOOM rows degrade mildly under per-tensor quant."""
    cfg, params = planted["tl-opt"]
    aux = run(cfg, params, doc_tokens(cfg))
    acts = np.array(aux["acts"])
    assert np.abs(acts).max() < 60.0


def test_freeze_masks_cover_plant():
    """Every planted entry must be frozen (mask 0)."""
    cfg = C.VARIANTS["tl-llama"]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    planted = P.plant_params(cfg, params)
    masks = P.freeze_masks(cfg)
    # wherever plant != raw-init, mask must be 0
    for name in params:
        raw = np.array(params[name])
        pl = np.array(planted[name])
        mask = np.array(masks[name])
        changed = ~np.isclose(raw, pl)
        assert (mask[changed] == 0).all(), f"unfrozen plant entries in {name}"


def test_plant_idempotent():
    cfg = C.VARIANTS["tl-mistral"]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    once = P.plant_params(cfg, params)
    twice = P.plant_params(cfg, once)
    for name in once:
        np.testing.assert_array_equal(np.array(once[name]),
                                      np.array(twice[name]))


def test_heavy_tail_of_sink_magnitude(planted):
    """Sink magnitude varies with context (heavy-tailed in the residual
    rms) — the source of static-vs-dynamic calibration mismatch."""
    cfg, params = planted["tl-llama"]
    mags = []
    for seed in range(6):
        aux = run(cfg, params, doc_tokens(cfg, b=1, seed=seed))
        mags.append(float(np.abs(np.array(aux["acts"])[2]).max()))
    assert max(mags) / min(mags) > 1.01
    assert min(mags) > 100.0
