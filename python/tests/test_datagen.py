"""synwiki grammar + task suite: determinism, structure, gold validity."""

import numpy as np
import pytest

from compile import configs as C, datagen as D
from compile.prng import SplitMix64, hash64
from compile.tokenizer import Tokenizer


def test_prng_known_answers():
    # cross-language anchors (mirrored in rust/src/util/prng.rs tests)
    assert SplitMix64(0).next_u64() == 0xE220A8397B1DCDAF
    assert hash64(0) == 0xE220A8397B1DCDAF
    r = SplitMix64(42)
    assert [r.next_below(512) for _ in range(3)] == [379, 81, 142]


def test_document_deterministic():
    g = D.Grammar(512)
    a = g.document(128, SplitMix64(5))
    b = g.document(128, SplitMix64(5))
    assert a == b
    assert a[0] == C.BOS and len(a) == 128


def test_document_structure():
    g = D.Grammar(512)
    d = g.document(256, SplitMix64(1))
    assert C.DOT in d and C.NL in d
    assert all(0 <= t < 512 for t in d)
    # delimiters are common but not dominant
    frac = sum(1 for t in d if t in C.TRIGGER_TOKENS) / len(d)
    assert 0.05 < frac < 0.4


def test_sentence_agreement_token():
    g = D.Grammar(512)
    rng = SplitMix64(2)
    for _ in range(20):
        s = g.sentence(3, rng)
        s0 = (s[0] - C.N_SPECIAL) % g.tpt
        assert s[-1] == C.DOT
        assert (s[-2] - C.N_SPECIAL) % g.tpt == g.agree(s0)


def test_markov_successors_within_topic():
    g = D.Grammar(512)
    tok = Tokenizer(512)
    rng = SplitMix64(3)
    d = g.document(256, rng)
    content = [t for t in d if t >= C.N_SPECIAL]
    # all content tokens of a sentence share its topic
    topics = set()
    cur = []
    for t in d:
        if t == C.DOT:
            if cur:
                topics.add(len({tok.topic_of(x) for x in cur}))
            cur = []
        elif t >= C.N_SPECIAL:
            cur.append(t)
    assert topics == {1}
    assert content


def test_corpus_splits_reproducible_and_disjoint():
    a = D.corpus_split(512, 4, 64, stream=1)
    a2 = D.corpus_split(512, 4, 64, stream=1)
    b = D.corpus_split(512, 4, 64, stream=2)
    assert a == a2
    assert a != b


@pytest.mark.parametrize("vocab", [512, 1024])
def test_tasks_well_formed(vocab):
    tasks = D.build_all_tasks(vocab, n_items=20, mmlu_per_subject=2)
    names = {t.name for t in tasks}
    assert set(D.ZERO_SHOT) <= names
    assert "mmlu-syn" in names and "gsm-syn" in names
    for t in tasks:
        assert t.items, t.name
        for it in t.items:
            assert it.gold < max(len(it.candidates), 1)
            assert all(0 <= x < vocab for x in it.context)
            for cand in it.candidates:
                assert all(0 <= x < vocab for x in cand)
            if it.kind == D.KIND_MC:
                assert len(it.candidates) in (2, 4)
                lens = {len(c) for c in it.candidates}
                assert len(lens) == 1, f"{t.name}: candidate length skew"


def test_task_gold_is_grammar_consistent():
    """winogrande-syn's gold candidate is the true agreement token."""
    g = D.Grammar(512)
    tasks = D.build_all_tasks(512, n_items=30, mmlu_per_subject=1)
    wino = next(t for t in tasks if t.name == "winogrande-syn")
    tok = Tokenizer(512)
    for it in wino.items[:10]:
        s0_tok = it.context[1]  # context = [BOS] + sentence prefix
        topic = tok.topic_of(s0_tok)
        want = g.gid(topic, g.agree(tok.index_of(s0_tok)))
        assert it.candidates[it.gold][0] == want


def test_gold_positions_shuffled():
    tasks = D.build_all_tasks(512, n_items=40, mmlu_per_subject=1)
    hs = next(t for t in tasks if t.name == "hellaswag-syn")
    golds = {it.gold for it in hs.items}
    assert len(golds) > 1, "gold index must not be constant"


def test_tokenizer_grammar_roundtrip():
    g = D.Grammar(512)
    tok = Tokenizer(512)
    d = g.document(64, SplitMix64(9))
    text = tok.detokenize(d)
    assert "t0" in text or "t1" in text
    # every rendered word maps back to a valid id
    for w in text.replace(".", " ").split():
        tok.str_to_id(w)
