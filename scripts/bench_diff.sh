#!/usr/bin/env bash
# Pre-merge perf gate: diff two BENCH_perf_hotpath.json snapshots and
# fail on a >10% regression in the decode-step mean or on ANY growth in
# a transfers_per_iter gauge (the transfer budget is a hard invariant of
# the device-resident serving design — see README "Serving hot path").
#
# Usage:
#   scripts/bench_diff.sh <base.json> [<new.json>] [--tol 0.10]
#
# <new.json> defaults to the BENCH_perf_hotpath.json at the repo root
# (i.e. "did my branch regress the committed baseline?" is:
#   git show main:BENCH_perf_hotpath.json > /tmp/base.json
#   cargo bench --bench perf_hotpath            # rewrites the snapshot
#   scripts/bench_diff.sh /tmp/base.json).
#
# The comparison itself is `cushiond bench-diff` (rust/src/bench/diff.rs);
# this wrapper just finds/builds the binary and forwards arguments.

set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
base="${1:?usage: bench_diff.sh <base.json> [<new.json>] [--tol F]}"
shift
new="${repo_root}/BENCH_perf_hotpath.json"
if [[ $# -gt 0 && "$1" != --* ]]; then
    new="$1"
    shift
fi

cushiond=""
for cand in \
    "${repo_root}/target/release/cushiond" \
    "${repo_root}/target/debug/cushiond"; do
    if [[ -x "$cand" ]]; then
        cushiond="$cand"
        break
    fi
done

if [[ -n "$cushiond" ]]; then
    exec "$cushiond" bench-diff "$base" "$new" "$@"
elif command -v cargo >/dev/null 2>&1; then
    exec cargo run --quiet --release --bin cushiond -- \
        bench-diff "$base" "$new" "$@"
else
    echo "bench_diff.sh: no cushiond binary and no cargo toolchain" >&2
    exit 2
fi
