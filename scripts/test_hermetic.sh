#!/usr/bin/env bash
# Hermetic pre-merge gate: build + test the crate with NO XLA toolchain,
# no PjRt crate, and no compiled artifacts — everything runs on the
# pure-Rust reference interpreter backend (README "Backends").
#
#   scripts/test_hermetic.sh            # from the repo root
#
# What runs:
#   1. cargo fmt --check (advisory: reports divergence, does not gate —
#      run `cargo fmt` before merging; the hermetic gate is the tests)
#   2. cargo test --no-default-features --features ref
#      - unit tests (incl. testkit::prop quantization + block-allocator
#        properties)
#      - rust/tests/interp_parity.rs  (interpreter vs committed JAX
#        goldens, 1e-4 across all four quant modes)
#      - rust/tests/hermetic_serve.rs (scheduler/streaming/search with
#        no artifact directory)
#      - rust/tests/paged_kv.rs       (paged KV pool: shared cushion
#        blocks, prefix caching, preemption/resume, residency + native
#        block-table parity)
#      - rust/tests/sharded_parity.rs (tensor-parallel group vs the
#        single engine: fp bit-identical at shards 1/2/4, quantized
#        within interp tolerance, shard-kill recovery)
#   3. an explicit focused re-run of the kvpool/preemption suites, so a
#      filter-induced skip in step 2 can never silently pass the gate
#   4. an explicit focused run of the replica fault-domain suite
#      (whole-replica kills: failover migration must be bit-identical,
#      all-replicas-dead must shed honestly), so a filter-induced skip
#      in step 2 can never silently pass it
#   5. the chaos suite under three fault seeds (PROP_SEED shifts the
#      property harness; the fault schedules inside each case are still
#      derived from the per-case seed) — end-to-end recovery, including
#      the replica-kill chaos tests and the id-conservation property,
#      must hold bit-identically across seeds, not just the default one
#   6. the chunked-prefill gate under the same three PROP_SEEDs:
#      chunked-vs-unchunked bit-identity, fixed-seed trace-replay
#      determinism, and the SLO percentile/goodput-monotonicity
#      properties (testkit::prop::slo_props)
#   7. the traced-serve gate: the fixed-seed chaos trace-export test
#      runs with CUSHION_TRACE_EXPORT pointed into the scratch dir, and
#      the exported Chrome trace must pass `cushiond trace-check`
#      (valid JSON, traceEvents present, strictly increasing args.seq,
#      no unclosed spans)
#
# CUSHION_ARTIFACTS points at an empty scratch dir so a developer's
# local `artifacts/` cannot leak into the hermetic run.

set -u
cd "$(dirname "$0")/.."

if command -v cargo >/dev/null 2>&1 && cargo fmt --version >/dev/null 2>&1; then
    echo "[hermetic] cargo fmt --check"
    if ! cargo fmt --check; then
        echo "[hermetic] warning: formatting divergence (run 'cargo fmt'); not gating"
    fi
fi

scratch="$(mktemp -d)"
trap 'rm -rf "$scratch"' EXIT
export CUSHION_ARTIFACTS="$scratch/artifacts"
export CUSHION_BACKEND=ref

echo "[hermetic] cargo test --no-default-features --features ref"
cargo test -q --no-default-features --features ref
status=$?

if [ $status -eq 0 ]; then
    echo "[hermetic] cargo test --no-default-features --features ref --test paged_kv"
    cargo test -q --no-default-features --features ref --test paged_kv
    status=$?
fi
if [ $status -eq 0 ]; then
    echo "[hermetic] kvpool allocator + scheduler preemption properties"
    cargo test -q --no-default-features --features ref \
        --test coordinator_props paged_kv_never_oversubscribes
    status=$?
fi
if [ $status -eq 0 ]; then
    # tensor-parallel gate: every test in this suite compares shard
    # counts {1, 2, 4} internally (fp bit-identity, quantized
    # tolerance, collective metering, shard-kill recovery), so a
    # filter-induced skip in step 2 can never silently pass it
    echo "[hermetic] sharded execution parity at shards 1/2/4"
    cargo test -q --no-default-features --features ref --test sharded_parity
    status=$?
fi

if [ $status -eq 0 ]; then
    # replica fault-domain gate: every whole-replica kill scenario
    # (mid-prefill, mid-decode, while preempted, all replicas dead)
    # runs here by name so it cannot be skipped by a filter above
    echo "[hermetic] replica fault domains: kill / failover / shed chaos"
    cargo test -q --no-default-features --features ref \
        --test hermetic_serve chaos_replica
    status=$?
fi

if [ $status -eq 0 ]; then
    echo "[hermetic] chaos suite across 3 fault seeds"
    for seed in 1 2 3; do
        echo "[hermetic]   PROP_SEED=$seed chaos + fault-recovery tests"
        PROP_SEED=$seed cargo test -q --no-default-features --features ref chaos
        status=$?
        [ $status -ne 0 ] && break
    done
fi

if [ $status -eq 0 ]; then
    # chunked-prefill gate: bit-identity vs single-shot prefill, the
    # fixed-seed trace-replay determinism check, and the SLO metric
    # properties, swept under the same three property seeds
    echo "[hermetic] chunked prefill + SLO scheduling across 3 seeds"
    for seed in 1 2 3; do
        echo "[hermetic]   PROP_SEED=$seed chunked prefill / trace replay / slo props"
        PROP_SEED=$seed cargo test -q --no-default-features --features ref \
            --test hermetic_serve chunked_prefill_serves_bit_identically
        status=$?
        [ $status -ne 0 ] && break
        PROP_SEED=$seed cargo test -q --no-default-features --features ref \
            --test hermetic_serve fixed_seed_trace_replay
        status=$?
        [ $status -ne 0 ] && break
        PROP_SEED=$seed cargo test -q --no-default-features --features ref \
            --lib slo_props
        status=$?
        [ $status -ne 0 ] && break
    done
fi

if [ $status -eq 0 ]; then
    # traced-serve gate: re-run the chaos trace-export test with the
    # export path armed, then validate the written Chrome trace with
    # the cushiond trace-check subcommand
    echo "[hermetic] traced serve -> trace-check"
    CUSHION_TRACE_EXPORT="$scratch/trace.json" \
        cargo test -q --no-default-features --features ref \
        --test hermetic_serve chaos_trace_export_records_the_request_lifecycle
    status=$?
    if [ $status -eq 0 ]; then
        cargo run -q --no-default-features --features ref --bin cushiond -- \
            trace-check "$scratch/trace.json"
        status=$?
    fi
fi

if [ $status -eq 0 ]; then
    echo "[hermetic] OK — full suite (incl. paged KV pool, preemption, chunked prefill, fault-injection chaos, and the traced-serve observability gate) passed with no artifacts and no XLA"
fi
exit $status
