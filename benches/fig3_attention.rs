//! Figure 3: attention patterns before/after CushionCache on tl-llama3
//! and tl-mistral. We emit (a) the fraction of attention mass landing on
//! the cushion slots per layer, and (b) the full head-0 attention map of
//! a middle layer as CSV, plus a coarse ASCII rendering.

use cushioncache::bench::scenario;
use cushioncache::bench::Table;
use cushioncache::eval::actstats;
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let mut mass_table = Table::new(
        "Figure 3a — attention mass on the prefix region, per layer",
        &["variant", "config", "layer", "prefix_mass"],
    );
    let mut map_table = Table::new(
        "Figure 3b — layer-2 head-0 attention map (query, key, prob)",
        &["variant", "config", "q", "k", "p"],
    );

    for variant in ["tl-llama3", "tl-mistral"] {
        for (with_cushion, config) in [(false, "baseline"), (true, "cushioncache")] {
            let s = scenario::prepared(&client, variant, false, with_cushion)?;
            let m_max = s.manifest.m_max;
            let rep = actstats::collect(&s, 1)?;
            for l in 0..s.manifest.n_layers {
                mass_table.row(vec![
                    variant.into(), config.into(), format!("{l}"),
                    format!("{:.4}", rep.prefix_attention_mass(l, m_max)),
                ]);
            }
            // layer 2 head 0 map, subsampled 4x to keep the CSV light
            let shape = rep.probs.shape.clone(); // [L, H, Sq, Skv]
            let (h, sq, skv) = (shape[1], shape[2], shape[3]);
            for q in (0..sq).step_by(4) {
                for k in (0..skv).step_by(4) {
                    let p = rep.probs.data[((2 * h) * sq + q) * skv + k];
                    if p > 1e-4 {
                        map_table.row(vec![
                            variant.into(), config.into(), format!("{q}"),
                            format!("{k}"), format!("{p:.4}"),
                        ]);
                    }
                }
            }
            // ASCII: where does each late query's mass concentrate?
            let q = sq - 2;
            let row: Vec<f32> = (0..skv)
                .map(|k| rep.probs.data[((2 * h) * sq + q) * skv + k])
                .collect();
            let peak = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            println!(
                "{variant:12} {config:12} query {q}: peak attention at key {peak} \
                 ({}), prefix mass {:.2}",
                if peak < m_max { "cushion region" } else { "token region" },
                rep.prefix_attention_mass(2, m_max)
            );
        }
    }
    mass_table.emit("fig3a_prefix_mass");
    map_table.emit("fig3b_attention_map");
    Ok(())
}
