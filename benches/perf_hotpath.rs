//! §Perf micro-bench: where does a serving step's time go?
//!
//! Breaks the hot paths into components — eval forward, decode step (in
//! all three serving modes: device-sampled/resident default, host-argmax
//! fallback, and the seed's host-roundtrip), bucketed vs full prefill,
//! and the isolated cache-sized upload/download — and, per component,
//! reports the host<->device transfer traffic per iteration
//! (runtime::transfer counters). Asserted invariants: loop-invariant
//! operands (weights, ranges, inv_smooth, cushion prefix KV) upload
//! exactly once per (re)configuration, the default decode step moves
//! <= 64 KB/step combined across the host boundary (ISSUE 3 budget;
//! steady state is ~100 B — tokens+lens up, [B] token ids down), and an
//! oversubscribed paged-KV pool (pool churn scenario: many short
//! requests over a third-size block pool) completes everything via
//! preemption/resume with zero rejections, and a fault-injection
//! scenario (10% transient execute faults over a wrapped backend) keeps
//! all tenants alive through the retry path while recording recovered
//! throughput. A replica-failover scenario drives 24 requests through a
//! 4-replica fp router and chaos-kills one replica mid-decode: the
//! router must quarantine it, migrate its in-flight work onto the
//! healthy siblings (paged prompt++generated re-prefill), and finish
//! every request with zero sheds — the row times the whole storm and
//! the `failover` extras record migration counters plus recovered
//! throughput. A tensor-parallel scenario decodes on a 2-shard
//! reference group, asserting the host budget is shard-invariant and
//! recording all-gather/all-reduce traffic per step
//! (`collective_per_iter`, hard-gated by bench-diff). An observability
//! scenario runs the same tiny serve batch untraced and with the trace
//! ring enabled (default act-sampling rate in both arms) and records
//! `tracing_overhead_frac` in the `observability` extras section —
//! bench-diff holds it to an absolute <= 5% ceiling. Emits
//! `BENCH_perf_hotpath.json` at the repo root so the perf trajectory is
//! tracked across PRs — gate regressions with `cushiond bench-diff` /
//! scripts/bench_diff.sh.

use std::rc::Rc;

use cushioncache::bench::scenario::{generate_trace, replay_trace, TraceCfg};
use cushioncache::bench::{emit_bench_json, summarize, time_n, Table, Timing};
use cushioncache::coordinator::metrics::SloMetrics;
use cushioncache::coordinator::{Engine, Request, Router, Scheduler};
use cushioncache::runtime::backend::RefBackend;
use cushioncache::model::resident;
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::literalx::HostValue;
use cushioncache::runtime::collective;
use cushioncache::runtime::transfer::{self, TransferStats};
use cushioncache::runtime::{faults, Client, FaultPlan, FaultyBackend};
use cushioncache::util::tensor::Tensor;

/// Time `iters` runs of `f` after `warmup`, with the transfer-counter
/// delta over the timed region.
fn time_with_xfer<F: FnMut()>(
    warmup: usize,
    iters: usize,
    mut f: F,
) -> (Vec<f64>, TransferStats) {
    for _ in 0..warmup {
        f();
    }
    let base = transfer::snapshot();
    let samples = time_n(0, iters, &mut f);
    (samples, transfer::snapshot().delta_since(&base))
}

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    // (cargo bench appends a literal `--bench`; skip flag-like args)
    let variant = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "tl-llama3".into());
    let iters = 20;
    let mut table = Table::new(
        &format!("Perf — hot-path breakdown ({variant})"),
        &["component", "mean (ms)", "p50 (ms)", "p99 (ms)"],
    );
    let mut xfer_table = Table::new(
        &format!("Perf — transfers per iteration ({variant})"),
        &["component", "uploads", "KB up", "fetches", "KB down"],
    );
    let mut components: Vec<(String, Timing)> = Vec::new();
    let mut xfer_rows: Vec<(String, TransferStats, usize)> = Vec::new();
    let mut record = |name: &str,
                      samples: &[f64],
                      xfer: Option<(TransferStats, usize)>,
                      table: &mut Table,
                      xfer_table: &mut Table,
                      components: &mut Vec<(String, Timing)>,
                      xfer_rows: &mut Vec<(String, TransferStats, usize)>| {
        let t = summarize(samples);
        table.row(vec![
            name.into(),
            format!("{:.2}", t.mean * 1e3),
            format!("{:.2}", t.p50 * 1e3),
            format!("{:.2}", t.p99 * 1e3),
        ]);
        components.push((name.to_string(), t));
        if let Some((d, n)) = xfer {
            let per = |v: u64| v as f64 / n.max(1) as f64;
            xfer_table.row(vec![
                name.into(),
                format!("{:.1}", per(d.uploads)),
                format!("{:.1}", per(d.bytes_uploaded) / 1024.0),
                format!("{:.1}", per(d.fetches)),
                format!("{:.1}", per(d.bytes_fetched) / 1024.0),
            ]);
            xfer_rows.push((name.to_string(), d, n));
        }
    };
    macro_rules! row {
        ($name:expr, $samples:expr) => {
            record($name, $samples, None, &mut table, &mut xfer_table,
                   &mut components, &mut xfer_rows)
        };
        ($name:expr, $samples:expr, $xfer:expr, $n:expr) => {
            record($name, $samples, Some(($xfer, $n)), &mut table,
                   &mut xfer_table, &mut components, &mut xfer_rows)
        };
    }

    // ---- eval forward -----------------------------------------------------
    let mut s = Session::load_with_client(&variant, client.clone())?;
    let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 2)?;
    let tokens: Vec<i32> = {
        let split = s.corpus.split("heldout")?;
        (0..s.manifest.eval_batch).flat_map(|i| split.seq(i).to_vec()).collect()
    };
    let (pts, pts_x) =
        time_with_xfer(1, iters, || { s.fwd(&scheme, &tokens).unwrap(); });
    row!("fwd_pts (B=8, S=128)", &pts, pts_x, iters);
    let (fp, fp_x) =
        time_with_xfer(1, iters, || { s.fwd(&Scheme::fp(), &tokens).unwrap(); });
    row!("fwd_fp  (B=8, S=128)", &fp, fp_x, iters);

    // pallas-kernel artifact variant, if present (tl-llama3)
    if s.manifest.graphs.iter().any(|g| g == "fwd_pts_pallas") {
        let run_pallas = || {
            let (pkv, plen) = s.prefix_args();
            s.run(
                "fwd_pts_pallas",
                &[
                    HostValue::F32(pkv),
                    HostValue::scalar_i32(plen),
                    HostValue::I32(cushioncache::runtime::IntTensor::new(
                        vec![s.manifest.eval_batch, s.manifest.seq_len],
                        tokens.clone(),
                    )),
                    HostValue::F32(s.ranges().clone()),
                    HostValue::scalar_f32(scheme.act_levels()),
                    HostValue::F32(s.inv_smooth().clone()),
                ],
            )
            .unwrap();
        };
        let (pl, _) = time_with_xfer(1, 5, run_pallas);
        row!("fwd_pts_pallas (interpret)", &pl);
    }

    // ---- serving decode breakdown ----------------------------------------
    let mut s2 = Session::load_with_client(&variant, client.clone())?;
    calibrate::calibrate_into(&mut s2, scheme.act_levels(), 2)?;
    let prompt: Vec<i32> = s2.corpus.split("heldout")?.seq(0)[..96].to_vec();
    let engine = Engine::new(s2, scheme)?;
    let device_sampled = engine.sampled_decode_available();
    let mut sched = Scheduler::new(engine);
    sched.submit(prompt.clone(), 8);
    sched.run_to_completion()?; // warm
    // fill all 8 slots and measure a full decode step. A 32-token prompt
    // leaves ~96 decode steps of KV headroom per slot — enough for all
    // three measured decode modes without any tenant finishing mid-bench.
    for _ in 0..8 {
        sched.submit(prompt[..32].to_vec(), 10_000_000); // never self-stop
    }
    for _ in 0..9 {
        sched.step()?; // admit all prefills + first decodes
    }
    // default mode: device-resident cache + device-side token selection
    let (dec, dec_x) =
        time_with_xfer(0, iters, || { sched.step().unwrap(); });
    row!("decode step (batch 8)", &dec, dec_x, iters);
    // the ISSUE-3 transfer budget: <= 64 KB/step combined in the default
    // mode (steady state is ~100 B: tokens+lens up, B ids down)
    if device_sampled {
        let per_step =
            (dec_x.bytes_uploaded + dec_x.bytes_fetched) / iters as u64;
        assert!(
            per_step <= 64 * 1024,
            "decode step moved {per_step} B/step (budget 64 KB)"
        );
        println!("[perf] decode-step transfer budget: {per_step} B/step (<= 64 KB)");
    } else {
        println!(
            "[perf] note: artifacts predate *_sampled_* graphs — decode \
             ran in host-argmax fallback mode (no budget assertion)"
        );
    }
    // comparison modes: host argmax over fetched logits, then the seed's
    // full per-step cache round-trip
    sched.engine.set_device_sampling(false);
    let (dec_host, dec_host_x) =
        time_with_xfer(1, iters, || { sched.step().unwrap(); });
    row!("decode step host-argmax (batch 8)", &dec_host, dec_host_x, iters);
    sched.engine.set_host_roundtrip(true);
    let (dec_rt, dec_rt_x) =
        time_with_xfer(1, iters, || { sched.step().unwrap(); });
    row!("decode step host-roundtrip (batch 8)", &dec_rt, dec_rt_x, iters);
    sched.engine.set_host_roundtrip(false);
    sched.engine.set_device_sampling(true);

    // residency: the loop invariants must have crossed to the device
    // exactly once for this engine's whole serving history.
    let pool = sched.engine.session.pool();
    let mut resident_counts = Vec::new();
    for key in [
        resident::KEY_WEIGHTS,
        resident::KEY_RANGES,
        resident::KEY_INV_SMOOTH,
        resident::KEY_PREFIX_KV,
    ] {
        let n = pool.upload_count(key);
        resident_counts.push((key, n));
        assert_eq!(
            n, 1,
            "loop-invariant operand '{key}' uploaded {n}x (expected once)"
        );
    }
    println!(
        "[perf] invariant uploads since engine setup: {}",
        resident_counts
            .iter()
            .map(|(k, n)| format!("{k}={n}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // ---- fault path: hostile requests under full load -------------------
    // all 8 slots are busy with never-stopping requests; the bad ones
    // must still drain as per-request errors on the next step, and the
    // engine (and its 8 tenants) must stay alive.
    let seq_len = sched.engine.session.manifest.seq_len;
    let vocab = sched.engine.session.manifest.vocab as i32;
    sched.submit(vec![5; seq_len + 1], 4); // prompt too long
    sched.submit(vec![0, vocab + 9], 4); // out-of-vocab token
    let (fault, _) = time_with_xfer(0, 1, || {
        sched.step().unwrap();
    });
    row!("step w/ 2 rejections (batch 8)", &fault);
    let faults = sched.take_finished();
    let errored_now = faults.iter().filter(|r| r.finished.is_error()).count();
    assert_eq!(errored_now, 2, "expected 2 per-request errors, engine alive");
    assert_eq!(sched.running_count(), 8, "tenants lost to a bad request");
    println!(
        "[perf] fault path: {} per-request errors, {} running unharmed",
        errored_now,
        sched.running_count()
    );

    // ---- fault injection: recovered throughput under transient faults ----
    // a fresh engine over a fault-wrapped backend (the main `sched` above
    // is near its KV headroom); 10% of execute calls fail transiently and
    // the scheduler's retry/backoff path must absorb them — all 8 tenants
    // alive afterwards, throughput measured across the injected faults.
    let mut s_fault = Session::load_with_client(
        &variant,
        Client::with_backend(Rc::new(FaultyBackend::wrap(client.backend_shared()))),
    )?;
    calibrate::calibrate_into(&mut s_fault, scheme.act_levels(), 1)?;
    let mut fault_sched = Scheduler::new(Engine::new(s_fault, scheme)?);
    for _ in 0..8 {
        fault_sched.submit(prompt[..32].to_vec(), 10_000_000);
    }
    for _ in 0..9 {
        fault_sched.step()?; // admit + settle before arming the plan
    }
    faults::arm(FaultPlan::parse("seed=11,execute=0.1")?);
    let mut produced = 0usize;
    let (dec_faulty, dec_faulty_x) = time_with_xfer(0, iters, || {
        produced += fault_sched.step().unwrap();
    });
    let injected = faults::disarm().map(|st| st.total()).unwrap_or(0);
    let retries = fault_sched.metrics.retries_total();
    row!("decode step w/ faults (batch 8, 10% execute)", &dec_faulty, dec_faulty_x, iters);
    for _ in 0..4 {
        fault_sched.step()?; // clean steps re-admit anything preempted
    }
    assert_eq!(fault_sched.running_count(), 8, "tenants lost to injected faults");
    let elapsed: f64 = dec_faulty.iter().sum();
    let recovered_tps = produced as f64 / elapsed.max(1e-9);
    println!(
        "[perf] fault injection: {injected} injected, {retries} retries, \
         {} preemption(s); recovered throughput {recovered_tps:.1} tok/s",
        fault_sched.metrics.preempted
    );

    // ---- pool churn: oversubscribed paged KV pool ------------------------
    // many short requests against a pool sized at a third of the default:
    // the
    // scheduler must admit by block availability and preempt/resume
    // instead of rejecting; completion is asserted, end-to-end latency
    // plus preemption/sharing gauges recorded.
    let mut s_churn = Session::load_with_client(&variant, client.clone())?;
    calibrate::calibrate_into(&mut s_churn, scheme.act_levels(), 1)?;
    let mut churn_engine = Engine::new(s_churn, scheme)?;
    churn_engine.set_pool_blocks(churn_engine.kv.total_blocks() / 3);
    let churn_blocks = churn_engine.kv.total_blocks();
    let mut churn_sched = Scheduler::new(churn_engine);
    let churn_reqs = 24usize;
    let (churn_t, churn_x) = time_with_xfer(0, 1, || {
        for _ in 0..churn_reqs {
            churn_sched.submit(prompt[..16].to_vec(), 48);
        }
        churn_sched.run_to_completion().unwrap();
    });
    row!("pool churn (24 reqs, third pool)", &churn_t, churn_x, 1);
    let churn_sum = churn_sched.metrics.summary();
    assert_eq!(
        churn_sum.completed, churn_reqs,
        "oversubscribed pool must complete everything via preemption"
    );
    assert_eq!(churn_sum.errored, 0, "paged admission must queue, not reject");
    println!(
        "[perf] pool churn: {churn_reqs} reqs over {churn_blocks} blocks, \
         {} preemptions, peak pool util {:.0}%, sharing saved {} allocations",
        churn_sum.preempted,
        churn_sum.pool_peak_utilization() * 100.0,
        churn_sum.pool_blocks_saved_peak,
    );

    // ---- replica failover: whole-replica chaos kill under load -----------
    // 4 same-weights fp replicas over the hermetic tiny model behind one
    // router; a seeded chaos plan kills replica 1 mid-run (after its
    // 17th engine call — mid-decode of its second admission wave) and
    // the router must quarantine it and reconstruct its queued + running
    // work on the survivors via the paged `prompt ++ generated` resume
    // path. Everything must complete (nothing shed: three replicas stay
    // healthy); the row times the whole storm and the extras record the
    // failover/migration/re-prefill counters plus recovered throughput.
    let fo_fleet = 4usize;
    let mut fo_router = Router::with_seed(0xBEEF);
    for _ in 0..fo_fleet {
        let s_r = cushioncache::testkit::tiny::TinyCfg::default()
            .session_with_client(Client::with_backend(Rc::new(
                FaultyBackend::wrap(Rc::new(RefBackend)),
            )))?;
        fo_router.add_engine("fp", Scheduler::new(Engine::new(s_r, Scheme::fp())?));
    }
    let fo_reqs = 24usize;
    let fo_prompt: Vec<i32> = fo_router
        .replica(0)
        .engine
        .session
        .corpus
        .split("heldout")?
        .seq(1)[..6]
        .to_vec();
    for i in 0..fo_reqs {
        let mut req = Request::new(1 + i as u64, fo_prompt.clone(), 8);
        req.stop_token = None;
        fo_router.route("fp", req)?;
    }
    faults::arm(FaultPlan::parse("seed=50,replica=1,kill_replica_after=17")?);
    let mut fo_resp = Vec::new();
    let (fo_t, fo_x) = time_with_xfer(0, 1, || {
        while fo_router.has_work() {
            fo_resp.extend(fo_router.step_all().unwrap());
        }
    });
    faults::disarm();
    row!("replica failover (24 reqs, 4 replicas, 1 killed)", &fo_t, fo_x, 1);
    assert_eq!(fo_resp.len(), fo_reqs, "requests lost across the failover");
    assert!(
        fo_resp.iter().all(|r| !r.finished.is_error()),
        "healthy siblings must absorb a killed replica's work"
    );
    let fo_sum = |f: fn(&cushioncache::coordinator::metrics::Metrics) -> usize| {
        (0..fo_fleet).map(|i| f(&fo_router.replica(i).metrics)).sum::<usize>()
    };
    let (fo_failovers, fo_migrated, fo_reprefill, fo_shed) = (
        fo_sum(|m| m.failovers),
        fo_sum(|m| m.migrated_sequences),
        fo_sum(|m| m.reprefill_tokens),
        fo_sum(|m| m.shed_requests),
    );
    assert_eq!(fo_failovers, 1, "exactly one replica kill, one failover");
    assert!(fo_migrated >= 1, "the killed replica had in-flight work");
    assert_eq!(fo_shed, 0, "nothing may shed while siblings are healthy");
    let fo_tokens: usize = fo_resp.iter().map(|r| r.tokens.len()).sum();
    let fo_elapsed: f64 = fo_t.iter().sum();
    let fo_tps = fo_tokens as f64 / fo_elapsed.max(1e-9);
    println!(
        "[perf] replica failover: {fo_failovers} failover, {fo_migrated} \
         migrated item(s), {fo_reprefill} re-prefill tokens burned, \
         {fo_shed} shed; recovered throughput {fo_tps:.1} tok/s over \
         {fo_fleet} replicas (1 killed)"
    );

    // ---- tensor-parallel: sharded decode on the reference group ----------
    // a 2-shard lock-step group over the hermetic tiny model (the
    // interpreter is the sharded substrate on every toolchain, so this
    // row never depends on artifacts): times the group decode step and
    // meters its collective traffic. The host-transfer gauges must stay
    // inside the unsharded 64 KB/step budget — all-gather/all-reduce
    // bytes ride the separate collective meter, gated by bench-diff.
    let shard_iters = 8usize; // tiny cache_cap bounds the decode run
    let tiny = cushioncache::testkit::tiny::TinyCfg {
        n_heads: 4,
        n_kv_heads: 4,
        d_head: 8,
        n_shards: 2,
        ..Default::default()
    };
    let mut shard_engine = Engine::new(tiny.session()?, Scheme::fp())?;
    let tiny_prompt: Vec<i32> =
        shard_engine.session.corpus.split("heldout")?.seq(0)[..5].to_vec();
    let tiny_b = shard_engine.session.manifest.serve_batch;
    let tiny_slot = shard_engine
        .kv
        .alloc(1, tiny_prompt.len())
        .ok_or_else(|| anyhow::anyhow!("tiny KV pool rejected one sequence"))?;
    let mut tiny_last = shard_engine.prefill(tiny_slot, &tiny_prompt)?;
    // warm one step so the timed region is steady-state
    {
        let mut feed = vec![cushioncache::data::PAD; tiny_b];
        feed[tiny_slot] = tiny_last;
        tiny_last = shard_engine.decode_step(&feed)?[tiny_slot];
        shard_engine.kv.push_token(tiny_slot);
    }
    let coll_base = collective::snapshot();
    let (shard_dec, shard_dec_x) = time_with_xfer(0, shard_iters, || {
        let mut feed = vec![cushioncache::data::PAD; tiny_b];
        feed[tiny_slot] = tiny_last;
        tiny_last = shard_engine.decode_step(&feed).unwrap()[tiny_slot];
        shard_engine.kv.push_token(tiny_slot);
    });
    let dcoll = collective::snapshot().delta_since(&coll_base);
    row!(
        "sharded decode step (tiny, 2 shards)",
        &shard_dec,
        shard_dec_x,
        shard_iters
    );
    let shard_per_step = (shard_dec_x.bytes_uploaded + shard_dec_x.bytes_fetched)
        / shard_iters as u64;
    assert!(
        shard_per_step <= 64 * 1024,
        "sharded decode moved {shard_per_step} B/step over the host \
         boundary (budget 64 KB; collectives are metered separately)"
    );
    let per_shard_iter = |v: u64| v as f64 / shard_iters as f64;
    let collective_json = format!(
        "{{\"sharded decode step (tiny, 2 shards)\": {{\"all_gathers\": \
         {:.1}, \"kb_gathered\": {:.2}, \"all_reduces\": {:.1}, \
         \"kb_reduced\": {:.2}}}}}",
        per_shard_iter(dcoll.all_gathers),
        per_shard_iter(dcoll.bytes_gathered) / 1024.0,
        per_shard_iter(dcoll.all_reduces),
        per_shard_iter(dcoll.bytes_reduced) / 1024.0,
    );
    println!(
        "[perf] sharded decode: {:.1} all-gathers and {:.2} KB gathered \
         per step, {} B/step host traffic",
        per_shard_iter(dcoll.all_gathers),
        per_shard_iter(dcoll.bytes_gathered) / 1024.0,
        shard_per_step
    );

    // marshalling cost: cache-sized host<->device round trip
    let m = &sched.engine.session.manifest;
    let cache_elems =
        m.n_layers * 2 * m.serve_batch * m.n_kv_heads * m.cache_cap * m.d_head;
    let host = Tensor::zeros(&[cache_elems]);
    let up = time_n(1, iters, || {
        let _ = client.upload(&host).unwrap();
    });
    row!("cache upload (alone)", &up);
    let buf = client.upload(&host)?;
    let down = time_n(1, iters, || {
        let _ = cushioncache::runtime::literalx::fetch_f32(&buf).unwrap();
    });
    row!("cache download (alone)", &down);

    // prefill: full-length prompt, then a short prompt that lands in the
    // smallest bucket (the bucketed-prefill win: no seq_len-wide forward)
    let mut s3 = Session::load_with_client(&variant, client.clone())?;
    calibrate::calibrate_into(&mut s3, scheme.act_levels(), 1)?;
    let mut engine3 = Engine::new(s3, scheme)?;
    let (pre, pre_x) = time_with_xfer(1, iters, || {
        engine3.prefill(0, &prompt).unwrap();
    });
    row!("prefill (prompt 96)", &pre, pre_x, iters);
    let buckets = engine3.sampled_prefill_buckets().to_vec();
    if let Some(&b0) = buckets.first().filter(|&&b| b < prompt.len()) {
        let short = &prompt[..b0.saturating_sub(8).max(1)];
        let (pre_b, pre_b_x) = time_with_xfer(1, iters, || {
            engine3.prefill(1, short).unwrap();
        });
        row!(
            &format!("prefill (prompt {}, bucket {b0})", short.len()),
            &pre_b,
            pre_b_x,
            iters
        );
    } else {
        println!(
            "[perf] note: no prefill bucket below the prompt length — \
             bucketed prefill row skipped"
        );
    }

    // ---- chunked prefill: long prompt co-batched with live decodes -------
    // the prefill-stall scenario on the hermetic tiny model: two short
    // tenants are decoding when a seq_len-scale prompt arrives. With a
    // 4-token chunk budget the prefill spreads over ceil(15/4) = 4
    // steps, and every one of those steps must still advance both
    // decode tenants — the long prompt may no longer stall the batch.
    let chunk_budget = 4usize;
    let tiny_serve = cushioncache::testkit::tiny::TinyCfg {
        serve_batch: 3,
        ..Default::default()
    };
    let mut chunk_sched = Scheduler::new(Engine::new(tiny_serve.session()?, Scheme::fp())?);
    assert!(
        chunk_sched.engine.supports_chunked_prefill(),
        "default-mode engine must support chunked prefill"
    );
    chunk_sched.set_prefill_chunk(Some(chunk_budget));
    let mut chunk_rid = 1u64;
    let mut sub = |sched: &mut Scheduler, prompt: Vec<i32>, max_new: usize| {
        let mut r = Request::new(chunk_rid, prompt, max_new);
        chunk_rid += 1;
        r.stop_token = None; // deterministic lengths
        sched.submit_request(r);
    };
    sub(&mut chunk_sched, vec![1, 2, 3], 12);
    sub(&mut chunk_sched, vec![2, 3, 4], 12);
    chunk_sched.step()?; // both shorts prefilled + first tokens
    let long_prompt: Vec<i32> = (0..15).map(|i| (i % 60) as i32).collect();
    sub(&mut chunk_sched, long_prompt.clone(), 2);
    let chunk_steps = long_prompt.len().div_ceil(chunk_budget);
    let mut chunk_step_t = Vec::with_capacity(chunk_steps);
    for i in 0..chunk_steps {
        let t0 = std::time::Instant::now();
        let produced = chunk_sched.step()?;
        chunk_step_t.push(t0.elapsed().as_secs_f64());
        assert!(
            produced >= 2,
            "decode stalled during chunked prefill (step {i} produced {produced})"
        );
    }
    row!(
        &format!("step w/ prefill chunk (budget {chunk_budget}, batch 3)"),
        &chunk_step_t
    );
    let chunk_resp = chunk_sched.run_to_completion()?;
    assert_eq!(chunk_resp.len(), 3, "all three tenants finish");
    assert!(chunk_resp.iter().all(|r| !r.finished.is_error()));
    println!(
        "[perf] chunked prefill: 15-token prompt over {chunk_steps} steps \
         (budget {chunk_budget}), co-batched decodes never stalled"
    );

    // ---- SLO trace replay: Poisson/burst arrivals, Zipf prompts ----------
    // the bench::scenario workload against a chunking scheduler on the
    // tiny model; per-class TTFT/TPOT percentiles and goodput feed the
    // "slo" extras, hard-gated by bench-diff.
    let mut trace_sched = Scheduler::new(Engine::new(
        cushioncache::testkit::tiny::TinyCfg { serve_batch: 3, ..Default::default() }
            .session()?,
        Scheme::fp(),
    )?);
    trace_sched.set_prefill_chunk(Some(chunk_budget));
    let trace_cfg = TraceCfg {
        seed: 0x510,
        n_requests: 32,
        prompt_len: (3, 12),
        gen_short: 4,
        gen_long: 8,
        deadline_ms: Some(10_000), // generous: goodput gates scheduling, not CI speed
        ..Default::default()
    };
    let events = generate_trace(&trace_cfg);
    let mut slo = SloMetrics::new();
    let mut trace_resp = Vec::new();
    let (trace_t, trace_x) = time_with_xfer(0, 1, || {
        trace_resp = replay_trace(&mut trace_sched, &events, Some(&mut slo)).unwrap();
    });
    row!("trace replay (32 reqs, zipf, chunk 4)", &trace_t, trace_x, 1);
    assert_eq!(trace_resp.len(), trace_cfg.n_requests, "requests lost in replay");
    assert!(
        trace_resp.iter().all(|r| !r.finished.is_error()),
        "trace replay produced per-request errors"
    );
    assert!(
        (slo.goodput() - 1.0).abs() < 1e-9,
        "goodput under a generous deadline must be 1.0, got {}",
        slo.goodput()
    );
    assert!(slo.tpot_p99().is_finite() && slo.ttft_p99().is_finite());
    let slo_classes = slo.summary();
    println!(
        "[perf] SLO trace replay: ttft_p99 {:.2} ms, tpot_p99 {:.2} ms, \
         goodput {:.2} over {} classes",
        slo.ttft_p99() * 1e3,
        slo.tpot_p99() * 1e3,
        slo.goodput(),
        slo_classes.len()
    );

    // ---- observability: tracing overhead at the default sampling rate ----
    // the same hermetic tiny serve workload run untraced, then with the
    // trace ring enabled (act sampling stays at the scheduler default in
    // both runs, so the delta isolates the tracer): the overhead
    // fraction feeds the "observability" extras section, hard-gated
    // <= 5% by `cushiond bench-diff`.
    let obs_iters = 5usize;
    let mut obs_sched = Scheduler::new(Engine::new(
        cushioncache::testkit::tiny::TinyCfg::default().session()?,
        Scheme::fp(),
    )?);
    let obs_prompt: Vec<i32> =
        obs_sched.engine.session.corpus.split("heldout")?.seq(0)[..5].to_vec();
    let mut obs_run = |sched: &mut Scheduler| {
        for _ in 0..6 {
            sched.submit(obs_prompt.clone(), 6);
        }
        sched.run_to_completion().unwrap();
    };
    let obs_untraced = time_n(1, obs_iters, || obs_run(&mut obs_sched));
    cushioncache::runtime::trace::enable(0);
    let obs_traced = time_n(1, obs_iters, || obs_run(&mut obs_sched));
    let obs_records = cushioncache::runtime::trace::records().len();
    cushioncache::runtime::trace::disable();
    row!("serve batch untraced (6 reqs, tiny)", &obs_untraced);
    row!("serve batch traced (6 reqs, tiny, ring on)", &obs_traced);
    let obs_un = summarize(&obs_untraced);
    let obs_tr = summarize(&obs_traced);
    let tracing_overhead_frac =
        ((obs_tr.mean - obs_un.mean) / obs_un.mean.max(1e-9)).max(0.0);
    println!(
        "[perf] observability: tracing overhead {:.2}% ({obs_records} \
         records; untraced {:.2} ms, traced {:.2} ms per batch)",
        tracing_overhead_frac * 100.0,
        obs_un.mean * 1e3,
        obs_tr.mean * 1e3
    );

    table.emit("perf_hotpath");
    print!("{}", xfer_table.render());

    // machine-readable snapshot at the repo root (cross-PR perf trail)
    let mut extras = vec![(
        "variant".to_string(),
        format!("\"{}\"", cushioncache::bench::json_escape(&variant)),
    )];
    let mut xfer_json = String::from("{");
    for (i, (name, d, n)) in xfer_rows.iter().enumerate() {
        let per = |v: u64| v as f64 / (*n).max(1) as f64;
        xfer_json.push_str(&format!(
            "{}\"{}\": {{\"uploads\": {:.1}, \"kb_up\": {:.1}, \"fetches\": {:.1}, \"kb_down\": {:.1}}}",
            if i == 0 { "" } else { ", " },
            cushioncache::bench::json_escape(name),
            per(d.uploads),
            per(d.bytes_uploaded) / 1024.0,
            per(d.fetches),
            per(d.bytes_fetched) / 1024.0,
        ));
    }
    xfer_json.push('}');
    extras.push(("transfers_per_iter".to_string(), xfer_json));
    extras.push(("collective_per_iter".to_string(), collective_json));
    let counts_json = resident_counts
        .iter()
        .map(|(k, n)| format!("\"{k}\": {n}"))
        .collect::<Vec<_>>()
        .join(", ");
    extras.push((
        "resident_upload_counts".to_string(),
        format!("{{{counts_json}}}"),
    ));
    extras.push((
        "fault_path".to_string(),
        format!(
            "{{\"errored\": {}, \"rejected\": {}, \"cancelled\": {}}}",
            sched.metrics.errored, sched.metrics.rejected, sched.metrics.cancelled
        ),
    ));
    extras.push((
        "fault_injection".to_string(),
        format!(
            "{{\"injected\": {injected}, \"retries\": {retries}, \
              \"preempted\": {}, \"recovered_tok_per_s\": {recovered_tps:.1}}}",
            fault_sched.metrics.preempted
        ),
    ));
    extras.push((
        "failover".to_string(),
        format!(
            "{{\"replicas\": {fo_fleet}, \"killed\": 1, \"failovers\": \
              {fo_failovers}, \"migrated\": {fo_migrated}, \
              \"reprefill_tokens\": {fo_reprefill}, \"shed\": {fo_shed}, \
              \"recovered_tok_per_s\": {fo_tps:.1}}}"
        ),
    ));
    extras.push((
        "kv_pool".to_string(),
        format!(
            "{{\"blocks\": {churn_blocks}, \"preempted\": {}, \
              \"peak_utilization\": {:.2}, \"shared_saved_peak\": {}}}",
            churn_sum.preempted,
            churn_sum.pool_peak_utilization(),
            churn_sum.pool_blocks_saved_peak,
        ),
    ));
    extras.push((
        "serving_mode".to_string(),
        format!(
            "{{\"device_sampled\": {device_sampled}, \"prefill_buckets\": [{}]}}",
            buckets
                .iter()
                .map(usize::to_string)
                .collect::<Vec<_>>()
                .join(", ")
        ),
    ));
    let mut slo_json = format!(
        "{{\"ttft_p99_ms\": {:.3}, \"tpot_p99_ms\": {:.3}, \"goodput\": {:.3}",
        slo.ttft_p99() * 1e3,
        slo.tpot_p99() * 1e3,
        slo.goodput()
    );
    for c in &slo_classes {
        slo_json.push_str(&format!(
            ", \"{}\": {{\"total\": {}, \"goodput\": {:.3}, \"ttft_p50_ms\": {:.3}, \
             \"ttft_p99_ms\": {:.3}, \"tpot_p50_ms\": {:.3}, \"tpot_p99_ms\": {:.3}}}",
            cushioncache::bench::json_escape(&c.class),
            c.total,
            c.goodput(),
            c.ttft_p50 * 1e3,
            c.ttft_p99 * 1e3,
            c.tpot_p50 * 1e3,
            c.tpot_p99 * 1e3,
        ));
    }
    slo_json.push('}');
    extras.push(("slo".to_string(), slo_json));
    extras.push((
        "observability".to_string(),
        format!(
            "{{\"tracing_overhead_frac\": {:.4}, \"untraced_mean_ms\": {:.3}, \
              \"traced_mean_ms\": {:.3}, \"trace_records\": {obs_records}}}",
            tracing_overhead_frac,
            obs_un.mean * 1e3,
            obs_tr.mean * 1e3,
        ),
    ));
    emit_bench_json("perf_hotpath", &components, &extras);
    Ok(())
}
