//! §Perf micro-bench: where does a serving step's time go?
//! Breaks the decode step into components — graph execution vs host
//! marshalling (the cache's host round-trip forced by the tuple-output
//! PJRT wrapper) vs coordinator logic — and measures the eval forward
//! and the pallas-vs-XLA-fusion artifact variants.

use std::time::Instant;

use cushioncache::bench::{summarize, time_n, Table};
use cushioncache::coordinator::{Engine, Scheduler};
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::literalx::HostValue;
use cushioncache::runtime::Client;
use cushioncache::util::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    // (cargo bench appends a literal `--bench`; skip flag-like args)
    let variant = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "tl-llama3".into());
    let iters = 20;
    let mut table = Table::new(
        &format!("Perf — hot-path breakdown ({variant})"),
        &["component", "mean (ms)", "p50 (ms)", "p99 (ms)"],
    );
    let mut row = |name: &str, samples: &[f64]| {
        let t = summarize(samples);
        table.row(vec![
            name.into(),
            format!("{:.2}", t.mean * 1e3),
            format!("{:.2}", t.p50 * 1e3),
            format!("{:.2}", t.p99 * 1e3),
        ]);
    };

    // ---- eval forward -----------------------------------------------------
    let mut s = Session::load_with_client(&variant, client.clone())?;
    let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 2)?;
    let tokens: Vec<i32> = {
        let split = s.corpus.split("heldout")?;
        (0..s.manifest.eval_batch).flat_map(|i| split.seq(i).to_vec()).collect()
    };
    let _ = s.fwd(&scheme, &tokens)?; // warm (compile)
    row("fwd_pts (B=8, S=128)",
        &time_n(1, iters, || { s.fwd(&scheme, &tokens).unwrap(); }));
    let _ = s.fwd(&Scheme::fp(), &tokens)?;
    row("fwd_fp  (B=8, S=128)",
        &time_n(1, iters, || { s.fwd(&Scheme::fp(), &tokens).unwrap(); }));

    // pallas-kernel artifact variant, if present (tl-llama3)
    if s.manifest.graphs.iter().any(|g| g == "fwd_pts_pallas") {
        let run_pallas = || {
            let (pkv, plen) = s.prefix_args();
            s.run(
                "fwd_pts_pallas",
                &[
                    HostValue::F32(pkv),
                    HostValue::scalar_i32(plen),
                    HostValue::I32(cushioncache::runtime::IntTensor::new(
                        vec![s.manifest.eval_batch, s.manifest.seq_len],
                        tokens.clone(),
                    )),
                    HostValue::F32(s.ranges.clone()),
                    HostValue::scalar_f32(scheme.act_levels()),
                    HostValue::F32(s.inv_smooth.clone()),
                ],
            )
            .unwrap();
        };
        run_pallas();
        row("fwd_pts_pallas (interpret)", &time_n(1, 5, run_pallas));
    }

    // ---- serving decode breakdown ----------------------------------------
    let mut s2 = Session::load_with_client(&variant, client.clone())?;
    calibrate::calibrate_into(&mut s2, scheme.act_levels(), 2)?;
    let prompt: Vec<i32> = s2.corpus.split("heldout")?.seq(0)[..96].to_vec();
    let engine = Engine::new(s2, scheme)?;
    let mut sched = Scheduler::new(engine);
    sched.submit(prompt.clone(), 8);
    sched.run_to_completion()?; // warm
    // fill all 8 slots and measure a full decode step
    for _ in 0..8 {
        sched.submit(prompt.clone(), 10_000_000); // never self-stop
    }
    for _ in 0..9 {
        sched.step()?; // admit all prefills + first decodes
    }
    row("decode step (batch 8)",
        &time_n(1, iters, || { sched.step().unwrap(); }));

    // marshalling cost: cache-sized host<->device round trip
    let m = &sched.engine.session.manifest;
    let cache_elems =
        m.n_layers * 2 * m.serve_batch * m.n_kv_heads * m.cache_cap * m.d_head;
    let host = Tensor::zeros(&[cache_elems]);
    row("cache upload (alone)", &time_n(1, iters, || {
        let _ = client.upload(&host).unwrap();
    }));
    let buf = client.upload(&host)?;
    row("cache download (alone)", &time_n(1, iters, || {
        let _ = cushioncache::runtime::literalx::fetch_f32(&buf).unwrap();
    }));

    // prefill
    let t0 = Instant::now();
    let mut s3 = Session::load_with_client(&variant, client.clone())?;
    calibrate::calibrate_into(&mut s3, scheme.act_levels(), 1)?;
    let mut engine3 = Engine::new(s3, scheme)?;
    engine3.prefill(0, &prompt)?; // warm
    let _ = t0;
    row("prefill (prompt 96)", &time_n(1, iters, || {
        engine3.prefill(0, &prompt).unwrap();
    }));

    table.emit("perf_hotpath");
    Ok(())
}
