//! Table 8 (Appendix A.2): serving latency — TTFT (prefill) and TPOT
//! (decode) per granularity, with and without CushionCache. The paper's
//! claim to reproduce: the cushion adds well under 1% to either number
//! while unlocking the fastest (per-tensor static) path.

use cushioncache::bench::scenario;
use cushioncache::bench::{summarize, Table};
use cushioncache::coordinator::{Engine, Scheduler};
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let variant = "tl-llama3";
    let n_decode = if scenario::fast_mode() { 16 } else { 64 };
    let mut table = Table::new(
        "Table 8 — generation latency (tl-llama3, prompt 96, batch 1)",
        &["scheme", "cushion", "TTFT (ms)", "TPOT mean (ms)", "TPOT std (ms)"],
    );

    for gran in [Granularity::PerTensorStatic, Granularity::PerTensorDynamic,
                 Granularity::PerTokenDynamic] {
        for with_cushion in [false, true] {
            let mut session =
                scenario::prepared(&client, variant, false, with_cushion)?;
            let scheme = Scheme::w8a8(gran, Algorithm::Naive);
            if scheme.gran.needs_calibration() {
                calibrate::calibrate_into(&mut session, scheme.act_levels(),
                                          scenario::eval_batches())?;
            }
            let prompt = session.corpus.split("heldout")?.seq(0)[..96].to_vec();
            let engine = Engine::new(session, scheme)?;
            let mut sched = Scheduler::new(engine);

            // warm-up (compilation + caches), excluded from the numbers
            sched.submit(prompt.clone(), 4);
            sched.run_to_completion()?;
            sched.metrics = Default::default();

            sched.submit(prompt.clone(), n_decode);
            let resp = sched.run_to_completion()?.pop().unwrap();
            let tpot = summarize(&resp.tpot);
            table.row(vec![
                scheme.label(),
                if with_cushion { "yes" } else { "no" }.into(),
                format!("{:.2}", resp.ttft.unwrap_or(0.0) * 1e3),
                format!("{:.2}", tpot.mean * 1e3),
                format!("{:.2}", tpot.std * 1e3),
            ]);
        }
    }
    table.emit("table8_latency");
    Ok(())
}
