//! Table 1: WikiText-2 (-> synwiki heldout) perplexity of W8A8-quantized
//! models, {naive, SmoothQuant} x {per-tensor static, per-tensor dynamic,
//! per-token dynamic}, with and without CushionCache.
//!
//!   cargo bench --bench table1_perplexity
//!   CUSHION_BENCH_FAST=1 cargo bench --bench table1_perplexity   (smoke)

use cushioncache::bench::scenario::{self, bench_variants, eval_cell, table_rows};
use cushioncache::bench::Table;
use cushioncache::eval::perplexity::perplexity;
use cushioncache::quant::scheme::Scheme;
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let mut table = Table::new(
        "Table 1 — heldout perplexity of W8A8-quantized models (down = better)",
        &["scheme", "variant", "no cushion", "+ CushionCache", "delta"],
    );

    for variant in bench_variants() {
        // FP reference row
        let mut s = scenario::prepared(&client, variant, false, false)?;
        let fp = perplexity(&s, &Scheme::fp(), "heldout", scenario::eval_batches())?;
        table.row(vec![
            "FP16".into(), variant.into(), format!("{fp:.2}"), "-".into(), "-".into(),
        ]);

        for (label, scheme, smooth) in table_rows() {
            let mut base = scenario::prepared(&client, variant, smooth, false)?;
            let (ppl0, _) = eval_cell(&mut base, &scheme, false)?;
            let mut with = scenario::prepared(&client, variant, smooth, true)?;
            let (ppl1, _) = eval_cell(&mut with, &scheme, false)?;
            table.row(vec![
                label.into(),
                variant.into(),
                format!("{ppl0:.2}"),
                format!("{ppl1:.2}"),
                scenario::pct_delta(ppl0, ppl1),
            ]);
            let _ = &mut s;
        }
    }
    table.emit("table1_perplexity");
    Ok(())
}
