//! Table 3: ablation on tl-llama3 under W8A8 per-tensor dynamic — add the
//! components one at a time: greedy-searched init, prefix tuning (without
//! the quantization loss, lambda = 0), full quantization-aware tuning.

use cushioncache::bench::scenario::{self, eval_cell};
use cushioncache::bench::Table;
use cushioncache::cushion::{self, SearchCfg, TuneCfg};
use cushioncache::model::session::Cushion;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let variant = "tl-llama3";
    let scheme = Scheme::w8a8(Granularity::PerTensorDynamic, Algorithm::Naive);
    let mut table = Table::new(
        "Table 3 — ablation (tl-llama3, W8A8 per-tensor dynamic)",
        &["configuration", "heldout ppl", "zero-shot acc (%)"],
    );

    let mut s = scenario::prepared(&client, variant, false, false)?;
    let (ppl_fp, acc_fp) = eval_cell(&mut s, &Scheme::fp(), true)?;
    table.row(vec!["FP16".into(), format!("{ppl_fp:.2}"), format!("{acc_fp:.2}")]);

    let (ppl0, acc0) = eval_cell(&mut s, &scheme, true)?;
    table.row(vec!["Per-tensor Dynamic".into(), format!("{ppl0:.2}"),
                   format!("{acc0:.2}")]);

    // + greedy-searched init (prefix KV straight from the search)
    let stride = if scenario::fast_mode() { 16 } else { 4 };
    let res = cushion::greedy_search(
        &s, &SearchCfg { vocab_stride: stride, max_len: 6, ..Default::default() })?;
    let kv = s.compute_prefix_kv(&res.prefix)?;
    s.set_cushion(Cushion { tokens: res.prefix.clone(),
                            len: res.prefix.len(), kv })?;
    let (ppl1, acc1) = eval_cell(&mut s, &scheme, true)?;
    table.row(vec!["+ Greedy-searched init.".into(), format!("{ppl1:.2}"),
                   format!("{acc1:.2}")]);

    // + prefix tuning without the quantization-aware loss (lambda = 0)
    let t0 = cushion::tune::tune_prefix(
        &s, &res.prefix, &TuneCfg { lambda: 0.0, ..Default::default() })?;
    s.set_cushion(Cushion { tokens: res.prefix.clone(),
                            len: res.prefix.len(), kv: t0.kv })?;
    let (ppl2, acc2) = eval_cell(&mut s, &scheme, true)?;
    table.row(vec!["+ Prefix tuning".into(), format!("{ppl2:.2}"),
                   format!("{acc2:.2}")]);

    // + quantization-aware loss (the full method, lambda = 0.01)
    let t1 = cushion::tune::tune_prefix(&s, &res.prefix, &TuneCfg::default())?;
    s.set_cushion(Cushion { tokens: res.prefix.clone(),
                            len: res.prefix.len(), kv: t1.kv })?;
    let (ppl3, acc3) = eval_cell(&mut s, &scheme, true)?;
    table.row(vec!["+ Quantization-aware loss".into(), format!("{ppl3:.2}"),
                   format!("{acc3:.2}")]);

    table.emit("table3_ablation");
    Ok(())
}
