//! Figure 1: activation-magnitude heatmap (position x layer) before and
//! after CushionCache, plus a compact ASCII rendering. The CSV rows are
//! (config, layer, position, magnitude) — plot position on x, layer as
//! series to regenerate the paper's panels.

use cushioncache::bench::scenario;
use cushioncache::bench::Table;
use cushioncache::eval::actstats;
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let variant = "tl-llama";
    let mut table = Table::new(
        "Figure 1 — per-position channel-absmax of block inputs (tl-llama)",
        &["config", "layer", "position", "magnitude"],
    );

    for (with_cushion, config) in [(false, "baseline"), (true, "cushioncache")] {
        let s = scenario::prepared(&client, variant, false, with_cushion)?;
        let rep = actstats::collect(&s, 2)?;
        for (l, row) in rep.heatmap.iter().enumerate() {
            for (p, &mag) in row.iter().enumerate() {
                table.row(vec![
                    config.into(), format!("{l}"), format!("{p}"),
                    format!("{mag:.3}"),
                ]);
            }
        }
        // ASCII sketch of the last-block row (log scale)
        let row = &rep.heatmap[rep.heatmap.len() - 2];
        let sketch: String = row
            .iter()
            .map(|&m| match m {
                m if m > 1000.0 => '#',
                m if m > 100.0 => '+',
                m if m > 10.0 => '.',
                _ => ' ',
            })
            .collect();
        println!("{config:>13} |{sketch}|");
    }
    table.emit("fig1_heatmap");
    Ok(())
}
