//! Table 9 (Appendix A.3): CushionCache composed with other quantization
//! algorithms — AWQ (4-bit weight-only), QuaRot-lite (Hadamard-rotated
//! W8A8), and KIVI (2-bit KV cache; evaluated generatively via gsm-syn,
//! as the KIVI paper reports GSM8K rather than perplexity).

use cushioncache::bench::scenario::{self, eval_cell, task_items};
use cushioncache::bench::Table;
use cushioncache::data::tasks as dtasks;
use cushioncache::eval::tasks as etasks;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::quant::{awq, calibrate, quarot};
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let variant = "tl-llama3";
    let mut table = Table::new(
        "Table 9 — CushionCache composed with AWQ / QuaRot / KIVI (tl-llama3)",
        &["configuration", "metric", "value"],
    );
    let pts = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);

    // FP reference
    let mut s = scenario::prepared(&client, variant, false, false)?;
    let (fp_ppl, _) = eval_cell(&mut s, &Scheme::fp(), false)?;
    table.row(vec!["FP16".into(), "ppl".into(), format!("{fp_ppl:.2}")]);

    // ---- AWQ (weight-only 4-bit) ----------------------------------------
    for (with_cushion, label) in [(false, "AWQ-4bit"), (true, "AWQ-4bit + CushionCache")] {
        let mut s = scenario::prepared(&client, variant, false, with_cushion)?;
        let calib = calibrate::calibrate(&s, scenario::eval_batches())?;
        let mut w = s.weights.clone();
        awq::apply(&mut w, &s.manifest, &calib, 4)?;
        s.set_weights(w);
        let (ppl, _) = eval_cell(&mut s, &Scheme::fp(), false)?;
        table.row(vec![label.into(), "ppl".into(), format!("{ppl:.2}")]);
    }
    // AWQ + per-tensor static activations (the paper's "+ Per-* Static")
    for (with_cushion, label) in [(false, "AWQ + Per-tensor Static"),
                                  (true, "AWQ + Per-tensor Static + CushionCache")] {
        let mut s = scenario::prepared(&client, variant, false, with_cushion)?;
        let calib = calibrate::calibrate(&s, scenario::eval_batches())?;
        let mut w = s.weights.clone();
        awq::apply(&mut w, &s.manifest, &calib, 4)?;
        s.set_weights(w);
        let (ppl, _) = eval_cell(&mut s, &pts, false)?;
        table.row(vec![label.into(), "ppl".into(), format!("{ppl:.2}")]);
    }

    // ---- QuaRot-lite (rotated residual, W8A8 per-tensor static) ---------
    for (with_cushion, label) in [(false, "QuaRot"), (true, "QuaRot + CushionCache")] {
        let mut s = scenario::prepared(&client, variant, false, with_cushion)?;
        let mut w = s.weights.clone();
        quarot::apply(&mut w, &s.manifest)?;
        s.set_weights(w);
        // NOTE: the cushion KV was computed pre-rotation; rotation is
        // function-preserving so the same token prefix is re-derived here.
        if with_cushion {
            let tokens = s.cushion().unwrap().tokens.clone();
            s.set_cushion_tokens(&tokens)?;
        }
        let (ppl, _) = eval_cell(&mut s, &pts, false)?;
        table.row(vec![label.into(), "ppl".into(), format!("{ppl:.2}")]);
    }

    // ---- KIVI (2-bit KV cache), gsm-syn exact match ----------------------
    let gsm_rows = [
        ("FP16 + KIVI", Scheme { kv_bits: 2, ..Scheme::fp() }, false),
        ("Per-tensor Static", pts, false),
        ("Per-tensor Static + KIVI", Scheme { kv_bits: 2, ..pts }, false),
        ("Per-tensor Static + KIVI + CushionCache", Scheme { kv_bits: 2, ..pts }, true),
    ];
    for (label, scheme, with_cushion) in gsm_rows {
        let mut s = scenario::prepared(&client, variant, false, with_cushion)?;
        if scheme.gran.needs_calibration() {
            calibrate::calibrate_into(&mut s, scheme.act_levels(),
                                      scenario::eval_batches())?;
        }
        let all = dtasks::load(
            &cushioncache::util::fsutil::variant_dir(variant).join("tasks.bin"))?;
        let t = dtasks::find(&all, "gsm-syn")?;
        // generative eval through the serving path — KV quantization
        // (KIVI) only exists in the prefill/decode graphs
        let mut engine = cushioncache::coordinator::Engine::new(s, scheme)?;
        let sc = etasks::eval_gen_serving(&mut engine, t, task_items() / 2)?;
        table.row(vec![label.into(), "gsm-syn acc (%)".into(),
                       format!("{:.2}", sc.accuracy * 100.0)]);
    }

    table.emit("table9_combos");
    Ok(())
}
