//! Table 5: top-1 / top-10% / median activation magnitudes of the input
//! to the last transformer block, before and after CushionCache.

use cushioncache::bench::scenario;
use cushioncache::bench::Table;
use cushioncache::eval::actstats;
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let mut table = Table::new(
        "Table 5 — activation magnitude order statistics (last block input)",
        &["model", "top-1", "top 10%", "median"],
    );
    let n = if scenario::fast_mode() { 1 } else { 8 };

    for variant in ["tl-llama", "tl-llama3", "tl-mistral"] {
        let s = scenario::prepared(&client, variant, false, false)?;
        let rep = actstats::collect(&s, n)?;
        let [t1, t10, med] = rep.last_block();
        table.row(vec![variant.into(), format!("{t1:.2}"),
                       format!("{t10:.2}"), format!("{med:.2}")]);

        let sc = scenario::prepared(&client, variant, false, true)?;
        let rep = actstats::collect(&sc, n)?;
        let [t1, t10, med] = rep.last_block();
        table.row(vec![format!("{variant} + CushionCache"), format!("{t1:.2}"),
                       format!("{t10:.2}"), format!("{med:.2}")]);
    }
    table.emit("table5_magnitudes");
    Ok(())
}
