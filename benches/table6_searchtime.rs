//! Table 6: wall-clock of step 1 (greedy search) and step 2 (QAT prefix
//! tuning). We measure a strided sweep and report both the measured time
//! and the full-vocabulary extrapolation (the sweep is embarrassingly
//! batched, so cost scales linearly in candidates — the paper's LLaMA3
//! row being slowest for its larger embedding table reproduces directly).

use cushioncache::bench::scenario;
use cushioncache::bench::Table;
use cushioncache::cushion::{self, SearchCfg, TuneCfg};
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let stride = if scenario::fast_mode() { 32 } else { 8 };
    let mut table = Table::new(
        "Table 6 — CushionCache discovery wall-clock",
        &["model", "vocab", "step 1 search (s)", "step 1 full-sweep est (s)",
          "step 2 tuning (s)", "total est (s)"],
    );

    for variant in ["tl-llama", "tl-llama3", "tl-opt"] {
        let s = scenario::prepared(&client, variant, false, false)?;
        let res = cushion::greedy_search(
            &s,
            &SearchCfg { vocab_stride: stride, max_len: 4, ..Default::default() },
        )?;
        let est_full = res.seconds * stride as f64;
        let epochs = if scenario::fast_mode() { 1 } else { 2 };
        let tuned = cushion::tune::tune_prefix(
            &s, &res.prefix, &TuneCfg { epochs, ..Default::default() })?;
        table.row(vec![
            variant.into(),
            format!("{}", s.manifest.vocab),
            format!("{:.1}", res.seconds),
            format!("{est_full:.1}"),
            format!("{:.1}", tuned.seconds),
            format!("{:.1}", est_full + tuned.seconds),
        ]);
    }
    table.emit("table6_searchtime");
    Ok(())
}
