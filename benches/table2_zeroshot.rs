//! Table 2: average zero-shot accuracy over the seven synthetic tasks
//! (LAMBADA/HellaSwag/PIQA/WinoGrande/OBQA/RTE/COPA analogues), same grid
//! as Table 1.

use cushioncache::bench::scenario::{self, bench_variants, eval_cell, table_rows};
use cushioncache::bench::Table;
use cushioncache::quant::scheme::Scheme;
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let mut table = Table::new(
        "Table 2 — zero-shot accuracy (7-task average, %; up = better)",
        &["scheme", "variant", "no cushion", "+ CushionCache", "delta (pp)"],
    );

    for variant in bench_variants() {
        let mut s = scenario::prepared(&client, variant, false, false)?;
        let (_, acc_fp) = eval_cell(&mut s, &Scheme::fp(), true)?;
        table.row(vec![
            "FP16".into(), variant.into(), format!("{acc_fp:.2}"), "-".into(),
            "-".into(),
        ]);
        for (label, scheme, smooth) in table_rows() {
            let mut base = scenario::prepared(&client, variant, smooth, false)?;
            let (_, a0) = eval_cell(&mut base, &scheme, true)?;
            let mut with = scenario::prepared(&client, variant, smooth, true)?;
            let (_, a1) = eval_cell(&mut with, &scheme, true)?;
            table.row(vec![
                label.into(),
                variant.into(),
                format!("{a0:.2}"),
                format!("{a1:.2}"),
                format!("{:+.2}", a1 - a0),
            ]);
        }
    }
    table.emit("table2_zeroshot");
    Ok(())
}
