//! Table 4: W6A6 / W4A4 per-token dynamic quantization (SmoothQuant-O1)
//! on tl-llama3 and tl-mistral, with and without CushionCache.

use cushioncache::bench::scenario::{self, eval_cell};
use cushioncache::bench::Table;
use cushioncache::quant::scales;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme, SMOOTH_ALPHA};
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let mut table = Table::new(
        "Table 4 — low-bit per-token dynamic (SmoothQuant-O1) +/- CushionCache",
        &["variant", "bits", "ppl", "+cushion ppl", "acc", "+cushion acc"],
    );

    for variant in ["tl-llama3", "tl-mistral"] {
        for bits in [6u32, 4u32] {
            let scheme = Scheme::wnan(
                bits, Granularity::PerTokenDynamic,
                Algorithm::SmoothQuant { alpha: SMOOTH_ALPHA });
            let run = |with: bool| -> anyhow::Result<(f64, f64)> {
                let mut s = scenario::prepared(&client, variant, true, with)?;
                // weight quantization to the same bit-width (paper WxAx)
                let mut w = s.weights.clone();
                for name in w.names.clone() {
                    if scales::is_quantized_weight(&name) {
                        scales::quant_weight_inplace(w.get_mut(&name)?, bits, 64);
                    }
                }
                s.set_weights(w);
                eval_cell(&mut s, &scheme, true)
            };
            let (p0, a0) = run(false)?;
            let (p1, a1) = run(true)?;
            table.row(vec![
                variant.into(), format!("W{bits}A{bits}"),
                format!("{p0:.2}"), format!("{p1:.2}"),
                format!("{a0:.2}"), format!("{a1:.2}"),
            ]);
        }
    }
    table.emit("table4_lowbit");
    Ok(())
}
