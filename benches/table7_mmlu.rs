//! Table 7 (Appendix A.1): mmlu-syn (14 subjects) under SmoothQuant
//! O3/O2/O1 with and without CushionCache.

use cushioncache::bench::scenario::{self, task_items};
use cushioncache::bench::Table;
use cushioncache::data::tasks as dtasks;
use cushioncache::eval::tasks as etasks;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme, SMOOTH_ALPHA};
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let sq = Algorithm::SmoothQuant { alpha: SMOOTH_ALPHA };
    let rows = [
        ("SmoothQuant-O3", Granularity::PerTensorStatic),
        ("SmoothQuant-O2", Granularity::PerTensorDynamic),
        ("SmoothQuant-O1", Granularity::PerTokenDynamic),
    ];
    let mut table = Table::new(
        "Table 7 — mmlu-syn accuracy (%), SmoothQuant +/- CushionCache",
        &["scheme", "variant", "no cushion", "+ CushionCache", "delta (pp)"],
    );

    let variants: Vec<&str> = if scenario::fast_mode() {
        vec!["tl-llama"]
    } else {
        vec!["tl-llama", "tl-mistral", "tl-llama3"]
    };
    for variant in variants {
        // FP reference
        let mut s = scenario::prepared(&client, variant, false, false)?;
        let fp = mmlu_acc(&mut s, &Scheme::fp())?;
        table.row(vec!["FP16".into(), variant.into(), format!("{fp:.2}"),
                       "-".into(), "-".into()]);
        for (label, gran) in rows {
            let scheme = Scheme::w8a8(gran, sq);
            let mut base = scenario::prepared(&client, variant, true, false)?;
            let a0 = mmlu_acc(&mut base, &scheme)?;
            let mut with = scenario::prepared(&client, variant, true, true)?;
            let a1 = mmlu_acc(&mut with, &scheme)?;
            table.row(vec![
                label.into(), variant.into(), format!("{a0:.2}"),
                format!("{a1:.2}"), format!("{:+.2}", a1 - a0),
            ]);
        }
    }
    table.emit("table7_mmlu");
    Ok(())
}

fn mmlu_acc(s: &mut cushioncache::model::session::Session,
            scheme: &Scheme) -> anyhow::Result<f64> {
    if scheme.gran.needs_calibration() {
        calibrate::calibrate_into(s, scheme.act_levels(), scenario::eval_batches())?;
    }
    let all = dtasks::load(
        &cushioncache::util::fsutil::variant_dir(&s.manifest.variant)
            .join("tasks.bin"))?;
    let t = dtasks::find(&all, "mmlu-syn")?;
    let sc = etasks::eval_task(s, scheme, t, task_items() * 2)?;
    Ok(sc.accuracy * 100.0)
}
