//! Figure 2: top-1/2/3 and median activation magnitude per layer of
//! tl-llama3, without (left panel) and with (right panel) CushionCache.
//! We emit top-1 / top-10% / median per block input as CSV series.

use cushioncache::bench::scenario;
use cushioncache::bench::Table;
use cushioncache::eval::actstats;
use cushioncache::runtime::Client;

fn main() -> anyhow::Result<()> {
    cushioncache::util::logging::init();
    let client = Client::cpu()?;
    let variant = "tl-llama3";
    let n = if scenario::fast_mode() { 1 } else { 8 };
    let mut table = Table::new(
        "Figure 2 — per-layer activation magnitudes (tl-llama3)",
        &["config", "layer", "top1", "top10pct", "median"],
    );

    for (with_cushion, config) in [(false, "baseline"), (true, "cushioncache")] {
        let s = scenario::prepared(&client, variant, false, with_cushion)?;
        let rep = actstats::collect(&s, n)?;
        for (l, [t1, t10, med]) in rep.per_level.iter().enumerate() {
            table.row(vec![
                config.into(), format!("{l}"), format!("{t1:.3}"),
                format!("{t10:.4}"), format!("{med:.4}"),
            ]);
        }
    }
    table.emit("fig2_layerwise");
    Ok(())
}
