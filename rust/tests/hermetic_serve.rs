//! Hermetic end-to-end serving + search: everything here runs on the
//! reference interpreter backend with **no artifact directory present**
//! and no XLA toolchain — the `testkit::tiny` model is assembled fully
//! in memory. Covers the scheduler (admission into every free slot,
//! fault isolation, cancel, chunked-prefill bit-identity, deterministic
//! trace replay), the TCP streaming protocol, the
//! device-vs-host sampling parity at engine level, the greedy
//! CushionCache search driver, and the steady-state transfer budget —
//! the same invariants the artifact-gated suites assert under PJRT.

use std::io::{BufRead, BufReader, Write};
use std::rc::Rc;

use cushioncache::bench::scenario::{generate_trace, replay_trace, TraceCfg};
use cushioncache::coordinator::metrics::SloMetrics;
use cushioncache::coordinator::{
    Engine, FinishReason, Health, Request, Router, Scheduler,
};
use cushioncache::cushion::{self, SearchCfg};
use cushioncache::data::PAD;
use cushioncache::eval::perplexity::{argmax, perplexity};
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::backend::RefBackend;
use cushioncache::runtime::{faults, transfer, Client, FaultPlan, FaultyBackend};
use cushioncache::testkit::tiny::TinyCfg;
use cushioncache::util::json;

fn tiny_session() -> Session {
    TinyCfg::default().session().unwrap()
}

/// A tiny session whose backend injects this thread's armed fault plan
/// (runtime::faults) — nothing is injected until `faults::arm` runs.
fn faulty_session() -> Session {
    TinyCfg::default()
        .session_with_client(Client::with_backend(Rc::new(FaultyBackend::wrap(
            Rc::new(RefBackend),
        ))))
        .unwrap()
}

fn prompt_from(s: &Session, seq: usize, len: usize) -> Vec<i32> {
    s.corpus.split("heldout").unwrap().seq(seq)[..len].to_vec()
}

#[test]
fn session_resolves_graphs_without_artifacts() {
    let s = tiny_session();
    assert!(s.registry.client().is_reference());
    for g in [
        "fwd_fp", "fwd_pts", "fwd_ptd", "fwd_ptk", "stats", "score_lq",
        "prefix_kv", "tune_step", "prefill_fp", "decode_fp",
        "decode_sampled_fp", "prefill_sampled_fp_b8", "prefill_paged_fp",
        "decode_paged_fp",
    ] {
        assert!(s.registry.has(g), "graph {g} should resolve hermetically");
        assert!(!s.registry.has_artifact(g), "no artifact may exist for {g}");
        s.registry.get(g).unwrap_or_else(|e| panic!("resolve {g}: {e:#}"));
    }
}

#[test]
fn serving_matches_eval_forward_hermetically() {
    // greedy continuation via prefill+decode == argmax chain via fwd —
    // two independent interpreter code paths must agree exactly
    let s = tiny_session();
    let (seq_len, vocab, eval_batch) = (
        s.manifest.seq_len,
        s.manifest.vocab,
        s.manifest.eval_batch,
    );
    let prompt = prompt_from(&s, 1, 6);

    let s2 = tiny_session();
    let mut seq = prompt.clone();
    let mut want = Vec::new();
    for _ in 0..4 {
        let mut batch = seq.clone();
        batch.resize(seq_len, PAD);
        batch.resize(seq_len * eval_batch, PAD);
        let out = s2.fwd(&Scheme::fp(), &batch).unwrap();
        let pos = seq.len() - 1;
        let next = argmax(&out.data[pos * vocab..(pos + 1) * vocab]) as i32;
        want.push(next);
        seq.push(next);
    }

    let engine = Engine::new(s, Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let mut req = Request::new(1, prompt, 4);
    req.stop_token = None;
    sched.submit_request(req);
    let resp = sched.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(resp.finished, FinishReason::MaxTokens);
    assert_eq!(resp.tokens, want, "serving diverges from eval forward");
}

#[test]
fn device_and_host_sampling_agree_hermetically() {
    // in-graph selection (interp select_tokens) vs logits + host argmax
    let run = |device_sampling: bool| -> Vec<i32> {
        let mut e = Engine::new(tiny_session(), Scheme::fp()).unwrap();
        e.set_device_sampling(device_sampling);
        let prompt = prompt_from(&e.session, 2, 5);
        let slot = e.kv.alloc(1, prompt.len()).unwrap();
        let mut last = e.prefill(slot, &prompt).unwrap();
        let mut out = vec![last];
        let b = e.session.manifest.serve_batch;
        for _ in 0..3 {
            let mut feed = vec![PAD; b];
            feed[slot] = last;
            last = e.decode_step(&feed).unwrap()[slot];
            e.kv.push_token(slot);
            out.push(last);
        }
        out
    };
    assert_eq!(run(true), run(false), "sampled ids != host argmax ids");
}

#[test]
fn scheduler_isolates_bad_requests_hermetically() {
    let engine = Engine::new(tiny_session(), Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let seq_len = sched.engine.session.manifest.seq_len;
    let vocab = sched.engine.session.manifest.vocab as i32;
    let good_prompt = prompt_from(&sched.engine.session, 1, 6);

    sched.submit_request(Request::new(101, vec![5; seq_len + 1], 4));
    sched.submit_request(Request::new(102, vec![0, vocab + 7], 4));
    sched.submit_request(Request::new(103, vec![], 4));
    let mut good = Request::new(104, good_prompt, 3);
    good.stop_token = None;
    sched.submit_request(good);

    let mut resp = sched.run_to_completion().unwrap();
    resp.sort_by_key(|r| r.id);
    assert_eq!(resp.len(), 4);
    for bad in &resp[..3] {
        assert!(bad.finished.is_error(), "{}: {:?}", bad.id, bad.finished);
        assert!(bad.tokens.is_empty());
    }
    assert_eq!(resp[3].finished, FinishReason::MaxTokens);
    assert_eq!(resp[3].tokens.len(), 3, "valid request starved by bad ones");
    assert_eq!(sched.metrics.errored, 3);
    assert_eq!(sched.metrics.completed, 1);
}

#[test]
fn scheduler_fills_slots_and_cancels_hermetically() {
    let engine = Engine::new(tiny_session(), Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let n_slots = sched.engine.kv.n_slots;
    let prompt = prompt_from(&sched.engine.session, 0, 6);
    for i in 0..n_slots + 1 {
        let mut r = Request::new(200 + i as u64, prompt.clone(), 8);
        r.stop_token = None;
        sched.submit_request(r);
    }
    sched.step().unwrap();
    assert_eq!(sched.running_count(), n_slots, "admit into every free slot");
    assert_eq!(sched.batcher.waiting(), 1);

    let free_before = sched.engine.kv.free_count();
    assert!(sched.cancel(200), "cancel in-flight request");
    assert_eq!(sched.engine.kv.free_count(), free_before + 1);
    assert!(!sched.cancel(200), "double-cancel is a no-op");
    sched.run_to_completion().unwrap();
    let resp = sched.take_finished();
    assert!(resp
        .iter()
        .any(|r| r.id == 200 && r.finished == FinishReason::Cancelled));
}

#[test]
fn chunked_prefill_serves_bit_identically_to_unchunked() {
    // the scheduler-budgeted chunked path must reproduce single-shot
    // prefill exactly: every chunk attends the full cache row like a
    // decode step, so fp and static-quant outputs match bit-for-bit
    let pts = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    for (scheme, calibrated) in [(Scheme::fp(), false), (pts, true)] {
        let run = |chunk: Option<usize>| -> Vec<(u64, Vec<i32>, FinishReason)> {
            let mut s = tiny_session();
            if calibrated {
                calibrate::calibrate_into(&mut s, scheme.act_levels(), 2)
                    .unwrap();
            }
            let mut sched = Scheduler::new(Engine::new(s, scheme).unwrap());
            if chunk.is_some() {
                assert!(
                    sched.engine.supports_chunked_prefill(),
                    "default device-resident mode must support chunking"
                );
            }
            sched.set_prefill_chunk(chunk);
            for (i, len) in [5usize, 9, 12].into_iter().enumerate() {
                let p = prompt_from(&sched.engine.session, i, len);
                let mut r = Request::new(1 + i as u64, p, 3);
                r.stop_token = None;
                sched.submit_request(r);
            }
            let mut resp = sched.run_to_completion().unwrap();
            resp.sort_by_key(|r| r.id);
            resp.into_iter()
                .map(|r| (r.id, r.tokens, r.finished))
                .collect()
        };
        let want = run(None);
        assert!(
            want.iter()
                .all(|(_, t, f)| *f == FinishReason::MaxTokens && t.len() == 3),
            "unchunked baseline must finish clean: {want:?}"
        );
        for chunk in [3usize, 4, 7] {
            assert_eq!(run(Some(chunk)), want, "chunk budget {chunk} diverges");
        }
    }
}

#[test]
fn fixed_seed_trace_replay_is_deterministic_hermetically() {
    // the bench::scenario workload replayed twice on fresh engines must
    // produce the same response schedule token-for-token — the property
    // scripts/test_hermetic.sh sweeps under multiple PROP_SEEDs
    let cfg = TraceCfg {
        seed: 0xD15EA5E,
        n_requests: 12,
        ..TraceCfg::default()
    };
    let run = |cfg: &TraceCfg| -> (Vec<(u64, Vec<i32>, FinishReason)>, f64) {
        let mut sched =
            Scheduler::new(Engine::new(tiny_session(), Scheme::fp()).unwrap());
        sched.set_prefill_chunk(Some(3));
        let events = generate_trace(cfg);
        let mut slo = SloMetrics::new();
        let mut resp =
            replay_trace(&mut sched, &events, Some(&mut slo)).unwrap();
        resp.sort_by_key(|r| r.id);
        (
            resp.into_iter()
                .map(|r| (r.id, r.tokens, r.finished))
                .collect(),
            slo.goodput(),
        )
    };
    let (a, goodput) = run(&cfg);
    let (b, _) = run(&cfg);
    assert_eq!(a.len(), 12, "every traced request must come back");
    assert!(a.iter().all(|(_, _, f)| !f.is_error()), "{a:?}");
    assert!(
        (goodput - 1.0).abs() < 1e-9,
        "no deadlines armed: every finish is good (goodput {goodput})"
    );
    assert_eq!(a, b, "same seed must replay to the same responses");
}

#[test]
fn chaos_fixed_seed_transient_faults_serve_bit_identically() {
    // a 100% execute-fault plan capped at 2 injections: the first engine
    // call fails twice, the bounded-backoff retry absorbs both, and the
    // batch finishes exactly as the fault-free run does
    let run = |faulted: bool| -> (Vec<Vec<i32>>, usize, u64) {
        let s = if faulted { faulty_session() } else { tiny_session() };
        let prompts: Vec<Vec<i32>> = (0..s.manifest.serve_batch)
            .map(|i| prompt_from(&s, i, 6))
            .collect();
        let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
        if faulted {
            faults::arm(FaultPlan::parse("seed=1,execute=1,max=2").unwrap());
        }
        for (i, p) in prompts.iter().enumerate() {
            let mut r = Request::new(1 + i as u64, p.clone(), 6);
            r.stop_token = None;
            sched.submit_request(r);
        }
        let mut resp = sched.run_to_completion().unwrap();
        let injected = faults::disarm().map(|st| st.total()).unwrap_or(0);
        resp.sort_by_key(|r| r.id);
        assert!(resp.iter().all(|r| r.finished == FinishReason::MaxTokens));
        (
            resp.into_iter().map(|r| r.tokens).collect(),
            sched.metrics.retries_total(),
            injected,
        )
    };
    let (clean, _, _) = run(false);
    let (faulted, retries, injected) = run(true);
    assert_eq!(injected, 2, "the capped plan must inject exactly twice");
    assert_eq!(retries, 2, "both transient faults must be retried in place");
    assert_eq!(faulted, clean, "recovered run must be bit-identical");
}

#[test]
fn persistent_fault_walks_the_degradation_ladder_and_still_serves() {
    // every execute call fails persistently until the ladder reaches
    // rung 2 (heal=2): retries can't help, so the scheduler must walk
    // device-split -> host-roundtrip -> interpreter and keep serving
    let s = faulty_session();
    let prompts: Vec<Vec<i32>> = (0..s.manifest.serve_batch)
        .map(|i| prompt_from(&s, i, 6))
        .collect();
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    faults::arm(FaultPlan::parse("seed=3,persistent=execute,heal=2").unwrap());
    for (i, p) in prompts.iter().enumerate() {
        let mut r = Request::new(1 + i as u64, p.clone(), 6);
        r.stop_token = None;
        sched.submit_request(r);
    }
    let mut resp = sched.run_to_completion().unwrap();
    let injected = faults::disarm().map(|st| st.total()).unwrap_or(0);
    resp.sort_by_key(|r| r.id);
    assert!(injected >= 2, "one persistent fault per rung below heal");
    assert_eq!(resp.len(), prompts.len());
    assert!(
        resp.iter().all(|r| r.finished == FinishReason::MaxTokens),
        "the ladder floor must still serve: {:?}",
        resp.iter().map(|r| &r.finished).collect::<Vec<_>>()
    );
    assert_eq!(sched.rung(), 2, "device-split -> host-roundtrip -> interp");
    assert_eq!(sched.metrics.downgrades, 2);
    assert_eq!(sched.metrics.backend_rung, 2);
    assert!(sched.engine.session.registry.interp_forced());
}

#[test]
fn expired_deadline_kills_queued_request_and_serves_the_rest() {
    let engine = Engine::new(tiny_session(), Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let prompt = prompt_from(&sched.engine.session, 1, 6);
    let mut doomed = Request::new(1, prompt.clone(), 4);
    doomed.stop_token = None;
    doomed.deadline = Some(std::time::Duration::ZERO);
    sched.submit_request(doomed);
    let mut ok = Request::new(2, prompt, 4);
    ok.stop_token = None;
    sched.submit_request(ok);
    std::thread::sleep(std::time::Duration::from_millis(2));
    let mut resp = sched.run_to_completion().unwrap();
    resp.sort_by_key(|r| r.id);
    assert_eq!(resp.len(), 2);
    assert_eq!(resp[0].finished, FinishReason::Error("deadline".into()));
    assert!(resp[0].tokens.is_empty(), "killed before any generation");
    assert_eq!(resp[1].finished, FinishReason::MaxTokens);
    assert_eq!(sched.metrics.deadline_expired, 1);
}

#[test]
fn decode_budget_holds_on_reference_backend() {
    // the transfer meters model the same host<->device boundary on the
    // interpreter, so the steady-state decode budget is checkable with
    // no artifacts: resident invariants must not re-cross per step
    let mut e = Engine::new(tiny_session(), Scheme::fp()).unwrap();
    let prompt = prompt_from(&e.session, 3, 5);
    let b = e.session.manifest.serve_batch;
    let slot = e.kv.alloc(1, prompt.len()).unwrap();
    let mut last = e.prefill(slot, &prompt).unwrap();
    // warm one step (resident invariants upload once here)
    let mut feed = vec![PAD; b];
    feed[slot] = last;
    last = e.decode_step(&feed).unwrap()[slot];
    e.kv.push_token(slot);

    let steps = 4u64;
    let before = transfer::snapshot();
    for _ in 0..steps {
        let mut feed = vec![PAD; b];
        feed[slot] = last;
        last = e.decode_step(&feed).unwrap()[slot];
        e.kv.push_token(slot);
    }
    let d = transfer::snapshot().delta_since(&before);
    let per_step = (d.bytes_uploaded + d.bytes_fetched) / steps;
    assert!(
        per_step <= 64 * 1024,
        "decode step moves {per_step} B/step hermetically (budget 64 KiB)"
    );
}

#[test]
fn greedy_search_and_quantized_eval_run_hermetically() {
    // the full CushionCache flow on the interpreter: calibrate ->
    // quantized eval -> greedy search (eq. 10 early stop) -> install
    // cushion -> recalibrate -> eval again. No artifacts anywhere.
    let w8a8 = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    let mut s = tiny_session();
    calibrate::calibrate_into(&mut s, w8a8.act_levels(), 2).unwrap();
    let before = perplexity(&s, &w8a8, "heldout", 2).unwrap();
    assert!(before.is_finite() && before > 1.0, "ppl {before}");

    let cfg = SearchCfg {
        max_len: 3,
        vocab_stride: 1,
        ..Default::default()
    };
    let res = cushion::greedy_search(&s, &cfg).unwrap();
    assert!(!res.prefix.is_empty() && res.prefix.len() <= 3);
    assert!(res.candidates_scored > 0);
    assert!(res.lq_trace.iter().all(|lq| lq.is_finite()));

    s.set_cushion_tokens(&res.prefix).unwrap();
    assert_eq!(s.prefix_len(), res.prefix.len() as i32);
    calibrate::calibrate_into(&mut s, w8a8.act_levels(), 2).unwrap();
    let after = perplexity(&s, &w8a8, "heldout", 2).unwrap();
    assert!(after.is_finite() && after > 1.0, "ppl {after}");
}

// ---------------------------------------------------------------------------
// Replica fault domains: whole-replica chaos kills
// ---------------------------------------------------------------------------

/// A tiny session on the fault-injecting backend, with an optional
/// undersized pool (blocks > 0) and the two-token cushion installed when
/// `cushion` — the preemption-heavy shape the replica-kill tests need.
fn faulty_session_cfg(blocks: usize, cushion: bool) -> Session {
    let cfg = TinyCfg { kv_pool_blocks: blocks, ..TinyCfg::default() };
    let mut s = cfg
        .session_with_client(Client::with_backend(Rc::new(FaultyBackend::wrap(
            Rc::new(RefBackend),
        ))))
        .unwrap();
    if cushion {
        s.set_cushion_tokens(&[cushioncache::data::BOS, cushioncache::data::DOT])
            .unwrap();
    }
    s
}

/// `n` same-weights fp replicas behind one router (seeded breakers).
fn fp_replica_router(n: usize, blocks: usize, cushion: bool) -> Router {
    let mut r = Router::with_seed(0xC4A05);
    for _ in 0..n {
        let s = faulty_session_cfg(blocks, cushion);
        r.add_engine("fp", Scheduler::new(Engine::new(s, Scheme::fp()).unwrap()));
    }
    r
}

/// Fault-free single-engine oracle: id -> token stream for the given
/// workload. fp decode is deterministic and per-sequence independent, so
/// a request's stream depends only on its prompt — which replica serves
/// it (or re-serves it after a failover re-prefill) must not matter.
fn baseline_streams(
    blocks: usize,
    cushion: bool,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> std::collections::HashMap<u64, Vec<i32>> {
    let cfg = TinyCfg { kv_pool_blocks: blocks, ..TinyCfg::default() };
    let mut s = cfg.session().unwrap();
    if cushion {
        s.set_cushion_tokens(&[cushioncache::data::BOS, cushioncache::data::DOT])
            .unwrap();
    }
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    for (i, p) in prompts.iter().enumerate() {
        let mut r = Request::new(1 + i as u64, p.clone(), max_new);
        r.stop_token = None;
        sched.submit_request(r);
    }
    let resp = sched.run_to_completion().unwrap();
    assert!(resp.iter().all(|r| r.finished == FinishReason::MaxTokens));
    resp.into_iter().map(|r| (r.id, r.tokens)).collect()
}

fn submit_router(r: &mut Router, prompts: &[Vec<i32>], max_new: usize) {
    for (i, p) in prompts.iter().enumerate() {
        let mut req = Request::new(1 + i as u64, p.clone(), max_new);
        req.stop_token = None;
        r.route("fp", req).unwrap();
    }
}

#[test]
fn chaos_replica_kill_mid_prefill_fails_over_bit_identically() {
    // replica 0 dies on its very first engine call — the prefill of its
    // first admitted request. Nothing has run there yet, so the whole
    // assignment migrates as fresh requests and replica 1 serves the
    // entire batch exactly as the fault-free oracle does.
    let mut r = fp_replica_router(2, 0, false);
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| prompt_from(&r.replica(0).engine.session, i, 6))
        .collect();
    let want = baseline_streams(0, false, &prompts, 6);
    submit_router(&mut r, &prompts, 6);
    faults::arm(FaultPlan::parse("seed=11,replica=0,kill_replica_after=1").unwrap());
    let mut resp = r.run_to_completion().unwrap();
    faults::disarm();
    resp.sort_by_key(|x| x.id);
    assert_eq!(resp.len(), 4, "every routed request must come back");
    for x in &resp {
        assert_eq!(x.finished, FinishReason::MaxTokens, "id {}: {:?}", x.id, x.finished);
        assert_eq!(x.tokens, want[&x.id], "id {}: diverged after failover", x.id);
    }
    let m = &r.replica(0).metrics;
    assert_eq!(m.breaker_opens, 1, "one breaker open on the killed replica");
    assert_eq!(m.failovers, 1);
    assert!(m.migrated_sequences >= 1, "the kill must migrate its queue");
    assert_eq!(r.pending_assignments(), 0);
}

#[test]
fn chaos_replica_kill_mid_decode_fails_over_bit_identically() {
    // let both replicas prefill and decode a few steps, then kill
    // replica 0 on its next engine call: its running sequences carry
    // generated tokens, so the migration must re-prefill
    // `prompt ++ generated` on replica 1 and continue bit-identically.
    let mut r = fp_replica_router(2, 0, false);
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| prompt_from(&r.replica(0).engine.session, i, 6))
        .collect();
    let want = baseline_streams(0, false, &prompts, 6);
    submit_router(&mut r, &prompts, 6);
    let mut resp = Vec::new();
    for _ in 0..3 {
        resp.extend(r.step_all().unwrap());
    }
    assert!(r.replica(0).running_count() > 0, "replica 0 must be mid-decode");
    faults::arm(FaultPlan::parse("seed=12,replica=0,kill_replica_after=1").unwrap());
    while r.has_work() {
        resp.extend(r.step_all().unwrap());
    }
    faults::disarm();
    resp.sort_by_key(|x| x.id);
    assert_eq!(resp.len(), 4);
    for x in &resp {
        assert_eq!(x.finished, FinishReason::MaxTokens, "id {}: {:?}", x.id, x.finished);
        assert_eq!(x.tokens, want[&x.id], "id {}: diverged after failover", x.id);
    }
    let m = &r.replica(0).metrics;
    assert_eq!(m.failovers, 1);
    assert!(
        m.reprefill_tokens > 2 * 6,
        "mid-decode migration must re-prefill generated tokens too \
         (got {} over 2 prompts of 6)",
        m.reprefill_tokens
    );
    assert_eq!(r.pending_assignments(), 0);
}

#[test]
fn chaos_replica_kill_while_preempted_migrates_the_resume_queue() {
    // undersized pool + cushion forces preemption; once replica 0 holds
    // a preempted (resumable) sequence, kill it: the resume queue must
    // migrate — donated prefix-cache holds settled exactly once on the
    // dead pool — and the batch still finishes bit-identically.
    let mut r = fp_replica_router(2, 6, true);
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| prompt_from(&r.replica(0).engine.session, i, 6))
        .collect();
    let want = baseline_streams(6, true, &prompts, 6);
    let base: Vec<usize> = (0..2)
        .map(|i| r.replica(i).engine.kv.blocks_in_use())
        .collect();
    submit_router(&mut r, &prompts, 6);
    let mut resp = Vec::new();
    let mut guard = 0;
    while r.replica(0).batcher.resume_count() == 0 {
        resp.extend(r.step_all().unwrap());
        guard += 1;
        assert!(guard < 300, "workload never left a preempted sequence queued");
        assert!(r.has_work(), "finished before any preemption on replica 0");
    }
    faults::arm(FaultPlan::parse("seed=13,replica=0,kill_replica_after=1").unwrap());
    while r.has_work() {
        resp.extend(r.step_all().unwrap());
    }
    faults::disarm();
    resp.sort_by_key(|x| x.id);
    assert_eq!(resp.len(), 8);
    for x in &resp {
        assert_eq!(x.finished, FinishReason::MaxTokens, "id {}: {:?}", x.id, x.finished);
        assert_eq!(x.tokens, want[&x.id], "id {}: diverged after failover", x.id);
    }
    assert_eq!(r.replica(0).metrics.failovers, 1);
    // both pools fully settled: the dead replica's donated holds were
    // dropped exactly once by evacuation, the survivor's by completion
    for i in 0..2 {
        r.replica_mut(i).engine.kv.clear_prefix_cache();
        assert_eq!(
            r.replica(i).engine.kv.blocks_in_use(),
            base[i],
            "replica {i}: leaked blocks after failover"
        );
        assert_eq!(
            r.replica(i).engine.kv.free_count(),
            r.replica(i).engine.kv.n_slots,
            "replica {i}: leaked lanes after failover"
        );
    }
    assert_eq!(r.pending_assignments(), 0);
}

#[test]
fn chaos_replicas_all_dead_shed_honestly() {
    // an unselective kill (no replica= key) latches on the first engine
    // call and fails every replica's calls from then on: both break in
    // the same pass, the second failover finds no routable sibling, and
    // every request comes back as an honest "overloaded" error — none
    // lost, none silently dropped, and new routes are refused the same
    // way.
    let mut r = fp_replica_router(2, 0, false);
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| prompt_from(&r.replica(0).engine.session, i, 6))
        .collect();
    submit_router(&mut r, &prompts, 6);
    faults::arm(FaultPlan::parse("seed=14,kill_replica_after=1").unwrap());
    let mut resp = r.run_to_completion().unwrap();
    faults::disarm();
    resp.sort_by_key(|x| x.id);
    assert_eq!(resp.len(), 4, "shed requests must still be answered");
    for x in &resp {
        assert_eq!(
            x.finished,
            FinishReason::Error("overloaded".into()),
            "id {}: {:?}",
            x.id,
            x.finished
        );
    }
    assert_eq!(r.replica_health(0), Health::Broken);
    assert_eq!(r.replica_health(1), Health::Broken);
    let shed: usize = (0..2).map(|i| r.replica(i).metrics.shed_requests).sum();
    assert_eq!(shed, 4);
    // and the front door says the same thing
    let mut late = Request::new(99, prompts[0].clone(), 2);
    late.stop_token = None;
    let err = r.route("fp", late).unwrap_err().to_string();
    assert!(err.contains("overloaded"), "honest refusal: {err}");
    assert_eq!(r.pending_assignments(), 0);
}

// ---------------------------------------------------------------------------
// Observability: trace export, activation-health gauges, admin commands
// ---------------------------------------------------------------------------

#[test]
fn chaos_trace_export_records_the_request_lifecycle() {
    // fixed-seed chaos serve (undersized pool -> preemption, plus one
    // injected replica kill -> failover) with the tracer on: the
    // exported Chrome trace must validate and contain the request
    // lifecycle in order — admit -> prefill chunks -> preempt -> resume
    // -> failover -> finish — with every span closed. Honors
    // CUSHION_TRACE_EXPORT=<file> so scripts/test_hermetic.sh can gate
    // the export through `cushiond trace-check`.
    use cushioncache::runtime::trace;

    let mut r = fp_replica_router(2, 6, true);
    for i in 0..2 {
        r.replica_mut(i).set_prefill_chunk(Some(3));
        r.replica_mut(i).set_act_sample(4);
    }
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| prompt_from(&r.replica(0).engine.session, i, 6))
        .collect();
    trace::enable(0);
    submit_router(&mut r, &prompts, 6);
    let mut resp = Vec::new();
    let mut guard = 0;
    while r.replica(0).batcher.resume_count() == 0 {
        resp.extend(r.step_all().unwrap());
        guard += 1;
        assert!(guard < 300, "workload never left a preempted sequence queued");
        assert!(r.has_work(), "finished before any preemption on replica 0");
    }
    faults::arm(FaultPlan::parse("seed=13,replica=0,kill_replica_after=1").unwrap());
    while r.has_work() {
        resp.extend(r.step_all().unwrap());
    }
    faults::disarm();
    assert_eq!(resp.len(), 8, "every routed request must come back");
    assert!(resp.iter().all(|x| x.finished == FinishReason::MaxTokens));

    assert_eq!(trace::open_spans(), 0, "every span must close");
    let mut records = trace::records();
    records.sort_by_key(|x| x.seq);
    let text = trace::export_string();
    let n = trace::check_export(&text).unwrap();
    assert_eq!(n, records.len(), "export must carry every surviving record");
    trace::disable();
    if let Ok(path) = std::env::var("CUSHION_TRACE_EXPORT") {
        if !path.is_empty() {
            std::fs::write(&path, &text).unwrap();
        }
    }

    let first = |name: &str| -> u64 {
        records
            .iter()
            .find(|x| x.name == name)
            .unwrap_or_else(|| panic!("no '{name}' event in trace"))
            .seq
    };
    let admit = first("admit");
    let chunk = first("prefill_chunk");
    let preempt = first("preempt");
    let resume = first("resume");
    let failover = first("failover");
    let finish_last = records
        .iter()
        .filter(|x| x.name == "finish")
        .map(|x| x.seq)
        .max()
        .expect("no 'finish' event in trace");
    assert!(admit < chunk, "admit {admit} must precede prefill chunk {chunk}");
    assert!(chunk < preempt, "chunk {chunk} must precede preempt {preempt}");
    assert!(preempt < resume, "preempt {preempt} must precede resume {resume}");
    assert!(preempt < failover, "kill armed after the preemption was observed");
    assert!(
        failover < finish_last,
        "migrated work must finish after the failover event"
    );

    // every prefill span carries its request's trace id, and the ids
    // are exactly the submitted ones
    let ids: std::collections::HashSet<u64> = (1..=8).collect();
    for rec in records
        .iter()
        .filter(|x| x.name == "prefill" || x.name == "prefill_chunk")
    {
        assert_eq!(rec.ph, trace::Phase::Complete, "{}: unclosed span", rec.name);
        let id = rec.trace_id.unwrap_or_else(|| {
            panic!("span '{}' (seq {}) has no trace id", rec.name, rec.seq)
        });
        assert!(ids.contains(&id), "span trace id {id} was never submitted");
    }
    // decode under act_sample=4 must have metered at least one step
    assert!(
        records.iter().any(|x| x.name == "act_sample"),
        "no act_sample instants despite act_sample=4"
    );
}

#[test]
fn act_gauges_separate_cushioned_from_uncushioned_pts_serving() {
    // the paper's loop, closed at serve time: calibrate pts ranges WITH
    // the cushion in place, then serve with and without it over the
    // same ranges. Dropping the cushion shifts the activation
    // distribution out of the calibrated envelope, so the absmax /
    // clip-rate gauges must separate the two runs — a missing cushion
    // is visible as an outlier alarm, not a silent quality loss.
    let pts = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    let cushion_toks = [cushioncache::data::BOS, cushioncache::data::DOT];

    let mut calib = tiny_session();
    calib.set_cushion_tokens(&cushion_toks).unwrap();
    calibrate::calibrate_into(&mut calib, pts.act_levels(), 2).unwrap();
    let ranges = calib.ranges().clone();

    let run = |cushion: bool| -> (usize, f32, f64) {
        let mut s = tiny_session();
        if cushion {
            s.set_cushion_tokens(&cushion_toks).unwrap();
        }
        s.set_ranges(ranges.clone());
        let mut sched = Scheduler::new(Engine::new(s, pts).unwrap());
        sched.set_act_sample(1); // meter every decode step
        for i in 0..3 {
            let p = prompt_from(&sched.engine.session, i, 6);
            let mut req = Request::new(1 + i as u64, p, 4);
            req.stop_token = None;
            sched.submit_request(req);
        }
        let resp = sched.run_to_completion().unwrap();
        assert!(resp.iter().all(|x| x.finished == FinishReason::MaxTokens));
        (
            sched.metrics.act_samples,
            sched.metrics.act_absmax_peak,
            sched.metrics.act_clip_rate(),
        )
    };
    let (n_c, absmax_c, clip_c) = run(true);
    let (n_u, absmax_u, clip_u) = run(false);
    assert!(n_c > 0 && n_u > 0, "act sampling must fire in both runs");
    assert!(absmax_c > 0.0 && absmax_u > 0.0, "absmax gauges must populate");
    assert!(
        clip_u >= clip_c,
        "stale-ranges serving must not clip less than matched serving \
         (uncushioned {clip_u} vs cushioned {clip_c})"
    );
    assert!(
        (absmax_u, clip_u) != (absmax_c, clip_c),
        "gauges must separate cushioned from uncushioned serving \
         (absmax {absmax_c} clip {clip_c})"
    );
}

#[test]
fn tcp_server_answers_admin_metrics_and_trace_mid_run() {
    let engine = Engine::new(tiny_session(), Scheme::fp()).unwrap();
    let sched = Scheduler::new(engine);
    let addr = "127.0.0.1:7394";
    let server = cushioncache::coordinator::server::Server::new(addr);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));

    let handle = std::thread::spawn(move || {
        let mut conn = None;
        for _ in 0..100 {
            if let Ok(c) = std::net::TcpStream::connect(addr) {
                conn = Some(c);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        let mut conn = conn.expect("server did not bind");
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut read = |line: &mut String| {
            line.clear();
            reader.read_line(line).unwrap();
            json::parse(line.trim()).unwrap()
        };

        // one request through, so the gauges have something to show
        let req = concat!(
            r#"{"prompt": [0, 10, 11], "max_new": 2, "#,
            r#""stream": false, "stop_token": null}"#
        );
        writeln!(conn, "{req}").unwrap();
        let done = read(&mut line);
        assert_eq!(done.req_str("finish").unwrap(), "max_tokens");

        // {"cmd":"metrics"}: live Prometheus gauges over the wire
        writeln!(conn, r#"{{"cmd": "metrics"}}"#).unwrap();
        let v = read(&mut line);
        assert_eq!(v.req_str("format").unwrap(), "prometheus");
        let body = v.req_str("body").unwrap().to_string();
        let samples =
            cushioncache::coordinator::telemetry::parse_prometheus(&body)
                .unwrap();
        let completed = cushioncache::coordinator::telemetry::find_sample(
            &samples,
            "cushion_requests_completed",
            &[("replica", "0")],
        );
        assert_eq!(completed, Some(1.0), "one finished request must show");
        let toks = cushioncache::coordinator::telemetry::find_sample(
            &samples,
            "cushion_tokens_out",
            &[],
        );
        assert_eq!(toks, Some(2.0));

        // {"cmd":"trace"}: a valid (possibly empty) Chrome trace object
        writeln!(conn, r#"{{"cmd": "trace"}}"#).unwrap();
        let v = read(&mut line);
        assert!(
            v.get("trace")
                .and_then(|t| t.get("traceEvents"))
                .and_then(|e| e.as_arr())
                .is_some(),
            "trace reply must carry a traceEvents array: {line}"
        );

        // unknown admin commands get an error line, not a hang
        writeln!(conn, r#"{{"cmd": "nope"}}"#).unwrap();
        let v = read(&mut line);
        assert!(v.get("error").is_some(), "unknown cmd must error: {line}");

        writeln!(conn, "quit").unwrap();
    });

    server.serve(sched, stop).unwrap();
    handle.join().unwrap();
}

#[test]
fn tcp_server_streams_hermetically() {
    let engine = Engine::new(tiny_session(), Scheme::fp()).unwrap();
    let sched = Scheduler::new(engine);
    let addr = "127.0.0.1:7393";
    let server = cushioncache::coordinator::server::Server::new(addr);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));

    let handle = std::thread::spawn(move || {
        let mut conn = None;
        for _ in 0..100 {
            if let Ok(c) = std::net::TcpStream::connect(addr) {
                conn = Some(c);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        let mut conn = conn.expect("server did not bind");
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut read = |line: &mut String| {
            line.clear();
            reader.read_line(line).unwrap();
            json::parse(line.trim()).unwrap()
        };

        // malformed JSON: error line, loop survives
        writeln!(conn, "not json at all").unwrap();
        let v = read(&mut line);
        assert!(v.get("error").is_some(), "no error field: {line}");

        // a valid streaming request completes token-by-token
        let req = concat!(
            r#"{"prompt": [0, 10, 11], "max_new": 3, "#,
            r#""stream": true, "stop_token": null}"#
        );
        writeln!(conn, "{req}").unwrap();
        let mut streamed = Vec::new();
        let summary = loop {
            let v = read(&mut line);
            if v.get("finish").is_some() {
                break v;
            }
            streamed.push(v.req_usize("token").unwrap() as i32);
            assert_eq!(v.req_usize("index").unwrap(), streamed.len() - 1);
        };
        assert_eq!(summary.req_str("finish").unwrap(), "max_tokens");
        let toks: Vec<i32> = summary
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(streamed, toks, "stream lines must precede the summary");
        assert_eq!(toks.len(), 3);

        writeln!(conn, "quit").unwrap();
    });

    server.serve(sched, stop).unwrap();
    handle.join().unwrap();
}
