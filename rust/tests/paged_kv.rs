//! Hermetic integration tests for the paged KV pool (coordinator::
//! kvpool) on the reference backend — no artifacts, no XLA:
//!
//! * the cushion prefix KV lives in exactly one shared block run: N
//!   concurrent requests use fewer blocks than N x (cushion blocks +
//!   prompt blocks), and identical prompts share full prompt blocks via
//!   the prefix cache (COW keeps shared contents intact at divergence);
//! * paged decode output is token-identical across the device-resident
//!   and host-roundtrip residency modes, and the native block-table
//!   path (`*_paged_*` graphs) matches the contiguous gather-view path
//!   token-for-token while the mirrored pool reproduces the contiguous
//!   cache bit-for-bit;
//! * a workload whose aggregate block demand exceeds the pool completes
//!   via preemption/resume with outputs identical to an ample-pool run
//!   (no rejection, no starvation);
//! * the admission off-by-one is fixed: a prompt of exactly
//!   `cap - m_max` tokens is served its prefill token and finished with
//!   `Length` instead of tripping capacity asserts downstream;
//! * the preemption victim filter skips a sequence already sitting at
//!   the `seq_len` boundary — preempting it would trade one decode step
//!   for a full-window re-prefill.

use cushioncache::coordinator::{Engine, FinishReason, Request, Scheduler};
use cushioncache::data::PAD;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::testkit::tiny::TinyCfg;

fn session_with_cushion(cfg: &TinyCfg) -> cushioncache::model::session::Session {
    let mut s = cfg.session().unwrap();
    s.set_cushion_tokens(&[cushioncache::data::BOS, cushioncache::data::DOT])
        .unwrap();
    s
}

fn prompt_from(s: &cushioncache::model::session::Session, seq: usize, len: usize) -> Vec<i32> {
    s.corpus.split("heldout").unwrap().seq(seq)[..len].to_vec()
}

fn submit_all(sched: &mut Scheduler, prompts: &[Vec<i32>], max_new: usize) {
    for (i, p) in prompts.iter().enumerate() {
        let mut r = Request::new(1 + i as u64, p.clone(), max_new);
        r.stop_token = None;
        sched.submit_request(r);
    }
}

#[test]
fn cushion_prefix_is_stored_once_and_shared() {
    // tiny geometry: m_max 4, block size 4 (auto: min(16, m_max)), cap 20
    // -> 1 full cushion block, 4 token blocks per full lane
    let cfg = TinyCfg::default();
    let s = session_with_cushion(&cfg);
    let n = s.manifest.serve_batch;
    let prompts: Vec<Vec<i32>> = (0..n).map(|i| prompt_from(&s, i, 6)).collect();
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    submit_all(&mut sched, &prompts, 6);
    sched.step().unwrap(); // admit everyone + first decode
    assert_eq!(sched.running_count(), n);

    let kv = &sched.engine.kv;
    assert_eq!(kv.cushion_run().len(), 1, "m_max/bs = one shared block");
    assert_eq!(kv.full_cushion_blocks(), 1, "no boundary template at 4/4");
    let tables: Vec<Vec<usize>> =
        (0..n).map(|l| kv.table(l).unwrap().to_vec()).collect();
    for t in &tables[1..] {
        assert_eq!(
            t[0], tables[0][0],
            "every table must point at the one cushion block run"
        );
    }
    assert_eq!(tables[0][0], kv.cushion_run()[0]);

    // the acceptance inequality: shared storage beats per-slot broadcast
    let per_seq_blocks = tables[0].len(); // cushion + prompt blocks
    let stats = kv.pool_stats();
    assert!(
        stats.in_use < n * per_seq_blocks,
        "{} blocks in use, per-slot broadcast would need {}",
        stats.in_use,
        n * per_seq_blocks
    );
    assert!(stats.shared >= 1, "cushion block must count as shared");
    assert!(stats.saved >= n - 1, "sharing saved {} allocations", stats.saved);
    assert_eq!(sched.metrics.pool_blocks_total, kv.total_blocks());
    assert!(sched.metrics.pool_blocks_peak >= stats.in_use);

    let responses = sched.run_to_completion().unwrap();
    assert_eq!(responses.len(), n);
    assert!(responses.iter().all(|r| r.finished == FinishReason::MaxTokens));
}

#[test]
fn identical_prompts_share_prompt_blocks_with_cow_on_divergence() {
    let cfg = TinyCfg::default();
    let s = session_with_cushion(&cfg);
    let shared_prompt = prompt_from(&s, 0, 6); // blocks: [cushion][full][tail]
    let mut engine = Engine::new(s, Scheme::fp()).unwrap();
    engine.set_host_roundtrip(true); // mirror KV into the pool

    let a = engine.kv.alloc_with_prompt(1, &shared_prompt).unwrap();
    engine.prefill(a, &shared_prompt).unwrap(); // publishes full blocks
    assert!(engine.kv.prefix_cache_len() >= 1);
    let ta = engine.kv.table(a).unwrap().to_vec();

    let b = engine.kv.alloc_with_prompt(2, &shared_prompt).unwrap();
    let tb = engine.kv.table(b).unwrap().to_vec();
    assert_eq!(ta[1], tb[1], "identical prompt head shares the full block");
    assert_ne!(ta[2], tb[2], "partial tail is copy-on-write private");

    // prefilling the sharer must not corrupt the shared block: contents
    // are recomputed identically and shared blocks are never rewritten
    let before = engine.cache_host().unwrap();
    engine.prefill(b, &shared_prompt).unwrap();
    let after = engine.cache_host().unwrap();
    let view = engine.kv.gather_view();
    // lane a's whole mapped region is untouched by b's prefill
    let m = engine.kv.m_max;
    let tok = engine.kv.tok_len(a);
    assert_lane_eq(&before, &after, a, m + tok);
    assert_lane_eq(&view, &after, a, m + tok);

    // divergent prompt: shares nothing past the divergence point
    engine.kv.free(b);
    let mut diverged = shared_prompt.clone();
    diverged[2] = (diverged[2] + 1) % engine.session.manifest.vocab as i32;
    let c = engine.kv.alloc_with_prompt(3, &diverged).unwrap();
    assert_ne!(engine.kv.table(c).unwrap()[1], ta[1], "COW at first divergence");
}

/// Compare one lane of two [L, 2, B, Hkv, CAP, dh] caches over
/// positions [0, upto).
fn assert_lane_eq(x: &cushioncache::util::tensor::Tensor,
                  y: &cushioncache::util::tensor::Tensor, lane: usize,
                  upto: usize) {
    assert_eq!(x.shape, y.shape);
    let (l, b, hkv, cap, dh) =
        (x.shape[0], x.shape[2], x.shape[3], x.shape[4], x.shape[5]);
    for li in 0..l {
        for w in 0..2 {
            for h in 0..hkv {
                for p in 0..upto {
                    let i = (((((li * 2 + w) * b) + lane) * hkv + h) * cap + p) * dh;
                    assert_eq!(
                        x.data[i..i + dh],
                        y.data[i..i + dh],
                        "lane {lane} diverges at (l={li}, w={w}, h={h}, p={p})"
                    );
                }
            }
        }
    }
}

/// Drive one engine over `prompts` (full occupancy) for `steps` decode
/// steps; returns each lane's token stream.
fn generate_batch(engine: &mut Engine, prompts: &[Vec<i32>], steps: usize) -> Vec<Vec<i32>> {
    let b = engine.session.manifest.serve_batch;
    assert_eq!(prompts.len(), b, "full occupancy required");
    let mut slots = Vec::new();
    let mut streams: Vec<Vec<i32>> = vec![Vec::new(); b];
    for (i, p) in prompts.iter().enumerate() {
        let slot = engine.kv.alloc_with_prompt(1 + i as u64, p).unwrap();
        let first = engine.prefill(slot, p).unwrap();
        streams[i].push(first);
        slots.push(slot);
    }
    for _ in 0..steps {
        let mut feed = vec![PAD; b];
        for (i, &slot) in slots.iter().enumerate() {
            feed[slot] = *streams[i].last().unwrap();
        }
        let next = engine.decode_step(&feed).unwrap();
        for (i, &slot) in slots.iter().enumerate() {
            engine.kv.push_token(slot);
            streams[i].push(next[slot]);
        }
    }
    streams
}

#[test]
fn decode_is_token_identical_across_residency_and_paged_modes() {
    // device-resident gather view (default), host-roundtrip mirror, and
    // the native block-table path must agree token-for-token — in fp
    // and in a statically quantized mode
    for scheme in [
        Scheme::fp(),
        Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive),
    ] {
        let cfg = TinyCfg::default();
        let run = |mode: &str| -> Vec<Vec<i32>> {
            let s = session_with_cushion(&cfg);
            let prompts: Vec<Vec<i32>> = (0..s.manifest.serve_batch)
                .map(|i| prompt_from(&s, i, 5))
                .collect();
            let mut e = Engine::new(s, scheme.clone()).unwrap();
            match mode {
                "device" => {}
                "host" => e.set_host_roundtrip(true),
                "paged" => e.set_paged_attention(true),
                _ => unreachable!(),
            }
            generate_batch(&mut e, &prompts, 6)
        };
        let device = run("device");
        let host = run("host");
        let paged = run("paged");
        assert_eq!(device, host, "{}: residency parity", scheme.label());
        assert_eq!(device, paged, "{}: native paged parity", scheme.label());
    }
}

#[test]
fn mirrored_pool_reproduces_the_contiguous_cache() {
    // gather view vs native path cross-check at the *bit* level: after
    // identical workloads, the mirrored pool (host-roundtrip mode) and
    // the natively-written pool (paged mode) both gather back into the
    // contiguous cache the arena path produced.
    let cfg = TinyCfg::default();
    let drive = |mode: &str| -> (Engine, Vec<usize>) {
        let s = session_with_cushion(&cfg);
        let prompts: Vec<Vec<i32>> = (0..s.manifest.serve_batch)
            .map(|i| prompt_from(&s, i, 5))
            .collect();
        let mut e = Engine::new(s, Scheme::fp()).unwrap();
        match mode {
            "host" => e.set_host_roundtrip(true),
            "paged" => e.set_paged_attention(true),
            _ => unreachable!(),
        }
        generate_batch(&mut e, &prompts, 4);
        let lens: Vec<usize> = (0..e.kv.n_slots)
            .map(|s| e.kv.m_max + e.kv.tok_len(s))
            .collect();
        (e, lens)
    };
    let (host_engine, lens) = drive("host");
    let (paged_engine, lens2) = drive("paged");
    assert_eq!(lens, lens2);

    let arena = host_engine.cache_host().unwrap(); // contiguous truth
    let mirrored = host_engine.kv.gather_view(); // pool mirror
    let native = paged_engine.kv.gather_view(); // natively-written pool
    for lane in 0..host_engine.kv.n_slots {
        assert_lane_eq(&arena, &mirrored, lane, lens[lane]);
        assert_lane_eq(&arena, &native, lane, lens[lane]);
    }
}

#[test]
fn oversubscribed_pool_completes_via_preemption() {
    // pool of 6 blocks; two lanes at prompt 6 / max_new 8 eventually
    // need 1 + 2 x 4 = 9 -> the pool runs dry mid-decode and the
    // scheduler must preempt + resume, never reject or starve
    let small = TinyCfg { kv_pool_blocks: 6, ..TinyCfg::default() };
    let ample = TinyCfg::default();
    let run = |cfg: &TinyCfg| -> (Vec<(u64, Vec<i32>)>, usize, usize) {
        let s = session_with_cushion(cfg);
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt_from(&s, i, 6)).collect();
        let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
        submit_all(&mut sched, &prompts, 8);
        let mut out: Vec<(u64, Vec<i32>)> = sched
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| {
                assert_eq!(
                    r.finished,
                    FinishReason::MaxTokens,
                    "request {} must complete normally",
                    r.id
                );
                (r.id, r.tokens)
            })
            .collect();
        out.sort_by_key(|(id, _)| *id);
        (out, sched.metrics.preempted, sched.metrics.errored)
    };
    let (small_out, preempted, errored) = run(&small);
    let (ample_out, ample_preempted, _) = run(&ample);
    assert_eq!(errored, 0, "no request may be rejected");
    assert!(preempted > 0, "the small pool must force preemption");
    assert_eq!(ample_preempted, 0, "the ample pool must not preempt");
    assert_eq!(small_out.len(), 4);
    assert_eq!(
        small_out, ample_out,
        "preemption/resume must not change any request's tokens"
    );
}

#[test]
fn cancel_of_preempted_request_releases_donated_blocks_exactly_once() {
    // same oversubscribed geometry as above: the 6-block pool forces a
    // preemption, which frees the victim's lane and donates its full
    // blocks to the prefix cache. Cancelling the victim while it sits
    // in the resume queue must release those donations exactly once —
    // a leaked hold shows up as blocks_in_use above baseline after the
    // run, a double release panics inside the pool.
    let cfg = TinyCfg { kv_pool_blocks: 6, ..TinyCfg::default() };
    let s = session_with_cushion(&cfg);
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt_from(&s, i, 6)).collect();
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    let base = sched.engine.kv.blocks_in_use(); // the pinned cushion run
    submit_all(&mut sched, &prompts, 8);

    let mut guard = 0;
    while sched.batcher.resume_count() == 0 {
        sched.step().unwrap();
        guard += 1;
        assert!(guard < 200, "small pool never preempted");
    }

    // find the preempted request by probing: only its cancel shrinks
    // the resume queue (queued/running cancels leave it unchanged)
    let mut preempted_id = None;
    for id in 1..=4u64 {
        let before = sched.batcher.resume_count();
        if sched.cancel(id) && sched.batcher.resume_count() < before {
            preempted_id = Some(id);
            break;
        }
    }
    let preempted_id = preempted_id.expect("a preempted request must exist");
    assert!(
        !sched.cancel(preempted_id),
        "cancelling twice must be a no-op (blocks released exactly once)"
    );

    // survivors still complete; afterwards every lane is free and —
    // once the cache is flushed — only the pinned cushion remains
    for r in sched.run_to_completion().unwrap() {
        assert_eq!(r.finished, FinishReason::MaxTokens);
    }
    assert_eq!(sched.engine.kv.free_count(), sched.engine.kv.n_slots);
    sched.engine.kv.clear_prefix_cache();
    assert_eq!(
        sched.engine.kv.blocks_in_use(),
        base,
        "cancelled preempted request leaked block holds"
    );
}

#[test]
fn admission_edge_prompt_filling_the_cache_finishes_with_length() {
    // cap - m_max == seq_len for the tiny model: a prompt that exactly
    // fills the per-sequence KV space is served its prefill token and
    // finished with Length (the old admission path admitted it and
    // relied on capacity asserts downstream)
    let cfg = TinyCfg::default();
    let s = session_with_cushion(&cfg);
    let full_len = s.manifest.cache_cap - s.manifest.m_max;
    let prompt = prompt_from(&s, 1, full_len);
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    let mut r = Request::new(1, prompt, 8);
    r.stop_token = None;
    sched.submit_request(r);
    let resp = sched.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(resp.finished, FinishReason::Length);
    assert_eq!(resp.tokens.len(), 1, "prefill token only — zero decode room");

    // one token shorter leaves exactly one decode step of room
    let cfg = TinyCfg::default();
    let s = session_with_cushion(&cfg);
    let prompt = prompt_from(&s, 1, full_len - 1);
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    let mut r = Request::new(1, prompt, 8);
    r.stop_token = None;
    sched.submit_request(r);
    let resp = sched.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(resp.finished, FinishReason::Length);
    assert_eq!(resp.tokens.len(), 2, "prefill token + one decode step");
}

#[test]
fn sequential_repeats_reuse_cached_prefix_blocks() {
    // router-demo / eval-sweep shape: the same prompt arrives again
    // after the first request completed — its full prompt blocks are
    // still cached (LRU) and get reused instead of reallocated
    let cfg = TinyCfg::default();
    let s = session_with_cushion(&cfg);
    let prompt = prompt_from(&s, 2, 6);
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    submit_all(&mut sched, std::slice::from_ref(&prompt), 4);
    sched.run_to_completion().unwrap();
    assert!(
        sched.engine.kv.prefix_cache_len() >= 1,
        "completed request must donate its full prompt blocks"
    );
    let cached = sched.engine.kv.blocks_in_use();
    submit_all(&mut sched, std::slice::from_ref(&prompt), 4);
    sched.step().unwrap();
    // the repeat reuses the cached full block: only the private tail
    // block is newly allocated
    assert_eq!(
        sched.engine.kv.blocks_in_use(),
        cached + 1,
        "repeat prompt must reuse the cached prefix block"
    );
    let resp = sched.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(resp.finished, FinishReason::MaxTokens);
}

#[test]
fn chaos_cancel_after_failover_releases_the_destination_pool() {
    // regression for the failover/cancel interaction: after a replica
    // kill migrates a running sequence, the router's assignment tracks
    // the *destination* replica — a cancel must release that pool's
    // lane and block refcounts (the dead source's holds were already
    // settled exactly once by evacuation). A cancel still routed to the
    // source would leak the survivor's blocks forever.
    use std::rc::Rc;

    use cushioncache::coordinator::{Health, Router};
    use cushioncache::runtime::backend::RefBackend;
    use cushioncache::runtime::{faults, Client, FaultPlan, FaultyBackend};

    let mk = || {
        let s = TinyCfg::default()
            .session_with_client(Client::with_backend(Rc::new(
                FaultyBackend::wrap(Rc::new(RefBackend)),
            )))
            .unwrap();
        Scheduler::new(Engine::new(s, Scheme::fp()).unwrap())
    };
    let mut r = Router::with_seed(0xCA9CE1);
    r.add_engine("fp", mk());
    r.add_engine("fp", mk());
    let base: Vec<usize> = (0..2)
        .map(|i| r.replica(i).engine.kv.blocks_in_use())
        .collect();
    // equal pools tie-break on load, so routing alternates: replica 0
    // gets ids 1 and 3 (long-running), replica 1 gets ids 2 and 4
    // (short, so its lanes free up for the migrated pair)
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| prompt_from(&r.replica(0).engine.session, i, 6))
        .collect();
    for (i, p) in prompts.iter().enumerate() {
        let max_new = if i % 2 == 0 { 8 } else { 3 };
        let mut req = Request::new(1 + i as u64, p.clone(), max_new);
        req.stop_token = None;
        r.route("fp", req).unwrap();
    }
    let mut resp = Vec::new();
    resp.extend(r.step_all().unwrap()); // everyone admitted and decoding
    assert_eq!(r.replica(0).running_count(), 2);
    faults::arm(FaultPlan::parse("seed=21,replica=0,kill_replica_after=1").unwrap());
    // step until the kill fires, ids 2/4 finish, and the migrated pair
    // (1 and 3) is re-prefilled into replica 1's lanes
    let mut guard = 0;
    while r.replica_health(0) != Health::Broken
        || r.replica(1).batcher.resume_count() > 0
        || r.replica(1).running_count() < 2
    {
        resp.extend(r.step_all().unwrap());
        guard += 1;
        assert!(guard < 100, "migrated sequences never re-admitted");
        assert!(r.has_work(), "drained before the migration landed");
    }
    faults::disarm();
    assert_eq!(r.replica(0).metrics.failovers, 1);
    // cancel one migrated id while it runs on the destination: its lane
    // and blocks must come back to *replica 1's* pool immediately
    let free_before = r.replica(1).engine.kv.free_count();
    let in_use_before = r.replica(1).engine.kv.blocks_in_use();
    assert!(r.cancel(1), "migrated request must be cancellable");
    assert_eq!(
        r.replica(1).engine.kv.free_count(),
        free_before + 1,
        "cancel must free the destination lane"
    );
    assert!(
        r.replica(1).engine.kv.blocks_in_use() < in_use_before,
        "cancel must release the destination's block refcounts"
    );
    assert!(!r.cancel(1), "double-cancel is a no-op");
    // drain the rest; every id answered exactly once, pools restored
    resp.extend(r.run_to_completion().unwrap());
    resp.sort_by_key(|x| x.id);
    let ids: Vec<u64> = resp.iter().map(|x| x.id).collect();
    assert_eq!(ids, vec![1, 2, 3, 4]);
    assert_eq!(resp[0].finished, FinishReason::Cancelled);
    assert_eq!(resp[2].finished, FinishReason::MaxTokens, "id 3 survives");
    for i in 0..2 {
        r.replica_mut(i).engine.kv.clear_prefix_cache();
        assert_eq!(
            r.replica(i).engine.kv.blocks_in_use(),
            base[i],
            "replica {i}: refcounts not restored after failover + cancel"
        );
        assert_eq!(
            r.replica(i).engine.kv.free_count(),
            r.replica(i).engine.kv.n_slots,
            "replica {i}: lanes not restored after failover + cancel"
        );
    }
    assert_eq!(r.pending_assignments(), 0);
}

#[test]
fn boundary_sequence_is_not_picked_as_preemption_victim() {
    // regression for the victim-filter off-by-one: a running sequence
    // with prompt + generated == seq_len would resume only to
    // re-prefill the *entire* window — the most expensive recompute the
    // engine can do — for tokens its very next decode step delivers
    // without any preemption. Geometry: pool of 9 blocks (1 pinned
    // cushion + 8), three lanes. A and C (prompt 6, 2 blocks each)
    // decode until their next KV write needs a third block; that same
    // step admits B (prompt 15, 4 blocks), which fills the pool and —
    // after its prefill token — sits exactly at the boundary. A's
    // growth then runs the pool dry: the old `<= seq_len` filter chose
    // B (the youngest), parking it for a 16-token re-prefill; the fixed
    // filter skips it, preempts C, and B finishes with `Length` in its
    // admission step.
    let cfg = TinyCfg { serve_batch: 3, kv_pool_blocks: 9, ..TinyCfg::default() };
    let s = session_with_cushion(&cfg);
    let seq_len = s.manifest.seq_len;
    let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
    let submit = |sched: &mut Scheduler, id: u64, prompt: Vec<i32>, max_new: usize| {
        let mut r = Request::new(id, prompt, max_new);
        r.stop_token = None;
        sched.submit_request(r);
    };
    // distinct prompts: prefix-cache sharing must not distort the math
    submit(&mut sched, 1, vec![1, 2, 3, 4, 5, 6], 8); // A (oldest)
    submit(&mut sched, 2, vec![7, 8, 9, 10, 11, 12], 8); // C
    sched.step().unwrap(); // prefill both + first decode
    sched.step().unwrap(); // second decode: lanes now hold 8 tokens
    assert_eq!(sched.running_count(), 2);
    assert_eq!(sched.metrics.preempted, 0, "no pool pressure yet");

    let b_prompt: Vec<i32> = (20..35).collect();
    assert_eq!(b_prompt.len() + 1, seq_len, "B lands exactly on the boundary");
    submit(&mut sched, 3, b_prompt, 4);
    sched.step().unwrap(); // B admitted (pool full), A's growth preempts
    let finished = sched.take_finished();
    let b = finished.iter().find(|r| r.id == 3).expect(
        "boundary sequence must not be the preemption victim — it \
         finishes with Length in its admission step",
    );
    assert_eq!(b.finished, FinishReason::Length);
    assert_eq!(b.tokens.len(), 2, "prefill token + the one decode step");
    assert_eq!(sched.metrics.preempted, 1, "pool pressure fell on C instead");

    // the preempted survivor resumes; everyone else completes normally
    let rest = sched.run_to_completion().unwrap();
    assert_eq!(rest.len(), 2);
    assert!(rest.iter().all(|r| r.finished == FinishReason::MaxTokens));
}
