//! Integration over runtime + artifacts: PJRT execution of the AOT
//! graphs must match the Python-side golden outputs, and the serving
//! path must agree with the eval forward.
//!
//! Each test owns its PJRT client (the client is Rc-based and cannot
//! cross the test harness's threads); they skip gracefully when
//! `make artifacts` has not run.

use cushioncache::coordinator::{Engine, FinishReason, Request, Router, Scheduler, ServeBackend};
use cushioncache::data::PAD;
use cushioncache::eval::perplexity::{argmax, perplexity};
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::Client;
use cushioncache::util::fsutil;
use cushioncache::util::json;

fn have_artifacts() -> bool {
    fsutil::variant_dir("tl-llama").join("manifest.json").exists()
}

fn session() -> Session {
    Session::load_with_client("tl-llama", Client::cpu().unwrap()).unwrap()
}

#[test]
fn fwd_fp_matches_python_golden() {
    if !have_artifacts() {
        return;
    }
    let s = session();
    let golden = json::parse(
        &std::fs::read_to_string(fsutil::variant_dir("tl-llama").join("golden.json"))
            .unwrap(),
    )
    .unwrap();

    let split = s.corpus.split("calib").unwrap();
    let m = &s.manifest;
    let mut tokens = Vec::new();
    for i in 0..m.eval_batch {
        tokens.extend_from_slice(split.seq(i));
    }
    let logits = s.fwd(&Scheme::fp(), &tokens).unwrap();

    // probe logits
    let probes = golden.get("logits_probe").unwrap().as_arr().unwrap();
    let v = m.vocab;
    let got = [
        logits.data[0],
        logits.data[v + 1], // [batch 0, pos 1, vocab 1]
        *logits.data.last().unwrap(),
        logits.data.iter().map(|&x| x as f64).sum::<f64>() as f32
            / logits.data.len() as f32,
    ];
    for (g, want) in got.iter().zip(probes) {
        let w = want.as_f64().unwrap() as f32;
        assert!(
            (g - w).abs() < 1e-2_f32.max(w.abs() * 1e-3),
            "logit probe mismatch: {g} vs {w}"
        );
    }

    // perplexity over the same batch
    let want_ppl = golden.get("fp_ppl_calib8").unwrap().as_f64().unwrap();
    let (nll, n) = cushioncache::eval::perplexity::batch_nll(
        &logits.data, &tokens, m.eval_batch, m.seq_len, v);
    let got_ppl = (nll / n as f64).exp();
    assert!(
        (got_ppl - want_ppl).abs() / want_ppl < 1e-3,
        "ppl {got_ppl} vs golden {want_ppl}"
    );

    // minmax of site 0 (via the stats graph — fwd graphs emit logits only)
    let stats = s.stats(&tokens).unwrap();
    let mm = golden.get("minmax_site0").unwrap().as_arr().unwrap();
    assert!((stats.minmax.at2(0, 0) - mm[0].as_f64().unwrap() as f32).abs() < 1e-3);
    assert!((stats.minmax.at2(0, 1) - mm[1].as_f64().unwrap() as f32).abs() < 1e-3);
}

#[test]
fn cushion_rescues_per_tensor_static() {
    if !have_artifacts() {
        return;
    }
    // The paper's headline claim, end to end through the runtime.
    let mut s = session();
    let w8a8 = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    let fp = perplexity(&s, &Scheme::fp(), "heldout", 2).unwrap();
    calibrate::calibrate_into(&mut s, w8a8.act_levels(), 2).unwrap();
    let broken = perplexity(&s, &w8a8, "heldout", 2).unwrap();
    s.set_cushion_tokens(&[cushioncache::data::BOS]).unwrap();
    calibrate::calibrate_into(&mut s, w8a8.act_levels(), 2).unwrap();
    let fixed = perplexity(&s, &w8a8, "heldout", 2).unwrap();
    assert!(broken > 2.0 * fp, "quant damage missing: {broken} vs fp {fp}");
    assert!(fixed < 1.15 * fp, "cushion failed: {fixed} vs fp {fp}");
}

#[test]
fn serving_matches_eval_forward() {
    if !have_artifacts() {
        return;
    }
    // greedy continuation via prefill+decode == argmax chain via fwd
    let s = session();
    let m_seq = s.manifest.seq_len;
    let vocab = s.manifest.vocab;
    let prompt: Vec<i32> = s.corpus.split("heldout").unwrap().seq(1)[..20].to_vec();

    // reference chain via fwd
    let s2 = session();
    let mut seq = prompt.clone();
    let mut want = Vec::new();
    for _ in 0..4 {
        let mut batch = seq.clone();
        batch.resize(m_seq, PAD);
        batch.resize(m_seq * s2.manifest.eval_batch, PAD);
        let out = s2.fwd(&Scheme::fp(), &batch).unwrap();
        let pos = seq.len() - 1;
        let next = argmax(&out.data[pos * vocab..(pos + 1) * vocab]) as i32;
        want.push(next);
        seq.push(next);
    }

    let engine = Engine::new(s, Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let mut req = cushioncache::coordinator::Request::new(1, prompt, 4);
    req.stop_token = None; // compare the full 4-token chain
    sched.submit_request(req);
    let resp = sched.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(resp.tokens, want, "serving path diverges from eval forward");
}

#[test]
fn continuous_batching_isolates_requests() {
    if !have_artifacts() {
        return;
    }
    let run = |prompts: Vec<Vec<i32>>| -> Vec<Vec<i32>> {
        let engine = Engine::new(session(), Scheme::fp()).unwrap();
        let mut sched = Scheduler::new(engine);
        let mut ids = Vec::new();
        for p in prompts {
            ids.push(sched.submit(p, 3));
        }
        let mut resp = sched.run_to_completion().unwrap();
        resp.sort_by_key(|r| r.id);
        resp.into_iter().map(|r| r.tokens).collect()
    };
    let s = session();
    let a: Vec<i32> = s.corpus.split("heldout").unwrap().seq(2)[..16].to_vec();
    let b: Vec<i32> = s.corpus.split("heldout").unwrap().seq(3)[..24].to_vec();
    let solo = run(vec![a.clone()]);
    let both = run(vec![a, b]);
    assert_eq!(solo[0], both[0], "batching changed request A's output");
}

#[test]
fn smoothquant_preserves_fp_function() {
    if !have_artifacts() {
        return;
    }
    // (x/s) @ (sW) == x @ W through the actual graphs: FP ppl unchanged.
    let mut s = session();
    let fp = Scheme::fp();
    let before = perplexity(&s, &fp, "heldout", 1).unwrap();
    let calib = calibrate::calibrate(&s, 1).unwrap();
    let mut w = s.base_weights.clone();
    let inv = cushioncache::quant::smoothquant::apply(
        &mut w, &calib, s.manifest.n_layers, s.manifest.d_model,
        s.manifest.act == "swiglu", 0.8,
    )
    .unwrap();
    s.set_weights(w);
    s.set_inv_smooth(inv);
    let after = perplexity(&s, &fp, "heldout", 1).unwrap();
    assert!(
        (before - after).abs() / before < 5e-3,
        "smoothing must be function-preserving: {before} vs {after}"
    );
}

#[test]
fn quarot_preserves_fp_function() {
    if !have_artifacts() {
        return;
    }
    let mut s = session();
    let fp = Scheme::fp();
    let before = perplexity(&s, &fp, "heldout", 1).unwrap();
    let mut w = s.base_weights.clone();
    cushioncache::quant::quarot::apply(&mut w, &s.manifest).unwrap();
    s.set_weights(w);
    let after = perplexity(&s, &fp, "heldout", 1).unwrap();
    assert!(
        (before - after).abs() / before < 5e-3,
        "rotation must be function-preserving: {before} vs {after}"
    );
}

#[test]
fn tcp_server_roundtrip() {
    if !have_artifacts() {
        return;
    }
    use std::io::{BufRead, BufReader, Write};
    let engine = Engine::new(session(), Scheme::fp()).unwrap();
    let sched = Scheduler::new(engine);
    let addr = "127.0.0.1:7391";
    let server = cushioncache::coordinator::server::Server::new(addr);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));

    // client thread: wait for bind, send one request, then "quit"
    let handle = std::thread::spawn(move || {
        let mut conn = None;
        for _ in 0..100 {
            if let Ok(c) = std::net::TcpStream::connect(addr) {
                conn = Some(c);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        let mut conn = conn.expect("server did not bind");
        writeln!(conn, r#"{{"prompt": [0, 10, 11, 12], "max_new": 3}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        writeln!(conn, "quit").unwrap();
        line
    });

    server.serve(sched, stop).unwrap();
    let line = handle.join().unwrap();
    let v = cushioncache::util::json::parse(line.trim()).unwrap();
    let toks = v.get("tokens").unwrap().as_arr().unwrap();
    assert!(!toks.is_empty() && toks.len() <= 3, "bad response: {line}");
    assert!(v.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn scheduler_isolates_bad_requests() {
    if !have_artifacts() {
        return;
    }
    // one bad request must never kill the serving loop: oversized and
    // out-of-vocab prompts become per-request FinishReason::Error
    // responses while a concurrently queued valid request completes.
    let engine = Engine::new(session(), Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let seq_len = sched.engine.session.manifest.seq_len;
    let vocab = sched.engine.session.manifest.vocab as i32;
    let good_prompt: Vec<i32> =
        sched.engine.session.corpus.split("heldout").unwrap().seq(1)[..12].to_vec();

    sched.submit_request(Request::new(101, vec![5; seq_len + 1], 4));
    sched.submit_request(Request::new(102, vec![0, vocab + 7], 4));
    let mut good = Request::new(103, good_prompt, 3);
    good.stop_token = None;
    sched.submit_request(good);

    let mut resp = sched.run_to_completion().unwrap();
    resp.sort_by_key(|r| r.id);
    assert_eq!(resp.len(), 3);
    assert!(resp[0].finished.is_error(), "oversized: {:?}", resp[0].finished);
    assert!(resp[0].tokens.is_empty());
    assert!(resp[1].finished.is_error(), "out-of-vocab: {:?}", resp[1].finished);
    assert_eq!(resp[2].finished, FinishReason::MaxTokens);
    assert_eq!(resp[2].tokens.len(), 3, "valid request starved by bad ones");
    assert_eq!(sched.metrics.errored, 2);
    assert_eq!(sched.metrics.completed, 1);
}

#[test]
fn scheduler_admits_into_every_free_slot() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::new(session(), Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let n_slots = sched.engine.kv.n_slots;
    let prompt: Vec<i32> =
        sched.engine.session.corpus.split("heldout").unwrap().seq(0)[..16].to_vec();
    for i in 0..n_slots + 2 {
        let mut r = Request::new(200 + i as u64, prompt.clone(), 8);
        r.stop_token = None;
        sched.submit_request(r);
    }
    sched.step().unwrap();
    assert_eq!(
        sched.running_count(),
        n_slots,
        "one step must admit a prefill into every free slot"
    );
    assert_eq!(sched.batcher.waiting(), 2);
    sched.run_to_completion().unwrap();
}

#[test]
fn scheduler_cancel_frees_slot() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::new(session(), Scheme::fp()).unwrap();
    let mut sched = Scheduler::new(engine);
    let prompt: Vec<i32> =
        sched.engine.session.corpus.split("heldout").unwrap().seq(0)[..16].to_vec();
    let mut r = Request::new(301, prompt.clone(), 1_000_000);
    r.stop_token = None; // would run (nearly) forever
    sched.submit_request(r);
    sched.step().unwrap();
    let free_before = sched.engine.kv.free_count();
    assert!(sched.cancel(301), "in-flight request not found");
    assert_eq!(sched.engine.kv.free_count(), free_before + 1);
    assert!(!sched.cancel(301), "double-cancel should be a no-op");
    let resp = sched.take_finished();
    assert!(resp.iter().any(|r| r.id == 301 && r.finished == FinishReason::Cancelled));
    assert_eq!(sched.metrics.cancelled, 1);
}

#[test]
fn router_backend_isolates_routing_errors() {
    if !have_artifacts() {
        return;
    }
    let mut router = Router::new();
    router.add_engine("fp", Scheduler::new(Engine::new(session(), Scheme::fp()).unwrap()));
    let prompt: Vec<i32> =
        sessionless_prompt(&mut router);
    // unknown mode: a routing error string, not an engine failure
    let err = ServeBackend::submit(&mut router, Some("int3"), Request::new(1, prompt.clone(), 2))
        .unwrap_err();
    assert!(err.contains("int3"), "routing error should name the mode: {err}");
    // no mode: defaults to the only engine and completes
    ServeBackend::submit(&mut router, None, Request::new(2, prompt, 2)).unwrap();
    while ServeBackend::has_work(&router) {
        ServeBackend::step(&mut router).unwrap();
    }
    let resp = ServeBackend::take_finished(&mut router);
    assert_eq!(resp.len(), 1);
    assert_eq!(resp[0].id, 2);
    assert!(!resp[0].finished.is_error());
}

fn sessionless_prompt(router: &mut Router) -> Vec<i32> {
    router
        .scheduler_mut("fp")
        .unwrap()
        .engine
        .session
        .corpus
        .split("heldout")
        .unwrap()
        .seq(2)[..10]
        .to_vec()
}

#[test]
fn tcp_server_fault_isolation_and_streaming() {
    if !have_artifacts() {
        return;
    }
    use std::io::{BufRead, BufReader, Write};
    let engine = Engine::new(session(), Scheme::fp()).unwrap();
    let seq_len = engine.session.manifest.seq_len;
    let sched = Scheduler::new(engine);
    let addr = "127.0.0.1:7392";
    let server = cushioncache::coordinator::server::Server::new(addr);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));

    let handle = std::thread::spawn(move || {
        let mut conn = None;
        for _ in 0..100 {
            if let Ok(c) = std::net::TcpStream::connect(addr) {
                conn = Some(c);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        let mut conn = conn.expect("server did not bind");
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        let mut read = |line: &mut String| {
            line.clear();
            reader.read_line(line).unwrap();
            cushioncache::util::json::parse(line.trim()).unwrap()
        };

        // 1) malformed JSON: error line, loop survives
        writeln!(conn, "this is not json").unwrap();
        let v = read(&mut line);
        assert!(v.get("error").is_some(), "no error field: {line}");
        assert!(v.get("id").is_none());

        // 2) truncated \u escape (the old parser panicked here)
        writeln!(conn, "{}", r#"{"prompt": [0], "bad": "\u12"#).unwrap();
        let v = read(&mut line);
        assert!(v.get("error").is_some(), "no error field: {line}");

        // 3) out-of-vocab token: refused at the door
        writeln!(conn, r#"{{"prompt": [0, 99999]}}"#).unwrap();
        let v = read(&mut line);
        assert!(
            v.get("error").unwrap().as_str().unwrap().contains("vocab"),
            "bad rejection: {line}"
        );

        // 4) oversized prompt: parses fine, errors per-request at admission
        let huge: Vec<String> = (0..seq_len + 1).map(|_| "5".to_string()).collect();
        writeln!(conn, r#"{{"prompt": [{}]}}"#, huge.join(",")).unwrap();
        let v = read(&mut line);
        assert_eq!(v.req_str("finish").unwrap(), "error", "line: {line}");
        assert!(v.get("id").is_some());
        assert!(v.get("error").unwrap().as_str().unwrap().contains("prompt"));

        // 5) the loop must still serve a valid streaming request fully
        let req = concat!(
            r#"{"prompt": [0, 10, 11, 12], "max_new": 3, "stream": true, "#,
            r#""stop_token": null, "echo_text": true}"#
        );
        writeln!(conn, "{req}").unwrap();
        let mut streamed = Vec::new();
        let summary = loop {
            let v = read(&mut line);
            if v.get("finish").is_some() {
                break v;
            }
            streamed.push(v.req_usize("token").unwrap() as i32);
            assert_eq!(
                v.req_usize("index").unwrap(),
                streamed.len() - 1,
                "stream indices must be dense and ordered"
            );
        };
        assert_eq!(summary.req_str("finish").unwrap(), "max_tokens");
        let toks: Vec<i32> = summary
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(
            streamed, toks,
            "every generated token must stream before the summary"
        );
        assert_eq!(toks.len(), 3);
        assert!(summary.get("text").is_some(), "echo_text missing: {line}");

        writeln!(conn, "quit").unwrap();
    });

    server.serve(sched, stop).unwrap();
    handle.join().unwrap();
}

#[test]
fn weight_quant_is_mild_at_8_bits() {
    if !have_artifacts() {
        return;
    }
    let mut s = session();
    let fp = Scheme::fp();
    let before = perplexity(&s, &fp, "heldout", 1).unwrap();
    let mut w = s.base_weights.clone();
    for name in w.names.clone() {
        if cushioncache::quant::scales::is_quantized_weight(&name) {
            cushioncache::quant::scales::quant_weight_inplace(
                w.get_mut(&name).unwrap(), 8, 64);
        }
    }
    s.set_weights(w);
    let after = perplexity(&s, &fp, "heldout", 1).unwrap();
    assert!(after < before * 1.1, "W8 should be near-lossless: {before} -> {after}");
}
