//! Sampling / bucketing parity for the device-side-selection serving
//! path: device-selected token ids must equal host argmax over fetched
//! logits in every residency mode, and bucketed prefill must pick the
//! same first token as the full-length prefill at/below/above each
//! bucket boundary.
//!
//! Like the other integration tests these skip when `make artifacts` has
//! not run; the sampled-graph tests additionally skip (loudly) when the
//! artifact set predates the `*_sampled_*` variants, so a stale artifact
//! dir degrades to "nothing to check" instead of a false failure.

use std::sync::{Mutex, MutexGuard};

use cushioncache::coordinator::Engine;
use cushioncache::data::PAD;
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::transfer;
use cushioncache::runtime::Client;
use cushioncache::util::fsutil;

const VARIANT: &str = "tl-llama";

/// The transfer counters are process-global; serialize this binary's
/// tests (poison-proof) so the byte-budget assertion is deterministic.
static XFER_LOCK: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    XFER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn have_artifacts() -> bool {
    fsutil::variant_dir(VARIANT).join("manifest.json").exists()
}

fn engine() -> Engine {
    let mut s =
        Session::load_with_client(VARIANT, Client::cpu().unwrap()).unwrap();
    let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 1).unwrap();
    s.set_cushion_tokens(&[cushioncache::data::BOS]).unwrap();
    Engine::new(s, scheme).unwrap()
}

fn prompt(len: usize, seq: usize) -> Vec<i32> {
    let s = Session::load_with_client(VARIANT, Client::cpu().unwrap()).unwrap();
    s.corpus.split("heldout").unwrap().seq(seq)[..len].to_vec()
}

/// Generate `steps` tokens from `prompt` on a fresh engine configured by
/// `setup`; returns the full token stream (first token included).
fn generate(prompt: &[i32], steps: usize, setup: impl Fn(&mut Engine)) -> Vec<i32> {
    let mut e = engine();
    setup(&mut e);
    let slot = e.kv.alloc(1, prompt.len()).unwrap();
    let mut out = Vec::new();
    let mut last = e.prefill(slot, prompt).unwrap();
    out.push(last);
    let b = e.session.manifest.serve_batch;
    for _ in 0..steps {
        let mut toks = vec![PAD; b];
        toks[slot] = last;
        last = e.decode_step(&toks).unwrap()[slot];
        e.kv.push_token(slot);
        out.push(last);
    }
    out
}

#[test]
fn device_selected_ids_match_host_argmax_in_every_residency_mode() {
    let _guard = serial();
    if !have_artifacts() {
        return;
    }
    if !engine().sampled_decode_available() {
        eprintln!("skipping: artifacts predate the *_sampled_* graphs");
        return;
    }
    let p = prompt(20, 1);
    for host_roundtrip in [false, true] {
        // host argmax over fetched logits (the reference semantics)
        let host = generate(&p, 6, |e| {
            e.set_device_sampling(false);
            e.set_host_roundtrip(host_roundtrip);
        });
        // in-graph selection, only ids fetched
        let device = generate(&p, 6, |e| {
            e.set_device_sampling(true);
            e.set_host_roundtrip(host_roundtrip);
        });
        assert_eq!(
            device, host,
            "device-selected ids diverge from host argmax \
             (host_roundtrip={host_roundtrip})"
        );
    }
}

#[test]
fn bucketed_prefill_matches_full_length_at_boundaries() {
    let _guard = serial();
    if !have_artifacts() {
        return;
    }
    let probe = engine();
    let buckets: Vec<usize> = probe.sampled_prefill_buckets().to_vec();
    if buckets.len() < 2 {
        eprintln!("skipping: artifacts carry no bucketed prefill graphs");
        return;
    }
    let seq_len = probe.session.manifest.seq_len;
    drop(probe);
    // prompts at/below/above every interior bucket boundary
    let mut lens = Vec::new();
    for &b in &buckets {
        for l in [b.saturating_sub(1), b, (b + 1).min(seq_len)] {
            if l >= 1 && !lens.contains(&l) {
                lens.push(l);
            }
        }
    }
    for len in lens {
        let p = prompt(len, 2);
        let full = generate(&p, 0, |e| e.set_prefill_bucketing(false));
        let bucketed = generate(&p, 0, |e| e.set_prefill_bucketing(true));
        assert_eq!(
            bucketed, full,
            "bucketed prefill first token diverges at prompt len {len} \
             (buckets {buckets:?})"
        );
    }
}

#[test]
fn device_sampled_decode_steps_fetch_kilobytes_not_logits() {
    let _guard = serial();
    if !have_artifacts() {
        return;
    }
    let mut e = engine();
    if !e.sampled_decode_available() {
        eprintln!("skipping: artifacts predate the *_sampled_* graphs");
        return;
    }
    let p = prompt(16, 0);
    let slot = e.kv.alloc(1, p.len()).unwrap();
    let mut last = e.prefill(slot, &p).unwrap();
    let b = e.session.manifest.serve_batch;
    // warm one step (first decode may compile / upload one-time state)
    let mut toks = vec![PAD; b];
    toks[slot] = last;
    last = e.decode_step(&toks).unwrap()[slot];
    e.kv.push_token(slot);

    let steps = 4u64;
    let base = transfer::snapshot();
    for _ in 0..steps {
        let mut toks = vec![PAD; b];
        toks[slot] = last;
        last = e.decode_step(&toks).unwrap()[slot];
        e.kv.push_token(slot);
    }
    let d = transfer::snapshot().delta_since(&base);
    let per_step = (d.bytes_uploaded + d.bytes_fetched) / steps;
    // the ISSUE-3 budget: <= 64 KB combined per step (actual steady
    // state is ~100 B; the slack covers counter noise from parallel
    // tests sharing the process-global meters)
    assert!(
        per_step <= 64 * 1024,
        "decode step moved {per_step} B across the host boundary \
         (budget 64 KB): cache residency or device sampling regressed"
    );
}
