//! Residency tests for the device-resident value pool: loop-invariant
//! operands (weights, ranges, inv_smooth, cushion prefix KV) are uploaded
//! exactly once per (re)configuration, the Session setters invalidate
//! exactly what changed, and the device-resident decode path is
//! token-for-token identical to the seed's host-round-trip semantics.
//!
//! Like the other integration tests these skip when `make artifacts` has
//! not run, and each test owns its PJRT client. The transfer counters
//! are process-global, so every test in this binary serializes on one
//! lock to keep the byte-level assertions deterministic.

use std::sync::{Mutex, MutexGuard};

use cushioncache::coordinator::Engine;
use cushioncache::model::resident;
use cushioncache::model::session::Session;
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::transfer;
use cushioncache::runtime::Client;
use cushioncache::util::fsutil;

static XFER_LOCK: Mutex<()> = Mutex::new(());

/// Serialize the whole binary's tests (poison-proof: a failed test must
/// not cascade into lock panics elsewhere).
fn serial() -> MutexGuard<'static, ()> {
    XFER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn have_artifacts() -> bool {
    fsutil::variant_dir("tl-llama").join("manifest.json").exists()
}

fn session() -> Session {
    Session::load_with_client("tl-llama", Client::cpu().unwrap()).unwrap()
}

fn eval_tokens(s: &Session) -> Vec<i32> {
    let split = s.corpus.split("heldout").unwrap();
    (0..s.manifest.eval_batch)
        .flat_map(|i| split.seq(i).to_vec())
        .collect()
}

#[test]
fn session_uploads_invariants_once() {
    if !have_artifacts() {
        return;
    }
    let _guard = serial();
    let mut s = session();
    let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 1).unwrap();
    let tokens = eval_tokens(&s);
    for _ in 0..3 {
        s.fwd(&scheme, &tokens).unwrap();
    }
    for key in [
        resident::KEY_WEIGHTS,
        resident::KEY_RANGES,
        resident::KEY_INV_SMOOTH,
        resident::KEY_PREFIX_KV,
    ] {
        assert_eq!(
            s.pool().upload_count(key),
            1,
            "invariant '{key}' must upload exactly once across repeated runs"
        );
    }
}

#[test]
fn setters_invalidate_exactly_what_changed() {
    if !have_artifacts() {
        return;
    }
    let _guard = serial();
    let mut s = session();
    let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 1).unwrap();
    let tokens = eval_tokens(&s);
    s.fwd(&scheme, &tokens).unwrap();

    // installing a cushion must re-upload only the prefix KV
    s.set_cushion_tokens(&[cushioncache::data::BOS]).unwrap();
    s.fwd(&scheme, &tokens).unwrap();
    assert_eq!(s.pool().upload_count(resident::KEY_PREFIX_KV), 2);
    assert_eq!(s.pool().upload_count(resident::KEY_RANGES), 1);
    assert_eq!(s.pool().upload_count(resident::KEY_INV_SMOOTH), 1);
    assert_eq!(s.pool().upload_count(resident::KEY_WEIGHTS), 1);

    // recalibration must re-upload only the ranges
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 1).unwrap();
    s.fwd(&scheme, &tokens).unwrap();
    assert_eq!(s.pool().upload_count(resident::KEY_RANGES), 2);
    assert_eq!(s.pool().upload_count(resident::KEY_PREFIX_KV), 2);
    assert_eq!(s.pool().upload_count(resident::KEY_WEIGHTS), 1);

    // swapping weights must re-upload only the bundle
    let w = s.base_weights.clone();
    s.set_weights(w);
    s.fwd(&scheme, &tokens).unwrap();
    assert_eq!(s.pool().upload_count(resident::KEY_WEIGHTS), 2);
    assert_eq!(s.pool().upload_count(resident::KEY_RANGES), 2);
    assert_eq!(s.pool().upload_count(resident::KEY_INV_SMOOTH), 1);

    // clearing the cushion must drop the prefix KV entry again
    s.clear_cushion();
    s.fwd(&scheme, &tokens).unwrap();
    assert_eq!(s.pool().upload_count(resident::KEY_PREFIX_KV), 3);
}

#[test]
fn decode_steps_do_not_reupload_invariants() {
    if !have_artifacts() {
        return;
    }
    let _guard = serial();
    let mut s = session();
    let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
    calibrate::calibrate_into(&mut s, scheme.act_levels(), 1).unwrap();
    let prompt: Vec<i32> = s.corpus.split("heldout").unwrap().seq(0)[..16].to_vec();
    let weight_bytes: usize =
        s.weights.tensors.iter().map(|t| 4 * t.data.len()).sum();
    let cache_bytes = {
        let m = &s.manifest;
        4 * m.n_layers * 2 * m.serve_batch * m.n_kv_heads * m.cache_cap * m.d_head
    };

    let mut engine = Engine::new(s, scheme).unwrap();
    let slot = engine.kv.alloc(1, prompt.len()).unwrap();
    let mut last = engine.prefill(slot, &prompt).unwrap();
    let b = engine.session.manifest.serve_batch;

    let base = transfer::snapshot();
    let steps = 4usize;
    for _ in 0..steps {
        let mut toks = vec![cushioncache::data::PAD; b];
        toks[slot] = last;
        last = engine.decode_step(&toks).unwrap()[slot];
        engine.kv.push_token(slot);
    }
    let d = transfer::snapshot().delta_since(&base);

    // per-step upload traffic: the (fallback) cache literal + tokens +
    // lens; never the weight bundle or the other invariants.
    let per_step_up = d.bytes_uploaded as usize / steps;
    assert!(
        per_step_up < cache_bytes + 64 * 1024,
        "decode step uploads {per_step_up} B — invariants are leaking \
         (cache is {cache_bytes} B, weights {weight_bytes} B)"
    );
    for key in [
        resident::KEY_WEIGHTS,
        resident::KEY_RANGES,
        resident::KEY_INV_SMOOTH,
        resident::KEY_PREFIX_KV,
    ] {
        assert_eq!(
            engine.session.pool().upload_count(key),
            1,
            "'{key}' re-uploaded during decode"
        );
    }
}

#[test]
fn device_resident_decode_matches_host_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let _guard = serial();
    let prompt_len = 20usize;
    let steps = 6usize;
    let run = |host_roundtrip: bool| -> (Vec<i32>, cushioncache::util::tensor::Tensor) {
        let mut s = session();
        let scheme = Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive);
        calibrate::calibrate_into(&mut s, scheme.act_levels(), 1).unwrap();
        s.set_cushion_tokens(&[cushioncache::data::BOS]).unwrap();
        let prompt: Vec<i32> =
            s.corpus.split("heldout").unwrap().seq(1)[..prompt_len].to_vec();
        let mut engine = Engine::new(s, scheme).unwrap();
        engine.set_host_roundtrip(host_roundtrip);
        let slot = engine.kv.alloc(1, prompt.len()).unwrap();
        let mut out = Vec::new();
        let mut last = engine.prefill(slot, &prompt).unwrap();
        out.push(last);
        let b = engine.session.manifest.serve_batch;
        for _ in 0..steps {
            let mut toks = vec![cushioncache::data::PAD; b];
            toks[slot] = last;
            last = engine.decode_step(&toks).unwrap()[slot];
            engine.kv.push_token(slot);
            out.push(last);
        }
        (out, engine.cache_host().unwrap())
    };
    let (resident_toks, resident_cache) = run(false);
    let (host_toks, host_cache) = run(true);
    assert_eq!(
        resident_toks, host_toks,
        "device-resident decode diverges from host-round-trip semantics"
    );
    assert_eq!(resident_cache.shape, host_cache.shape);
    let max_diff = resident_cache
        .data
        .iter()
        .zip(&host_cache.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff <= 1e-5,
        "cache state diverges between residency modes (max |Δ| = {max_diff})"
    );
}
