//! Property tests (testkit::prop) on coordinator invariants — these run
//! against the logical components (no PJRT needed).

use cushioncache::coordinator::batcher::{Batcher, Running};
use cushioncache::coordinator::kvpool::{BlockDims, PagedKv};
use cushioncache::coordinator::request::Request;
use cushioncache::data::grammar::Grammar;
use cushioncache::data::tokenizer::Tokenizer;
use cushioncache::quant::scales::{quant_weight_inplace, MinMax};
use cushioncache::testkit::prop::*;
use cushioncache::util::prng::SplitMix64;
use cushioncache::util::tensor::Tensor;

#[test]
fn json_parse_never_panics_on_mutated_documents() {
    // the parser feeds on untrusted network bytes: arbitrary byte
    // mutations (and truncations) of valid documents must parse or Err,
    // never panic. This is the regression net for the `\u` slice panic.
    check(
        "json no-panic fuzz",
        400,
        pair(usize_in(0..1_000_000), vec_u32(0..12, u32::MAX)),
        |&(seed, ref muts)| {
            let mut rng = SplitMix64::new(seed as u64);
            let doc = format!(
                concat!(
                    r#"{{"prompt":[{},{},-3,1.5e2],"s":"aé 😀 \n \ud83d\ude00 \u00e9 x","#,
                    r#""n":{}.25,"b":[true,false,null,{{"k":"\t\\"}}],"#,
                    r#""u":"😀 héllo"}}"#
                ),
                rng.next_below(10_000),
                rng.next_below(100),
                rng.next_below(1000),
            );
            let mut bytes = doc.into_bytes();
            for &m in muts {
                let pos = (m as usize) % bytes.len();
                if m % 7 == 0 {
                    bytes.truncate(pos.max(1));
                } else if m % 3 == 0 {
                    bytes[pos] = ((m >> 8) % 128) as u8; // ascii clobber
                } else {
                    bytes[pos] = (m >> 8) as u8; // arbitrary clobber
                }
            }
            let Ok(s) = std::str::from_utf8(&bytes) else {
                return true; // parse() takes &str; invalid utf-8 never reaches it
            };
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = cushioncache::util::json::parse(s);
            }))
            .is_ok()
        },
    );
}

#[test]
fn paged_kv_never_oversubscribes() {
    check("paged kv alloc/free", 300, vec_u32(0..64, 3), |ops| {
        // ops: 0 = alloc, 1 = free first busy, 2 = push token
        let mut kv = PagedKv::new(
            4,
            4,
            20,
            2,
            4,
            21, // cushion block + 4 lanes x 5 token blocks: never dry
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 4 },
            None,
        );
        let baseline_blocks = kv.blocks_in_use(); // the pinned cushion run
        let mut live = 0usize;
        for (i, &op) in ops.iter().enumerate() {
            match op {
                0 => {
                    if kv.alloc(i as u64, 4).is_some() {
                        live += 1;
                    }
                }
                1 => {
                    if let Some(slot) = kv.busy_slots().first().copied() {
                        kv.free(slot);
                        live -= 1;
                    }
                }
                _ => {
                    if let Some(slot) = kv.busy_slots().first().copied() {
                        if kv.remaining(slot) > 0 {
                            kv.push_token(slot);
                        }
                    }
                }
            }
            if kv.busy_slots().len() != live || kv.free_count() != 4 - live {
                return false;
            }
            // capacity invariant on every slot
            for s in kv.busy_slots() {
                if kv.m_max + kv.tok_len(s) > kv.cap {
                    return false;
                }
            }
            // block accounting: nothing leaks past the live tables
            if live == 0 && kv.blocks_in_use() != baseline_blocks {
                return false;
            }
        }
        true
    });
}

#[test]
fn batcher_preserves_fifo_and_ids() {
    check("batcher fifo", 200, usize_in(1..40), |&n| {
        let mut b = Batcher::new();
        let ids: Vec<u64> = (0..n).map(|i| b.submit(vec![i as i32], 4)).collect();
        let mut out = Vec::new();
        while let Some(r) = b.pop() {
            out.push(r.id);
        }
        out == ids && out.windows(2).all(|w| w[0] < w[1])
    });
}

#[test]
fn running_stop_respects_budget() {
    check(
        "stop at max_new",
        200,
        pair(usize_in(1..20), usize_in(0..30)),
        |&(max_new, produced)| {
            let mut r = Running::new(Request::new(1, vec![0], max_new), 0);
            for t in 0..produced {
                r.push_token(t as i32 + 10);
            }
            let stopped = r.should_stop(100).is_some();
            stopped == (produced >= max_new)
        },
    );
}

#[test]
fn minmax_merge_is_commutative_and_widening() {
    check("minmax merge", 200, vec_f64(2..40, -50.0, 50.0), |xs| {
        let mut a = MinMax::new(1);
        let mut b = MinMax::new(1);
        for pair in xs.chunks(2) {
            let lo = pair[0].min(*pair.last().unwrap()) as f32;
            let hi = pair[0].max(*pair.last().unwrap()) as f32;
            let t = Tensor::new(vec![1, 2], vec![lo, hi]);
            a.merge(&t);
            b.merge(&t);
        }
        // merged range covers every batch
        a.mins[0] <= a.maxs[0] && a.mins[0] == b.mins[0] && a.maxs[0] == b.maxs[0]
    });
}

#[test]
fn weight_qdq_error_bounded_by_step() {
    check("weight qdq bound", 100, vec_f64(64..65, -3.0, 3.0), |xs| {
        let data: Vec<f32> = xs.iter().map(|&v| v as f32).collect();
        let orig = Tensor::new(vec![64, 1], data);
        let mut q = orig.clone();
        quant_weight_inplace(&mut q, 8, 64);
        let amax = orig.absmax();
        let step = amax / 127.0;
        q.data
            .iter()
            .zip(&orig.data)
            .all(|(a, b)| (a - b).abs() <= step / 2.0 + 1e-6)
    });
}

#[test]
fn tokenizer_roundtrips_grammar_output() {
    check("tokenizer roundtrip", 100, usize_in(0..10_000), |&seed| {
        let g = Grammar::new(512);
        let tok = Tokenizer::new(512);
        let mut rng = SplitMix64::new(seed as u64);
        let doc = g.document(64, &mut rng);
        doc.iter().all(|&id| {
            let s = tok.id_to_str(id);
            tok.str_to_id(&s).map(|back| back == id).unwrap_or(false)
        })
    });
}

#[test]
fn grammar_documents_always_well_formed() {
    check("grammar well-formed", 150, usize_in(0..100_000), |&seed| {
        let g = Grammar::new(1024);
        let mut rng = SplitMix64::new(seed as u64);
        let d = g.document(128, &mut rng);
        d.len() == 128
            && d[0] == cushioncache::data::BOS
            && d.iter().all(|&t| t >= 0 && (t as usize) < 1024)
    });
}

#[test]
fn hadamard_rotation_preserves_l2_norm() {
    check("hadamard isometry", 50, vec_f64(256..257, -5.0, 5.0), |xs| {
        let h = cushioncache::util::tensor::hadamard(256);
        let x = Tensor::new(vec![1, 256], xs.iter().map(|&v| v as f32).collect());
        let xr = x.matmul(&h);
        let n = |t: &Tensor| t.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        let (a, b) = (n(&x), n(&xr));
        (a - b).abs() <= 1e-3 * a.max(1.0)
    });
}
