//! Cross-language parity: the Rust grammar/PRNG mirrors must reproduce
//! the Python-generated corpus artifacts bit-for-bit, and the manifest /
//! weights / tasks loaders must agree with what aot.py wrote.

use cushioncache::data::corpus::Corpus;
use cushioncache::data::grammar::{self, corpus_split};
use cushioncache::data::tasks;
use cushioncache::model::{Manifest, Weights};
use cushioncache::util::fsutil;

fn have_artifacts() -> bool {
    fsutil::variant_dir("tl-llama").join("manifest.json").exists()
}

#[test]
fn grammar_matches_python_corpus() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let corpus = Corpus::load(&fsutil::variant_dir("tl-llama").join("corpus.bin"))
        .unwrap();
    for (name, stream) in [
        ("calib", grammar::STREAM_CALIB),
        ("heldout", grammar::STREAM_HELDOUT),
        ("trainsample", grammar::STREAM_TRAINSAMPLE),
    ] {
        let split = corpus.split(name).unwrap();
        let ours = corpus_split(512, split.n_seqs, split.seq_len, stream,
                                grammar::CORPUS_SEED);
        for (i, seq) in ours.iter().enumerate() {
            assert_eq!(split.seq(i), &seq[..], "split {name} seq {i} diverges");
        }
    }
}

#[test]
fn grammar_matches_python_corpus_large_vocab() {
    if !have_artifacts() {
        return;
    }
    let dir = fsutil::variant_dir("tl-llama3");
    if !dir.join("corpus.bin").exists() {
        return;
    }
    let corpus = Corpus::load(&dir.join("corpus.bin")).unwrap();
    let split = corpus.split("trainsample").unwrap();
    let ours = corpus_split(1024, split.n_seqs, split.seq_len,
                            grammar::STREAM_TRAINSAMPLE, grammar::CORPUS_SEED);
    for (i, seq) in ours.iter().enumerate() {
        assert_eq!(split.seq(i), &seq[..]);
    }
}

#[test]
fn manifest_and_weights_consistent() {
    if !have_artifacts() {
        return;
    }
    for variant in cushioncache::model::available_variants() {
        let m = Manifest::load_variant(&variant).unwrap();
        assert_eq!(m.variant, variant);
        let w = Weights::load_variant(&variant, &m).unwrap();
        assert!(w.total_params() > 100_000, "{variant}: too few params");
        // the planted always-on channel: embed[:, one] == 1
        let emb = w.get("embed").unwrap();
        let one_dim = 245;
        for t in 0..m.vocab {
            assert_eq!(emb.at2(t, one_dim), 1.0, "{variant} embed one-dim");
        }
        for g in &m.graphs {
            assert!(
                fsutil::variant_dir(&variant)
                    .join(format!("{g}.hlo.txt"))
                    .exists(),
                "{variant}: missing graph {g}"
            );
        }
    }
}

#[test]
fn tasks_load_and_are_well_formed() {
    if !have_artifacts() {
        return;
    }
    let all = tasks::load(&fsutil::variant_dir("tl-llama").join("tasks.bin"))
        .unwrap();
    let names: Vec<&str> = all.iter().map(|t| t.name.as_str()).collect();
    for z in tasks::ZERO_SHOT {
        assert!(names.contains(&z), "missing task {z}");
    }
    for t in &all {
        for item in &t.items {
            assert!(item.gold < item.candidates.len().max(1));
            for c in &item.candidates {
                assert!(c.iter().all(|&x| x >= 0 && (x as usize) < 512));
            }
        }
    }
}
