//! Tensor-parallel parity, hermetically: a sharded engine (attention
//! heads and MLP columns split across a lock-step `DeviceGroup`, one
//! interpreter instance per shard) must reproduce the unsharded engine
//! exactly. fp mode is **bit-identical** across prefill + decode for
//! shards in {1, 2, 4} on every attention/position axis the tiny model
//! exposes; quantized modes stay within the interp-parity tolerance.
//! Bucketed prefill is covered at a bucket boundary: the sharded path
//! must pick the same smallest covering `prefill_buckets` entry as the
//! unsharded plan instead of padding to the full `seq_len`.
//! Also asserted here: the 64 KiB/step host-transfer budget holds with
//! `--shards > 1` (collective traffic is metered separately), and a
//! killed shard surfaces exactly one typed engine-level error that the
//! scheduler's retry path absorbs — no deadlocked peers.
//!
//! The transfer and collective meters are process-global, so every
//! test in this binary serializes behind one mutex.

use std::sync::Mutex;

use cushioncache::coordinator::{Engine, FinishReason, Request, Scheduler};
use cushioncache::data::PAD;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme};
use cushioncache::runtime::faults::{self, FaultOp};
use cushioncache::runtime::{collective, transfer, FaultPlan};
use cushioncache::testkit::tiny::TinyCfg;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// MHA tiny config: 4 query heads == 4 KV heads, divisible by 1/2/4.
fn cfg_mha() -> TinyCfg {
    TinyCfg {
        n_heads: 4,
        n_kv_heads: 4,
        d_head: 8,
        d_ff: 48,
        ..TinyCfg::default()
    }
}

/// GQA tiny config: 8 query heads over 4 KV heads (group size 2), so
/// shard boundaries must respect whole KV-head groups.
fn cfg_gqa() -> TinyCfg {
    TinyCfg {
        n_heads: 8,
        n_kv_heads: 4,
        d_head: 4,
        d_ff: 48,
        ..TinyCfg::default()
    }
}

/// Greedy prefill + `steps` decode steps on one engine; returns the
/// emitted tokens and the final contiguous KV cache. The unsharded
/// baseline disables sampled/bucketed prefill so both paths run the
/// full-length logits prefill graph and write the same cache region.
fn run_engine(
    cfg: &TinyCfg,
    scheme: Scheme,
    n_shards: usize,
    steps: usize,
) -> (Vec<i32>, Vec<f32>) {
    let mut cfg = cfg.clone();
    cfg.n_shards = n_shards;
    let s = cfg.session().unwrap();
    let prompt: Vec<i32> = s.corpus.split("heldout").unwrap().seq(1)[..5].to_vec();
    let mut e = Engine::new(s, scheme).unwrap();
    e.set_device_sampling(false);
    e.set_prefill_bucketing(false);
    let b = e.session.manifest.serve_batch;
    let slot = e.kv.alloc(1, prompt.len()).unwrap();
    let mut last = e.prefill(slot, &prompt).unwrap();
    let mut out = vec![last];
    for _ in 0..steps {
        let mut feed = vec![PAD; b];
        feed[slot] = last;
        last = e.decode_step(&feed).unwrap()[slot];
        e.kv.push_token(slot);
        out.push(last);
    }
    (out, e.cache_host().unwrap().data)
}

#[test]
fn fp_sharded_serving_is_bit_identical_to_unsharded() {
    let _g = serial();
    for base in [cfg_mha(), cfg_gqa()] {
        for pos in ["rope", "alibi", "learned"] {
            for window in [0usize, 4] {
                let mut cfg = base.clone();
                cfg.pos = pos;
                cfg.window = window;
                let (want_toks, want_cache) =
                    run_engine(&cfg, Scheme::fp(), 1, 3);
                for n in [2usize, 4] {
                    let (toks, cache) = run_engine(&cfg, Scheme::fp(), n, 3);
                    let tag = format!(
                        "{} heads/{} kv, pos={pos}, window={window}, \
                         shards={n}",
                        cfg.n_heads, cfg.n_kv_heads
                    );
                    assert_eq!(toks, want_toks, "greedy tokens diverge: {tag}");
                    assert_eq!(
                        cache, want_cache,
                        "KV cache not bit-identical: {tag}"
                    );
                }
            }
        }
    }
}

/// The bucketed prefill cache written by one engine: bucketing stays
/// ON, the prompt sits exactly at the smallest bucket boundary, and the
/// unsharded baseline routes through the sampled `prefill_sampled_*_b8`
/// graph (the only unsharded plan that buckets below `seq_len`).
fn bucketed_prefill(cfg: &TinyCfg, n_shards: usize) -> (i32, Vec<f32>) {
    let mut cfg = cfg.clone();
    cfg.n_shards = n_shards;
    let s = cfg.session().unwrap();
    let prompt: Vec<i32> = s.corpus.split("heldout").unwrap().seq(1)[..8].to_vec();
    let mut e = Engine::new(s, Scheme::fp()).unwrap();
    e.set_prefill_bucketing(true);
    if n_shards == 1 {
        e.set_device_sampling(true);
        assert_eq!(
            e.sampled_prefill_buckets().first().copied(),
            Some(prompt.len()),
            "tiny geometry: the first prefill bucket must sit exactly at \
             the prompt length"
        );
    }
    let slot = e.kv.alloc(1, prompt.len()).unwrap();
    let first = e.prefill(slot, &prompt).unwrap();
    (first, e.cache_host().unwrap().data)
}

/// Regression: sharded prefill used to ignore `prefill_buckets` and pad
/// every prompt to the full `seq_len`, writing pad-row KV garbage past
/// the prompt. With bucketing on and a prompt exactly at a bucket
/// boundary, the sharded cache must match the unsharded bucketed cache
/// bit-for-bit — including the untouched (still-zero) tail rows a
/// full-length pad would have clobbered.
#[test]
fn bucketed_sharded_prefill_matches_unsharded_at_bucket_boundary() {
    let _g = serial();
    for base in [cfg_mha(), cfg_gqa()] {
        let (want_first, want_cache) = bucketed_prefill(&base, 1);
        for n in [2usize, 4] {
            let (first, cache) = bucketed_prefill(&base, n);
            let tag = format!(
                "{} heads/{} kv, shards={n}",
                base.n_heads, base.n_kv_heads
            );
            assert_eq!(first, want_first, "first token diverges: {tag}");
            assert_eq!(
                cache, want_cache,
                "bucketed sharded prefill must not write past the \
                 covering bucket: {tag}"
            );
        }
    }
}

/// Drive both engines with the *same* forced continuation so quant
/// noise can't fork the sampled trajectory; compare the caches they
/// write within the interp-parity tolerance (1e-4, scaled).
fn quantized_cache(cfg: &TinyCfg, scheme: Scheme, n_shards: usize) -> Vec<f32> {
    let mut cfg = cfg.clone();
    cfg.n_shards = n_shards;
    let s = cfg.session().unwrap();
    let prompt: Vec<i32> = s.corpus.split("heldout").unwrap().seq(1)[..5].to_vec();
    let forced: Vec<i32> = s.corpus.split("heldout").unwrap().seq(2)[..3].to_vec();
    let mut e = Engine::new(s, scheme).unwrap();
    e.set_device_sampling(false);
    e.set_prefill_bucketing(false);
    let b = e.session.manifest.serve_batch;
    let slot = e.kv.alloc(1, prompt.len()).unwrap();
    e.prefill(slot, &prompt).unwrap();
    for &t in &forced {
        let mut feed = vec![PAD; b];
        feed[slot] = t;
        e.decode_step(&feed).unwrap();
        e.kv.push_token(slot);
    }
    e.cache_host().unwrap().data
}

#[test]
fn quantized_sharded_serving_stays_within_interp_parity_tolerance() {
    let _g = serial();
    const TOL: f32 = 1e-4;
    for gran in [Granularity::PerTensorDynamic, Granularity::PerTokenDynamic] {
        let scheme = Scheme::w8a8(gran, Algorithm::Naive);
        let want = quantized_cache(&cfg_gqa(), scheme, 1);
        let absmax = want.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1.0);
        for n in [2usize, 4] {
            let got = quantized_cache(&cfg_gqa(), scheme, n);
            assert_eq!(got.len(), want.len());
            let worst = got
                .iter()
                .zip(&want)
                .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
            assert!(
                worst <= TOL * absmax,
                "{gran:?} shards={n}: cache diverges by {worst} \
                 (tol {})",
                TOL * absmax
            );
        }
    }
}

#[test]
fn sharded_decode_holds_host_budget_and_meters_collectives() {
    let _g = serial();
    let mut cfg = cfg_mha();
    cfg.n_shards = 2;
    let nl = cfg.n_layers as u64;
    let mut e = Engine::new(cfg.session().unwrap(), Scheme::fp()).unwrap();
    let prompt: Vec<i32> =
        e.session.corpus.split("heldout").unwrap().seq(3)[..5].to_vec();
    let b = e.session.manifest.serve_batch;
    let slot = e.kv.alloc(1, prompt.len()).unwrap();
    let mut last = e.prefill(slot, &prompt).unwrap();
    // warm one step so resident invariants are in steady state
    let mut feed = vec![PAD; b];
    feed[slot] = last;
    last = e.decode_step(&feed).unwrap()[slot];
    e.kv.push_token(slot);

    let steps = 4u64;
    let before_xfer = transfer::snapshot();
    let before_coll = collective::snapshot();
    for _ in 0..steps {
        let mut feed = vec![PAD; b];
        feed[slot] = last;
        last = e.decode_step(&feed).unwrap()[slot];
        e.kv.push_token(slot);
    }
    let dx = transfer::snapshot().delta_since(&before_xfer);
    let dc = collective::snapshot().delta_since(&before_coll);

    // the host<->device budget is unchanged by sharding: collective
    // traffic rides its own meter, not the transfer gauges
    let per_step = (dx.bytes_uploaded + dx.bytes_fetched) / steps;
    assert!(
        per_step <= 64 * 1024,
        "sharded decode moves {per_step} B/step over the host boundary \
         (budget 64 KiB)"
    );
    // two collective points per layer per step: attention head gather
    // + MLP hidden gather; the hot path never all-reduces (summation
    // order would stop being bit-identical)
    assert!(
        dc.all_gathers >= steps * 2 * nl,
        "expected >= {} all-gathers, saw {}",
        steps * 2 * nl,
        dc.all_gathers
    );
    assert!(dc.bytes_gathered > 0, "gathered bytes must be metered");
    assert_eq!(dc.bytes_reduced, 0, "no all-reduce on the decode hot path");
    assert!(collective::last_skew_seconds() >= 0.0);
}

#[test]
fn killed_shard_surfaces_one_typed_error_and_peers_survive() {
    let _g = serial();
    let mut cfg = cfg_mha();
    cfg.n_shards = 2;
    let mut e = Engine::new(cfg.session().unwrap(), Scheme::fp()).unwrap();
    let prompt: Vec<i32> =
        e.session.corpus.split("heldout").unwrap().seq(1)[..5].to_vec();
    let b = e.session.manifest.serve_batch;
    let slot = e.kv.alloc(1, prompt.len()).unwrap();

    // kill shard 1 exactly once: shard 0, waiting at the first
    // collective, must wake via bus poisoning (this call returning at
    // all proves no deadlock) and the one error must be the injected
    // fault, not a peer's secondary "collective aborted"
    faults::arm(FaultPlan::parse("seed=5,execute=1,max=1,shard=1").unwrap());
    let err = e.prefill(slot, &prompt).unwrap_err();
    let (op, transient) =
        faults::classify(&err).expect("engine error must stay typed");
    assert_eq!(op, FaultOp::Execute);
    assert!(transient, "injected shard fault should classify transient");

    // the budget is global across group runs, so the retry runs clean
    let mut last = e.prefill(slot, &prompt).unwrap();
    for _ in 0..2 {
        let mut feed = vec![PAD; b];
        feed[slot] = last;
        last = e.decode_step(&feed).unwrap()[slot];
        e.kv.push_token(slot);
    }
    let injected = faults::disarm().map(|st| st.total()).unwrap_or(0);
    assert_eq!(injected, 1, "shard=1 selector must inject exactly once");
}

#[test]
fn sharded_scheduler_retries_shard_fault_and_serves_bit_identically() {
    let _g = serial();
    let run = |faulted: bool| -> (Vec<Vec<i32>>, usize, u64) {
        let mut cfg = cfg_gqa();
        cfg.n_shards = 2;
        let s = cfg.session().unwrap();
        let prompts: Vec<Vec<i32>> = (0..s.manifest.serve_batch)
            .map(|i| s.corpus.split("heldout").unwrap().seq(i)[..6].to_vec())
            .collect();
        let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
        if faulted {
            faults::arm(
                FaultPlan::parse("seed=7,execute=1,max=1,shard=0").unwrap(),
            );
        }
        for (i, p) in prompts.iter().enumerate() {
            let mut r = Request::new(1 + i as u64, p.clone(), 5);
            r.stop_token = None;
            sched.submit_request(r);
        }
        let mut resp = sched.run_to_completion().unwrap();
        let injected = faults::disarm().map(|st| st.total()).unwrap_or(0);
        resp.sort_by_key(|r| r.id);
        assert!(resp.iter().all(|r| r.finished == FinishReason::MaxTokens));
        (
            resp.into_iter().map(|r| r.tokens).collect(),
            sched.metrics.retries_total(),
            injected,
        )
    };
    let (clean, _, _) = run(false);
    let (faulted, retries, injected) = run(true);
    assert_eq!(injected, 1, "one shard killed exactly once");
    assert!(retries >= 1, "the scheduler must preempt and requeue in place");
    assert_eq!(faulted, clean, "recovered sharded run must be bit-identical");
}
