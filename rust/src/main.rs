//! `cushiond` — the CushionCache CLI: calibration, greedy prefix search,
//! quantization-aware prefix tuning, evaluation, and serving.
//!
//! Quickstart (after `make artifacts`):
//!   cushiond list
//!   cushiond pipeline --variant tl-llama --stride 4
//!   cushiond eval --variant tl-llama --gran pts --cushion default
//!   cushiond serve --variant tl-llama --gran pts --cushion default

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use cushioncache::coordinator::server::Server;
use cushioncache::coordinator::{Engine, Router, Scheduler};
use cushioncache::cushion::{self, SearchCfg, TuneCfg};
use cushioncache::eval::{perplexity, tasks as evtasks};
use cushioncache::model::session::{Cushion, Session};
use cushioncache::quant::calibrate;
use cushioncache::quant::scheme::{Algorithm, Granularity, Scheme, SMOOTH_ALPHA};
use cushioncache::util::cli::Cli;
use cushioncache::util::logging;

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn gran_of(s: &str) -> anyhow::Result<Granularity> {
    Ok(match s {
        "fp" => Granularity::Fp,
        "pts" => Granularity::PerTensorStatic,
        "ptd" => Granularity::PerTensorDynamic,
        "ptk" => Granularity::PerTokenDynamic,
        _ => anyhow::bail!("unknown granularity '{s}' (fp|pts|ptd|ptk)"),
    })
}

fn run() -> anyhow::Result<()> {
    let cli = Cli::new(
        "cushiond — CushionCache (EMNLP 2024) coordinator\n\
         commands: list | calibrate | search | tune | pipeline | eval | serve\n\
         | bench-diff <base.json> <new.json> | trace-check <trace.json>",
    )
    .positional("command", "subcommand")
    .opt("variant", "tl-llama", "model variant (see `list`)")
    .opt("backend", "auto", "execution backend: auto|xla|ref (ref = the \
         pure-Rust interpreter, no artifacts/XLA needed; also honors \
         CUSHION_BACKEND)")
    .opt("gran", "pts", "activation quant granularity: fp|pts|ptd|ptk")
    .opt("bits", "8", "activation/weight bits")
    .opt("cushion", "", "cushion name to load ('' = none)")
    .opt("save", "default", "cushion name to save under")
    .opt("stride", "1", "search vocab stride (1 = full sweep)")
    .opt("max-len", "8", "max prefix length")
    .opt("tau", "0.5", "search early-stop threshold")
    .opt("epochs", "2", "prefix-tuning epochs")
    .opt("addr", "127.0.0.1:7199", "serve address")
    .opt("modes", "", "serve: comma-separated granularities behind one \
         router (e.g. 'fp,pts'); '' = single engine with --gran")
    .opt("queue-limit", "64", "serve: max queued+running requests before \
         'overloaded' rejections")
    .opt("shards", "0", "serve: tensor-parallel shard count (0 = the \
         manifest's n_shards; >1 runs attention heads / MLP columns \
         split across a lock-step shard group on the reference \
         interpreter)")
    .opt("replicas", "1", "serve: engine replicas per mode behind the \
         router (health-checked; a broken replica's work fails over to \
         its siblings)")
    .opt("prefill-chunk", "0", "serve: per-step prefill token budget; \
         long prompts prefill in chunks interleaved with decode so no \
         decode step stalls behind a full prompt (0 = single-shot \
         prefill; engine-gated, bit-identical in fp/static modes)")
    .opt("tol", "0.10", "bench-diff: mean-latency regression tolerance \
         (fraction; transfer growth always fails)")
    .opt("trace-out", "", "serve: export a Chrome-trace JSON of the run \
         to this file on shutdown (open in chrome://tracing or Perfetto; \
         '' = tracing off)")
    .opt("metrics-interval", "0", "serve: log a Prometheus-format metrics \
         snapshot every N seconds (0 = only at drain/shutdown)")
    .opt("act-sample", "16", "serve: meter activation absmax/clip-rate \
         every Nth decode step (0 = off)")
    .opt("faults", "", "fault-injection plan, e.g. \
         'seed=1,execute=0.1,stall_ms=5' (see runtime::faults; also \
         honors CUSHION_FAULTS; '' = off)")
    .flag("smooth", "apply SmoothQuant (alpha 0.8)")
    .flag("no-tune", "pipeline: skip the tuning stage");
    let args = cli.parse_env()?;
    // `--backend` wins over the environment; Session::load and every
    // Client::auto() constructed below read CUSHION_BACKEND
    let backend = args.get("backend");
    if backend != "auto" {
        cushioncache::runtime::BackendKind::parse(backend)?; // validate
        std::env::set_var("CUSHION_BACKEND", backend);
    }
    // `--faults` wins over the environment the same way; every Client
    // constructed below arms the plan and wraps its backend
    let faults = args.get("faults");
    if !faults.is_empty() {
        cushioncache::runtime::FaultPlan::parse(faults)?; // validate
        std::env::set_var("CUSHION_FAULTS", faults);
    }
    let cmd = args
        .positionals()
        .first()
        .map(String::as_str)
        .unwrap_or("help");

    match cmd {
        "list" => {
            for v in cushioncache::model::available_variants() {
                println!("{v}");
            }
            Ok(())
        }
        "calibrate" => {
            let mut s = load_session(&args)?;
            let scheme = scheme_of(&args)?;
            let res = calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
            let (site, width) = res.minmax.widest();
            println!(
                "calibrated {} sites over {} batches; widest: {} ({width:.2})",
                s.manifest.n_sites, res.batches, s.manifest.site_name(site)
            );
            Ok(())
        }
        "search" => {
            let mut s = load_session(&args)?;
            maybe_smooth(&mut s, &args)?;
            let cfg = SearchCfg {
                tau: args.get_f64("tau")? as f32,
                max_len: args.get_usize("max-len")?,
                vocab_stride: args.get_usize("stride")?,
                ..Default::default()
            };
            let res = cushion::greedy_search(&s, &cfg)?;
            println!(
                "prefix {:?} (lq {:?}, {} candidates, {:.1}s)",
                res.prefix, res.lq_trace, res.candidates_scored, res.seconds
            );
            let kv = s.compute_prefix_kv(&res.prefix)?;
            let c = Cushion { len: res.prefix.len(), tokens: res.prefix, kv };
            let path = cushion::save_cushion(&s.manifest.variant, args.get("save"), &c)?;
            println!("saved {}", path.display());
            Ok(())
        }
        "tune" => {
            let mut s = load_session(&args)?;
            maybe_smooth(&mut s, &args)?;
            let base = cushion::load_cushion(&s.manifest.variant, args.get("save"))?;
            let cfg = TuneCfg {
                epochs: args.get_usize("epochs")?,
                ..Default::default()
            };
            let res = cushion::tune::tune_prefix(&s, &base.tokens, &cfg)?;
            let c = Cushion { tokens: base.tokens, len: base.len, kv: res.kv };
            let path = cushion::save_cushion(&s.manifest.variant, args.get("save"), &c)?;
            println!(
                "tuned {} steps ({:.1}s), loss {:.4} -> {:.4}; saved {}",
                res.steps,
                res.seconds,
                res.loss_trace.first().unwrap_or(&0.0),
                res.loss_trace.last().unwrap_or(&0.0),
                path.display()
            );
            Ok(())
        }
        "pipeline" => {
            let mut s = load_session(&args)?;
            maybe_smooth(&mut s, &args)?;
            let scheme = scheme_of(&args)?;
            // 1) baseline calibration + eval
            calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
            let before = perplexity::perplexity(&s, &scheme, "heldout", 8)?;
            // 2) greedy search (paper §4.1)
            let cfg = SearchCfg {
                vocab_stride: args.get_usize("stride")?,
                max_len: args.get_usize("max-len")?,
                ..Default::default()
            };
            let res = cushion::greedy_search(&s, &cfg)?;
            println!("searched prefix: {:?}", res.prefix);
            // 3) quantization-aware prefix tuning (paper §4.2)
            let kv = if args.flag("no-tune") {
                s.compute_prefix_kv(&res.prefix)?
            } else {
                cushion::tune::tune_prefix(&s, &res.prefix, &TuneCfg::default())?.kv
            };
            s.set_cushion(Cushion {
                tokens: res.prefix.clone(),
                len: res.prefix.len(),
                kv,
            })?;
            // 4) recalibrate with the cushion in place + final eval
            calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
            let after = perplexity::perplexity(&s, &scheme, "heldout", 8)?;
            println!(
                "{} {}: ppl {before:.2} -> {after:.2}",
                s.manifest.variant,
                scheme.label()
            );
            let c = s.cushion().cloned().unwrap();
            let path = cushion::save_cushion(&s.manifest.variant, args.get("save"), &c)?;
            println!("saved {}", path.display());
            Ok(())
        }
        "eval" => {
            let mut s = load_session(&args)?;
            maybe_smooth(&mut s, &args)?;
            let scheme = scheme_of(&args)?;
            if scheme.gran.needs_calibration() {
                calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
            }
            let ppl = perplexity::perplexity(&s, &scheme, "heldout", 8)?;
            println!(
                "{} {}: heldout ppl {ppl:.3}",
                s.manifest.variant,
                scheme.label()
            );
            let task_file = cushioncache::util::fsutil::variant_dir(&s.manifest.variant)
                .join("tasks.bin");
            let all = cushioncache::data::tasks::load(&task_file)?;
            let mut scores = Vec::new();
            for name in cushioncache::data::tasks::ZERO_SHOT {
                let t = cushioncache::data::tasks::find(&all, name)?;
                let sc = evtasks::eval_task(&s, &scheme, t, 50)?;
                println!("  {:16} acc {:.3}", sc.name, sc.accuracy);
                scores.push(sc);
            }
            println!("  zero-shot avg: {:.3}", evtasks::zero_shot_average(&scores));
            Ok(())
        }
        "serve" => {
            // --trace-out: the ring is thread-local and the scheduler
            // steps on this thread (serve loop), so enable/export here
            // bracket exactly the events of this serve run
            let trace_out = args.get("trace-out").to_string();
            if !trace_out.is_empty() {
                cushioncache::runtime::trace::enable(
                    cushioncache::runtime::trace::DEFAULT_CAPACITY,
                );
            }
            let act_sample = args.get_usize("act-sample")? as u32;
            let server = Server::new(args.get("addr"))
                .with_queue_limit(args.get_usize("queue-limit")?)
                .with_metrics_interval(args.get_usize("metrics-interval")? as u64);
            let stop = Arc::new(AtomicBool::new(false));
            let modes = args.get("modes");
            let replicas = args.get_usize("replicas")?.max(1);
            let res = if modes.is_empty() && replicas == 1 {
                let mut s = load_session(&args)?;
                maybe_smooth(&mut s, &args)?;
                apply_shards(&mut s, &args)?;
                let scheme = scheme_of(&args)?;
                if scheme.gran.needs_calibration() {
                    calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
                }
                let engine = Engine::new(s, scheme)?;
                if engine.n_shards() > 1 {
                    log::info!("tensor-parallel: {} shards", engine.n_shards());
                }
                let mut sched = Scheduler::new(engine);
                sched.set_prefill_chunk(prefill_chunk(&args)?);
                sched.set_act_sample(act_sample);
                server.serve(sched, stop)
            } else {
                // one process, several quantization variants and/or
                // several replicas per variant: requests pick a mode
                // with {"mode": "<gran>"}; the router health-checks
                // replicas and fails a broken one's work over to its
                // siblings
                let mode_list: Vec<String> = if modes.is_empty() {
                    vec![args.get("gran").to_string()]
                } else {
                    modes
                        .split(',')
                        .map(str::trim)
                        .filter(|m| !m.is_empty())
                        .map(String::from)
                        .collect()
                };
                let mut router = Router::new();
                for mode in &mode_list {
                    for _ in 0..replicas {
                        let mut s = load_session(&args)?;
                        maybe_smooth(&mut s, &args)?;
                        apply_shards(&mut s, &args)?;
                        let scheme = scheme_for(gran_of(mode)?, &args)?;
                        if scheme.gran.needs_calibration() {
                            calibrate::calibrate_into(&mut s, scheme.act_levels(), 8)?;
                        }
                        let mut sched = Scheduler::new(Engine::new(s, scheme)?);
                        sched.set_prefill_chunk(prefill_chunk(&args)?);
                        sched.set_act_sample(act_sample);
                        router.add_engine(mode, sched);
                    }
                }
                log::info!(
                    "router serving modes {:?} x {replicas} replica(s)",
                    router.modes()
                );
                server.serve_router(router, stop)
            };
            if !trace_out.is_empty() {
                let text = cushioncache::runtime::trace::export_string();
                let n = cushioncache::runtime::trace::check_export(&text)?;
                std::fs::write(&trace_out, &text)?;
                log::info!("wrote {n} trace events to {trace_out}");
            }
            res
        }
        "bench-diff" => {
            // pre-merge perf gate: diff two BENCH_*.json snapshots and
            // fail (exit 1) on a latency regression beyond --tol or on
            // any per-iteration transfer growth (see scripts/bench_diff.sh)
            let pos = args.positionals();
            let (base, new) = match (pos.get(1), pos.get(2)) {
                (Some(b), Some(n)) => (b.as_str(), n.as_str()),
                _ => anyhow::bail!(
                    "usage: cushiond bench-diff <base.json> <new.json> [--tol 0.10]"
                ),
            };
            let tol = args.get_f64("tol")?;
            let report = cushioncache::bench::diff::diff_files(base, new, tol)?;
            for n in &report.notes {
                println!("note: {n}");
            }
            if report.passed() {
                println!("bench-diff: OK ({base} -> {new}, tol {:.0}%)", tol * 100.0);
                Ok(())
            } else {
                for r in &report.regressions {
                    eprintln!("REGRESSION: {r}");
                }
                anyhow::bail!(
                    "bench-diff: {} regression(s) ({base} -> {new})",
                    report.regressions.len()
                );
            }
        }
        "trace-check" => {
            // validate an exported Chrome-trace file (the traced-serve
            // gate in scripts/test_hermetic.sh)
            let pos = args.positionals();
            let Some(path) = pos.get(1) else {
                anyhow::bail!("usage: cushiond trace-check <trace.json>");
            };
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
            let n = cushioncache::runtime::trace::check_export(&text)?;
            println!("trace-check: OK ({n} events, {path})");
            Ok(())
        }
        other => anyhow::bail!(
            "unknown command '{other}'\ncommands: list | calibrate | search | \
             tune | pipeline | eval | serve | bench-diff | trace-check \
             (--help for options)"
        ),
    }
}

fn load_session(args: &cushioncache::util::cli::Args) -> anyhow::Result<Session> {
    let mut s = Session::load(args.get("variant"))?;
    let name = args.get("cushion");
    if !name.is_empty() {
        let c = cushion::load_cushion(&s.manifest.variant, name)?;
        log::info!("loaded cushion '{name}' ({} tokens)", c.len);
        s.set_cushion(c)?;
    }
    Ok(s)
}

/// `--shards N` override for serve: validated against the model's head
/// and MLP geometry before the engine resolves per-shard graphs.
fn apply_shards(
    s: &mut Session,
    args: &cushioncache::util::cli::Args,
) -> anyhow::Result<()> {
    let n = args.get_usize("shards")?;
    if n > 0 {
        cushioncache::runtime::ShardPlan::validate(
            s.manifest.n_kv_heads,
            s.manifest.d_ff,
            n,
        )?;
        s.manifest.n_shards = n;
    }
    Ok(())
}

/// `--prefill-chunk N` for serve: 0 = single-shot prefill (off).
fn prefill_chunk(
    args: &cushioncache::util::cli::Args,
) -> anyhow::Result<Option<usize>> {
    let n = args.get_usize("prefill-chunk")?;
    Ok((n > 0).then_some(n))
}

fn scheme_of(args: &cushioncache::util::cli::Args) -> anyhow::Result<Scheme> {
    scheme_for(gran_of(args.get("gran"))?, args)
}

/// Scheme for one granularity, honoring the shared --bits/--smooth flags
/// (the router serve path builds one per --modes entry).
fn scheme_for(
    gran: Granularity,
    args: &cushioncache::util::cli::Args,
) -> anyhow::Result<Scheme> {
    let bits = args.get_usize("bits")? as u32;
    let algorithm = if args.flag("smooth") {
        Algorithm::SmoothQuant { alpha: SMOOTH_ALPHA }
    } else {
        Algorithm::Naive
    };
    Ok(if gran == Granularity::Fp {
        Scheme::fp()
    } else {
        Scheme::wnan(bits, gran, algorithm)
    })
}

/// Apply SmoothQuant to the session (calibrate -> migrate -> install).
fn maybe_smooth(s: &mut Session, args: &cushioncache::util::cli::Args) -> anyhow::Result<()> {
    if !args.flag("smooth") {
        return Ok(());
    }
    let calib = calibrate::calibrate(s, 8)?;
    let mut w = s.base_weights.clone();
    let inv = cushioncache::quant::smoothquant::apply(
        &mut w,
        &calib,
        s.manifest.n_layers,
        s.manifest.d_model,
        s.manifest.act == "swiglu",
        SMOOTH_ALPHA,
    )?;
    s.set_weights(w);
    s.set_inv_smooth(inv);
    Ok(())
}
