//! QuaRot-lite (Ashkboos et al. 2024): rotate the residual stream by an
//! orthonormal Hadamard matrix so outlier magnitude is spread across all
//! channels, folded entirely into the weights (function-preserving):
//!
//!   embed' = embed R            lm_head' = Rᵀ diag(γ_f) lm_head
//!   per layer (pre-RMSNorm only — rmsnorm commutes with rotation once
//!   the gain is folded into the consuming linear):
//!     W_in'  = Rᵀ diag(γ) W_in      (wq wk wv | wg wu),  γ := 1
//!     W_out' = W_out R              (wo | wd)
//!
//! "lite": the residual rotation only (no online Hadamard on the
//! down_proj input, no KV-cache rotation) — documented in DESIGN.md §1.

use crate::model::manifest::Manifest;
use crate::model::weights::Weights;
use crate::util::tensor::hadamard;

pub fn applicable(manifest: &Manifest) -> bool {
    manifest.is_pre_norm() && manifest.d_model.is_power_of_two()
}

pub fn apply(weights: &mut Weights, manifest: &Manifest) -> crate::Result<()> {
    anyhow::ensure!(
        applicable(manifest),
        "QuaRot requires a pre-RMSNorm variant with power-of-two d_model"
    );
    let d = manifest.d_model;
    let r = hadamard(d);
    let rt = r.transpose2();

    // embeddings: rows are residual vectors
    let emb = weights.get_mut("embed")?;
    *emb = emb.matmul(&r);

    for l in 0..manifest.n_layers {
        let g1 = weights.get(&Weights::layer_name(l, "ln1_g"))?.data.clone();
        for base in ["wq", "wk", "wv"] {
            let w = weights.get_mut(&Weights::layer_name(l, base))?;
            w.scale_rows(&g1);
            *w = rt.matmul(w);
        }
        weights.get_mut(&Weights::layer_name(l, "ln1_g"))?.data.fill(1.0);

        let wo = weights.get_mut(&Weights::layer_name(l, "wo"))?;
        *wo = wo.matmul(&r);

        let g2 = weights.get(&Weights::layer_name(l, "ln2_g"))?.data.clone();
        let mut mlp_in = vec![Weights::layer_name(l, "wu")];
        if manifest.act == "swiglu" {
            mlp_in.push(Weights::layer_name(l, "wg"));
        }
        for name in &mlp_in {
            let w = weights.get_mut(name)?;
            w.scale_rows(&g2);
            *w = rt.matmul(w);
        }
        weights.get_mut(&Weights::layer_name(l, "ln2_g"))?.data.fill(1.0);

        let wd = weights.get_mut(&Weights::layer_name(l, "wd"))?;
        *wd = wd.matmul(&r);
    }

    let gf = weights.get("lnf_g")?.data.clone();
    let lm = weights.get_mut("lm_head")?;
    lm.scale_rows(&gf);
    *lm = rt.matmul(lm);
    weights.get_mut("lnf_g")?.data.fill(1.0);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;

    #[test]
    fn rotation_spreads_outliers() {
        // a residual vector with one massive channel, rotated, has a much
        // smaller max/median ratio — QuaRot's core claim.
        let d = 256;
        let r = hadamard(d);
        let mut x = Tensor::zeros(&[1, d]);
        x.data[13] = 1000.0;
        for i in 0..d {
            x.data[i] += ((i * 31) as f32 * 0.1).sin();
        }
        let xr = x.matmul(&r);
        let ratio = |t: &Tensor| {
            let mut mags: Vec<f32> = t.data.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            mags[d - 1] / mags[d / 2].max(1e-6)
        };
        assert!(ratio(&x) > 100.0);
        assert!(ratio(&xr) < 10.0, "rotated ratio {}", ratio(&xr));
    }

    #[test]
    fn rotation_preserves_norm() {
        let d = 64;
        let r = hadamard(d);
        let x = Tensor::new(vec![1, d], (0..d).map(|i| (i as f32).cos()).collect());
        let xr = x.matmul(&r);
        let n = |t: &Tensor| t.data.iter().map(|v| v * v).sum::<f32>();
        assert!((n(&x) - n(&xr)).abs() / n(&x) < 1e-4);
    }
}
