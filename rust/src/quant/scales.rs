//! Scale/zero-point math for asymmetric activation quantization
//! (mirrors quantlib.ranges_from_minmax; the golden test in rust/tests/
//! cross-checks against graph-produced minmax).

use crate::util::tensor::Tensor;

/// Accumulated per-site (min, max) statistics.
#[derive(Clone, Debug)]
pub struct MinMax {
    pub n_sites: usize,
    pub mins: Vec<f32>,
    pub maxs: Vec<f32>,
}

impl MinMax {
    pub fn new(n_sites: usize) -> Self {
        Self {
            n_sites,
            mins: vec![f32::INFINITY; n_sites],
            maxs: vec![f32::NEG_INFINITY; n_sites],
        }
    }

    /// Merge one batch's [n_sites, 2] minmax tensor (graph output).
    pub fn merge(&mut self, batch: &Tensor) {
        let (r, c) = batch.dims2();
        assert_eq!((r, c), (self.n_sites, 2));
        for i in 0..r {
            self.mins[i] = self.mins[i].min(batch.at2(i, 0));
            self.maxs[i] = self.maxs[i].max(batch.at2(i, 1));
        }
    }

    /// (lo, scale) ranges tensor [n_sites, 2] for the pts graphs.
    pub fn to_ranges(&self, levels: f32) -> Tensor {
        let mut out = Tensor::zeros(&[self.n_sites, 2]);
        for i in 0..self.n_sites {
            let lo = self.mins[i].min(0.0);
            let hi = self.maxs[i].max(0.0);
            out.set2(i, 0, lo);
            out.set2(i, 1, ((hi - lo).max(1e-8)) / levels);
        }
        out
    }

    /// Widest per-site dynamic range (diagnostics / Table 5 support).
    pub fn widest(&self) -> (usize, f32) {
        let mut best = (0, 0.0f32);
        for i in 0..self.n_sites {
            let w = self.maxs[i] - self.mins[i];
            if w > best.1 {
                best = (i, w);
            }
        }
        best
    }
}

/// Placeholder ranges for graphs that ignore them (fp/ptd/ptk modes).
pub fn unit_ranges(n_sites: usize) -> Tensor {
    let mut t = Tensor::zeros(&[n_sites, 2]);
    for i in 0..n_sites {
        t.set2(i, 1, 1.0);
    }
    t
}

/// Symmetric group-wise weight quantize-dequantize along the input dim
/// (mirrors quantlib.quant_weight; w: [K, N], in place).
pub fn quant_weight_inplace(w: &mut Tensor, bits: u32, group: usize) {
    let (k, n) = w.dims2();
    let g = if k % group == 0 { group } else { k };
    let qmax = ((1u64 << (bits - 1)) - 1) as f32;
    for gs in (0..k).step_by(g) {
        for j in 0..n {
            let mut amax = 0.0f32;
            for i in gs..gs + g {
                amax = amax.max(w.at2(i, j).abs());
            }
            let scale = (amax / qmax).max(1e-8);
            for i in gs..gs + g {
                let q = (w.at2(i, j) / scale).round().clamp(-qmax, qmax);
                w.set2(i, j, q * scale);
            }
        }
    }
}

/// Weight tensors the W-quant applies to (block linears only, matching
/// the paper's setup: embeddings/norms/head stay FP).
pub fn is_quantized_weight(name: &str) -> bool {
    let base = name.rsplit('.').next().unwrap_or(name);
    matches!(base, "wq" | "wk" | "wv" | "wo" | "wg" | "wu" | "wd")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_and_ranges() {
        let mut mm = MinMax::new(2);
        let b1 = Tensor::new(vec![2, 2], vec![-1.0, 2.0, 0.0, 5.0]);
        let b2 = Tensor::new(vec![2, 2], vec![-3.0, 1.0, 0.5, 4.0]);
        mm.merge(&b1);
        mm.merge(&b2);
        assert_eq!(mm.mins, vec![-3.0, 0.0]);
        assert_eq!(mm.maxs, vec![2.0, 5.0]);
        let r = mm.to_ranges(255.0);
        assert!((r.at2(0, 0) - -3.0).abs() < 1e-6);
        assert!((r.at2(0, 1) - 5.0 / 255.0).abs() < 1e-6);
        // site 1 keeps zero representable
        assert_eq!(r.at2(1, 0), 0.0);
    }

    #[test]
    fn weight_qdq_is_close_and_grid_aligned() {
        let mut w = Tensor::new(vec![4, 2], vec![0.9, -0.5, 0.3, 0.1, -1.0, 0.7, 0.2, -0.2]);
        let orig = w.clone();
        quant_weight_inplace(&mut w, 8, 4);
        for (a, b) in w.data.iter().zip(&orig.data) {
            assert!((a - b).abs() < 1.0 / 127.0 + 1e-6);
        }
    }

    #[test]
    fn weight_qdq_low_bits_coarser() {
        let data: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let orig = Tensor::new(vec![64, 1], data);
        let mut w8 = orig.clone();
        let mut w4 = orig.clone();
        quant_weight_inplace(&mut w8, 8, 64);
        quant_weight_inplace(&mut w4, 4, 64);
        let err = |w: &Tensor| -> f32 {
            w.data.iter().zip(&orig.data).map(|(a, b)| (a - b).powi(2)).sum()
        };
        assert!(err(&w4) > err(&w8));
    }

    #[test]
    fn quantized_weight_filter() {
        assert!(is_quantized_weight("layer2.wq"));
        assert!(is_quantized_weight("layer0.wd"));
        assert!(!is_quantized_weight("embed"));
        assert!(!is_quantized_weight("layer1.ln1_g"));
        assert!(!is_quantized_weight("lm_head"));
    }
}
