//! Quantization: schemes, scale math, static-range calibration, and the
//! host-side weight transforms (weight qdq, SmoothQuant, AWQ, QuaRot).
//!
//! Activation quantization itself happens inside the AOT graphs (the
//! paper's W8A8 simulation, python/compile/quantlib.py); this module owns
//! everything computed on the host: calibrated ranges, migration scales
//! folded into the weight bundle, rotations, and weight fake-quant.

pub mod awq;
pub mod calibrate;
pub mod quarot;
pub mod scales;
pub mod scheme;
pub mod smoothquant;

pub use scheme::{Algorithm, Granularity, Scheme};
