//! Quantization scheme configuration (paper §5.1 "Base algorithms").

/// Activation quantization granularity. Orders from the most
/// hardware-friendly (per-tensor static: fixed scalar scale, no runtime
/// reduction, no scale AllReduce under tensor parallelism) to the least
/// (per-token dynamic) — the axis of the paper's Tables 1/2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Granularity {
    Fp,
    PerTensorStatic,
    PerTensorDynamic,
    PerTokenDynamic,
}

impl Granularity {
    /// Suffix of the fwd/prefill/decode graphs implementing it.
    pub fn graph_suffix(self) -> &'static str {
        match self {
            Granularity::Fp => "fp",
            Granularity::PerTensorStatic => "pts",
            Granularity::PerTensorDynamic => "ptd",
            Granularity::PerTokenDynamic => "ptk",
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Granularity::Fp => "FP",
            Granularity::PerTensorStatic => "Per-tensor Static",
            Granularity::PerTensorDynamic => "Per-tensor Dynamic",
            Granularity::PerTokenDynamic => "Per-token Dynamic",
        }
    }

    pub fn needs_calibration(self) -> bool {
        matches!(self, Granularity::PerTensorStatic)
    }

    pub const ALL_QUANT: [Granularity; 3] = [
        Granularity::PerTensorStatic,
        Granularity::PerTensorDynamic,
        Granularity::PerTokenDynamic,
    ];
}

/// Base activation-quantization algorithm. SmoothQuant's O3/O2/O1 map to
/// (SmoothQuant, pts/ptd/ptk) pairs as in the paper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algorithm {
    Naive,
    SmoothQuant { alpha: f32 },
}

impl Algorithm {
    pub fn label(self, g: Granularity) -> String {
        match self {
            Algorithm::Naive => g.label().to_string(),
            Algorithm::SmoothQuant { .. } => match g {
                Granularity::PerTensorStatic => "SmoothQuant-O3".into(),
                Granularity::PerTensorDynamic => "SmoothQuant-O2".into(),
                Granularity::PerTokenDynamic => "SmoothQuant-O1".into(),
                Granularity::Fp => "SmoothQuant(FP)".into(),
            },
        }
    }
}

pub const SMOOTH_ALPHA: f32 = 0.8; // paper §5.1

#[derive(Clone, Copy, Debug)]
pub struct Scheme {
    pub gran: Granularity,
    pub algorithm: Algorithm,
    /// Activation bits (8 for the main tables; 6/4 for Table 4).
    pub act_bits: u32,
    /// Weight bits (0 = FP weights).
    pub weight_bits: u32,
    /// KV-cache bits (0 = FP cache; 2 = KIVI, Table 9).
    pub kv_bits: u32,
}

impl Scheme {
    pub fn fp() -> Self {
        Scheme {
            gran: Granularity::Fp,
            algorithm: Algorithm::Naive,
            act_bits: 0,
            weight_bits: 0,
            kv_bits: 0,
        }
    }

    pub fn w8a8(gran: Granularity, algorithm: Algorithm) -> Self {
        Scheme { gran, algorithm, act_bits: 8, weight_bits: 8, kv_bits: 0 }
    }

    pub fn wnan(bits: u32, gran: Granularity, algorithm: Algorithm) -> Self {
        Scheme { gran, algorithm, act_bits: bits, weight_bits: bits, kv_bits: 0 }
    }

    /// `levels` graph input: 2^bits - 1.
    pub fn act_levels(&self) -> f32 {
        if self.act_bits == 0 {
            (1u64 << 24) as f32 // effectively FP (identity grid)
        } else {
            ((1u64 << self.act_bits) - 1) as f32
        }
    }

    /// kv_levels graph input (>= 2^20 disables KV quantization in-graph).
    pub fn kv_levels(&self) -> f32 {
        if self.kv_bits == 0 {
            (1u64 << 24) as f32
        } else {
            ((1u64 << self.kv_bits) - 1) as f32
        }
    }

    pub fn label(&self) -> String {
        if self.gran == Granularity::Fp {
            return "FP16".into();
        }
        let base = self.algorithm.label(self.gran);
        if self.act_bits != 8 {
            format!("{base} (W{0}A{0})", self.act_bits)
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels() {
        assert_eq!(Scheme::w8a8(Granularity::PerTensorStatic, Algorithm::Naive)
                       .act_levels(), 255.0);
        assert_eq!(
            Scheme::wnan(4, Granularity::PerTokenDynamic, Algorithm::Naive)
                .act_levels(),
            15.0
        );
        assert!(Scheme::fp().act_levels() > 1e6);
    }

    #[test]
    fn labels() {
        let s = Scheme::w8a8(
            Granularity::PerTensorStatic,
            Algorithm::SmoothQuant { alpha: 0.8 },
        );
        assert_eq!(s.label(), "SmoothQuant-O3");
        assert_eq!(Scheme::fp().label(), "FP16");
    }

    #[test]
    fn graph_suffixes() {
        assert_eq!(Granularity::PerTokenDynamic.graph_suffix(), "ptk");
        assert!(Granularity::PerTensorStatic.needs_calibration());
        assert!(!Granularity::PerTensorDynamic.needs_calibration());
    }
}
