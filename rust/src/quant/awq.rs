//! AWQ (Lin et al. 2024), simplified: activation-aware weight-only
//! quantization. Salient input channels (by activation magnitude) are
//! scaled up before group quantization and the inverse is folded back
//! into the stored weight, so the activation path is untouched:
//!     W ~= diag(1/s) . Q(diag(s) . W),   s_j = a_j^alpha (geo-normalized)
//! The real AWQ grid-searches alpha per layer; we use the fixed
//! alpha = 0.5 the paper reports as the robust default (simplification
//! documented in DESIGN.md §1). Mirrors quantlib.awq_scale_weight.

use crate::model::manifest::Manifest;
use crate::model::weights::Weights;

use super::calibrate::CalibResult;
use super::scales::quant_weight_inplace;

pub const AWQ_ALPHA: f32 = 0.5;
pub const AWQ_GROUP: usize = 64;

/// AWQ-quantize one weight matrix in place given its input activations'
/// per-channel absmax.
pub fn awq_weight(w: &mut crate::util::tensor::Tensor, act_absmax: &[f32],
                  bits: u32, group: usize, alpha: f32) {
    let (k, _) = w.dims2();
    assert_eq!(k, act_absmax.len());
    let mut s: Vec<f32> = act_absmax.iter().map(|&a| a.max(1e-5).powf(alpha)).collect();
    let log_mean = s.iter().map(|v| v.ln()).sum::<f32>() / s.len() as f32;
    let norm = log_mean.exp();
    for v in s.iter_mut() {
        *v /= norm;
    }
    w.scale_rows(&s);
    quant_weight_inplace(w, bits, group);
    let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
    w.scale_rows(&inv);
}

/// Apply AWQ to every block linear of the bundle (weight-only: the
/// activation path and graphs are unchanged — combine with the fp or pts
/// fwd graphs as Table 9 does).
pub fn apply(weights: &mut Weights, manifest: &Manifest, calib: &CalibResult,
             bits: u32) -> crate::Result<()> {
    let has_gate = manifest.act == "swiglu";
    for l in 0..manifest.n_layers {
        for base in ["wq", "wk", "wv"] {
            awq_weight(
                weights.get_mut(&Weights::layer_name(l, base))?,
                calib.chan_attn_in(l), bits, AWQ_GROUP, AWQ_ALPHA,
            );
        }
        awq_weight(
            weights.get_mut(&Weights::layer_name(l, "wo"))?,
            calib.chan_attn_out(l), bits, AWQ_GROUP, AWQ_ALPHA,
        );
        awq_weight(
            weights.get_mut(&Weights::layer_name(l, "wu"))?,
            calib.chan_mlp_in(l), bits, AWQ_GROUP, AWQ_ALPHA,
        );
        if has_gate {
            awq_weight(
                weights.get_mut(&Weights::layer_name(l, "wg"))?,
                calib.chan_mlp_in(l), bits, AWQ_GROUP, AWQ_ALPHA,
            );
        }
        awq_weight(
            weights.get_mut(&Weights::layer_name(l, "wd"))?,
            calib.chan_mlp_hidden(l), bits, AWQ_GROUP, AWQ_ALPHA,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;

    #[test]
    fn awq_protects_salient_channels() {
        // channel 0 has huge activations -> AWQ should quantize it with
        // smaller relative error than plain group quant does.
        let k = 64;
        let mut w = Tensor::zeros(&[k, 1]);
        for i in 0..k {
            w.data[i] = if i == 0 { 0.01 } else { 1.0 - 0.001 * i as f32 };
        }
        let mut act = vec![1.0f32; k];
        act[0] = 1e4;

        let mut plain = w.clone();
        quant_weight_inplace(&mut plain, 3, 64);
        let mut awq = w.clone();
        awq_weight(&mut awq, &act, 3, 64, 0.5);

        let err_plain = (plain.data[0] - w.data[0]).abs();
        let err_awq = (awq.data[0] - w.data[0]).abs();
        assert!(err_awq < err_plain,
                "awq {err_awq} should beat plain {err_plain} on the salient channel");
    }

    #[test]
    fn awq_overall_close() {
        let k = 128;
        let data: Vec<f32> = (0..k).map(|i| ((i * 37) as f32 * 0.01).sin()).collect();
        let w = Tensor::new(vec![k, 1], data);
        let act: Vec<f32> = (0..k).map(|i| 1.0 + (i % 7) as f32).collect();
        let mut q = w.clone();
        awq_weight(&mut q, &act, 8, 64, 0.5);
        for (a, b) in q.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }
}
