//! Static-range calibration (paper §5.1: "for static range quantization,
//! we calibrate using the training split") — runs the stats graph over the
//! calibration corpus split, merging per-site (min, max) and per-channel
//! absolute maxima.
//!
//! Calibration respects the session's current cushion: after installing a
//! CushionCache the ranges must be recomputed, because the whole point is
//! that the post-cushion activation distribution is different (no massive
//! sink rows -> tight ranges).

use crate::model::session::Session;
use crate::util::tensor::Tensor;

use super::scales::MinMax;

#[derive(Clone, Debug)]
pub struct CalibResult {
    pub minmax: MinMax,
    /// [3L, d] per-channel absmax for attn_in / attn_out / mlp_in sites.
    pub chan_d: Tensor,
    /// [L, d_ff] per-channel absmax for mlp_hidden sites.
    pub chan_f: Tensor,
    pub batches: usize,
}

impl CalibResult {
    /// The SmoothQuant activation statistic for layer l:
    /// index 0 = attn_in, 2 = mlp_in within the layer's chan_d triple.
    pub fn chan_attn_in(&self, l: usize) -> &[f32] {
        self.chan_d.row(3 * l)
    }

    pub fn chan_attn_out(&self, l: usize) -> &[f32] {
        self.chan_d.row(3 * l + 1)
    }

    pub fn chan_mlp_in(&self, l: usize) -> &[f32] {
        self.chan_d.row(3 * l + 2)
    }

    pub fn chan_mlp_hidden(&self, l: usize) -> &[f32] {
        self.chan_f.row(l)
    }
}

/// Run calibration over up to `max_batches` batches of the calib split.
pub fn calibrate(session: &Session, max_batches: usize) -> crate::Result<CalibResult> {
    let m = &session.manifest;
    let split = session.corpus.split("calib")?;
    let bsz = m.eval_batch;
    let n_batches = (split.n_seqs / bsz).min(max_batches).max(1);

    let mut minmax = MinMax::new(m.n_sites);
    let mut chan_d: Option<Tensor> = None;
    let mut chan_f: Option<Tensor> = None;

    for bi in 0..n_batches {
        let mut tokens = Vec::with_capacity(bsz * m.seq_len);
        for s in 0..bsz {
            tokens.extend_from_slice(split.seq(bi * bsz + s));
        }
        let out = session.stats(&tokens)?;
        minmax.merge(&out.minmax);
        chan_d = Some(merge_absmax(chan_d.take(), out.chan_d));
        chan_f = Some(merge_absmax(chan_f.take(), out.chan_f));
    }
    Ok(CalibResult {
        minmax,
        chan_d: chan_d.unwrap(),
        chan_f: chan_f.unwrap(),
        batches: n_batches,
    })
}

/// Calibrate and install static ranges for the given activation levels.
pub fn calibrate_into(session: &mut Session, levels: f32,
                      max_batches: usize) -> crate::Result<CalibResult> {
    let res = calibrate(session, max_batches)?;
    session.set_ranges(res.minmax.to_ranges(levels));
    Ok(res)
}

fn merge_absmax(acc: Option<Tensor>, cur: Tensor) -> Tensor {
    match acc {
        None => cur,
        Some(mut a) => {
            assert_eq!(a.shape, cur.shape);
            for (x, y) in a.data.iter_mut().zip(cur.data) {
                *x = x.max(y);
            }
            a
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_absmax_elementwise() {
        let a = Tensor::new(vec![2], vec![1.0, 5.0]);
        let b = Tensor::new(vec![2], vec![3.0, 2.0]);
        let m = merge_absmax(Some(a), b);
        assert_eq!(m.data, vec![3.0, 5.0]);
        let first = merge_absmax(None, Tensor::new(vec![1], vec![9.0]));
        assert_eq!(first.data, vec![9.0]);
    }
}
