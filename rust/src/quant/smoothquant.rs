//! SmoothQuant (Xiao et al. 2023): migrate activation outlier magnitude
//! into the weights with per-channel scales s_j = a_j^alpha / w_j^(1-alpha).
//!
//! Computationally: X W = (X / s)(s W). The graphs apply the division at
//! the attn_in / mlp_in quantization sites via the `inv_smooth` input
//! (quantlib.QuantCtx.inv_smooth), and this module multiplies the
//! consuming weights' input rows by s host-side — valid for every norm
//! placement (the classic "fold into the preceding LayerNorm" is just an
//! inference-time optimization of the same math, only sound for pre-norm).

use crate::model::weights::Weights;
use crate::util::tensor::Tensor;

use super::calibrate::CalibResult;

/// Per-channel migration scales (mirrors quantlib.smooth_scales).
pub fn smooth_scales(act_absmax: &[f32], w_absmax: &[f32], alpha: f32) -> Vec<f32> {
    act_absmax
        .iter()
        .zip(w_absmax)
        .map(|(&a, &w)| {
            let a = a.max(1e-5);
            let w = w.max(1e-5);
            (a.powf(alpha) / w.powf(1.0 - alpha)).clamp(1e-4, 1e4)
        })
        .collect()
}

/// Apply SmoothQuant to the bundle. Returns the `inv_smooth` graph input
/// [L, 2, d] (1/s for attn_in and mlp_in per layer).
pub fn apply(weights: &mut Weights, calib: &CalibResult, n_layers: usize,
             d_model: usize, has_gate: bool, alpha: f32) -> crate::Result<Tensor> {
    let mut inv = Tensor::full(&[n_layers, 2, d_model], 1.0);
    for l in 0..n_layers {
        // pair 1: attn_in -> wq / wk / wv
        let names: Vec<String> = ["wq", "wk", "wv"]
            .iter()
            .map(|b| Weights::layer_name(l, b))
            .collect();
        let s = pair_scales(weights, &names, calib.chan_attn_in(l), alpha)?;
        for n in &names {
            weights.get_mut(n)?.scale_rows(&s);
        }
        write_inv(&mut inv, l, 0, d_model, &s);

        // pair 2: mlp_in -> [wg,] wu
        let mut names: Vec<String> = vec![Weights::layer_name(l, "wu")];
        if has_gate {
            names.push(Weights::layer_name(l, "wg"));
        }
        let s = pair_scales(weights, &names, calib.chan_mlp_in(l), alpha)?;
        for n in &names {
            weights.get_mut(n)?.scale_rows(&s);
        }
        write_inv(&mut inv, l, 1, d_model, &s);
    }
    Ok(inv)
}

fn pair_scales(weights: &Weights, names: &[String], act: &[f32],
               alpha: f32) -> crate::Result<Vec<f32>> {
    let mut w_absmax = vec![0.0f32; act.len()];
    for n in names {
        let w = weights.get(n)?;
        for (j, v) in w.row_absmax().iter().enumerate() {
            w_absmax[j] = w_absmax[j].max(*v);
        }
    }
    Ok(smooth_scales(act, &w_absmax, alpha))
}

fn write_inv(inv: &mut Tensor, l: usize, which: usize, d: usize, s: &[f32]) {
    let base = (l * 2 + which) * d;
    for (j, &v) in s.iter().enumerate() {
        inv.data[base + j] = 1.0 / v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_formula() {
        let s = smooth_scales(&[8.0, 1.0], &[2.0, 2.0], 0.5);
        // a^.5 / w^.5 = sqrt(8/2)=2, sqrt(1/2)=0.707
        assert!((s[0] - 2.0).abs() < 1e-5);
        assert!((s[1] - 0.70710677).abs() < 1e-5);
    }

    #[test]
    fn scales_clamped() {
        let s = smooth_scales(&[1e9], &[1e-9], 1.0);
        assert!(s[0] <= 1e4);
        let s = smooth_scales(&[0.0], &[1e9], 1.0);
        assert!(s[0] >= 1e-4);
    }

    #[test]
    fn alpha_extremes() {
        // alpha=1: s = a (all migration); alpha=0: s = 1/w
        let s1 = smooth_scales(&[4.0], &[2.0], 1.0);
        assert!((s1[0] - 4.0).abs() < 1e-5);
        let s0 = smooth_scales(&[4.0], &[2.0], 0.0);
        assert!((s0[0] - 0.5).abs() < 1e-5);
    }
}
