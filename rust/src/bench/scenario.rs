//! Shared bench scenarios: prepared sessions, cushion acquisition, and
//! the (ppl, zero-shot) evaluation cell all table benches share.
//!
//! Wall-clock knobs (environment):
//!   CUSHION_BENCH_FAST=1   — fewer batches/items/variants (smoke runs)
//!   CUSHION_SEARCH_STRIDE  — vocab stride for on-demand cushion search

use crate::cushion::{self, SearchCfg, TuneCfg};
use crate::data::tasks as dtasks;
use crate::eval::{perplexity, tasks as etasks};
use crate::model::session::{Cushion, Session};
use crate::quant::scheme::{Algorithm, Scheme, SMOOTH_ALPHA};
use crate::quant::{calibrate, smoothquant};
use crate::runtime::Client;

pub fn fast_mode() -> bool {
    std::env::var("CUSHION_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn eval_batches() -> usize {
    if fast_mode() { 2 } else { 8 }
}

pub fn task_items() -> usize {
    if fast_mode() { 16 } else { 40 }
}

pub fn bench_variants() -> Vec<&'static str> {
    if fast_mode() {
        vec!["tl-llama", "tl-opt"]
    } else {
        vec!["tl-llama", "tl-llama3", "tl-mistral", "tl-opt", "tl-bloom"]
    }
}

/// Load a session, optionally SmoothQuant-transform it, optionally install
/// a cushion (from the store, searching + tuning on demand).
pub fn prepared(client: &Client, variant: &str, smooth: bool,
                with_cushion: bool) -> crate::Result<Session> {
    let mut s = Session::load_with_client(variant, client.clone())?;
    if smooth {
        apply_smooth(&mut s)?;
    }
    if with_cushion {
        let c = ensure_cushion(&mut s)?;
        s.set_cushion(c)?;
    }
    Ok(s)
}

pub fn apply_smooth(s: &mut Session) -> crate::Result<()> {
    let calib = calibrate::calibrate(s, eval_batches())?;
    let mut w = s.base_weights.clone();
    let inv = smoothquant::apply(
        &mut w, &calib, s.manifest.n_layers, s.manifest.d_model,
        s.manifest.act == "swiglu", SMOOTH_ALPHA,
    )?;
    s.set_weights(w);
    s.set_inv_smooth(inv);
    Ok(())
}

/// Load the stored "default" cushion, or search + tune one and store it.
pub fn ensure_cushion(s: &mut Session) -> crate::Result<Cushion> {
    let variant = s.manifest.variant.clone();
    if let Ok(c) = cushion::load_cushion(&variant, "default") {
        return Ok(c);
    }
    let stride: usize = std::env::var("CUSHION_SEARCH_STRIDE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast_mode() { 16 } else { 4 });
    log::info!("[scenario] no stored cushion for {variant}; searching (stride {stride})");
    let res = cushion::greedy_search(
        s,
        &SearchCfg { vocab_stride: stride, max_len: 6, ..Default::default() },
    )?;
    let tuned = cushion::tune::tune_prefix(
        s, &res.prefix,
        &TuneCfg { epochs: if fast_mode() { 1 } else { 2 }, ..Default::default() },
    )?;
    let c = Cushion {
        tokens: res.prefix.clone(),
        len: res.prefix.len(),
        kv: tuned.kv,
    };
    cushion::save_cushion(&variant, "default", &c)?;
    Ok(c)
}

/// One evaluation cell: calibrate if needed, heldout ppl + zero-shot avg.
pub fn eval_cell(s: &mut Session, scheme: &Scheme,
                 with_tasks: bool) -> crate::Result<(f64, f64)> {
    if scheme.gran.needs_calibration() {
        calibrate::calibrate_into(s, scheme.act_levels(), eval_batches())?;
    }
    let ppl = perplexity::perplexity(s, scheme, "heldout", eval_batches())?;
    if !with_tasks {
        return Ok((ppl, 0.0));
    }
    let all = dtasks::load(
        &crate::util::fsutil::variant_dir(&s.manifest.variant).join("tasks.bin"))?;
    let mut scores = Vec::new();
    for name in dtasks::ZERO_SHOT {
        let t = dtasks::find(&all, name)?;
        scores.push(etasks::eval_task(s, scheme, t, task_items())?);
    }
    Ok((ppl, etasks::zero_shot_average(&scores) * 100.0))
}

/// The six scheme rows of Tables 1/2 (naive + SmoothQuant x 3 granularities).
pub fn table_rows() -> Vec<(&'static str, Scheme, bool)> {
    use crate::quant::scheme::Granularity::*;
    let sq = Algorithm::SmoothQuant { alpha: SMOOTH_ALPHA };
    vec![
        ("Per-tensor Static", Scheme::w8a8(PerTensorStatic, Algorithm::Naive), false),
        ("SmoothQuant-O3", Scheme::w8a8(PerTensorStatic, sq), true),
        ("Per-tensor Dynamic", Scheme::w8a8(PerTensorDynamic, Algorithm::Naive), false),
        ("SmoothQuant-O2", Scheme::w8a8(PerTensorDynamic, sq), true),
        ("Per-token Dynamic", Scheme::w8a8(PerTokenDynamic, Algorithm::Naive), false),
        ("SmoothQuant-O1", Scheme::w8a8(PerTokenDynamic, sq), true),
    ]
}

pub fn pct_delta(base: f64, ours: f64) -> String {
    if base == 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (ours - base) / base * 100.0)
}

// ---------------------------------------------------------------------------
// Trace-replay workload generator
// ---------------------------------------------------------------------------
//
// Seeded, fully deterministic serving workload for the SLO benches and
// the chunked-prefill tests: Poisson arrivals with periodic bursts,
// Zipf-distributed prompt popularity over a small prompt pool (repeated
// ranks submit *identical* prompts, so the prefix cache sees real
// reuse), and long-tail generation lengths split into "short" / "long"
// request classes. Arrival times are measured in *scheduler steps*, not
// wall clock, so a replay is step-indexed and reproducible.

use crate::coordinator::metrics::SloMetrics;
use crate::coordinator::request::{Request, RequestId, Response};
use crate::coordinator::scheduler::Scheduler;
use crate::util::prng::{hash64, SplitMix64};

/// Knobs of the synthetic serving trace. All sampling flows from
/// `seed`; two configs with equal fields generate identical traces.
#[derive(Clone, Debug)]
pub struct TraceCfg {
    pub seed: u64,
    pub n_requests: usize,
    /// Mean arrivals per scheduler step of the base Poisson process
    /// (exponential inter-arrival times, `-ln(1-u)/rate`).
    pub arrival_rate: f64,
    /// Every `burst_every`-th Poisson arrival drags `burst_size` extra
    /// requests in at the same step (0 disables bursts).
    pub burst_every: usize,
    pub burst_size: usize,
    /// Distinct prompts in the popularity pool; requests pick a rank
    /// with probability ∝ 1/(rank+1)^`zipf_s`, and equal ranks submit
    /// byte-identical prompts (prefix-cache hits).
    pub prompt_pool: usize,
    pub zipf_s: f64,
    /// Token-id range for prompt content (must not exceed the serving
    /// session's vocab).
    pub vocab: usize,
    /// Inclusive prompt-length range; keep `max <= seq_len` (and
    /// `m_max + max < cache_cap` if chunked prefill should engage).
    pub prompt_len: (usize, usize),
    /// Generation length of the "short" class.
    pub gen_short: usize,
    /// Base generation length of the "long" class; an exponential tail
    /// on top makes the distribution long-tailed.
    pub gen_long: usize,
    /// Fraction of requests in the "long" class.
    pub long_frac: f64,
    /// Deadline applied to "short"-class requests (the tight-SLO
    /// tenants); `None` leaves every request deadline-free.
    pub deadline_ms: Option<u64>,
}

impl Default for TraceCfg {
    fn default() -> Self {
        Self {
            seed: 0xC0FFEE,
            n_requests: 32,
            arrival_rate: 1.5,
            burst_every: 8,
            burst_size: 3,
            prompt_pool: 6,
            zipf_s: 1.1,
            vocab: 64,
            prompt_len: (3, 10),
            gen_short: 4,
            gen_long: 12,
            long_frac: 0.25,
            deadline_ms: None,
        }
    }
}

/// One request of the generated trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Scheduler step at which the request arrives.
    pub step: usize,
    /// Popularity rank of the prompt (0 = most popular). Equal ranks
    /// carry identical `prompt` vectors.
    pub rank: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Request class: "short" | "long".
    pub class: &'static str,
    pub deadline_ms: Option<u64>,
}

/// Generate the deterministic trace for `cfg` (sorted by arrival step;
/// generation order breaks ties, preserving submission order).
pub fn generate_trace(cfg: &TraceCfg) -> Vec<TraceEvent> {
    assert!(cfg.prompt_pool > 0, "empty prompt pool");
    assert!(cfg.prompt_len.0 >= 1 && cfg.prompt_len.0 <= cfg.prompt_len.1);
    let mut rng = SplitMix64::new(cfg.seed);
    // Zipf CDF over ranks 0..prompt_pool
    let weights: Vec<f64> =
        (0..cfg.prompt_pool).map(|r| 1.0 / ((r + 1) as f64).powf(cfg.zipf_s)).collect();
    let total: f64 = weights.iter().sum();
    // Per-rank prompt content: forked off the seed by rank, so the same
    // rank yields the same prompt independent of draw order.
    let prompts: Vec<Vec<i32>> = (0..cfg.prompt_pool)
        .map(|rank| {
            let mut pr = SplitMix64::new(cfg.seed ^ hash64(rank as u64 + 1));
            let span = (cfg.prompt_len.1 - cfg.prompt_len.0 + 1) as u64;
            let len = cfg.prompt_len.0 + pr.next_below(span) as usize;
            (0..len).map(|_| pr.next_below(cfg.vocab as u64) as i32).collect()
        })
        .collect();

    let mut events = Vec::with_capacity(cfg.n_requests);
    let mut t = 0.0f64;
    let mut arrivals = 0usize;
    let mut burst_left = 0usize;
    for _ in 0..cfg.n_requests {
        if burst_left > 0 {
            // burst member: same arrival step as the arrival that
            // triggered the burst
            burst_left -= 1;
        } else {
            t += -(1.0 - rng.next_f64()).ln() / cfg.arrival_rate.max(1e-9);
            arrivals += 1;
            if cfg.burst_every > 0 && arrivals % cfg.burst_every == 0 {
                burst_left = cfg.burst_size;
            }
        }
        // Zipf rank draw
        let u = rng.next_f64() * total;
        let mut acc = 0.0;
        let mut rank = cfg.prompt_pool - 1;
        for (r, w) in weights.iter().enumerate() {
            acc += w;
            if u < acc {
                rank = r;
                break;
            }
        }
        let long = rng.next_f64() < cfg.long_frac;
        let (class, max_new) = if long {
            // exponential tail on top of the base long length
            let tail = -(1.0 - rng.next_f64()).ln() * cfg.gen_long as f64 * 0.5;
            ("long", (cfg.gen_long + tail as usize).max(1))
        } else {
            ("short", cfg.gen_short.max(1))
        };
        events.push(TraceEvent {
            step: t as usize,
            rank,
            prompt: prompts[rank].clone(),
            max_new,
            class,
            deadline_ms: if class == "short" { cfg.deadline_ms } else { None },
        });
    }
    events
}

/// Step-indexed deterministic replay: submit each event at its arrival
/// step, run the scheduler to drain, and (optionally) feed every
/// response into per-class SLO metrics. Requests are submitted with
/// `stop_token: None` so generation lengths follow the trace exactly.
/// Returns responses in finish order.
pub fn replay_trace(
    sched: &mut Scheduler,
    events: &[TraceEvent],
    mut slo: Option<&mut SloMetrics>,
) -> crate::Result<Vec<Response>> {
    let mut class_of: std::collections::HashMap<RequestId, &'static str> =
        std::collections::HashMap::new();
    let mut out = Vec::with_capacity(events.len());
    let mut collect = |sched: &mut Scheduler,
                       slo: &mut Option<&mut SloMetrics>,
                       class_of: &std::collections::HashMap<RequestId, &'static str>,
                       out: &mut Vec<Response>| {
        for r in sched.take_finished() {
            if let Some(slo) = slo.as_deref_mut() {
                slo.record(class_of.get(&r.id).copied().unwrap_or("?"), &r);
            }
            out.push(r);
        }
    };
    let last_step = events.iter().map(|e| e.step).max().unwrap_or(0);
    let mut next_id: RequestId = 1;
    let mut iter = events.iter().peekable();
    for step in 0..=last_step {
        while let Some(e) = iter.peek() {
            if e.step > step {
                break;
            }
            let e = iter.next().unwrap();
            let mut req = Request::new(next_id, e.prompt.clone(), e.max_new);
            req.stop_token = None;
            req.deadline =
                e.deadline_ms.map(std::time::Duration::from_millis);
            class_of.insert(next_id, e.class);
            next_id += 1;
            sched.submit_request(req);
        }
        sched.step()?;
        collect(sched, &mut slo, &class_of, &mut out);
    }
    // drain: everything has arrived; bounded so a scheduling bug fails
    // the replay instead of hanging it
    let mut guard = 0usize;
    while sched.has_work() {
        guard += 1;
        anyhow::ensure!(
            guard <= 1000 + 100 * events.len(),
            "trace replay did not converge"
        );
        sched.step()?;
        collect(sched, &mut slo, &class_of, &mut out);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_per_seed() {
        let cfg = TraceCfg::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a, b, "same seed → same trace");
        let c = generate_trace(&TraceCfg { seed: 7, ..cfg });
        assert_ne!(a, c, "different seed → different trace");
        assert_eq!(a.len(), cfg.n_requests);
        // arrival steps are monotonically non-decreasing
        assert!(a.windows(2).all(|w| w[0].step <= w[1].step));
    }

    #[test]
    fn trace_zipf_reuses_prompts_and_classes_split() {
        let cfg = TraceCfg { n_requests: 64, deadline_ms: Some(200), ..Default::default() };
        let t = generate_trace(&cfg);
        // rank 0 is the Zipf head: it must repeat, with identical prompts
        let head: Vec<_> = t.iter().filter(|e| e.rank == 0).collect();
        assert!(head.len() >= 2, "Zipf head never repeated");
        assert!(head.windows(2).all(|w| w[0].prompt == w[1].prompt));
        // both classes show up; short carries the deadline, long doesn't
        assert!(t.iter().any(|e| e.class == "short"));
        assert!(t.iter().any(|e| e.class == "long"));
        assert!(t
            .iter()
            .all(|e| (e.class == "short") == (e.deadline_ms == Some(200))));
        // long-tail: some long request generates more than the base
        assert!(t.iter().filter(|e| e.class == "long").all(|e| e.max_new >= cfg.gen_long));
        // prompt lengths respect the configured range
        assert!(t
            .iter()
            .all(|e| e.prompt.len() >= cfg.prompt_len.0
                && e.prompt.len() <= cfg.prompt_len.1));
    }

    #[test]
    fn trace_bursts_cluster_arrivals() {
        let cfg = TraceCfg {
            n_requests: 40,
            arrival_rate: 0.2, // sparse base process...
            burst_every: 4,
            burst_size: 4, // ...with dense bursts
            ..Default::default()
        };
        let t = generate_trace(&cfg);
        let mut per_step = std::collections::HashMap::new();
        for e in &t {
            *per_step.entry(e.step).or_insert(0usize) += 1;
        }
        assert!(
            per_step.values().any(|&n| n >= 5),
            "no burst step found: {per_step:?}"
        );
    }
}
