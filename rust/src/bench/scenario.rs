//! Shared bench scenarios: prepared sessions, cushion acquisition, and
//! the (ppl, zero-shot) evaluation cell all table benches share.
//!
//! Wall-clock knobs (environment):
//!   CUSHION_BENCH_FAST=1   — fewer batches/items/variants (smoke runs)
//!   CUSHION_SEARCH_STRIDE  — vocab stride for on-demand cushion search

use crate::cushion::{self, SearchCfg, TuneCfg};
use crate::data::tasks as dtasks;
use crate::eval::{perplexity, tasks as etasks};
use crate::model::session::{Cushion, Session};
use crate::quant::scheme::{Algorithm, Scheme, SMOOTH_ALPHA};
use crate::quant::{calibrate, smoothquant};
use crate::runtime::Client;

pub fn fast_mode() -> bool {
    std::env::var("CUSHION_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn eval_batches() -> usize {
    if fast_mode() { 2 } else { 8 }
}

pub fn task_items() -> usize {
    if fast_mode() { 16 } else { 40 }
}

pub fn bench_variants() -> Vec<&'static str> {
    if fast_mode() {
        vec!["tl-llama", "tl-opt"]
    } else {
        vec!["tl-llama", "tl-llama3", "tl-mistral", "tl-opt", "tl-bloom"]
    }
}

/// Load a session, optionally SmoothQuant-transform it, optionally install
/// a cushion (from the store, searching + tuning on demand).
pub fn prepared(client: &Client, variant: &str, smooth: bool,
                with_cushion: bool) -> crate::Result<Session> {
    let mut s = Session::load_with_client(variant, client.clone())?;
    if smooth {
        apply_smooth(&mut s)?;
    }
    if with_cushion {
        let c = ensure_cushion(&mut s)?;
        s.set_cushion(c)?;
    }
    Ok(s)
}

pub fn apply_smooth(s: &mut Session) -> crate::Result<()> {
    let calib = calibrate::calibrate(s, eval_batches())?;
    let mut w = s.base_weights.clone();
    let inv = smoothquant::apply(
        &mut w, &calib, s.manifest.n_layers, s.manifest.d_model,
        s.manifest.act == "swiglu", SMOOTH_ALPHA,
    )?;
    s.set_weights(w);
    s.set_inv_smooth(inv);
    Ok(())
}

/// Load the stored "default" cushion, or search + tune one and store it.
pub fn ensure_cushion(s: &mut Session) -> crate::Result<Cushion> {
    let variant = s.manifest.variant.clone();
    if let Ok(c) = cushion::load_cushion(&variant, "default") {
        return Ok(c);
    }
    let stride: usize = std::env::var("CUSHION_SEARCH_STRIDE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast_mode() { 16 } else { 4 });
    log::info!("[scenario] no stored cushion for {variant}; searching (stride {stride})");
    let res = cushion::greedy_search(
        s,
        &SearchCfg { vocab_stride: stride, max_len: 6, ..Default::default() },
    )?;
    let tuned = cushion::tune::tune_prefix(
        s, &res.prefix,
        &TuneCfg { epochs: if fast_mode() { 1 } else { 2 }, ..Default::default() },
    )?;
    let c = Cushion {
        tokens: res.prefix.clone(),
        len: res.prefix.len(),
        kv: tuned.kv,
    };
    cushion::save_cushion(&variant, "default", &c)?;
    Ok(c)
}

/// One evaluation cell: calibrate if needed, heldout ppl + zero-shot avg.
pub fn eval_cell(s: &mut Session, scheme: &Scheme,
                 with_tasks: bool) -> crate::Result<(f64, f64)> {
    if scheme.gran.needs_calibration() {
        calibrate::calibrate_into(s, scheme.act_levels(), eval_batches())?;
    }
    let ppl = perplexity::perplexity(s, scheme, "heldout", eval_batches())?;
    if !with_tasks {
        return Ok((ppl, 0.0));
    }
    let all = dtasks::load(
        &crate::util::fsutil::variant_dir(&s.manifest.variant).join("tasks.bin"))?;
    let mut scores = Vec::new();
    for name in dtasks::ZERO_SHOT {
        let t = dtasks::find(&all, name)?;
        scores.push(etasks::eval_task(s, scheme, t, task_items())?);
    }
    Ok((ppl, etasks::zero_shot_average(&scores) * 100.0))
}

/// The six scheme rows of Tables 1/2 (naive + SmoothQuant x 3 granularities).
pub fn table_rows() -> Vec<(&'static str, Scheme, bool)> {
    use crate::quant::scheme::Granularity::*;
    let sq = Algorithm::SmoothQuant { alpha: SMOOTH_ALPHA };
    vec![
        ("Per-tensor Static", Scheme::w8a8(PerTensorStatic, Algorithm::Naive), false),
        ("SmoothQuant-O3", Scheme::w8a8(PerTensorStatic, sq), true),
        ("Per-tensor Dynamic", Scheme::w8a8(PerTensorDynamic, Algorithm::Naive), false),
        ("SmoothQuant-O2", Scheme::w8a8(PerTensorDynamic, sq), true),
        ("Per-token Dynamic", Scheme::w8a8(PerTokenDynamic, Algorithm::Naive), false),
        ("SmoothQuant-O1", Scheme::w8a8(PerTokenDynamic, sq), true),
    ]
}

pub fn pct_delta(base: f64, ours: f64) -> String {
    if base == 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (ours - base) / base * 100.0)
}
