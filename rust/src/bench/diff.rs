//! Bench-snapshot regression diff: compare two `BENCH_*.json` files
//! (the cross-PR perf trail emitted by `emit_bench_json`) and flag
//!
//! * a component's `mean_ms` growing by more than a tolerance (default
//!   10%) — the latency gate, applied to every component present in
//!   both files plus a must-exist "key component" (the decode step), and
//! * ANY growth in a `transfers_per_iter` gauge (uploads / kb_up /
//!   fetches / kb_down) — the transfer budget is a hard invariant of
//!   the device-resident serving design, so there is no tolerance — and
//!   likewise in a `collective_per_iter` gauge (all_gathers /
//!   kb_gathered / all_reduces / kb_reduced), the tensor-parallel
//!   decode step's collective traffic, and
//! * the `slo` section's tail latencies (`ttft_p99_ms` / `tpot_p99_ms`
//!   from the trace-replay scenario) growing past the latency
//!   tolerance, or `goodput` dropping at all. Once a baseline carries
//!   the section, losing it (or one of its p99 gauges) is itself a
//!   regression — the SLO gate must not go vacuously green, and
//! * the `observability` section's `tracing_overhead_frac` — the
//!   enabled-tracer cost of the decode hot path as a fraction of the
//!   untraced step — exceeding a hard 5% ceiling, baseline or not.
//!   Losing the section once baselined is a regression, same as SLO.
//!
//! Consumed by `cushiond bench-diff <base.json> <new.json>` and
//! `scripts/bench_diff.sh`, the documented pre-merge check.

use crate::util::json::{self, Value};

/// Default mean-latency regression tolerance (fraction).
pub const DEFAULT_TOL: f64 = 0.10;
/// The component the diff refuses to silently lose track of.
pub const KEY_COMPONENT: &str = "decode step (batch 8)";
/// Absolute slack (KB / count) for transfer gauges: absorbs rounding in
/// the emitted 0.1-precision values, nothing more.
const XFER_EPS: f64 = 0.05;
/// Hard ceiling on the enabled-tracer decode overhead fraction: an
/// absolute budget, not a relative one — a baseline that already pays
/// 8% does not grandfather the regression in.
pub const TRACING_OVERHEAD_CEIL: f64 = 0.05;

/// The outcome of one base-vs-new comparison.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// Human-readable regression lines; empty = pass.
    pub regressions: Vec<String>,
    /// Non-fatal observations (improvements, skipped components).
    pub notes: Vec<String>,
}

impl DiffReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

fn component_mean(v: &Value, name: &str) -> Option<f64> {
    v.get("components")?.get(name)?.get("mean_ms")?.as_f64()
}

fn component_names(v: &Value) -> Vec<String> {
    match v.get("components") {
        Some(Value::Obj(kvs)) => kvs.iter().map(|(k, _)| k.clone()).collect(),
        _ => Vec::new(),
    }
}

/// Diff two parsed bench snapshots. `tol` is the fractional mean-latency
/// tolerance; transfer gauges tolerate no growth.
pub fn diff_values(base: &Value, new: &Value, tol: f64) -> DiffReport {
    let mut r = DiffReport::default();

    // latency: every component in both files, and the key component
    // must not disappear (a renamed hot-path row would otherwise make
    // the gate vacuously green)
    if component_mean(base, KEY_COMPONENT).is_some()
        && component_mean(new, KEY_COMPONENT).is_none()
    {
        r.regressions.push(format!(
            "key component '{KEY_COMPONENT}' missing from the new snapshot"
        ));
    }
    for name in component_names(base) {
        let Some(b) = component_mean(base, &name) else { continue };
        let Some(n) = component_mean(new, &name) else {
            r.notes.push(format!("component '{name}' dropped (not compared)"));
            continue;
        };
        if b > 0.0 && n > b * (1.0 + tol) {
            r.regressions.push(format!(
                "'{name}' mean {b:.2} ms -> {n:.2} ms ({:+.1}% > {:.0}% tolerance)",
                (n - b) / b * 100.0,
                tol * 100.0
            ));
        } else if b > 0.0 && n < b * 0.9 {
            r.notes
                .push(format!("'{name}' improved {b:.2} ms -> {n:.2} ms"));
        }
    }

    // transfer and collective gauges: any growth fails. The collective
    // section meters all-gather/all-reduce bytes of the tensor-parallel
    // decode step, which is a design invariant exactly like the
    // host-transfer budget.
    let sections: [(&str, &str, &[&str]); 2] = [
        (
            "transfers_per_iter",
            "transfer",
            &["uploads", "kb_up", "fetches", "kb_down"],
        ),
        (
            "collective_per_iter",
            "collective",
            &["all_gathers", "kb_gathered", "all_reduces", "kb_reduced"],
        ),
    ];
    for (section, kind, gauges) in sections {
        let (bx, nx) = (base.get(section), new.get(section));
        let (Some(Value::Obj(bkvs)), Some(nxv)) = (bx, nx) else { continue };
        for (name, brow) in bkvs {
            let Some(nrow) = nxv.get(name) else {
                r.notes.push(format!(
                    "{kind} row '{name}' dropped (not compared)"
                ));
                continue;
            };
            for gauge in gauges {
                let b = brow.get(gauge).and_then(Value::as_f64).unwrap_or(0.0);
                let n = nrow.get(gauge).and_then(Value::as_f64).unwrap_or(0.0);
                if n > b + XFER_EPS {
                    r.regressions.push(format!(
                        "'{name}' {gauge} grew {b:.1} -> {n:.1} \
                         (per-iter {kind} growth is a hard failure)"
                    ));
                }
            }
        }
    }

    // SLO gauges (trace-replay scenario): tail latencies use the same
    // fractional tolerance as component means; goodput is monotone —
    // any drop fails.
    match (base.get("slo"), new.get("slo")) {
        (Some(b), Some(n)) => {
            for g in ["ttft_p99_ms", "tpot_p99_ms"] {
                match (
                    b.get(g).and_then(Value::as_f64),
                    n.get(g).and_then(Value::as_f64),
                ) {
                    (Some(bv), Some(nv)) => {
                        if bv > 0.0 && nv > bv * (1.0 + tol) {
                            r.regressions.push(format!(
                                "slo {g} {bv:.2} -> {nv:.2} ({:+.1}% > {:.0}% tolerance)",
                                (nv - bv) / bv * 100.0,
                                tol * 100.0
                            ));
                        } else if bv > 0.0 && nv < bv * 0.9 {
                            r.notes.push(format!("slo {g} improved {bv:.2} -> {nv:.2}"));
                        }
                    }
                    (Some(_), None) => r.regressions.push(format!(
                        "slo gauge '{g}' missing from the new snapshot"
                    )),
                    (None, _) => {}
                }
            }
            if let (Some(bg), Some(ng)) = (
                b.get("goodput").and_then(Value::as_f64),
                n.get("goodput").and_then(Value::as_f64),
            ) {
                if ng + 1e-9 < bg {
                    r.regressions
                        .push(format!("slo goodput fell {bg:.3} -> {ng:.3}"));
                }
            }
        }
        (Some(_), None) => r
            .regressions
            .push("slo section missing from the new snapshot".into()),
        (None, Some(_)) => r
            .notes
            .push("slo section appeared (no baseline to compare)".into()),
        (None, None) => {}
    }

    // observability gauges: tracing overhead on the decode hot path is
    // an absolute budget — the ceiling applies to the new snapshot
    // whether or not a baseline exists. Losing the section (or the
    // gauge) once baselined fails, same as the SLO gate.
    match (base.get("observability"), new.get("observability")) {
        (b, Some(n)) => {
            match n.get("tracing_overhead_frac").and_then(Value::as_f64) {
                Some(f) if f > TRACING_OVERHEAD_CEIL => {
                    r.regressions.push(format!(
                        "tracing overhead {:.1}% exceeds the {:.0}% ceiling",
                        f * 100.0,
                        TRACING_OVERHEAD_CEIL * 100.0
                    ));
                }
                Some(_) => {}
                None => {
                    if b.map_or(false, |b| {
                        b.get("tracing_overhead_frac").is_some()
                    }) {
                        r.regressions.push(
                            "observability gauge 'tracing_overhead_frac' \
                             missing from the new snapshot"
                                .into(),
                        );
                    }
                }
            }
            if b.is_none() {
                r.notes.push(
                    "observability section appeared (no baseline to compare)"
                        .into(),
                );
            }
        }
        (Some(_), None) => r.regressions.push(
            "observability section missing from the new snapshot".into(),
        ),
        (None, None) => {}
    }
    r
}

/// Diff two bench snapshot files. Errors on unreadable/unparseable
/// input (a missing baseline is a setup problem, not a pass).
pub fn diff_files(base: &str, new: &str, tol: f64) -> crate::Result<DiffReport> {
    let read = |p: &str| -> crate::Result<Value> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("reading {p}: {e}"))?;
        json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e:#}"))
    };
    Ok(diff_values(&read(base)?, &read(new)?, tol))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(decode_ms: f64, kb_up: f64, kb_down: f64) -> Value {
        json::parse(&format!(
            r#"{{
              "bench": "perf_hotpath",
              "components": {{
                "decode step (batch 8)": {{"mean_ms": {decode_ms}, "p50_ms": 1.0, "p99_ms": 2.0}},
                "prefill (prompt 96)": {{"mean_ms": 9.0, "p50_ms": 9.0, "p99_ms": 9.9}}
              }},
              "transfers_per_iter": {{
                "decode step (batch 8)": {{"uploads": 2.0, "kb_up": {kb_up}, "fetches": 1.0, "kb_down": {kb_down}}}
              }}
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn identical_snapshots_pass() {
        let a = snap(1.5, 0.1, 0.1);
        let r = diff_values(&a, &a, DEFAULT_TOL);
        assert!(r.passed(), "{:?}", r.regressions);
    }

    #[test]
    fn latency_regression_over_tolerance_fails() {
        let r = diff_values(&snap(1.5, 0.1, 0.1), &snap(1.7, 0.1, 0.1), 0.10);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("decode step"));
        // within tolerance passes
        let r = diff_values(&snap(1.5, 0.1, 0.1), &snap(1.6, 0.1, 0.1), 0.10);
        assert!(r.passed(), "{:?}", r.regressions);
    }

    #[test]
    fn any_transfer_growth_fails() {
        let r = diff_values(&snap(1.5, 0.1, 0.1), &snap(1.5, 0.3, 0.1), 0.10);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("kb_up"));
        let r = diff_values(&snap(1.5, 0.1, 0.1), &snap(1.5, 0.1, 4096.0), 0.10);
        assert!(!r.passed());
    }

    #[test]
    fn transfer_shrink_and_latency_improvement_pass_with_notes() {
        let r = diff_values(&snap(4.7, 4608.0, 4640.0), &snap(1.4, 0.1, 0.1), 0.10);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.notes.iter().any(|n| n.contains("improved")));
    }

    #[test]
    fn collective_traffic_growth_fails() {
        let snap_coll = |kb_gathered: f64, kb_reduced: f64| -> Value {
            json::parse(&format!(
                r#"{{
                  "components": {{
                    "sharded decode step (tiny, 2 shards)": {{"mean_ms": 3.0, "p50_ms": 3.0, "p99_ms": 3.5}}
                  }},
                  "collective_per_iter": {{
                    "sharded decode step (tiny, 2 shards)": {{"all_gathers": 4.0, "kb_gathered": {kb_gathered}, "all_reduces": 0.0, "kb_reduced": {kb_reduced}}}
                  }}
                }}"#
            ))
            .unwrap()
        };
        let a = snap_coll(1.25, 0.0);
        assert!(diff_values(&a, &a, DEFAULT_TOL).passed());
        let r = diff_values(&a, &snap_coll(2.5, 0.0), DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("kb_gathered"));
        // a new all-reduce sneaking onto the hot path is a regression
        let r = diff_values(&a, &snap_coll(1.25, 0.5), DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("kb_reduced"));
    }

    #[test]
    fn slo_gauges_are_gated() {
        let snap_slo = |ttft: f64, tpot: f64, goodput: f64| -> Value {
            json::parse(&format!(
                r#"{{
                  "components": {{"decode step (batch 8)": {{"mean_ms": 1.0}}}},
                  "slo": {{"ttft_p99_ms": {ttft}, "tpot_p99_ms": {tpot}, "goodput": {goodput},
                           "short": {{"total": 24, "goodput": {goodput}}}}}
                }}"#
            ))
            .unwrap()
        };
        let a = snap_slo(8.0, 2.0, 1.0);
        assert!(diff_values(&a, &a, DEFAULT_TOL).passed());
        // p99 growth beyond tolerance fails
        let r = diff_values(&a, &snap_slo(9.5, 2.0, 1.0), DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("ttft_p99_ms"));
        let r = diff_values(&a, &snap_slo(8.0, 2.5, 1.0), DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("tpot_p99_ms"));
        // within tolerance passes; improvement is a note
        assert!(diff_values(&a, &snap_slo(8.5, 2.1, 1.0), DEFAULT_TOL).passed());
        let r = diff_values(&a, &snap_slo(4.0, 2.0, 1.0), DEFAULT_TOL);
        assert!(r.passed());
        assert!(r.notes.iter().any(|n| n.contains("improved")));
        // any goodput drop fails
        let r = diff_values(&a, &snap_slo(8.0, 2.0, 0.95), DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("goodput"));
        // losing the section (or a p99 gauge) once baselined fails
        let bare = json::parse(
            r#"{"components": {"decode step (batch 8)": {"mean_ms": 1.0}}}"#,
        )
        .unwrap();
        let r = diff_values(&a, &bare, DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions.iter().any(|x| x.contains("slo section missing")));
        let partial = json::parse(
            r#"{"components": {"decode step (batch 8)": {"mean_ms": 1.0}},
                "slo": {"ttft_p99_ms": 8.0, "goodput": 1.0}}"#,
        )
        .unwrap();
        let r = diff_values(&a, &partial, DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions.iter().any(|x| x.contains("tpot_p99_ms")));
        // no baseline section → new one is only a note
        let r = diff_values(&bare, &a, DEFAULT_TOL);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.notes.iter().any(|n| n.contains("slo section appeared")));
    }

    #[test]
    fn observability_overhead_is_gated() {
        let snap_obs = |frac: f64| -> Value {
            json::parse(&format!(
                r#"{{
                  "components": {{"decode step (batch 8)": {{"mean_ms": 1.0}}}},
                  "observability": {{"tracing_overhead_frac": {frac},
                                     "traced_mean_ms": 1.02,
                                     "untraced_mean_ms": 1.0}}
                }}"#
            ))
            .unwrap()
        };
        let a = snap_obs(0.02);
        assert!(diff_values(&a, &a, DEFAULT_TOL).passed());
        // the ceiling is absolute: even a worse baseline doesn't excuse it
        let r = diff_values(&snap_obs(0.08), &snap_obs(0.06), DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("tracing overhead"));
        let r = diff_values(&a, &snap_obs(0.051), DEFAULT_TOL);
        assert!(!r.passed());
        // losing the section once baselined fails
        let bare = json::parse(
            r#"{"components": {"decode step (batch 8)": {"mean_ms": 1.0}}}"#,
        )
        .unwrap();
        let r = diff_values(&a, &bare, DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r
            .regressions
            .iter()
            .any(|x| x.contains("observability section missing")));
        // losing just the gauge fails too
        let partial = json::parse(
            r#"{"components": {"decode step (batch 8)": {"mean_ms": 1.0}},
                "observability": {"traced_mean_ms": 1.0}}"#,
        )
        .unwrap();
        let r = diff_values(&a, &partial, DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r
            .regressions
            .iter()
            .any(|x| x.contains("tracing_overhead_frac")));
        // a brand-new section is only a note
        let r = diff_values(&bare, &a, DEFAULT_TOL);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r
            .notes
            .iter()
            .any(|n| n.contains("observability section appeared")));
    }

    #[test]
    fn missing_key_component_fails() {
        let a = snap(1.5, 0.1, 0.1);
        let b = json::parse(
            r#"{"components": {"something else": {"mean_ms": 1.0}}}"#,
        )
        .unwrap();
        let r = diff_values(&a, &b, DEFAULT_TOL);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("missing"));
    }
}
