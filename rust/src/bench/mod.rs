//! Benchmark harness (substrate for the absent criterion crate) plus the
//! table/CSV emitters shared by `benches/*` — one bench per paper
//! table/figure (DESIGN.md §6).

pub mod diff;
pub mod scenario;

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::stats;

/// Time a closure `iters` times after `warmup` runs; returns per-iteration
/// seconds.
pub fn time_n<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

#[derive(Clone, Debug)]
pub struct Timing {
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p99: f64,
}

pub fn summarize(samples: &[f64]) -> Timing {
    Timing {
        mean: stats::mean(samples),
        std: stats::std(samples),
        p50: stats::percentile(samples, 50.0),
        p99: stats::percentile(samples, 99.0),
    }
}

// ---------------------------------------------------------------------------
// Table formatting + CSV output
// ---------------------------------------------------------------------------

/// An ASCII table that also serializes to CSV under bench_out/.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("| {:w$} ", c, w = widths[i]))
                .collect::<String>()
                + "|"
        };
        let mut out = format!("\n== {} ==\n{sep}\n{}\n{sep}\n", self.title,
                              fmt_row(&self.headers));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Print to stdout and write `bench_out/<name>.csv`.
    pub fn emit(&self, name: &str) {
        print!("{}", self.render());
        let dir = out_dir();
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(format!("{name}.csv"));
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = writeln!(f, "{}", self.headers.join(","));
            for row in &self.rows {
                let _ = writeln!(f, "{}", row.join(","));
            }
            println!("[bench] wrote {}", path.display());
        }
    }
}

pub fn out_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CUSHION_BENCH_OUT") {
        return PathBuf::from(p);
    }
    workspace_root().join("bench_out")
}

/// The workspace root (parent of the artifacts dir), `.` as fallback.
pub fn workspace_root() -> PathBuf {
    crate::util::fsutil::artifacts_dir()
        .parent()
        .filter(|p| !p.as_os_str().is_empty())
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| PathBuf::from("."))
}

// ---------------------------------------------------------------------------
// Machine-readable bench snapshots (perf trajectory across PRs)
// ---------------------------------------------------------------------------

/// Minimal JSON string escaping for bench keys/values.
pub fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Write `BENCH_<name>.json` at the workspace root: component -> timing
/// stats in ms, plus pre-rendered extra JSON sections (key, raw value).
/// The file is the cross-PR perf trail — every run overwrites it, and
/// every run stamps its own provenance so a measured run is
/// distinguishable from any hand-committed placeholder baseline.
pub fn emit_bench_json(
    name: &str,
    components: &[(String, Timing)],
    extras: &[(String, String)],
) {
    let mut body = String::from("{\n");
    body.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(name)));
    body.push_str(&format!(
        "  \"provenance\": \"measured run of benches/{name}.rs\",\n"
    ));
    body.push_str("  \"components\": {\n");
    for (i, (comp, t)) in components.iter().enumerate() {
        body.push_str(&format!(
            "    \"{}\": {{\"mean_ms\": {:.4}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}}}{}\n",
            json_escape(comp),
            t.mean * 1e3,
            t.p50 * 1e3,
            t.p99 * 1e3,
            if i + 1 == components.len() { "" } else { "," },
        ));
    }
    body.push_str("  }");
    for (k, v) in extras {
        body.push_str(&format!(",\n  \"{}\": {}", json_escape(k), v));
    }
    body.push_str("\n}\n");
    let path = workspace_root().join(format!("BENCH_{name}.json"));
    match std::fs::write(&path, body) {
        Ok(()) => println!("[bench] wrote {}", path.display()),
        Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
    }
}

/// Emit a long-form CSV of (series, x, y) triples — the figure format.
pub fn emit_series(name: &str, headers: &[&str], rows: &[Vec<String>]) {
    let mut t = Table::new(name, headers);
    for r in rows {
        t.row(r.clone());
    }
    t.emit(name);
}

pub fn fmt_ms(sec: f64) -> String {
    format!("{:.2}", sec * 1e3)
}

pub fn fmt_pct_delta(base: f64, ours: f64) -> String {
    if base == 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (ours - base) / base * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("long-header"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    fn timing_summary() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
