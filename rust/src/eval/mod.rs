//! Evaluation harness: perplexity, zero-shot / mmlu / gsm task scoring,
//! and activation statistics for the figures.

pub mod actstats;
pub mod perplexity;
pub mod tasks;
