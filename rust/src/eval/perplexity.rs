//! Perplexity evaluation (Table 1 metric) over a corpus split.

use crate::model::session::Session;
use crate::quant::scheme::Scheme;

/// Mean per-token NLL -> perplexity on the given split, under the
//  session's current weights / ranges / smoothing / cushion.
pub fn perplexity(session: &Session, scheme: &Scheme, split_name: &str,
                  max_batches: usize) -> crate::Result<f64> {
    let m = &session.manifest;
    let split = session.corpus.split(split_name)?;
    let bsz = m.eval_batch;
    let n_batches = (split.n_seqs / bsz).min(max_batches).max(1);
    let mut nll_sum = 0.0f64;
    let mut count = 0usize;
    for bi in 0..n_batches {
        let mut tokens = Vec::with_capacity(bsz * m.seq_len);
        for s in 0..bsz {
            tokens.extend_from_slice(split.seq(bi * bsz + s));
        }
        let out = session.fwd(scheme, &tokens)?;
        let (nll, n) = batch_nll(&out.data, &tokens, bsz, m.seq_len, m.vocab);
        nll_sum += nll;
        count += n;
    }
    Ok((nll_sum / count as f64).exp())
}

/// Sum of next-token NLLs + target count for one batch. logits row-major
/// [B, S, V]; targets are tokens shifted by one.
pub fn batch_nll(logits: &[f32], tokens: &[i32], b: usize, s: usize,
                 v: usize) -> (f64, usize) {
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for bi in 0..b {
        for si in 0..s - 1 {
            let row = &logits[(bi * s + si) * v..(bi * s + si + 1) * v];
            let tgt = tokens[bi * s + si + 1] as usize;
            sum += -log_softmax_at(row, tgt);
            count += 1;
        }
    }
    (sum, count)
}

/// log softmax(row)[idx], numerically stable.
pub fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x)) as f64;
    let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum::<f64>().ln() + mx;
    row[idx] as f64 - lse
}

/// Argmax of a logit row.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = (0usize, f32::NEG_INFINITY);
    for (i, &x) in row.iter().enumerate() {
        if x > best.1 {
            best = (i, x);
        }
    }
    best.0
}

/// Per-row argmax over a flattened [b, v] logit matrix — the host-side
/// reference for (and fallback of) the engine's device-side token
/// selection. Ties resolve to the lowest index, matching both `argmax`
/// and jnp.argmax in the `decode_sampled_*` graphs.
pub fn argmax_rows(logits: &[f32], b: usize, v: usize) -> Vec<i32> {
    assert_eq!(logits.len(), b * v, "argmax_rows: bad [b, v] layout");
    (0..b)
        .map(|bi| argmax(&logits[bi * v..(bi + 1) * v]) as i32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_uniform() {
        let row = vec![0.0f32; 4];
        assert!((log_softmax_at(&row, 2) - (-(4f64).ln())).abs() < 1e-9);
    }

    #[test]
    fn log_softmax_stable_large() {
        let row = vec![1000.0f32, 0.0];
        assert!(log_softmax_at(&row, 0).abs() < 1e-6);
        assert!(log_softmax_at(&row, 1) < -900.0);
    }

    #[test]
    fn nll_of_perfect_prediction_is_small() {
        // B=1, S=3, V=2; logits strongly favor the actual next token
        let tokens = vec![0, 1, 0];
        let mut logits = vec![0.0f32; 3 * 2];
        logits[0 * 2 + 1] = 20.0; // pos0 predicts token 1
        logits[1 * 2 + 0] = 20.0; // pos1 predicts token 0
        let (nll, n) = batch_nll(&logits, &tokens, 1, 3, 2);
        assert_eq!(n, 2);
        assert!(nll < 1e-6);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
    }

    #[test]
    fn argmax_rows_matches_per_row_argmax() {
        let logits = [0.1, 3.0, -2.0, 5.0, 4.0, 4.0];
        assert_eq!(argmax_rows(&logits, 2, 3), vec![1, 0]);
        // ties resolve low, matching jnp.argmax in the sampled graphs
        assert_eq!(argmax_rows(&[7.0, 7.0], 1, 2), vec![0]);
    }
}
