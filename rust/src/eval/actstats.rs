//! Activation statistics for the analysis section: Table 5 (order
//! statistics of activation magnitudes), Figure 1 (position heatmap),
//! Figure 2 (per-layer top-k), Figure 3 (attention maps).

use crate::model::session::Session;
use crate::util::stats;
use crate::util::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct ActReport {
    /// [L+1][3]: mean over batches of (top-1, top-10%, median) magnitude
    /// of each block input (entry L = final block output).
    pub per_level: Vec<[f64; 3]>,
    /// [L+1][S]: per-position channel-absmax, averaged over sequences
    /// (Figure 1's heatmap rows).
    pub heatmap: Vec<Vec<f64>>,
    /// Attention maps of the first sample, [L][H][Sq][Skv] flattened into
    /// tensors (Figure 3).
    pub probs: Tensor,
}

/// Run the stats graph over `n_batches` heldout batches and aggregate.
pub fn collect(session: &Session, n_batches: usize) -> crate::Result<ActReport> {
    let m = &session.manifest;
    let split = session.corpus.split("heldout")?;
    let bsz = m.eval_batch;
    let n_batches = (split.n_seqs / bsz).min(n_batches).max(1);

    let levels = m.n_layers + 1;
    let mut acc: Vec<Vec<f64>> = vec![Vec::new(); levels * 3];
    let mut heat = vec![vec![0.0f64; m.seq_len]; levels];
    let mut probs: Option<Tensor> = None;

    for bi in 0..n_batches {
        let mut tokens = Vec::with_capacity(bsz * m.seq_len);
        for s in 0..bsz {
            tokens.extend_from_slice(split.seq(bi * bsz + s));
        }
        let out = session.stats(&tokens)?;
        // act_stats: [L+1, 3]
        for l in 0..levels {
            for k in 0..3 {
                acc[l * 3 + k].push(out.act_stats.at2(l, k) as f64);
            }
        }
        // acts_grid: [L+1, B, S] -> mean over B accumulated over batches
        let grid = &out.acts_grid;
        for l in 0..levels {
            for s in 0..m.seq_len {
                let mut v = 0.0f64;
                for b in 0..bsz {
                    v += grid.data[(l * bsz + b) * m.seq_len + s] as f64;
                }
                heat[l][s] += v / (bsz * n_batches) as f64;
            }
        }
        if probs.is_none() {
            probs = Some(out.probs);
        }
        let _ = bi;
    }

    let per_level = (0..levels)
        .map(|l| {
            [
                stats::mean(&acc[l * 3]),
                stats::mean(&acc[l * 3 + 1]),
                stats::mean(&acc[l * 3 + 2]),
            ]
        })
        .collect();
    Ok(ActReport { per_level, heatmap: heat, probs: probs.unwrap() })
}

impl ActReport {
    /// Table 5's row: stats of the input to the LAST transformer block.
    pub fn last_block(&self) -> [f64; 3] {
        self.per_level[self.per_level.len() - 2]
    }

    /// Fraction of attention mass landing on the prefix region for one
    /// layer (Figure 3 / §6.2's "attention redirected onto CushionCache").
    pub fn prefix_attention_mass(&self, layer: usize, m_max: usize) -> f64 {
        let shape = &self.probs.shape; // [L, H, Sq, Skv]
        let (h, sq, skv) = (shape[1], shape[2], shape[3]);
        let mut on_prefix = 0.0f64;
        let mut total = 0.0f64;
        for hi in 0..h {
            for qi in 0..sq {
                for ki in 0..skv {
                    let p = self.probs.data
                        [((layer * h + hi) * sq + qi) * skv + ki] as f64;
                    total += p;
                    if ki < m_max {
                        on_prefix += p;
                    }
                }
            }
        }
        if total == 0.0 {
            0.0
        } else {
            on_prefix / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_mass_counts_prefix_keys() {
        let probs = Tensor::new(
            vec![1, 1, 2, 4],
            vec![
                0.5, 0.5, 0.0, 0.0, // q0: all mass on first two keys
                0.0, 0.0, 1.0, 0.0, // q1: all mass past the prefix
            ],
        );
        let r = ActReport { per_level: vec![], heatmap: vec![], probs };
        let mass = r.prefix_attention_mass(0, 2);
        assert!((mass - 0.5).abs() < 1e-9);
    }
}
