//! Zero-shot / MMLU / GSM task evaluation (Tables 2, 7, 9).
//!
//! Multiple-choice items are scored by length-normalized candidate
//! log-likelihood (the LM-eval-harness convention); argmax items by exact
//! next-token argmax; generative items by greedy continuation + exact
//! match of the answer token.

use std::collections::BTreeMap;

use crate::data::tasks::{Task, TaskItem, KIND_ARGMAX, KIND_GEN, KIND_MC};
use crate::data::{DOT, PAD};
use crate::model::session::Session;
use crate::quant::scheme::Scheme;

use super::perplexity::{argmax, log_softmax_at};

#[derive(Clone, Debug)]
pub struct TaskScore {
    pub name: String,
    pub accuracy: f64,
    pub n_items: usize,
    /// For mmlu-syn: per-subject accuracy.
    pub per_meta: BTreeMap<u32, f64>,
}

/// Evaluate one task. `max_items` bounds wall-clock for the quick paths.
pub fn eval_task(session: &Session, scheme: &Scheme, task: &Task,
                 max_items: usize) -> crate::Result<TaskScore> {
    let items = &task.items[..task.items.len().min(max_items)];
    let mut correct = 0usize;
    let mut meta_hits: BTreeMap<u32, (usize, usize)> = BTreeMap::new();

    // batched row evaluation: collect (row tokens, judge closure feed)
    let mut rows: Vec<Vec<i32>> = Vec::new();
    let mut row_meta: Vec<(usize, usize, usize)> = Vec::new(); // item, cand, ctx_len
    for (ii, item) in items.iter().enumerate() {
        match item.kind {
            KIND_MC => {
                for (ci, cand) in item.candidates.iter().enumerate() {
                    let mut row = item.context.clone();
                    row.extend_from_slice(cand);
                    row_meta.push((ii, ci, item.context.len()));
                    rows.push(row);
                }
            }
            KIND_ARGMAX => {
                row_meta.push((ii, 0, item.context.len()));
                rows.push(item.context.clone());
            }
            KIND_GEN => {} // handled separately below
            k => anyhow::bail!("unknown task kind {k}"),
        }
    }

    let scores = score_rows(session, scheme, &rows, &row_meta, items)?;

    // aggregate per item
    let mut best: BTreeMap<usize, (usize, f64)> = BTreeMap::new();
    for ((ii, ci, _), sc) in row_meta.iter().zip(&scores) {
        let e = best.entry(*ii).or_insert((usize::MAX, f64::NEG_INFINITY));
        if *sc > e.1 {
            *e = (*ci, *sc);
        }
    }
    for (ii, item) in items.iter().enumerate() {
        let ok = match item.kind {
            KIND_MC => best.get(&ii).map(|b| b.0) == Some(item.gold),
            // ARGMAX rows score +inf on a hit, -inf on a miss
            KIND_ARGMAX => best.get(&ii).map(|b| b.1 == f64::INFINITY)
                .unwrap_or(false),
            KIND_GEN => eval_gen(session, scheme, item)?,
            _ => false,
        };
        if ok {
            correct += 1;
        }
        let e = meta_hits.entry(item.meta).or_insert((0, 0));
        e.1 += 1;
        if ok {
            e.0 += 1;
        }
    }

    Ok(TaskScore {
        name: task.name.clone(),
        accuracy: correct as f64 / items.len().max(1) as f64,
        n_items: items.len(),
        per_meta: meta_hits
            .into_iter()
            .map(|(k, (c, n))| (k, c as f64 / n.max(1) as f64))
            .collect(),
    })
}

/// Batched scoring of packed rows through the eval fwd graph.
/// MC rows return mean candidate log-likelihood; ARGMAX rows return a
/// sentinel score encoding whether the argmax hit gold.
fn score_rows(session: &Session, scheme: &Scheme, rows: &[Vec<i32>],
              row_meta: &[(usize, usize, usize)], items: &[TaskItem])
              -> crate::Result<Vec<f64>> {
    let m = &session.manifest;
    let (b, s, v) = (m.eval_batch, m.seq_len, m.vocab);
    let mut out = vec![f64::NEG_INFINITY; rows.len()];
    for (chunk_idx, chunk) in rows.chunks(b).enumerate() {
        let mut tokens = Vec::with_capacity(b * s);
        for row in chunk {
            anyhow::ensure!(row.len() <= s, "task row longer than seq_len");
            let mut padded = row.clone();
            padded.resize(s, PAD);
            tokens.extend_from_slice(&padded);
        }
        for _ in chunk.len()..b {
            tokens.extend(std::iter::repeat(PAD).take(s));
        }
        let fwd = session.fwd(scheme, &tokens)?;
        for (ri, row) in chunk.iter().enumerate() {
            let gi = chunk_idx * b + ri;
            let (ii, _ci, ctx_len) = row_meta[gi];
            let item = &items[ii];
            let logits = |pos: usize| -> &[f32] {
                &fwd.data[(ri * s + pos) * v..(ri * s + pos + 1) * v]
            };
            out[gi] = match item.kind {
                KIND_ARGMAX => {
                    // predict the token after the context; +inf/-inf
                    // sentinel consumed by the aggregation in eval_task
                    let gold = item.candidates[0][0] as usize;
                    if argmax(logits(ctx_len - 1)) == gold {
                        f64::INFINITY
                    } else {
                        f64::NEG_INFINITY
                    }
                }
                _ => {
                    // mean LL of candidate tokens (positions ctx..row_len)
                    let mut ll = 0.0f64;
                    let mut n = 0usize;
                    for pos in ctx_len..row.len() {
                        ll += log_softmax_at(logits(pos - 1), row[pos] as usize);
                        n += 1;
                    }
                    ll / n.max(1) as f64
                }
            };
        }
    }
    Ok(out)
}

/// Greedy generation for gsm-syn: continue the context until <dot> (or 8
/// steps) and exact-match the token right before it against gold.
fn eval_gen(session: &Session, scheme: &Scheme, item: &TaskItem)
            -> crate::Result<bool> {
    let m = &session.manifest;
    let (b, s, v) = (m.eval_batch, m.seq_len, m.vocab);
    let gold = item.candidates[0][0];
    let mut row = item.context.clone();
    for _step in 0..8 {
        if row.len() >= s {
            return Ok(false);
        }
        let mut tokens = row.clone();
        tokens.resize(s, PAD);
        let mut batch = tokens;
        batch.resize(b * s, PAD);
        let fwd = session.fwd(scheme, &batch)?;
        let pos = row.len() - 1;
        let next = argmax(&fwd.data[pos * v..(pos + 1) * v]) as i32;
        if next == DOT {
            return Ok(row.last() == Some(&gold));
        }
        row.push(next);
    }
    Ok(false)
}

/// Generative task evaluation through the *serving* path (prefill +
/// decode over the slot cache) — required for KV-cache quantization
/// (KIVI, Table 9), which only exists in the serving graphs.
pub fn eval_gen_serving(engine: &mut crate::coordinator::Engine, task: &Task,
                        max_items: usize) -> crate::Result<TaskScore> {
    let items: Vec<&TaskItem> = task
        .items
        .iter()
        .filter(|i| i.kind == KIND_GEN)
        .take(max_items)
        .collect();
    let mut correct = 0usize;
    for item in &items {
        engine.reset_cache();
        let slot = engine
            .kv
            .alloc(1, item.context.len())
            .ok_or_else(|| anyhow::anyhow!("context does not fit cache"))?;
        let gold = item.candidates[0][0];
        let mut last = engine.prefill(slot, &item.context)?;
        let mut prev = *item.context.last().unwrap();
        let mut ok = false;
        for _ in 0..8 {
            if last == DOT {
                ok = prev == gold;
                break;
            }
            if engine.kv.remaining(slot) == 0 {
                break;
            }
            let mut toks = vec![PAD; engine.session.manifest.serve_batch];
            toks[slot] = last;
            let next = engine.decode_step(&toks)?[slot];
            engine.kv.push_token(slot); // `last` is now cached
            prev = last;
            last = next;
        }
        if ok {
            correct += 1;
        }
    }
    Ok(TaskScore {
        name: task.name.clone(),
        accuracy: correct as f64 / items.len().max(1) as f64,
        n_items: items.len(),
        per_meta: BTreeMap::new(),
    })
}

/// Average accuracy over the seven zero-shot tasks (Table 2's metric).
pub fn zero_shot_average(scores: &[TaskScore]) -> f64 {
    let zs: Vec<&TaskScore> = scores
        .iter()
        .filter(|s| crate::data::tasks::ZERO_SHOT.contains(&s.name.as_str()))
        .collect();
    if zs.is_empty() {
        return 0.0;
    }
    zs.iter().map(|s| s.accuracy).sum::<f64>() / zs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shot_average_filters() {
        let mk = |name: &str, acc: f64| TaskScore {
            name: name.into(),
            accuracy: acc,
            n_items: 1,
            per_meta: Default::default(),
        };
        let scores = vec![mk("lambada-syn", 1.0), mk("copa-syn", 0.0),
                          mk("gsm-syn", 0.123)];
        assert!((zero_shot_average(&scores) - 0.5).abs() < 1e-12);
    }
}
