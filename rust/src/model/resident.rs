//! ResidentPool: device-resident caching of loop-invariant operands.
//!
//! Every graph in this system takes the same handful of operands on every
//! call of a serving/eval loop: the weight bundle, the calibration
//! `ranges`, the SmoothQuant `inv_smooth` scales, the cushion prefix KV,
//! and (for the search scorer) the padded prefix tokens. The seed runtime
//! re-uploaded all of them per call; this pool uploads each exactly once
//! per (re)configuration and hands out shared `Rc<DeviceBuf>` handles
//! (backend-resident on PJRT *and* on the reference interpreter, where
//! residency is host memory but the upload-once contract is identical).
//!
//! Invalidation rules (dirty-tracking is by construction — the Session
//! setters are the only mutation paths and each invalidates exactly the
//! entries derived from what changed):
//! * `Session::set_weights` / `reset_weights`  -> weights
//! * `Session::set_ranges` (calibrate_into)    -> KEY_RANGES
//! * `Session::set_inv_smooth`                 -> KEY_INV_SMOOTH
//! * cushion install/clear (`set_cushion`,
//!   `set_cushion_tokens`, `clear_cushion`)    -> KEY_PREFIX_KV + KEY_PREFIX_LEN
//! * padded prefix tokens are content-keyed: a lookup with different
//!   tokens replaces the entry automatically.
//!
//! Per-key upload counts are kept for observability: the residency tests
//! and `benches/perf_hotpath.rs` assert "uploaded exactly once per
//! configuration" through them.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Mutex;

use crate::runtime::literalx::HostValue;
use crate::runtime::{Client, DeviceBuf};
use crate::util::tensor::Tensor;

use super::weights::Weights;

/// Pool key: static-range calibration result, [n_sites, 2].
pub const KEY_RANGES: &str = "ranges";
/// Pool key: SmoothQuant inverse migration scales, [L, 2, d].
pub const KEY_INV_SMOOTH: &str = "inv_smooth";
/// Pool key: cushion prefix KV (or the all-zero empty prefix).
pub const KEY_PREFIX_KV: &str = "prefix_kv";
/// Pool key: the cushion prefix length scalar. Invalidated together with
/// KEY_PREFIX_KV so the (KV, len) pair a graph sees is always coherent.
pub const KEY_PREFIX_LEN: &str = "prefix_len";
/// Upload-count key for the weight bundle (one count per full upload).
pub const KEY_WEIGHTS: &str = "weights";
/// Upload-count key for the padded prefix-token buffer.
pub const KEY_PREFIX_TOKENS: &str = "prefix_tokens";
/// Upload-count key for the per-shard weight slice bundles (one count
/// per full re-slice of all shards).
pub const KEY_SHARD_WEIGHTS: &str = "shard_weights";
/// Upload-count key for the per-shard cushion/prefix KV slices.
pub const KEY_SHARD_PREFIX_KV: &str = "shard_prefix_kv";

// Locking note: `Rc<DeviceBuf>` makes the pool (like the rest of the
// runtime-touching types here) !Send/!Sync, so these Mutexes can never be
// contended — they are kept for consistency with the seed's idiom
// (Session's old `weight_bufs: Mutex<..>`, Registry's compile cache) and
// so that a future Rc->Arc swap (multi-engine scheduler) only has to
// change the handle type, not the interior-mutability story.
pub struct ResidentPool {
    client: Client,
    weights: Mutex<Option<Vec<Rc<DeviceBuf>>>>,
    single: Mutex<HashMap<&'static str, Rc<DeviceBuf>>>,
    /// Content-keyed cache of the padded prefix-token vector (the greedy
    /// search scores thousands of candidate batches under one prefix).
    tokens: Mutex<Option<(Vec<i32>, Rc<DeviceBuf>)>>,
    /// Tensor-parallel residency (host tensors: shard threads are the
    /// logical devices and execute on host values directly). Keyed by
    /// shard count; sliced once per (re)configuration like everything
    /// else here. Invalidated with the full bundle / prefix KV.
    shard_weights: Mutex<Option<(usize, Vec<Rc<Vec<Tensor>>>)>>,
    shard_prefix: Mutex<Option<(usize, Vec<Rc<Tensor>>)>>,
    uploads: Mutex<HashMap<&'static str, u64>>,
}

impl ResidentPool {
    pub fn new(client: Client) -> Self {
        Self {
            client,
            weights: Mutex::new(None),
            single: Mutex::new(HashMap::new()),
            tokens: Mutex::new(None),
            shard_weights: Mutex::new(None),
            shard_prefix: Mutex::new(None),
            uploads: Mutex::new(HashMap::new()),
        }
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    fn count_upload(&self, key: &'static str) {
        *self.uploads.lock().unwrap().entry(key).or_insert(0) += 1;
    }

    /// How many times the entry under `key` has been uploaded since the
    /// pool was created (KEY_WEIGHTS counts full-bundle uploads).
    pub fn upload_count(&self, key: &str) -> u64 {
        self.uploads.lock().unwrap().get(key).copied().unwrap_or(0)
    }

    // -- weight bundle ----------------------------------------------------

    /// The device-resident weight bundle, uploading on first use.
    pub fn weight_buffers(&self, w: &Weights) -> crate::Result<Vec<Rc<DeviceBuf>>> {
        let mut guard = self.weights.lock().unwrap();
        if guard.is_none() {
            let bufs = w
                .tensors
                .iter()
                .map(|t| Ok(Rc::new(self.client.upload(t)?)))
                .collect::<crate::Result<Vec<_>>>()?;
            self.count_upload(KEY_WEIGHTS);
            *guard = Some(bufs);
        }
        Ok(guard.as_ref().unwrap().clone())
    }

    pub fn invalidate_weights(&self) {
        *self.weights.lock().unwrap() = None;
        *self.shard_weights.lock().unwrap() = None;
    }

    // -- per-shard slices (tensor-parallel residency) ----------------------

    /// The per-shard weight slice bundles for an `n_shards` group,
    /// slicing once on first use (re-sliced only after
    /// `invalidate_weights`). Shard `k`'s bundle is `out[k]`, in param
    /// order; the `Rc` stays on the driver thread — shard threads
    /// borrow `&[Tensor]` through `std::thread::scope`.
    pub fn shard_weight_slices(
        &self,
        w: &Weights,
        manifest: &super::manifest::Manifest,
        n_shards: usize,
    ) -> crate::Result<Vec<Rc<Vec<Tensor>>>> {
        let mut guard = self.shard_weights.lock().unwrap();
        if let Some((n, slices)) = guard.as_ref() {
            if *n == n_shards {
                return Ok(slices.clone());
            }
        }
        let slices = (0..n_shards)
            .map(|k| {
                let plan = crate::runtime::collective::ShardPlan::new(k, n_shards);
                Ok(Rc::new(w.shard_slices(manifest, plan)?))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        self.count_upload(KEY_SHARD_WEIGHTS);
        *guard = Some((n_shards, slices.clone()));
        Ok(slices)
    }

    /// The per-shard cushion/prefix KV slices (`[L, 2, Hkv/n, m, dh]`),
    /// slicing `make()`'s full tensor once on first use. Invalidated
    /// together with KEY_PREFIX_KV so the slices always match the
    /// installed cushion.
    pub fn shard_prefix_slices(
        &self,
        n_shards: usize,
        make: impl FnOnce() -> Tensor,
    ) -> crate::Result<Vec<Rc<Tensor>>> {
        let mut guard = self.shard_prefix.lock().unwrap();
        if let Some((n, slices)) = guard.as_ref() {
            if *n == n_shards {
                return Ok(slices.clone());
            }
        }
        let full = make();
        let slices = (0..n_shards)
            .map(|k| {
                let plan = crate::runtime::collective::ShardPlan::new(k, n_shards);
                Ok(Rc::new(super::weights::shard_prefix_kv(&full, plan)?))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        self.count_upload(KEY_SHARD_PREFIX_KV);
        *guard = Some((n_shards, slices.clone()));
        Ok(slices)
    }

    // -- single-tensor invariants -----------------------------------------

    /// The resident buffer under `key`, uploading `make()` on first use
    /// (or after `invalidate(key)`).
    pub fn get_or_upload(
        &self,
        key: &'static str,
        make: impl FnOnce() -> HostValue,
    ) -> crate::Result<Rc<DeviceBuf>> {
        let mut guard = self.single.lock().unwrap();
        if let Some(b) = guard.get(key) {
            return Ok(b.clone());
        }
        let buf = self.client.upload_host(&make())?;
        self.count_upload(key);
        let rc = Rc::new(buf);
        guard.insert(key, rc.clone());
        Ok(rc)
    }

    pub fn invalidate(&self, key: &str) {
        self.single.lock().unwrap().remove(key);
        if key == KEY_PREFIX_KV {
            *self.shard_prefix.lock().unwrap() = None;
        }
    }

    // -- padded prefix tokens (content-keyed) ------------------------------

    /// Resident buffer for a padded prefix-token vector; re-uploaded only
    /// when the tokens differ from the cached entry.
    pub fn prefix_tokens(&self, padded: &[i32]) -> crate::Result<Rc<DeviceBuf>> {
        let mut guard = self.tokens.lock().unwrap();
        if let Some((cached, buf)) = guard.as_ref() {
            if cached == padded {
                return Ok(buf.clone());
            }
        }
        let buf = Rc::new(self.client.upload_i32(padded, &[padded.len()])?);
        self.count_upload(KEY_PREFIX_TOKENS);
        *guard = Some((padded.to_vec(), buf.clone()));
        Ok(buf)
    }

    /// Drop every resident entry (weights included).
    pub fn clear(&self) {
        self.invalidate_weights();
        self.single.lock().unwrap().clear();
        *self.tokens.lock().unwrap() = None;
        *self.shard_prefix.lock().unwrap() = None;
    }

    /// Keys currently resident (debugging / tests).
    pub fn resident_keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self
            .single
            .lock()
            .unwrap()
            .keys()
            .map(|k| k.to_string())
            .collect();
        if self.weights.lock().unwrap().is_some() {
            keys.push(KEY_WEIGHTS.to_string());
        }
        if self.tokens.lock().unwrap().is_some() {
            keys.push(KEY_PREFIX_TOKENS.to_string());
        }
        keys.sort();
        keys
    }
}
