//! Model metadata + weight bundle handling.

pub mod forward;
pub mod manifest;
pub mod resident;
pub mod session;
pub mod weights;

pub use manifest::Manifest;
pub use resident::ResidentPool;
pub use session::{Cushion, Session, StatsOut};
pub use weights::Weights;

/// List the variants present under the artifacts directory.
pub fn available_variants() -> Vec<String> {
    let dir = crate::util::fsutil::artifacts_dir();
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&dir) {
        for e in rd.flatten() {
            if e.path().join("manifest.json").exists() {
                if let Some(n) = e.file_name().to_str() {
                    out.push(n.to_string());
                }
            }
        }
    }
    out.sort();
    out
}
