//! manifest.json: the variant's config, tensor spec, and graph inventory
//! (written by python/compile/aot.py).

use std::path::Path;

use crate::util::json::{self, Value};

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub variant: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub norm: String,
    pub act: String,
    pub pos: String,
    pub window: usize, // 0 = full attention
    pub n_sites: usize,
    pub seq_len: usize,
    /// Prefill bucket lengths (ascending, last == seq_len): one
    /// `prefill_sampled_*_b<n>` graph is lowered per bucket and the
    /// serving engine picks the smallest bucket >= prompt length.
    /// Manifests written before buckets existed default to `[seq_len]`.
    pub prefill_buckets: Vec<usize>,
    pub m_max: usize,
    pub cache_cap: usize,
    /// Paged KV pool geometry (coordinator::kvpool). 0 = derive: block
    /// size min(16, m_max) tokens; pool sized so every serve lane can
    /// reach cache_cap with the cushion run shared once.
    pub kv_block_size: usize,
    pub kv_pool_blocks: usize,
    /// Tensor-parallel shard count (runtime::collective). 1 = unsharded
    /// (the default for manifests written before sharding existed).
    /// Validated against head/column divisibility at parse time so a
    /// bad count fails at load, not mid-forward.
    pub n_shards: usize,
    pub serve_batch: usize,
    pub eval_batch: usize,
    pub score_batch: usize,
    pub score_text_len: usize,
    pub tune_batch: usize,
    pub params: Vec<ParamSpec>,
    pub graphs: Vec<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> crate::Result<Self> {
        let v = json::parse(text)?;
        let params = v
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("params not an array"))?
            .iter()
            .map(|p| -> crate::Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Value::as_usize)
                        .collect(),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        let seq_len = v.req_usize("seq_len")?;
        let mut prefill_buckets: Vec<usize> = v
            .get("prefill_buckets")
            .and_then(Value::as_arr)
            .map(|a| a.iter().filter_map(Value::as_usize).collect())
            .unwrap_or_default();
        prefill_buckets.retain(|&b| b > 0 && b <= seq_len);
        prefill_buckets.sort_unstable();
        prefill_buckets.dedup();
        if prefill_buckets.is_empty() {
            prefill_buckets = vec![seq_len];
        }
        let graphs = v
            .req("graphs")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|g| g.as_str().map(str::to_string))
            .collect();
        Ok(Self {
            variant: v.req_str("variant")?.to_string(),
            vocab: v.req_usize("vocab")?,
            d_model: v.req_usize("d_model")?,
            n_layers: v.req_usize("n_layers")?,
            n_heads: v.req_usize("n_heads")?,
            n_kv_heads: v.req_usize("n_kv_heads")?,
            d_head: v.req_usize("d_head")?,
            d_ff: v.req_usize("d_ff")?,
            norm: v.req_str("norm")?.to_string(),
            act: v.req_str("act")?.to_string(),
            pos: v.req_str("pos")?.to_string(),
            window: v.req_usize("window")?,
            n_sites: v.req_usize("n_sites")?,
            seq_len,
            prefill_buckets,
            m_max: v.req_usize("m_max")?,
            cache_cap: v.req_usize("cache_cap")?,
            kv_block_size: v
                .get("kv_block_size")
                .and_then(Value::as_usize)
                .unwrap_or(0),
            kv_pool_blocks: v
                .get("kv_pool_blocks")
                .and_then(Value::as_usize)
                .unwrap_or(0),
            n_shards: {
                let n = v.get("n_shards").and_then(Value::as_usize).unwrap_or(1);
                crate::runtime::collective::ShardPlan::validate(
                    v.req_usize("n_kv_heads")?,
                    v.req_usize("d_ff")?,
                    n,
                )?;
                n
            },
            serve_batch: v.req_usize("serve_batch")?,
            eval_batch: v.req_usize("eval_batch")?,
            score_batch: v.req_usize("score_batch")?,
            score_text_len: v.req_usize("score_text_len")?,
            tune_batch: v.req_usize("tune_batch")?,
            params,
            graphs,
        })
    }

    pub fn load_variant(variant: &str) -> crate::Result<Self> {
        Self::load(&crate::util::fsutil::variant_dir(variant).join("manifest.json"))
    }

    /// Sites are (layer, kind) with kinds attn_in/attn_out/mlp_in/mlp_hidden.
    pub fn site_name(&self, idx: usize) -> String {
        const KINDS: [&str; 4] = ["attn_in", "attn_out", "mlp_in", "mlp_hidden"];
        format!("layer{}.{}", idx / 4, KINDS[idx % 4])
    }

    pub fn is_pre_norm(&self) -> bool {
        self.norm == "rmsnorm_pre"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "variant": "tl-x", "vocab": 512, "d_model": 256, "n_layers": 4,
      "n_heads": 4, "n_kv_heads": 2, "d_head": 64, "d_ff": 688,
      "norm": "rmsnorm_pre", "act": "swiglu", "pos": "rope", "window": 0,
      "n_sites": 16, "seq_len": 128, "m_max": 16, "cache_cap": 144,
      "serve_batch": 8, "eval_batch": 8, "score_batch": 64,
      "score_text_len": 96, "tune_batch": 8,
      "params": [{"name": "embed", "shape": [512, 256]}],
      "graphs": ["fwd_fp", "decode_pts"]
    }"#;

    #[test]
    fn parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.variant, "tl-x");
        assert_eq!(m.params[0].shape, vec![512, 256]);
        assert!(m.is_pre_norm());
        assert_eq!(m.site_name(5), "layer1.attn_out");
        assert_eq!(m.graphs.len(), 2);
        // pre-bucket manifests degrade to one full-length bucket
        assert_eq!(m.prefill_buckets, vec![128]);
        // pre-paging manifests derive the pool geometry (0 = auto)
        assert_eq!(m.kv_block_size, 0);
        assert_eq!(m.kv_pool_blocks, 0);
        // pre-sharding manifests default to one shard
        assert_eq!(m.n_shards, 1);
    }

    #[test]
    fn n_shards_parses_and_validates_at_load() {
        let with = SAMPLE.replacen(
            "\"cache_cap\": 144,",
            "\"cache_cap\": 144, \"n_shards\": 2,",
            1,
        );
        assert_eq!(Manifest::parse(&with).unwrap().n_shards, 2);
        // n_kv_heads = 2 is not divisible 4 ways: must fail at parse,
        // not mid-forward
        let bad = SAMPLE.replacen(
            "\"cache_cap\": 144,",
            "\"cache_cap\": 144, \"n_shards\": 4,",
            1,
        );
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("n_kv_heads"), "{err:#}");
        let zero = SAMPLE.replacen(
            "\"cache_cap\": 144,",
            "\"cache_cap\": 144, \"n_shards\": 0,",
            1,
        );
        assert!(Manifest::parse(&zero).is_err());
    }

    #[test]
    fn kv_pool_fields_parse_when_present() {
        let with = SAMPLE.replacen(
            "\"cache_cap\": 144,",
            "\"cache_cap\": 144, \"kv_block_size\": 8, \"kv_pool_blocks\": 40,",
            1,
        );
        let m = Manifest::parse(&with).unwrap();
        assert_eq!(m.kv_block_size, 8);
        assert_eq!(m.kv_pool_blocks, 40);
    }

    #[test]
    fn prefill_buckets_parse_sorted_and_bounded() {
        let with = SAMPLE.replacen(
            "\"seq_len\": 128,",
            "\"seq_len\": 128, \"prefill_buckets\": [128, 32, 64, 999, 32],",
            1,
        );
        let m = Manifest::parse(&with).unwrap();
        // sorted, deduped, clamped to seq_len (the 999 entry is dropped)
        assert_eq!(m.prefill_buckets, vec![32, 64, 128]);
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse("{}").is_err());
    }
}
