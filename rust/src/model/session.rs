//! Session: one loaded variant — manifest + (possibly transformed) weight
//! bundle + compiled graphs + quantization state + CushionCache.
//!
//! This is the substrate shared by calibration (quant::calibrate), the
//! CushionCache drivers (cushion::search / cushion::tune), the evaluation
//! harness (eval::*), and the serving engine (coordinator::engine).
//!
//! Loop-invariant operands — the weight bundle, the calibration `ranges`,
//! the SmoothQuant `inv_smooth` scales, the cushion prefix KV, the padded
//! prefix tokens — live in a `ResidentPool` of device buffers, uploaded
//! once and reused across calls. The quantization state is therefore
//! private with invalidating setters (`set_ranges`, `set_inv_smooth`,
//! `set_cushion*`), mirroring `set_weights`: each setter evicts exactly
//! the pool entries derived from what changed.

use crate::data::corpus::Corpus;
use crate::quant::scales;
use crate::quant::scheme::Scheme;
use crate::runtime::literalx::{HostValue, IntTensor, Outputs, Value};
use crate::runtime::split::TupleSplitter;
use crate::runtime::{Client, Registry};
use crate::util::fsutil;
use crate::util::tensor::Tensor;

use super::manifest::Manifest;
use super::resident::{self, ResidentPool};
use super::weights::Weights;

/// A discovered CushionCache: the searched prefix tokens and their
/// per-layer KV (possibly further tuned), [L, 2, Hkv, M_MAX, dh].
#[derive(Clone, Debug)]
pub struct Cushion {
    pub tokens: Vec<i32>,
    pub len: usize,
    pub kv: Tensor,
}

pub struct Session {
    pub manifest: Manifest,
    pub base_weights: Weights,
    /// Current (possibly transformed) weights. Mutate via `set_weights`
    /// only — direct writes would bypass the resident pool.
    pub weights: Weights,
    pub registry: Registry,
    pub corpus: Corpus,
    /// Static-range calibration result, [n_sites, 2] (lo, scale).
    ranges: Tensor,
    /// SmoothQuant inverse migration scales, [L, 2, d] (ones = off).
    inv_smooth: Tensor,
    cushion: Option<Cushion>,
    pool: ResidentPool,
}

pub struct StatsOut {
    pub minmax: Tensor,     // [n_sites, 2]
    pub chan_d: Tensor,     // [3L, d]   per-channel absmax (attn_in/out, mlp_in)
    pub chan_f: Tensor,     // [L, d_ff] per-channel absmax (mlp_hidden)
    pub acts_grid: Tensor,  // [L+1, B, S] channel-absmax of block inputs
    pub act_stats: Tensor,  // [L+1, 3] top-1 / p90 / median magnitude
    pub probs: Tensor,      // [L, Hq, S, M+S] attention maps (batch 0)
}

impl Session {
    /// Load a variant from its artifact directory on the backend the
    /// environment selects (`Client::auto`: CUSHION_BACKEND / PJRT
    /// availability — see runtime::backend).
    pub fn load(variant: &str) -> crate::Result<Self> {
        let client = Client::auto()?;
        Self::load_with_client(variant, client)
    }

    pub fn load_with_client(variant: &str, client: Client) -> crate::Result<Self> {
        let dir = fsutil::variant_dir(variant);
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let weights = Weights::load(&dir.join("weights.bin"), &manifest)?;
        let corpus = Corpus::load(&dir.join("corpus.bin"))?;
        Self::from_parts_at(manifest, weights, corpus, client, dir)
    }

    /// Assemble a session from in-memory parts — no artifact directory
    /// at all. Graphs resolve to reference-interpreter programs (the
    /// hermetic test path: testkit::tiny builds manifest/weights/corpus
    /// from thin air).
    pub fn from_parts(manifest: Manifest, weights: Weights, corpus: Corpus,
                      client: Client) -> crate::Result<Self> {
        let dir = fsutil::variant_dir(&manifest.variant);
        Self::from_parts_at(manifest, weights, corpus, client, dir)
    }

    fn from_parts_at(manifest: Manifest, weights: Weights, corpus: Corpus,
                     client: Client, dir: std::path::PathBuf)
                     -> crate::Result<Self> {
        let pool = ResidentPool::new(client.clone());
        let registry = Registry::new(client, dir);
        // every load path can fall back to the interpreter per-graph
        registry.enable_interp(crate::runtime::interp::spec_for(&manifest)?);
        let n_sites = manifest.n_sites;
        let l = manifest.n_layers;
        let d = manifest.d_model;
        Ok(Self {
            base_weights: weights.clone(),
            weights,
            manifest,
            registry,
            corpus,
            ranges: scales::unit_ranges(n_sites),
            inv_smooth: Tensor::full(&[l, 2, d], 1.0),
            cushion: None,
            pool,
        })
    }

    /// The device-resident operand pool (observability / tests).
    pub fn pool(&self) -> &ResidentPool {
        &self.pool
    }

    // -- weight management ------------------------------------------------

    pub fn set_weights(&mut self, w: Weights) {
        self.weights = w;
        self.pool.invalidate_weights();
    }

    pub fn reset_weights(&mut self) {
        let base = self.base_weights.clone();
        self.set_weights(base);
    }

    // -- quantization state -----------------------------------------------

    pub fn ranges(&self) -> &Tensor {
        &self.ranges
    }

    /// Install new static calibration ranges (quant::calibrate_into).
    pub fn set_ranges(&mut self, ranges: Tensor) {
        self.ranges = ranges;
        self.pool.invalidate(resident::KEY_RANGES);
    }

    pub fn inv_smooth(&self) -> &Tensor {
        &self.inv_smooth
    }

    /// Install SmoothQuant inverse migration scales.
    pub fn set_inv_smooth(&mut self, inv: Tensor) {
        self.inv_smooth = inv;
        self.pool.invalidate(resident::KEY_INV_SMOOTH);
    }

    // -- graph execution --------------------------------------------------

    /// Execute graph `name` with the resident weights + these operands.
    /// Outputs stay in runtime form; fetch only what you need (see
    /// literalx::Outputs).
    pub fn run_values(&self, name: &str, extra: Vec<Value>) -> crate::Result<Outputs> {
        self.run_values_split(name, extra, None)
    }

    /// `run_values` with an optional on-device tuple splitter for the
    /// graph's output signature (runtime::split): the hot-path variant
    /// where a tuple-shaped result decomposes into per-output *device*
    /// buffers instead of materializing as one host literal.
    pub fn run_values_split(
        &self,
        name: &str,
        extra: Vec<Value>,
        splitter: Option<&TupleSplitter>,
    ) -> crate::Result<Outputs> {
        let exe = self.registry.get(name)?;
        let client = self.registry.client();
        let mut bufs = self.pool.weight_buffers(&self.weights)?;
        bufs.reserve(extra.len());
        for v in extra {
            bufs.push(v.into_buffer(client)?);
        }
        client.backend().execute(&exe, &bufs, splitter)
    }

    /// Execute graph `name` with host args, fetching all outputs as f32
    /// host tensors (compat path for drivers that consume everything).
    /// Uploads straight from the borrowed args — no tensor clones.
    pub fn run(&self, name: &str, extra: &[HostValue]) -> crate::Result<Vec<Tensor>> {
        let exe = self.registry.get(name)?;
        let client = self.registry.client();
        let mut bufs = self.pool.weight_buffers(&self.weights)?;
        bufs.reserve(extra.len());
        for v in extra {
            bufs.push(std::rc::Rc::new(client.upload_host(v)?));
        }
        client.backend().execute(&exe, &bufs, None)?.into_tensors()
    }

    // -- pooled operand handles -------------------------------------------

    /// Device-resident calibration ranges.
    pub fn ranges_value(&self) -> crate::Result<Value> {
        let buf = self
            .pool
            .get_or_upload(resident::KEY_RANGES, || HostValue::F32(self.ranges.clone()))?;
        Ok(Value::Device(buf))
    }

    /// Device-resident SmoothQuant scales.
    pub fn inv_smooth_value(&self) -> crate::Result<Value> {
        let buf = self.pool.get_or_upload(resident::KEY_INV_SMOOTH, || {
            HostValue::F32(self.inv_smooth.clone())
        })?;
        Ok(Value::Device(buf))
    }

    /// Device-resident cushion prefix KV (the all-zero empty prefix when
    /// no cushion is installed).
    pub fn prefix_kv_value(&self) -> crate::Result<Value> {
        let buf = self.pool.get_or_upload(resident::KEY_PREFIX_KV, || {
            HostValue::F32(match &self.cushion {
                Some(c) => c.kv.clone(),
                None => self.empty_prefix(),
            })
        })?;
        Ok(Value::Device(buf))
    }

    /// Device-resident prefix length scalar. Pooled under the same
    /// invalidation as the prefix KV, so a graph can never observe a new
    /// KV with a stale length (or vice versa).
    pub fn prefix_len_value(&self) -> crate::Result<Value> {
        let buf = self.pool.get_or_upload(resident::KEY_PREFIX_LEN, || {
            HostValue::scalar_i32(self.prefix_len())
        })?;
        Ok(Value::Device(buf))
    }

    /// Per-shard weight slice bundles for a tensor-parallel group,
    /// sliced once per configuration (model::resident caching).
    pub fn shard_weight_slices(
        &self,
        n_shards: usize,
    ) -> crate::Result<Vec<std::rc::Rc<Vec<Tensor>>>> {
        self.pool
            .shard_weight_slices(&self.weights, &self.manifest, n_shards)
    }

    /// Per-shard cushion prefix KV slices `[L, 2, Hkv/n, m, dh]`,
    /// sliced once per installed cushion (invalidated with the full
    /// prefix KV so the pair can never go stale independently).
    pub fn shard_prefix_slices(
        &self,
        n_shards: usize,
    ) -> crate::Result<Vec<std::rc::Rc<Tensor>>> {
        self.pool.shard_prefix_slices(n_shards, || match &self.cushion {
            Some(c) => c.kv.clone(),
            None => self.empty_prefix(),
        })
    }

    // -- prefix helpers ---------------------------------------------------

    pub fn m_max(&self) -> usize {
        self.manifest.m_max
    }

    pub fn cushion(&self) -> Option<&Cushion> {
        self.cushion.as_ref()
    }

    pub fn prefix_len(&self) -> i32 {
        self.cushion.as_ref().map(|c| c.len as i32).unwrap_or(0)
    }

    /// Host-side (prefix_kv, prefix_len) reflecting the current cushion
    /// (analysis/bench path; the hot paths use `prefix_kv_value`).
    pub fn prefix_args(&self) -> (Tensor, i32) {
        match &self.cushion {
            Some(c) => (c.kv.clone(), c.len as i32),
            None => (self.empty_prefix(), 0),
        }
    }

    pub fn empty_prefix(&self) -> Tensor {
        let m = &self.manifest;
        Tensor::zeros(&[m.n_layers, 2, m.n_kv_heads, m.m_max, m.d_head])
    }

    /// Compute the prefix KV for a token sequence via the prefix_kv graph.
    pub fn compute_prefix_kv(&self, tokens: &[i32]) -> crate::Result<Tensor> {
        let m = self.m_max();
        anyhow::ensure!(tokens.len() <= m, "prefix too long");
        let mut padded = tokens.to_vec();
        padded.resize(m, crate::data::PAD);
        let out = self.run(
            "prefix_kv",
            &[
                HostValue::I32(IntTensor::vec(padded)),
                HostValue::scalar_i32(tokens.len() as i32),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Validate a cushion against this variant's geometry. The KV must
    /// be exactly `[L, 2, Hkv, m_max, dh]` and finite — a torn or
    /// cross-variant cushion file must error here, *before* it poisons
    /// the serving pool's shared prefix blocks.
    pub fn validate_cushion(&self, c: &Cushion) -> crate::Result<()> {
        let m = &self.manifest;
        let want = vec![m.n_layers, 2, m.n_kv_heads, m.m_max, m.d_head];
        anyhow::ensure!(
            c.kv.shape == want,
            "cushion KV shape {:?} does not match this variant's \
             [L, 2, Hkv, m_max, dh] = {want:?}",
            c.kv.shape
        );
        anyhow::ensure!(
            c.len == c.tokens.len() && c.len <= m.m_max,
            "cushion length {} inconsistent ({} tokens, m_max {})",
            c.len,
            c.tokens.len(),
            m.m_max
        );
        anyhow::ensure!(
            c.kv.data.iter().all(|v| v.is_finite()),
            "cushion KV contains non-finite values"
        );
        Ok(())
    }

    /// Install a cushion directly (search/tune/store results). Rejects
    /// shape/length mismatches (`validate_cushion`).
    pub fn set_cushion(&mut self, c: Cushion) -> crate::Result<()> {
        self.validate_cushion(&c)?;
        self.cushion = Some(c);
        self.pool.invalidate(resident::KEY_PREFIX_KV);
        self.pool.invalidate(resident::KEY_PREFIX_LEN);
        Ok(())
    }

    /// Install a cushion from prefix tokens (computes its KV).
    pub fn set_cushion_tokens(&mut self, tokens: &[i32]) -> crate::Result<()> {
        let kv = self.compute_prefix_kv(tokens)?;
        self.set_cushion(Cushion { tokens: tokens.to_vec(), len: tokens.len(), kv })
    }

    pub fn clear_cushion(&mut self) {
        self.cushion = None;
        self.pool.invalidate(resident::KEY_PREFIX_KV);
        self.pool.invalidate(resident::KEY_PREFIX_LEN);
    }

    // -- eval forwards ----------------------------------------------------

    /// Quantized eval forward over one token batch [B, S] (B = eval_batch).
    /// Returns the logits [B, S, V] (the fwd graphs are the throughput
    /// path and emit nothing else — stats live in the stats graph).
    pub fn fwd(&self, scheme: &Scheme, tokens: &[i32]) -> crate::Result<Tensor> {
        let m = &self.manifest;
        let b = m.eval_batch;
        anyhow::ensure!(tokens.len() == b * m.seq_len, "bad token batch size");
        let name = format!("fwd_{}", scheme.gran.graph_suffix());
        let out = self.run_values(
            &name,
            vec![
                self.prefix_kv_value()?,
                self.prefix_len_value()?,
                Value::Host(HostValue::I32(IntTensor::new(
                    vec![b, m.seq_len],
                    tokens.to_vec(),
                ))),
                self.ranges_value()?,
                Value::scalar_f32(scheme.act_levels()),
                self.inv_smooth_value()?,
            ],
        )?;
        anyhow::ensure!(out.len() == 1, "fwd: expected 1 output");
        out.host_f32(0)
    }

    /// Analysis forward (stats graph) over one token batch.
    pub fn stats(&self, tokens: &[i32]) -> crate::Result<StatsOut> {
        let m = &self.manifest;
        let b = m.eval_batch;
        let out = self
            .run_values(
                "stats",
                vec![
                    self.prefix_kv_value()?,
                    self.prefix_len_value()?,
                    Value::Host(HostValue::I32(IntTensor::new(
                        vec![b, m.seq_len],
                        tokens.to_vec(),
                    ))),
                ],
            )?
            .into_tensors()?;
        anyhow::ensure!(out.len() == 6, "stats: expected 6 outputs");
        let mut it = out.into_iter();
        Ok(StatsOut {
            minmax: it.next().unwrap(),
            chan_d: it.next().unwrap(),
            chan_f: it.next().unwrap(),
            acts_grid: it.next().unwrap(),
            act_stats: it.next().unwrap(),
            probs: it.next().unwrap(),
        })
    }

    /// Greedy-search scorer: L_q for each candidate continuation token.
    /// The padded prefix and the smoothing scales are device-resident —
    /// one scoring round sweeps the whole vocab under a fixed prefix, so
    /// only the candidate/text batches cross to the device per call.
    pub fn score_candidates(
        &self,
        prefix: &[i32],
        cands: &[i32],
        text: &[i32],
        levels: f32,
    ) -> crate::Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(cands.len() == m.score_batch);
        anyhow::ensure!(text.len() == m.score_text_len);
        let mut padded = prefix.to_vec();
        padded.resize(m.m_max, crate::data::PAD);
        let ptok = self.pool.prefix_tokens(&padded)?;
        let out = self
            .run_values(
                "score_lq",
                vec![
                    Value::Device(ptok),
                    Value::scalar_i32(prefix.len() as i32),
                    Value::Host(HostValue::I32(IntTensor::vec(cands.to_vec()))),
                    Value::Host(HostValue::I32(IntTensor::vec(text.to_vec()))),
                    Value::scalar_f32(levels),
                    self.inv_smooth_value()?,
                ],
            )?
            .into_tensors()?;
        Ok(out.into_iter().next().unwrap().data)
    }
}
