//! Session: one loaded variant — manifest + (possibly transformed) weight
//! bundle + compiled graphs + quantization state + CushionCache.
//!
//! This is the substrate shared by calibration (quant::calibrate), the
//! CushionCache drivers (cushion::search / cushion::tune), the evaluation
//! harness (eval::*), and the serving engine (coordinator::engine).
//!
//! Weights are uploaded to the device once and reused across calls;
//! `set_weights` (after a SmoothQuant/AWQ/QuaRot/weight-qdq transform)
//! invalidates the cached device buffers.

use std::sync::Mutex;

use crate::data::corpus::Corpus;
use crate::quant::scales;
use crate::quant::scheme::Scheme;
use crate::runtime::literalx::{self, HostValue, IntTensor};
use crate::runtime::{Client, Registry};
use crate::util::fsutil;
use crate::util::tensor::Tensor;

use super::manifest::Manifest;
use super::weights::Weights;

/// A discovered CushionCache: the searched prefix tokens and their
/// per-layer KV (possibly further tuned), [L, 2, Hkv, M_MAX, dh].
#[derive(Clone, Debug)]
pub struct Cushion {
    pub tokens: Vec<i32>,
    pub len: usize,
    pub kv: Tensor,
}

pub struct Session {
    pub manifest: Manifest,
    pub base_weights: Weights,
    pub weights: Weights,
    pub registry: Registry,
    pub corpus: Corpus,
    /// Static-range calibration result, [n_sites, 2] (lo, scale).
    pub ranges: Tensor,
    /// SmoothQuant inverse migration scales, [L, 2, d] (ones = off).
    pub inv_smooth: Tensor,
    pub cushion: Option<Cushion>,
    weight_bufs: Mutex<Option<Vec<xla::PjRtBuffer>>>,
}

pub struct StatsOut {
    pub minmax: Tensor,     // [n_sites, 2]
    pub chan_d: Tensor,     // [3L, d]   per-channel absmax (attn_in/out, mlp_in)
    pub chan_f: Tensor,     // [L, d_ff] per-channel absmax (mlp_hidden)
    pub acts_grid: Tensor,  // [L+1, B, S] channel-absmax of block inputs
    pub act_stats: Tensor,  // [L+1, 3] top-1 / p90 / median magnitude
    pub probs: Tensor,      // [L, Hq, S, M+S] attention maps (batch 0)
}

impl Session {
    pub fn load(variant: &str) -> crate::Result<Self> {
        let client = Client::cpu()?;
        Self::load_with_client(variant, client)
    }

    pub fn load_with_client(variant: &str, client: Client) -> crate::Result<Self> {
        let dir = fsutil::variant_dir(variant);
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let weights = Weights::load(&dir.join("weights.bin"), &manifest)?;
        let corpus = Corpus::load(&dir.join("corpus.bin"))?;
        let registry = Registry::new(client, dir);
        let n_sites = manifest.n_sites;
        let l = manifest.n_layers;
        let d = manifest.d_model;
        Ok(Self {
            base_weights: weights.clone(),
            weights,
            manifest,
            registry,
            corpus,
            ranges: scales::unit_ranges(n_sites),
            inv_smooth: Tensor::full(&[l, 2, d], 1.0),
            cushion: None,
            weight_bufs: Mutex::new(None),
        })
    }

    // -- weight management ------------------------------------------------

    pub fn set_weights(&mut self, w: Weights) {
        self.weights = w;
        *self.weight_bufs.lock().unwrap() = None;
    }

    pub fn reset_weights(&mut self) {
        let base = self.base_weights.clone();
        self.set_weights(base);
    }

    fn ensure_weight_bufs(&self) -> crate::Result<()> {
        let mut guard = self.weight_bufs.lock().unwrap();
        if guard.is_none() {
            let client = self.registry.client();
            let bufs = self
                .weights
                .tensors
                .iter()
                .map(|t| client.upload(t))
                .collect::<crate::Result<Vec<_>>>()?;
            *guard = Some(bufs);
        }
        Ok(())
    }

    /// Execute graph `name` with the resident weights + these extra args.
    /// Returns all outputs as host f32 tensors (XLA's root tuple is
    /// decomposed transparently — see literalx::fetch_all_f32).
    pub fn run(&self, name: &str, extra: &[HostValue]) -> crate::Result<Vec<Tensor>> {
        self.ensure_weight_bufs()?;
        let exe = self.registry.get(name)?;
        let extra_bufs: Vec<xla::PjRtBuffer> = extra
            .iter()
            .map(|a| exe.upload(a))
            .collect::<crate::Result<_>>()?;
        let guard = self.weight_bufs.lock().unwrap();
        let weights = guard.as_ref().unwrap();
        let mut refs: Vec<&xla::PjRtBuffer> = weights.iter().collect();
        refs.extend(extra_bufs.iter());
        let outs = exe.run_buffers(&refs)?;
        drop(guard);
        literalx::fetch_all_f32(&outs)
    }

    // -- prefix helpers ---------------------------------------------------

    pub fn m_max(&self) -> usize {
        self.manifest.m_max
    }

    /// (prefix_kv, prefix_len) inputs reflecting the current cushion.
    pub fn prefix_args(&self) -> (Tensor, i32) {
        match &self.cushion {
            Some(c) => (c.kv.clone(), c.len as i32),
            None => (self.empty_prefix(), 0),
        }
    }

    pub fn empty_prefix(&self) -> Tensor {
        let m = &self.manifest;
        Tensor::zeros(&[m.n_layers, 2, m.n_kv_heads, m.m_max, m.d_head])
    }

    /// Compute the prefix KV for a token sequence via the prefix_kv graph.
    pub fn compute_prefix_kv(&self, tokens: &[i32]) -> crate::Result<Tensor> {
        let m = self.m_max();
        anyhow::ensure!(tokens.len() <= m, "prefix too long");
        let mut padded = tokens.to_vec();
        padded.resize(m, crate::data::PAD);
        let out = self.run(
            "prefix_kv",
            &[
                HostValue::I32(IntTensor::vec(padded)),
                HostValue::scalar_i32(tokens.len() as i32),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Install a cushion from prefix tokens (computes its KV).
    pub fn set_cushion_tokens(&mut self, tokens: &[i32]) -> crate::Result<()> {
        let kv = self.compute_prefix_kv(tokens)?;
        self.cushion = Some(Cushion { tokens: tokens.to_vec(), len: tokens.len(), kv });
        Ok(())
    }

    pub fn clear_cushion(&mut self) {
        self.cushion = None;
    }

    // -- eval forwards ----------------------------------------------------

    /// Quantized eval forward over one token batch [B, S] (B = eval_batch).
    /// Returns the logits [B, S, V] (the fwd graphs are the throughput
    /// path and emit nothing else — stats live in the stats graph).
    pub fn fwd(&self, scheme: &Scheme, tokens: &[i32]) -> crate::Result<Tensor> {
        let m = &self.manifest;
        let b = m.eval_batch;
        anyhow::ensure!(tokens.len() == b * m.seq_len, "bad token batch size");
        let (pkv, plen) = self.prefix_args();
        let name = format!("fwd_{}", scheme.gran.graph_suffix());
        let mut out = self.run(
            &name,
            &[
                HostValue::F32(pkv),
                HostValue::scalar_i32(plen),
                HostValue::I32(IntTensor::new(vec![b, m.seq_len], tokens.to_vec())),
                HostValue::F32(self.ranges.clone()),
                HostValue::scalar_f32(scheme.act_levels()),
                HostValue::F32(self.inv_smooth.clone()),
            ],
        )?;
        anyhow::ensure!(out.len() == 1, "fwd: expected 1 output");
        Ok(out.pop().unwrap())
    }

    /// Analysis forward (stats graph) over one token batch.
    pub fn stats(&self, tokens: &[i32]) -> crate::Result<StatsOut> {
        let m = &self.manifest;
        let b = m.eval_batch;
        let (pkv, plen) = self.prefix_args();
        let out = self.run(
            "stats",
            &[
                HostValue::F32(pkv),
                HostValue::scalar_i32(plen),
                HostValue::I32(IntTensor::new(vec![b, m.seq_len], tokens.to_vec())),
            ],
        )?;
        anyhow::ensure!(out.len() == 6, "stats: expected 6 outputs");
        let mut it = out.into_iter();
        Ok(StatsOut {
            minmax: it.next().unwrap(),
            chan_d: it.next().unwrap(),
            chan_f: it.next().unwrap(),
            acts_grid: it.next().unwrap(),
            act_stats: it.next().unwrap(),
            probs: it.next().unwrap(),
        })
    }

    /// Greedy-search scorer: L_q for each candidate continuation token.
    pub fn score_candidates(
        &self,
        prefix: &[i32],
        cands: &[i32],
        text: &[i32],
        levels: f32,
    ) -> crate::Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(cands.len() == m.score_batch);
        anyhow::ensure!(text.len() == m.score_text_len);
        let mut padded = prefix.to_vec();
        padded.resize(m.m_max, crate::data::PAD);
        let out = self.run(
            "score_lq",
            &[
                HostValue::I32(IntTensor::vec(padded)),
                HostValue::scalar_i32(prefix.len() as i32),
                HostValue::I32(IntTensor::vec(cands.to_vec())),
                HostValue::I32(IntTensor::vec(text.to_vec())),
                HostValue::scalar_f32(levels),
                HostValue::F32(self.inv_smooth.clone()),
            ],
        )?;
        Ok(out.into_iter().next().unwrap().data)
    }
}
