//! The weight bundle: loads weights.bin, validates it against the
//! manifest's tensor spec, and serves as the substrate the quantization
//! transforms rewrite (SmoothQuant scaling, AWQ/weight qdq, QuaRot
//! rotation) before upload.

use std::collections::HashMap;
use std::path::Path;

use super::manifest::Manifest;
use crate::util::fsutil::{self, Cursor};
use crate::util::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Weights {
    /// In param_spec order (the graphs' leading-argument order).
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl Weights {
    pub fn load(path: &Path, manifest: &Manifest) -> crate::Result<Self> {
        let buf = fsutil::read(path)?;
        let mut c = Cursor::new(&buf);
        c.magic(b"CCW1")?;
        let n = c.u32()? as usize;
        anyhow::ensure!(
            n == manifest.params.len(),
            "weights.bin has {n} tensors, manifest expects {}",
            manifest.params.len()
        );
        let mut names = Vec::with_capacity(n);
        let mut tensors = Vec::with_capacity(n);
        let mut index = HashMap::new();
        for spec in &manifest.params {
            let name = c.string()?;
            anyhow::ensure!(
                name == spec.name,
                "weights.bin order mismatch: got {name}, expected {}",
                spec.name
            );
            let nd = c.u32()? as usize;
            let mut dims = Vec::with_capacity(nd);
            for _ in 0..nd {
                dims.push(c.u32()? as usize);
            }
            anyhow::ensure!(dims == spec.shape, "{name}: shape {dims:?} != {:?}",
                            spec.shape);
            let data = c.f32_vec(dims.iter().product())?;
            index.insert(name.clone(), tensors.len());
            names.push(name);
            tensors.push(Tensor::new(dims, data));
        }
        Ok(Self { names, tensors, index })
    }

    /// Assemble a bundle from in-memory tensors in manifest param order
    /// (the hermetic test path — no weights.bin on disk).
    pub fn from_tensors(manifest: &Manifest, tensors: Vec<Tensor>)
                        -> crate::Result<Self> {
        anyhow::ensure!(
            tensors.len() == manifest.params.len(),
            "got {} tensors, manifest expects {}",
            tensors.len(),
            manifest.params.len()
        );
        let mut names = Vec::with_capacity(tensors.len());
        let mut index = HashMap::new();
        for (spec, t) in manifest.params.iter().zip(&tensors) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "{}: shape {:?} != {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
            index.insert(spec.name.clone(), names.len());
            names.push(spec.name.clone());
        }
        Ok(Self { names, tensors, index })
    }

    pub fn load_variant(variant: &str, manifest: &Manifest) -> crate::Result<Self> {
        Self::load(
            &crate::util::fsutil::variant_dir(variant).join("weights.bin"),
            manifest,
        )
    }

    pub fn get(&self, name: &str) -> crate::Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow::anyhow!("weight '{name}' missing"))
    }

    pub fn get_mut(&mut self, name: &str) -> crate::Result<&mut Tensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight '{name}' missing"))?;
        Ok(&mut self.tensors[i])
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    pub fn layer_name(l: usize, base: &str) -> String {
        format!("layer{l}.{base}")
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(Tensor::len).sum()
    }

    /// This shard's slice of the bundle, in param order (the graphs'
    /// leading-argument order, same as `tensors`): attention
    /// projections sliced to the shard's query/KV head columns, MLP
    /// up/gate to its `d_ff` columns, everything else — embeddings,
    /// norm gains/biases, the output projections `wo`/`wd`, `lm_head` —
    /// replicated whole. Column slicing preserves each output column's
    /// f64 summation order in `forward::matmul`, which is what makes
    /// sharded fp outputs bit-identical to unsharded.
    ///
    /// Returned as raw tensors, not a `Weights`: slice shapes
    /// intentionally disagree with the manifest's (full) param spec.
    pub fn shard_slices(
        &self,
        manifest: &Manifest,
        plan: crate::runtime::collective::ShardPlan,
    ) -> crate::Result<Vec<Tensor>> {
        crate::runtime::collective::ShardPlan::validate(
            manifest.n_kv_heads,
            manifest.d_ff,
            plan.n_shards,
        )?;
        let dh = manifest.d_head;
        let (q0, q1) = plan.q_range(manifest.n_heads, manifest.n_kv_heads);
        let (k0, k1) = plan.kv_range(manifest.n_kv_heads);
        let (f0, f1) = plan.ff_range(manifest.d_ff);
        self.names
            .iter()
            .zip(&self.tensors)
            .map(|(name, t)| {
                Ok(if name.ends_with(".wq") {
                    slice_cols(t, q0 * dh, q1 * dh)?
                } else if name.ends_with(".wk") || name.ends_with(".wv") {
                    slice_cols(t, k0 * dh, k1 * dh)?
                } else if name.ends_with(".wg") || name.ends_with(".wu") {
                    slice_cols(t, f0, f1)?
                } else {
                    t.clone()
                })
            })
            .collect()
    }
}

/// Columns `[c0, c1)` of a `[rows, cols]` matrix.
fn slice_cols(t: &Tensor, c0: usize, c1: usize) -> crate::Result<Tensor> {
    let (rows, cols) = t.dims2();
    anyhow::ensure!(
        c0 < c1 && c1 <= cols,
        "column slice [{c0}, {c1}) out of range for {cols} columns"
    );
    let w = c1 - c0;
    let mut data = Vec::with_capacity(rows * w);
    for r in 0..rows {
        data.extend_from_slice(&t.data[r * cols + c0..r * cols + c1]);
    }
    Ok(Tensor::new(vec![rows, w], data))
}

/// The shard's slice of a cushion/prefix KV tensor
/// `[L, 2, Hkv, m_max, dh]`: rows of the shard's KV heads, all layers.
pub fn shard_prefix_kv(
    kv: &Tensor,
    plan: crate::runtime::collective::ShardPlan,
) -> crate::Result<Tensor> {
    anyhow::ensure!(kv.shape.len() == 5, "prefix KV must be rank 5, got {:?}", kv.shape);
    let (l2, hkv, m, dh) = (
        kv.shape[0] * kv.shape[1],
        kv.shape[2],
        kv.shape[3],
        kv.shape[4],
    );
    let (h0, h1) = plan.kv_range(hkv);
    let row = m * dh;
    let mut data = Vec::with_capacity(l2 * (h1 - h0) * row);
    for lw in 0..l2 {
        let base = lw * hkv * row;
        data.extend_from_slice(&kv.data[base + h0 * row..base + h1 * row]);
    }
    Ok(Tensor::new(
        vec![kv.shape[0], kv.shape[1], h1 - h0, m, dh],
        data,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::ParamSpec;

    fn mini_manifest() -> Manifest {
        let mut m = Manifest::parse(
            r#"{"variant":"t","vocab":8,"d_model":4,"n_layers":1,"n_heads":1,
             "n_kv_heads":1,"d_head":4,"d_ff":8,"norm":"rmsnorm_pre",
             "act":"swiglu","pos":"rope","window":0,"n_sites":4,
             "seq_len":8,"m_max":2,"cache_cap":10,"serve_batch":2,
             "eval_batch":2,"score_batch":4,"score_text_len":6,
             "tune_batch":2,"params":[],"graphs":[]}"#,
        )
        .unwrap();
        m.params = vec![
            ParamSpec { name: "a".into(), shape: vec![2, 2] },
            ParamSpec { name: "b".into(), shape: vec![3] },
        ];
        m
    }

    fn write_bundle(path: &std::path::Path) {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"CCW1");
        buf.extend_from_slice(&2u32.to_le_bytes());
        for (name, dims, data) in [
            ("a", vec![2u32, 2], vec![1f32, 2., 3., 4.]),
            ("b", vec![3u32], vec![5f32, 6., 7.]),
        ] {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in &dims {
                buf.extend_from_slice(&d.to_le_bytes());
            }
            for v in &data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(path, &buf).unwrap();
    }

    #[test]
    fn load_validates_and_indexes() {
        let dir = std::env::temp_dir().join("cc_weights_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("weights.bin");
        write_bundle(&path);
        let w = Weights::load(&path, &mini_manifest()).unwrap();
        assert_eq!(w.get("a").unwrap().at2(1, 0), 3.0);
        assert_eq!(w.get("b").unwrap().data, vec![5., 6., 7.]);
        assert_eq!(w.total_params(), 7);
        assert!(w.get("zzz").is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("cc_weights_test2");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("weights.bin");
        write_bundle(&path);
        let mut m = mini_manifest();
        m.params[1].shape = vec![4];
        assert!(Weights::load(&path, &m).is_err());
    }
}
