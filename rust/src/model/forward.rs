//! The reference forward pass: a pure-Rust implementation of the JAX
//! model/serving/quantlib semantics (python/compile/{model,serving,
//! quantlib}.py) on `util::tensor::Tensor`, powering the interpreter
//! backend (`runtime::interp`).
//!
//! Three implementations of these semantics exist and are pinned
//! together by golden fixtures (python/tests/fixtures/interp/*.json):
//! the JAX graphs (the oracle, lowered to the AOT artifacts), the numpy
//! reference (python/tests/ref_interp.py — this file is a
//! statement-for-statement transliteration of it), and this module
//! (checked by rust/tests/interp_parity.rs). Change semantics in all
//! three places or the parity suites will say so.
//!
//! Numerics: f32 storage with f64 accumulation in reductions (dot
//! products, sums, softmax denominators). The fixtures' committed
//! x64-margin check guarantees every golden sits far enough from
//! quantization rounding boundaries that this mix stays within the
//! 1e-4 parity budget. Rounding is round-half-to-even, matching
//! jnp.round.

use std::collections::HashMap;

use crate::model::manifest::Manifest;
use crate::runtime::trace;
use crate::util::tensor::Tensor;

pub const EPS: f32 = 1e-5;
pub const BIG: f32 = 3.4e38;
pub const NEG: f32 = -1e30;

// ---------------------------------------------------------------------------
// Model spec + parameter view
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormKind {
    RmsPre,
    LnPost,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActKind {
    Swiglu,
    Relu,
    Gelu,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PosKind {
    Rope,
    Learned,
    Alibi,
}

/// Activation-quantization granularity of a graph variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Fp,
    Pts,
    Ptd,
    Ptk,
}

impl Mode {
    pub fn parse(s: &str) -> crate::Result<Mode> {
        Ok(match s {
            "fp" => Mode::Fp,
            "pts" => Mode::Pts,
            "ptd" => Mode::Ptd,
            "ptk" => Mode::Ptk,
            other => anyhow::bail!("unknown quant mode '{other}'"),
        })
    }
}

/// Everything the interpreter needs to know about a variant's
/// architecture — derived from the manifest (rope_theta is a constant of
/// the model families, configs.py).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub n_sites: usize,
    pub m_max: usize,
    pub norm: NormKind,
    pub act: ActKind,
    pub pos: PosKind,
    pub window: Option<usize>,
    pub rope_theta: f32,
    /// Weight names in param_spec order (the graphs' leading-argument
    /// order).
    pub param_names: Vec<String>,
}

impl ModelSpec {
    pub fn from_manifest(m: &Manifest) -> crate::Result<Self> {
        let norm = match m.norm.as_str() {
            "rmsnorm_pre" => NormKind::RmsPre,
            "ln_post" => NormKind::LnPost,
            other => anyhow::bail!("unknown norm '{other}'"),
        };
        let act = match m.act.as_str() {
            "swiglu" => ActKind::Swiglu,
            "relu" => ActKind::Relu,
            "gelu" => ActKind::Gelu,
            other => anyhow::bail!("unknown act '{other}'"),
        };
        let pos = match m.pos.as_str() {
            "rope" => PosKind::Rope,
            "learned" => PosKind::Learned,
            "alibi" => PosKind::Alibi,
            other => anyhow::bail!("unknown pos '{other}'"),
        };
        anyhow::ensure!(m.n_heads % m.n_kv_heads == 0, "bad GQA grouping");
        Ok(ModelSpec {
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            n_kv_heads: m.n_kv_heads,
            d_head: m.d_head,
            d_ff: m.d_ff,
            n_sites: m.n_sites,
            m_max: m.m_max,
            norm,
            act,
            pos,
            window: (m.window > 0).then_some(m.window),
            rope_theta: 10000.0,
            param_names: m.params.iter().map(|p| p.name.clone()).collect(),
        })
    }

    /// KV-head group size (GQA).
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
}

/// Borrowed view of the weight tensors, keyed by param_spec name.
pub struct Params<'a> {
    map: HashMap<&'a str, &'a Tensor>,
}

impl<'a> Params<'a> {
    pub fn new(spec: &'a ModelSpec, tensors: Vec<&'a Tensor>) -> crate::Result<Self> {
        anyhow::ensure!(
            tensors.len() == spec.param_names.len(),
            "interp: got {} weights, spec has {}",
            tensors.len(),
            spec.param_names.len()
        );
        let map = spec
            .param_names
            .iter()
            .map(String::as_str)
            .zip(tensors)
            .collect();
        Ok(Self { map })
    }

    pub fn get(&self, name: &str) -> crate::Result<&'a Tensor> {
        self.map
            .get(name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("interp: weight '{name}' missing"))
    }

    pub fn layer(&self, l: usize, base: &str) -> crate::Result<&'a Tensor> {
        self.get(&format!("layer{l}.{base}"))
    }
}

// ---------------------------------------------------------------------------
// Dense primitives (f64 accumulation)
// ---------------------------------------------------------------------------

/// [rows, k] @ [k, n] with f64 accumulation.
fn matmul(x: &[f32], rows: usize, k: usize, w: &Tensor) -> Vec<f32> {
    let (wk, n) = w.dims2();
    assert_eq!(k, wk, "matmul contraction mismatch");
    let mut out = vec![0.0f32; rows * n];
    for r in 0..rows {
        let xr = &x[r * k..(r + 1) * k];
        let mut acc = vec![0.0f64; n];
        for (p, &a) in xr.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let wrow = &w.data[p * n..(p + 1) * n];
            let a = a as f64;
            for (dst, &ww) in acc.iter_mut().zip(wrow) {
                *dst += a * ww as f64;
            }
        }
        for (o, a) in out[r * n..(r + 1) * n].iter_mut().zip(&acc) {
            *o = *a as f32;
        }
    }
    out
}

/// [rows, n] @ [k, n]^T -> [rows, k] with f64 accumulation (backward).
fn matmul_t(x: &[f32], rows: usize, n: usize, w: &Tensor) -> Vec<f32> {
    let (k, wn) = w.dims2();
    assert_eq!(n, wn, "matmul_t contraction mismatch");
    let mut out = vec![0.0f32; rows * k];
    for r in 0..rows {
        let xr = &x[r * n..(r + 1) * n];
        for p in 0..k {
            let wrow = &w.data[p * n..(p + 1) * n];
            let mut acc = 0.0f64;
            for (&a, &ww) in xr.iter().zip(wrow) {
                acc += a as f64 * ww as f64;
            }
            out[r * k + p] = acc as f32;
        }
    }
    out
}

fn rmsnorm(x: &[f32], rows: usize, d: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms: f64 = xr.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / d as f64;
        let rinv = 1.0 / (ms as f32 + EPS).sqrt();
        for i in 0..d {
            out[r * d + i] = xr[i] * rinv * g[i];
        }
    }
    out
}

fn rmsnorm_bwd(dy: &[f32], x: &[f32], rows: usize, d: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let ms: f64 = xr.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / d as f64;
        let rinv = 1.0 / ((ms as f32 + EPS) as f64).sqrt();
        let dot: f64 = (0..d)
            .map(|i| dyr[i] as f64 * g[i] as f64 * xr[i] as f64)
            .sum();
        let r3 = rinv * rinv * rinv / d as f64;
        for i in 0..d {
            out[r * d + i] =
                (dyr[i] as f64 * g[i] as f64 * rinv - xr[i] as f64 * r3 * dot) as f32;
        }
    }
    out
}

fn layernorm(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu: f64 = xr.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var: f64 = xr
            .iter()
            .map(|&v| (v as f64 - mu) * (v as f64 - mu))
            .sum::<f64>()
            / d as f64;
        let rinv = 1.0 / (var as f32 + EPS).sqrt() as f64;
        for i in 0..d {
            out[r * d + i] =
                (((xr[i] as f64 - mu) * rinv) as f32) * g[i] + b[i];
        }
    }
    out
}

fn layernorm_bwd(dy: &[f32], x: &[f32], rows: usize, d: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let mu: f64 = xr.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var: f64 = xr
            .iter()
            .map(|&v| (v as f64 - mu) * (v as f64 - mu))
            .sum::<f64>()
            / d as f64;
        let rinv = 1.0 / ((var as f32 + EPS) as f64).sqrt();
        let mut m_dxhat = 0.0f64;
        let mut m_dx_xhat = 0.0f64;
        for i in 0..d {
            let xhat = (xr[i] as f64 - mu) * rinv;
            let dxhat = dyr[i] as f64 * g[i] as f64;
            m_dxhat += dxhat;
            m_dx_xhat += dxhat * xhat;
        }
        m_dxhat /= d as f64;
        m_dx_xhat /= d as f64;
        for i in 0..d {
            let xhat = (xr[i] as f64 - mu) * rinv;
            let dxhat = dyr[i] as f64 * g[i] as f64;
            out[r * d + i] = (rinv * (dxhat - m_dxhat - xhat * m_dx_xhat)) as f32;
        }
    }
    out
}

/// jnp.round: round half to even.
fn round_half_even(x: f32) -> f32 {
    let f = x.floor();
    let diff = x - f;
    if diff > 0.5 {
        f + 1.0
    } else if diff < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Asymmetric quantize-dequantize with a given range (kernels/ref.py).
pub fn qdq_asym(x: f32, lo: f32, scale: f32, levels: f32) -> f32 {
    let q = round_half_even((x - lo) / scale).clamp(0.0, levels);
    lo + q * scale
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn silu_grad(x: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-x).exp());
    sig * (1.0 + x * (1.0 - sig))
}

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + 0.044715 * x * x * x)).tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let t = (GELU_C * (x + 0.044715 * x * x * x)).tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * 0.044715 * x * x)
}

fn act_apply(act: ActKind, x: f32) -> f32 {
    match act {
        ActKind::Relu => x.max(0.0),
        ActKind::Gelu => gelu(x),
        ActKind::Swiglu => silu(x),
    }
}

/// Reversed geometric ALiBi slopes (model.alibi_slopes): head 0 gets the
/// smallest slope.
pub fn alibi_slopes(n_heads: usize) -> Vec<f32> {
    (0..n_heads)
        .map(|h| {
            let i = (n_heads - 1 - h) as f64;
            (2.0f64).powf(-8.0 * (i + 1.0) / n_heads as f64) as f32
        })
        .collect()
}

/// RoPE rotation (model.rope); `inverse` applies the transpose (backward).
fn rope_rotate(x: &mut [f32], heads: usize, s: usize, dh: usize,
               positions: &[i32], theta: f32, inverse: bool) {
    let half = dh / 2;
    let freqs: Vec<f64> = (0..half)
        .map(|i| (theta as f64).powf(-(i as f64) / half as f64))
        .collect();
    for h in 0..heads {
        for si in 0..s {
            let base = (h * s + si) * dh;
            let pos = positions[si] as f64;
            for i in 0..half {
                let ang = pos * freqs[i];
                let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
                let x1 = x[base + i];
                let x2 = x[base + half + i];
                if inverse {
                    x[base + i] = x1 * cos + x2 * sin;
                    x[base + half + i] = -x1 * sin + x2 * cos;
                } else {
                    x[base + i] = x1 * cos - x2 * sin;
                    x[base + half + i] = x1 * sin + x2 * cos;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Attention (kernels/ref.py + model._attend_probs)
// ---------------------------------------------------------------------------

/// [Hq, Sq, Skv] visibility mask (kernels/ref.attention semantics).
fn attention_mask(spec: &ModelSpec, layer: usize, sq: usize, skv: usize,
                  prefix_len: i32, causal_offset: i32,
                  kv_valid: Option<&[bool]>) -> Vec<bool> {
    let hq = spec.n_heads;
    let m = spec.m_max as i32;
    let mut mask = vec![false; hq * sq * skv];
    for i in 0..sq {
        let qpos = causal_offset + i as i32;
        for j in 0..skv {
            let ji = j as i32;
            let kpos = ji - m;
            let in_prefix = ji < m;
            let prefix_ok = in_prefix && ji < prefix_len;
            let tok_ok = !in_prefix && kpos <= qpos;
            let tok_win = match spec.window {
                Some(w) => tok_ok && kpos >= qpos - w as i32 + 1,
                None => tok_ok,
            };
            let valid = kv_valid.map_or(true, |kv| kv[j]);
            for h in 0..hq {
                let mut ok = prefix_ok || tok_win;
                if spec.window.is_some() && h == 0 {
                    ok = prefix_ok || tok_ok; // head0_global
                }
                if layer == 0 && h == 0 && !in_prefix && kpos == qpos {
                    ok = false; // strict-causal detector head
                }
                mask[(h * sq + i) * skv + j] = ok && valid;
            }
        }
    }
    mask
}

/// -slope_h * distance ALiBi bias at (h, i, j), or 0 without ALiBi.
fn alibi_bias_at(spec: &ModelSpec, slopes: &[f32], h: usize, i: usize,
                 j: usize, prefix_len: i32, causal_offset: i32) -> f32 {
    let m = spec.m_max as i32;
    let ji = j as i32;
    let qpos = causal_offset + i as i32;
    let kabs = if ji < m { ji } else { ji - m + prefix_len };
    let dist = (qpos + prefix_len - kabs) as f32;
    -slopes[h] * dist
}

/// One batch element of sink attention. q: [Hq, Sq, dh]; k, v:
/// [Hkv, Skv, dh] with the first m_max key slots being the prefix
/// region. Returns out [Hq, Sq, dh] and, when `want_probs`, the
/// post-mask probabilities [Hq, Sq, Skv] (all-masked rows zeroed, as in
/// ref.attention).
fn attention(spec: &ModelSpec, layer: usize, q: &[f32], k: &[f32], v: &[f32],
             sq: usize, skv: usize, prefix_len: i32, causal_offset: i32,
             kv_valid: Option<&[bool]>, want_probs: bool)
             -> (Vec<f32>, Option<Vec<f32>>) {
    let (hq, dh, g) = (spec.n_heads, spec.d_head, spec.group());
    let inv_sqrt = 1.0 / (dh as f64).sqrt();
    let slopes = if spec.pos == PosKind::Alibi {
        alibi_slopes(hq)
    } else {
        Vec::new()
    };
    let mask = attention_mask(spec, layer, sq, skv, prefix_len,
                              causal_offset, kv_valid);
    let mut out = vec![0.0f32; hq * sq * dh];
    let mut probs_all = want_probs.then(|| vec![0.0f32; hq * sq * skv]);

    let mut row = vec![0.0f32; skv];
    let mut prow = vec![0.0f32; skv];
    for h in 0..hq {
        let kh = h / g;
        for i in 0..sq {
            let qrow = &q[(h * sq + i) * dh..(h * sq + i) * dh + dh];
            let mrow = &mask[(h * sq + i) * skv..(h * sq + i) * skv + skv];
            let mut any = false;
            for j in 0..skv {
                if !mrow[j] {
                    row[j] = NEG;
                    continue;
                }
                any = true;
                let krow = &k[(kh * skv + j) * dh..(kh * skv + j) * dh + dh];
                let mut acc = 0.0f64;
                for (&a, &b) in qrow.iter().zip(krow) {
                    acc += a as f64 * b as f64;
                }
                let mut l = (acc * inv_sqrt) as f32;
                if !slopes.is_empty() {
                    l += alibi_bias_at(spec, &slopes, h, i, j, prefix_len,
                                       causal_offset);
                }
                row[j] = l;
            }
            softmax_row(&row, &mut prow);
            if !any {
                prow.iter_mut().for_each(|p| *p = 0.0);
            }
            if let Some(pa) = probs_all.as_mut() {
                pa[(h * sq + i) * skv..(h * sq + i) * skv + skv]
                    .copy_from_slice(&prow);
            }
            let orow = &mut out[(h * sq + i) * dh..(h * sq + i) * dh + dh];
            for d in 0..dh {
                let mut acc = 0.0f64;
                for j in 0..skv {
                    if prow[j] != 0.0 {
                        acc += prow[j] as f64 * v[(kh * skv + j) * dh + d] as f64;
                    }
                }
                orow[d] = acc as f32;
            }
        }
    }
    (out, probs_all)
}

/// Numerically-stable row softmax (f64 accumulation).
fn softmax_row(row: &[f32], out: &mut [f32]) {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
    let mut sum = 0.0f64;
    for (o, &x) in out.iter_mut().zip(row) {
        let e = ((x - mx) as f64).exp();
        *o = e as f32;
        sum += e;
    }
    for o in out.iter_mut() {
        *o = (*o as f64 / sum) as f32;
    }
}

/// model._attend_probs of batch element 0: same mask (no kv_valid), but
/// — mirroring the JAX stats graph exactly — *without* the
/// all-masked-row zeroing (such rows softmax to uniform).
fn attend_probs(spec: &ModelSpec, layer: usize, q: &[f32], k: &[f32],
                sq: usize, skv: usize, prefix_len: i32,
                causal_offset: i32) -> Vec<f32> {
    let (hq, dh, g) = (spec.n_heads, spec.d_head, spec.group());
    let inv_sqrt = 1.0 / (dh as f64).sqrt();
    let slopes = if spec.pos == PosKind::Alibi {
        alibi_slopes(hq)
    } else {
        Vec::new()
    };
    let mask = attention_mask(spec, layer, sq, skv, prefix_len,
                              causal_offset, None);
    let mut probs = vec![0.0f32; hq * sq * skv];
    let mut row = vec![0.0f32; skv];
    let mut prow = vec![0.0f32; skv];
    for h in 0..hq {
        let kh = h / g;
        for i in 0..sq {
            let qrow = &q[(h * sq + i) * dh..(h * sq + i) * dh + dh];
            for j in 0..skv {
                let krow = &k[(kh * skv + j) * dh..(kh * skv + j) * dh + dh];
                let mut acc = 0.0f64;
                for (&a, &b) in qrow.iter().zip(krow) {
                    acc += a as f64 * b as f64;
                }
                let mut l = (acc * inv_sqrt) as f32;
                if !slopes.is_empty() {
                    l += alibi_bias_at(spec, &slopes, h, i, j, prefix_len,
                                       causal_offset);
                }
                if !mask[(h * sq + i) * skv + j] {
                    l = NEG;
                }
                row[j] = l;
            }
            softmax_row(&row, &mut prow);
            probs[(h * sq + i) * skv..(h * sq + i) * skv + skv]
                .copy_from_slice(&prow);
        }
    }
    probs
}

// ---------------------------------------------------------------------------
// Quantization context (quantlib.QuantCtx)
// ---------------------------------------------------------------------------

/// What the tune backward needs to replay one site: STE passes the
/// output gradient through, the L_q term adds 2 (x - xq) / denom (lo and
/// scale are stop-gradded; round/clip have zero gradient a.e.).
pub struct SiteRec {
    x: Vec<f32>,
    xq: Vec<f32>,
    denom: f64,
    layer: usize,
    site: usize,
}

/// Per-forward quantization state + statistics accumulator, mirroring
/// quantlib.QuantCtx field-for-field (ste is implicit: the tape records
/// what the backward needs and the forward always returns xq).
pub struct QuantCtx {
    pub mode: Mode,
    pub levels: f32,
    pub ranges: Option<Tensor>,
    /// [B*S] row-major validity mask (None = all valid).
    pub valid: Option<Vec<bool>>,
    pub per_example: bool,
    pub inv_smooth: Option<Tensor>,
    pub collect_stats: bool,
    pub collect_chan: bool,
    /// Scalar L_q accumulator ([B] when per_example).
    pub lq: f64,
    pub lq_per: Vec<f64>,
    pub minmax: Vec<(f32, f32)>,
    pub chan_absmax: Vec<Vec<f32>>,
    /// One entry per site() call when taping (tune_step backward).
    pub tape: Option<Vec<Option<SiteRec>>>,
}

impl QuantCtx {
    pub fn new(mode: Mode, levels: f32) -> Self {
        QuantCtx {
            mode,
            levels,
            ranges: None,
            valid: None,
            per_example: false,
            inv_smooth: None,
            collect_stats: true,
            collect_chan: false,
            lq: 0.0,
            lq_per: Vec::new(),
            minmax: Vec::new(),
            chan_absmax: Vec::new(),
            tape: None,
        }
    }

    pub fn serving(mode: Mode, levels: f32, ranges: &Tensor,
                   inv_smooth: &Tensor) -> Self {
        QuantCtx {
            ranges: Some(ranges.clone()),
            inv_smooth: Some(inv_smooth.clone()),
            collect_stats: false,
            ..QuantCtx::new(mode, levels)
        }
    }

    /// Quantize one site. x: [b, s, f] row-major. Returns the tensor the
    /// downstream matmul consumes.
    pub fn site(&mut self, mut x: Vec<f32>, b: usize, s: usize, f: usize,
                layer: usize, site: usize) -> Vec<f32> {
        if let Some(inv) = &self.inv_smooth {
            if site == 0 || site == 2 {
                let which = if site == 0 { 0 } else { 1 };
                let off = (layer * 2 + which) * f;
                let row = &inv.data[off..off + f];
                for r in 0..b * s {
                    for (xi, &iv) in x[r * f..(r + 1) * f].iter_mut().zip(row) {
                        *xi *= iv;
                    }
                }
            }
        }
        let valid_row = |row: usize| -> bool {
            self.valid.as_ref().map_or(true, |v| v[row])
        };

        // Activation-health sampling: when the scheduler armed a sample
        // for this decode step, meter the post-smoothing absmax of the
        // site and, under Pts, how many elements fall outside the
        // calibrated range. A missing or stale cushion surfaces here as
        // a clip-rate spike before it shows up in output quality.
        if trace::act_sampling() {
            let mut am = 0.0f32;
            let mut total = 0u64;
            for r in 0..b * s {
                if !valid_row(r) {
                    continue;
                }
                for &v in &x[r * f..(r + 1) * f] {
                    am = am.max(v.abs());
                }
                total += f as u64;
            }
            let clipped = if self.mode == Mode::Pts {
                let idx = layer * 4 + site;
                let ranges = self.ranges.as_ref().expect("pts needs ranges");
                let lo = ranges.data[idx * 2];
                let hi = lo + ranges.data[idx * 2 + 1] * self.levels;
                let mut c = 0u64;
                for r in 0..b * s {
                    if !valid_row(r) {
                        continue;
                    }
                    for &v in &x[r * f..(r + 1) * f] {
                        if v < lo || v > hi {
                            c += 1;
                        }
                    }
                }
                c
            } else {
                0
            };
            trace::act_note(am, clipped, total);
        }

        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        if self.collect_stats || self.mode == Mode::Ptd {
            for r in 0..b * s {
                if !valid_row(r) {
                    continue;
                }
                for &v in &x[r * f..(r + 1) * f] {
                    mn = mn.min(v);
                    mx = mx.max(v);
                }
            }
            mn = mn.min(0.0);
            mx = mx.max(0.0);
        }
        if self.collect_stats {
            self.minmax.push((mn, mx));
        }
        if self.collect_chan {
            let mut ch = vec![0.0f32; f];
            for r in 0..b * s {
                if !valid_row(r) {
                    continue;
                }
                for (c, &v) in ch.iter_mut().zip(&x[r * f..(r + 1) * f]) {
                    *c = c.max(v.abs());
                }
            }
            self.chan_absmax.push(ch);
        }
        if self.mode == Mode::Fp {
            if let Some(t) = self.tape.as_mut() {
                t.push(None);
            }
            return x;
        }

        // (lo, scale) per the granularity; quantize
        let mut xq = vec![0.0f32; x.len()];
        match self.mode {
            Mode::Pts => {
                let idx = layer * 4 + site;
                let ranges = self.ranges.as_ref().expect("pts needs ranges");
                let lo = ranges.data[idx * 2];
                let scale = ranges.data[idx * 2 + 1];
                for (o, &v) in xq.iter_mut().zip(&x) {
                    *o = qdq_asym(v, lo, scale, self.levels);
                }
            }
            Mode::Ptd if self.per_example => {
                for bi in 0..b {
                    let mut emn = f32::INFINITY;
                    let mut emx = f32::NEG_INFINITY;
                    for si in 0..s {
                        let r = bi * s + si;
                        if !valid_row(r) {
                            continue;
                        }
                        for &v in &x[r * f..(r + 1) * f] {
                            emn = emn.min(v);
                            emx = emx.max(v);
                        }
                    }
                    emn = emn.min(0.0);
                    emx = emx.max(0.0);
                    let scale = (emx - emn).max(1e-8) / self.levels;
                    for r in bi * s..(bi + 1) * s {
                        for i in r * f..(r + 1) * f {
                            xq[i] = qdq_asym(x[i], emn, scale, self.levels);
                        }
                    }
                }
            }
            Mode::Ptd => {
                let scale = (mx - mn).max(1e-8) / self.levels;
                for (o, &v) in xq.iter_mut().zip(&x) {
                    *o = qdq_asym(v, mn, scale, self.levels);
                }
            }
            Mode::Ptk => {
                for r in 0..b * s {
                    let row_valid = valid_row(r);
                    let mut rmn = f32::INFINITY;
                    let mut rmx = f32::NEG_INFINITY;
                    if row_valid {
                        for &v in &x[r * f..(r + 1) * f] {
                            rmn = rmn.min(v);
                            rmx = rmx.max(v);
                        }
                    }
                    let rmn = rmn.min(0.0);
                    let rmx = rmx.max(0.0);
                    let scale = (rmx - rmn).max(1e-8) / self.levels;
                    for i in r * f..(r + 1) * f {
                        xq[i] = qdq_asym(x[i], rmn, scale, self.levels);
                    }
                }
            }
            Mode::Fp => unreachable!(),
        }

        let mut denom_scalar = 1.0f64;
        if self.collect_stats {
            if self.per_example {
                if self.lq_per.is_empty() {
                    self.lq_per = vec![0.0; b];
                }
                for bi in 0..b {
                    let mut err = 0.0f64;
                    let mut cnt = 0.0f64;
                    for si in 0..s {
                        let r = bi * s + si;
                        if !valid_row(r) {
                            continue;
                        }
                        cnt += 1.0;
                        for i in r * f..(r + 1) * f {
                            let d = (x[i] - xq[i]) as f64;
                            err += d * d;
                        }
                    }
                    let denom = (cnt * f as f64).max(1.0);
                    self.lq_per[bi] += err / denom;
                }
            } else {
                let mut err = 0.0f64;
                let mut cnt = 0.0f64;
                for r in 0..b * s {
                    if !valid_row(r) {
                        continue;
                    }
                    cnt += 1.0;
                    for i in r * f..(r + 1) * f {
                        let d = (x[i] - xq[i]) as f64;
                        err += d * d;
                    }
                }
                denom_scalar = (cnt * f as f64).max(1.0);
                self.lq += err / denom_scalar;
            }
        }
        if let Some(t) = self.tape.as_mut() {
            t.push(Some(SiteRec {
                x: std::mem::take(&mut x),
                xq: xq.clone(),
                denom: denom_scalar,
                layer,
                site,
            }));
        }
        xq
    }
}

// ---------------------------------------------------------------------------
// Full forward (model.fwd)
// ---------------------------------------------------------------------------

struct LayerP<'a> {
    ln1_g: &'a Tensor,
    ln1_b: Option<&'a Tensor>,
    wq: &'a Tensor,
    wk: &'a Tensor,
    wv: &'a Tensor,
    wo: &'a Tensor,
    ln2_g: &'a Tensor,
    ln2_b: Option<&'a Tensor>,
    wg: Option<&'a Tensor>,
    wu: &'a Tensor,
    wd: &'a Tensor,
}

fn layer_p<'a>(spec: &ModelSpec, params: &Params<'a>, l: usize)
               -> crate::Result<LayerP<'a>> {
    let ln = spec.norm == NormKind::LnPost;
    Ok(LayerP {
        ln1_g: params.layer(l, "ln1_g")?,
        ln1_b: if ln { Some(params.layer(l, "ln1_b")?) } else { None },
        wq: params.layer(l, "wq")?,
        wk: params.layer(l, "wk")?,
        wv: params.layer(l, "wv")?,
        wo: params.layer(l, "wo")?,
        ln2_g: params.layer(l, "ln2_g")?,
        ln2_b: if ln { Some(params.layer(l, "ln2_b")?) } else { None },
        wg: if spec.act == ActKind::Swiglu {
            Some(params.layer(l, "wg")?)
        } else {
            None
        },
        wu: params.layer(l, "wu")?,
        wd: params.layer(l, "wd")?,
    })
}

/// [b*s, H*dh] row-major -> [b, H, s, dh].
fn to_heads(y: &[f32], b: usize, s: usize, heads: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * heads * s * dh];
    for bi in 0..b {
        for si in 0..s {
            for h in 0..heads {
                let src = (bi * s + si) * heads * dh + h * dh;
                let dst = ((bi * heads + h) * s + si) * dh;
                out[dst..dst + dh].copy_from_slice(&y[src..src + dh]);
            }
        }
    }
    out
}

/// [b, H, s, dh] -> [b*s, H*dh] row-major.
fn from_heads(q: &[f32], b: usize, s: usize, heads: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * s * heads * dh];
    for bi in 0..b {
        for h in 0..heads {
            for si in 0..s {
                let src = ((bi * heads + h) * s + si) * dh;
                let dst = (bi * s + si) * heads * dh + h * dh;
                out[dst..dst + dh].copy_from_slice(&q[src..src + dh]);
            }
        }
    }
    out
}

/// Build the concatenated [Hkv, m+s, dh] key-or-value rows of one batch
/// element: prefix slots from the cushion KV, token slots from k/v.
fn concat_prefix(spec: &ModelSpec, prefix_kv: &Tensor, l: usize, which: usize,
                 tok: &[f32], bi: usize, s: usize) -> Vec<f32> {
    let (hkv, m, dh) = (spec.n_kv_heads, spec.m_max, spec.d_head);
    let mut out = vec![0.0f32; hkv * (m + s) * dh];
    let pbase = ((l * 2 + which) * hkv) * m * dh;
    for kh in 0..hkv {
        let dst = kh * (m + s) * dh;
        let src = pbase + kh * m * dh;
        out[dst..dst + m * dh].copy_from_slice(&prefix_kv.data[src..src + m * dh]);
        let tsrc = ((bi * hkv + kh) * s) * dh;
        out[dst + m * dh..dst + (m + s) * dh]
            .copy_from_slice(&tok[tsrc..tsrc + s * dh]);
    }
    out
}

/// Auxiliary outputs of a collect-enabled forward.
pub struct FwdAux {
    /// [L+1][b*s*d] block inputs (+ final residual).
    pub acts: Vec<Vec<f32>>,
    /// [L][Hq*S*(m+S)] attention probabilities of batch element 0.
    pub probs: Vec<Vec<f32>>,
    /// [L][2*b*Hkv*S*dh] per-layer roped token K/V.
    pub kv: Vec<Vec<f32>>,
}

/// model.fwd: tokens [b, s] -> logits [b, s, vocab] (+ aux collections).
#[allow(clippy::too_many_arguments)]
pub fn fwd(spec: &ModelSpec, params: &Params, qctx: &mut QuantCtx,
           tokens: &[i32], b: usize, s: usize, prefix_kv: &Tensor,
           prefix_len: i32, kv_valid: Option<&[bool]>,
           positions: Option<&[i32]>, causal_offset: i32,
           collect_acts: bool, collect_probs: bool, collect_kv: bool)
           -> crate::Result<(Tensor, FwdAux)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    anyhow::ensure!(tokens.len() == b * s, "fwd: bad token count");
    let embed = params.get("embed")?;
    anyhow::ensure!(embed.shape == vec![spec.vocab, d], "embed shape");

    let default_pos: Vec<i32>;
    let positions: &[i32] = match positions {
        Some(p) => p,
        None => {
            default_pos = (0..b * s)
                .map(|i| prefix_len + (i % s) as i32)
                .collect();
            &default_pos
        }
    };

    let mut x = vec![0.0f32; b * s * d];
    for (r, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(
            t >= 0 && (t as usize) < spec.vocab,
            "fwd: token {t} outside vocab"
        );
        x[r * d..(r + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        let cap = pos_emb.shape[0];
        for r in 0..b * s {
            let p = positions[r];
            anyhow::ensure!(
                p >= 0 && (p as usize) < cap,
                "fwd: position {p} outside pos_emb table"
            );
            for i in 0..d {
                x[r * d + i] += pos_emb.data[p as usize * d + i];
            }
        }
    }

    // in-band kv validity over the token region, shared across batch
    let kvv_full: Option<Vec<bool>> = kv_valid.map(|kv| {
        assert_eq!(kv.len(), s, "kv_valid must cover the token region");
        let mut full = Vec::with_capacity(m + s);
        for j in 0..m {
            full.push((j as i32) < prefix_len);
        }
        full.extend_from_slice(kv);
        full
    });

    let mut aux = FwdAux { acts: Vec::new(), probs: Vec::new(), kv: Vec::new() };
    for l in 0..spec.n_layers {
        if collect_acts {
            aux.acts.push(x.clone());
        }
        let p = layer_p(spec, params, l)?;

        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, b * s, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, b, s, d, l, 0);
        let mut q = to_heads(&matmul(&h, b * s, d, p.wq), b, s, hq, dh);
        let mut k = to_heads(&matmul(&h, b * s, d, p.wk), b, s, hkv, dh);
        let v = to_heads(&matmul(&h, b * s, d, p.wv), b, s, hkv, dh);
        if spec.pos == PosKind::Rope {
            for bi in 0..b {
                let pos = &positions[bi * s..(bi + 1) * s];
                rope_rotate(&mut q[bi * hq * s * dh..(bi + 1) * hq * s * dh],
                            hq, s, dh, pos, spec.rope_theta, false);
                rope_rotate(&mut k[bi * hkv * s * dh..(bi + 1) * hkv * s * dh],
                            hkv, s, dh, pos, spec.rope_theta, false);
            }
        }
        if collect_kv {
            let mut kv_rec = Vec::with_capacity(2 * b * hkv * s * dh);
            kv_rec.extend_from_slice(&k);
            kv_rec.extend_from_slice(&v);
            aux.kv.push(kv_rec);
        }

        let mut o = vec![0.0f32; b * hq * s * dh];
        let mut probs0: Option<Vec<f32>> = None;
        for bi in 0..b {
            let kf = concat_prefix(spec, prefix_kv, l, 0, &k, bi, s);
            let vf = concat_prefix(spec, prefix_kv, l, 1, &v, bi, s);
            let qb = &q[bi * hq * s * dh..(bi + 1) * hq * s * dh];
            let (ob, _) = attention(spec, l, qb, &kf, &vf, s, m + s,
                                    prefix_len, causal_offset,
                                    kvv_full.as_deref(), false);
            o[bi * hq * s * dh..(bi + 1) * hq * s * dh].copy_from_slice(&ob);
            if collect_probs && bi == 0 {
                probs0 = Some(attend_probs(spec, l, qb, &kf, s, m + s,
                                           prefix_len, causal_offset));
            }
        }
        if let Some(pr) = probs0 {
            aux.probs.push(pr);
        }

        let o = from_heads(&o, b, s, hq, dh);
        let o = qctx.site(o, b, s, hq * dh, l, 1);
        let attn_out = matmul(&o, b * s, hq * dh, p.wo);

        match spec.norm {
            NormKind::RmsPre => {
                for (xi, a) in x.iter_mut().zip(&attn_out) {
                    *xi += a;
                }
                let h2 = rmsnorm(&x, b * s, d, &p.ln2_g.data);
                let mlp_out = mlp_fwd(spec, qctx, &p, h2, b, s, l)?;
                for (xi, a) in x.iter_mut().zip(&mlp_out) {
                    *xi += a;
                }
            }
            NormKind::LnPost => {
                let mut pre1 = x;
                for (xi, a) in pre1.iter_mut().zip(&attn_out) {
                    *xi += a;
                }
                let x_mid = layernorm(&pre1, b * s, d, &p.ln1_g.data,
                                      &p.ln1_b.unwrap().data);
                let mlp_out = mlp_fwd(spec, qctx, &p, x_mid.clone(), b, s, l)?;
                let mut pre2 = x_mid;
                for (xi, a) in pre2.iter_mut().zip(&mlp_out) {
                    *xi += a;
                }
                x = layernorm(&pre2, b * s, d, &p.ln2_g.data,
                              &p.ln2_b.unwrap().data);
            }
        }
    }
    if collect_acts {
        aux.acts.push(x.clone());
    }

    let h = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, b * s, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, b * s, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&h, b * s, d, params.get("lm_head")?);
    Ok((Tensor::new(vec![b, s, spec.vocab], logits), aux))
}

/// model.mlp: site 2 (mlp_in) -> activation -> site 3 (mlp_hidden) -> wd.
fn mlp_fwd(spec: &ModelSpec, qctx: &mut QuantCtx, p: &LayerP, h: Vec<f32>,
           b: usize, s: usize, l: usize) -> crate::Result<Vec<f32>> {
    let d = spec.d_model;
    let h = qctx.site(h, b, s, d, l, 2);
    let hidden = match spec.act {
        ActKind::Swiglu => {
            let ga = matmul(&h, b * s, d, p.wg.unwrap());
            let ub = matmul(&h, b * s, d, p.wu);
            ga.iter().zip(&ub).map(|(&a, &u)| silu(a) * u).collect()
        }
        _ => {
            let a = matmul(&h, b * s, d, p.wu);
            a.iter().map(|&v| act_apply(spec.act, v)).collect::<Vec<f32>>()
        }
    };
    let hidden = qctx.site(hidden, b, s, spec.d_ff, l, 3);
    Ok(matmul(&hidden, b * s, spec.d_ff, p.wd))
}

// ---------------------------------------------------------------------------
// Graph entry points: eval/analysis (graphs.py make_fwd / make_stats /
// make_score / make_prefix_kv)
// ---------------------------------------------------------------------------

/// fwd_{mode}: logits [b, s, vocab].
#[allow(clippy::too_many_arguments)]
pub fn run_fwd(spec: &ModelSpec, params: &Params, mode: Mode,
               prefix_kv: &Tensor, prefix_len: i32, tokens: &[i32],
               b: usize, s: usize, ranges: &Tensor, levels: f32,
               inv_smooth: &Tensor) -> crate::Result<Tensor> {
    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);
    let (logits, _) = fwd(spec, params, &mut qctx, tokens, b, s, prefix_kv,
                          prefix_len, None, None, 0, false, false, false)?;
    Ok(logits)
}

/// jnp.percentile with the default linear interpolation.
fn percentile(sorted: &[f32], q: f64) -> f32 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = (lo + 1).min(n - 1);
    let frac = pos - lo as f64;
    (sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac) as f32
}

/// stats: (minmax [n_sites,2], chan_d [3L,d], chan_f [L,d_ff],
/// acts_grid [L+1,b,s], act_stats [L+1,3], probs [L,Hq,s,m+s]).
pub fn run_stats(spec: &ModelSpec, params: &Params, prefix_kv: &Tensor,
                 prefix_len: i32, tokens: &[i32], b: usize, s: usize)
                 -> crate::Result<Vec<Tensor>> {
    let mut qctx = QuantCtx::new(Mode::Fp, 255.0);
    qctx.collect_chan = true;
    let (_, aux) = fwd(spec, params, &mut qctx, tokens, b, s, prefix_kv,
                       prefix_len, None, None, 0, true, true, false)?;

    let lp1 = spec.n_layers + 1;
    let d = spec.d_model;
    let mut acts_grid = vec![0.0f32; lp1 * b * s];
    let mut act_stats = vec![0.0f32; lp1 * 3];
    for (li, act) in aux.acts.iter().enumerate() {
        let mut mags: Vec<f32> = act.iter().map(|v| v.abs()).collect();
        for r in 0..b * s {
            let row = &mags[r * d..(r + 1) * d];
            acts_grid[li * b * s + r] =
                row.iter().fold(0.0f32, |a, &v| a.max(v));
        }
        mags.sort_unstable_by(f32::total_cmp);
        act_stats[li * 3] = *mags.last().unwrap();
        act_stats[li * 3 + 1] = percentile(&mags, 90.0);
        act_stats[li * 3 + 2] = percentile(&mags, 50.0);
    }

    let n_sites = spec.n_sites;
    let mut minmax = vec![0.0f32; n_sites * 2];
    anyhow::ensure!(qctx.minmax.len() == n_sites, "stats: bad site count");
    for (i, &(mn, mx)) in qctx.minmax.iter().enumerate() {
        minmax[i * 2] = mn;
        minmax[i * 2 + 1] = mx;
    }
    let mut chan_d: Vec<f32> = Vec::with_capacity(3 * spec.n_layers * d);
    let mut chan_f: Vec<f32> = Vec::with_capacity(spec.n_layers * spec.d_ff);
    for (i, ch) in qctx.chan_absmax.iter().enumerate() {
        if i % 4 == 3 {
            chan_f.extend_from_slice(ch);
        } else {
            chan_d.extend_from_slice(ch);
        }
    }
    let mut probs = Vec::with_capacity(spec.n_layers * spec.n_heads * s
                                       * (spec.m_max + s));
    for pr in &aux.probs {
        probs.extend_from_slice(pr);
    }
    Ok(vec![
        Tensor::new(vec![n_sites, 2], minmax),
        Tensor::new(vec![3 * spec.n_layers, d], chan_d),
        Tensor::new(vec![spec.n_layers, spec.d_ff], chan_f),
        Tensor::new(vec![lp1, b, s], acts_grid),
        Tensor::new(vec![lp1, 3], act_stats),
        Tensor::new(vec![spec.n_layers, spec.n_heads, s, spec.m_max + s],
                    probs),
    ])
}

/// score_lq: L_q of the text under [prefix ++ candidate] per candidate —
/// per-example dynamic per-tensor ranges over the text region only.
pub fn run_score(spec: &ModelSpec, params: &Params, prefix_tokens: &[i32],
                 prefix_len: i32, cands: &[i32], text: &[i32], levels: f32,
                 inv_smooth: &Tensor) -> crate::Result<Tensor> {
    let m = spec.m_max;
    anyhow::ensure!(prefix_tokens.len() == m, "score: bad prefix pad");
    let bc = cands.len();
    let tl = text.len();
    let s_total = m + 1 + tl;
    let mut rows = Vec::with_capacity(bc * s_total);
    for &c in cands {
        rows.extend_from_slice(prefix_tokens);
        rows.push(c);
        rows.extend_from_slice(text);
    }
    let kv_valid: Vec<bool> = (0..s_total)
        .map(|i| (i as i32) < prefix_len || i >= m)
        .collect();
    let gap = m as i32 - prefix_len;
    let pos_row: Vec<i32> = (0..s_total as i32)
        .map(|i| if (i as usize) < m { i } else { i - gap })
        .collect();
    let mut positions = Vec::with_capacity(bc * s_total);
    for _ in 0..bc {
        positions.extend_from_slice(&pos_row);
    }
    let valid: Vec<bool> = (0..bc * s_total)
        .map(|i| i % s_total >= m + 1)
        .collect();

    let empty = Tensor::zeros(&[spec.n_layers, 2, spec.n_kv_heads, m,
                                spec.d_head]);
    let mut qctx = QuantCtx::new(Mode::Ptd, levels);
    qctx.per_example = true;
    qctx.valid = Some(valid);
    qctx.inv_smooth = Some(inv_smooth.clone());
    fwd(spec, params, &mut qctx, &rows, bc, s_total, &empty, 0,
        Some(&kv_valid), Some(&positions), 0, false, false, false)?;
    let lq: Vec<f32> = qctx.lq_per.iter().map(|&v| v as f32).collect();
    anyhow::ensure!(lq.len() == bc, "score: lq batch mismatch");
    Ok(Tensor::new(vec![bc], lq))
}

/// prefix_kv: CushionCache KV [L, 2, Hkv, m_max, dh] from padded prefix
/// token ids, roped at positions 0..len-1, padding slots zeroed.
pub fn run_prefix_kv(spec: &ModelSpec, params: &Params,
                     prefix_tokens: &[i32], prefix_len: i32)
                     -> crate::Result<Tensor> {
    let m = spec.m_max;
    anyhow::ensure!(prefix_tokens.len() == m, "prefix_kv: bad prefix pad");
    let (hkv, dh) = (spec.n_kv_heads, spec.d_head);
    let kv_valid: Vec<bool> = (0..m).map(|i| (i as i32) < prefix_len).collect();
    let positions: Vec<i32> = (0..m as i32).collect();
    let empty = Tensor::zeros(&[spec.n_layers, 2, hkv, m, dh]);
    let mut qctx = QuantCtx::new(Mode::Fp, 255.0);
    let (_, aux) = fwd(spec, params, &mut qctx, prefix_tokens, 1, m, &empty,
                       0, Some(&kv_valid), Some(&positions), 0, false,
                       false, true)?;
    // aux.kv[l] is [2, 1, Hkv, m, dh]; zero the padding slots
    let mut out = vec![0.0f32; spec.n_layers * 2 * hkv * m * dh];
    for (l, rec) in aux.kv.iter().enumerate() {
        for w in 0..2 {
            for kh in 0..hkv {
                for p in 0..m {
                    if !kv_valid[p] {
                        continue;
                    }
                    let src = ((w * hkv + kh) * m + p) * dh;
                    let dst = (((l * 2 + w) * hkv + kh) * m + p) * dh;
                    out[dst..dst + dh].copy_from_slice(&rec[src..src + dh]);
                }
            }
        }
    }
    Ok(Tensor::new(vec![spec.n_layers, 2, hkv, m, dh], out))
}

// ---------------------------------------------------------------------------
// Serving (serving.py): prefill / decode over the slot cache
// ---------------------------------------------------------------------------

/// serving.select_tokens (greedy): per-row argmax over the last axis
/// (ties resolve to the lowest index, like jnp.argmax) + the winning
/// logit.
pub fn select_tokens(logits: &[f32], rows: usize, v: usize)
                     -> (Vec<i32>, Vec<f32>) {
    let mut ids = Vec::with_capacity(rows);
    let mut tops = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &logits[r * v..(r + 1) * v];
        let mut best = (0usize, f32::NEG_INFINITY);
        for (i, &x) in row.iter().enumerate() {
            if x > best.1 {
                best = (i, x);
            }
        }
        ids.push(best.0 as i32);
        tops.push(best.1);
    }
    (ids, tops)
}

/// quantlib.kivi_qdq_kv: keys asym per-channel-group along d_head
/// (group 32 when divisible, else d_head — the rule the fixture dumper
/// patches in for mini head dims), values asym per-token. In place over
/// [heads, s, dh] rows.
fn kivi_qdq(k: &mut [f32], v: &mut [f32], heads: usize, s: usize, dh: usize,
            levels: f32) {
    let group = if dh % 32 == 0 { 32 } else { dh };
    for h in 0..heads {
        for si in 0..s {
            let base = (h * s + si) * dh;
            for g0 in (0..dh).step_by(group) {
                qdq_dynamic_span(&mut k[base + g0..base + g0 + group], levels);
            }
            qdq_dynamic_span(&mut v[base..base + dh], levels);
        }
    }
}

/// ref.qdq_dynamic over one contiguous span (axis = the span).
fn qdq_dynamic_span(span: &mut [f32], levels: f32) {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &x in span.iter() {
        mn = mn.min(x);
        mx = mx.max(x);
    }
    let mn = mn.min(0.0);
    let mx = mx.max(0.0);
    let scale = (mx - mn).max(1e-8) / levels;
    for x in span.iter_mut() {
        *x = qdq_asym(*x, mn, scale, levels);
    }
}

/// serving._kv_maybe_quant: kv_levels >= 2^20 disables KV quantization.
fn kv_maybe_quant(k: &mut [f32], v: &mut [f32], heads: usize, s: usize,
                  dh: usize, kv_levels: f32) {
    if kv_levels < (1u32 << 20) as f32 {
        kivi_qdq(k, v, heads, s, dh, kv_levels);
    }
}

/// serving.prefill: one prompt into cache slot `slot`.
/// cache: [L, 2, B, Hkv, CAP, dh]. Returns (cache', last_logits [V]).
#[allow(clippy::too_many_arguments)]
pub fn run_prefill(spec: &ModelSpec, params: &Params, mode: Mode,
                   cache: &Tensor, prefix_kv: &Tensor, cushion_len: i32,
                   slot: usize, tokens: &[i32], tok_len: i32,
                   ranges: &Tensor, levels: f32, kv_levels: f32,
                   inv_smooth: &Tensor) -> crate::Result<(Tensor, Tensor)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    let s = tokens.len();
    anyhow::ensure!(cache.shape.len() == 6, "prefill: bad cache rank");
    let (bsz, cap) = (cache.shape[2], cache.shape[4]);
    anyhow::ensure!(slot < bsz, "prefill: slot out of range");
    anyhow::ensure!(m + s <= cap, "prefill: tokens exceed cache capacity");
    let mut cache = cache.clone();

    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);
    qctx.valid = Some((0..s).map(|i| (i as i32) < tok_len).collect());

    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; s * d];
    for (r, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "prefill: token {t} outside vocab");
        x[r * d..(r + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    let positions: Vec<i32> = (0..s as i32).map(|i| cushion_len + i).collect();
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for r in 0..s {
            let p = positions[r] as usize;
            anyhow::ensure!(p < pos_emb.shape[0], "prefill: position overflow");
            for i in 0..d {
                x[r * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, s, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, 1, s, d, l, 0);
        let mut q = to_heads(&matmul(&h, s, d, p.wq), 1, s, hq, dh);
        let mut k = to_heads(&matmul(&h, s, d, p.wk), 1, s, hkv, dh);
        let mut v = to_heads(&matmul(&h, s, d, p.wv), 1, s, hkv, dh);
        if spec.pos == PosKind::Rope {
            rope_rotate(&mut q, hq, s, dh, &positions, spec.rope_theta, false);
            rope_rotate(&mut k, hkv, s, dh, &positions, spec.rope_theta, false);
        }
        kv_maybe_quant(&mut k, &mut v, hkv, s, dh, kv_levels);
        // write this layer's token KV into the slot
        for (which, t) in [(0usize, &k), (1usize, &v)] {
            for kh in 0..hkv {
                for si in 0..s {
                    let src = (kh * s + si) * dh;
                    let dst = ((((l * 2 + which) * bsz + slot) * hkv + kh)
                        * cap + m + si) * dh;
                    cache.data[dst..dst + dh]
                        .copy_from_slice(&t[src..src + dh]);
                }
            }
        }
        let kf = concat_prefix(spec, prefix_kv, l, 0, &k, 0, s);
        let vf = concat_prefix(spec, prefix_kv, l, 1, &v, 0, s);
        let (o, _) = attention(spec, l, &q, &kf, &vf, s, m + s, cushion_len,
                               0, None, false);
        let o = from_heads(&o, 1, s, hq, dh);
        let o = qctx.site(o, 1, s, hq * dh, l, 1);
        let attn_out = matmul(&o, s, hq * dh, p.wo);
        x = block_tail(spec, &mut qctx, &p, x, &attn_out, 1, s, l)?;
    }

    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, s, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, s, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&hfin, s, d, params.get("lm_head")?);
    let last_row = (tok_len - 1).max(0) as usize;
    let v = spec.vocab;
    let last = logits[last_row * v..(last_row + 1) * v].to_vec();
    Ok((cache, Tensor::new(vec![v], last)))
}

/// serving.prefill_chunk: extend slot `slot`'s paged KV prefix — `done`
/// prompt tokens already written by earlier chunks — by the next
/// `tokens.len()` prompt tokens. Positions continue at
/// `cushion_len + done`, the new KV lands at cache offset `m + done`,
/// and attention runs over the slot's full cache row with
/// `causal_offset = done` (the decode pattern): keys past the causal
/// horizon are masked, their softmax mass underflows to exactly 0.0,
/// and the output accumulation skips zero-probability keys, so chunked
/// prefill is **bit-identical** to single-shot `run_prefill` in fp and
/// static (pts) modes. Dynamic per-tensor modes (ptd/ptk) compute
/// activation stats over the chunk shape instead of the full prompt and
/// may diverge within quantization tolerance — the same caveat as
/// preemption-resume re-prefill (coordinator::scheduler).
/// cache: [L, 2, B, Hkv, CAP, dh]. Returns (cache', last_logits [V]).
#[allow(clippy::too_many_arguments)]
pub fn run_prefill_chunk(spec: &ModelSpec, params: &Params, mode: Mode,
                         cache: &Tensor, _prefix_kv: &Tensor,
                         cushion_len: i32, slot: usize, tokens: &[i32],
                         done: i32, ranges: &Tensor, levels: f32,
                         kv_levels: f32, inv_smooth: &Tensor)
                         -> crate::Result<(Tensor, Tensor)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    let s = tokens.len();
    anyhow::ensure!(cache.shape.len() == 6, "prefill_chunk: bad cache rank");
    anyhow::ensure!(done >= 0, "prefill_chunk: negative done offset");
    let done_u = done as usize;
    let (bsz, cap) = (cache.shape[2], cache.shape[4]);
    anyhow::ensure!(slot < bsz, "prefill_chunk: slot out of range");
    anyhow::ensure!(s >= 1, "prefill_chunk: empty chunk");
    anyhow::ensure!(m + done_u + s <= cap,
                    "prefill_chunk: tokens exceed cache capacity");
    let mut cache = cache.clone();

    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);
    qctx.valid = Some(vec![true; s]); // chunks arrive unpadded

    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; s * d];
    for (r, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "prefill_chunk: token {t} outside vocab");
        x[r * d..(r + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    let positions: Vec<i32> =
        (0..s as i32).map(|i| cushion_len + done + i).collect();
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for r in 0..s {
            let p = positions[r] as usize;
            anyhow::ensure!(p < pos_emb.shape[0],
                            "prefill_chunk: position overflow");
            for i in 0..d {
                x[r * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, s, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, 1, s, d, l, 0);
        let mut q = to_heads(&matmul(&h, s, d, p.wq), 1, s, hq, dh);
        let mut k = to_heads(&matmul(&h, s, d, p.wk), 1, s, hkv, dh);
        let mut v = to_heads(&matmul(&h, s, d, p.wv), 1, s, hkv, dh);
        if spec.pos == PosKind::Rope {
            rope_rotate(&mut q, hq, s, dh, &positions, spec.rope_theta, false);
            rope_rotate(&mut k, hkv, s, dh, &positions, spec.rope_theta, false);
        }
        kv_maybe_quant(&mut k, &mut v, hkv, s, dh, kv_levels);
        // write this chunk's token KV at the slot's `done` offset
        for (which, t) in [(0usize, &k), (1usize, &v)] {
            for kh in 0..hkv {
                for si in 0..s {
                    let src = (kh * s + si) * dh;
                    let dst = ((((l * 2 + which) * bsz + slot) * hkv + kh)
                        * cap + m + done_u + si) * dh;
                    cache.data[dst..dst + dh]
                        .copy_from_slice(&t[src..src + dh]);
                }
            }
        }
        // attend over the slot's full cache row (cushion prefix at
        // [0, m), earlier chunks at [m, m+done), this chunk just
        // written) — exactly how run_decode reads the cache.
        let kbase = (((l * 2) * bsz + slot) * hkv) * cap * dh;
        let vbase = (((l * 2 + 1) * bsz + slot) * hkv) * cap * dh;
        let kf = cache.data[kbase..kbase + hkv * cap * dh].to_vec();
        let vf = cache.data[vbase..vbase + hkv * cap * dh].to_vec();
        let (o, _) = attention(spec, l, &q, &kf, &vf, s, cap, cushion_len,
                               done, None, false);
        let o = from_heads(&o, 1, s, hq, dh);
        let o = qctx.site(o, 1, s, hq * dh, l, 1);
        let attn_out = matmul(&o, s, hq * dh, p.wo);
        x = block_tail(spec, &mut qctx, &p, x, &attn_out, 1, s, l)?;
    }

    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, s, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, s, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&hfin, s, d, params.get("lm_head")?);
    let v = spec.vocab;
    let last = logits[(s - 1) * v..s * v].to_vec();
    Ok((cache, Tensor::new(vec![v], last)))
}

/// The shared residual/MLP tail of a serving block (serving._block_tail).
fn block_tail(spec: &ModelSpec, qctx: &mut QuantCtx, p: &LayerP,
              mut x: Vec<f32>, attn_out: &[f32], b: usize, s: usize,
              l: usize) -> crate::Result<Vec<f32>> {
    let d = spec.d_model;
    match spec.norm {
        NormKind::RmsPre => {
            for (xi, a) in x.iter_mut().zip(attn_out) {
                *xi += a;
            }
            let h2 = rmsnorm(&x, b * s, d, &p.ln2_g.data);
            let mlp_out = mlp_fwd(spec, qctx, p, h2, b, s, l)?;
            for (xi, a) in x.iter_mut().zip(&mlp_out) {
                *xi += a;
            }
            Ok(x)
        }
        NormKind::LnPost => {
            for (xi, a) in x.iter_mut().zip(attn_out) {
                *xi += a;
            }
            let x_mid = layernorm(&x, b * s, d, &p.ln1_g.data,
                                  &p.ln1_b.unwrap().data);
            let mlp_out = mlp_fwd(spec, qctx, p, x_mid.clone(), b, s, l)?;
            let mut pre2 = x_mid;
            for (xi, a) in pre2.iter_mut().zip(&mlp_out) {
                *xi += a;
            }
            Ok(layernorm(&pre2, b * s, d, &p.ln2_g.data,
                         &p.ln2_b.unwrap().data))
        }
    }
}

/// serving.decode: one decode step for all B slots.
/// Returns (cache', logits [B, V]).
#[allow(clippy::too_many_arguments)]
pub fn run_decode(spec: &ModelSpec, params: &Params, mode: Mode,
                  cache: &Tensor, cache_tok_len: &[i32], cushion_len: i32,
                  tokens: &[i32], ranges: &Tensor, levels: f32,
                  kv_levels: f32, inv_smooth: &Tensor)
                  -> crate::Result<(Tensor, Tensor)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    let b = tokens.len();
    anyhow::ensure!(cache.shape.len() == 6, "decode: bad cache rank");
    let (bsz, cap) = (cache.shape[2], cache.shape[4]);
    anyhow::ensure!(b == bsz, "decode: token batch != cache slots");
    anyhow::ensure!(cache_tok_len.len() == b, "decode: bad lens");
    let mut cache = cache.clone();

    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);

    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; b * d];
    for (bi, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "decode: token {t} outside vocab");
        x[bi * d..(bi + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    let positions: Vec<i32> = cache_tok_len
        .iter()
        .map(|&len| cushion_len + len)
        .collect();
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for bi in 0..b {
            let p = positions[bi] as usize;
            anyhow::ensure!(p < pos_emb.shape[0], "decode: position overflow");
            for i in 0..d {
                x[bi * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, b, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, b, 1, d, l, 0);
        let mut q = to_heads(&matmul(&h, b, d, p.wq), b, 1, hq, dh);
        let mut k = to_heads(&matmul(&h, b, d, p.wk), b, 1, hkv, dh);
        let mut v = to_heads(&matmul(&h, b, d, p.wv), b, 1, hkv, dh);
        if spec.pos == PosKind::Rope {
            for bi in 0..b {
                rope_rotate(&mut q[bi * hq * dh..(bi + 1) * hq * dh], hq, 1,
                            dh, &positions[bi..bi + 1], spec.rope_theta,
                            false);
                rope_rotate(&mut k[bi * hkv * dh..(bi + 1) * hkv * dh], hkv,
                            1, dh, &positions[bi..bi + 1], spec.rope_theta,
                            false);
            }
        }
        kv_maybe_quant(&mut k, &mut v, b * hkv, 1, dh, kv_levels);
        // scatter each slot's new KV at its own length offset
        for bi in 0..b {
            let off = m + cache_tok_len[bi] as usize;
            anyhow::ensure!(off < cap, "decode: slot {bi} cache overflow");
            for which in 0..2 {
                let t = if which == 0 { &k } else { &v };
                for kh in 0..hkv {
                    let src = (bi * hkv + kh) * dh;
                    let dst = ((((l * 2 + which) * bsz + bi) * hkv + kh)
                        * cap + off) * dh;
                    cache.data[dst..dst + dh]
                        .copy_from_slice(&t[src..src + dh]);
                }
            }
        }
        let mut o = vec![0.0f32; b * hq * dh];
        for bi in 0..b {
            let kbase = (((l * 2) * bsz + bi) * hkv) * cap * dh;
            let vbase = (((l * 2 + 1) * bsz + bi) * hkv) * cap * dh;
            let kf = &cache.data[kbase..kbase + hkv * cap * dh];
            let vf = &cache.data[vbase..vbase + hkv * cap * dh];
            let qb = &q[bi * hq * dh..(bi + 1) * hq * dh];
            let (ob, _) = attention(spec, l, qb, kf, vf, 1, cap, cushion_len,
                                    cache_tok_len[bi], None, false);
            o[bi * hq * dh..(bi + 1) * hq * dh].copy_from_slice(&ob);
        }
        let o = from_heads(&o, b, 1, hq, dh);
        let o = qctx.site(o, b, 1, hq * dh, l, 1);
        let attn_out = matmul(&o, b, hq * dh, p.wo);
        x = block_tail(spec, &mut qctx, &p, x, &attn_out, b, 1, l)?;
    }

    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, b, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, b, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&hfin, b, d, params.get("lm_head")?);
    Ok((cache, Tensor::new(vec![b, spec.vocab], logits)))
}

// ---------------------------------------------------------------------------
// Tensor-parallel serving (runtime::collective): shard-local variants of
// prefill and decode. Each shard owns whole GQA groups (its query heads
// plus their KV head) and a contiguous span of MLP columns; weights
// arrive pre-sliced (Weights::shard_slices) so the per-column f64
// accumulation order in `matmul` is untouched. Collective points per
// layer: one all-gather of the attention head partials (before quant
// site 1 and the replicated full `wo` matmul) and one all-gather of the
// MLP hidden partials (before site 3 and the replicated full `wd`).
// The residual stream, norms, quant sites, and lm_head are replicated —
// every shard computes identical full-width tensors after each gather,
// which is what makes sharded outputs bit-identical to unsharded in
// every mode (fp and quantized alike). No all-reduce on this path: a
// sum across shards would change f64 summation order.
// ---------------------------------------------------------------------------

use crate::runtime::collective::{CollectiveBus, ShardPlan};

/// `attention` over this shard's heads only. `q`: [hq_loc, Sq, dh];
/// `k`, `v`: [hkv_loc, Skv, dh]. Masks and ALiBi slopes are indexed by
/// the *global* head id (`head_offset + h`): the strict-causal detector
/// head, the head-0 global-window exception, and the per-head slopes
/// must land on the same physical heads as the unsharded pass.
#[allow(clippy::too_many_arguments)]
fn attention_sharded(spec: &ModelSpec, layer: usize, q: &[f32], k: &[f32],
                     v: &[f32], sq: usize, skv: usize, prefix_len: i32,
                     causal_offset: i32, hq_loc: usize, head_offset: usize)
                     -> Vec<f32> {
    let (dh, g) = (spec.d_head, spec.group());
    let inv_sqrt = 1.0 / (dh as f64).sqrt();
    let slopes = if spec.pos == PosKind::Alibi {
        alibi_slopes(spec.n_heads)
    } else {
        Vec::new()
    };
    let mask = attention_mask(spec, layer, sq, skv, prefix_len,
                              causal_offset, None);
    let mut out = vec![0.0f32; hq_loc * sq * dh];
    let mut row = vec![0.0f32; skv];
    let mut prow = vec![0.0f32; skv];
    for h in 0..hq_loc {
        let hg = head_offset + h;
        // Local KV head: exact because the shard's first query head is
        // group-aligned (q0 = kv0 * g, see ShardPlan::q_range).
        let kh = h / g;
        for i in 0..sq {
            let qrow = &q[(h * sq + i) * dh..(h * sq + i) * dh + dh];
            let mrow = &mask[(hg * sq + i) * skv..(hg * sq + i) * skv + skv];
            let mut any = false;
            for j in 0..skv {
                if !mrow[j] {
                    row[j] = NEG;
                    continue;
                }
                any = true;
                let krow = &k[(kh * skv + j) * dh..(kh * skv + j) * dh + dh];
                let mut acc = 0.0f64;
                for (&a, &b) in qrow.iter().zip(krow) {
                    acc += a as f64 * b as f64;
                }
                let mut lg = (acc * inv_sqrt) as f32;
                if !slopes.is_empty() {
                    lg += alibi_bias_at(spec, &slopes, hg, i, j, prefix_len,
                                        causal_offset);
                }
                row[j] = lg;
            }
            softmax_row(&row, &mut prow);
            if !any {
                prow.iter_mut().for_each(|p| *p = 0.0);
            }
            let orow = &mut out[(h * sq + i) * dh..(h * sq + i) * dh + dh];
            for d in 0..dh {
                let mut acc = 0.0f64;
                for j in 0..skv {
                    if prow[j] != 0.0 {
                        acc += prow[j] as f64 * v[(kh * skv + j) * dh + d] as f64;
                    }
                }
                orow[d] = acc as f32;
            }
        }
    }
    out
}

/// `concat_prefix` against a *sliced* prefix KV `[L, 2, hkv, m, dh]`
/// holding only this shard's KV heads.
fn concat_prefix_local(prefix_kv: &Tensor, m: usize, dh: usize, hkv: usize,
                       l: usize, which: usize, tok: &[f32], bi: usize,
                       s: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; hkv * (m + s) * dh];
    let pbase = ((l * 2 + which) * hkv) * m * dh;
    for kh in 0..hkv {
        let dst = kh * (m + s) * dh;
        let src = pbase + kh * m * dh;
        out[dst..dst + m * dh].copy_from_slice(&prefix_kv.data[src..src + m * dh]);
        let tsrc = ((bi * hkv + kh) * s) * dh;
        out[dst + m * dh..dst + (m + s) * dh]
            .copy_from_slice(&tok[tsrc..tsrc + s * dh]);
    }
    out
}

/// Stitch all-gathered row-major parts back into the unsharded layout:
/// part `k` is `[rows, w_k]`, output row `r` is the shard-order
/// concatenation of every part's row `r`. With `rows == 1` this is a
/// plain concatenation (head-major attention partials of one prompt);
/// with `rows == b` / `rows == b*s` it re-interleaves per-lane head
/// rows / per-token MLP columns.
fn stitch_gathered(parts: &[Vec<f32>], rows: usize) -> Vec<f32> {
    let total: usize = parts.iter().map(|p| p.len() / rows).sum();
    let mut out = vec![0.0f32; rows * total];
    for r in 0..rows {
        let mut off = 0;
        for p in parts {
            let w = p.len() / rows;
            out[r * total + off..r * total + off + w]
                .copy_from_slice(&p[r * w..(r + 1) * w]);
            off += w;
        }
    }
    out
}

/// `mlp_fwd` with column-sliced `wg`/`wu`: local columns + local
/// elementwise activation, all-gather the hidden partials, then site 3
/// and the replicated full `wd` on every shard.
#[allow(clippy::too_many_arguments)]
fn mlp_fwd_sharded(spec: &ModelSpec, qctx: &mut QuantCtx, p: &LayerP,
                   h: Vec<f32>, b: usize, s: usize, l: usize, shard: usize,
                   bus: &CollectiveBus) -> crate::Result<Vec<f32>> {
    let d = spec.d_model;
    let h = qctx.site(h, b, s, d, l, 2);
    let hidden_loc: Vec<f32> = match spec.act {
        ActKind::Swiglu => {
            let ga = matmul(&h, b * s, d, p.wg.unwrap());
            let ub = matmul(&h, b * s, d, p.wu);
            ga.iter().zip(&ub).map(|(&a, &u)| silu(a) * u).collect()
        }
        _ => {
            let a = matmul(&h, b * s, d, p.wu);
            a.iter().map(|&v| act_apply(spec.act, v)).collect()
        }
    };
    let parts = bus.all_gather(shard, hidden_loc)?;
    let hidden = stitch_gathered(&parts, b * s);
    let hidden = qctx.site(hidden, b, s, spec.d_ff, l, 3);
    Ok(matmul(&hidden, b * s, spec.d_ff, p.wd))
}

/// `block_tail` routed through the sharded MLP.
#[allow(clippy::too_many_arguments)]
fn block_tail_sharded(spec: &ModelSpec, qctx: &mut QuantCtx, p: &LayerP,
                      mut x: Vec<f32>, attn_out: &[f32], b: usize, s: usize,
                      l: usize, shard: usize, bus: &CollectiveBus)
                      -> crate::Result<Vec<f32>> {
    let d = spec.d_model;
    match spec.norm {
        NormKind::RmsPre => {
            for (xi, a) in x.iter_mut().zip(attn_out) {
                *xi += a;
            }
            let h2 = rmsnorm(&x, b * s, d, &p.ln2_g.data);
            let mlp_out = mlp_fwd_sharded(spec, qctx, p, h2, b, s, l, shard, bus)?;
            for (xi, a) in x.iter_mut().zip(&mlp_out) {
                *xi += a;
            }
            Ok(x)
        }
        NormKind::LnPost => {
            for (xi, a) in x.iter_mut().zip(attn_out) {
                *xi += a;
            }
            let x_mid = layernorm(&x, b * s, d, &p.ln1_g.data,
                                  &p.ln1_b.unwrap().data);
            let mlp_out =
                mlp_fwd_sharded(spec, qctx, p, x_mid.clone(), b, s, l, shard, bus)?;
            let mut pre2 = x_mid;
            for (xi, a) in pre2.iter_mut().zip(&mlp_out) {
                *xi += a;
            }
            Ok(layernorm(&pre2, b * s, d, &p.ln2_g.data,
                         &p.ln2_b.unwrap().data))
        }
    }
}

/// `run_prefill` on one shard. `params` holds this shard's sliced
/// bundle (Weights::shard_slices); `cache` is the per-shard slot cache
/// [L, 2, B, hkv_loc, CAP, dh]; `prefix_kv` the per-shard cushion slice
/// [L, 2, hkv_loc, m, dh]. Returns the updated local cache and the
/// last-token logits [V] — identical on every shard.
#[allow(clippy::too_many_arguments)]
pub fn run_prefill_sharded(spec: &ModelSpec, params: &Params, mode: Mode,
                           cache: &Tensor, prefix_kv: &Tensor,
                           cushion_len: i32, slot: usize, tokens: &[i32],
                           tok_len: i32, ranges: &Tensor, levels: f32,
                           kv_levels: f32, inv_smooth: &Tensor,
                           plan: ShardPlan, bus: &CollectiveBus)
                           -> crate::Result<(Tensor, Tensor)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    ShardPlan::validate(hkv, spec.d_ff, plan.n_shards)?;
    let (q0, q1) = plan.q_range(hq, hkv);
    let (k0, k1) = plan.kv_range(hkv);
    let (hq_loc, hkv_loc) = (q1 - q0, k1 - k0);
    let s = tokens.len();
    anyhow::ensure!(cache.shape.len() == 6, "prefill_shard: bad cache rank");
    anyhow::ensure!(cache.shape[3] == hkv_loc,
                    "prefill_shard: cache holds {} KV heads, shard owns {}",
                    cache.shape[3], hkv_loc);
    let (bsz, cap) = (cache.shape[2], cache.shape[4]);
    anyhow::ensure!(slot < bsz, "prefill_shard: slot out of range");
    anyhow::ensure!(m + s <= cap, "prefill_shard: tokens exceed capacity");
    anyhow::ensure!(prefix_kv.shape == vec![spec.n_layers, 2, hkv_loc, m, dh],
                    "prefill_shard: prefix slice shape {:?}", prefix_kv.shape);
    let mut cache = cache.clone();

    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);
    qctx.valid = Some((0..s).map(|i| (i as i32) < tok_len).collect());

    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; s * d];
    for (r, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "prefill_shard: token {t} outside vocab");
        x[r * d..(r + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    let positions: Vec<i32> = (0..s as i32).map(|i| cushion_len + i).collect();
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for r in 0..s {
            let p = positions[r] as usize;
            anyhow::ensure!(p < pos_emb.shape[0],
                            "prefill_shard: position overflow");
            for i in 0..d {
                x[r * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, s, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, 1, s, d, l, 0);
        // p.wq/wk/wv are column slices: local heads only
        let mut q = to_heads(&matmul(&h, s, d, p.wq), 1, s, hq_loc, dh);
        let mut k = to_heads(&matmul(&h, s, d, p.wk), 1, s, hkv_loc, dh);
        let mut v = to_heads(&matmul(&h, s, d, p.wv), 1, s, hkv_loc, dh);
        if spec.pos == PosKind::Rope {
            rope_rotate(&mut q, hq_loc, s, dh, &positions, spec.rope_theta,
                        false);
            rope_rotate(&mut k, hkv_loc, s, dh, &positions, spec.rope_theta,
                        false);
        }
        kv_maybe_quant(&mut k, &mut v, hkv_loc, s, dh, kv_levels);
        // write this layer's token KV into the shard-local slot
        for (which, t) in [(0usize, &k), (1usize, &v)] {
            for kh in 0..hkv_loc {
                for si in 0..s {
                    let src = (kh * s + si) * dh;
                    let dst = ((((l * 2 + which) * bsz + slot) * hkv_loc + kh)
                        * cap + m + si) * dh;
                    cache.data[dst..dst + dh]
                        .copy_from_slice(&t[src..src + dh]);
                }
            }
        }
        let kf = concat_prefix_local(prefix_kv, m, dh, hkv_loc, l, 0, &k, 0, s);
        let vf = concat_prefix_local(prefix_kv, m, dh, hkv_loc, l, 1, &v, 0, s);
        let o = attention_sharded(spec, l, &q, &kf, &vf, s, m + s,
                                  cushion_len, 0, hq_loc, q0);
        // collective point 1: gather head partials, then identical
        // full-width math (site 1, full wo) on every shard
        let parts = bus.all_gather(plan.shard, o)?;
        let o = from_heads(&stitch_gathered(&parts, 1), 1, s, hq, dh);
        let o = qctx.site(o, 1, s, hq * dh, l, 1);
        let attn_out = matmul(&o, s, hq * dh, p.wo);
        x = block_tail_sharded(spec, &mut qctx, &p, x, &attn_out, 1, s, l,
                               plan.shard, bus)?;
    }

    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, s, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, s, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&hfin, s, d, params.get("lm_head")?);
    let last_row = (tok_len - 1).max(0) as usize;
    let v = spec.vocab;
    let last = logits[last_row * v..(last_row + 1) * v].to_vec();
    Ok((cache, Tensor::new(vec![v], last)))
}

/// `run_decode` on one shard: one step for all B slots over the
/// per-shard cache [L, 2, B, hkv_loc, CAP, dh]. Returns the updated
/// local cache and logits [B, V] — identical on every shard.
#[allow(clippy::too_many_arguments)]
pub fn run_decode_sharded(spec: &ModelSpec, params: &Params, mode: Mode,
                          cache: &Tensor, cache_tok_len: &[i32],
                          cushion_len: i32, tokens: &[i32], ranges: &Tensor,
                          levels: f32, kv_levels: f32, inv_smooth: &Tensor,
                          plan: ShardPlan, bus: &CollectiveBus)
                          -> crate::Result<(Tensor, Tensor)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    ShardPlan::validate(hkv, spec.d_ff, plan.n_shards)?;
    let (q0, q1) = plan.q_range(hq, hkv);
    let (k0, k1) = plan.kv_range(hkv);
    let (hq_loc, hkv_loc) = (q1 - q0, k1 - k0);
    let b = tokens.len();
    anyhow::ensure!(cache.shape.len() == 6, "decode_shard: bad cache rank");
    anyhow::ensure!(cache.shape[3] == hkv_loc,
                    "decode_shard: cache holds {} KV heads, shard owns {}",
                    cache.shape[3], hkv_loc);
    let (bsz, cap) = (cache.shape[2], cache.shape[4]);
    anyhow::ensure!(b == bsz, "decode_shard: token batch != cache slots");
    anyhow::ensure!(cache_tok_len.len() == b, "decode_shard: bad lens");
    let mut cache = cache.clone();

    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);

    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; b * d];
    for (bi, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "decode_shard: token {t} outside vocab");
        x[bi * d..(bi + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    let positions: Vec<i32> = cache_tok_len
        .iter()
        .map(|&len| cushion_len + len)
        .collect();
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for bi in 0..b {
            let p = positions[bi] as usize;
            anyhow::ensure!(p < pos_emb.shape[0],
                            "decode_shard: position overflow");
            for i in 0..d {
                x[bi * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, b, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, b, 1, d, l, 0);
        let mut q = to_heads(&matmul(&h, b, d, p.wq), b, 1, hq_loc, dh);
        let mut k = to_heads(&matmul(&h, b, d, p.wk), b, 1, hkv_loc, dh);
        let mut v = to_heads(&matmul(&h, b, d, p.wv), b, 1, hkv_loc, dh);
        if spec.pos == PosKind::Rope {
            for bi in 0..b {
                rope_rotate(&mut q[bi * hq_loc * dh..(bi + 1) * hq_loc * dh],
                            hq_loc, 1, dh, &positions[bi..bi + 1],
                            spec.rope_theta, false);
                rope_rotate(&mut k[bi * hkv_loc * dh..(bi + 1) * hkv_loc * dh],
                            hkv_loc, 1, dh, &positions[bi..bi + 1],
                            spec.rope_theta, false);
            }
        }
        kv_maybe_quant(&mut k, &mut v, b * hkv_loc, 1, dh, kv_levels);
        // scatter each slot's new KV at its own length offset
        for bi in 0..b {
            let off = m + cache_tok_len[bi] as usize;
            anyhow::ensure!(off < cap, "decode_shard: slot {bi} overflow");
            for which in 0..2 {
                let t = if which == 0 { &k } else { &v };
                for kh in 0..hkv_loc {
                    let src = (bi * hkv_loc + kh) * dh;
                    let dst = ((((l * 2 + which) * bsz + bi) * hkv_loc + kh)
                        * cap + off) * dh;
                    cache.data[dst..dst + dh]
                        .copy_from_slice(&t[src..src + dh]);
                }
            }
        }
        let mut o = vec![0.0f32; b * hq_loc * dh];
        for bi in 0..b {
            let kbase = (((l * 2) * bsz + bi) * hkv_loc) * cap * dh;
            let vbase = (((l * 2 + 1) * bsz + bi) * hkv_loc) * cap * dh;
            let kf = &cache.data[kbase..kbase + hkv_loc * cap * dh];
            let vf = &cache.data[vbase..vbase + hkv_loc * cap * dh];
            let qb = &q[bi * hq_loc * dh..(bi + 1) * hq_loc * dh];
            let ob = attention_sharded(spec, l, qb, kf, vf, 1, cap,
                                       cushion_len, cache_tok_len[bi],
                                       hq_loc, q0);
            o[bi * hq_loc * dh..(bi + 1) * hq_loc * dh].copy_from_slice(&ob);
        }
        // collective point 1: per-lane head partials, re-interleaved to
        // the unsharded [b, hq, dh] layout
        let parts = bus.all_gather(plan.shard, o)?;
        let o = from_heads(&stitch_gathered(&parts, b), b, 1, hq, dh);
        let o = qctx.site(o, b, 1, hq * dh, l, 1);
        let attn_out = matmul(&o, b, hq * dh, p.wo);
        x = block_tail_sharded(spec, &mut qctx, &p, x, &attn_out, b, 1, l,
                               plan.shard, bus)?;
    }

    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, b, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, b, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&hfin, b, d, params.get("lm_head")?);
    Ok((cache, Tensor::new(vec![b, spec.vocab], logits)))
}

// ---------------------------------------------------------------------------
// Paged serving (coordinator::kvpool): block-table variants of prefill and
// decode. KV lives in a pool tensor [n_blocks, L, 2, Hkv, BS, dh]; a
// sequence's block table maps logical position p to pool row
// (table[p / BS], p % BS). Positions [0, m_max) are the cushion region
// (stored once in shared blocks), [m_max, ..) the request tokens. The
// math is identical to run_prefill / run_decode — same embedding, RoPE,
// quant sites, and the very same `attention` over a table-gathered
// [Hkv, m + len + 1, dh] key/value window (positions past the window are
// fully masked in the contiguous path, so the reduced window is
// bit-identical; see the masking proof in `attention_mask`).
// ---------------------------------------------------------------------------

/// Pool geometry parsed (and validated) from the pool tensor shape.
struct PoolView {
    n_blocks: usize,
    bs: usize,
    block_elems: usize,
}

fn pool_view(spec: &ModelSpec, pool: &Tensor, what: &str) -> crate::Result<PoolView> {
    anyhow::ensure!(
        pool.shape.len() == 6
            && pool.shape[1] == spec.n_layers
            && pool.shape[2] == 2
            && pool.shape[3] == spec.n_kv_heads
            && pool.shape[5] == spec.d_head,
        "{what}: pool shape {:?} does not match [n, L, 2, Hkv, BS, dh]",
        pool.shape
    );
    let bs = pool.shape[4];
    anyhow::ensure!(bs > 0, "{what}: zero block size");
    Ok(PoolView {
        n_blocks: pool.shape[0],
        bs,
        block_elems: spec.n_layers * 2 * spec.n_kv_heads * bs * spec.d_head,
    })
}

impl PoolView {
    /// Flat offset of the dh-row at (block id, layer, k|v, head,
    /// in-block position).
    fn row(&self, spec: &ModelSpec, id: usize, l: usize, w: usize, h: usize,
           q: usize) -> usize {
        id * self.block_elems
            + (((l * 2 + w) * spec.n_kv_heads + h) * self.bs + q) * spec.d_head
    }

    /// Resolve logical position `p` through a block table.
    fn locate(&self, table: &[i32], p: usize, what: &str)
              -> crate::Result<(usize, usize)> {
        let bi = p / self.bs;
        let id = *table.get(bi).ok_or_else(|| {
            anyhow::anyhow!("{what}: position {p} beyond the block table")
        })?;
        anyhow::ensure!(
            id >= 0 && (id as usize) < self.n_blocks,
            "{what}: position {p} maps to invalid block {id}"
        );
        Ok((id as usize, p % self.bs))
    }
}

/// serving.prefill over the block pool: one prompt written through its
/// block table. Returns (pool', last_logits [V]).
#[allow(clippy::too_many_arguments)]
pub fn run_prefill_paged(spec: &ModelSpec, params: &Params, mode: Mode,
                         pool: &Tensor, table: &[i32], prefix_kv: &Tensor,
                         cushion_len: i32, tokens: &[i32], tok_len: i32,
                         ranges: &Tensor, levels: f32, kv_levels: f32,
                         inv_smooth: &Tensor)
                         -> crate::Result<(Tensor, Tensor)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    let s = tokens.len();
    let pv = pool_view(spec, pool, "prefill_paged")?;
    anyhow::ensure!(
        table.len() * pv.bs >= m + s,
        "prefill_paged: table covers {} positions, prompt needs {}",
        table.len() * pv.bs,
        m + s
    );
    let mut pool = pool.clone();

    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);
    qctx.valid = Some((0..s).map(|i| (i as i32) < tok_len).collect());

    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; s * d];
    for (r, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "prefill_paged: token {t} outside vocab");
        x[r * d..(r + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    let positions: Vec<i32> = (0..s as i32).map(|i| cushion_len + i).collect();
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for r in 0..s {
            let p = positions[r] as usize;
            anyhow::ensure!(p < pos_emb.shape[0],
                            "prefill_paged: position overflow");
            for i in 0..d {
                x[r * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, s, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, 1, s, d, l, 0);
        let mut q = to_heads(&matmul(&h, s, d, p.wq), 1, s, hq, dh);
        let mut k = to_heads(&matmul(&h, s, d, p.wk), 1, s, hkv, dh);
        let mut v = to_heads(&matmul(&h, s, d, p.wv), 1, s, hkv, dh);
        if spec.pos == PosKind::Rope {
            rope_rotate(&mut q, hq, s, dh, &positions, spec.rope_theta, false);
            rope_rotate(&mut k, hkv, s, dh, &positions, spec.rope_theta, false);
        }
        kv_maybe_quant(&mut k, &mut v, hkv, s, dh, kv_levels);
        // write this layer's token KV through the block table
        for (which, t) in [(0usize, &k), (1usize, &v)] {
            for kh in 0..hkv {
                for si in 0..s {
                    let src = (kh * s + si) * dh;
                    let (id, q_in) =
                        pv.locate(table, m + si, "prefill_paged")?;
                    let dst = pv.row(spec, id, l, which, kh, q_in);
                    pool.data[dst..dst + dh]
                        .copy_from_slice(&t[src..src + dh]);
                }
            }
        }
        let kf = concat_prefix(spec, prefix_kv, l, 0, &k, 0, s);
        let vf = concat_prefix(spec, prefix_kv, l, 1, &v, 0, s);
        let (o, _) = attention(spec, l, &q, &kf, &vf, s, m + s, cushion_len,
                               0, None, false);
        let o = from_heads(&o, 1, s, hq, dh);
        let o = qctx.site(o, 1, s, hq * dh, l, 1);
        let attn_out = matmul(&o, s, hq * dh, p.wo);
        x = block_tail(spec, &mut qctx, &p, x, &attn_out, 1, s, l)?;
    }

    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, s, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, s, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&hfin, s, d, params.get("lm_head")?);
    let last_row = (tok_len - 1).max(0) as usize;
    let v = spec.vocab;
    let last = logits[last_row * v..(last_row + 1) * v].to_vec();
    Ok((pool, Tensor::new(vec![v], last)))
}

/// serving.decode over the block pool: one step for all `B` lanes, KV
/// read and written through per-lane block tables (true paged
/// attention — only mapped blocks are touched, the attention window is
/// [Hkv, m + len + 1, dh] instead of a full-capacity row).
///
/// Lanes whose table row is empty (all -1) are *inactive*: they skip the
/// KV write and attend over nothing (zero attention output). Their
/// logits are discarded by every caller. Note for the dynamic
/// quantization modes (ptd/ptk): inactive-lane rows still participate in
/// batch-wide dynamic ranges — exactly like the contiguous path — but
/// their attention output differs from the contiguous path's
/// stale-cache garbage, so cross-path parity on dynamic modes holds for
/// fully-occupied batches (the parity tests use full occupancy).
#[allow(clippy::too_many_arguments)]
pub fn run_decode_paged(spec: &ModelSpec, params: &Params, mode: Mode,
                        pool: &Tensor, tables: &[i32], n_lanes: usize,
                        cache_tok_len: &[i32], cushion_len: i32,
                        tokens: &[i32], ranges: &Tensor, levels: f32,
                        kv_levels: f32, inv_smooth: &Tensor)
                        -> crate::Result<(Tensor, Tensor)> {
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    let b = tokens.len();
    anyhow::ensure!(b == n_lanes, "decode_paged: token batch != table lanes");
    anyhow::ensure!(cache_tok_len.len() == b, "decode_paged: bad lens");
    anyhow::ensure!(b > 0 && tables.len() % b == 0,
                    "decode_paged: ragged tables");
    let width = tables.len() / b;
    let pv = pool_view(spec, pool, "decode_paged")?;
    let lane_table = |bi: usize| &tables[bi * width..(bi + 1) * width];
    let active: Vec<bool> =
        (0..b).map(|bi| lane_table(bi).iter().any(|&id| id >= 0)).collect();
    let mut pool = pool.clone();

    let mut qctx = QuantCtx::serving(mode, levels, ranges, inv_smooth);

    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; b * d];
    for (bi, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "decode_paged: token {t} outside vocab");
        x[bi * d..(bi + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    let positions: Vec<i32> = cache_tok_len
        .iter()
        .map(|&len| cushion_len + len)
        .collect();
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for bi in 0..b {
            let p = positions[bi] as usize;
            anyhow::ensure!(p < pos_emb.shape[0],
                            "decode_paged: position overflow");
            for i in 0..d {
                x[bi * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let h = match spec.norm {
            NormKind::RmsPre => rmsnorm(&x, b, d, &p.ln1_g.data),
            NormKind::LnPost => x.clone(),
        };
        let h = qctx.site(h, b, 1, d, l, 0);
        let mut q = to_heads(&matmul(&h, b, d, p.wq), b, 1, hq, dh);
        let mut k = to_heads(&matmul(&h, b, d, p.wk), b, 1, hkv, dh);
        let mut v = to_heads(&matmul(&h, b, d, p.wv), b, 1, hkv, dh);
        if spec.pos == PosKind::Rope {
            for bi in 0..b {
                rope_rotate(&mut q[bi * hq * dh..(bi + 1) * hq * dh], hq, 1,
                            dh, &positions[bi..bi + 1], spec.rope_theta,
                            false);
                rope_rotate(&mut k[bi * hkv * dh..(bi + 1) * hkv * dh], hkv,
                            1, dh, &positions[bi..bi + 1], spec.rope_theta,
                            false);
            }
        }
        kv_maybe_quant(&mut k, &mut v, b * hkv, 1, dh, kv_levels);
        // scatter each active lane's new KV row through its table
        for bi in 0..b {
            if !active[bi] {
                continue;
            }
            let off = m + cache_tok_len[bi] as usize;
            for which in 0..2 {
                let t = if which == 0 { &k } else { &v };
                for kh in 0..hkv {
                    let src = (bi * hkv + kh) * dh;
                    let (id, q_in) =
                        pv.locate(lane_table(bi), off, "decode_paged")?;
                    let dst = pv.row(spec, id, l, which, kh, q_in);
                    pool.data[dst..dst + dh]
                        .copy_from_slice(&t[src..src + dh]);
                }
            }
        }
        // paged attention: gather only the mapped window per lane
        let mut o = vec![0.0f32; b * hq * dh];
        for bi in 0..b {
            if !active[bi] {
                continue; // zero attention output for empty lanes
            }
            let len = cache_tok_len[bi] as usize;
            let skv = m + len + 1;
            let mut kf = vec![0.0f32; hkv * skv * dh];
            let mut vf = vec![0.0f32; hkv * skv * dh];
            for j in 0..skv {
                let (id, q_in) = pv.locate(lane_table(bi), j, "decode_paged")?;
                for kh in 0..hkv {
                    let ks = pv.row(spec, id, l, 0, kh, q_in);
                    let vs = pv.row(spec, id, l, 1, kh, q_in);
                    let dst = (kh * skv + j) * dh;
                    kf[dst..dst + dh].copy_from_slice(&pool.data[ks..ks + dh]);
                    vf[dst..dst + dh].copy_from_slice(&pool.data[vs..vs + dh]);
                }
            }
            let qb = &q[bi * hq * dh..(bi + 1) * hq * dh];
            let (ob, _) = attention(spec, l, qb, &kf, &vf, 1, skv,
                                    cushion_len, cache_tok_len[bi], None,
                                    false);
            o[bi * hq * dh..(bi + 1) * hq * dh].copy_from_slice(&ob);
        }
        let o = from_heads(&o, b, 1, hq, dh);
        let o = qctx.site(o, b, 1, hq * dh, l, 1);
        let attn_out = matmul(&o, b, hq * dh, p.wo);
        x = block_tail(spec, &mut qctx, &p, x, &attn_out, b, 1, l)?;
    }

    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x, b, d, &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x, b, d, &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let logits = matmul(&hfin, b, d, params.get("lm_head")?);
    Ok((pool, Tensor::new(vec![b, spec.vocab], logits)))
}

// ---------------------------------------------------------------------------
// tune_step (graphs.make_tune_step): one Adam step of quantization-aware
// prefix tuning — forward with a tape, hand-derived backward wrt the
// prefix KV only (the weights are constants here), exactly the gradient
// jax.value_and_grad computes through the ptd+STE forward. Verified
// against jax.grad by python/tests/ref_interp.py + the tune_step goldens.
// ---------------------------------------------------------------------------

struct LayerTape<'a> {
    p: LayerP<'a>,
    x_in: Vec<f32>,
    q: Vec<f32>,
    kf: Vec<f32>,
    vf: Vec<f32>,
    probs: Vec<f32>,
    x_mid: Vec<f32>,
    pre_ln1: Vec<f32>,
    pre_ln2: Vec<f32>,
    ga: Vec<f32>,
    ub: Vec<f32>,
}

/// STE site backward: d loss / d site-input-(pre-smoothing) given
/// d loss / d site-output and the taped record.
fn site_bwd(inv_smooth: &Tensor, d_model: usize, rec: &Option<SiteRec>,
            g_out: &[f32], lam: f32) -> Vec<f32> {
    let Some(rec) = rec else {
        return g_out.to_vec();
    };
    let mut g: Vec<f32> = g_out
        .iter()
        .zip(rec.x.iter().zip(&rec.xq))
        .map(|(&go, (&x, &xq))| {
            (go as f64 + lam as f64 * 2.0 * (x - xq) as f64 / rec.denom) as f32
        })
        .collect();
    if rec.site == 0 || rec.site == 2 {
        let which = if rec.site == 0 { 0 } else { 1 };
        let off = (rec.layer * 2 + which) * d_model;
        let row = &inv_smooth.data[off..off + d_model];
        let f = d_model;
        for r in 0..g.len() / f {
            for (gi, &iv) in g[r * f..(r + 1) * f].iter_mut().zip(row) {
                *gi *= iv;
            }
        }
    }
    g
}

/// tune_step: (prefix_kv', m', v', loss, lq).
#[allow(clippy::too_many_arguments)]
pub fn run_tune_step(spec: &ModelSpec, params: &Params, prefix_kv: &Tensor,
                     adam_m: &Tensor, adam_v: &Tensor, step: i32,
                     tokens: &[i32], b: usize, s: usize, prefix_len: i32,
                     lam: f32, lr: f32, levels: f32, inv_smooth: &Tensor)
                     -> crate::Result<(Tensor, Tensor, Tensor, f32, f32)> {
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let (d, dh, hq, hkv, m) = (spec.d_model, spec.d_head, spec.n_heads,
                               spec.n_kv_heads, spec.m_max);
    let g = spec.group();
    let pre = spec.norm == NormKind::RmsPre;
    let skv = m + s;

    let mut qctx = QuantCtx::new(Mode::Ptd, levels);
    qctx.inv_smooth = Some(inv_smooth.clone());
    qctx.tape = Some(Vec::new());
    let positions: Vec<i32> = (0..b * s)
        .map(|i| prefix_len + (i % s) as i32)
        .collect();

    // ---- forward with tape ------------------------------------------------
    let embed = params.get("embed")?;
    let mut x = vec![0.0f32; b * s * d];
    for (r, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < spec.vocab,
                        "tune: token outside vocab");
        x[r * d..(r + 1) * d].copy_from_slice(embed.row(t as usize));
    }
    if spec.pos == PosKind::Learned {
        let pos_emb = params.get("pos_emb")?;
        for r in 0..b * s {
            let p = positions[r] as usize;
            for i in 0..d {
                x[r * d + i] += pos_emb.data[p * d + i];
            }
        }
    }

    let mut tape: Vec<LayerTape> = Vec::with_capacity(spec.n_layers);
    for l in 0..spec.n_layers {
        let p = layer_p(spec, params, l)?;
        let x_in = x.clone();
        let h1 = if pre {
            rmsnorm(&x, b * s, d, &p.ln1_g.data)
        } else {
            x.clone()
        };
        let a_in = qctx.site(h1, b, s, d, l, 0);
        let mut q = to_heads(&matmul(&a_in, b * s, d, p.wq), b, s, hq, dh);
        let mut k = to_heads(&matmul(&a_in, b * s, d, p.wk), b, s, hkv, dh);
        let v = to_heads(&matmul(&a_in, b * s, d, p.wv), b, s, hkv, dh);
        if spec.pos == PosKind::Rope {
            for bi in 0..b {
                let pos = &positions[bi * s..(bi + 1) * s];
                rope_rotate(&mut q[bi * hq * s * dh..(bi + 1) * hq * s * dh],
                            hq, s, dh, pos, spec.rope_theta, false);
                rope_rotate(&mut k[bi * hkv * s * dh..(bi + 1) * hkv * s * dh],
                            hkv, s, dh, pos, spec.rope_theta, false);
            }
        }
        let mut kf = vec![0.0f32; b * hkv * skv * dh];
        let mut vf = vec![0.0f32; b * hkv * skv * dh];
        let mut probs = vec![0.0f32; b * hq * s * skv];
        let mut o = vec![0.0f32; b * hq * s * dh];
        for bi in 0..b {
            let kfb = concat_prefix(spec, prefix_kv, l, 0, &k, bi, s);
            let vfb = concat_prefix(spec, prefix_kv, l, 1, &v, bi, s);
            let qb = &q[bi * hq * s * dh..(bi + 1) * hq * s * dh];
            let (ob, pb) = attention(spec, l, qb, &kfb, &vfb, s, skv,
                                     prefix_len, 0, None, true);
            o[bi * hq * s * dh..(bi + 1) * hq * s * dh].copy_from_slice(&ob);
            probs[bi * hq * s * skv..(bi + 1) * hq * s * skv]
                .copy_from_slice(&pb.unwrap());
            kf[bi * hkv * skv * dh..(bi + 1) * hkv * skv * dh]
                .copy_from_slice(&kfb);
            vf[bi * hkv * skv * dh..(bi + 1) * hkv * skv * dh]
                .copy_from_slice(&vfb);
        }
        let o = from_heads(&o, b, s, hq, dh);
        let o_q = qctx.site(o, b, s, hq * dh, l, 1);
        let attn_out = matmul(&o_q, b * s, hq * dh, p.wo);

        let (x_mid, pre_ln1, h2);
        if pre {
            let mut xm = x.clone();
            for (xi, a) in xm.iter_mut().zip(&attn_out) {
                *xi += a;
            }
            h2 = rmsnorm(&xm, b * s, d, &p.ln2_g.data);
            x_mid = xm;
            pre_ln1 = Vec::new();
        } else {
            let mut p1 = x.clone();
            for (xi, a) in p1.iter_mut().zip(&attn_out) {
                *xi += a;
            }
            let xm = layernorm(&p1, b * s, d, &p.ln1_g.data,
                               &p.ln1_b.unwrap().data);
            h2 = xm.clone();
            x_mid = xm;
            pre_ln1 = p1;
        }
        let m_in = qctx.site(h2, b, s, d, l, 2);
        let (ga, ub, hidden): (Vec<f32>, Vec<f32>, Vec<f32>);
        match spec.act {
            ActKind::Swiglu => {
                ga = matmul(&m_in, b * s, d, p.wg.unwrap());
                ub = matmul(&m_in, b * s, d, p.wu);
                hidden = ga.iter().zip(&ub).map(|(&a, &u)| silu(a) * u)
                    .collect();
            }
            _ => {
                ga = matmul(&m_in, b * s, d, p.wu);
                ub = Vec::new();
                hidden = ga.iter().map(|&a| act_apply(spec.act, a)).collect();
            }
        }
        let hidden_q = qctx.site(hidden, b, s, spec.d_ff, l, 3);
        let mlp_out = matmul(&hidden_q, b * s, spec.d_ff, p.wd);

        let pre_ln2;
        if pre {
            let mut xo = x_mid.clone();
            for (xi, a) in xo.iter_mut().zip(&mlp_out) {
                *xi += a;
            }
            x = xo;
            pre_ln2 = Vec::new();
        } else {
            let mut p2 = x_mid.clone();
            for (xi, a) in p2.iter_mut().zip(&mlp_out) {
                *xi += a;
            }
            x = layernorm(&p2, b * s, d, &p.ln2_g.data,
                          &p.ln2_b.unwrap().data);
            pre_ln2 = p2;
        }
        tape.push(LayerTape {
            p, x_in, q, kf, vf, probs, x_mid, pre_ln1, pre_ln2, ga, ub,
        });
    }

    let x_final = x;
    let hfin = match spec.norm {
        NormKind::RmsPre => rmsnorm(&x_final, b * s, d,
                                    &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm(&x_final, b * s, d,
                                      &params.get("lnf_g")?.data,
                                      &params.get("lnf_b")?.data),
    };
    let lm_head = params.get("lm_head")?;
    let logits = matmul(&hfin, b * s, d, lm_head);
    let vocab = spec.vocab;

    // loss_pred: mean next-token NLL over positions 0..s-1
    let count = (b * (s - 1)) as f64;
    let mut l_pred = 0.0f64;
    let mut dlogits = vec![0.0f32; b * s * vocab];
    for bi in 0..b {
        for si in 0..s - 1 {
            let r = bi * s + si;
            let row = &logits[r * vocab..(r + 1) * vocab];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let mut sum = 0.0f64;
            for &v in row {
                sum += ((v - mx) as f64).exp();
            }
            let tgt = tokens[bi * s + si + 1] as usize;
            l_pred -= (row[tgt] - mx) as f64 - sum.ln();
            let drow = &mut dlogits[r * vocab..(r + 1) * vocab];
            for (j, dv) in drow.iter_mut().enumerate() {
                let sm = ((row[j] - mx) as f64).exp() / sum;
                let one = if j == tgt { 1.0 } else { 0.0 };
                *dv = ((sm - one) / count) as f32;
            }
        }
    }
    l_pred /= count;
    let lq = qctx.lq;
    let loss = (l_pred + lam as f64 * lq) as f32;

    // ---- backward ---------------------------------------------------------
    let sites = qctx.tape.take().unwrap();
    let inv = inv_smooth;
    let dh_fin = matmul_t(&dlogits, b * s, vocab, lm_head);
    let mut dx = match spec.norm {
        NormKind::RmsPre => rmsnorm_bwd(&dh_fin, &x_final, b * s, d,
                                        &params.get("lnf_g")?.data),
        NormKind::LnPost => layernorm_bwd(&dh_fin, &x_final, b * s, d,
                                          &params.get("lnf_g")?.data),
    };

    let mut d_pkv = vec![0.0f64; prefix_kv.data.len()];
    let inv_sqrt = 1.0 / (dh as f64).sqrt();
    for l in (0..spec.n_layers).rev() {
        let t = &tape[l];
        let p = &t.p;
        let (s0, s1, s2, s3) = (&sites[4 * l], &sites[4 * l + 1],
                                &sites[4 * l + 2], &sites[4 * l + 3]);
        let (mut dx_mid, dmlp_out);
        if pre {
            dx_mid = dx.clone();
            dmlp_out = dx;
        } else {
            let d2 = layernorm_bwd(&dx, &t.pre_ln2, b * s, d, &p.ln2_g.data);
            dx_mid = d2.clone();
            dmlp_out = d2;
        }
        let dhidden_q = matmul_t(&dmlp_out, b * s, d, p.wd);
        let dhidden = site_bwd(inv, d, s3, &dhidden_q, lam);
        let dm_in = match spec.act {
            ActKind::Swiglu => {
                let mut dga = vec![0.0f32; t.ga.len()];
                let mut dub = vec![0.0f32; t.ub.len()];
                for i in 0..t.ga.len() {
                    dga[i] = dhidden[i] * t.ub[i] * silu_grad(t.ga[i]);
                    dub[i] = dhidden[i] * silu(t.ga[i]);
                }
                let a = matmul_t(&dga, b * s, spec.d_ff, p.wg.unwrap());
                let u = matmul_t(&dub, b * s, spec.d_ff, p.wu);
                a.iter().zip(&u).map(|(&x1, &x2)| x1 + x2).collect::<Vec<_>>()
            }
            ActKind::Relu => {
                let dga: Vec<f32> = dhidden
                    .iter()
                    .zip(&t.ga)
                    .map(|(&dv, &a)| if a > 0.0 { dv } else { 0.0 })
                    .collect();
                matmul_t(&dga, b * s, spec.d_ff, p.wu)
            }
            ActKind::Gelu => {
                let dga: Vec<f32> = dhidden
                    .iter()
                    .zip(&t.ga)
                    .map(|(&dv, &a)| dv * gelu_grad(a))
                    .collect();
                matmul_t(&dga, b * s, spec.d_ff, p.wu)
            }
        };
        let dh2 = site_bwd(inv, d, s2, &dm_in, lam);
        let dattn_out;
        if pre {
            let dxm2 = rmsnorm_bwd(&dh2, &t.x_mid, b * s, d, &p.ln2_g.data);
            for (a, &v) in dx_mid.iter_mut().zip(&dxm2) {
                *a += v;
            }
            dattn_out = dx_mid.clone();
            dx = dx_mid;
        } else {
            for (a, &v) in dx_mid.iter_mut().zip(&dh2) {
                *a += v;
            }
            let d1 = layernorm_bwd(&dx_mid, &t.pre_ln1, b * s, d,
                                   &p.ln1_g.data);
            dattn_out = d1.clone();
            dx = d1;
        }

        // attention backward
        let do_q = matmul_t(&dattn_out, b * s, d, p.wo);
        let do_flat = site_bwd(inv, d, s1, &do_q, lam);
        let dout = to_heads(&do_flat, b, s, hq, dh); // [b, hq, s, dh]
        let mut dq = vec![0.0f32; b * hq * s * dh];
        let mut dkf = vec![0.0f64; b * hkv * skv * dh];
        let mut dvf = vec![0.0f64; b * hkv * skv * dh];
        let mut dp_row = vec![0.0f64; skv];
        let mut dlog = vec![0.0f64; skv];
        for bi in 0..b {
            for h in 0..hq {
                let kh = h / g;
                let kfb = &t.kf[((bi * hkv + kh) * skv) * dh
                    ..((bi * hkv + kh) * skv + skv) * dh];
                let vfb = &t.vf[((bi * hkv + kh) * skv) * dh
                    ..((bi * hkv + kh) * skv + skv) * dh];
                for i in 0..s {
                    let prow = &t.probs[((bi * hq + h) * s + i) * skv
                        ..((bi * hq + h) * s + i) * skv + skv];
                    let dorow = &dout[((bi * hq + h) * s + i) * dh
                        ..((bi * hq + h) * s + i) * dh + dh];
                    let mut dot_pp = 0.0f64;
                    for j in 0..skv {
                        let mut acc = 0.0f64;
                        for dd in 0..dh {
                            acc += dorow[dd] as f64 * vfb[j * dh + dd] as f64;
                        }
                        dp_row[j] = acc;
                        dot_pp += acc * prow[j] as f64;
                        if prow[j] != 0.0 {
                            let pj = prow[j] as f64;
                            for dd in 0..dh {
                                dvf[((bi * hkv + kh) * skv + j) * dh + dd] +=
                                    pj * dorow[dd] as f64;
                            }
                        }
                    }
                    for j in 0..skv {
                        dlog[j] = prow[j] as f64 * (dp_row[j] - dot_pp);
                    }
                    let qrow = &t.q[((bi * hq + h) * s + i) * dh
                        ..((bi * hq + h) * s + i) * dh + dh];
                    let dqrow = &mut dq[((bi * hq + h) * s + i) * dh
                        ..((bi * hq + h) * s + i) * dh + dh];
                    for j in 0..skv {
                        if dlog[j] == 0.0 {
                            continue;
                        }
                        let w = dlog[j] * inv_sqrt;
                        for dd in 0..dh {
                            dqrow[dd] =
                                (dqrow[dd] as f64 + w * kfb[j * dh + dd] as f64)
                                    as f32;
                            dkf[((bi * hkv + kh) * skv + j) * dh + dd] +=
                                w * qrow[dd] as f64;
                        }
                    }
                }
            }
        }
        // prefix slots -> d prefix_kv (summed over batch); token slots ->
        // backward through rope into the projections
        let mut dk = vec![0.0f32; b * hkv * s * dh];
        let mut dv = vec![0.0f32; b * hkv * s * dh];
        for bi in 0..b {
            for kh in 0..hkv {
                for j in 0..skv {
                    let src = ((bi * hkv + kh) * skv + j) * dh;
                    if j < m {
                        let kdst = (((l * 2) * hkv + kh) * m + j) * dh;
                        let vdst = (((l * 2 + 1) * hkv + kh) * m + j) * dh;
                        for dd in 0..dh {
                            d_pkv[kdst + dd] += dkf[src + dd];
                            d_pkv[vdst + dd] += dvf[src + dd];
                        }
                    } else {
                        let dst = ((bi * hkv + kh) * s + (j - m)) * dh;
                        for dd in 0..dh {
                            dk[dst + dd] = dkf[src + dd] as f32;
                            dv[dst + dd] = dvf[src + dd] as f32;
                        }
                    }
                }
            }
        }
        if spec.pos == PosKind::Rope {
            for bi in 0..b {
                let pos = &positions[bi * s..(bi + 1) * s];
                rope_rotate(&mut dq[bi * hq * s * dh..(bi + 1) * hq * s * dh],
                            hq, s, dh, pos, spec.rope_theta, true);
                rope_rotate(&mut dk[bi * hkv * s * dh..(bi + 1) * hkv * s * dh],
                            hkv, s, dh, pos, spec.rope_theta, true);
            }
        }
        let dq_flat = from_heads(&dq, b, s, hq, dh);
        let dk_flat = from_heads(&dk, b, s, hkv, dh);
        let dv_flat = from_heads(&dv, b, s, hkv, dh);
        let mut da_in = matmul_t(&dq_flat, b * s, hq * dh, p.wq);
        let dak = matmul_t(&dk_flat, b * s, hkv * dh, p.wk);
        let dav = matmul_t(&dv_flat, b * s, hkv * dh, p.wv);
        for i in 0..da_in.len() {
            da_in[i] += dak[i] + dav[i];
        }
        let dh1 = site_bwd(inv, d, s0, &da_in, lam);
        if pre {
            let dx1 = rmsnorm_bwd(&dh1, &t.x_in, b * s, d, &p.ln1_g.data);
            for (a, &v) in dx.iter_mut().zip(&dx1) {
                *a += v;
            }
        } else {
            for (a, &v) in dx.iter_mut().zip(&dh1) {
                *a += v;
            }
        }
    }

    // ---- Adam -------------------------------------------------------------
    let t_f = step as f32 + 1.0;
    let n = prefix_kv.data.len();
    let mut m2 = vec![0.0f32; n];
    let mut v2 = vec![0.0f32; n];
    let mut pkv2 = vec![0.0f32; n];
    let bc1 = 1.0 - b1.powf(t_f);
    let bc2 = 1.0 - b2.powf(t_f);
    for i in 0..n {
        let gi = d_pkv[i] as f32;
        m2[i] = b1 * adam_m.data[i] + (1.0 - b1) * gi;
        v2[i] = b2 * adam_v.data[i] + (1.0 - b2) * gi * gi;
        let mhat = m2[i] / bc1;
        let vhat = v2[i] / bc2;
        pkv2[i] = prefix_kv.data[i] - lr * mhat / (vhat.sqrt() + eps);
    }
    let shape = prefix_kv.shape.clone();
    Ok((
        Tensor::new(shape.clone(), pkv2),
        Tensor::new(shape.clone(), m2),
        Tensor::new(shape, v2),
        loss,
        lq as f32,
    ))
}
