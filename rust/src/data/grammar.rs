//! The synwiki generative grammar — a bit-for-bit mirror of
//! python/compile/datagen.py (same SplitMix64 call order, same successor
//! tables, same sentence/document structure). Parity is asserted against
//! the `trainsample` corpus split in rust/tests/parity.rs.

use super::{BOS, DOT, GRAMMAR_SEED, NL, N_SPECIAL, N_TOPICS};
use crate::util::prng::{hash64, SplitMix64};

pub const SUCC_WEIGHTS: [f64; 3] = [0.55, 0.30, 0.15];
pub const N_STARTERS: u64 = 8;
pub const BODY_MIN: u64 = 3;
pub const BODY_RANGE: u64 = 5;
pub const SENTS_PER_PARA: usize = 4;
pub const TOPIC_SWITCH: f64 = 0.1;

#[derive(Clone, Debug)]
pub struct Grammar {
    pub vocab: usize,
    pub tpt: usize, // tokens per topic
    pub seed: u64,
}

impl Grammar {
    pub fn new(vocab: usize) -> Self {
        Self {
            vocab,
            tpt: (vocab - N_SPECIAL as usize) / N_TOPICS,
            seed: GRAMMAR_SEED,
        }
    }

    /// k-th allowed successor (within-topic index) of token index t.
    pub fn successor(&self, topic: usize, t: usize, k: usize) -> usize {
        let h = hash64(self.seed ^ (topic as u64 * 131071 + t as u64 * 31 + k as u64));
        (h % self.tpt as u64) as usize
    }

    pub fn step(&self, topic: usize, t: usize, rng: &mut SplitMix64) -> usize {
        let u = rng.next_f64();
        let k = if u < SUCC_WEIGHTS[0] {
            0
        } else if u < SUCC_WEIGHTS[0] + SUCC_WEIGHTS[1] {
            1
        } else {
            2
        };
        self.successor(topic, t, k)
    }

    pub fn agree(&self, s0: usize) -> usize {
        (7 * s0 + 3) % self.tpt
    }

    pub fn gid(&self, topic: usize, idx: usize) -> i32 {
        N_SPECIAL + (topic * self.tpt + idx) as i32
    }

    /// Is this id one of the low-semantic trigger tokens?
    pub fn is_trigger(&self, id: i32) -> bool {
        id == BOS || id == NL || id == DOT
    }

    pub fn sentence(&self, topic: usize, rng: &mut SplitMix64) -> Vec<i32> {
        let s0 = rng.next_below(N_STARTERS) as usize;
        let body_len = (BODY_MIN + rng.next_below(BODY_RANGE)) as usize;
        let mut idxs = vec![s0];
        let mut cur = s0;
        for _ in 0..body_len {
            cur = self.step(topic, cur, rng);
            idxs.push(cur);
        }
        idxs.push(self.agree(s0));
        let mut out: Vec<i32> = idxs.into_iter().map(|i| self.gid(topic, i)).collect();
        out.push(DOT);
        out
    }

    pub fn document(&self, length: usize, rng: &mut SplitMix64) -> Vec<i32> {
        let mut toks = vec![BOS];
        let mut topic = rng.next_below(N_TOPICS as u64) as usize;
        let mut n_sent = 0usize;
        while toks.len() < length {
            if n_sent > 0 && rng.next_f64() < TOPIC_SWITCH {
                topic = rng.next_below(N_TOPICS as u64) as usize;
            }
            toks.extend(self.sentence(topic, rng));
            n_sent += 1;
            if n_sent % SENTS_PER_PARA == 0 {
                toks.push(NL);
            }
        }
        toks.truncate(length);
        toks
    }
}

/// Reproducible corpus split — mirrors datagen.corpus_split exactly.
pub fn corpus_split(vocab: usize, n_seqs: usize, seq_len: usize, stream: u64,
                    seed: u64) -> Vec<Vec<i32>> {
    let g = Grammar::new(vocab);
    let mut base = SplitMix64::new(seed);
    let mut rng = base.fork(stream);
    (0..n_seqs)
        .map(|i| {
            let mut r = rng.fork(i as u64);
            g.document(seq_len, &mut r)
        })
        .collect()
}

pub const CORPUS_SEED: u64 = 0x5EED;
pub const STREAM_CALIB: u64 = 1;
pub const STREAM_HELDOUT: u64 = 2;
pub const STREAM_TRAINSAMPLE: u64 = 3;
/// Serve-time workloads draw from their own stream so they never collide
/// with the eval splits.
pub const STREAM_SERVE: u64 = 100;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn document_shape() {
        let g = Grammar::new(512);
        let mut rng = SplitMix64::new(1);
        let d = g.document(128, &mut rng);
        assert_eq!(d.len(), 128);
        assert_eq!(d[0], BOS);
        assert!(d.iter().all(|&t| t >= 0 && (t as usize) < 512));
        assert!(d.contains(&DOT));
    }

    #[test]
    fn deterministic() {
        let g = Grammar::new(512);
        let a = g.document(64, &mut SplitMix64::new(5));
        let b = g.document(64, &mut SplitMix64::new(5));
        assert_eq!(a, b);
    }

    #[test]
    fn sentence_ends_with_agreement_then_dot() {
        let g = Grammar::new(512);
        let mut rng = SplitMix64::new(2);
        for topic in 0..N_TOPICS {
            let s = g.sentence(topic, &mut rng);
            assert_eq!(*s.last().unwrap(), DOT);
            let s0 = (s[0] - N_SPECIAL) as usize % g.tpt;
            let agree = s[s.len() - 2];
            assert_eq!(agree, g.gid(topic, g.agree(s0)));
        }
    }

    #[test]
    fn successor_table_is_stable() {
        let g = Grammar::new(512);
        // pure function of (topic, t, k): same across calls
        assert_eq!(g.successor(3, 7, 1), g.successor(3, 7, 1));
        // weights order: step with u<0.55 picks successor 0
        let g2 = Grammar::new(1024);
        assert!(g2.tpt > g.tpt);
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let a = corpus_split(512, 4, 64, STREAM_CALIB, CORPUS_SEED);
        let b = corpus_split(512, 4, 64, STREAM_HELDOUT, CORPUS_SEED);
        assert_ne!(a, b);
        let a2 = corpus_split(512, 4, 64, STREAM_CALIB, CORPUS_SEED);
        assert_eq!(a, a2);
    }
}
