//! Loader for corpus.bin (python/compile/binio.write_corpus).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::fsutil::{self, Cursor};

#[derive(Clone, Debug)]
pub struct Split {
    pub n_seqs: usize,
    pub seq_len: usize,
    /// Row-major [n_seqs, seq_len].
    pub tokens: Vec<i32>,
}

impl Split {
    pub fn seq(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }

    pub fn seqs(&self) -> impl Iterator<Item = &[i32]> {
        (0..self.n_seqs).map(move |i| self.seq(i))
    }
}

#[derive(Clone, Debug, Default)]
pub struct Corpus {
    pub splits: BTreeMap<String, Split>,
}

impl Corpus {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let buf = fsutil::read(path)?;
        let mut c = Cursor::new(&buf);
        c.magic(b"CCC1")?;
        let n = c.u32()? as usize;
        let mut splits = BTreeMap::new();
        for _ in 0..n {
            let name = c.string()?;
            let n_seqs = c.u32()? as usize;
            let seq_len = c.u32()? as usize;
            let tokens = c.i32_vec(n_seqs * seq_len)?;
            splits.insert(name, Split { n_seqs, seq_len, tokens });
        }
        Ok(Self { splits })
    }

    pub fn split(&self, name: &str) -> crate::Result<&Split> {
        self.splits
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("corpus split '{name}' missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_roundtrip() {
        // hand-build a corpus.bin in memory-equivalent file
        let dir = std::env::temp_dir().join("cc_corpus_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("corpus.bin");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"CCC1");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&5u32.to_le_bytes());
        buf.extend_from_slice(b"calib");
        buf.extend_from_slice(&2u32.to_le_bytes()); // n_seqs
        buf.extend_from_slice(&3u32.to_le_bytes()); // seq_len
        for t in [1i32, 2, 3, 4, 5, 6] {
            buf.extend_from_slice(&t.to_le_bytes());
        }
        std::fs::write(&path, &buf).unwrap();
        let c = Corpus::load(&path).unwrap();
        let s = c.split("calib").unwrap();
        assert_eq!(s.seq(1), &[4, 5, 6]);
        assert!(c.split("nope").is_err());
    }
}
