//! id <-> string tokenizer, mirroring python/compile/tokenizer.py.

use super::{BOS, DOT, NL, N_SPECIAL, N_TOPICS, PAD};

#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab: usize,
    pub tokens_per_topic: usize,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Self {
        Self {
            vocab,
            tokens_per_topic: vocab.saturating_sub(N_SPECIAL as usize) / N_TOPICS,
        }
    }

    pub fn is_special(&self, id: i32) -> bool {
        id < N_SPECIAL
    }

    /// Is `id` a valid embedding-table index for this vocabulary?
    /// The serving front end checks every prompt token against this.
    pub fn in_vocab(&self, id: i32) -> bool {
        id >= 0 && (id as usize) < self.vocab
    }

    pub fn topic_of(&self, id: i32) -> usize {
        debug_assert!(id >= N_SPECIAL);
        (id - N_SPECIAL) as usize / self.tokens_per_topic
    }

    pub fn index_of(&self, id: i32) -> usize {
        debug_assert!(id >= N_SPECIAL);
        (id - N_SPECIAL) as usize % self.tokens_per_topic
    }

    pub fn content_id(&self, topic: usize, index: usize) -> i32 {
        assert!(topic < N_TOPICS && index < self.tokens_per_topic);
        N_SPECIAL + (topic * self.tokens_per_topic + index) as i32
    }

    pub fn id_to_str(&self, id: i32) -> String {
        match id {
            x if x == BOS => "<bos>".into(),
            x if x == NL => "<nl>".into(),
            x if x == DOT => "<dot>".into(),
            x if x == PAD => "<pad>".into(),
            // total on arbitrary ids: echo_text renders model output,
            // and rendering must never be the thing that panics
            x if !self.in_vocab(x) || self.tokens_per_topic == 0 => {
                format!("<unk{id}>")
            }
            _ => format!("t{:02}w{:03}", self.topic_of(id), self.index_of(id)),
        }
    }

    pub fn str_to_id(&self, s: &str) -> crate::Result<i32> {
        match s {
            "<bos>" => return Ok(BOS),
            "<nl>" => return Ok(NL),
            "<dot>" => return Ok(DOT),
            "<pad>" => return Ok(PAD),
            _ => {}
        }
        let rest = s
            .strip_prefix('t')
            .ok_or_else(|| anyhow::anyhow!("bad token {s:?}"))?;
        let (topic, index) = rest
            .split_once('w')
            .ok_or_else(|| anyhow::anyhow!("bad token {s:?}"))?;
        Ok(self.content_id(topic.parse()?, index.parse()?))
    }

    pub fn detokenize(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == BOS || id == PAD {
                continue;
            } else if id == DOT {
                out.push('.');
            } else if id == NL {
                out.push('\n');
            } else {
                out.push(' ');
                out.push_str(&self.id_to_str(id));
            }
        }
        out.trim().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_ids() {
        let t = Tokenizer::new(512);
        for id in 0..512i32 {
            if id >= N_SPECIAL
                && self_content_in_range(&t, id)
            {
                let s = t.id_to_str(id);
                assert_eq!(t.str_to_id(&s).unwrap(), id, "{s}");
            }
        }
        for id in 0..N_SPECIAL {
            let s = t.id_to_str(id);
            assert_eq!(t.str_to_id(&s).unwrap(), id);
        }
    }

    fn self_content_in_range(t: &Tokenizer, id: i32) -> bool {
        // ids beyond the last full topic block are unused by the grammar
        ((id - N_SPECIAL) as usize) < N_TOPICS * t.tokens_per_topic
    }

    #[test]
    fn detok_renders_structure() {
        let t = Tokenizer::new(512);
        let s = t.detokenize(&[BOS, 4, 5, DOT, NL, 6]);
        assert!(s.contains('.'));
        assert!(s.contains('\n'));
        assert!(!s.contains("<bos>"));
    }

    #[test]
    fn out_of_vocab_ids_render_totally() {
        let t = Tokenizer::new(512);
        assert!(t.in_vocab(0) && t.in_vocab(511));
        assert!(!t.in_vocab(-1) && !t.in_vocab(512));
        assert_eq!(t.id_to_str(512), "<unk512>");
        assert_eq!(t.id_to_str(-7), "<unk-7>");
        // tiny vocab: no topic blocks at all, still total
        let tiny = Tokenizer::new(4);
        assert_eq!(tiny.tokens_per_topic, 0);
        assert_eq!(tiny.id_to_str(3), "<pad>");
        assert_eq!(tiny.id_to_str(5), "<unk5>");
    }

    #[test]
    fn bad_strings_rejected() {
        let t = Tokenizer::new(512);
        assert!(t.str_to_id("xyz").is_err());
        assert!(t.str_to_id("t99").is_err());
    }
}
