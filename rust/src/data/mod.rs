//! Data substrate: the synwiki grammar (bit-for-bit mirror of
//! python/compile/datagen.py), tokenizer, corpus/tasks artifact loaders,
//! and serve-time workload generation.

pub mod corpus;
pub mod grammar;
pub mod tasks;
pub mod tokenizer;

/// Tokenizer special ids (configs.py).
pub const BOS: i32 = 0;
pub const NL: i32 = 1;
pub const DOT: i32 = 2;
pub const PAD: i32 = 3;
pub const N_SPECIAL: i32 = 4;
pub const N_TOPICS: usize = 14;
pub const GRAMMAR_SEED: u64 = 0xC0DE;
pub const TRIGGER_TOKENS: [i32; 3] = [BOS, NL, DOT];
