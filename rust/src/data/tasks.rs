//! Loader for tasks.bin (python/compile/binio.write_tasks): the seven
//! zero-shot analogues + mmlu-syn + gsm-syn.

use std::path::Path;

use crate::util::fsutil::{self, Cursor};

pub const KIND_ARGMAX: u32 = 0;
pub const KIND_MC: u32 = 1;
pub const KIND_GEN: u32 = 2;

pub const ZERO_SHOT: [&str; 7] = [
    "lambada-syn", "hellaswag-syn", "piqa-syn", "winogrande-syn",
    "obqa-syn", "rte-syn", "copa-syn",
];

#[derive(Clone, Debug)]
pub struct TaskItem {
    pub kind: u32,
    pub meta: u32,
    pub context: Vec<i32>,
    pub candidates: Vec<Vec<i32>>,
    pub gold: usize,
}

#[derive(Clone, Debug)]
pub struct Task {
    pub name: String,
    pub items: Vec<TaskItem>,
}

pub fn load(path: &Path) -> crate::Result<Vec<Task>> {
    let buf = fsutil::read(path)?;
    let mut c = Cursor::new(&buf);
    c.magic(b"CCT1")?;
    let n_tasks = c.u32()? as usize;
    let mut tasks = Vec::with_capacity(n_tasks);
    for _ in 0..n_tasks {
        let name = c.string()?;
        let n_items = c.u32()? as usize;
        let mut items = Vec::with_capacity(n_items);
        for _ in 0..n_items {
            let kind = c.u32()?;
            let meta = c.u32()?;
            let ctx_len = c.u32()? as usize;
            let context = c.i32_vec(ctx_len)?;
            let n_cands = c.u32()? as usize;
            let gold = c.u32()? as usize;
            let mut candidates = Vec::with_capacity(n_cands);
            for _ in 0..n_cands {
                let len = c.u32()? as usize;
                candidates.push(c.i32_vec(len)?);
            }
            anyhow::ensure!(gold < n_cands.max(1), "gold out of range");
            items.push(TaskItem { kind, meta, context, candidates, gold });
        }
        tasks.push(Task { name, items });
    }
    Ok(tasks)
}

pub fn find<'a>(tasks: &'a [Task], name: &str) -> crate::Result<&'a Task> {
    tasks
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| anyhow::anyhow!("task '{name}' missing"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_synthesized_file() {
        let dir = std::env::temp_dir().join("cc_tasks_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("tasks.bin");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"CCT1");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&4u32.to_le_bytes());
        buf.extend_from_slice(b"mini");
        buf.extend_from_slice(&1u32.to_le_bytes()); // n_items
        buf.extend_from_slice(&KIND_MC.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // meta
        buf.extend_from_slice(&2u32.to_le_bytes()); // ctx_len
        buf.extend_from_slice(&0i32.to_le_bytes());
        buf.extend_from_slice(&7i32.to_le_bytes());
        buf.extend_from_slice(&2u32.to_le_bytes()); // n_cands
        buf.extend_from_slice(&1u32.to_le_bytes()); // gold
        for cand in [[8i32, 9], [10i32, 11]] {
            buf.extend_from_slice(&2u32.to_le_bytes());
            for t in cand {
                buf.extend_from_slice(&t.to_le_bytes());
            }
        }
        std::fs::write(&path, &buf).unwrap();
        let tasks = load(&path).unwrap();
        assert_eq!(tasks.len(), 1);
        let it = &tasks[0].items[0];
        assert_eq!(it.gold, 1);
        assert_eq!(it.candidates[1], vec![10, 11]);
        assert!(find(&tasks, "mini").is_ok());
        assert!(find(&tasks, "nope").is_err());
    }
}
