//! An artifact-free tiny model: manifest, deterministic random weights,
//! and a synwiki corpus, assembled fully in memory so hermetic tests
//! (rust/tests/hermetic_serve.rs) and examples can build a working
//! `Session` on the reference backend with **no artifact directory** and
//! no XLA toolchain.
//!
//! The dimensions mirror the golden-fixture mini configs
//! (python/tests/conftest.py::mini_configs) so anything validated by
//! interp_parity.rs is exercised at the same scale here.

use crate::data::corpus::{Corpus, Split};
use crate::data::grammar::{self, corpus_split};
use crate::model::manifest::Manifest;
use crate::model::session::Session;
use crate::model::weights::Weights;
use crate::runtime::Client;
use crate::util::prng::SplitMix64;
use crate::util::tensor::Tensor;

/// Dimensions of the in-memory tiny model.
#[derive(Clone, Debug)]
pub struct TinyCfg {
    pub variant: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub norm: &'static str,
    pub act: &'static str,
    pub pos: &'static str,
    pub window: usize,
    pub seq_len: usize,
    pub m_max: usize,
    /// Paged-KV pool knobs (0 = derive; see model::manifest). Tests
    /// shrink `kv_pool_blocks` to force preemption.
    pub kv_block_size: usize,
    pub kv_pool_blocks: usize,
    pub serve_batch: usize,
    pub eval_batch: usize,
    pub score_batch: usize,
    pub score_text_len: usize,
    /// Tensor-parallel shard count (1 = unsharded; must divide
    /// `n_kv_heads` and `d_ff` — validated at manifest parse).
    pub n_shards: usize,
    pub seed: u64,
}

impl Default for TinyCfg {
    fn default() -> Self {
        TinyCfg {
            variant: "tiny-hermetic".to_string(),
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            d_head: 16,
            d_ff: 48,
            norm: "rmsnorm_pre",
            act: "swiglu",
            pos: "rope",
            window: 0,
            seq_len: 16,
            m_max: 4,
            kv_block_size: 0,
            kv_pool_blocks: 0,
            serve_batch: 2,
            eval_batch: 2,
            score_batch: 8,
            score_text_len: 12,
            n_shards: 1,
            seed: 0x7157,
        }
    }
}

impl TinyCfg {
    pub fn cache_cap(&self) -> usize {
        self.m_max + self.seq_len
    }

    /// The (name, shape) weight spec in param_spec order
    /// (python/compile/model.py::param_spec).
    pub fn param_spec(&self) -> Vec<(String, Vec<usize>)> {
        let (d, dh) = (self.d_model, self.d_head);
        let (hq, hkv, f) = (self.n_heads, self.n_kv_heads, self.d_ff);
        let ln = self.norm == "ln_post";
        let mut spec = vec![("embed".to_string(), vec![self.vocab, d])];
        if self.pos == "learned" {
            spec.push(("pos_emb".to_string(), vec![self.cache_cap(), d]));
        }
        for l in 0..self.n_layers {
            let p = |base: &str| format!("layer{l}.{base}");
            spec.push((p("ln1_g"), vec![d]));
            if ln {
                spec.push((p("ln1_b"), vec![d]));
            }
            spec.push((p("wq"), vec![d, hq * dh]));
            spec.push((p("wk"), vec![d, hkv * dh]));
            spec.push((p("wv"), vec![d, hkv * dh]));
            spec.push((p("wo"), vec![hq * dh, d]));
            spec.push((p("ln2_g"), vec![d]));
            if ln {
                spec.push((p("ln2_b"), vec![d]));
            }
            if self.act == "swiglu" {
                spec.push((p("wg"), vec![d, f]));
            }
            spec.push((p("wu"), vec![d, f]));
            spec.push((p("wd"), vec![f, d]));
        }
        spec.push(("lnf_g".to_string(), vec![d]));
        if ln {
            spec.push(("lnf_b".to_string(), vec![d]));
        }
        spec.push(("lm_head".to_string(), vec![d, self.vocab]));
        spec
    }

    pub fn manifest(&self) -> crate::Result<Manifest> {
        let params: Vec<String> = self
            .param_spec()
            .iter()
            .map(|(name, shape)| {
                let dims: Vec<String> =
                    shape.iter().map(usize::to_string).collect();
                format!(
                    r#"{{"name": "{name}", "shape": [{}]}}"#,
                    dims.join(", ")
                )
            })
            .collect();
        Manifest::parse(&format!(
            r#"{{
              "variant": "{v}", "vocab": {vocab}, "d_model": {d},
              "n_layers": {l}, "n_heads": {hq}, "n_kv_heads": {hkv},
              "d_head": {dh}, "d_ff": {ff}, "norm": "{norm}",
              "act": "{act}", "pos": "{pos}", "window": {w},
              "n_sites": {sites}, "seq_len": {s},
              "prefill_buckets": [{half}, {s}],
              "m_max": {m}, "cache_cap": {cap},
              "kv_block_size": {kbs}, "kv_pool_blocks": {kpb},
              "serve_batch": {sb}, "n_shards": {ns},
              "eval_batch": {eb}, "score_batch": {scb},
              "score_text_len": {stl}, "tune_batch": {eb},
              "params": [{params}], "graphs": []
            }}"#,
            v = self.variant,
            vocab = self.vocab,
            d = self.d_model,
            l = self.n_layers,
            hq = self.n_heads,
            hkv = self.n_kv_heads,
            dh = self.d_head,
            ff = self.d_ff,
            norm = self.norm,
            act = self.act,
            pos = self.pos,
            w = self.window,
            sites = self.n_layers * 4,
            s = self.seq_len,
            half = self.seq_len / 2,
            m = self.m_max,
            cap = self.cache_cap(),
            kbs = self.kv_block_size,
            kpb = self.kv_pool_blocks,
            sb = self.serve_batch,
            ns = self.n_shards,
            eb = self.eval_batch,
            scb = self.score_batch,
            stl = self.score_text_len,
            params = params.join(", ")
        ))
    }

    /// Deterministic random weights (model.init_params conventions:
    /// gains one, biases zero, embeddings 0.02 sigma, matrices
    /// 1/sqrt(fan_in) sigma).
    pub fn weights(&self, manifest: &Manifest) -> crate::Result<Weights> {
        let mut rng = SplitMix64::new(self.seed);
        let tensors: Vec<Tensor> = self
            .param_spec()
            .into_iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data: Vec<f32> = if name.ends_with("_g") {
                    vec![1.0; n]
                } else if name.ends_with("_b") {
                    vec![0.0; n]
                } else {
                    let sigma = if name == "embed" || name == "pos_emb" {
                        0.02
                    } else {
                        1.0 / (shape[0] as f64).sqrt()
                    };
                    (0..n).map(|_| (sigma * gauss(&mut rng)) as f32).collect()
                };
                Tensor::new(shape, data)
            })
            .collect();
        Weights::from_tensors(manifest, tensors)
    }

    /// A corpus with the splits the drivers expect (calib, heldout),
    /// generated by the synwiki grammar at this vocab.
    pub fn corpus(&self, n_seqs: usize) -> Corpus {
        let mut corpus = Corpus::default();
        for (name, stream) in [
            ("calib", grammar::STREAM_CALIB),
            ("heldout", grammar::STREAM_HELDOUT),
        ] {
            let seqs = corpus_split(self.vocab, n_seqs, self.seq_len, stream,
                                    grammar::CORPUS_SEED);
            let tokens: Vec<i32> = seqs.into_iter().flatten().collect();
            corpus.splits.insert(
                name.to_string(),
                Split { n_seqs, seq_len: self.seq_len, tokens },
            );
        }
        corpus
    }

    /// A fully in-memory session on the reference backend: no artifact
    /// directory, no XLA.
    pub fn session(&self) -> crate::Result<Session> {
        self.session_with_client(Client::reference())
    }

    /// Like `session`, but on a caller-supplied client — chaos tests
    /// pass a fault-wrapped reference client here so injection stays
    /// scoped to one test without touching `CUSHION_FAULTS`.
    pub fn session_with_client(&self, client: Client) -> crate::Result<Session> {
        let manifest = self.manifest()?;
        let weights = self.weights(&manifest)?;
        let corpus = self.corpus(8);
        Session::from_parts(manifest, weights, corpus, client)
    }
}

/// Standard normal via Box-Muller over the SplitMix64 stream.
fn gauss(rng: &mut SplitMix64) -> f64 {
    let u1 = rng.next_f64().max(1e-12);
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_session_builds_without_artifacts() {
        let s = TinyCfg::default().session().unwrap();
        assert_eq!(s.manifest.vocab, 64);
        assert!(s.registry.client().is_reference());
        assert!(s.registry.has("decode_sampled_fp"), "interp inventory");
        assert!(!s.registry.has_artifact("decode_sampled_fp"));
        assert_eq!(s.corpus.split("calib").unwrap().seq_len, 16);
    }

    #[test]
    fn tiny_weights_follow_init_conventions() {
        let cfg = TinyCfg::default();
        let m = cfg.manifest().unwrap();
        let w = cfg.weights(&m).unwrap();
        assert!(w.get("layer0.ln1_g").unwrap().data.iter().all(|&v| v == 1.0));
        let emb = w.get("embed").unwrap();
        assert!(emb.absmax() < 0.2, "embedding sigma should be small");
        // deterministic across builds
        let w2 = cfg.weights(&m).unwrap();
        assert_eq!(w.get("embed").unwrap().data, w2.get("embed").unwrap().data);
    }
}
