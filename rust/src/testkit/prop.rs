//! A small property-testing harness (substrate for the absent proptest).
//!
//! Seeded generation + bounded shrinking: on failure the harness retries
//! the property on progressively "smaller" inputs derived by the
//! generator's `shrink` and reports the smallest failing case.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this offline image;
//! // the same example executes as a unit test below)
//! use cushioncache::testkit::prop::*;
//! check("reverse is an involution", 200, vec_f64(0..32, -1.0, 1.0), |xs| {
//!     let mut twice = xs.clone();
//!     twice.reverse();
//!     twice.reverse();
//!     twice == *xs
//! });
//! ```

use crate::util::prng::SplitMix64;

pub struct Gen<T> {
    pub sample: Box<dyn Fn(&mut SplitMix64) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

/// Run a property over `cases` random cases. Panics (test failure) with
/// the smallest failing case found.
pub fn check<T: std::fmt::Debug>(name: &str, cases: usize, gen: Gen<T>,
                                 prop: impl Fn(&T) -> bool) {
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = (gen.sample)(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink: greedy descent over the shrink candidates
        let mut smallest = input;
        let mut budget = 200;
        'outer: while budget > 0 {
            for cand in (gen.shrink)(&smallest) {
                budget -= 1;
                if !prop(&cand) {
                    smallest = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed (case {case}, seed {seed}):\n  input: {smallest:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

pub fn usize_in(range: std::ops::Range<usize>) -> Gen<usize> {
    let (lo, hi) = (range.start, range.end);
    Gen {
        sample: Box::new(move |r| lo + r.next_below((hi - lo) as u64) as usize),
        shrink: Box::new(move |&v| {
            let mut out = Vec::new();
            if v > lo {
                out.push(lo);
                out.push(lo + (v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        }),
    }
}

pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen {
        sample: Box::new(move |r| lo + r.next_f64() * (hi - lo)),
        shrink: Box::new(move |&v| {
            let mid = (lo + hi) / 2.0;
            if (v - mid).abs() > 1e-9 {
                vec![mid, (v + mid) / 2.0]
            } else {
                vec![]
            }
        }),
    }
}

pub fn vec_f64(len: std::ops::Range<usize>, lo: f64, hi: f64) -> Gen<Vec<f64>> {
    let (llo, lhi) = (len.start, len.end);
    Gen {
        sample: Box::new(move |r| {
            let n = llo + r.next_below((lhi - llo) as u64) as usize;
            (0..n).map(|_| lo + r.next_f64() * (hi - lo)).collect()
        }),
        shrink: Box::new(move |v| {
            let mut out = Vec::new();
            if v.len() > llo {
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[1..].to_vec());
            }
            if !v.is_empty() {
                let mut z = v.clone();
                z[0] = 0.0;
                out.push(z);
            }
            out
        }),
    }
}

pub fn vec_u32(len: std::ops::Range<usize>, max: u32) -> Gen<Vec<u32>> {
    let (llo, lhi) = (len.start, len.end);
    Gen {
        sample: Box::new(move |r| {
            let n = llo + r.next_below((lhi - llo).max(1) as u64) as usize;
            (0..n).map(|_| r.next_below(max as u64) as u32).collect()
        }),
        shrink: Box::new(move |v| {
            let mut out = Vec::new();
            if v.len() > llo {
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[1..].to_vec());
            }
            out
        }),
    }
}

/// Pair two generators.
pub fn pair<A: 'static + Clone + std::fmt::Debug, B: 'static + Clone + std::fmt::Debug>(
    a: Gen<A>, b: Gen<B>,
) -> Gen<(A, B)> {
    let (sa, sha) = (a.sample, a.shrink);
    let (sb, shb) = (b.sample, b.shrink);
    Gen {
        sample: Box::new(move |r| ((sa)(r), (sb)(r))),
        shrink: Box::new(move |(x, y)| {
            let mut out: Vec<(A, B)> = Vec::new();
            for xs in (sha)(x) {
                out.push((xs, y.clone()));
            }
            for ys in (shb)(y) {
                out.push((x.clone(), ys));
            }
            out
        }),
    }
}

#[cfg(test)]
mod quant_props {
    //! Interpreter quantization/selection properties (hermetic: pure
    //! host math, no backend).

    use super::*;
    use crate::eval::perplexity::argmax_rows;
    use crate::model::forward::{qdq_asym, select_tokens};
    use crate::quant::scheme::{Algorithm, Granularity, Scheme};

    fn schemes() -> Vec<Scheme> {
        let mut out = vec![Scheme::fp()];
        for gran in Granularity::ALL_QUANT {
            out.push(Scheme::w8a8(gran, Algorithm::Naive));
            out.push(Scheme::wnan(6, gran, Algorithm::Naive));
            out.push(Scheme::wnan(4, gran, Algorithm::Naive));
        }
        out
    }

    #[test]
    fn qdq_roundtrip_error_bounded_per_scheme() {
        // |x - qdq(x)| <= scale/2 for in-range x, for every scheme's
        // activation grid (the bound the paper's W8A8 analysis assumes).
        // The (|x|+1)*1e-6 term covers f32 arithmetic slop, which only
        // matters for the effectively-FP 2^24 grid where scale/2 is
        // below float resolution — there the bound degrades to
        // "identity within float noise", which is the right claim.
        for scheme in schemes() {
            let levels = scheme.act_levels();
            check(
                &format!("qdq roundtrip bound ({})", scheme.label()),
                120,
                vec_f64(1..64, -12.0, 12.0),
                |xs| {
                    if xs.is_empty() {
                        return true;
                    }
                    let mn = xs.iter().cloned().fold(0.0f64, f64::min) as f32;
                    let mx = xs.iter().cloned().fold(0.0f64, f64::max) as f32;
                    let scale = (mx - mn).max(1e-8) / levels;
                    xs.iter().all(|&x| {
                        let x = x as f32;
                        let err = (x - qdq_asym(x, mn, scale, levels)).abs();
                        err <= scale / 2.0 + (x.abs() + 1.0) * 1e-6
                    })
                },
            );
        }
    }

    #[test]
    fn qdq_zero_stays_in_range_and_near_grid() {
        // asymmetric ranges are clamped through min(mn,0)/max(mx,0) so 0
        // is always *in range*: qdq(0) can be off-grid by at most half a
        // step, never clipped to a range edge
        check("qdq(0) within half a step", 200,
              vec_f64(1..32, -5.0, 5.0), |xs| {
            let mn = xs.iter().cloned().fold(0.0f64, f64::min) as f32;
            let mx = xs.iter().cloned().fold(0.0f64, f64::max) as f32;
            let scale = (mx - mn).max(1e-8) / 255.0;
            qdq_asym(0.0, mn, scale, 255.0).abs() <= scale / 2.0 + 1e-6
        });
    }

    #[test]
    fn select_tokens_matches_host_argmax_rows_with_ties() {
        // device-side selection (select_tokens, in-graph on PJRT /
        // forward.rs on the interpreter) and the host fallback
        // (argmax_rows) must agree token-for-token — including on ties,
        // which both resolve to the lowest index. Coarse grid forces
        // plenty of exact ties.
        check("select_tokens == argmax_rows", 300,
              pair(usize_in(1..6), vec_f64(6..48, -4.0, 4.0)), |(v, xs)| {
            let v = *v + 1; // vocab >= 2
            let rows = xs.len() / v;
            if rows == 0 {
                return true;
            }
            let logits: Vec<f32> = xs[..rows * v]
                .iter()
                .map(|&x| (x * 2.0).round() as f32 / 2.0)
                .collect();
            let (ids, tops) = select_tokens(&logits, rows, v);
            let host = argmax_rows(&logits, rows, v);
            ids == host
                && ids.iter().enumerate().all(|(r, &id)| {
                    tops[r] == logits[r * v + id as usize]
                })
        });
    }
}

#[cfg(test)]
mod kvpool_props {
    //! Block-allocator invariants (coordinator::kvpool::BlockPool):
    //! alloc/free never double-assigns, refcounts never underflow, full
    //! churn restores the initial free count, and COW preserves the
    //! shared original.

    use super::*;
    use crate::coordinator::kvpool::{BlockDims, BlockPool};

    fn pool(n: usize) -> BlockPool {
        BlockPool::new(
            n,
            BlockDims { n_layers: 1, n_kv_heads: 1, d_head: 2, block_size: 2 },
        )
    }

    #[test]
    fn alloc_free_churn_preserves_pool_invariants() {
        check("block pool churn", 250, vec_u32(0..96, 4), |ops| {
            const N: usize = 8;
            let mut p = pool(N);
            let initial_free = p.free_blocks();
            let mut live: Vec<usize> = Vec::new(); // ids we hold one ref on
            for &op in ops {
                match op % 4 {
                    0 => {
                        if let Some(id) = p.alloc() {
                            // never double-assigned: a fresh block cannot
                            // already be live, and comes back zeroed
                            if live.contains(&id) {
                                return false;
                            }
                            if p.block(id).iter().any(|&v| v != 0.0) {
                                return false;
                            }
                            p.block_mut(id).fill(id as f32 + 1.0);
                            live.push(id);
                        } else if live.len() != N {
                            return false; // alloc failed with free blocks
                        }
                    }
                    1 => {
                        if let Some(id) = live.pop() {
                            if p.release(id).is_err() {
                                return false;
                            }
                        }
                    }
                    2 => {
                        // retain + release is a no-op pair
                        if let Some(&id) = live.first() {
                            p.retain(id);
                            if !matches!(p.release(id), Ok(false)) {
                                return false;
                            }
                        }
                    }
                    _ => {
                        // releasing a dead block must error, not underflow
                        let dead = (0..N).find(|id| !live.contains(id));
                        if let Some(id) = dead {
                            if p.ref_count(id) == 0 && p.release(id).is_ok() {
                                return false;
                            }
                        }
                    }
                }
                // conservation: free + live == total, and every live
                // block still carries its tag (no aliasing)
                if p.free_blocks() + live.len() != N {
                    return false;
                }
                if live
                    .iter()
                    .any(|&id| p.block(id).iter().any(|&v| v != id as f32 + 1.0))
                {
                    return false;
                }
            }
            // full churn: drain everything, free count returns to start
            while let Some(id) = live.pop() {
                if p.release(id).is_err() {
                    return false;
                }
            }
            p.free_blocks() == initial_free
        });
    }

    #[test]
    fn cow_preserves_the_shared_original() {
        check(
            "COW preserves source",
            150,
            pair(vec_f64(4..5, -9.0, 9.0), vec_f64(4..5, -9.0, 9.0)),
            |(orig, clobber)| {
                let mut p = pool(4);
                let shared = p.alloc().unwrap();
                for (dst, &v) in
                    p.block_mut(shared).iter_mut().zip(orig.iter())
                {
                    *dst = v as f32;
                }
                p.retain(shared); // second holder -> writers must COW
                let before: Vec<f32> = p.block(shared).to_vec();
                let copy = p.alloc().unwrap();
                p.copy_block(shared, copy);
                if !matches!(p.release(shared), Ok(false)) {
                    return false; // still one holder
                }
                for (dst, &v) in
                    p.block_mut(copy).iter_mut().zip(clobber.iter())
                {
                    *dst = v as f32;
                }
                p.block(shared) == before.as_slice()
                    && p.ref_count(copy) == 1
            },
        )
    }
}

#[cfg(test)]
mod shard_props {
    //! Tensor-parallel shard-plan invariants (runtime::collective):
    //! head/column assignments partition exactly, GQA groups are never
    //! split across shards, and invalid divisibility fails at manifest
    //! load — before any forward could run half-sharded.

    use super::*;
    use crate::runtime::collective::ShardPlan;
    use crate::testkit::tiny::TinyCfg;

    #[test]
    fn shard_assignments_partition_and_respect_gqa() {
        check(
            "shard plan partitions heads/columns",
            250,
            pair(
                pair(usize_in(1..7), usize_in(1..5)),
                pair(usize_in(1..13), usize_in(1..9)),
            ),
            |&((hkv, g), (ffq, n))| {
                let hq = hkv * g;
                let d_ff = ffq * 8;
                let valid = hkv % n == 0 && d_ff % n == 0;
                if ShardPlan::validate(hkv, d_ff, n).is_ok() != valid {
                    return false;
                }
                // manifest load must agree with the plan's validation
                let cfg = TinyCfg {
                    n_heads: hq,
                    n_kv_heads: hkv,
                    d_ff,
                    n_shards: n,
                    ..TinyCfg::default()
                };
                if cfg.manifest().is_ok() != valid {
                    return false;
                }
                if !valid {
                    return true;
                }
                // exact partition: every query head, KV head and MLP
                // column owned by exactly one shard
                let mut q_seen = vec![0usize; hq];
                let mut kv_seen = vec![0usize; hkv];
                let mut ff_seen = vec![0usize; d_ff];
                for k in 0..n {
                    let plan = ShardPlan::new(k, n);
                    let (q0, q1) = plan.q_range(hq, hkv);
                    let (k0, k1) = plan.kv_range(hkv);
                    let (f0, f1) = plan.ff_range(d_ff);
                    // a GQA group's query heads live with their KV head
                    if q0 != k0 * g || q1 != k1 * g {
                        return false;
                    }
                    for h in q0..q1 {
                        q_seen[h] += 1;
                    }
                    for h in k0..k1 {
                        kv_seen[h] += 1;
                    }
                    for c in f0..f1 {
                        ff_seen[c] += 1;
                    }
                }
                q_seen.iter().all(|&c| c == 1)
                    && kv_seen.iter().all(|&c| c == 1)
                    && ff_seen.iter().all(|&c| c == 1)
            },
        );
    }
}

#[cfg(test)]
mod chaos_props {
    //! End-to-end fault-recovery chaos property (runtime::faults + the
    //! scheduler's retry/requeue machinery): a batch served over an
    //! undersized pool under a seeded, randomized fault schedule must
    //! terminate with one response per request, fully restore the block
    //! pool, and — in fp mode, where preempt/resume re-prefill is
    //! bit-identical — produce exactly the fault-free token streams.

    use std::rc::Rc;

    use super::*;
    use crate::coordinator::{Engine, FinishReason, Request, Scheduler};
    use crate::quant::scheme::Scheme;
    use crate::runtime::backend::RefBackend;
    use crate::runtime::{faults, Client, FaultPlan, FaultyBackend};
    use crate::testkit::tiny::TinyCfg;

    struct ChaosRun {
        /// (id, finish, tokens) per request, id-sorted.
        outputs: Vec<(u64, FinishReason, Vec<i32>)>,
        pool_restored: bool,
        injected: u64,
    }

    /// Serve 4 requests over a 6-block pool (preemption guaranteed),
    /// optionally under `plan`. The plan is armed only for the serving
    /// phase — faulting the setup would abort in the `unwrap`s instead
    /// of exercising recovery.
    fn run_batch(plan: Option<FaultPlan>) -> ChaosRun {
        let cfg = TinyCfg { kv_pool_blocks: 6, ..TinyCfg::default() };
        let client =
            Client::with_backend(Rc::new(FaultyBackend::wrap(Rc::new(RefBackend))));
        let mut s = cfg.session_with_client(client).unwrap();
        s.set_cushion_tokens(&[crate::data::BOS, crate::data::DOT])
            .unwrap();
        let prompts: Vec<Vec<i32>> = (0..4)
            .map(|i| s.corpus.split("heldout").unwrap().seq(i)[..6].to_vec())
            .collect();
        let mut sched = Scheduler::new(Engine::new(s, Scheme::fp()).unwrap());
        let base_blocks = sched.engine.kv.blocks_in_use();
        if let Some(p) = plan {
            faults::arm(p);
        }
        for (i, p) in prompts.iter().enumerate() {
            let mut r = Request::new(1 + i as u64, p.clone(), 6);
            r.stop_token = None;
            sched.submit_request(r);
        }
        let mut outputs: Vec<(u64, FinishReason, Vec<i32>)> = sched
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.finished, r.tokens))
            .collect();
        outputs.sort_by_key(|(id, _, _)| *id);
        let injected = faults::disarm().map(|s| s.total()).unwrap_or(0);
        sched.engine.kv.clear_prefix_cache();
        let pool_restored = sched.engine.kv.blocks_in_use() == base_blocks
            && sched.engine.kv.free_count() == sched.engine.kv.n_slots;
        ChaosRun { outputs, pool_restored, injected }
    }

    #[test]
    fn chaos_transient_faults_recover_bit_identically() {
        let clean = run_batch(None);
        assert!(clean.pool_restored, "fault-free run must restore the pool");
        assert_eq!(clean.injected, 0);
        assert_eq!(clean.outputs.len(), 4);
        assert!(clean
            .outputs
            .iter()
            .all(|(_, f, _)| *f == FinishReason::MaxTokens));

        let any_injected = std::cell::Cell::new(false);
        check("chaos recovery", 8, usize_in(0..10_000), |&seed| {
            // transient-only schedule, capped so every case terminates;
            // the seed randomizes which engine calls fault
            let plan = FaultPlan::parse(&format!(
                "seed={seed},execute=0.15,upload=0.08,fetch=0.08,max=6"
            ))
            .unwrap();
            let run = run_batch(Some(plan));
            if run.injected > 0 {
                any_injected.set(true);
            }
            run.pool_restored && run.outputs == clean.outputs
        });
        assert!(
            any_injected.get(),
            "no case injected a fault — the schedule never exercised recovery"
        );
    }
}

#[cfg(test)]
mod replica_chaos_props {
    //! Replica fault-domain property (coordinator::router + the
    //! whole-replica kill fault): under a randomized kill schedule —
    //! which replica dies, and after how many engine calls — the router
    //! must terminate with *exactly one* response per submitted request
    //! id: none lost, none duplicated, no assignment left dangling.
    //! Where the kill lands (mid-prefill, mid-decode, while preempted)
    //! varies with the countdown; the id-conservation invariant must
    //! not.

    use std::rc::Rc;

    use super::*;
    use crate::coordinator::{Engine, Request, Router, Scheduler};
    use crate::quant::scheme::Scheme;
    use crate::runtime::backend::RefBackend;
    use crate::runtime::{faults, Client, FaultPlan, FaultyBackend};
    use crate::testkit::tiny::TinyCfg;

    /// One replica over an undersized 6-block pool (preemption in play)
    /// on the fault-injecting backend.
    fn replica() -> Scheduler {
        let cfg = TinyCfg { kv_pool_blocks: 6, ..TinyCfg::default() };
        let client =
            Client::with_backend(Rc::new(FaultyBackend::wrap(Rc::new(RefBackend))));
        let s = cfg.session_with_client(client).unwrap();
        Scheduler::new(Engine::new(s, Scheme::fp()).unwrap())
    }

    #[test]
    fn chaos_replica_kills_never_lose_or_duplicate_requests() {
        check(
            "replica kills conserve request ids",
            6,
            pair(usize_in(0..3), usize_in(1..40)),
            |&(victim, kill_after)| {
                let mut r = Router::with_seed(0xD00D);
                r.add_engine("fp", replica());
                r.add_engine("fp", replica());
                let prompts: Vec<Vec<i32>> = (0..6)
                    .map(|i| {
                        r.replica(0).engine.session.corpus.split("heldout")
                            .unwrap()
                            .seq(i)[..6]
                            .to_vec()
                    })
                    .collect();
                // victim 2 = the no-kill control case
                if victim < 2 {
                    faults::arm(
                        FaultPlan::parse(&format!(
                            "seed=1,replica={victim},kill_replica_after={kill_after}"
                        ))
                        .unwrap(),
                    );
                }
                for (i, p) in prompts.iter().enumerate() {
                    let mut req = Request::new(1 + i as u64, p.clone(), 4);
                    req.stop_token = None;
                    r.route("fp", req).unwrap();
                }
                let out = r.run_to_completion().unwrap();
                faults::disarm();
                let mut ids: Vec<u64> = out.iter().map(|x| x.id).collect();
                ids.sort_unstable();
                ids == (1..=6).collect::<Vec<u64>>()
                    && r.pending_assignments() == 0
                    && !r.has_work()
            },
        );
    }
}

#[cfg(test)]
mod slo_props {
    //! SLO-accounting invariants (coordinator::metrics::SloMetrics):
    //! per-class percentiles are ordered (p50 <= p99 for both TTFT and
    //! TPOT), goodput lives in [0, 1] and is monotone in the deadline —
    //! tightening it (turning more finishes into deadline errors) can
    //! never raise goodput — and the worst-across-classes p99 gauges
    //! never drop when a strictly slower sample lands.

    use super::*;
    use crate::coordinator::metrics::SloMetrics;
    use crate::coordinator::{FinishReason, Response};

    fn response(id: u64, ttft: f64, tpot: Vec<f64>, good: bool) -> Response {
        Response {
            id,
            tokens: vec![0; tpot.len() + 1],
            ttft: Some(ttft),
            tpot,
            finished: if good {
                FinishReason::MaxTokens
            } else {
                FinishReason::Error("deadline".into())
            },
            echo_text: false,
        }
    }

    /// Record `lat` as alternating short/long responses, good iff the
    /// TTFT met `deadline`.
    fn fill(slo: &mut SloMetrics, lat: &[f64], deadline: f64) {
        for (i, &t) in lat.iter().enumerate() {
            let class = if i % 2 == 0 { "short" } else { "long" };
            let r = response(1 + i as u64, t, vec![t / 2.0, t], t <= deadline);
            slo.record(class, &r);
        }
    }

    #[test]
    fn slo_percentiles_ordered_and_goodput_monotone_in_deadline() {
        check(
            "slo p50 <= p99, goodput monotone in deadline",
            200,
            pair(
                vec_f64(1..24, 0.0, 0.050),
                pair(f64_in(0.0, 0.050), f64_in(0.0, 0.050)),
            ),
            |(lat, (d1, d2))| {
                let (tight, loose) = if d1 <= d2 { (*d1, *d2) } else { (*d2, *d1) };
                let goodput_at = |deadline: f64| -> f64 {
                    let mut slo = SloMetrics::new();
                    fill(&mut slo, lat, deadline);
                    for s in slo.summary() {
                        if s.ttft_p50 > s.ttft_p99 + 1e-12
                            || s.tpot_p50 > s.tpot_p99 + 1e-12
                        {
                            return f64::NAN; // ordering violated
                        }
                    }
                    slo.goodput()
                };
                let g_tight = goodput_at(tight);
                let g_loose = goodput_at(loose);
                (0.0..=1.0).contains(&g_tight)
                    && (0.0..=1.0).contains(&g_loose)
                    && g_tight <= g_loose + 1e-12
            },
        );
    }

    #[test]
    fn slo_worst_gauges_never_drop_when_a_slower_sample_lands() {
        // percentile() interpolates linearly, so only a sample at or
        // above the current maximum is guaranteed not to pull p99 down —
        // which is exactly the shape a straggler request has
        check(
            "p99 gauges monotone under a dominating sample",
            200,
            vec_f64(1..24, 0.0, 0.050),
            |lat| {
                let gauges = |extra: Option<(f64, f64)>| -> (f64, f64) {
                    let mut slo = SloMetrics::new();
                    fill(&mut slo, lat, f64::INFINITY);
                    if let Some((ttft, tpot)) = extra {
                        let r = response(99, ttft, vec![tpot], true);
                        slo.record("short", &r);
                    }
                    (slo.ttft_p99(), slo.tpot_p99())
                };
                let (t0, p0) = gauges(None);
                let worst = lat.iter().cloned().fold(0.0, f64::max);
                let (t1, p1) = gauges(Some((worst + 0.010, worst + 0.010)));
                t1 + 1e-12 >= t0 && p1 + 1e-12 >= p0
            },
        );
    }
}

#[cfg(test)]
mod trace_props {
    //! Observability invariants (runtime::trace + coordinator::telemetry):
    //! a bounded ring under arbitrary begin/end/instant interleavings
    //! never drops an open span's close record, every export round-trips
    //! through util::json and passes `check_export`, and Prometheus
    //! exposition lines parse back to the exact gauge values rendered
    //! (f64 `Display` is shortest-round-trip, so equality is exact).

    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::telemetry;
    use crate::runtime::trace;
    use crate::util::json;

    const CAP: usize = 4;

    /// Replay `ops` against a tiny (capacity [`CAP`]) ring: op%3 == 0
    /// begins a span, 1 ends the deepest open one, 2 emits an instant.
    /// Every span still open after the ops is closed at the end.
    /// Returns (spans closed, instants emitted, spans closed by the
    /// final drain).
    fn replay(ops: &[u32]) -> (usize, usize, usize) {
        trace::enable(CAP);
        let mut open = Vec::new();
        let mut closed = 0usize;
        let mut instants = 0usize;
        for (i, &op) in ops.iter().enumerate() {
            match op % 3 {
                0 => open.push(trace::begin(
                    "span",
                    "prop",
                    Some(i as u64),
                    &[("i", i.to_string())],
                )),
                1 => {
                    if let Some(tok) = open.pop() {
                        trace::end(tok, &[]);
                        closed += 1;
                    }
                }
                _ => {
                    trace::instant("tick", "prop", None, &[("i", i.to_string())]);
                    instants += 1;
                }
            }
        }
        let drained = open.len();
        while let Some(tok) = open.pop() {
            trace::end(tok, &[]);
            closed += 1;
        }
        (closed, instants, drained)
    }

    #[test]
    fn ring_never_orphans_an_open_span_and_exports_check_clean() {
        check("trace ring close-preservation", 150, vec_u32(0..48, 9), |ops| {
            let (closed, instants, drained) = replay(ops);
            let records = trace::records();
            let total = closed + instants;
            // bounded ring accounting: the newest min(total, CAP)
            // records survive, the rest are counted dropped
            let ok_len = records.len() == total.min(CAP);
            let ok_dropped =
                trace::dropped() == (total as u64).saturating_sub(CAP as u64);
            // close-preservation: spans open through arbitrary instant
            // flooding still land their close — the final drain's closes
            // are the newest pushes, so they are all in the ring
            let tail = drained.min(CAP).min(records.len());
            let ok_tail = records[records.len() - tail..]
                .iter()
                .all(|r| r.ph == trace::Phase::Complete);
            let ok_open = trace::open_spans() == 0;
            let export_ok = trace::check_export(&trace::export_string()).is_ok();
            trace::disable();
            ok_len && ok_dropped && ok_tail && ok_open && export_ok
        });
    }

    #[test]
    fn trace_export_round_trips_through_util_json() {
        check("trace export json round-trip", 100, vec_u32(0..32, 9), |ops| {
            replay(ops);
            let n_records = trace::records().len();
            let text = trace::export_string();
            trace::disable();
            let Ok(v) = json::parse(&text) else { return false };
            let Some(events) = v.get("traceEvents").and_then(|e| e.as_arr())
            else {
                return false;
            };
            // one event per surviving record, each with the fields
            // check_export demands — and re-serializing parses again
            let Ok(checked) = trace::check_export(&text) else { return false };
            events.len() == n_records
                && checked == n_records
                && json::parse(&v.to_string()).is_ok()
        });
    }

    #[test]
    fn prometheus_exposition_round_trips_gauge_values() {
        check(
            "prometheus render -> parse is exact",
            120,
            pair(vec_f64(1..16, 0.0, 0.2), usize_in(0..40)),
            |(lat, count)| {
                let mut m = Metrics::new();
                for (i, &dt) in lat.iter().enumerate() {
                    m.record_decode(
                        dt,
                        1 + i % 3,
                        Default::default(),
                        Default::default(),
                        0.0,
                    );
                }
                m.completed = *count;
                m.tokens_out = count * 7;
                m.record_act_sample(trace::ActSample {
                    absmax: lat[0] as f32 * 100.0,
                    clipped: *count as u64,
                    total: 4096,
                });
                let labels = [("mode", "FP16".to_string()), ("replica", "0".to_string())];
                let text = telemetry::render_metrics(&m, &labels);
                let Ok(samples) = telemetry::parse_prometheus(&text) else {
                    return false;
                };
                let want = [("mode", "FP16"), ("replica", "0")];
                let find = |name: &str| telemetry::find_sample(&samples, name, &want);
                find("cushion_requests_completed") == Some(*count as f64)
                    && find("cushion_tokens_out") == Some((count * 7) as f64)
                    && find("cushion_decode_p50_seconds")
                        == Some(m.decode_percentile(50.0))
                    && find("cushion_decode_p99_seconds")
                        == Some(m.decode_percentile(99.0))
                    && find("cushion_act_absmax") == Some(m.act_absmax as f64)
                    && find("cushion_act_clip_rate") == Some(m.act_clip_rate())
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("reverse twice is identity", 100, vec_u32(0..20, 100), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics() {
        check("always false", 10, usize_in(0..5), |_| false);
    }

    #[test]
    fn shrinking_finds_small_case() {
        // capture the failing case via catch_unwind on the panic message
        let res = std::panic::catch_unwind(|| {
            check("len < 5", 100, vec_u32(0..40, 9), |v| v.len() < 5)
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // shrunk case should be close to the boundary (len 5..9)
        let n = msg.matches(',').count() + 1;
        assert!(n <= 10, "shrunk case too large: {msg}");
    }
}
