//! Testing substrates: a property-testing harness (the offline vendor
//! has no proptest) and an artifact-free tiny model for hermetic tests.

pub mod prop;
pub mod tiny;
