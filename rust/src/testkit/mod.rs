//! Testing substrates (the offline vendor has no proptest).

pub mod prop;
