//! CushionCache: prefixing attention sinks to mitigate activation outliers
//! for LLM quantization (Son et al., EMNLP 2024) — full-system reproduction.
//!
//! Three-layer architecture (see DESIGN.md):
//! * L1/L2 (python, build-time only): Pallas kernels + JAX model variants,
//!   AOT-lowered to HLO text under `artifacts/`.
//! * L3 (this crate): the runtime — graph execution behind
//!   `runtime::backend::Backend` (PJRT over the AOT artifacts, or the
//!   pure-Rust reference interpreter `runtime::interp` +
//!   `model::forward`, which needs neither artifacts nor XLA — see
//!   README "Backends"), quantization calibration and weight-side
//!   transforms, the CushionCache greedy search + prefix tuning drivers,
//!   the serving coordinator, the eval harness, and the benchmark suite
//!   regenerating every table/figure of the paper.
//!
//! Entry points: the `cushiond` binary (`rust/src/main.rs`), the runnable
//! `examples/`, and the `benches/` (one per paper table/figure).

pub mod bench;
pub mod coordinator;
pub mod cushion;
pub mod data;
pub mod eval;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod testkit;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
