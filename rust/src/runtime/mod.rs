//! PJRT runtime: load AOT HLO-text artifacts, compile them on the CPU
//! PJRT client, and execute them from the coordinator's hot path.
//!
//! Flow (see /opt/xla-example and DESIGN.md §2):
//!   HLO text --HloModuleProto::from_text_file--> XlaComputation
//!            --client.compile--> PjRtLoadedExecutable
//!            --execute_b(device buffers)--> output buffers
//!
//! Everything big (weights, KV cache) lives as device buffers; only small
//! outputs (token ids, logits, losses) are fetched to the host per call.
//! Operands are `literalx::Value`s — per-call host data or device-resident
//! buffers (model::resident::ResidentPool caches the loop-invariant ones);
//! tuple-shaped results decompose into per-output device buffers via
//! `split::TupleSplitter` so pass-through state never materializes on the
//! host — and every host<->device crossing is metered by `transfer`.

pub mod client;
pub mod executable;
pub mod literalx;
pub mod registry;
pub mod split;
pub mod transfer;

pub use client::Client;
pub use executable::Executable;
pub use literalx::{HostValue, IntTensor, OutValue, Outputs, Value};
pub use registry::Registry;
pub use split::{DType, OutSpec, TupleSplitter};
pub use transfer::TransferStats;
