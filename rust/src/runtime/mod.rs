//! Runtime: execute a variant's graphs on one of two backends behind the
//! `Backend` trait — the PJRT client over AOT HLO-text artifacts (`xla`
//! feature), or the pure-Rust reference interpreter (`interp` +
//! `model::forward`), which needs no artifacts and no XLA toolchain.
//!
//! PJRT flow (see /opt/xla-example and DESIGN.md §2):
//!   HLO text --HloModuleProto::from_text_file--> XlaComputation
//!            --client.compile--> PjRtLoadedExecutable
//!            --execute_b(device buffers)--> output buffers
//!
//! Everything big (weights, KV cache) lives as backend-resident
//! `DeviceBuf`s; only small outputs (token ids, logits, losses) are
//! fetched to the host per call. Operands are `literalx::Value`s —
//! per-call host data or resident buffers (model::resident::ResidentPool
//! caches the loop-invariant ones); on PJRT, tuple-shaped results
//! decompose into per-output device buffers via `split::TupleSplitter`
//! so pass-through state never materializes on the host — and every
//! host<->device crossing is metered by `transfer` on both backends.
//!
//! Backend selection and the per-graph interpreter fallback are
//! documented in `backend` and `registry` respectively.

pub mod backend;
pub mod client;
pub mod collective;
pub mod executable;
pub mod faults;
pub mod interp;
pub mod literalx;
pub mod registry;
pub mod split;
pub mod trace;
pub mod transfer;

pub use backend::{Backend, BackendKind, DeviceBuf};
pub use client::Client;
pub use collective::{CollectiveBus, CollectiveStats, DeviceGroup, ShardPlan};
pub use faults::{FaultPlan, FaultyBackend};
pub use executable::Executable;
pub use literalx::{HostValue, IntTensor, OutValue, Outputs, Value};
pub use registry::Registry;
pub use split::{DType, OutSpec, TupleSplitter};
pub use transfer::TransferStats;
