//! A compiled artifact: HLO text -> XlaComputation -> PjRtLoadedExecutable,
//! with buffer-level execution so large state stays on device.

use std::path::Path;
use std::time::Instant;

use super::client::Client;
use super::literalx::{self, HostValue, Outputs};
use crate::util::tensor::Tensor;

pub struct Executable {
    pub name: String,
    client: Client,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative (calls, seconds) — feeds the coordinator metrics.
    pub calls: std::sync::atomic::AtomicU64,
    pub nanos: std::sync::atomic::AtomicU64,
}

impl Executable {
    /// Load + compile an HLO-text artifact.
    pub fn load(client: &Client, name: &str, path: &Path) -> crate::Result<Self> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .raw()
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        log::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(Self {
            name: name.to_string(),
            client: client.clone(),
            exe,
            calls: 0.into(),
            nanos: 0.into(),
        })
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    /// Upload a host value to a device buffer.
    pub fn upload(&self, v: &HostValue) -> crate::Result<xla::PjRtBuffer> {
        self.client.upload_host(v)
    }

    /// Execute on device buffers; returns one buffer per graph output.
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> crate::Result<Vec<xla::PjRtBuffer>> {
        let t0 = Instant::now();
        let mut out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.nanos.fetch_add(
            t0.elapsed().as_nanos() as u64,
            std::sync::atomic::Ordering::Relaxed,
        );
        anyhow::ensure!(!out.is_empty(), "no replica outputs from {}", self.name);
        Ok(out.swap_remove(0))
    }

    /// Execute on device buffers; outputs stay in runtime form so callers
    /// fetch only what they need (see literalx::Outputs).
    pub fn run_outputs(&self, args: &[&xla::PjRtBuffer]) -> crate::Result<Outputs> {
        Outputs::from_execute(self.run_buffers(args)?)
    }

    /// Execute on device buffers, decomposing a tuple-shaped result into
    /// per-output *device* buffers via `splitter` (runtime::split) — the
    /// hot-path variant where pass-through state (the serving KV cache)
    /// must never materialize on the host.
    pub fn run_outputs_with(
        &self,
        args: &[&xla::PjRtBuffer],
        splitter: Option<&crate::runtime::split::TupleSplitter>,
    ) -> crate::Result<Outputs> {
        Outputs::from_execute_split(self.run_buffers(args)?, splitter)
    }

    /// Convenience: upload host args, execute, fetch all outputs as f32.
    pub fn run_host(&self, args: &[HostValue]) -> crate::Result<Vec<Tensor>> {
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|a| self.upload(a))
            .collect::<crate::Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = self.run_buffers(&refs)?;
        literalx::fetch_all_f32(outs)
    }

    pub fn mean_call_seconds(&self) -> f64 {
        let calls = self.calls.load(std::sync::atomic::Ordering::Relaxed);
        if calls == 0 {
            return 0.0;
        }
        let nanos = self.nanos.load(std::sync::atomic::Ordering::Relaxed);
        nanos as f64 / 1e9 / calls as f64
    }
}
