//! A resolved graph: either a compiled PJRT artifact (HLO text ->
//! XlaComputation -> PjRtLoadedExecutable, `xla` feature) or a reference
//! interpreter program (`runtime::interp`). Execution dispatches on the
//! program form, so one `Session`/`Engine` can mix both — the registry
//! resolves per graph, and a missing artifact degrades to the
//! interpreter instead of failing.

use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use super::backend::DeviceBuf;
use super::client::Client;
use super::interp::InterpProgram;
use super::literalx::{HostValue, Outputs};
use crate::util::tensor::Tensor;

/// The executable form of a graph.
pub enum Program {
    /// A compiled PJRT executable (the artifact path).
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtLoadedExecutable),
    /// A reference-interpreter program (the hermetic path).
    Interp(InterpProgram),
}

pub struct Executable {
    pub name: String,
    client: Client,
    program: Program,
    /// Cumulative (calls, seconds) — feeds the coordinator metrics.
    pub calls: std::sync::atomic::AtomicU64,
    pub nanos: std::sync::atomic::AtomicU64,
}

impl Executable {
    /// Load + compile an HLO-text artifact (PJRT clients only).
    #[cfg(feature = "xla")]
    pub fn load(client: &Client, name: &str, path: &Path) -> crate::Result<Self> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .raw()?
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        log::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(Self::from_program(client, name, Program::Pjrt(exe)))
    }

    #[cfg(not(feature = "xla"))]
    pub fn load(_client: &Client, name: &str, path: &Path) -> crate::Result<Self> {
        anyhow::bail!(
            "cannot load artifact {name} from {path:?}: built without the \
             `xla` feature (the reference interpreter resolves graphs by \
             name instead)"
        )
    }

    /// Wrap a resolved program (the interpreter path goes through here).
    pub fn from_program(client: &Client, name: &str, program: Program) -> Self {
        Self {
            name: name.to_string(),
            client: client.clone(),
            program,
            calls: 0.into(),
            nanos: 0.into(),
        }
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    /// Whether this graph executes on the reference interpreter.
    pub fn is_interp(&self) -> bool {
        matches!(self.program, Program::Interp(_))
    }

    /// Upload a host value into backend residency.
    pub fn upload(&self, v: &HostValue) -> crate::Result<DeviceBuf> {
        self.client.upload_host(v)
    }

    /// Execute on raw PJRT buffers; returns one buffer per graph output
    /// (the tuple-splitter building block).
    #[cfg(feature = "xla")]
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> crate::Result<Vec<xla::PjRtBuffer>> {
        let Program::Pjrt(exe) = &self.program else {
            anyhow::bail!("{}: run_buffers on an interpreter program", self.name);
        };
        let t0 = Instant::now();
        let mut out = exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        self.note_call(t0);
        anyhow::ensure!(!out.is_empty(), "no replica outputs from {}", self.name);
        Ok(out.swap_remove(0))
    }

    fn note_call(&self, t0: Instant) {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.nanos.fetch_add(
            t0.elapsed().as_nanos() as u64,
            std::sync::atomic::Ordering::Relaxed,
        );
    }

    /// Execute on resident operands; outputs stay in runtime form so
    /// callers fetch only what they need (see literalx::Outputs). The
    /// splitter (when given, PJRT only) decomposes a tuple-shaped result
    /// into per-output *device* buffers — the hot-path variant where
    /// pass-through state (the serving KV cache) must never materialize
    /// on the host. Interpreter programs ignore it: their outputs are
    /// already per-element.
    pub fn run_values(
        &self,
        args: &[Rc<DeviceBuf>],
        splitter: Option<&super::split::TupleSplitter>,
    ) -> crate::Result<Outputs> {
        match &self.program {
            #[cfg(feature = "xla")]
            Program::Pjrt(_) => {
                // upload any host-resident operand (state produced by an
                // interpreter-resolved graph in a mixed artifact dir) so
                // per-graph degradation keeps serving
                let mut uploaded: Vec<DeviceBuf> = Vec::new();
                let mut slot: Vec<Option<usize>> = Vec::with_capacity(args.len());
                for a in args {
                    match a.as_ref() {
                        DeviceBuf::Pjrt(_) => slot.push(None),
                        DeviceBuf::Host(v) => {
                            uploaded.push(self.client.upload_host(v)?);
                            slot.push(Some(uploaded.len() - 1));
                        }
                    }
                }
                let mut refs = Vec::with_capacity(args.len());
                for (a, ix) in args.iter().zip(&slot) {
                    let buf = match ix {
                        Some(i) => &uploaded[*i],
                        None => a.as_ref(),
                    };
                    match buf {
                        DeviceBuf::Pjrt(b) => refs.push(b),
                        DeviceBuf::Host(_) => anyhow::bail!(
                            "{}: upload did not produce a PJRT buffer",
                            self.name
                        ),
                    }
                }
                Outputs::from_execute_split(self.run_buffers(&refs)?, splitter)
            }
            Program::Interp(ip) => {
                let _ = splitter;
                let t0 = Instant::now();
                // host-ify operands: reference-backend residency is free;
                // a PJRT-resident operand (mixed fallback) pays one fetch
                let mut host: Vec<HostValue> = Vec::with_capacity(args.len());
                for a in args {
                    match a.as_ref() {
                        DeviceBuf::Host(v) => host.push(v.clone()),
                        #[cfg(feature = "xla")]
                        DeviceBuf::Pjrt(b) => {
                            // the element type is only known on device:
                            // materialize the literal once, convert by
                            // type, meter the single crossing
                            let lit = b.to_literal_sync().map_err(|e| {
                                anyhow::anyhow!("to_literal: {e:?}")
                            })?;
                            let hv = match super::literalx::literal_f32(&lit) {
                                Ok(t) => HostValue::F32(t),
                                Err(_) => HostValue::I32(
                                    super::literalx::literal_i32(&lit)?,
                                ),
                            };
                            super::transfer::note_fetch(4 * hv.elems());
                            host.push(hv);
                        }
                    }
                }
                let outs = ip.execute(&host)?;
                self.note_call(t0);
                Ok(Outputs::from_host(outs))
            }
        }
    }

    /// Convenience: upload host args, execute, fetch all outputs as f32.
    pub fn run_host(&self, args: &[HostValue]) -> crate::Result<Vec<Tensor>> {
        let bufs: Vec<Rc<DeviceBuf>> = args
            .iter()
            .map(|a| Ok(Rc::new(self.upload(a)?)))
            .collect::<crate::Result<_>>()?;
        self.run_values(&bufs, None)?.into_tensors()
    }

    pub fn mean_call_seconds(&self) -> f64 {
        let calls = self.calls.load(std::sync::atomic::Ordering::Relaxed);
        if calls == 0 {
            return 0.0;
        }
        let nanos = self.nanos.load(std::sync::atomic::Ordering::Relaxed);
        nanos as f64 / 1e9 / calls as f64
    }
}
