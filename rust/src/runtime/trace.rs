//! Structured tracing for the serving runtime: a bounded, lock-cheap,
//! thread-local ring buffer of typed span/instant records with
//! monotonic timestamps, exportable as Chrome-trace-format JSON
//! (`chrome://tracing` / Perfetto — see README "Observability").
//!
//! Design constraints, in priority order:
//!
//! * **Deterministic identity.** Everything a hermetic test asserts on —
//!   `seq`, `name`, `cat`, `ph`, `trace_id`, `replica`, `args` — is
//!   derived from program order under a fixed seed, never from
//!   wall-clock. Timestamps (`ts_us`/`dur_us`) exist only so the export
//!   renders on a real time axis; they are presentation, not identity.
//! * **Lock-cheap.** The whole serve path (scheduler, router, server
//!   step loop, benches, hermetic tests) emits from one thread, so the
//!   buffer is `thread_local` (the same isolation idiom as
//!   `runtime::faults`): no mutex, no atomics on the emit path, and a
//!   disabled tracer costs exactly one `Cell<bool>` read. Shard worker
//!   threads do not emit; per-step shard skew is recorded on the driver
//!   thread at the end of `DeviceGroup::run`, which is where the skew
//!   instant comes from.
//! * **Bounded, close-preserving.** The ring drops *oldest* records on
//!   overflow (counted in `dropped()`), but a span close is never
//!   rejected: `begin` parks the span in a side table that the ring's
//!   eviction cannot touch, and `end` always lands its `Complete`
//!   record — the `testkit::prop` trace properties pin this.
//!
//! The replica label is read from `faults::current_replica()` at record
//! time, so router-bracketed engine work is attributed to its replica
//! with zero router plumbing.
//!
//! Activation-health sampling (the paper loop-closer) also lives here:
//! the scheduler arms `act_begin()` every Nth decode step, the
//! interpreter's quantization hot path (`model::forward::QuantCtx`)
//! notes per-site absmax/clip counts behind a single `Cell<bool>`
//! check, and `act_end()` hands the step's aggregate back to the
//! scheduler for the `cushion_act_*` gauges.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::time::Instant;

use crate::util::json::{self, Value};

/// Default ring capacity (records). At ~8 events per scheduler step
/// this holds a few thousand steps — far past any hermetic run.
pub const DEFAULT_CAPACITY: usize = 16384;

/// Chrome-trace phase of a record: a point event or a closed span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `ph: "i"` — an instantaneous event.
    Instant,
    /// `ph: "X"` — a complete (begin..end) span with a duration.
    Complete,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Instant => "i",
            Phase::Complete => "X",
        }
    }
}

/// One trace record. Identity (what tests assert) is `seq`, `name`,
/// `cat`, `ph`, `trace_id`, `replica`, `args`; the `*_us` fields are
/// monotonic presentation timestamps relative to `enable()`.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    /// Emission-order sequence number, reset by `enable()`/`clear()`.
    /// Assigned at `begin`/`instant` time; a span's record lands in the
    /// ring at `end`, so ring order is *push* order and interleaved
    /// traces are seq-non-monotonic there. `chrome_json` sorts by seq,
    /// making the export strictly increasing (`trace-check` validates).
    pub seq: u64,
    pub name: String,
    pub cat: &'static str,
    pub ph: Phase,
    /// The request this record belongs to (`RequestId`), if any.
    pub trace_id: Option<u64>,
    /// Replica index (`faults::current_replica()` at record time).
    pub replica: Option<usize>,
    /// Microseconds since `enable()` (begin time for spans).
    pub ts_us: u64,
    /// Span duration in microseconds (0 for instants).
    pub dur_us: u64,
    /// Small typed payload, insertion-ordered.
    pub args: Vec<(String, String)>,
}

/// Handle returned by [`begin`]; pass to [`end`] to close the span.
/// Deliberately not `Copy`/`Clone`: one begin, one end.
#[derive(Debug)]
#[must_use = "an unclosed span never reaches the trace"]
pub struct SpanToken(u64);

/// A span that has begun but not ended. Lives in a side table outside
/// the ring, so ring eviction can never orphan it.
struct OpenSpan {
    token: u64,
    seq: u64,
    name: String,
    cat: &'static str,
    trace_id: Option<u64>,
    replica: Option<usize>,
    t0: Instant,
    ts_us: u64,
    args: Vec<(String, String)>,
}

struct TraceState {
    cap: usize,
    epoch: Instant,
    next_seq: u64,
    next_token: u64,
    ring: VecDeque<Record>,
    open: Vec<OpenSpan>,
    dropped: u64,
}

/// Aggregate of one sampled decode step's quantization-site activity:
/// the max |x| seen across all sites and the clipped/total element
/// counts against the static quantization ranges (pts; dynamic modes
/// clip nothing by construction, so their clip rate is structurally 0).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ActSample {
    pub absmax: f32,
    pub clipped: u64,
    pub total: u64,
}

impl ActSample {
    pub fn clip_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.clipped as f64 / self.total as f64
        }
    }
}

thread_local! {
    /// Fast-path gate: one Cell read decides whether emit helpers touch
    /// the RefCell at all.
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    /// Fast-path gate for the quantization hot loop: set for the
    /// duration of a sampled decode step only.
    static ACT_ACTIVE: Cell<bool> = const { Cell::new(false) };
    static STATE: RefCell<Option<TraceState>> = const { RefCell::new(None) };
    static ACT: RefCell<ActSample> = const { RefCell::new(ActSample {
        absmax: 0.0,
        clipped: 0,
        total: 0,
    }) };
}

/// Turn tracing on for this thread with a ring of `cap` records
/// (`0` → [`DEFAULT_CAPACITY`]). Resets sequence numbers, the ring,
/// open spans, and the timestamp epoch.
pub fn enable(cap: usize) {
    let cap = if cap == 0 { DEFAULT_CAPACITY } else { cap };
    STATE.with(|s| {
        *s.borrow_mut() = Some(TraceState {
            cap,
            epoch: Instant::now(),
            next_seq: 0,
            next_token: 0,
            ring: VecDeque::with_capacity(cap.min(1024)),
            open: Vec::new(),
            dropped: 0,
        });
    });
    ENABLED.with(|e| e.set(true));
}

/// Turn tracing off and discard all state (ring and open spans).
pub fn disable() {
    ENABLED.with(|e| e.set(false));
    ACT_ACTIVE.with(|a| a.set(false));
    STATE.with(|s| *s.borrow_mut() = None);
}

pub fn enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Drop every recorded event but keep tracing enabled (sequence
/// numbers and the epoch restart, so identity stays deterministic).
pub fn clear() {
    if !enabled() {
        return;
    }
    STATE.with(|s| {
        if let Some(st) = s.borrow_mut().as_mut() {
            st.ring.clear();
            st.open.clear();
            st.next_seq = 0;
            st.next_token = 0;
            st.dropped = 0;
            st.epoch = Instant::now();
        }
    });
}

fn push(st: &mut TraceState, rec: Record) {
    if st.ring.len() >= st.cap {
        st.ring.pop_front();
        st.dropped += 1;
    }
    st.ring.push_back(rec);
}

/// Emit an instantaneous event. No-op when tracing is disabled.
pub fn instant(
    name: &str,
    cat: &'static str,
    trace_id: Option<u64>,
    args: &[(&str, String)],
) {
    if !enabled() {
        return;
    }
    let replica = super::faults::current_replica();
    STATE.with(|s| {
        if let Some(st) = s.borrow_mut().as_mut() {
            let seq = st.next_seq;
            st.next_seq += 1;
            let ts_us = st.epoch.elapsed().as_micros() as u64;
            let args =
                args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
            push(
                st,
                Record {
                    seq,
                    name: name.to_string(),
                    cat,
                    ph: Phase::Instant,
                    trace_id,
                    replica,
                    ts_us,
                    dur_us: 0,
                    args,
                },
            );
        }
    });
}

/// Open a span. The span is parked in the open-span side table (immune
/// to ring eviction) until [`end`] lands its `Complete` record. When
/// tracing is disabled the returned token is inert.
pub fn begin(
    name: &str,
    cat: &'static str,
    trace_id: Option<u64>,
    args: &[(&str, String)],
) -> SpanToken {
    if !enabled() {
        return SpanToken(u64::MAX);
    }
    let replica = super::faults::current_replica();
    STATE.with(|s| {
        let mut b = s.borrow_mut();
        let Some(st) = b.as_mut() else { return SpanToken(u64::MAX) };
        let seq = st.next_seq;
        st.next_seq += 1;
        let token = st.next_token;
        st.next_token += 1;
        let now = Instant::now();
        st.open.push(OpenSpan {
            token,
            seq,
            name: name.to_string(),
            cat,
            trace_id,
            replica,
            t0: now,
            ts_us: now.duration_since(st.epoch).as_micros() as u64,
            args: args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        });
        SpanToken(token)
    })
}

/// Close a span opened by [`begin`], optionally appending result args.
/// The close always lands (the ring evicts oldest records to make
/// room, never the incoming close).
pub fn end(token: SpanToken, extra: &[(&str, String)]) {
    if token.0 == u64::MAX || !enabled() {
        return;
    }
    STATE.with(|s| {
        let mut b = s.borrow_mut();
        let Some(st) = b.as_mut() else { return };
        let Some(i) = st.open.iter().position(|o| o.token == token.0) else {
            return;
        };
        let o = st.open.swap_remove(i);
        let mut args = o.args;
        args.extend(extra.iter().map(|(k, v)| (k.to_string(), v.clone())));
        let dur_us = o.t0.elapsed().as_micros() as u64;
        push(
            st,
            Record {
                seq: o.seq,
                name: o.name,
                cat: o.cat,
                ph: Phase::Complete,
                trace_id: o.trace_id,
                replica: o.replica,
                ts_us: o.ts_us,
                dur_us,
                args,
            },
        );
    });
}

/// Snapshot of the ring, oldest first.
pub fn records() -> Vec<Record> {
    STATE.with(|s| {
        s.borrow()
            .as_ref()
            .map(|st| st.ring.iter().cloned().collect())
            .unwrap_or_default()
    })
}

/// Number of spans begun but not yet ended.
pub fn open_spans() -> usize {
    STATE.with(|s| s.borrow().as_ref().map(|st| st.open.len()).unwrap_or(0))
}

/// Records evicted by ring overflow since `enable()`/`clear()`.
pub fn dropped() -> u64 {
    STATE.with(|s| s.borrow().as_ref().map(|st| st.dropped).unwrap_or(0))
}

/// Render `records` as Chrome Trace Event Format JSON
/// (https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):
/// `pid` = replica + 1 (0 = unattributed), `tid` = trace id (request),
/// spans are `ph:"X"` complete events, instants `ph:"i"` thread-scoped.
/// Identity fields ride along in `args` so a parsed trace can be
/// asserted on without the Record type. Events are emitted in `seq`
/// order: ring order is *push* order, and a span's seq was assigned at
/// `begin` while its record lands at `end`, so an instant emitted
/// inside the span sits earlier in the ring with a later seq.
pub fn chrome_json(records: &[Record]) -> Value {
    let mut ordered: Vec<&Record> = records.iter().collect();
    ordered.sort_by_key(|r| r.seq);
    let events = ordered.into_iter().map(|r| {
        let mut fields = vec![
            ("name", json::s(&r.name)),
            ("cat", json::s(r.cat)),
            ("ph", json::s(r.ph.as_str())),
            ("ts", json::num(r.ts_us as f64)),
            ("pid", json::num(r.replica.map(|i| i as f64 + 1.0).unwrap_or(0.0))),
            ("tid", json::num(r.trace_id.map(|t| t as f64).unwrap_or(0.0))),
        ];
        match r.ph {
            Phase::Complete => fields.push(("dur", json::num(r.dur_us as f64))),
            Phase::Instant => fields.push(("s", json::s("t"))),
        }
        let mut args = vec![("seq", json::num(r.seq as f64))];
        if let Some(t) = r.trace_id {
            args.push(("trace_id", json::num(t as f64)));
        }
        let extra: Vec<(&str, Value)> =
            r.args.iter().map(|(k, v)| (k.as_str(), json::s(v))).collect();
        args.extend(extra);
        fields.push(("args", json::obj(args)));
        json::obj(fields)
    });
    json::obj(vec![
        ("traceEvents", json::arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// `chrome_json` over the current ring, serialized.
pub fn export_string() -> String {
    chrome_json(&records()).to_string()
}

/// Validate `text` as a well-formed Chrome-trace export of this
/// module: parses as JSON, has a `traceEvents` array, every event has
/// a string `name`, a `ph` of `"X"`/`"i"`, numeric `ts`/`pid`/`tid`,
/// spans carry `dur`, and `args.seq` is strictly increasing (the
/// deterministic emission order). Returns the event count.
pub fn check_export(text: &str) -> crate::Result<usize> {
    let v = json::parse(text)?;
    let events = v
        .get("traceEvents")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow::anyhow!("trace: missing traceEvents array"))?;
    let mut last_seq = -1.0f64;
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("trace event {i}: missing name"))?;
        let ph = ev
            .get("ph")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("trace event {i} ({name}): missing ph"))?;
        if ph != "X" && ph != "i" {
            anyhow::bail!("trace event {i} ({name}): unknown ph {ph:?}");
        }
        for key in ["ts", "pid", "tid"] {
            if ev.get(key).and_then(Value::as_f64).is_none() {
                anyhow::bail!("trace event {i} ({name}): missing numeric {key}");
            }
        }
        if ph == "X" && ev.get("dur").and_then(Value::as_f64).is_none() {
            anyhow::bail!("trace event {i} ({name}): span without dur");
        }
        let seq = ev
            .get("args")
            .and_then(|a| a.get("seq"))
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow::anyhow!("trace event {i} ({name}): missing args.seq"))?;
        if seq <= last_seq {
            anyhow::bail!(
                "trace event {i} ({name}): seq {seq} not increasing past {last_seq}"
            );
        }
        last_seq = seq;
    }
    Ok(events.len())
}

// ---------------------------------------------------------------------------
// Activation-health sampling (quantization hot path)
// ---------------------------------------------------------------------------

/// Arm activation sampling for the current decode step: the
/// quantization sites hit until `act_end()` accumulate absmax/clip
/// counts. Independent of `enable()` — the gauges work untraced.
pub fn act_begin() {
    ACT.with(|a| *a.borrow_mut() = ActSample::default());
    ACT_ACTIVE.with(|f| f.set(true));
}

/// Whether the quantization hot path should meter this call. One Cell
/// read; false outside a sampled step.
#[inline]
pub fn act_sampling() -> bool {
    ACT_ACTIVE.with(|f| f.get())
}

/// Fold one quantization site's activity into the step sample.
pub fn act_note(absmax: f32, clipped: u64, total: u64) {
    ACT.with(|a| {
        let mut s = a.borrow_mut();
        s.absmax = s.absmax.max(absmax);
        s.clipped += clipped;
        s.total += total;
    });
}

/// Disarm sampling and return the step's aggregate.
pub fn act_end() -> ActSample {
    ACT_ACTIVE.with(|f| f.set(false));
    ACT.with(|a| std::mem::replace(&mut a.borrow_mut(), ActSample::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Everything here runs on the test's own thread, so no
    /// serialization with other tests is needed (thread-local state).
    fn fresh() {
        disable();
        enable(0);
    }

    #[test]
    fn disabled_tracer_is_inert() {
        disable();
        instant("x", "test", None, &[]);
        let t = begin("y", "test", None, &[]);
        end(t, &[]);
        assert!(records().is_empty());
        assert_eq!(open_spans(), 0);
    }

    #[test]
    fn spans_and_instants_record_in_emission_order() {
        fresh();
        instant("admit", "sched", Some(7), &[("queue", "1".into())]);
        let t = begin("prefill", "sched", Some(7), &[]);
        instant("mid", "sched", None, &[]);
        assert_eq!(open_spans(), 1);
        end(t, &[("tokens", "5".into())]);
        let recs = records();
        // ring order is push order; the span's seq was taken at begin
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].name, "admit");
        assert_eq!(recs[0].seq, 0);
        assert_eq!(recs[1].name, "mid");
        assert_eq!(recs[1].seq, 2);
        assert_eq!(recs[2].name, "prefill");
        assert_eq!(recs[2].seq, 1);
        assert_eq!(recs[2].ph, Phase::Complete);
        assert_eq!(recs[2].trace_id, Some(7));
        assert_eq!(recs[2].args, vec![("tokens".to_string(), "5".to_string())]);
        assert_eq!(open_spans(), 0);
        // the export re-sorts by seq, so even this interleaved ring
        // passes the strictly-increasing-seq check
        assert_eq!(check_export(&export_string()).unwrap(), 3);
        disable();
    }

    #[test]
    fn ring_drops_oldest_never_the_close() {
        disable();
        enable(4);
        let t = begin("span", "test", Some(1), &[]);
        for i in 0..10 {
            instant(&format!("i{i}"), "test", None, &[]);
        }
        end(t, &[]);
        let recs = records();
        assert_eq!(recs.len(), 4, "ring stays bounded");
        assert!(dropped() >= 6);
        assert!(
            recs.iter().any(|r| r.name == "span" && r.ph == Phase::Complete),
            "the close of an open span always lands"
        );
        disable();
    }

    #[test]
    fn chrome_export_round_trips_and_checks() {
        fresh();
        instant("failover", "router", Some(3), &[("from", "0".into())]);
        let t = begin("decode", "sched", Some(3), &[]);
        end(t, &[("batch", "2".into())]);
        let text = export_string();
        let n = check_export(&text).unwrap();
        assert_eq!(n, 2);
        let v = crate::util::json::parse(&text).unwrap();
        let evs = v.get("traceEvents").and_then(Value::as_arr).unwrap();
        assert_eq!(evs[0].get("ph").and_then(Value::as_str), Some("i"));
        assert_eq!(evs[1].get("ph").and_then(Value::as_str), Some("X"));
        assert!(evs[1].get("dur").and_then(Value::as_f64).is_some());
        disable();
    }

    #[test]
    fn check_export_rejects_malformed() {
        assert!(check_export("not json").is_err());
        assert!(check_export(r#"{"foo": 1}"#).is_err());
        assert!(
            check_export(r#"{"traceEvents": [{"name": "x", "ph": "Q"}]}"#).is_err()
        );
    }

    #[test]
    fn act_sampling_accumulates_per_step() {
        assert!(!act_sampling());
        act_begin();
        assert!(act_sampling());
        act_note(1.5, 2, 100);
        act_note(3.0, 0, 50);
        let s = act_end();
        assert!(!act_sampling());
        assert_eq!(s.absmax, 3.0);
        assert_eq!(s.clipped, 2);
        assert_eq!(s.total, 150);
        assert!((s.clip_rate() - 2.0 / 150.0).abs() < 1e-12);
        // ended: the accumulator is reset
        act_begin();
        assert_eq!(act_end(), ActSample::default());
    }
}
