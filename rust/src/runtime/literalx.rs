//! Host <-> PJRT marshalling helpers.

use crate::util::tensor::Tensor;

/// An i32 host tensor (token ids, lengths).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn scalar(v: i32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn vec(v: Vec<i32>) -> Self {
        Self { shape: vec![v.len()], data: v }
    }
}

/// A host-side graph argument: every artifact input is one of these.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32(IntTensor),
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostValue::I32(IntTensor::scalar(v))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => &t.shape,
            HostValue::I32(t) => &t.shape,
        }
    }
}

/// Download a PJRT output buffer into an f32 host tensor.
pub fn fetch_f32(buf: &xla::PjRtBuffer) -> crate::Result<Tensor> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    literal_f32(&lit)
}

/// Literal -> f32 host tensor.
pub fn literal_f32(lit: &xla::Literal) -> crate::Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))?;
    Ok(Tensor::new(dims, data))
}

/// Fetch all outputs of an execute call as f32 host tensors. XLA wraps
/// multi-output programs in a root tuple, which PJRT returns as a single
/// tuple-shaped buffer — decompose it transparently.
pub fn fetch_all_f32(outs: &[xla::PjRtBuffer]) -> crate::Result<Vec<Tensor>> {
    if outs.len() == 1 {
        let mut lit = outs[0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        if lit.array_shape().is_err() {
            // tuple output: decompose into element literals
            let parts = lit
                .decompose_tuple()
                .map_err(|e| anyhow::anyhow!("decompose_tuple: {e:?}"))?;
            return parts.iter().map(literal_f32).collect();
        }
        return Ok(vec![literal_f32(&lit)?]);
    }
    outs.iter().map(fetch_f32).collect()
}

/// Download a PJRT output buffer into an i32 host tensor.
pub fn fetch_i32(buf: &xla::PjRtBuffer) -> crate::Result<IntTensor> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("to_vec<i32>: {e:?}"))?;
    Ok(IntTensor::new(dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_shapes() {
        assert!(HostValue::scalar_f32(1.0).shape().is_empty());
        let v = HostValue::I32(IntTensor::vec(vec![1, 2, 3]));
        assert_eq!(v.shape(), &[3]);
    }

    #[test]
    #[should_panic]
    fn int_tensor_shape_checked() {
        IntTensor::new(vec![2, 2], vec![1, 2, 3]);
    }
}
