//! Host <-> PJRT marshalling: host tensors, the `Value` abstraction for
//! graph operands (host data vs device-resident buffers), and the
//! `Outputs` view that keeps execute results in runtime form so callers
//! fetch only the elements they actually need on the host.

use std::rc::Rc;

use super::client::Client;
use super::split::TupleSplitter;
use super::transfer;
use crate::util::tensor::Tensor;

/// An i32 host tensor (token ids, lengths).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn scalar(v: i32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn vec(v: Vec<i32>) -> Self {
        Self { shape: vec![v.len()], data: v }
    }
}

/// A host-side graph argument: every artifact input is one of these.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32(IntTensor),
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostValue::I32(IntTensor::scalar(v))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => &t.shape,
            HostValue::I32(t) => &t.shape,
        }
    }
}

/// A graph operand in runtime form: per-call host data that must be
/// uploaded, or a device-resident buffer (weights, calibration ranges,
/// smoothing scales, the cushion prefix KV, the serving KV cache) that is
/// reused across calls without touching host memory. `Rc` because
/// PjRtBuffer is not clonable but resident buffers are shared between the
/// pool, the engine, and in-flight argument lists (the PJRT handles are
/// single-threaded anyway — see model::resident for the locking story).
#[derive(Clone)]
pub enum Value {
    Host(HostValue),
    Device(Rc<xla::PjRtBuffer>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Self {
        Value::Host(HostValue::scalar_f32(v))
    }

    pub fn scalar_i32(v: i32) -> Self {
        Value::Host(HostValue::scalar_i32(v))
    }

    /// Materialize as a device buffer: uploads `Host`, passes `Device`
    /// through untouched (no transfer).
    pub fn into_buffer(self, client: &Client) -> crate::Result<Rc<xla::PjRtBuffer>> {
        match self {
            Value::Host(v) => Ok(Rc::new(client.upload_host(&v)?)),
            Value::Device(b) => Ok(b),
        }
    }

    pub fn is_device(&self) -> bool {
        matches!(self, Value::Device(_))
    }
}

impl std::fmt::Debug for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Host(h) => write!(f, "Value::Host({h:?})"),
            Value::Device(_) => write!(f, "Value::Device(<PjRtBuffer>)"),
        }
    }
}

/// One output of an execute call, still in runtime form: a device buffer
/// (PJRT returned per-output buffers), or an element literal of the
/// fetched root tuple (xla_extension 0.5.1 cannot split the tuple
/// on-device, so multi-output programs come back as one tuple literal —
/// see `Outputs::from_execute`). A `Literal` element can be re-uploaded
/// verbatim via `into_value` without converting through f32 host tensors.
pub enum OutValue {
    Device(xla::PjRtBuffer),
    Literal(xla::Literal),
}

impl OutValue {
    /// Bring this output to the host as an f32 tensor. `Device` incurs a
    /// fetch; `Literal` is already host-side and only converts.
    pub fn to_tensor(&self) -> crate::Result<Tensor> {
        match self {
            OutValue::Device(b) => fetch_f32(b),
            OutValue::Literal(l) => literal_f32(l),
        }
    }

    /// Bring this output to the host as an i32 tensor (token ids). The
    /// device-side-selection fetch path: a decode step fetches [B] ids
    /// through here instead of [B, vocab] f32 logits.
    pub fn to_int_tensor(&self) -> crate::Result<IntTensor> {
        match self {
            OutValue::Device(b) => fetch_i32(b),
            OutValue::Literal(l) => literal_i32(l),
        }
    }

    /// Keep this output on device for the next call: `Device` is wrapped
    /// as-is; `Literal` is uploaded without an f32 conversion.
    pub fn into_value(self, client: &Client) -> crate::Result<Value> {
        match self {
            OutValue::Device(b) => Ok(Value::Device(Rc::new(b))),
            OutValue::Literal(l) => Ok(Value::Device(Rc::new(client.upload_literal(&l)?))),
        }
    }
}

/// The outputs of one execute call. Elements stay in runtime form until a
/// caller fetches (`host_f32`) or claims (`take`) them, so pass-through
/// state (the serving KV cache) never converts through host f32 vectors.
pub struct Outputs {
    vals: Vec<Option<OutValue>>,
}

impl Outputs {
    /// Wrap raw execute outputs, decomposing a root tuple on device when
    /// a `TupleSplitter` for the graph's output signature is supplied:
    /// every element stays a `Device` buffer and nothing crosses to the
    /// host (the serving hot path — the KV cache element in particular
    /// never materializes as a host literal between steps). Without a
    /// splitter, or if the split fails, this degrades to the host
    /// materialization of `from_execute`.
    pub fn from_execute_split(
        bufs: Vec<xla::PjRtBuffer>,
        splitter: Option<&TupleSplitter>,
    ) -> crate::Result<Outputs> {
        if bufs.len() == 1 {
            if let Some(sp) = splitter.filter(|s| s.usable()) {
                match sp.split(&bufs[0]) {
                    Ok(parts) => {
                        return Ok(Outputs {
                            vals: parts
                                .into_iter()
                                .map(|b| Some(OutValue::Device(b)))
                                .collect(),
                        });
                    }
                    Err(e) => {
                        // latch the splitter off: one warn, no doomed
                        // device execution retried every step
                        sp.disable();
                        log::warn!(
                            "on-device tuple split failed ({e:#}); this \
                             signature will materialize on host from now on"
                        );
                    }
                }
            }
        }
        Self::from_execute(bufs)
    }

    /// Wrap raw execute outputs. XLA wraps multi-output programs in a
    /// root tuple which PJRT returns as a single tuple-shaped buffer; it
    /// is materialized to a host literal *once* here and decomposed into
    /// element literals (the 0.5.1 wrapper offers no native on-device
    /// split — `runtime::split` works around that for signatures the
    /// caller declares; this is the fallback).
    pub fn from_execute(bufs: Vec<xla::PjRtBuffer>) -> crate::Result<Outputs> {
        if bufs.len() == 1 {
            let mut lit = bufs[0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
            if lit.array_shape().is_err() {
                // tuple output: decompose into element literals. One
                // physical boundary crossing -> one fetch, total bytes.
                let parts = lit
                    .decompose_tuple()
                    .map_err(|e| anyhow::anyhow!("decompose_tuple: {e:?}"))?;
                let bytes: usize = parts.iter().map(|p| 4 * literal_elems(p)).sum();
                transfer::note_fetch(bytes);
                return Ok(Outputs {
                    vals: parts.into_iter().map(|p| Some(OutValue::Literal(p))).collect(),
                });
            }
            transfer::note_fetch(4 * literal_elems(&lit));
            return Ok(Outputs { vals: vec![Some(OutValue::Literal(lit))] });
        }
        Ok(Outputs {
            vals: bufs.into_iter().map(|b| Some(OutValue::Device(b))).collect(),
        })
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Claim output `i` in runtime form (for pass-through state).
    pub fn take(&mut self, i: usize) -> crate::Result<OutValue> {
        self.vals
            .get_mut(i)
            .and_then(|v| v.take())
            .ok_or_else(|| anyhow::anyhow!("output {i} missing or already taken"))
    }

    /// Fetch output `i` to the host as an f32 tensor (leaves it in place).
    pub fn host_f32(&self, i: usize) -> crate::Result<Tensor> {
        self.vals
            .get(i)
            .and_then(|v| v.as_ref())
            .ok_or_else(|| anyhow::anyhow!("output {i} missing or already taken"))?
            .to_tensor()
    }

    /// Fetch output `i` to the host as an i32 tensor (leaves it in
    /// place) — the token-id fetch path of the `*_sampled_*` graphs.
    pub fn host_i32(&self, i: usize) -> crate::Result<IntTensor> {
        self.vals
            .get(i)
            .and_then(|v| v.as_ref())
            .ok_or_else(|| anyhow::anyhow!("output {i} missing or already taken"))?
            .to_int_tensor()
    }

    /// Fetch every remaining output as an f32 host tensor, in order.
    pub fn into_tensors(self) -> crate::Result<Vec<Tensor>> {
        self.vals
            .into_iter()
            .enumerate()
            .map(|(i, v)| {
                v.ok_or_else(|| anyhow::anyhow!("output {i} already taken"))?
                    .to_tensor()
            })
            .collect()
    }
}

/// Element count of an array literal (0 for tuple shapes).
pub(crate) fn literal_elems(lit: &xla::Literal) -> usize {
    lit.array_shape()
        .map(|s| s.dims().iter().map(|&d| d as usize).product())
        .unwrap_or(0)
}

/// Download a PJRT output buffer into an f32 host tensor.
pub fn fetch_f32(buf: &xla::PjRtBuffer) -> crate::Result<Tensor> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    transfer::note_fetch(4 * literal_elems(&lit));
    literal_f32(&lit)
}

/// Literal -> f32 host tensor (host-side conversion, no device transfer).
pub fn literal_f32(lit: &xla::Literal) -> crate::Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))?;
    Ok(Tensor::new(dims, data))
}

/// Literal -> i32 host tensor (host-side conversion, no device transfer).
pub fn literal_i32(lit: &xla::Literal) -> crate::Result<IntTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("to_vec<i32>: {e:?}"))?;
    Ok(IntTensor::new(dims, data))
}

/// Fetch all outputs of an execute call as f32 host tensors (the analysis
/// path; the serving hot path uses `Outputs` and fetches selectively).
pub fn fetch_all_f32(outs: Vec<xla::PjRtBuffer>) -> crate::Result<Vec<Tensor>> {
    Outputs::from_execute(outs)?.into_tensors()
}

/// Download a PJRT output buffer into an i32 host tensor.
pub fn fetch_i32(buf: &xla::PjRtBuffer) -> crate::Result<IntTensor> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    let t = literal_i32(&lit)?;
    transfer::note_fetch(4 * t.data.len());
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_shapes() {
        assert!(HostValue::scalar_f32(1.0).shape().is_empty());
        let v = HostValue::I32(IntTensor::vec(vec![1, 2, 3]));
        assert_eq!(v.shape(), &[3]);
    }

    #[test]
    #[should_panic]
    fn int_tensor_shape_checked() {
        IntTensor::new(vec![2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn value_scalar_constructors_are_host() {
        assert!(!Value::scalar_f32(1.0).is_device());
        assert!(!Value::scalar_i32(3).is_device());
    }
}
