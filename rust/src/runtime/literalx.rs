//! Host <-> backend marshalling: host tensors, the `Value` abstraction
//! for graph operands (host data vs backend-resident `DeviceBuf`s), and
//! the `Outputs` view that keeps execute results in runtime form so
//! callers fetch only the elements they actually need on the host.
//!
//! Everything here is backend-polymorphic: under PJRT a resident value
//! is a device buffer and a fetch is a PCIe crossing; under the
//! reference interpreter a resident value is host memory and the
//! "crossing" is a copy — metered identically (`runtime::transfer`) so
//! residency budgets mean the same thing on both backends.

use std::rc::Rc;

use super::backend::DeviceBuf;
use super::client::Client;
#[cfg(feature = "xla")]
use super::split::TupleSplitter;
use super::transfer;
use crate::util::tensor::Tensor;

/// An i32 host tensor (token ids, lengths).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn scalar(v: i32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn vec(v: Vec<i32>) -> Self {
        Self { shape: vec![v.len()], data: v }
    }
}

/// A host-side graph argument: every artifact input is one of these.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32(IntTensor),
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostValue::I32(IntTensor::scalar(v))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => &t.shape,
            HostValue::I32(t) => &t.shape,
        }
    }

    pub fn elems(&self) -> usize {
        match self {
            HostValue::F32(t) => t.data.len(),
            HostValue::I32(t) => t.data.len(),
        }
    }
}

/// A graph operand in runtime form: per-call host data that must be
/// uploaded, or a backend-resident buffer (weights, calibration ranges,
/// smoothing scales, the cushion prefix KV, the serving KV cache) that is
/// reused across calls without touching host memory. `Rc` because PJRT
/// buffers are not clonable but resident buffers are shared between the
/// pool, the engine, and in-flight argument lists (the PJRT handles are
/// single-threaded anyway — see model::resident for the locking story).
#[derive(Clone)]
pub enum Value {
    Host(HostValue),
    Device(Rc<DeviceBuf>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Self {
        Value::Host(HostValue::scalar_f32(v))
    }

    pub fn scalar_i32(v: i32) -> Self {
        Value::Host(HostValue::scalar_i32(v))
    }

    /// Materialize as a resident buffer: uploads `Host`, passes `Device`
    /// through untouched (no transfer).
    pub fn into_buffer(self, client: &Client) -> crate::Result<Rc<DeviceBuf>> {
        match self {
            Value::Host(v) => Ok(Rc::new(client.upload_host(&v)?)),
            Value::Device(b) => Ok(b),
        }
    }

    pub fn is_device(&self) -> bool {
        matches!(self, Value::Device(_))
    }
}

impl std::fmt::Debug for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Host(h) => write!(f, "Value::Host({h:?})"),
            Value::Device(_) => write!(f, "Value::Device(<DeviceBuf>)"),
        }
    }
}

/// One output of an execute call, still in runtime form:
///
/// * `Device` — a PJRT buffer (per-output execute results, or one the
///   tuple splitter decomposed on device).
/// * `Literal` — an element literal of a fetched root tuple
///   (xla_extension 0.5.1 cannot split the tuple on-device, so
///   multi-output programs come back as one tuple literal — see
///   `Outputs::from_execute`). Re-uploads verbatim via `into_value`
///   without converting through f32 host tensors.
/// * `Host` — a reference-interpreter output: conceptually resident on
///   the backend; converting to a host tensor meters a fetch, while
///   `into_value` keeps it resident for free.
pub enum OutValue {
    #[cfg(feature = "xla")]
    Device(xla::PjRtBuffer),
    #[cfg(feature = "xla")]
    Literal(xla::Literal),
    Host(HostValue),
}

impl OutValue {
    /// Bring this output to the host as an f32 tensor. `Device` and
    /// `Host` incur a (metered) fetch; `Literal` is already host-side
    /// and only converts.
    pub fn to_tensor(&self) -> crate::Result<Tensor> {
        match self {
            #[cfg(feature = "xla")]
            OutValue::Device(b) => pjrt_fetch_f32(b),
            #[cfg(feature = "xla")]
            OutValue::Literal(l) => literal_f32(l),
            OutValue::Host(HostValue::F32(t)) => {
                transfer::note_fetch(4 * t.data.len());
                Ok(t.clone())
            }
            OutValue::Host(HostValue::I32(_)) => {
                anyhow::bail!("to_tensor on an i32 output (use to_int_tensor)")
            }
        }
    }

    /// Bring this output to the host as an i32 tensor (token ids). The
    /// device-side-selection fetch path: a decode step fetches [B] ids
    /// through here instead of [B, vocab] f32 logits.
    pub fn to_int_tensor(&self) -> crate::Result<IntTensor> {
        match self {
            #[cfg(feature = "xla")]
            OutValue::Device(b) => pjrt_fetch_i32(b),
            #[cfg(feature = "xla")]
            OutValue::Literal(l) => literal_i32(l),
            OutValue::Host(HostValue::I32(t)) => {
                transfer::note_fetch(4 * t.data.len());
                Ok(t.clone())
            }
            OutValue::Host(HostValue::F32(_)) => {
                anyhow::bail!("to_int_tensor on an f32 output")
            }
        }
    }

    /// Keep this output resident for the next call: `Device`/`Host` wrap
    /// as-is (no transfer); `Literal` is uploaded without an f32
    /// conversion.
    pub fn into_value(self, client: &Client) -> crate::Result<Value> {
        match self {
            #[cfg(feature = "xla")]
            OutValue::Device(b) => Ok(Value::Device(Rc::new(DeviceBuf::Pjrt(b)))),
            #[cfg(feature = "xla")]
            OutValue::Literal(l) => {
                Ok(Value::Device(Rc::new(client.upload_literal(&l)?)))
            }
            OutValue::Host(v) => {
                let _ = client;
                Ok(Value::Device(Rc::new(DeviceBuf::Host(v))))
            }
        }
    }
}

/// The outputs of one execute call. Elements stay in runtime form until a
/// caller fetches (`host_f32`) or claims (`take`) them, so pass-through
/// state (the serving KV cache) never converts through host f32 vectors.
pub struct Outputs {
    vals: Vec<Option<OutValue>>,
}

impl Outputs {
    /// Wrap reference-interpreter results. The values are conceptually
    /// backend-resident — nothing is metered until a caller fetches.
    pub fn from_host(vals: Vec<HostValue>) -> Outputs {
        Outputs {
            vals: vals.into_iter().map(|v| Some(OutValue::Host(v))).collect(),
        }
    }

    /// Wrap raw execute outputs, decomposing a root tuple on device when
    /// a `TupleSplitter` for the graph's output signature is supplied:
    /// every element stays a `Device` buffer and nothing crosses to the
    /// host (the serving hot path — the KV cache element in particular
    /// never materializes as a host literal between steps). Without a
    /// splitter, or if the split fails, this degrades to the host
    /// materialization of `from_execute`.
    #[cfg(feature = "xla")]
    pub fn from_execute_split(
        bufs: Vec<xla::PjRtBuffer>,
        splitter: Option<&TupleSplitter>,
    ) -> crate::Result<Outputs> {
        if bufs.len() == 1 {
            if let Some(sp) = splitter.filter(|s| s.usable()) {
                match sp.split(&bufs[0]) {
                    Ok(parts) => {
                        return Ok(Outputs {
                            vals: parts
                                .into_iter()
                                .map(|b| Some(OutValue::Device(b)))
                                .collect(),
                        });
                    }
                    Err(e) => {
                        // latch the splitter off: one warn, no doomed
                        // device execution retried every step
                        sp.disable();
                        log::warn!(
                            "on-device tuple split failed ({e:#}); this \
                             signature will materialize on host from now on"
                        );
                    }
                }
            }
        }
        Self::from_execute(bufs)
    }

    /// Wrap raw execute outputs. XLA wraps multi-output programs in a
    /// root tuple which PJRT returns as a single tuple-shaped buffer; it
    /// is materialized to a host literal *once* here and decomposed into
    /// element literals (the 0.5.1 wrapper offers no native on-device
    /// split — `runtime::split` works around that for signatures the
    /// caller declares; this is the fallback).
    #[cfg(feature = "xla")]
    pub fn from_execute(bufs: Vec<xla::PjRtBuffer>) -> crate::Result<Outputs> {
        if bufs.len() == 1 {
            let mut lit = bufs[0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
            if lit.array_shape().is_err() {
                // tuple output: decompose into element literals. One
                // physical boundary crossing -> one fetch, total bytes.
                let parts = lit
                    .decompose_tuple()
                    .map_err(|e| anyhow::anyhow!("decompose_tuple: {e:?}"))?;
                let bytes: usize = parts.iter().map(|p| 4 * literal_elems(p)).sum();
                transfer::note_fetch(bytes);
                return Ok(Outputs {
                    vals: parts.into_iter().map(|p| Some(OutValue::Literal(p))).collect(),
                });
            }
            transfer::note_fetch(4 * literal_elems(&lit));
            return Ok(Outputs { vals: vec![Some(OutValue::Literal(lit))] });
        }
        Ok(Outputs {
            vals: bufs.into_iter().map(|b| Some(OutValue::Device(b))).collect(),
        })
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Claim output `i` in runtime form (for pass-through state).
    pub fn take(&mut self, i: usize) -> crate::Result<OutValue> {
        self.vals
            .get_mut(i)
            .and_then(|v| v.take())
            .ok_or_else(|| anyhow::anyhow!("output {i} missing or already taken"))
    }

    /// Fetch output `i` to the host as an f32 tensor (leaves it in place).
    pub fn host_f32(&self, i: usize) -> crate::Result<Tensor> {
        self.vals
            .get(i)
            .and_then(|v| v.as_ref())
            .ok_or_else(|| anyhow::anyhow!("output {i} missing or already taken"))?
            .to_tensor()
    }

    /// Fetch output `i` to the host as an i32 tensor (leaves it in
    /// place) — the token-id fetch path of the `*_sampled_*` graphs.
    pub fn host_i32(&self, i: usize) -> crate::Result<IntTensor> {
        self.vals
            .get(i)
            .and_then(|v| v.as_ref())
            .ok_or_else(|| anyhow::anyhow!("output {i} missing or already taken"))?
            .to_int_tensor()
    }

    /// Fetch every remaining output as an f32 host tensor, in order.
    pub fn into_tensors(self) -> crate::Result<Vec<Tensor>> {
        self.vals
            .into_iter()
            .enumerate()
            .map(|(i, v)| {
                v.ok_or_else(|| anyhow::anyhow!("output {i} already taken"))?
                    .to_tensor()
            })
            .collect()
    }
}

/// Element count of an array literal (0 for tuple shapes).
#[cfg(feature = "xla")]
pub(crate) fn literal_elems(lit: &xla::Literal) -> usize {
    lit.array_shape()
        .map(|s| s.dims().iter().map(|&d| d as usize).product())
        .unwrap_or(0)
}

/// Download a PJRT output buffer into an f32 host tensor.
#[cfg(feature = "xla")]
pub fn pjrt_fetch_f32(buf: &xla::PjRtBuffer) -> crate::Result<Tensor> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    transfer::note_fetch(4 * literal_elems(&lit));
    literal_f32(&lit)
}

/// Literal -> f32 host tensor (host-side conversion, no device transfer).
#[cfg(feature = "xla")]
pub fn literal_f32(lit: &xla::Literal) -> crate::Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))?;
    Ok(Tensor::new(dims, data))
}

/// Literal -> i32 host tensor (host-side conversion, no device transfer).
#[cfg(feature = "xla")]
pub fn literal_i32(lit: &xla::Literal) -> crate::Result<IntTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("to_vec<i32>: {e:?}"))?;
    Ok(IntTensor::new(dims, data))
}

/// Download a PJRT output buffer into an i32 host tensor.
#[cfg(feature = "xla")]
pub fn pjrt_fetch_i32(buf: &xla::PjRtBuffer) -> crate::Result<IntTensor> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    let t = literal_i32(&lit)?;
    transfer::note_fetch(4 * t.data.len());
    Ok(t)
}

/// Fetch a resident value to the host (any backend).
pub fn fetch_f32(buf: &DeviceBuf) -> crate::Result<Tensor> {
    buf.fetch_f32()
}

/// Fetch a resident value to the host as i32 ids (any backend).
pub fn fetch_i32(buf: &DeviceBuf) -> crate::Result<IntTensor> {
    buf.fetch_i32()
}

/// Fetch all outputs of an execute call as f32 host tensors (the analysis
/// path; the serving hot path uses `Outputs` and fetches selectively).
#[cfg(feature = "xla")]
pub fn fetch_all_f32(outs: Vec<xla::PjRtBuffer>) -> crate::Result<Vec<Tensor>> {
    Outputs::from_execute(outs)?.into_tensors()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_shapes() {
        assert!(HostValue::scalar_f32(1.0).shape().is_empty());
        let v = HostValue::I32(IntTensor::vec(vec![1, 2, 3]));
        assert_eq!(v.shape(), &[3]);
    }

    #[test]
    #[should_panic]
    fn int_tensor_shape_checked() {
        IntTensor::new(vec![2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn value_scalar_constructors_are_host() {
        assert!(!Value::scalar_f32(1.0).is_device());
        assert!(!Value::scalar_i32(3).is_device());
    }

    #[test]
    fn host_outputs_fetch_and_typecheck() {
        let outs = Outputs::from_host(vec![
            HostValue::F32(Tensor::new(vec![2], vec![1.0, 2.0])),
            HostValue::I32(IntTensor::vec(vec![7, 8])),
        ]);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs.host_f32(0).unwrap().data, vec![1.0, 2.0]);
        assert_eq!(outs.host_i32(1).unwrap().data, vec![7, 8]);
        // fetching with the wrong element type is an error, not a cast
        assert!(outs.host_i32(0).is_err());
        assert!(outs.host_f32(1).is_err());
    }

    #[test]
    fn host_outputs_take_then_refetch_errors() {
        let mut outs = Outputs::from_host(vec![HostValue::scalar_f32(5.0)]);
        let v = outs.take(0).unwrap();
        assert!(matches!(v, OutValue::Host(HostValue::F32(_))));
        assert!(outs.take(0).is_err());
        assert!(outs.host_f32(0).is_err());
    }
}
