//! On-device decomposition of tuple-shaped execute results.
//!
//! xla_extension 0.5.1 returns a multi-output program's root tuple as a
//! single tuple-shaped `PjRtBuffer` and offers no native on-device
//! split, so the seed runtime materialized the whole tuple to a host
//! literal per call — for the serving decode step that meant the ~4.5 MB
//! KV cache crossed the host boundary twice per token (fetch + re-upload)
//! even though no host code ever read it.
//!
//! `TupleSplitter` closes that hole with the one primitive the wrapper
//! *does* expose: compiling HLO text. For a declared output signature it
//! synthesizes one tiny `get-tuple-element` program per element
//!
//! ```text
//! HloModule cushion_split_e0
//! ENTRY main {
//!   arg = (f32[4,2,8,2,144,64], s32[8], f32[8]) parameter(0)
//!   ROOT out = f32[4,2,8,2,144,64] get-tuple-element(arg), index=0
//! }
//! ```
//!
//! and executes each against the tuple buffer, yielding per-output
//! *device* buffers: the cache element never materializes as a host
//! literal between steps (a device-to-device copy replaces two PCIe
//! crossings; input donation would also elide the copy, but the 0.5.1
//! wrapper exposes no aliasing config — see DESIGN.md §Perf). Where the
//! runtime already returns per-output buffers (`return_tuple=False`
//! lowering honored by the PJRT client) the splitter is simply unused.
//!
//! Construction is fallible by design: if the wrapper rejects
//! tuple-shaped parameters, callers degrade to the host-literal
//! materialization path (`Outputs::from_execute` without a splitter) and
//! the system behaves exactly like the seed.

//! Feature note: splitter *construction* requires a PJRT client (`xla`
//! feature); the shape declarations (`OutSpec`, `DType`) and the HLO
//! text synthesis stay available in hermetic builds, where the engine
//! simply never constructs a splitter (interpreter outputs are already
//! per-element).

use super::client::Client;
use super::executable::Executable;

/// Element type of one graph output (everything this system moves is
/// f32 or i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn hlo(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "s32",
        }
    }
}

/// Declared shape of one output of a multi-output graph.
#[derive(Clone, Debug)]
pub struct OutSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl OutSpec {
    pub fn f32(dims: &[usize]) -> Self {
        Self { dtype: DType::F32, dims: dims.to_vec() }
    }

    pub fn i32(dims: &[usize]) -> Self {
        Self { dtype: DType::I32, dims: dims.to_vec() }
    }

    /// HLO shape string, e.g. `f32[8,144]` (`f32[]` for scalars).
    fn hlo(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(usize::to_string).collect();
        format!("{}[{}]", self.dtype.hlo(), dims.join(","))
    }

    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

/// HLO text of the get-tuple-element program for element `index`.
fn gte_module_text(spec: &[OutSpec], index: usize) -> String {
    let tuple: Vec<String> = spec.iter().map(OutSpec::hlo).collect();
    format!(
        "HloModule cushion_split_e{index}\n\n\
         ENTRY main {{\n  \
           arg = ({tuple}) parameter(0)\n  \
           ROOT out = {elem} get-tuple-element(arg), index={index}\n\
         }}\n",
        tuple = tuple.join(", "),
        elem = spec[index].hlo(),
    )
}

/// One compiled extractor per tuple element. Splitters are keyed by the
/// output *signature*, so graphs sharing one (every prefill bucket, for
/// instance) share one splitter.
pub struct TupleSplitter {
    spec: Vec<OutSpec>,
    parts: Vec<Executable>,
    /// Latched on the first *runtime* split failure (compile succeeded
    /// but execute rejected the tuple argument): callers skip the
    /// splitter from then on instead of re-running a doomed device
    /// execution — and re-warning — every step. Cell is fine here: the
    /// PJRT-touching types are !Sync already (see model::resident).
    dead: std::cell::Cell<bool>,
}

impl TupleSplitter {
    /// Compile the per-element extractors for `spec`. Errors (no PJRT
    /// client, the HLO parser or PJRT rejecting tuple parameters) leave
    /// the caller on the host-materialization fallback — never fatal.
    #[cfg(not(feature = "xla"))]
    pub fn new(_client: &Client, _spec: &[OutSpec]) -> crate::Result<Self> {
        anyhow::bail!("tuple splitter requires the `xla` feature")
    }

    #[cfg(feature = "xla")]
    pub fn new(client: &Client, spec: &[OutSpec]) -> crate::Result<Self> {
        anyhow::ensure!(spec.len() > 1, "splitter needs a multi-output spec");
        anyhow::ensure!(
            client.compiles_artifacts(),
            "tuple splitter requires a PJRT client"
        );
        // pid + process-wide counter: several engines (or parallel
        // tests) building splitters concurrently must never write the
        // same scratch path, or one would compile the other's signature.
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let dir = std::env::temp_dir();
        let tag = format!(
            "{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        );
        let mut parts = Vec::with_capacity(spec.len());
        for i in 0..spec.len() {
            let text = gte_module_text(spec, i);
            // HloModuleProto only parses from a file in this wrapper.
            let path = dir.join(format!("cushion_split_{tag}_{i}.hlo.txt"));
            std::fs::write(&path, &text)
                .map_err(|e| anyhow::anyhow!("writing {path:?}: {e}"))?;
            let loaded = Executable::load(client, &format!("split_e{i}"), &path);
            let _ = std::fs::remove_file(&path);
            parts.push(loaded?);
        }
        Ok(Self {
            spec: spec.to_vec(),
            parts,
            dead: std::cell::Cell::new(false),
        })
    }

    pub fn arity(&self) -> usize {
        self.parts.len()
    }

    pub fn spec(&self) -> &[OutSpec] {
        &self.spec
    }

    /// False once a runtime split has failed; callers fall back to host
    /// materialization without retrying.
    pub fn usable(&self) -> bool {
        !self.dead.get()
    }

    /// Latch this splitter off after a runtime failure (warned once by
    /// the caller).
    pub fn disable(&self) {
        self.dead.set(true);
    }

    /// Decompose a tuple-shaped result buffer into per-element device
    /// buffers. Pure device-side: no transfer counters move.
    #[cfg(feature = "xla")]
    pub fn split(&self, tuple: &xla::PjRtBuffer) -> crate::Result<Vec<xla::PjRtBuffer>> {
        let mut out = Vec::with_capacity(self.parts.len());
        for (i, part) in self.parts.iter().enumerate() {
            let mut bufs = part.run_buffers(&[tuple])?;
            anyhow::ensure!(
                bufs.len() == 1,
                "split element {i}: expected 1 output, got {}",
                bufs.len()
            );
            out.push(bufs.pop().unwrap());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gte_text_shapes() {
        let spec = vec![
            OutSpec::f32(&[4, 2, 8]),
            OutSpec::i32(&[8]),
            OutSpec::f32(&[]),
        ];
        let t = gte_module_text(&spec, 1);
        assert!(t.contains("(f32[4,2,8], s32[8], f32[])"));
        assert!(t.contains("ROOT out = s32[8] get-tuple-element(arg), index=1"));
        let t0 = gte_module_text(&spec, 2);
        assert!(t0.contains("ROOT out = f32[] get-tuple-element(arg), index=2"));
    }

    #[test]
    fn out_spec_elems() {
        assert_eq!(OutSpec::f32(&[3, 4]).elems(), 12);
        assert_eq!(OutSpec::i32(&[]).elems(), 1);
    }
}
