//! Reference-interpreter programs: the hermetic execution path behind
//! `runtime::backend`.
//!
//! An `InterpProgram` is the interpreter's counterpart of a compiled
//! artifact: parsed from the same graph name the registry resolves
//! (`<op>[_sampled]_<mode>[_b<bucket>][_pallas]`, see registry.rs), it
//! takes the same operand list — the flat weight bundle in param_spec
//! order followed by the graph-specific inputs — and produces the same
//! outputs, computed by `model::forward` on host tensors. Bucketed
//! prefill variants need no per-bucket programs: the interpreter reads
//! the token-vector length from the argument itself.
//!
//! Parity with the lowered JAX graphs is pinned by the golden fixtures
//! (python/tests/fixtures/interp/) via rust/tests/interp_parity.rs.

use std::rc::Rc;

use crate::model::forward::{self, Mode, ModelSpec, Params};
use crate::model::manifest::Manifest;
use crate::runtime::literalx::{HostValue, IntTensor};
use crate::util::tensor::Tensor;

/// The graph inventory the interpreter implements (graphs.py, plus the
/// interpreter-native paged serving ops of coordinator::kvpool).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Fwd(Mode),
    Stats,
    ScoreLq,
    PrefixKv,
    TuneStep,
    Prefill { mode: Mode, sampled: bool },
    /// Resumable chunked prefill (`prefill_chunk_<mode>`): extend a
    /// slot's paged KV prefix — `done` tokens already written — by the
    /// next chunk of prompt tokens. No sampled variant: only the final
    /// chunk's logits seed decode, and the engine host-argmaxes those.
    PrefillChunk(Mode),
    Decode { mode: Mode, sampled: bool },
    /// Block-table prefill over the pool tensor (`prefill_paged_<mode>`,
    /// no compiled counterpart — the hermetic true-paging path).
    PrefillPaged(Mode),
    /// Block-table decode over the pool tensor (`decode_paged_<mode>`).
    DecodePaged(Mode),
    /// One shard of a tensor-parallel prefill
    /// (`prefill_<mode>_s<k>of<n>`, 0-based `k`). Executes through
    /// `execute_sharded` — the forward pass rendezvouses on a
    /// `CollectiveBus` at each all-gather point.
    PrefillShard { mode: Mode, shard: usize, n_shards: usize },
    /// One shard of a tensor-parallel decode (`decode_<mode>_s<k>of<n>`).
    DecodeShard { mode: Mode, shard: usize, n_shards: usize },
}

/// A resolved interpreter program: the variant's architecture plus the
/// op parsed from the graph name.
pub struct InterpProgram {
    pub spec: Rc<ModelSpec>,
    pub op: Op,
    name: String,
}

impl InterpProgram {
    /// Parse a registry graph name into an interpreter op. Unknown names
    /// (custom artifacts the interpreter has no implementation for)
    /// return an error, which the registry surfaces as "no artifact and
    /// no interpreter program".
    pub fn parse(spec: Rc<ModelSpec>, name: &str) -> crate::Result<Self> {
        let base = name.strip_suffix("_pallas").unwrap_or(name);
        let op = if let Some((inner, k, n)) = strip_shard(base) {
            // Sharded variants exist only for the logits-graph serving
            // ops; divisibility and shard range fail at resolve time.
            crate::runtime::collective::ShardPlan::validate(
                spec.n_kv_heads, spec.d_ff, n,
            )?;
            anyhow::ensure!(
                k < n,
                "graph '{name}': shard {k} out of range for {n} shards"
            );
            if let Some(mode) = inner.strip_prefix("prefill_") {
                Op::PrefillShard { mode: Mode::parse(mode)?, shard: k, n_shards: n }
            } else if let Some(mode) = inner.strip_prefix("decode_") {
                Op::DecodeShard { mode: Mode::parse(mode)?, shard: k, n_shards: n }
            } else {
                anyhow::bail!(
                    "graph '{name}': only prefill/decode have sharded variants"
                )
            }
        } else if base == "stats" {
            Op::Stats
        } else if base == "score_lq" {
            Op::ScoreLq
        } else if base == "prefix_kv" {
            Op::PrefixKv
        } else if base == "tune_step" {
            Op::TuneStep
        } else if let Some(mode) = base.strip_prefix("fwd_") {
            Op::Fwd(Mode::parse(mode)?)
        } else if let Some(rest) = base.strip_prefix("prefill_sampled_") {
            Op::Prefill { mode: Mode::parse(strip_bucket(rest))?, sampled: true }
        } else if let Some(mode) = base.strip_prefix("prefill_paged_") {
            Op::PrefillPaged(Mode::parse(mode)?)
        } else if let Some(mode) = base.strip_prefix("prefill_chunk_") {
            // must precede the bare `prefill_` branch, which would
            // otherwise eat the name and choke on Mode::parse("chunk_..")
            Op::PrefillChunk(Mode::parse(mode)?)
        } else if let Some(mode) = base.strip_prefix("prefill_") {
            Op::Prefill { mode: Mode::parse(mode)?, sampled: false }
        } else if let Some(rest) = base.strip_prefix("decode_sampled_") {
            Op::Decode { mode: Mode::parse(strip_bucket(rest))?, sampled: true }
        } else if let Some(mode) = base.strip_prefix("decode_paged_") {
            Op::DecodePaged(Mode::parse(mode)?)
        } else if let Some(mode) = base.strip_prefix("decode_") {
            Op::Decode { mode: Mode::parse(mode)?, sampled: false }
        } else {
            anyhow::bail!("no interpreter program for graph '{name}'")
        };
        Ok(Self { spec, op, name: name.to_string() })
    }

    /// Whether `name` resolves to an interpreter op under `spec`
    /// (registry `has` support, no allocation of the program).
    pub fn resolvable(spec: &Rc<ModelSpec>, name: &str) -> bool {
        Self::parse(spec.clone(), name).is_ok()
    }

    /// Execute on host operands: the weight bundle (param_spec order)
    /// followed by the op's inputs, exactly the compiled graph's operand
    /// list. Returns one host value per graph output.
    pub fn execute(&self, args: &[HostValue]) -> crate::Result<Vec<HostValue>> {
        if matches!(self.op, Op::PrefillShard { .. } | Op::DecodeShard { .. }) {
            anyhow::bail!(
                "{}: sharded graph executes through a DeviceGroup \
                 (execute_sharded), not the scalar path",
                self.name
            );
        }
        let spec = self.spec.as_ref();
        let n = spec.param_names.len();
        anyhow::ensure!(
            args.len() >= n,
            "{}: {} operands given, the weight bundle alone is {n}",
            self.name,
            args.len()
        );
        let mut weights: Vec<&Tensor> = Vec::with_capacity(n);
        for (i, a) in args[..n].iter().enumerate() {
            match a {
                HostValue::F32(t) => weights.push(t),
                HostValue::I32(_) => anyhow::bail!(
                    "{}: weight operand {i} ({}) is not f32",
                    self.name,
                    spec.param_names[i]
                ),
            }
        }
        let params = Params::new(spec, weights)?;
        let x = Extractor { name: &self.name, args: &args[n..] };

        match self.op {
            Op::Fwd(mode) => {
                x.arity(6)?;
                let prefix_kv = x.f32(0, "prefix_kv")?;
                let prefix_len = x.scalar_i32(1, "prefix_len")?;
                let tokens = x.i32(2, "tokens")?;
                let (b, s) = dims2(&tokens.shape, "tokens")?;
                let logits = forward::run_fwd(
                    spec, &params, mode, prefix_kv, prefix_len, &tokens.data,
                    b, s, x.f32(3, "ranges")?, x.scalar_f32(4, "levels")?,
                    x.f32(5, "inv_smooth")?,
                )?;
                Ok(vec![HostValue::F32(logits)])
            }
            Op::Stats => {
                x.arity(3)?;
                let prefix_kv = x.f32(0, "prefix_kv")?;
                let prefix_len = x.scalar_i32(1, "prefix_len")?;
                let tokens = x.i32(2, "tokens")?;
                let (b, s) = dims2(&tokens.shape, "tokens")?;
                let outs = forward::run_stats(spec, &params, prefix_kv,
                                              prefix_len, &tokens.data, b, s)?;
                Ok(outs.into_iter().map(HostValue::F32).collect())
            }
            Op::ScoreLq => {
                x.arity(6)?;
                let prefix_tokens = x.i32(0, "prefix_tokens")?;
                let prefix_len = x.scalar_i32(1, "prefix_len")?;
                let cands = x.i32(2, "cands")?;
                let text = x.i32(3, "text")?;
                let lq = forward::run_score(
                    spec, &params, &prefix_tokens.data, prefix_len,
                    &cands.data, &text.data, x.scalar_f32(4, "levels")?,
                    x.f32(5, "inv_smooth")?,
                )?;
                Ok(vec![HostValue::F32(lq)])
            }
            Op::PrefixKv => {
                x.arity(2)?;
                let prefix_tokens = x.i32(0, "prefix_tokens")?;
                let prefix_len = x.scalar_i32(1, "prefix_len")?;
                let kv = forward::run_prefix_kv(spec, &params,
                                                &prefix_tokens.data,
                                                prefix_len)?;
                Ok(vec![HostValue::F32(kv)])
            }
            Op::TuneStep => {
                x.arity(10)?;
                let tokens = x.i32(4, "tokens")?;
                let (b, s) = dims2(&tokens.shape, "tokens")?;
                let (pkv2, m2, v2, loss, lq) = forward::run_tune_step(
                    spec,
                    &params,
                    x.f32(0, "prefix_kv")?,
                    x.f32(1, "adam_m")?,
                    x.f32(2, "adam_v")?,
                    x.scalar_i32(3, "step")?,
                    &tokens.data,
                    b,
                    s,
                    x.scalar_i32(5, "prefix_len")?,
                    x.scalar_f32(6, "lambda")?,
                    x.scalar_f32(7, "lr")?,
                    x.scalar_f32(8, "levels")?,
                    x.f32(9, "inv_smooth")?,
                )?;
                Ok(vec![
                    HostValue::F32(pkv2),
                    HostValue::F32(m2),
                    HostValue::F32(v2),
                    HostValue::F32(Tensor::scalar(loss)),
                    HostValue::F32(Tensor::scalar(lq)),
                ])
            }
            Op::Prefill { mode, sampled } => {
                x.arity(10)?;
                let tokens = x.i32(4, "tokens")?;
                let (cache, last) = forward::run_prefill(
                    spec,
                    &params,
                    mode,
                    x.f32(0, "cache")?,
                    x.f32(1, "prefix_kv")?,
                    x.scalar_i32(2, "cushion_len")?,
                    x.scalar_i32(3, "slot")? as usize,
                    &tokens.data,
                    x.scalar_i32(5, "tok_len")?,
                    x.f32(6, "ranges")?,
                    x.scalar_f32(7, "levels")?,
                    x.scalar_f32(8, "kv_levels")?,
                    x.f32(9, "inv_smooth")?,
                )?;
                if sampled {
                    let (ids, tops) =
                        forward::select_tokens(&last.data, 1, spec.vocab);
                    Ok(vec![
                        HostValue::F32(cache),
                        HostValue::I32(IntTensor::scalar(ids[0])),
                        HostValue::F32(Tensor::scalar(tops[0])),
                    ])
                } else {
                    Ok(vec![HostValue::F32(cache), HostValue::F32(last)])
                }
            }
            Op::PrefillChunk(mode) => {
                x.arity(10)?;
                let tokens = x.i32(4, "tokens")?;
                let (cache, last) = forward::run_prefill_chunk(
                    spec,
                    &params,
                    mode,
                    x.f32(0, "cache")?,
                    x.f32(1, "prefix_kv")?,
                    x.scalar_i32(2, "cushion_len")?,
                    x.scalar_i32(3, "slot")? as usize,
                    &tokens.data,
                    x.scalar_i32(5, "done")?,
                    x.f32(6, "ranges")?,
                    x.scalar_f32(7, "levels")?,
                    x.scalar_f32(8, "kv_levels")?,
                    x.f32(9, "inv_smooth")?,
                )?;
                Ok(vec![HostValue::F32(cache), HostValue::F32(last)])
            }
            Op::PrefillPaged(mode) => {
                x.arity(10)?;
                let table = x.i32(1, "block_table")?;
                let tokens = x.i32(4, "tokens")?;
                let (pool, last) = forward::run_prefill_paged(
                    spec,
                    &params,
                    mode,
                    x.f32(0, "pool")?,
                    &table.data,
                    x.f32(2, "prefix_kv")?,
                    x.scalar_i32(3, "cushion_len")?,
                    &tokens.data,
                    x.scalar_i32(5, "tok_len")?,
                    x.f32(6, "ranges")?,
                    x.scalar_f32(7, "levels")?,
                    x.scalar_f32(8, "kv_levels")?,
                    x.f32(9, "inv_smooth")?,
                )?;
                Ok(vec![HostValue::F32(pool), HostValue::F32(last)])
            }
            Op::DecodePaged(mode) => {
                x.arity(9)?;
                let tables = x.i32(1, "block_tables")?;
                let lens = x.i32(2, "cache_tok_len")?;
                let tokens = x.i32(4, "tokens")?;
                let (n_lanes, _w) = dims2(&tables.shape, "block_tables")?;
                let (pool, logits) = forward::run_decode_paged(
                    spec,
                    &params,
                    mode,
                    x.f32(0, "pool")?,
                    &tables.data,
                    n_lanes,
                    &lens.data,
                    x.scalar_i32(3, "cushion_len")?,
                    &tokens.data,
                    x.f32(5, "ranges")?,
                    x.scalar_f32(6, "levels")?,
                    x.scalar_f32(7, "kv_levels")?,
                    x.f32(8, "inv_smooth")?,
                )?;
                Ok(vec![HostValue::F32(pool), HostValue::F32(logits)])
            }
            Op::Decode { mode, sampled } => {
                x.arity(8)?;
                let lens = x.i32(1, "cache_tok_len")?;
                let tokens = x.i32(3, "tokens")?;
                let (cache, logits) = forward::run_decode(
                    spec,
                    &params,
                    mode,
                    x.f32(0, "cache")?,
                    &lens.data,
                    x.scalar_i32(2, "cushion_len")?,
                    &tokens.data,
                    x.f32(4, "ranges")?,
                    x.scalar_f32(5, "levels")?,
                    x.scalar_f32(6, "kv_levels")?,
                    x.f32(7, "inv_smooth")?,
                )?;
                if sampled {
                    let b = tokens.data.len();
                    let (ids, tops) =
                        forward::select_tokens(&logits.data, b, spec.vocab);
                    Ok(vec![
                        HostValue::F32(cache),
                        HostValue::I32(IntTensor::vec(ids)),
                        HostValue::F32(Tensor::new(vec![b], tops)),
                    ])
                } else {
                    Ok(vec![HostValue::F32(cache), HostValue::F32(logits)])
                }
            }
            Op::PrefillShard { .. } | Op::DecodeShard { .. } => {
                unreachable!("guarded above")
            }
        }
    }

    /// Execute one shard of a tensor-parallel serving op. Operands are
    /// the shard's sliced weight bundle (param order, attention/MLP
    /// columns only) followed by the op's inputs with the per-shard
    /// cache/prefix slices; the forward pass all-gathers on `bus` at
    /// each collective point. Outputs mirror the unsharded op: the
    /// shard-local cache plus logits identical on every shard.
    pub fn execute_sharded(
        &self,
        args: &[HostValue],
        bus: &crate::runtime::collective::CollectiveBus,
    ) -> crate::Result<Vec<HostValue>> {
        let spec = self.spec.as_ref();
        let n = spec.param_names.len();
        anyhow::ensure!(
            args.len() >= n,
            "{}: {} operands given, the weight bundle alone is {n}",
            self.name,
            args.len()
        );
        let mut weights: Vec<&Tensor> = Vec::with_capacity(n);
        for (i, a) in args[..n].iter().enumerate() {
            match a {
                HostValue::F32(t) => weights.push(t),
                HostValue::I32(_) => anyhow::bail!(
                    "{}: weight operand {i} ({}) is not f32",
                    self.name,
                    spec.param_names[i]
                ),
            }
        }
        let params = Params::new(spec, weights)?;
        let x = Extractor { name: &self.name, args: &args[n..] };

        match self.op {
            Op::PrefillShard { mode, shard, n_shards } => {
                anyhow::ensure!(
                    bus.n_shards() == n_shards,
                    "{}: bus has {} shards, graph wants {n_shards}",
                    self.name,
                    bus.n_shards()
                );
                let plan = crate::runtime::collective::ShardPlan::new(
                    shard, n_shards,
                );
                x.arity(10)?;
                let tokens = x.i32(4, "tokens")?;
                let (cache, last) = forward::run_prefill_sharded(
                    spec,
                    &params,
                    mode,
                    x.f32(0, "cache")?,
                    x.f32(1, "prefix_kv")?,
                    x.scalar_i32(2, "cushion_len")?,
                    x.scalar_i32(3, "slot")? as usize,
                    &tokens.data,
                    x.scalar_i32(5, "tok_len")?,
                    x.f32(6, "ranges")?,
                    x.scalar_f32(7, "levels")?,
                    x.scalar_f32(8, "kv_levels")?,
                    x.f32(9, "inv_smooth")?,
                    plan,
                    bus,
                )?;
                Ok(vec![HostValue::F32(cache), HostValue::F32(last)])
            }
            Op::DecodeShard { mode, shard, n_shards } => {
                anyhow::ensure!(
                    bus.n_shards() == n_shards,
                    "{}: bus has {} shards, graph wants {n_shards}",
                    self.name,
                    bus.n_shards()
                );
                let plan = crate::runtime::collective::ShardPlan::new(
                    shard, n_shards,
                );
                x.arity(8)?;
                let lens = x.i32(1, "cache_tok_len")?;
                let tokens = x.i32(3, "tokens")?;
                let (cache, logits) = forward::run_decode_sharded(
                    spec,
                    &params,
                    mode,
                    x.f32(0, "cache")?,
                    &lens.data,
                    x.scalar_i32(2, "cushion_len")?,
                    &tokens.data,
                    x.f32(4, "ranges")?,
                    x.scalar_f32(5, "levels")?,
                    x.scalar_f32(6, "kv_levels")?,
                    x.f32(7, "inv_smooth")?,
                    plan,
                    bus,
                )?;
                Ok(vec![HostValue::F32(cache), HostValue::F32(logits)])
            }
            _ => anyhow::bail!("{}: not a sharded graph", self.name),
        }
    }
}

/// `<op>_<mode>_s<k>of<n>` -> (`<op>_<mode>`, k, n). Returns None when
/// the name carries no shard suffix (the unsharded graphs).
fn strip_shard(base: &str) -> Option<(&str, usize, usize)> {
    let i = base.rfind("_s")?;
    let tail = &base[i + 2..];
    let j = tail.find("of")?;
    let (ks, ns) = (&tail[..j], &tail[j + 2..]);
    if ks.is_empty() || ns.is_empty()
        || !ks.bytes().all(|c| c.is_ascii_digit())
        || !ns.bytes().all(|c| c.is_ascii_digit())
    {
        return None;
    }
    Some((&base[..i], ks.parse().ok()?, ns.parse().ok()?))
}

/// `prefill_sampled_<mode>_b<bucket>` -> `<mode>` (the interpreter is
/// length-polymorphic, the bucket is only part of the artifact name).
fn strip_bucket(rest: &str) -> &str {
    match rest.rfind("_b") {
        Some(i) if rest[i + 2..].chars().all(|c| c.is_ascii_digit())
            && i + 2 < rest.len() =>
        {
            &rest[..i]
        }
        _ => rest,
    }
}

fn dims2(shape: &[usize], what: &str) -> crate::Result<(usize, usize)> {
    anyhow::ensure!(shape.len() == 2, "{what}: expected rank 2, got {shape:?}");
    Ok((shape[0], shape[1]))
}

/// Typed operand accessors with op-contextual errors.
struct Extractor<'a> {
    name: &'a str,
    args: &'a [HostValue],
}

impl<'a> Extractor<'a> {
    fn arity(&self, want: usize) -> crate::Result<()> {
        anyhow::ensure!(
            self.args.len() == want,
            "{}: expected {want} operands after the weights, got {}",
            self.name,
            self.args.len()
        );
        Ok(())
    }

    fn f32(&self, i: usize, what: &str) -> crate::Result<&'a Tensor> {
        match self.args.get(i) {
            Some(HostValue::F32(t)) => Ok(t),
            Some(HostValue::I32(_)) => {
                anyhow::bail!("{}: operand {what} is i32, expected f32", self.name)
            }
            None => anyhow::bail!("{}: operand {what} missing", self.name),
        }
    }

    fn i32(&self, i: usize, what: &str) -> crate::Result<&'a IntTensor> {
        match self.args.get(i) {
            Some(HostValue::I32(t)) => Ok(t),
            Some(HostValue::F32(_)) => {
                anyhow::bail!("{}: operand {what} is f32, expected i32", self.name)
            }
            None => anyhow::bail!("{}: operand {what} missing", self.name),
        }
    }

    fn scalar_f32(&self, i: usize, what: &str) -> crate::Result<f32> {
        let t = self.f32(i, what)?;
        anyhow::ensure!(t.data.len() == 1, "{}: {what} not a scalar", self.name);
        Ok(t.data[0])
    }

    fn scalar_i32(&self, i: usize, what: &str) -> crate::Result<i32> {
        let t = self.i32(i, what)?;
        anyhow::ensure!(t.data.len() == 1, "{}: {what} not a scalar", self.name);
        Ok(t.data[0])
    }
}

/// Derive the interpreter spec for a variant (manifest + constants).
pub fn spec_for(manifest: &Manifest) -> crate::Result<Rc<ModelSpec>> {
    Ok(Rc::new(ModelSpec::from_manifest(manifest)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Rc<ModelSpec> {
        let m = Manifest::parse(
            r#"{"variant":"t","vocab":8,"d_model":4,"n_layers":1,"n_heads":1,
             "n_kv_heads":1,"d_head":4,"d_ff":8,"norm":"rmsnorm_pre",
             "act":"swiglu","pos":"rope","window":0,"n_sites":4,
             "seq_len":8,"m_max":2,"cache_cap":10,"serve_batch":2,
             "eval_batch":2,"score_batch":4,"score_text_len":6,
             "tune_batch":2,"params":[],"graphs":[]}"#,
        )
        .unwrap();
        spec_for(&m).unwrap()
    }

    #[test]
    fn parses_graph_names() {
        let s = spec();
        for (name, op) in [
            ("fwd_fp", Op::Fwd(Mode::Fp)),
            ("fwd_ptk_pallas", Op::Fwd(Mode::Ptk)),
            ("stats", Op::Stats),
            ("score_lq", Op::ScoreLq),
            ("prefix_kv", Op::PrefixKv),
            ("tune_step", Op::TuneStep),
            ("prefill_pts", Op::Prefill { mode: Mode::Pts, sampled: false }),
            (
                "prefill_sampled_fp_b32",
                Op::Prefill { mode: Mode::Fp, sampled: true },
            ),
            (
                "prefill_sampled_ptd_b128",
                Op::Prefill { mode: Mode::Ptd, sampled: true },
            ),
            ("decode_fp", Op::Decode { mode: Mode::Fp, sampled: false }),
            (
                "decode_sampled_ptk",
                Op::Decode { mode: Mode::Ptk, sampled: true },
            ),
            ("prefill_paged_fp", Op::PrefillPaged(Mode::Fp)),
            ("decode_paged_pts", Op::DecodePaged(Mode::Pts)),
            ("prefill_chunk_fp", Op::PrefillChunk(Mode::Fp)),
            ("prefill_chunk_pts", Op::PrefillChunk(Mode::Pts)),
        ] {
            let p = InterpProgram::parse(s.clone(), name).unwrap();
            assert_eq!(p.op, op, "{name}");
        }
    }

    fn spec2() -> Rc<ModelSpec> {
        let m = Manifest::parse(
            r#"{"variant":"t2","vocab":8,"d_model":4,"n_layers":1,"n_heads":2,
             "n_kv_heads":2,"d_head":2,"d_ff":8,"norm":"rmsnorm_pre",
             "act":"swiglu","pos":"rope","window":0,"n_sites":4,
             "seq_len":8,"m_max":2,"cache_cap":10,"serve_batch":2,
             "eval_batch":2,"score_batch":4,"score_text_len":6,
             "tune_batch":2,"params":[],"graphs":[]}"#,
        )
        .unwrap();
        spec_for(&m).unwrap()
    }

    #[test]
    fn parses_sharded_names() {
        let s2 = spec2();
        let p = InterpProgram::parse(s2.clone(), "decode_fp_s1of2").unwrap();
        assert_eq!(p.op,
                   Op::DecodeShard { mode: Mode::Fp, shard: 1, n_shards: 2 });
        let p = InterpProgram::parse(s2.clone(), "prefill_ptk_s0of2").unwrap();
        assert_eq!(p.op,
                   Op::PrefillShard { mode: Mode::Ptk, shard: 0, n_shards: 2 });
        // shard index out of range
        assert!(InterpProgram::parse(s2.clone(), "decode_fp_s2of2").is_err());
        // spec() has one KV head: indivisible counts fail at resolve
        assert!(InterpProgram::parse(spec(), "decode_fp_s0of2").is_err());
        // sampled/paged graphs have no sharded variants
        assert!(
            InterpProgram::parse(s2.clone(), "prefill_sampled_fp_s0of2").is_err()
        );
        assert!(
            InterpProgram::parse(s2.clone(), "decode_paged_fp_s0of2").is_err()
        );
        // the scalar execute path refuses sharded ops outright
        let p = InterpProgram::parse(s2, "decode_fp_s0of2").unwrap();
        let err = p.execute(&[]).unwrap_err();
        assert!(format!("{err:#}").contains("execute_sharded"), "{err:#}");
    }

    #[test]
    fn rejects_unknown_names() {
        let s = spec();
        for name in [
            "fwd_int3", "warmup", "prefill_", "decode_sampled_zzz",
            "decode_paged_zzz", "prefill_paged_", "prefill_chunk_",
            "prefill_chunk_zzz",
        ] {
            assert!(
                InterpProgram::parse(s.clone(), name).is_err(),
                "{name} should not parse"
            );
            assert!(!InterpProgram::resolvable(&s, name));
        }
        assert!(InterpProgram::resolvable(&s, "decode_sampled_pts"));
    }
}
