//! The runtime client: a cloneable handle over the active execution
//! backend (`runtime::backend::Backend`) — PJRT when the `xla` feature
//! is enabled and the native client comes up, the pure-Rust reference
//! interpreter otherwise. All uploads go through here so the transfer
//! counters and the `DeviceBuf` residency model are uniform across
//! backends.

use std::rc::Rc;

use super::backend::{Backend, BackendKind, DeviceBuf, RefBackend};
use crate::util::tensor::Tensor;

/// Shared backend handle. `Rc` (not `Arc`): the PJRT buffer types are
/// single-threaded and every runtime structure above this is already
/// per-thread (see model::resident's locking note).
#[derive(Clone)]
pub struct Client {
    backend: Rc<dyn Backend>,
}

impl Client {
    /// The PJRT CPU backend. Errors when the `xla` feature is off or the
    /// native client cannot be constructed (e.g. the vendored API stub).
    #[cfg(feature = "xla")]
    pub fn cpu() -> crate::Result<Self> {
        let b = super::backend::PjrtBackend::cpu()?;
        Ok(Self { backend: Rc::new(b) })
    }

    /// The PJRT CPU backend (unavailable in this build: no `xla` feature).
    #[cfg(not(feature = "xla"))]
    pub fn cpu() -> crate::Result<Self> {
        anyhow::bail!(
            "PJRT backend unavailable: built without the `xla` feature \
             (use Client::reference() or CUSHION_BACKEND=ref)"
        )
    }

    /// The pure-Rust reference interpreter backend.
    pub fn reference() -> Self {
        Self { backend: Rc::new(RefBackend) }
    }

    /// Construct per the selection rules (backend.rs module docs):
    /// honor `CUSHION_BACKEND`, else try PJRT and fall back to the
    /// interpreter with one log line.
    pub fn auto() -> crate::Result<Self> {
        Self::of_kind(BackendKind::from_env()?)
    }

    pub fn of_kind(kind: BackendKind) -> crate::Result<Self> {
        let base = match kind {
            BackendKind::Reference => Self::reference(),
            BackendKind::Pjrt => Self::cpu()?,
            BackendKind::Auto => match Self::cpu() {
                Ok(c) => c,
                Err(e) => {
                    log::info!(
                        "PJRT unavailable ({e:#}); using the reference \
                         interpreter backend"
                    );
                    Self::reference()
                }
            },
        };
        base.with_env_faults()
    }

    /// Wrap the backend in `runtime::faults::FaultyBackend` (arming the
    /// plan on this thread if none is armed yet) when `CUSHION_FAULTS`
    /// requests injection. No-op otherwise.
    fn with_env_faults(self) -> crate::Result<Self> {
        match super::faults::FaultPlan::from_env()? {
            None => Ok(self),
            Some(plan) => {
                if !super::faults::armed() {
                    super::faults::arm(plan);
                }
                log::info!(
                    "fault injection armed (CUSHION_FAULTS): wrapping the \
                     {} backend",
                    self.backend.name()
                );
                Ok(Self::with_backend(Rc::new(
                    super::faults::FaultyBackend::wrap(self.backend),
                )))
            }
        }
    }

    /// Wrap an arbitrary backend implementation — the hook the fault
    /// harness and tests use to interpose at the trait boundary.
    pub fn with_backend(backend: Rc<dyn Backend>) -> Self {
        Self { backend }
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// The shared backend handle — the hook for interposing a decorator
    /// (e.g. `faults::FaultyBackend::wrap`) over an existing client.
    pub fn backend_shared(&self) -> Rc<dyn Backend> {
        self.backend.clone()
    }

    /// Whether this client executes compiled HLO artifacts (false = the
    /// reference interpreter, where graphs resolve to interp programs).
    pub fn compiles_artifacts(&self) -> bool {
        self.backend.compiles_artifacts()
    }

    pub fn is_reference(&self) -> bool {
        !self.backend.compiles_artifacts()
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn device_count(&self) -> usize {
        self.backend.device_count()
    }

    /// Upload an f32 host tensor into backend residency.
    pub fn upload(&self, t: &Tensor) -> crate::Result<DeviceBuf> {
        self.backend
            .upload(&super::literalx::HostValue::F32(t.clone()))
    }

    /// Upload either flavor of host value.
    pub fn upload_host(&self, v: &super::literalx::HostValue) -> crate::Result<DeviceBuf> {
        self.backend.upload(v)
    }

    /// Upload an i32 host tensor.
    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> crate::Result<DeviceBuf> {
        self.backend.upload(&super::literalx::HostValue::I32(
            super::literalx::IntTensor::new(shape.to_vec(), data.to_vec()),
        ))
    }

    /// Upload a literal as-is — the pass-through path for root-tuple
    /// elements (e.g. the serving KV cache) that go straight back into
    /// the next execute call without an f32 round-trip through `Tensor`.
    #[cfg(feature = "xla")]
    pub fn upload_literal(&self, lit: &xla::Literal) -> crate::Result<DeviceBuf> {
        let raw = self.raw()?;
        super::transfer::note_upload(4 * super::literalx::literal_elems(lit));
        let buf = raw
            .buffer_from_host_literal(lit, None)
            .map_err(|e| anyhow::anyhow!("upload literal: {e:?}"))?;
        Ok(DeviceBuf::Pjrt(buf))
    }

    /// The raw PJRT client (artifact compilation, tuple splitters).
    #[cfg(feature = "xla")]
    pub fn raw(&self) -> crate::Result<&xla::PjRtClient> {
        self.backend
            .pjrt()
            .map(|a| a.as_ref())
            .ok_or_else(|| anyhow::anyhow!("not a PJRT-backed client"))
    }
}
