//! Thin wrapper over the PJRT CPU client.

use std::sync::Arc;

use super::transfer;
use crate::util::tensor::Tensor;

/// Shared PJRT client handle. `xla::PjRtClient` is internally
/// reference-counted; we add an Arc so engines/replicas can clone freely.
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    pub fn cpu() -> crate::Result<Self> {
        let inner = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { inner: Arc::new(inner) })
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    /// Upload an f32 host tensor to the device.
    pub fn upload(&self, t: &Tensor) -> crate::Result<xla::PjRtBuffer> {
        transfer::note_upload(4 * t.data.len());
        self.inner
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {:?}: {e:?}", t.shape))
    }

    /// Upload either flavor of host value to the device.
    pub fn upload_host(&self, v: &super::literalx::HostValue) -> crate::Result<xla::PjRtBuffer> {
        use super::literalx::HostValue;
        match v {
            HostValue::F32(t) => self.upload(t),
            HostValue::I32(t) => self.upload_i32(&t.data, &t.shape),
        }
    }

    /// Upload an i32 host tensor to the device.
    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> crate::Result<xla::PjRtBuffer> {
        transfer::note_upload(4 * data.len());
        self.inner
            .buffer_from_host_buffer::<i32>(data, shape, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {shape:?}: {e:?}"))
    }

    /// Upload a literal as-is — the pass-through path for root-tuple
    /// elements (e.g. the serving KV cache) that go straight back into the
    /// next execute call without an f32 round-trip through `Tensor`.
    pub fn upload_literal(&self, lit: &xla::Literal) -> crate::Result<xla::PjRtBuffer> {
        transfer::note_upload(4 * super::literalx::literal_elems(lit));
        self.inner
            .buffer_from_host_literal(lit, None)
            .map_err(|e| anyhow::anyhow!("upload literal: {e:?}"))
    }
}
