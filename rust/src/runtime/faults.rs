//! Deterministic fault injection at the `Backend` trait boundary.
//!
//! A seeded, schedule-driven `FaultPlan` (env `CUSHION_FAULTS`, CLI
//! `--faults`) arms a **thread-local** fault state; `FaultyBackend`
//! wraps any `Backend` and consults that state on every `execute` /
//! `upload` / `fetch_*` call, injecting:
//!
//! * **transient faults** — each call independently fails with
//!   probability `execute=` / `upload=` / `fetch=`; a retry can succeed;
//! * **persistent faults** — `persistent=<op>` fails *every* call of
//!   that op until the degradation ladder reaches `heal=<rung>`
//!   (modeling a fault that lives in the device path: once the engine
//!   downgrades past it, calls succeed again);
//! * **transfer stalls** — `stall_ms=` injects latency into every
//!   upload/fetch;
//! * **torn writes** — `torn=` makes `util::fsutil::write_atomic` crash
//!   mid-write (truncated temp file, no rename), proving the
//!   crash-consistency of `cushion::store`.
//!
//! State is thread-local on purpose: `cargo test` runs tests on
//! separate threads, so one test's armed plan can never leak into
//! another, while the serving stack (scheduler/engine/backend) is
//! single-threaded per serve loop and sees the plan it armed.
//!
//! Injected errors carry a typed payload (`InjectedFault`) and a
//! greppable `Display` (`fault-injected(transient): execute fault #3`)
//! so `classify` survives `anyhow` re-wrapping at any layer.

use std::cell::RefCell;
use std::fmt;
use std::rc::Rc;
use std::time::Duration;

use super::backend::{Backend, DeviceBuf};
use super::literalx::{HostValue, IntTensor, Outputs};
use crate::util::prng::SplitMix64;
use crate::util::tensor::Tensor;

/// Which backend operation a fault targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    Execute,
    Upload,
    Fetch,
}

impl FaultOp {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultOp::Execute => "execute",
            FaultOp::Upload => "upload",
            FaultOp::Fetch => "fetch",
        }
    }

    fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "execute" => FaultOp::Execute,
            "upload" => FaultOp::Upload,
            "fetch" => FaultOp::Fetch,
            other => anyhow::bail!(
                "unknown fault op '{other}' (execute | upload | fetch)"
            ),
        })
    }
}

/// The typed error an injection produces. Survives as the anyhow root
/// cause unless a layer re-formats it, in which case the `Display`
/// prefix keeps it classifiable (`classify`).
#[derive(Clone, Copy, Debug)]
pub struct InjectedFault {
    pub op: FaultOp,
    pub transient: bool,
    /// Injection sequence number (1-based) under the armed plan.
    pub seq: u64,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "fault-injected({}): {} fault #{}",
            if self.transient { "transient" } else { "persistent" },
            self.op.as_str(),
            self.seq
        )
    }
}

impl std::error::Error for InjectedFault {}

/// The error a killed replica produces on every call. Its `Display`
/// deliberately matches *neither* `classify` arm — `with_retry` will
/// not retry it and `recover_decode_fault` will not preempt around it,
/// so it propagates out of `Scheduler::step` as an engine-level `Err`
/// and lands in the router's fault-domain layer, which is the only
/// machinery that can actually recover (quarantine + migrate).
#[derive(Clone, Copy, Debug)]
pub struct ReplicaDown {
    /// The plan's `replica=` selector (None = the whole process).
    pub replica: Option<usize>,
    /// The `kill_replica_after=` threshold that was crossed.
    pub after: u64,
}

impl fmt::Display for ReplicaDown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.replica {
            Some(k) => write!(
                f,
                "fault-injected(replica-down): replica {k} dead after {} calls",
                self.after
            ),
            None => write!(
                f,
                "fault-injected(replica-down): replica dead after {} calls",
                self.after
            ),
        }
    }
}

impl std::error::Error for ReplicaDown {}

/// Whether an error is a whole-replica kill (`kill_replica_after=`) —
/// typed downcast first, greppable `Display` fallback, exactly like
/// `classify`. The router reports these as chaos kills rather than
/// genuine engine bugs; both quarantine the replica either way.
pub fn is_replica_down(e: &anyhow::Error) -> bool {
    e.downcast_ref::<ReplicaDown>().is_some()
        || format!("{e:#}").contains("fault-injected(replica-down)")
}

/// A parsed fault schedule. Deterministic given `seed`: the same plan
/// over the same call sequence injects the same faults.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-call transient failure probability by op.
    pub p_execute: f64,
    pub p_upload: f64,
    pub p_fetch: f64,
    /// An op that fails on *every* call until the ladder heals it.
    pub persistent: Option<FaultOp>,
    /// Ladder rung at which injection stops (`set_rung`): models a
    /// fault localized to the path the ladder downgrades away from.
    pub heal_rung: u32,
    /// Injected latency per upload/fetch (transfer stall).
    pub stall: Duration,
    /// Torn-write probability for `fsutil::write_atomic`.
    pub p_torn: f64,
    /// Cap on total injections (0 = unlimited).
    pub max_injections: u64,
    /// Restrict injection to one shard of a tensor-parallel group
    /// (`runtime::collective::DeviceGroup` arms the plan only on the
    /// matching shard thread). None = every shard / the whole process.
    pub shard: Option<usize>,
    /// Restrict injection to one replica of a router fleet. Unlike
    /// `shard` (gated at arm time — shards live on their own threads),
    /// every replica steps on the serve thread, so the router marks the
    /// current replica (`set_replica`) around each engine's calls and
    /// injection fires only while the marker matches. None = everywhere.
    pub replica: Option<usize>,
    /// Whole-replica kill: after N execute-class calls on the selected
    /// replica, *every* subsequent backend call there fails permanently
    /// with an error the retry/ladder machinery cannot classify as
    /// recoverable — the replica is dead and only the router's fault
    /// domain (quarantine + failover migration) can save its work.
    /// Ignores `heal=` and `max=`. 0 = disabled.
    pub kill_after: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0,
            p_execute: 0.0,
            p_upload: 0.0,
            p_fetch: 0.0,
            persistent: None,
            heal_rung: 1,
            stall: Duration::ZERO,
            p_torn: 0.0,
            max_injections: 0,
            shard: None,
            replica: None,
            kill_after: 0,
        }
    }
}

impl FaultPlan {
    /// Parse a comma-separated `key=value` spec:
    ///
    /// `seed=N,execute=P,upload=P,fetch=P,persistent=<op>,heal=N,`
    /// `stall_ms=N,torn=P,max=N,shard=K,replica=K,kill_replica_after=N`
    pub fn parse(spec: &str) -> crate::Result<Self> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("fault spec '{part}': expected key=value")
            })?;
            let prob = |v: &str| -> crate::Result<f64> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault spec {key}={v}: not a number"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "fault spec {key}={v}: probability must be in [0, 1]"
                );
                Ok(p)
            };
            let int = |v: &str| -> crate::Result<u64> {
                v.parse()
                    .map_err(|_| anyhow::anyhow!("fault spec {key}={v}: not an integer"))
            };
            match key {
                "seed" => plan.seed = int(val)?,
                "execute" => plan.p_execute = prob(val)?,
                "upload" => plan.p_upload = prob(val)?,
                "fetch" => plan.p_fetch = prob(val)?,
                "persistent" => plan.persistent = Some(FaultOp::parse(val)?),
                "heal" => plan.heal_rung = int(val)? as u32,
                "stall_ms" => plan.stall = Duration::from_millis(int(val)?),
                "torn" => plan.p_torn = prob(val)?,
                "max" => plan.max_injections = int(val)?,
                "shard" => plan.shard = Some(int(val)? as usize),
                "replica" => plan.replica = Some(int(val)? as usize),
                "kill_replica_after" => plan.kill_after = int(val)?,
                other => anyhow::bail!(
                    "unknown fault spec key '{other}' (seed | execute | upload \
                     | fetch | persistent | heal | stall_ms | torn | max | shard \
                     | replica | kill_replica_after)"
                ),
            }
        }
        Ok(plan)
    }

    /// The plan requested by `CUSHION_FAULTS` (None when unset/empty).
    pub fn from_env() -> crate::Result<Option<Self>> {
        match std::env::var("CUSHION_FAULTS") {
            Ok(v) if !v.trim().is_empty() => Ok(Some(Self::parse(&v)?)),
            _ => Ok(None),
        }
    }
}

/// Counters for what the armed plan actually injected — chaos tests
/// assert injection happened; `coordinator::metrics` mirrors the total.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub execute: u64,
    pub upload: u64,
    pub fetch: u64,
    pub stalls: u64,
    pub torn: u64,
}

impl FaultStats {
    /// Total injected *failures* (stalls add latency, not failure).
    pub fn total(&self) -> u64 {
        self.execute + self.upload + self.fetch + self.torn
    }
}

struct FaultState {
    plan: FaultPlan,
    rng: SplitMix64,
    stats: FaultStats,
    rung: u32,
    seq: u64,
    /// Execute-class calls counted toward `kill_replica_after`.
    kill_calls: u64,
    /// Latched once the kill threshold is crossed: the replica stays
    /// dead for the life of the armed plan (re-arming resurrects it —
    /// chaos runs model replacement, not repair).
    killed: bool,
}

thread_local! {
    static STATE: RefCell<Option<FaultState>> = const { RefCell::new(None) };
    /// Which replica's engine is currently executing on this thread.
    /// Set by the router around every engine call; `None` outside a
    /// router (single-engine serving, tests, stores).
    static REPLICA: std::cell::Cell<Option<usize>> =
        const { std::cell::Cell::new(None) };
}

/// Mark the replica whose engine is about to run on this thread (the
/// router brackets every submit/step/cancel with this). Injection under
/// a `replica=K` plan fires only while the marker matches.
pub fn set_replica(r: Option<usize>) {
    REPLICA.with(|c| c.set(r));
}

/// The replica marker currently set on this thread, if any.
pub fn current_replica() -> Option<usize> {
    REPLICA.with(|c| c.get())
}

/// Whether `plan`'s replica selector matches the current marker. A plan
/// without a selector matches everywhere (including outside routers).
fn replica_selected(plan: &FaultPlan) -> bool {
    match plan.replica {
        None => true,
        Some(k) => current_replica() == Some(k),
    }
}

/// Arm `plan` on this thread (replaces any armed plan, resets stats).
pub fn arm(plan: FaultPlan) {
    let rng = SplitMix64::new(plan.seed ^ 0xFA_017);
    STATE.with(|s| {
        *s.borrow_mut() = Some(FaultState {
            plan,
            rng,
            stats: FaultStats::default(),
            rung: 0,
            seq: 0,
            kill_calls: 0,
            killed: false,
        });
    });
}

/// Disarm this thread's plan, returning its final stats.
pub fn disarm() -> Option<FaultStats> {
    STATE.with(|s| s.borrow_mut().take().map(|st| st.stats))
}

pub fn armed() -> bool {
    STATE.with(|s| s.borrow().is_some())
}

/// Stats of the armed plan (zeros when unarmed).
pub fn stats() -> FaultStats {
    STATE.with(|s| s.borrow().as_ref().map(|st| st.stats).unwrap_or_default())
}

/// The plan armed on this thread, if any. `DeviceGroup` uses this to
/// re-arm the driver's plan on each shard thread (state is
/// thread-local, so shard threads never see the driver's arming).
pub fn plan() -> Option<FaultPlan> {
    STATE.with(|s| s.borrow().as_ref().map(|st| st.plan.clone()))
}

/// Fold a shard thread's final stats into this thread's armed state so
/// chaos tests (which disarm on the driver thread) see one aggregate
/// injection count for the whole group. No-op when unarmed.
pub fn absorb(extra: FaultStats) {
    STATE.with(|s| {
        if let Some(st) = s.borrow_mut().as_mut() {
            st.stats.execute += extra.execute;
            st.stats.upload += extra.upload;
            st.stats.fetch += extra.fetch;
            st.stats.stalls += extra.stalls;
            st.stats.torn += extra.torn;
        }
    });
}

/// Record the degradation ladder's current rung: once
/// `rung >= plan.heal_rung`, injection stops (the fault has been
/// downgraded around). Called by the scheduler on each downgrade.
pub fn set_rung(r: u32) {
    STATE.with(|s| {
        if let Some(st) = s.borrow_mut().as_mut() {
            st.rung = r;
        }
    });
}

pub fn rung() -> u32 {
    STATE.with(|s| s.borrow().as_ref().map(|st| st.rung).unwrap_or(0))
}

/// Roll the dice for one backend call of `op`.
fn roll(op: FaultOp) -> Option<InjectedFault> {
    let hit = roll_inner(op);
    if let Some(f) = hit {
        super::trace::instant("fault_inject", "fault", None, &[
            ("op", format!("{:?}", f.op)),
            ("transient", (f.transient as u8).to_string()),
            ("seq", f.seq.to_string()),
        ]);
    }
    hit
}

fn roll_inner(op: FaultOp) -> Option<InjectedFault> {
    STATE.with(|s| {
        let mut s = s.borrow_mut();
        let st = s.as_mut()?;
        if !replica_selected(&st.plan) {
            return None;
        }
        if st.rung >= st.plan.heal_rung {
            return None;
        }
        if st.plan.max_injections > 0 && st.stats.total() >= st.plan.max_injections {
            return None;
        }
        let transient = if st.plan.persistent == Some(op) {
            false
        } else {
            let p = match op {
                FaultOp::Execute => st.plan.p_execute,
                FaultOp::Upload => st.plan.p_upload,
                FaultOp::Fetch => st.plan.p_fetch,
            };
            if p <= 0.0 || st.rng.next_f64() >= p {
                return None;
            }
            true
        };
        st.seq += 1;
        match op {
            FaultOp::Execute => st.stats.execute += 1,
            FaultOp::Upload => st.stats.upload += 1,
            FaultOp::Fetch => st.stats.fetch += 1,
        }
        Some(InjectedFault { op, transient, seq: st.seq })
    })
}

/// Execute-class injection point for paths that never cross a
/// `Backend` boundary — the tensor-parallel shard threads execute
/// interpreter programs directly on host values, so each shard consults
/// the (per-thread re-armed) plan here, exactly as
/// `FaultyBackend::execute` would.
pub fn inject_execute() -> crate::Result<()> {
    maybe_stall();
    if let Some(k) = check_kill(true) {
        return Err(k.into());
    }
    if let Some(f) = roll(FaultOp::Execute) {
        return Err(f.into());
    }
    Ok(())
}

/// Consult the whole-replica kill schedule for one backend call.
/// Execute-class calls (`counts = true`) advance the countdown; once
/// the threshold is crossed, *every* call on the selected replica —
/// counted or not — fails with `ReplicaDown`. Deliberately ignores
/// `heal=` (a ladder rung cannot route around a dead replica) and
/// `max=` (death is a state, not a scheduled injection).
fn check_kill(counts: bool) -> Option<ReplicaDown> {
    STATE.with(|s| {
        let mut s = s.borrow_mut();
        let st = s.as_mut()?;
        if st.plan.kill_after == 0 || !replica_selected(&st.plan) {
            return None;
        }
        if !st.killed {
            if !counts {
                return None;
            }
            st.kill_calls += 1;
            if st.kill_calls < st.plan.kill_after {
                return None;
            }
            st.killed = true;
            log::warn!(
                "chaos: replica {:?} killed after {} execute calls",
                st.plan.replica,
                st.plan.kill_after
            );
        }
        Some(ReplicaDown { replica: st.plan.replica, after: st.plan.kill_after })
    })
}

/// Sleep out the plan's transfer stall, if any (upload/fetch latency).
fn maybe_stall() {
    let stall = STATE.with(|s| {
        let mut s = s.borrow_mut();
        let st = s.as_mut()?;
        if st.plan.stall.is_zero()
            || st.rung >= st.plan.heal_rung
            || !replica_selected(&st.plan)
        {
            return None;
        }
        st.stats.stalls += 1;
        Some(st.plan.stall)
    });
    if let Some(d) = stall {
        std::thread::sleep(d);
    }
}

/// Whether `fsutil::write_atomic` should simulate a crash mid-write
/// this call (counts toward stats when it fires).
pub fn should_tear() -> bool {
    STATE.with(|s| {
        let mut s = s.borrow_mut();
        let Some(st) = s.as_mut() else { return false };
        if st.plan.p_torn <= 0.0
            || st.rung >= st.plan.heal_rung
            || !replica_selected(&st.plan)
        {
            return false;
        }
        if st.plan.max_injections > 0 && st.stats.total() >= st.plan.max_injections {
            return false;
        }
        if st.rng.next_f64() < st.plan.p_torn {
            st.stats.torn += 1;
            true
        } else {
            false
        }
    })
}

/// Classify an error as an injected fault: `(op, transient)`. Typed
/// downcast first; falls back to the greppable `Display` prefix so
/// classification survives `anyhow!("...: {e}")` re-wrapping.
pub fn classify(e: &anyhow::Error) -> Option<(FaultOp, bool)> {
    if let Some(f) = e.downcast_ref::<InjectedFault>() {
        return Some((f.op, f.transient));
    }
    let msg = format!("{e:#}");
    let transient = if msg.contains("fault-injected(transient)") {
        true
    } else if msg.contains("fault-injected(persistent)") {
        false
    } else {
        return None;
    };
    let op = if msg.contains("execute fault") {
        FaultOp::Execute
    } else if msg.contains("upload fault") {
        FaultOp::Upload
    } else if msg.contains("fetch fault") {
        FaultOp::Fetch
    } else {
        return None;
    };
    Some((op, transient))
}

/// A `Backend` decorator that injects the armed thread-local plan's
/// faults at the trait boundary. Transparent (name aside) when no plan
/// is armed.
pub struct FaultyBackend {
    inner: Rc<dyn Backend>,
}

impl FaultyBackend {
    pub fn wrap(inner: Rc<dyn Backend>) -> Self {
        Self { inner }
    }
}

impl Backend for FaultyBackend {
    fn name(&self) -> &'static str {
        "faulty"
    }

    fn compiles_artifacts(&self) -> bool {
        self.inner.compiles_artifacts()
    }

    fn upload(&self, v: &HostValue) -> crate::Result<DeviceBuf> {
        maybe_stall();
        if let Some(k) = check_kill(false) {
            return Err(k.into());
        }
        if let Some(f) = roll(FaultOp::Upload) {
            return Err(f.into());
        }
        self.inner.upload(v)
    }

    fn fetch_f32(&self, b: &DeviceBuf) -> crate::Result<Tensor> {
        maybe_stall();
        if let Some(k) = check_kill(false) {
            return Err(k.into());
        }
        if let Some(f) = roll(FaultOp::Fetch) {
            return Err(f.into());
        }
        self.inner.fetch_f32(b)
    }

    fn fetch_i32(&self, b: &DeviceBuf) -> crate::Result<IntTensor> {
        maybe_stall();
        if let Some(k) = check_kill(false) {
            return Err(k.into());
        }
        if let Some(f) = roll(FaultOp::Fetch) {
            return Err(f.into());
        }
        self.inner.fetch_i32(b)
    }

    fn execute(
        &self,
        exe: &super::executable::Executable,
        args: &[Rc<DeviceBuf>],
        splitter: Option<&super::split::TupleSplitter>,
    ) -> crate::Result<Outputs> {
        if let Some(k) = check_kill(true) {
            return Err(k.into());
        }
        if let Some(f) = roll(FaultOp::Execute) {
            return Err(f.into());
        }
        self.inner.execute(exe, args, splitter)
    }

    fn platform(&self) -> String {
        format!("{}+faults", self.inner.platform())
    }

    fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    #[cfg(feature = "xla")]
    fn pjrt(&self) -> Option<&std::sync::Arc<xla::PjRtClient>> {
        self.inner.pjrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::RefBackend;
    use crate::util::tensor::Tensor;

    fn host_scalar() -> HostValue {
        HostValue::F32(Tensor::full(&[1], 1.0))
    }

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "seed=7,execute=0.5,upload=0.25,fetch=1,persistent=fetch,\
             heal=2,stall_ms=3,torn=0.1,max=9,shard=1,replica=2,\
             kill_replica_after=50",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.p_execute, 0.5);
        assert_eq!(p.p_upload, 0.25);
        assert_eq!(p.p_fetch, 1.0);
        assert_eq!(p.persistent, Some(FaultOp::Fetch));
        assert_eq!(p.heal_rung, 2);
        assert_eq!(p.stall, Duration::from_millis(3));
        assert_eq!(p.p_torn, 0.1);
        assert_eq!(p.max_injections, 9);
        assert_eq!(p.shard, Some(1));
        assert_eq!(p.replica, Some(2));
        assert_eq!(p.kill_after, 50);
        let d = FaultPlan::parse("execute=1").unwrap();
        assert_eq!(d.shard, None);
        assert_eq!(d.replica, None);
        assert_eq!(d.kill_after, 0);
    }

    #[test]
    fn replica_selector_gates_injection() {
        arm(FaultPlan::parse("seed=1,upload=1,replica=2").unwrap());
        let b = FaultyBackend::wrap(Rc::new(RefBackend));
        // no marker: a replica-targeted plan stays quiet
        assert!(b.upload(&host_scalar()).is_ok());
        set_replica(Some(1));
        assert!(b.upload(&host_scalar()).is_ok(), "wrong replica untouched");
        set_replica(Some(2));
        assert!(b.upload(&host_scalar()).is_err(), "selected replica faults");
        set_replica(None);
        assert_eq!(disarm().unwrap().upload, 1);
    }

    #[test]
    fn replica_kill_latches_and_defeats_classify() {
        arm(FaultPlan::parse("seed=2,replica=0,kill_replica_after=3").unwrap());
        let b = FaultyBackend::wrap(Rc::new(RefBackend));
        set_replica(Some(0));
        // countdown: execute-class calls advance it
        assert!(inject_execute().is_ok());
        assert!(inject_execute().is_ok());
        let err = inject_execute().unwrap_err();
        assert!(is_replica_down(&err), "third call crosses the threshold");
        // neither retryable-transient nor ladder-persistent
        assert_eq!(classify(&err), None);
        // the wrapped form still identifies as a kill
        let rewrapped = anyhow::anyhow!("batched decode: {err:#}");
        assert!(is_replica_down(&rewrapped));
        assert_eq!(classify(&rewrapped), None);
        // dead means dead: every op fails now, even non-counted ones,
        // and healing the ladder does not resurrect it
        set_rung(2);
        assert!(b.upload(&host_scalar()).is_err());
        assert!(inject_execute().is_err());
        // the sibling replica never notices
        set_replica(Some(1));
        assert!(b.upload(&host_scalar()).is_ok());
        assert!(inject_execute().is_ok());
        set_replica(None);
        disarm();
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("execute=1.5").is_err());
        assert!(FaultPlan::parse("persistent=flux").is_err());
        assert!(FaultPlan::parse("seed").is_err());
        // empty / whitespace spec is the default plan
        let p = FaultPlan::parse(" ").unwrap();
        assert_eq!(p.p_execute, 0.0);
        assert!(p.persistent.is_none());
    }

    #[test]
    fn transient_upload_fault_injects_and_classifies() {
        arm(FaultPlan::parse("seed=1,upload=1").unwrap());
        let b = FaultyBackend::wrap(Rc::new(RefBackend));
        let err = b.upload(&host_scalar()).unwrap_err();
        assert_eq!(classify(&err), Some((FaultOp::Upload, true)));
        // classification survives anyhow re-wrapping that loses the type
        let rewrapped = anyhow::anyhow!("uploading weights: {err:#}");
        assert!(rewrapped.downcast_ref::<InjectedFault>().is_none());
        assert_eq!(classify(&rewrapped), Some((FaultOp::Upload, true)));
        let stats = disarm().unwrap();
        assert_eq!(stats.upload, 1);
        assert_eq!(stats.total(), 1);
    }

    #[test]
    fn persistent_fault_heals_at_rung() {
        arm(FaultPlan::parse("seed=3,persistent=upload,heal=1").unwrap());
        let b = FaultyBackend::wrap(Rc::new(RefBackend));
        for _ in 0..3 {
            let err = b.upload(&host_scalar()).unwrap_err();
            assert_eq!(classify(&err), Some((FaultOp::Upload, false)));
        }
        set_rung(1);
        assert!(b.upload(&host_scalar()).is_ok(), "healed past the fault");
        let stats = disarm().unwrap();
        assert_eq!(stats.upload, 3);
    }

    #[test]
    fn max_injections_caps_the_schedule() {
        arm(FaultPlan::parse("seed=5,upload=1,max=2").unwrap());
        let b = FaultyBackend::wrap(Rc::new(RefBackend));
        assert!(b.upload(&host_scalar()).is_err());
        assert!(b.upload(&host_scalar()).is_err());
        assert!(b.upload(&host_scalar()).is_ok(), "cap reached");
        assert_eq!(disarm().unwrap().upload, 2);
    }

    #[test]
    fn unarmed_backend_is_transparent() {
        assert!(!armed());
        let b = FaultyBackend::wrap(Rc::new(RefBackend));
        assert!(b.upload(&host_scalar()).is_ok());
        assert!(!should_tear());
        assert_eq!(stats(), FaultStats::default());
    }

    #[test]
    fn seeded_schedule_is_deterministic() {
        let run = || {
            arm(FaultPlan::parse("seed=11,upload=0.5").unwrap());
            let b = FaultyBackend::wrap(Rc::new(RefBackend));
            let pat: Vec<bool> =
                (0..32).map(|_| b.upload(&host_scalar()).is_err()).collect();
            disarm();
            pat
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.iter().any(|&x| x) && !a.iter().all(|&x| x));
    }
}
