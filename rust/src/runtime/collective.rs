//! Collectives for tensor-parallel sharded execution.
//!
//! With `n_shards > 1` one logical forward runs as N interpreter
//! instances — one logical device per shard, each a thread driving
//! `model::forward`'s sharded runners over its own weight/KV slices.
//! The shards meet at explicit collective points (an all-gather after
//! the attention partials and one after the MLP partials); this module
//! is everything below the model:
//!
//! * [`ShardPlan`] — which KV-head groups / query heads / MLP columns a
//!   shard owns. GQA group-aligned by construction: the unit of
//!   sharding is the whole KV-head group, so a group's query heads can
//!   never split across shards. Divisibility is validated by
//!   [`ShardPlan::validate`] at manifest load, not mid-forward.
//! * Process-global collective counters, mirroring `runtime::transfer`
//!   but in their own gauges: shard-to-shard traffic is "device
//!   interconnect" movement and must never be conflated with the
//!   ≤ 64 KB/step *host* transfer budget.
//! * [`CollectiveBus`] — a generation-counted rendezvous barrier with
//!   poisoning. A shard that fails (error or panic) poisons the bus so
//!   every peer blocked at a collective wakes with a typed error
//!   instead of deadlocking.
//! * [`DeviceGroup`] — runs one closure per shard on scoped threads in
//!   lock-step, arms per-shard fault plans (honoring the
//!   `FaultPlan::shard` selector), records per-shard step skew, and
//!   surfaces exactly one engine-level error for the whole group.
//!
//! Determinism note: `all_gather` returns the parts in shard order and
//! `all_reduce_sum` folds them in shard order with an f64 accumulator
//! on every shard, so each shard computes bit-identical results. The
//! hot serving path uses only all-gather (partials are concatenated,
//! then the replicated second matmuls run on the full tensor), which
//! keeps sharded fp outputs bit-identical to unsharded — summation
//! order never changes.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// One shard's slice of the model: `shard` of `n_shards`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    pub shard: usize,
    pub n_shards: usize,
}

impl ShardPlan {
    pub fn new(shard: usize, n_shards: usize) -> Self {
        assert!(n_shards >= 1 && shard < n_shards, "shard {shard} of {n_shards}");
        Self { shard, n_shards }
    }

    /// Whether a model geometry is shardable `n_shards` ways. Called at
    /// manifest load so a bad `n_shards` fails before any forward runs.
    pub fn validate(n_kv_heads: usize, d_ff: usize, n_shards: usize) -> crate::Result<()> {
        anyhow::ensure!(n_shards >= 1, "n_shards must be >= 1, got {n_shards}");
        anyhow::ensure!(
            n_kv_heads % n_shards == 0,
            "n_kv_heads {n_kv_heads} not divisible by n_shards {n_shards} \
             (shards own whole GQA groups; see README \"Sharded execution\")"
        );
        anyhow::ensure!(
            d_ff % n_shards == 0,
            "d_ff {d_ff} not divisible by n_shards {n_shards}"
        );
        Ok(())
    }

    /// KV-head range `[start, end)` this shard owns.
    pub fn kv_range(&self, n_kv_heads: usize) -> (usize, usize) {
        let per = n_kv_heads / self.n_shards;
        (self.shard * per, (self.shard + 1) * per)
    }

    /// Query-head range: the KV range times the GQA group size, so a
    /// group's query heads always live with their KV head. The shard's
    /// first query head `k0 * g` is divisible by `g`, so the local
    /// `h / g` grouping inside a shard matches the global one.
    pub fn q_range(&self, n_heads: usize, n_kv_heads: usize) -> (usize, usize) {
        let g = n_heads / n_kv_heads;
        let (k0, k1) = self.kv_range(n_kv_heads);
        (k0 * g, k1 * g)
    }

    /// MLP column range `[start, end)` of `d_ff` this shard owns.
    pub fn ff_range(&self, d_ff: usize) -> (usize, usize) {
        let per = d_ff / self.n_shards;
        (self.shard * per, (self.shard + 1) * per)
    }
}

// -- collective traffic accounting ----------------------------------------

static ALL_GATHERS: AtomicU64 = AtomicU64::new(0);
static BYTES_GATHERED: AtomicU64 = AtomicU64::new(0);
static ALL_REDUCES: AtomicU64 = AtomicU64::new(0);
static BYTES_REDUCED: AtomicU64 = AtomicU64::new(0);
static BROADCASTS: AtomicU64 = AtomicU64::new(0);
static BYTES_BROADCAST: AtomicU64 = AtomicU64::new(0);
/// Per-shard execute-time skew (max - min) of the most recent
/// `DeviceGroup::run`, in nanoseconds.
static LAST_SKEW_NANOS: AtomicU64 = AtomicU64::new(0);

/// A point-in-time (or delta) view of the collective counters. Bytes
/// count the payload assembled per collective once (the sum over shard
/// contributions for gather/reduce, the root part for broadcast), not
/// per-receiver fan-out — a deterministic, monotone traffic gauge.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CollectiveStats {
    pub all_gathers: u64,
    pub bytes_gathered: u64,
    pub all_reduces: u64,
    pub bytes_reduced: u64,
    pub broadcasts: u64,
    pub bytes_broadcast: u64,
}

impl CollectiveStats {
    /// Counter movement since `base` (an earlier snapshot).
    pub fn delta_since(&self, base: &CollectiveStats) -> CollectiveStats {
        CollectiveStats {
            all_gathers: self.all_gathers - base.all_gathers,
            bytes_gathered: self.bytes_gathered - base.bytes_gathered,
            all_reduces: self.all_reduces - base.all_reduces,
            bytes_reduced: self.bytes_reduced - base.bytes_reduced,
            broadcasts: self.broadcasts - base.broadcasts,
            bytes_broadcast: self.bytes_broadcast - base.bytes_broadcast,
        }
    }

    /// Total bytes moved by all collective kinds.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_gathered + self.bytes_reduced + self.bytes_broadcast
    }
}

fn note_all_gather(bytes: usize) {
    ALL_GATHERS.fetch_add(1, Ordering::Relaxed);
    BYTES_GATHERED.fetch_add(bytes as u64, Ordering::Relaxed);
}

fn note_all_reduce(bytes: usize) {
    ALL_REDUCES.fetch_add(1, Ordering::Relaxed);
    BYTES_REDUCED.fetch_add(bytes as u64, Ordering::Relaxed);
}

fn note_broadcast(bytes: usize) {
    BROADCASTS.fetch_add(1, Ordering::Relaxed);
    BYTES_BROADCAST.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Current cumulative counters.
pub fn snapshot() -> CollectiveStats {
    CollectiveStats {
        all_gathers: ALL_GATHERS.load(Ordering::Relaxed),
        bytes_gathered: BYTES_GATHERED.load(Ordering::Relaxed),
        all_reduces: ALL_REDUCES.load(Ordering::Relaxed),
        bytes_reduced: BYTES_REDUCED.load(Ordering::Relaxed),
        broadcasts: BROADCASTS.load(Ordering::Relaxed),
        bytes_broadcast: BYTES_BROADCAST.load(Ordering::Relaxed),
    }
}

/// Run `f` and return its result with the collective-counter delta over
/// the call — same metering idiom as `transfer::measure`.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, CollectiveStats) {
    let base = snapshot();
    let r = f();
    (r, snapshot().delta_since(&base))
}

/// Per-shard execute-time skew (max - min) of the most recent group
/// run, in seconds. Zero when no sharded run has happened.
pub fn last_skew_seconds() -> f64 {
    LAST_SKEW_NANOS.load(Ordering::Relaxed) as f64 / 1e9
}

// -- the rendezvous bus ----------------------------------------------------

enum Kind {
    Gather,
    Reduce,
    Broadcast { root: usize },
}

struct BusState {
    /// Rendezvous generation: bumped when the last shard arrives. A
    /// waiter for generation `g` returns once the state reads `> g`.
    generation: u64,
    slots: Vec<Option<Vec<f32>>>,
    arrived: usize,
    /// The assembled parts of the *last completed* generation. Safe to
    /// overwrite at the end of generation `g+1` because no shard can
    /// enter `g+1` before returning from `g` (threads are sequential),
    /// so every reader of generation `g` has already cloned its handle.
    result: Option<Arc<Vec<Vec<f32>>>>,
    poisoned: Option<String>,
}

/// The meeting point of one sharded group run. One bus per
/// `DeviceGroup::run`: generations count collectives within the run,
/// and poisoning is scoped to the run that failed.
pub struct CollectiveBus {
    n_shards: usize,
    state: Mutex<BusState>,
    cv: Condvar,
}

impl CollectiveBus {
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards >= 1);
        Self {
            n_shards,
            state: Mutex::new(BusState {
                generation: 0,
                slots: vec![None; n_shards],
                arrived: 0,
                result: None,
                poisoned: None,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Mark the group failed: every shard waiting at (or later arriving
    /// at) a collective returns an error instead of blocking forever.
    /// First poisoner wins; the message names the failing shard.
    pub fn poison(&self, msg: &str) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.poisoned.is_none() {
            st.poisoned = Some(msg.to_string());
        }
        self.cv.notify_all();
    }

    fn rendezvous(&self, shard: usize, part: Vec<f32>, kind: Kind)
                  -> crate::Result<Arc<Vec<Vec<f32>>>> {
        assert!(shard < self.n_shards, "shard {shard} of {}", self.n_shards);
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(msg) = &st.poisoned {
            anyhow::bail!("collective aborted: {msg}");
        }
        let gen = st.generation;
        assert!(
            st.slots[shard].is_none(),
            "shard {shard} arrived twice at collective generation {gen}"
        );
        st.slots[shard] = Some(part);
        st.arrived += 1;
        if st.arrived == self.n_shards {
            // Last arrival assembles, meters once, and publishes.
            let parts: Vec<Vec<f32>> =
                st.slots.iter_mut().map(|s| s.take().unwrap()).collect();
            let bytes = 4 * parts.iter().map(Vec::len).sum::<usize>();
            match kind {
                Kind::Gather => note_all_gather(bytes),
                Kind::Reduce => note_all_reduce(bytes),
                Kind::Broadcast { root } => note_broadcast(4 * parts[root].len()),
            }
            let res = Arc::new(parts);
            st.result = Some(res.clone());
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
            return Ok(res);
        }
        while st.generation == gen && st.poisoned.is_none() {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if let Some(msg) = &st.poisoned {
            anyhow::bail!("collective aborted: {msg}");
        }
        Ok(st.result.as_ref().unwrap().clone())
    }

    /// Gather every shard's `part`; returns the parts in shard order
    /// (shared, read-only). Parts may differ in length — callers
    /// concatenate along whatever axis they sharded.
    pub fn all_gather(&self, shard: usize, part: Vec<f32>)
                      -> crate::Result<Arc<Vec<Vec<f32>>>> {
        self.rendezvous(shard, part, Kind::Gather)
    }

    /// Element-wise sum across shards. Every shard folds the parts in
    /// shard order with an f64 accumulator, so all shards compute the
    /// same result bit-for-bit.
    pub fn all_reduce_sum(&self, shard: usize, part: Vec<f32>) -> crate::Result<Vec<f32>> {
        let n = part.len();
        let parts = self.rendezvous(shard, part, Kind::Reduce)?;
        anyhow::ensure!(
            parts.iter().all(|p| p.len() == n),
            "all_reduce: shard payload lengths differ"
        );
        let mut out = vec![0f32; n];
        for (i, o) in out.iter_mut().enumerate() {
            let mut acc = 0f64;
            for p in parts.iter() {
                acc += p[i] as f64;
            }
            *o = acc as f32;
        }
        Ok(out)
    }

    /// Every shard receives `root`'s part (non-root contributions are
    /// rendezvous payloads only and are discarded).
    pub fn broadcast(&self, shard: usize, part: Vec<f32>, root: usize)
                     -> crate::Result<Vec<f32>> {
        anyhow::ensure!(root < self.n_shards, "broadcast root {root} out of range");
        let parts = self.rendezvous(shard, part, Kind::Broadcast { root })?;
        Ok(parts[root].clone())
    }
}

// -- the device group ------------------------------------------------------

/// N logical devices run in lock-step. Each `run` spawns one scoped
/// thread per shard (scoped so closures can borrow the engine's
/// per-shard weight slices), meets at the bus's collectives, and joins
/// into either all shards' results (shard order) or exactly one
/// engine-level error.
///
/// Fault injection composes per shard: the driver thread's armed
/// `FaultPlan` is re-armed on each shard thread it applies to (the
/// `shard=K` selector restricts it to one), with the seed varied per
/// shard and per run so retries see fresh rolls and shards don't fault
/// in lock-step. Shard-thread injection counts are folded back into
/// the driver's stats via `faults::absorb`.
pub struct DeviceGroup {
    n_shards: usize,
    runs: AtomicU64,
}

impl DeviceGroup {
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards >= 1);
        Self { n_shards, runs: AtomicU64::new(0) }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Run `f(shard, bus)` once per shard, lock-step through the bus.
    /// On any shard failure the bus is poisoned (peers waiting at a
    /// collective wake immediately — no deadlock) and one error is
    /// returned, preferring a `faults::classify`-able one so the
    /// scheduler's retry/degrade ladder sees the injected fault rather
    /// than a peer's secondary "collective aborted" error.
    pub fn run<T, F>(&self, f: F) -> crate::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, &CollectiveBus) -> crate::Result<T> + Sync,
    {
        let n = self.n_shards;
        let bus = CollectiveBus::new(n);
        let run_id = self.runs.fetch_add(1, Ordering::Relaxed);
        // Shard threads get a clone of the driver's plan, but the
        // injection budget (`max=N`) is global across runs: injections
        // absorbed back into the driver's stats reduce the budget
        // handed to the next run, so a retry after `max` injections
        // runs clean — matching the single-thread FaultyBackend
        // semantics chaos tests rely on.
        let base_plan = super::faults::plan().and_then(|mut p| {
            if p.max_injections > 0 {
                let used = super::faults::stats().total();
                if used >= p.max_injections {
                    return None;
                }
                p.max_injections -= used;
            }
            Some(p)
        });
        let rung = super::faults::rung();

        let mut slots: Vec<Option<crate::Result<T>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let mut nanos = vec![0u64; n];
        let mut injected = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|k| {
                    let bus = &bus;
                    let f = &f;
                    let plan = base_plan.clone();
                    scope.spawn(move || {
                        if let Some(mut p) = plan {
                            if p.shard.map_or(true, |s| s == k) {
                                // Vary the seed per (run, shard): retries
                                // must see fresh rolls, and peers must not
                                // fault in lock-step.
                                p.seed ^= run_id
                                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                                    .wrapping_add(k as u64);
                                super::faults::arm(p);
                                super::faults::set_rung(rung);
                            }
                        }
                        let t0 = std::time::Instant::now();
                        let out = catch_unwind(AssertUnwindSafe(|| f(k, bus)));
                        let dt = t0.elapsed().as_nanos() as u64;
                        let res = match out {
                            Ok(Ok(v)) => Ok(v),
                            Ok(Err(e)) => {
                                bus.poison(&format!("shard {k}/{n} failed: {e:#}"));
                                Err(e)
                            }
                            Err(p) => {
                                let msg = panic_message(&p);
                                bus.poison(&format!("shard {k}/{n} panicked: {msg}"));
                                Err(anyhow::anyhow!("shard {k}/{n} panicked: {msg}"))
                            }
                        };
                        (res, dt, super::faults::disarm())
                    })
                })
                .collect();
            for (k, h) in handles.into_iter().enumerate() {
                let (res, dt, stats) =
                    h.join().expect("shard thread died outside catch_unwind");
                slots[k] = Some(res);
                nanos[k] = dt;
                if let Some(s) = stats {
                    injected.push(s);
                }
            }
        });

        for s in injected {
            super::faults::absorb(s);
        }
        let skew = nanos.iter().max().unwrap_or(&0) - nanos.iter().min().unwrap_or(&0);
        LAST_SKEW_NANOS.store(skew, Ordering::Relaxed);
        if n > 1 {
            super::trace::instant("shard_skew", "collective", None, &[
                ("shards", n.to_string()),
                ("skew_us", (skew / 1_000).to_string()),
            ]);
        }

        let mut results = Vec::with_capacity(n);
        let mut first_err: Option<anyhow::Error> = None;
        let mut classified_err: Option<anyhow::Error> = None;
        for slot in slots {
            match slot.expect("every shard thread was joined") {
                Ok(v) => results.push(v),
                Err(e) => {
                    if classified_err.is_none() && super::faults::classify(&e).is_some() {
                        classified_err = Some(e);
                    } else if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = classified_err.or(first_err) {
            return Err(e);
        }
        Ok(results)
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The collective counters are process-global; serialize the tests
    // that assert exact deltas (same idiom as transfer::tests).
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn shard_plan_partitions_gqa_aligned() {
        ShardPlan::validate(4, 48, 2).unwrap();
        assert!(ShardPlan::validate(3, 48, 2).is_err());
        assert!(ShardPlan::validate(4, 50, 4).is_err());
        assert!(ShardPlan::validate(4, 48, 0).is_err());
        // 8 q heads over 4 kv heads (g=2), 2 shards
        let p0 = ShardPlan::new(0, 2);
        let p1 = ShardPlan::new(1, 2);
        assert_eq!(p0.kv_range(4), (0, 2));
        assert_eq!(p1.kv_range(4), (2, 4));
        assert_eq!(p0.q_range(8, 4), (0, 4));
        assert_eq!(p1.q_range(8, 4), (4, 8));
        assert_eq!(p0.ff_range(48), (0, 24));
        assert_eq!(p1.ff_range(48), (24, 48));
    }

    #[test]
    fn all_gather_orders_and_meters() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let group = DeviceGroup::new(3);
        let ((), d) = measure(|| {
            let outs = group
                .run(|k, bus| {
                    let parts = bus.all_gather(k, vec![k as f32; k + 1])?;
                    Ok(parts.iter().map(Vec::len).collect::<Vec<_>>())
                })
                .unwrap();
            // every shard sees the same shard-ordered parts
            for o in outs {
                assert_eq!(o, vec![1, 2, 3]);
            }
        });
        assert_eq!(d.all_gathers, 1);
        assert_eq!(d.bytes_gathered, 4 * 6);
        assert_eq!(d.all_reduces, 0);
    }

    #[test]
    fn all_reduce_is_identical_on_every_shard() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let group = DeviceGroup::new(4);
        let outs = group
            .run(|k, bus| bus.all_reduce_sum(k, vec![k as f32 + 0.5, 1.0]))
            .unwrap();
        for o in &outs {
            assert_eq!(o, &vec![0.5 + 1.5 + 2.5 + 3.5, 4.0]);
        }
    }

    #[test]
    fn broadcast_takes_root_part() {
        let group = DeviceGroup::new(2);
        let outs = group
            .run(|k, bus| bus.broadcast(k, vec![k as f32], 1))
            .unwrap();
        assert_eq!(outs, vec![vec![1.0], vec![1.0]]);
    }

    #[test]
    fn repeated_collectives_reuse_one_bus() {
        let group = DeviceGroup::new(2);
        let outs = group
            .run(|k, bus| {
                let mut acc = 0.0;
                for step in 0..5 {
                    let parts = bus.all_gather(k, vec![(k + step) as f32])?;
                    acc += parts[0][0] + parts[1][0];
                }
                Ok(acc)
            })
            .unwrap();
        // sum over steps of (step + step+1) = 2*step+1 for step in 0..5
        assert_eq!(outs, vec![25.0, 25.0]);
    }

    #[test]
    fn failed_shard_poisons_peers_no_deadlock() {
        let group = DeviceGroup::new(3);
        let err = group
            .run(|k, bus| {
                if k == 1 {
                    anyhow::bail!("shard 1 exploded before the collective");
                }
                // peers head straight into the collective and must wake
                bus.all_gather(k, vec![0.0])?;
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("shard 1"), "got: {err:#}");
    }

    #[test]
    fn panicked_shard_poisons_peers() {
        let group = DeviceGroup::new(2);
        let err = group
            .run(|k, bus| {
                if k == 0 {
                    panic!("shard 0 hit a wall");
                }
                bus.all_gather(k, vec![1.0])?;
                Ok(())
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked") && msg.contains("shard 0"), "got: {msg}");
    }

    #[test]
    fn shard_selector_arms_only_matching_thread() {
        use crate::runtime::faults::{self, FaultPlan};
        // Kill only shard 1 (persistent execute-class fault); shard 0
        // must finish clean and the group must surface the injected
        // fault as THE error (classifiable), with stats absorbed back.
        faults::arm(FaultPlan::parse("seed=2,persistent=execute,shard=1").unwrap());
        let group = DeviceGroup::new(2);
        let err = group
            .run(|k, bus| {
                if faults::armed() && faults::rung() < 1 {
                    if let Some(p) = faults::plan() {
                        if p.persistent.is_some() {
                            // emulate the backend boundary consulting the plan
                            bus.poison("shard fault path");
                            return Err(anyhow::anyhow!(
                                "fault-injected(persistent): execute fault #1"
                            ));
                        }
                    }
                }
                bus.all_gather(k, vec![k as f32])?;
                Ok(k)
            })
            .unwrap_err();
        assert!(
            faults::classify(&err).is_some(),
            "group error must stay classifiable: {err:#}"
        );
        faults::disarm();
    }

    #[test]
    fn skew_gauge_updates_per_run() {
        let group = DeviceGroup::new(2);
        group
            .run(|k, _bus| {
                if k == 1 {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Ok(())
            })
            .unwrap();
        assert!(last_skew_seconds() >= 0.004, "skew {}", last_skew_seconds());
    }
}
