//! Artifact registry: lazily compiles a variant's graphs by name.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;
use std::sync::Arc;

use super::client::Client;
use super::executable::Executable;

pub struct Registry {
    client: Client,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Registry {
    pub fn new(client: Client, dir: PathBuf) -> Self {
        Self { client, dir, cache: Mutex::new(HashMap::new()) }
    }

    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Get (compiling on first use) the named graph.
    pub fn get(&self, name: &str) -> crate::Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(
            path.exists(),
            "artifact {name} not found at {path:?}; run `make artifacts`"
        );
        let exe = Arc::new(Executable::load(&self.client, name, &path)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    pub fn loaded(&self) -> Vec<String> {
        self.cache.lock().unwrap().keys().cloned().collect()
    }
}
