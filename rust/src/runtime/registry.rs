//! Artifact registry: resolves a variant's graphs by name, lazily.
//!
//! ## Graph-variant naming scheme
//!
//! Artifacts follow `<op>[_sampled]_<mode>[_b<bucket>]` (mirrored in
//! python/compile/graphs.py, which lowers them):
//!
//! * `<op>` — `fwd` | `prefill` | `decode` | `stats` | `score_lq` |
//!   `prefix_kv` | `tune_step`
//! * `_sampled` — greedy token selection runs *in-graph*; the graph
//!   outputs `(cache, next_token_ids i32, top_logit f32)` instead of
//!   `(cache, logits)`, so only token ids cross to the host.
//! * `<mode>` — activation-quantization granularity: `fp` | `pts` |
//!   `ptd` | `ptk`.
//! * `_b<bucket>` — prefill lowered at a shorter token-vector length
//!   (manifest `prefill_buckets`); the engine picks the smallest bucket
//!   >= prompt length.
//!
//! Examples: `decode_sampled_pts`, `prefill_sampled_fp_b32`,
//! `fwd_ptk_pallas` (Pallas-kernel eval build). The logits-emitting base
//! graphs (`decode_pts`, `prefill_pts`) remain the parity/fallback path
//! for artifacts produced before a variant existed.
//!
//! ## Resolution order (interpreter fallback)
//!
//! `get(name)` resolves, in order:
//!
//! 1. **Compiled artifact** — when the client's backend executes
//!    artifacts (PJRT) *and* `<name>.hlo.txt` exists in the variant
//!    directory: compile and cache it (the seed behavior).
//! 2. **Interpreter program** — when a model spec has been installed
//!    (`enable_interp`, done by `Session::load*`): parse the name into a
//!    `runtime::interp` op and run it on the reference interpreter. This
//!    is the *only* path on the `ref` backend, and the per-graph
//!    degradation path on PJRT when an artifact is missing (stale or
//!    partially regenerated artifact dirs keep serving).
//! 3. Error naming both failures.
//!
//! `has(name)` answers "would `get` succeed" under the same order, so
//! engine feature probes (`decode_sampled_*` availability, prefill
//! buckets) automatically see the interpreter's full inventory on the
//! reference backend.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;
use std::sync::Mutex;

use super::client::Client;
use super::executable::{Executable, Program};
use super::interp::InterpProgram;
use crate::model::forward::ModelSpec;

pub struct Registry {
    client: Client,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Installed by `Session::load*`; enables interpreter resolution.
    interp: Mutex<Option<Rc<ModelSpec>>>,
    /// Degradation-ladder bottom rung: when set, every graph resolves
    /// to its interpreter program even where a compiled artifact exists
    /// (the artifact path is what keeps faulting).
    force_interp: std::sync::atomic::AtomicBool,
}

impl Registry {
    pub fn new(client: Client, dir: PathBuf) -> Self {
        Self {
            client,
            dir,
            cache: Mutex::new(HashMap::new()),
            interp: Mutex::new(None),
            force_interp: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Force (or stop forcing) interpreter resolution for every graph —
    /// the ladder's last rung. Enabling drops cached compiled
    /// executables so already-resolved graphs re-resolve under the new
    /// policy on their next use.
    pub fn force_interp(&self, on: bool) {
        use std::sync::atomic::Ordering;
        let was = self.force_interp.swap(on, Ordering::Relaxed);
        if on != was {
            self.cache.lock().unwrap().clear();
        }
    }

    pub fn interp_forced(&self) -> bool {
        self.force_interp.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Install the model spec that lets unresolved graph names fall back
    /// to reference-interpreter programs.
    pub fn enable_interp(&self, spec: Rc<ModelSpec>) {
        *self.interp.lock().unwrap() = Some(spec);
    }

    /// The interpreter spec, when installed.
    pub fn interp_spec(&self) -> Option<Rc<ModelSpec>> {
        self.interp.lock().unwrap().clone()
    }

    /// Whether the named graph's compiled artifact exists on disk (and
    /// this client can execute it).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.client.compiles_artifacts()
            && !self.interp_forced()
            && self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Whether `get(name)` would resolve — compiled artifact or
    /// interpreter program.
    pub fn has(&self, name: &str) -> bool {
        if self.has_artifact(name) {
            return true;
        }
        self.interp
            .lock()
            .unwrap()
            .as_ref()
            .is_some_and(|spec| InterpProgram::resolvable(spec, name))
    }

    /// Whether the *optional* graph `name` should be picked over its
    /// `base` fallback: true when `name` resolves without downgrading
    /// the execution class — it has a compiled artifact, or `base`
    /// would itself run on the interpreter. Engine feature probes
    /// (`decode_sampled_*`, bucketed prefill) go through this rather
    /// than `has`, so a partially regenerated artifact dir keeps the
    /// hot path on the compiled base graphs instead of silently moving
    /// it onto the (much slower, host-resident) interpreter, while a
    /// fully artifact-less checkout still gets the interpreter's full
    /// inventory.
    pub fn has_upgrade(&self, name: &str, base: &str) -> bool {
        if self.has_artifact(name) {
            return true;
        }
        !self.has_artifact(base) && self.has(name)
    }

    /// Get (resolving on first use) the named graph.
    pub fn get(&self, name: &str) -> crate::Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let exe = Arc::new(self.resolve(name)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn resolve(&self, name: &str) -> crate::Result<Executable> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if self.has_artifact(name) {
            return Executable::load(&self.client, name, &path);
        }
        let spec = self.interp.lock().unwrap().clone();
        if let Some(spec) = spec {
            match InterpProgram::parse(spec, name) {
                Ok(ip) => {
                    if self.client.compiles_artifacts() {
                        log::debug!(
                            "artifact {name} not found at {path:?}; \
                             resolving to the reference interpreter"
                        );
                    }
                    return Ok(Executable::from_program(
                        &self.client,
                        name,
                        Program::Interp(ip),
                    ));
                }
                Err(e) => anyhow::bail!(
                    "graph {name}: no artifact at {path:?} and no \
                     interpreter program ({e:#}); run `make artifacts`"
                ),
            }
        }
        anyhow::bail!(
            "artifact {name} not found at {path:?}; run `make artifacts`"
        )
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    pub fn loaded(&self) -> Vec<String> {
        self.cache.lock().unwrap().keys().cloned().collect()
    }
}
