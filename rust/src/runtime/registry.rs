//! Artifact registry: lazily compiles a variant's graphs by name.
//!
//! ## Graph-variant naming scheme
//!
//! Artifacts follow `<op>[_sampled]_<mode>[_b<bucket>]` (mirrored in
//! python/compile/graphs.py, which lowers them):
//!
//! * `<op>` — `fwd` | `prefill` | `decode` | `stats` | `score_lq` |
//!   `prefix_kv` | `tune_step`
//! * `_sampled` — greedy token selection runs *in-graph*; the graph
//!   outputs `(cache, next_token_ids i32, top_logit f32)` instead of
//!   `(cache, logits)`, so only token ids cross to the host.
//! * `<mode>` — activation-quantization granularity: `fp` | `pts` |
//!   `ptd` | `ptk`.
//! * `_b<bucket>` — prefill lowered at a shorter token-vector length
//!   (manifest `prefill_buckets`); the engine picks the smallest bucket
//!   >= prompt length.
//!
//! Examples: `decode_sampled_pts`, `prefill_sampled_fp_b32`,
//! `fwd_ptk_pallas` (Pallas-kernel eval build). The logits-emitting base
//! graphs (`decode_pts`, `prefill_pts`) remain the parity/fallback path
//! for artifacts produced before a variant existed.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;
use std::sync::Arc;

use super::client::Client;
use super::executable::Executable;

pub struct Registry {
    client: Client,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Registry {
    pub fn new(client: Client, dir: PathBuf) -> Self {
        Self { client, dir, cache: Mutex::new(HashMap::new()) }
    }

    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Whether the named graph's artifact exists on disk. Callers use
    /// this (not just the manifest's graph list) to pick optional
    /// variants — e.g. `decode_sampled_*` — so a stale manifest or a
    /// partially regenerated artifact dir degrades to the base graphs
    /// instead of failing at execute time.
    pub fn has(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Get (compiling on first use) the named graph.
    pub fn get(&self, name: &str) -> crate::Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(
            path.exists(),
            "artifact {name} not found at {path:?}; run `make artifacts`"
        );
        let exe = Arc::new(Executable::load(&self.client, name, &path)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    pub fn loaded(&self) -> Vec<String> {
        self.cache.lock().unwrap().keys().cloned().collect()
    }
}
