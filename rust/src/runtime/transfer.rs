//! Process-wide host<->device transfer accounting.
//!
//! Every upload (`Client::upload*`) and every fetch (`literalx::fetch_*`,
//! the root-tuple materialization in `Outputs::from_execute`) bumps these
//! counters, so the serving metrics and the perf benches can attribute
//! step time to marshalling vs graph execution and — more importantly —
//! prove that loop-invariant operands (weights, ranges, inv_smooth, the
//! cushion prefix KV) are *not* re-crossing the PCIe/host boundary per
//! step. See model::resident for the per-operand upload counts.
//!
//! Counters are process-global atomics: cheap, always on, and safe to
//! read from any thread. Consumers take a `snapshot()` before a region
//! and `delta_since` after it.

use std::sync::atomic::{AtomicU64, Ordering};

static UPLOADS: AtomicU64 = AtomicU64::new(0);
static BYTES_UPLOADED: AtomicU64 = AtomicU64::new(0);
static FETCHES: AtomicU64 = AtomicU64::new(0);
static BYTES_FETCHED: AtomicU64 = AtomicU64::new(0);

/// A point-in-time (or delta) view of the transfer counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransferStats {
    pub uploads: u64,
    pub bytes_uploaded: u64,
    pub fetches: u64,
    pub bytes_fetched: u64,
}

impl TransferStats {
    /// Counter movement since `base` (an earlier snapshot).
    pub fn delta_since(&self, base: &TransferStats) -> TransferStats {
        TransferStats {
            uploads: self.uploads - base.uploads,
            bytes_uploaded: self.bytes_uploaded - base.bytes_uploaded,
            fetches: self.fetches - base.fetches,
            bytes_fetched: self.bytes_fetched - base.bytes_fetched,
        }
    }
}

/// Record one host->device upload of `bytes`.
pub fn note_upload(bytes: usize) {
    UPLOADS.fetch_add(1, Ordering::Relaxed);
    BYTES_UPLOADED.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Record one device->host fetch of `bytes`.
pub fn note_fetch(bytes: usize) {
    FETCHES.fetch_add(1, Ordering::Relaxed);
    BYTES_FETCHED.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Run `f` and return its result with the transfer-counter delta over
/// the call — the metering idiom of the scheduler's per-step gauges and
/// the perf benches.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, TransferStats) {
    let base = snapshot();
    let r = f();
    (r, snapshot().delta_since(&base))
}

/// Emit a `transfer` trace instant for a measured delta. Inert when the
/// tracer is disabled or the delta is empty, so callers can invoke it
/// unconditionally on the hot path.
pub fn trace_delta(delta: &TransferStats) {
    if *delta == TransferStats::default() {
        return;
    }
    crate::runtime::trace::instant("transfer", "xfer", None, &[
        ("uploads", delta.uploads.to_string()),
        ("bytes_up", delta.bytes_uploaded.to_string()),
        ("fetches", delta.fetches.to_string()),
        ("bytes_down", delta.bytes_fetched.to_string()),
    ]);
}

/// Current cumulative counters.
pub fn snapshot() -> TransferStats {
    TransferStats {
        uploads: UPLOADS.load(Ordering::Relaxed),
        bytes_uploaded: BYTES_UPLOADED.load(Ordering::Relaxed),
        fetches: FETCHES.load(Ordering::Relaxed),
        bytes_fetched: BYTES_FETCHED.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The counters are process-global; serialize the tests that bump
    // them so the exact-equality assertions stay deterministic.
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn deltas_track_notes() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let base = snapshot();
        note_upload(128);
        note_upload(64);
        note_fetch(256);
        let d = snapshot().delta_since(&base);
        assert_eq!(d.uploads, 2);
        assert_eq!(d.bytes_uploaded, 192);
        assert_eq!(d.fetches, 1);
        assert_eq!(d.bytes_fetched, 256);
    }

    #[test]
    fn measure_scopes_delta() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let (r, d) = measure(|| {
            note_upload(32);
            7
        });
        assert_eq!(r, 7);
        assert_eq!(d.uploads, 1);
        assert_eq!(d.bytes_uploaded, 32);
        assert_eq!(d.fetches, 0);
    }
}
