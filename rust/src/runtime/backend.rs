//! Execution-backend abstraction: one trait (`Backend`) with two
//! implementations —
//!
//! * **PJRT** (`PjrtBackend`, `xla` cargo feature): uploads host tensors
//!   to device buffers and executes AOT-compiled HLO artifacts, exactly
//!   as the seed runtime did.
//! * **Reference interpreter** (`RefBackend`, always available): a pure
//!   Rust implementation where "device residency" is host memory
//!   (`DeviceBuf::Host`) and graphs resolve to `runtime::interp` programs
//!   that run the tiny-transformer forward pass directly on
//!   `util::tensor::Tensor` (`model::forward`). No artifacts, no XLA
//!   toolchain, bit-identical semantics to the lowered graphs within the
//!   float budget pinned by `rust/tests/interp_parity.rs`.
//!
//! ## Selection rules (see also README "Backends")
//!
//! 1. `CUSHION_BACKEND=ref` (or `--backend ref` on the CLI, which sets
//!    it) forces the interpreter.
//! 2. `CUSHION_BACKEND=xla` (alias `pjrt`) forces PJRT; client
//!    construction failure is a hard error.
//! 3. Unset / `auto`: try PJRT, fall back to the interpreter with one
//!    log line. The stub `xla` crate build (third_party/xla) always
//!    lands here, so a toolchain-less checkout transparently runs on the
//!    interpreter.
//!
//! Graph-level fallback is separate and finer-grained: even under a PJRT
//! client, `runtime::registry` resolves any graph whose artifact is
//! missing on disk to an interpreter program (see the registry docs for
//! the resolution order), so a stale or partial artifact directory
//! degrades per-graph instead of failing.
//!
//! The interpreter backend meters `runtime::transfer` exactly like PJRT
//! — an upload or fetch models the host/device boundary crossing the
//! real backend would pay — so residency invariants (ResidentPool upload
//! counts, per-step byte budgets) stay observable hermetically.

use std::rc::Rc;

use super::literalx::{HostValue, IntTensor};
use super::transfer;
use crate::util::tensor::Tensor;

/// A backend-resident value: what `Value::Device` wraps and what execute
/// calls consume/produce. The PJRT arm only exists with the `xla`
/// feature; the `Host` arm is the reference backend's residency (and is
/// what a stale-artifact interpreter fallback produces under PJRT).
pub enum DeviceBuf {
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtBuffer),
    Host(HostValue),
}

impl DeviceBuf {
    /// Element count when known host-side (None for PJRT buffers, whose
    /// shape lives on device until fetched).
    pub fn host_elems(&self) -> Option<usize> {
        match self {
            #[cfg(feature = "xla")]
            DeviceBuf::Pjrt(_) => None,
            DeviceBuf::Host(HostValue::F32(t)) => Some(t.data.len()),
            DeviceBuf::Host(HostValue::I32(t)) => Some(t.data.len()),
        }
    }

    /// Borrow the host value (reference backend residency).
    pub fn as_host(&self) -> Option<&HostValue> {
        match self {
            DeviceBuf::Host(v) => Some(v),
            #[cfg(feature = "xla")]
            DeviceBuf::Pjrt(_) => None,
        }
    }

    /// Bring this value to the host, metering the fetch.
    pub fn fetch_f32(&self) -> crate::Result<Tensor> {
        match self {
            #[cfg(feature = "xla")]
            DeviceBuf::Pjrt(b) => super::literalx::pjrt_fetch_f32(b),
            DeviceBuf::Host(HostValue::F32(t)) => {
                transfer::note_fetch(4 * t.data.len());
                Ok(t.clone())
            }
            DeviceBuf::Host(HostValue::I32(_)) => {
                anyhow::bail!("fetch_f32 on an i32 resident value")
            }
        }
    }

    /// Bring this value to the host as i32 ids, metering the fetch.
    pub fn fetch_i32(&self) -> crate::Result<IntTensor> {
        match self {
            #[cfg(feature = "xla")]
            DeviceBuf::Pjrt(b) => super::literalx::pjrt_fetch_i32(b),
            DeviceBuf::Host(HostValue::I32(t)) => {
                transfer::note_fetch(4 * t.data.len());
                Ok(t.clone())
            }
            DeviceBuf::Host(HostValue::F32(_)) => {
                anyhow::bail!("fetch_i32 on an f32 resident value")
            }
        }
    }
}

/// The execution backend: upload host values into residency, fetch them
/// back, and execute resolved programs (`runtime::Executable`). The
/// `Client` handle wraps one of these behind `Rc<dyn Backend>` and is
/// what the registry/session/engine thread around.
pub trait Backend {
    /// Short name for logs/metrics ("pjrt" | "ref").
    fn name(&self) -> &'static str;

    /// Whether this backend can load + execute compiled HLO artifacts
    /// (drives the registry's resolution order).
    fn compiles_artifacts(&self) -> bool;

    /// Move a host value into backend residency (meters the upload).
    fn upload(&self, v: &HostValue) -> crate::Result<DeviceBuf>;

    /// Fetch a resident value to the host as f32 (meters the fetch).
    fn fetch_f32(&self, b: &DeviceBuf) -> crate::Result<Tensor> {
        b.fetch_f32()
    }

    /// Fetch a resident value to the host as i32 (meters the fetch).
    fn fetch_i32(&self, b: &DeviceBuf) -> crate::Result<IntTensor> {
        b.fetch_i32()
    }

    /// Execute a resolved program on resident operands; outputs stay in
    /// runtime form (`literalx::Outputs`).
    fn execute(
        &self,
        exe: &super::executable::Executable,
        args: &[Rc<DeviceBuf>],
        splitter: Option<&super::split::TupleSplitter>,
    ) -> crate::Result<super::literalx::Outputs> {
        exe.run_values(args, splitter)
    }

    /// Backend platform string (diagnostics).
    fn platform(&self) -> String {
        self.name().to_string()
    }

    fn device_count(&self) -> usize {
        1
    }

    /// The raw PJRT client, when this backend has one (compilation of
    /// artifacts and tuple-splitter programs needs it).
    #[cfg(feature = "xla")]
    fn pjrt(&self) -> Option<&std::sync::Arc<xla::PjRtClient>> {
        None
    }
}

/// The pure-Rust reference backend: residency is host memory and
/// programs are `runtime::interp` interpreter ops.
pub struct RefBackend;

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn compiles_artifacts(&self) -> bool {
        false
    }

    fn upload(&self, v: &HostValue) -> crate::Result<DeviceBuf> {
        let elems = match v {
            HostValue::F32(t) => t.data.len(),
            HostValue::I32(t) => t.data.len(),
        };
        transfer::note_upload(4 * elems);
        Ok(DeviceBuf::Host(v.clone()))
    }

    fn device_count(&self) -> usize {
        1
    }
}

/// The PJRT CPU backend over the `xla` crate.
#[cfg(feature = "xla")]
pub struct PjrtBackend {
    pub(crate) inner: std::sync::Arc<xla::PjRtClient>,
}

#[cfg(feature = "xla")]
impl PjrtBackend {
    pub fn cpu() -> crate::Result<Self> {
        let inner = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { inner: std::sync::Arc::new(inner) })
    }
}

#[cfg(feature = "xla")]
impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compiles_artifacts(&self) -> bool {
        true
    }

    fn upload(&self, v: &HostValue) -> crate::Result<DeviceBuf> {
        let buf = match v {
            HostValue::F32(t) => {
                transfer::note_upload(4 * t.data.len());
                self.inner
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload f32 {:?}: {e:?}", t.shape))?
            }
            HostValue::I32(t) => {
                transfer::note_upload(4 * t.data.len());
                self.inner
                    .buffer_from_host_buffer::<i32>(&t.data, &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload i32 {:?}: {e:?}", t.shape))?
            }
        };
        Ok(DeviceBuf::Pjrt(buf))
    }

    fn platform(&self) -> String {
        self.inner.platform_name()
    }

    fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    fn pjrt(&self) -> Option<&std::sync::Arc<xla::PjRtClient>> {
        Some(&self.inner)
    }
}

/// Which backend `Client::auto()` / the CLI should construct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Auto,
    Pjrt,
    Reference,
}

impl BackendKind {
    /// Parse a `--backend` / `CUSHION_BACKEND` value.
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => BackendKind::Auto,
            "xla" | "pjrt" => BackendKind::Pjrt,
            "ref" | "interp" | "reference" => BackendKind::Reference,
            other => anyhow::bail!(
                "unknown backend '{other}' (auto | xla | ref)"
            ),
        })
    }

    /// The kind requested by `CUSHION_BACKEND` (Auto when unset).
    pub fn from_env() -> crate::Result<Self> {
        match std::env::var("CUSHION_BACKEND") {
            Ok(v) => Self::parse(&v),
            Err(_) => Ok(BackendKind::Auto),
        }
    }
}
