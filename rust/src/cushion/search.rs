//! Greedy prefix search (paper §4.1, Algorithm 1).
//!
//! At each step, draw a text sample t_{1:n} from the calibration corpus
//! (the paper samples C4; synwiki's calib split is our stand-in), sweep
//! every embedding-table token p as a candidate continuation of the
//! current prefix, and keep the argmin of L_q(t | p_{1:k}, p) — computed
//! by the AOT `score_lq` graph in SCORE_BATCH-sized candidate batches
//! ("batched inference" in the paper). Stop early when the best candidate
//! no longer reduces the error below tau * previous (eq. 10), or at
//! m_max. Optionally warm-start from non-semantic tokens (<bos>), the
//! heuristic the paper §4.1 recommends.

use std::time::Instant;

use crate::data;
use crate::model::session::Session;
use crate::util::prng::SplitMix64;

#[derive(Clone, Debug)]
pub struct SearchCfg {
    /// Early-stopping threshold tau (paper uses 0.5).
    pub tau: f32,
    /// Maximum prefix length (paper's m; bounded by M_MAX).
    pub max_len: usize,
    /// Activation levels used inside the scorer's L_q (2^bits - 1).
    pub levels: f32,
    /// Warm-start tokens (e.g. [<bos>]); empty = cold start.
    pub init: Vec<i32>,
    /// RNG seed for drawing text samples.
    pub seed: u64,
    /// Restrict the sweep to every k-th vocab token (1 = full sweep, the
    /// paper's setting; >1 trades fidelity for wall-clock, used by the
    /// quick examples).
    pub vocab_stride: usize,
}

impl Default for SearchCfg {
    fn default() -> Self {
        Self {
            tau: 0.5,
            max_len: 8,
            levels: 255.0,
            init: vec![],
            seed: 0x5EA7C4,
            vocab_stride: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct SearchResult {
    pub prefix: Vec<i32>,
    /// L_q trace: entry k = error after the prefix had k tokens.
    pub lq_trace: Vec<f32>,
    pub candidates_scored: usize,
    pub seconds: f64,
}

/// Run Algorithm 1 against the session's current weights/smoothing.
pub fn greedy_search(session: &Session, cfg: &SearchCfg) -> crate::Result<SearchResult> {
    let t0 = Instant::now();
    let m = &session.manifest;
    let max_len = cfg.max_len.min(m.m_max);
    let calib = session.corpus.split("calib")?;
    let mut rng = SplitMix64::new(cfg.seed);

    let mut prefix: Vec<i32> = cfg.init.clone();
    anyhow::ensure!(prefix.len() < max_len, "warm start already at max_len");
    let mut scored = 0usize;
    let mut lq_trace = Vec::new();

    // baseline error with the current prefix (scored with a PAD candidate
    // slot appended — the candidate position is masked out of L_q anyway,
    // but we need *some* token there; PAD has an inert embedding).
    let draw_text = |rng: &mut SplitMix64| -> Vec<i32> {
        let i = rng.next_below(calib.n_seqs as u64) as usize;
        calib.seq(i)[..m.score_text_len].to_vec()
    };

    let text0 = draw_text(&mut rng);
    let base = score_one(session, &prefix, data::PAD, &text0, cfg.levels)?;
    lq_trace.push(base);
    let mut prev_lq = base;
    log::info!("[search] start lq={base:.5} prefix={prefix:?}");

    while prefix.len() < max_len {
        let text = draw_text(&mut rng);
        // sweep the embedding table in score_batch-sized chunks
        let mut best: (i32, f32) = (data::PAD, f32::INFINITY);
        let vocab: Vec<i32> = (0..m.vocab as i32)
            .step_by(cfg.vocab_stride)
            .filter(|&t| t != data::PAD)
            .collect();
        for chunk in vocab.chunks(m.score_batch) {
            let mut cands = chunk.to_vec();
            cands.resize(m.score_batch, data::PAD);
            let lqs = session.score_candidates(&prefix, &cands, &text, cfg.levels)?;
            scored += chunk.len();
            for (i, &t) in chunk.iter().enumerate() {
                if lqs[i] < best.1 {
                    best = (t, lqs[i]);
                }
            }
        }
        // eq. 10: accept only if the error drops below tau * previous
        if best.1 > cfg.tau * prev_lq && !prefix.is_empty() {
            log::info!(
                "[search] stop: best lq {:.5} > tau*{:.5}",
                best.1, prev_lq
            );
            break;
        }
        log::info!(
            "[search] += token {} (lq {:.5} -> {:.5})",
            best.0, prev_lq, best.1
        );
        prefix.push(best.0);
        prev_lq = best.1;
        lq_trace.push(best.1);
    }

    Ok(SearchResult {
        prefix,
        lq_trace,
        candidates_scored: scored,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Score a single (prefix, candidate) pair on a text sample.
fn score_one(session: &Session, prefix: &[i32], cand: i32, text: &[i32],
             levels: f32) -> crate::Result<f32> {
    let m = &session.manifest;
    let cands = vec![cand; m.score_batch];
    Ok(session.score_candidates(prefix, &cands, text, levels)?[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cfg_matches_paper() {
        let c = SearchCfg::default();
        assert_eq!(c.tau, 0.5);
        assert_eq!(c.vocab_stride, 1);
    }
}
