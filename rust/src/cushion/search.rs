//! Greedy prefix search (paper §4.1, Algorithm 1).
//!
//! At each step, draw a text sample t_{1:n} from the calibration corpus
//! (the paper samples C4; synwiki's calib split is our stand-in), sweep
//! every embedding-table token p as a candidate continuation of the
//! current prefix, and keep the argmin of L_q(t | p_{1:k}, p) — computed
//! by the AOT `score_lq` graph in SCORE_BATCH-sized candidate batches
//! ("batched inference" in the paper). Stop early when the best candidate
//! no longer reduces the error below tau * previous (eq. 10), or at
//! m_max. Optionally warm-start from non-semantic tokens (<bos>), the
//! heuristic the paper §4.1 recommends.
//!
//! The eq.-10 comparison is made on a *single* text sample: slot 0 of
//! the first candidate chunk carries a PAD sentinel (PAD is masked out
//! of L_q, so that row scores the incumbent prefix itself), giving the
//! incumbent's error on exactly the sample the sweep is scored on. The
//! seed compared the new best against the error remembered from the
//! previous iteration's freshly drawn sample — two numbers from
//! different texts — which made the early stop fire (or not) on sample
//! noise rather than on the candidate's actual improvement. The sentinel
//! also rides along in the first sweep chunk, so the incumbent costs no
//! extra graph call (the seed's `score_one` paid a whole SCORE_BATCH
//! forward for that one scalar).

use std::time::Instant;

use crate::data;
use crate::model::session::Session;
use crate::util::prng::SplitMix64;

#[derive(Clone, Debug)]
pub struct SearchCfg {
    /// Early-stopping threshold tau (paper uses 0.5).
    pub tau: f32,
    /// Maximum prefix length (paper's m; bounded by M_MAX).
    pub max_len: usize,
    /// Activation levels used inside the scorer's L_q (2^bits - 1).
    pub levels: f32,
    /// Warm-start tokens (e.g. [<bos>]); empty = cold start.
    pub init: Vec<i32>,
    /// RNG seed for drawing text samples.
    pub seed: u64,
    /// Restrict the sweep to every k-th vocab token (1 = full sweep, the
    /// paper's setting; >1 trades fidelity for wall-clock, used by the
    /// quick examples).
    pub vocab_stride: usize,
}

impl Default for SearchCfg {
    fn default() -> Self {
        Self {
            tau: 0.5,
            max_len: 8,
            levels: 255.0,
            init: vec![],
            seed: 0x5EA7C4,
            vocab_stride: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct SearchResult {
    pub prefix: Vec<i32>,
    /// L_q trace: entry k = error after the prefix had k tokens.
    pub lq_trace: Vec<f32>,
    pub candidates_scored: usize,
    pub seconds: f64,
}

/// Run Algorithm 1 against the session's current weights/smoothing.
pub fn greedy_search(session: &Session, cfg: &SearchCfg) -> crate::Result<SearchResult> {
    let t0 = Instant::now();
    let m = &session.manifest;
    let max_len = cfg.max_len.min(m.m_max);
    let calib = session.corpus.split("calib")?;
    let mut rng = SplitMix64::new(cfg.seed);

    let mut prefix: Vec<i32> = cfg.init.clone();
    anyhow::ensure!(prefix.len() < max_len, "warm start already at max_len");
    let mut scored = 0usize;
    let mut lq_trace = Vec::new();

    let draw_text = |rng: &mut SplitMix64| -> Vec<i32> {
        let i = rng.next_below(calib.n_seqs as u64) as usize;
        calib.seq(i)[..m.score_text_len].to_vec()
    };

    // The candidate list is loop-invariant — build it once, with a PAD
    // sentinel at slot 0: PAD's position is masked out of L_q, so that
    // row scores the *incumbent* prefix on the iteration's text sample
    // for free (one slot of the first chunk, not an extra graph call).
    let mut cands_all: Vec<i32> = Vec::with_capacity(m.vocab / cfg.vocab_stride.max(1) + 1);
    cands_all.push(data::PAD);
    cands_all.extend(
        (0..m.vocab as i32)
            .step_by(cfg.vocab_stride)
            .filter(|&t| t != data::PAD),
    );

    while prefix.len() < max_len {
        let text = draw_text(&mut rng);
        // sweep the embedding table in score_batch-sized chunks
        let mut incumbent = f32::INFINITY;
        let mut best: (i32, f32) = (data::PAD, f32::INFINITY);
        for (ci, chunk) in cands_all.chunks(m.score_batch).enumerate() {
            let mut cands = chunk.to_vec();
            cands.resize(m.score_batch, data::PAD);
            let lqs = session.score_candidates(&prefix, &cands, &text, cfg.levels)?;
            // slot 0 of chunk 0 is the sentinel, not a candidate
            let skip = usize::from(ci == 0);
            if ci == 0 {
                incumbent = lqs[0];
            }
            scored += chunk.len() - skip;
            for (i, &t) in chunk.iter().enumerate().skip(skip) {
                if lqs[i] < best.1 {
                    best = (t, lqs[i]);
                }
            }
        }
        if lq_trace.is_empty() {
            lq_trace.push(incumbent);
            log::info!("[search] start lq={incumbent:.5} prefix={prefix:?}");
        }
        // eq. 10: accept only if the error drops below tau * the
        // incumbent's error on the SAME sample (comparable numbers)
        if best.1 > cfg.tau * incumbent && !prefix.is_empty() {
            log::info!(
                "[search] stop: best lq {:.5} > tau*{:.5}",
                best.1, incumbent
            );
            break;
        }
        log::info!(
            "[search] += token {} (lq {:.5} -> {:.5})",
            best.0, incumbent, best.1
        );
        prefix.push(best.0);
        lq_trace.push(best.1);
    }

    Ok(SearchResult {
        prefix,
        lq_trace,
        candidates_scored: scored,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cfg_matches_paper() {
        let c = SearchCfg::default();
        assert_eq!(c.tau, 0.5);
        assert_eq!(c.vocab_stride, 1);
    }
}
