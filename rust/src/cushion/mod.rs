//! CushionCache drivers: greedy prefix search + quantization-aware prefix
//! tuning (paper §4), plus cushion persistence.

pub mod search;
pub mod store;
pub mod tune;

pub use search::{greedy_search, SearchCfg, SearchResult};
pub use store::{load_cushion, save_cushion};
pub use tune::{tune_prefix, TuneCfg, TuneResult};
