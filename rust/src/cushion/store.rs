//! Cushion persistence: save/load a discovered CushionCache (tokens +
//! tuned KV) under artifacts/<variant>/cushions/<name>.bin.
//!
//! Format: "CCK1" | n_tokens | i32[] | ndim | dims u32[] | f32 kv[].

use std::path::PathBuf;

use crate::model::session::Cushion;
use crate::util::fsutil::{self, Cursor};
use crate::util::tensor::Tensor;

pub fn cushion_path(variant: &str, name: &str) -> PathBuf {
    fsutil::variant_dir(variant)
        .join("cushions")
        .join(format!("{name}.bin"))
}

/// Atomic save via `fsutil::write_atomic`: a crash mid-write (real or
/// fault-injected) can never leave a torn `<name>.bin` for the next
/// load to install as the shared prefix KV.
pub fn save_cushion(variant: &str, name: &str, c: &Cushion) -> crate::Result<PathBuf> {
    let path = cushion_path(variant, name);
    std::fs::create_dir_all(path.parent().unwrap())?;
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(b"CCK1");
    buf.extend_from_slice(&(c.tokens.len() as u32).to_le_bytes());
    for t in &c.tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    buf.extend_from_slice(&(c.kv.shape.len() as u32).to_le_bytes());
    for d in &c.kv.shape {
        buf.extend_from_slice(&(*d as u32).to_le_bytes());
    }
    for v in &c.kv.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    fsutil::write_atomic(&path, &buf)?;
    Ok(path)
}

pub fn load_cushion(variant: &str, name: &str) -> crate::Result<Cushion> {
    let path = cushion_path(variant, name);
    let buf = fsutil::read(&path)?;
    let mut c = Cursor::new(&buf);
    c.magic(b"CCK1")?;
    let n = c.u32()? as usize;
    let tokens = c.i32_vec(n)?;
    let nd = c.u32()? as usize;
    let mut dims = Vec::with_capacity(nd);
    for _ in 0..nd {
        dims.push(c.u32()? as usize);
    }
    let kv = Tensor::new(dims.clone(), c.f32_vec(dims.iter().product())?);
    Ok(Cushion { len: tokens.len(), tokens, kv })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        std::env::set_var("CUSHION_ARTIFACTS",
                          std::env::temp_dir().join("cc_store_test").to_str().unwrap());
        let c = Cushion {
            tokens: vec![0, 1, 2],
            len: 3,
            kv: Tensor::new(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]),
        };
        let path = save_cushion("vtest", "default", &c).unwrap();
        assert!(
            !path.with_extension("bin.tmp").exists(),
            "atomic save must not leave the staging file behind"
        );
        let back = load_cushion("vtest", "default").unwrap();
        assert_eq!(back.tokens, c.tokens);
        assert_eq!(back.kv, c.kv);
        assert!(load_cushion("vtest", "missing").is_err());

        // a torn file (e.g. a partial copy) errors instead of yielding a
        // silently-truncated cushion
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_cushion("vtest", "default").is_err(), "torn file");

        // crash consistency under the fault harness: a torn-write
        // injection mid-save errors out, leaves no renamed file, and an
        // existing good cushion survives byte-identical
        let good = save_cushion("vtest", "crashy", &c).unwrap();
        let before = std::fs::read(&good).unwrap();
        crate::runtime::faults::arm(
            crate::runtime::faults::FaultPlan::parse("seed=2,torn=1").unwrap(),
        );
        let err = save_cushion("vtest", "crashy", &c).unwrap_err();
        let stats = crate::runtime::faults::disarm().unwrap();
        assert!(format!("{err:#}").contains("fault-injected(torn)"), "{err:#}");
        assert_eq!(stats.torn, 1);
        assert_eq!(std::fs::read(&good).unwrap(), before, "target file torn");
        assert!(load_cushion("vtest", "crashy").is_ok());
        std::env::remove_var("CUSHION_ARTIFACTS");
    }
}
