//! Quantization-aware prefix tuning (paper §4.2): starting from the
//! greedily-searched prefix's KV, run Adam on the prefix KV itself with
//! loss L = L_pred + lambda * L_q (STE through rounding, stop-grad on
//! scales — all inside the AOT `tune_step` graph; this driver owns the
//! data loop and optimizer state plumbing).

use std::time::Instant;

use crate::model::session::{Cushion, Session};
use crate::runtime::literalx::{HostValue, IntTensor};
use crate::util::prng::SplitMix64;
use crate::util::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct TuneCfg {
    /// Loss balance lambda (paper: 0.01).
    pub lambda: f32,
    pub lr: f32,
    /// Passes over the calibration split (paper: 2).
    pub epochs: usize,
    /// Activation levels for the L_q regularizer.
    pub levels: f32,
    pub seed: u64,
}

impl Default for TuneCfg {
    fn default() -> Self {
        Self { lambda: 0.01, lr: 3e-3, epochs: 2, levels: 255.0, seed: 0x7E5E }
    }
}

#[derive(Clone, Debug)]
pub struct TuneResult {
    pub kv: Tensor,
    pub loss_trace: Vec<f32>,
    pub lq_trace: Vec<f32>,
    pub steps: usize,
    pub seconds: f64,
}

/// Tune the KV of `prefix_tokens` (greedy-search output). Returns the
/// tuned KV; install with `session.set_cushion(Cushion { ... })`.
pub fn tune_prefix(session: &Session, prefix_tokens: &[i32],
                   cfg: &TuneCfg) -> crate::Result<TuneResult> {
    let t0 = Instant::now();
    let m = &session.manifest;
    let mut kv = session.compute_prefix_kv(prefix_tokens)?;
    let mut adam_m = Tensor::zeros(&kv.shape);
    let mut adam_v = Tensor::zeros(&kv.shape);
    let calib = session.corpus.split("calib")?;
    let batches_per_epoch = calib.n_seqs / m.tune_batch;
    let mut rng = SplitMix64::new(cfg.seed);
    let mut order: Vec<usize> = (0..calib.n_seqs).collect();

    let mut loss_trace = Vec::new();
    let mut lq_trace = Vec::new();
    let mut step = 0usize;
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for b in 0..batches_per_epoch {
            let mut tokens = Vec::with_capacity(m.tune_batch * m.seq_len);
            for s in 0..m.tune_batch {
                tokens.extend_from_slice(calib.seq(order[b * m.tune_batch + s]));
            }
            let out = session.run(
                "tune_step",
                &[
                    HostValue::F32(kv.clone()),
                    HostValue::F32(adam_m.clone()),
                    HostValue::F32(adam_v.clone()),
                    HostValue::scalar_i32(step as i32),
                    HostValue::I32(IntTensor::new(
                        vec![m.tune_batch, m.seq_len], tokens)),
                    HostValue::scalar_i32(prefix_tokens.len() as i32),
                    HostValue::scalar_f32(cfg.lambda),
                    HostValue::scalar_f32(cfg.lr),
                    HostValue::scalar_f32(cfg.levels),
                    HostValue::F32(session.inv_smooth().clone()),
                ],
            )?;
            anyhow::ensure!(out.len() == 5, "tune_step: expected 5 outputs");
            let mut it = out.into_iter();
            kv = it.next().unwrap();
            adam_m = it.next().unwrap();
            adam_v = it.next().unwrap();
            let loss = it.next().unwrap().data[0];
            let lq = it.next().unwrap().data[0];
            loss_trace.push(loss);
            lq_trace.push(lq);
            step += 1;
            if step % 4 == 0 {
                log::info!("[tune] step {step} loss {loss:.4} lq {lq:.5}");
            }
        }
    }
    Ok(TuneResult {
        kv,
        loss_trace,
        lq_trace,
        steps: step,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Convenience: build the full cushion (search already done) and install.
pub fn install_tuned(session: &mut Session, prefix_tokens: &[i32],
                     cfg: &TuneCfg) -> crate::Result<TuneResult> {
    let res = tune_prefix(session, prefix_tokens, cfg)?;
    session.set_cushion(Cushion {
        tokens: prefix_tokens.to_vec(),
        len: prefix_tokens.len(),
        kv: res.kv.clone(),
    })?;
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TuneCfg::default();
        assert!((c.lambda - 0.01).abs() < 1e-9);
        assert_eq!(c.epochs, 2);
    }
}
