//! TCP line-protocol front end (JSON lines over std::net — the offline
//! vendor has no HTTP/tokio stack, and a line protocol keeps the demo
//! client trivial: `nc localhost 7199`).
//!
//! ## Protocol (one JSON object per line, both directions)
//!
//! Request:
//!   {"prompt": [1, 2, 3], "max_new": 16}
//! with optional per-request fields:
//!   "stream": true        — one line per generated token before the summary
//!   "echo_text": true     — detokenize the output into a "text" field
//!   "stop_token": 7|null  — override the default stop token (null = none)
//!   "mode": "pts"         — quantization mode (multi-engine router only)
//!   "deadline_ms": 250    — per-request deadline from submission; an
//!                           expired request (queued, preempted, or
//!                           running) finishes with "error": "deadline"
//!                           and its slot and pool blocks are freed
//!
//! Stream line (only with "stream": true), one per generated token:
//!   {"id": 7, "token": 42, "index": 0}
//!
//! Summary line (always the request's final line):
//!   {"id": 7, "tokens": [42, 17], "finish": "max_tokens",
//!    "ttft_ms": 12.1, "tpot_ms": 4.0, "text": "..."}
//! where "finish" is one of "max_tokens" | "stop_token" | "length"
//! (KV capacity reached) | "cancelled" |
//! "error"; on "error" the line also carries "error": "<why>" and "text"
//! appears only when "echo_text" was set. "ttft_ms" is null for a
//! request that never produced a token (rejection, pre-decode cancel,
//! deadline expiry) — never a fake 0.0.
//!
//! Error line (unparseable request — no id was ever assigned):
//!   {"error": "json: ..."}
//! Overload line (bounded admission queue full):
//!   {"id": 7, "finish": "error", "error": "overloaded", ...}
//!
//! ## Fault isolation
//!
//! Every request-level failure — malformed JSON, non-integer or
//! out-of-vocab prompt tokens, an oversized prompt, queue overload, a
//! client disconnect mid-generation — is answered (or logged) on that
//! request alone. The scheduler loop only propagates *engine* failures
//! (a batched decode aborting); a bad request can never take the serving
//! loop down.
//!
//! One acceptor thread; per-connection reader threads submit into an
//! mpsc channel; the scheduler thread owns the engine(s) and steps
//! continuously. Responses and stream lines are rendered on the
//! scheduler thread (which owns the tokenizer) and travel back through
//! per-request channels as finished strings; a failed client write
//! cancels the in-flight request and frees its KV slot.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::data::tokenizer::Tokenizer;
use crate::util::json::{self, Value};

use super::request::{Request, RequestId, Response};
use super::router::{Router, ServeBackend};
use super::scheduler::Scheduler;

/// Default bound on queued+running requests before `overloaded`.
pub const DEFAULT_QUEUE_LIMIT: usize = 64;

/// Read/write timeout on accepted connections: a stuck or byzantine
/// client can hold a reader thread (and, mid-stream, a KV slot) for at
/// most this long before the connection is closed and the request
/// cancelled.
const CONN_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// Upper bound on the graceful-shutdown drain: in-flight requests get
/// this long to finish before the remainder is cancelled.
const DRAIN_DEADLINE: std::time::Duration = std::time::Duration::from_secs(30);

/// SIGINT/SIGTERM → graceful drain. The vendored build has no signal
/// crate, so this uses the raw libc `signal` entry point directly; the
/// handler only stores to an atomic, which is async-signal-safe.
#[cfg(unix)]
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static STOP: AtomicBool = AtomicBool::new(false);
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_sig: i32) {
        STOP.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }

    pub fn pending() -> bool {
        STOP.load(Ordering::Relaxed)
    }
}

#[cfg(not(unix))]
mod signals {
    pub fn install() {}

    pub fn pending() -> bool {
        false
    }
}

enum Inbound {
    Submit {
        req: Request,
        mode: Option<String>,
        back: Sender<Outbound>,
    },
    Cancel(RequestId),
    Shutdown,
    /// Admin wire command ({"cmd":"metrics"} / {"cmd":"trace"}): the
    /// scheduler thread renders one reply line — metrics and the trace
    /// ring both live on that thread, so servicing these between steps
    /// needs no locks.
    Admin { cmd: String, back: Sender<Outbound> },
}

/// Pre-rendered wire lines headed back to one connection.
enum Outbound {
    /// A stream line; more lines follow for this request.
    Line(String),
    /// The request's final line (summary or error).
    Done(String),
}

struct Waiter {
    back: Sender<Outbound>,
    stream: bool,
    n_sent: usize,
}

pub struct Server {
    addr: String,
    queue_limit: usize,
    /// Periodic Prometheus snapshot interval (`--metrics-interval N`);
    /// `None` = snapshots only on demand, at drain entry, and on a
    /// ladder-floor error.
    metrics_interval: Option<std::time::Duration>,
}

impl Server {
    pub fn new(addr: &str) -> Self {
        Self {
            addr: addr.to_string(),
            queue_limit: DEFAULT_QUEUE_LIMIT,
            metrics_interval: None,
        }
    }

    /// Bound on queued+running requests before new ones are refused
    /// with an `overloaded` error line.
    pub fn with_queue_limit(mut self, limit: usize) -> Self {
        self.queue_limit = limit.max(1);
        self
    }

    /// Log a Prometheus metrics snapshot every `secs` seconds while
    /// serving (0 disables periodic snapshots).
    pub fn with_metrics_interval(mut self, secs: u64) -> Self {
        self.metrics_interval =
            (secs > 0).then(|| std::time::Duration::from_secs(secs));
        self
    }

    /// Serve a single scheduler until `stop` flips. Blocks.
    pub fn serve(&self, sched: Scheduler, stop: Arc<AtomicBool>) -> crate::Result<()> {
        self.serve_backend(sched, stop)
    }

    /// Serve a multi-mode router (one process, several quantization
    /// variants and/or several replicas per variant; requests pick a
    /// variant via "mode"). Blocks. The router's step never errors
    /// while any replica is healthy — a broken replica is quarantined
    /// and its work failed over — so a single dead engine can no
    /// longer end the serve loop, unlike the single-scheduler path.
    pub fn serve_router(&self, router: Router, stop: Arc<AtomicBool>) -> crate::Result<()> {
        self.serve_backend(router, stop)
    }

    fn serve_backend<B: ServeBackend>(
        &self,
        mut backend: B,
        stop: Arc<AtomicBool>,
    ) -> crate::Result<()> {
        let listener = TcpListener::bind(&self.addr)?;
        listener.set_nonblocking(true)?;
        signals::install();
        log::info!("cushiond listening on {}", self.addr);
        let (tx, rx): (Sender<Inbound>, Receiver<Inbound>) = channel();
        let next_id = Arc::new(AtomicU64::new(1));
        let vocab = backend.vocab();
        let tokenizer = Tokenizer::new(vocab);

        // scheduler loop on this thread; acceptor inline (non-blocking)
        let mut waiters: HashMap<RequestId, Waiter> = HashMap::new();
        let mut last_floor = backend.floor_errors();
        let mut last_snapshot = std::time::Instant::now();
        loop {
            if stop.load(Ordering::Relaxed) || signals::pending() {
                break;
            }
            // accept new connections
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::debug!("connection from {peer}");
                    let tx = tx.clone();
                    let ids = next_id.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, tx, ids, vocab) {
                            log::warn!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => log::warn!("accept: {e}"),
            }
            // drain inbound submissions / cancellations
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Inbound::Submit { req, mode, back } => {
                        if backend.load() >= self.queue_limit {
                            backend.record_rejected();
                            let resp = Response::rejection(
                                req.id,
                                req.echo_text,
                                "overloaded".to_string(),
                            );
                            let _ = back.send(Outbound::Done(render_response(
                                &resp, None,
                            )));
                            continue;
                        }
                        let id = req.id;
                        let waiter = Waiter {
                            back,
                            stream: req.stream,
                            n_sent: 0,
                        };
                        match backend.submit(mode.as_deref(), req) {
                            Ok(()) => {
                                waiters.insert(id, waiter);
                            }
                            Err(why) => {
                                // routing failure (e.g. unknown mode):
                                // per-request error, loop stays alive
                                let resp = Response::rejection(id, false, why);
                                let _ = waiter
                                    .back
                                    .send(Outbound::Done(render_response(&resp, None)));
                            }
                        }
                    }
                    Inbound::Cancel(id) => {
                        waiters.remove(&id);
                        if backend.cancel(id) {
                            log::debug!("request {id} cancelled (client gone)");
                        }
                    }
                    Inbound::Shutdown => {
                        stop.store(true, Ordering::Relaxed);
                    }
                    Inbound::Admin { cmd, back } => {
                        let _ = back
                            .send(Outbound::Done(admin_response(&backend, &cmd)));
                    }
                }
            }
            // advance the engine(s)
            if backend.has_work() {
                backend.step()?;
                flush_output(&mut backend, &mut waiters, &tokenizer);
                // a run dying at the fault-ladder floor must leave
                // evidence: flush a snapshot the moment the floor
                // counter advances, not only at shutdown
                let floor = backend.floor_errors();
                if floor > last_floor {
                    last_floor = floor;
                    log::warn!(
                        "ladder-floor errors at {floor}; metrics snapshot:\n{}",
                        backend.metrics_text()
                    );
                }
            } else {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            if let Some(iv) = self.metrics_interval {
                if last_snapshot.elapsed() >= iv {
                    last_snapshot = std::time::Instant::now();
                    log::info!("metrics snapshot:\n{}", backend.metrics_text());
                }
            }
        }
        // graceful shutdown: drain — finish the work already accepted
        // (queued, preempted, running) while rejecting new submissions
        // with "overloaded"; anything still unfinished at the drain
        // deadline is cancelled. Then leave the serving metrics in the
        // log — after the drain, so its counters include everything the
        // shutdown finished or cancelled.
        let drain_t0 = std::time::Instant::now();
        backend.drain();
        // metrics used to surface only after the drain completed
        // (log_metrics at the very end) — a drain that hangs or is
        // killed left nothing. Flush a snapshot at drain *entry* so
        // partial runs leave evidence.
        log::info!(
            "drain-entry metrics snapshot:\n{}",
            backend.metrics_text()
        );
        log::info!(
            "shutting down: draining {} in-flight request(s)",
            backend.load()
        );
        while backend.has_work() && drain_t0.elapsed() < DRAIN_DEADLINE {
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Inbound::Submit { req, back, .. } => {
                        backend.record_rejected();
                        let resp = Response::rejection(
                            req.id,
                            req.echo_text,
                            "overloaded".to_string(),
                        );
                        let _ =
                            back.send(Outbound::Done(render_response(&resp, None)));
                    }
                    Inbound::Cancel(id) => {
                        waiters.remove(&id);
                        backend.cancel(id);
                    }
                    Inbound::Shutdown => {}
                    Inbound::Admin { cmd, back } => {
                        let _ = back
                            .send(Outbound::Done(admin_response(&backend, &cmd)));
                    }
                }
            }
            backend.step()?;
            flush_output(&mut backend, &mut waiters, &tokenizer);
            let floor = backend.floor_errors();
            if floor > last_floor {
                last_floor = floor;
                log::warn!(
                    "ladder-floor errors at {floor} during drain; metrics \
                     snapshot:\n{}",
                    backend.metrics_text()
                );
            }
        }
        backend.cancel_all();
        for resp in backend.take_finished() {
            if let Some(w) = waiters.remove(&resp.id) {
                let _ = w
                    .back
                    .send(Outbound::Done(render_response(&resp, Some(&tokenizer))));
            }
        }
        backend.record_drain(drain_t0.elapsed().as_secs_f64());
        backend.log_metrics();
        Ok(())
    }
}

/// Push this step's stream lines and summaries back to their waiters.
/// Stream lines go first: a request's tokens must all be on the wire
/// before its summary line.
fn flush_output<B: ServeBackend>(
    backend: &mut B,
    waiters: &mut HashMap<RequestId, Waiter>,
    tokenizer: &Tokenizer,
) {
    for (id, token) in backend.take_token_events() {
        if let Some(w) = waiters.get_mut(&id) {
            let index = w.n_sent;
            w.n_sent += 1;
            if w.stream {
                let line = render_token_line(id, token, index);
                if w.back.send(Outbound::Line(line)).is_err() {
                    // conn thread is gone: free the slot now
                    waiters.remove(&id);
                    backend.cancel(id);
                }
            }
        }
    }
    for resp in backend.take_finished() {
        if let Some(w) = waiters.remove(&resp.id) {
            let line = render_response(&resp, Some(tokenizer));
            let _ = w.back.send(Outbound::Done(line));
        }
    }
}

/// Render one reply line for an admin wire command. `metrics` returns
/// the Prometheus exposition as a JSON string field; `trace` returns
/// the Chrome-trace export of the serve thread's ring (the scheduler
/// thread is the emitting thread, so the snapshot is exact).
fn admin_response<B: ServeBackend>(backend: &B, cmd: &str) -> String {
    match cmd {
        "metrics" => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("format", json::s("prometheus")),
            ("body", json::s(&backend.metrics_text())),
        ])
        .to_string(),
        "trace" => json::obj(vec![
            ("ok", Value::Bool(true)),
            (
                "trace",
                crate::runtime::trace::chrome_json(
                    &crate::runtime::trace::records(),
                ),
            ),
        ])
        .to_string(),
        other => render_error_line(None, &format!("unknown admin cmd {other:?}")),
    }
}

/// An admin line is a JSON object carrying a string `cmd` field
/// ({"cmd":"metrics"} / {"cmd":"trace"}); anything else — including
/// every ordinary request, which has no `cmd` — falls through to
/// `parse_request`.
pub fn parse_admin(line: &str) -> Option<String> {
    match json::parse(line).ok()?.get("cmd") {
        Some(Value::Str(s)) => Some(s.clone()),
        _ => None,
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<Inbound>,
    ids: Arc<AtomicU64>,
    vocab: usize,
) -> crate::Result<()> {
    // bound how long a stuck client can hold this thread: reads and
    // writes both time out, after which the connection is closed (and
    // any in-flight request cancelled by the writer path below)
    stream.set_read_timeout(Some(CONN_TIMEOUT))?;
    stream.set_write_timeout(Some(CONN_TIMEOUT))?;
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut writer = peer;
    loop {
        let mut raw = String::new();
        match reader.read_line(&mut raw) {
            Ok(0) => break, // EOF: client closed the connection
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                log::debug!("connection idle past {CONN_TIMEOUT:?}; closing");
                break;
            }
            Err(e) => return Err(e.into()),
        }
        let line: &str = raw.trim_end_matches(['\r', '\n']);
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "quit" {
            let _ = tx.send(Inbound::Shutdown);
            break;
        }
        if let Some(cmd) = parse_admin(line) {
            let (back_tx, back_rx) = channel();
            if tx.send(Inbound::Admin { cmd, back: back_tx }).is_err() {
                let _ =
                    writeln!(writer, "{}", render_error_line(None, "scheduler gone"));
                break;
            }
            match back_rx.recv() {
                Ok(Outbound::Done(l)) | Ok(Outbound::Line(l)) => {
                    if writeln!(writer, "{l}").and_then(|_| writer.flush()).is_err() {
                        return Ok(());
                    }
                }
                Err(_) => {
                    let _ = writeln!(
                        writer,
                        "{}",
                        render_error_line(None, "scheduler gone")
                    );
                    return Ok(());
                }
            }
            continue;
        }
        match parse_request(line, &ids, vocab) {
            Ok((req, mode)) => {
                let id = req.id;
                let (back_tx, back_rx) = channel();
                if tx
                    .send(Inbound::Submit {
                        req,
                        mode,
                        back: back_tx,
                    })
                    .is_err()
                {
                    let _ = writeln!(writer, "{}", render_error_line(None, "scheduler gone"));
                    break;
                }
                loop {
                    match back_rx.recv() {
                        Ok(Outbound::Line(l)) => {
                            if writeln!(writer, "{l}").and_then(|_| writer.flush()).is_err()
                            {
                                // client disconnected mid-stream: cancel
                                // the request so its KV slot frees up
                                let _ = tx.send(Inbound::Cancel(id));
                                return Ok(());
                            }
                        }
                        Ok(Outbound::Done(l)) => {
                            if writeln!(writer, "{l}").is_err() {
                                return Ok(());
                            }
                            break;
                        }
                        Err(_) => {
                            let _ = writeln!(
                                writer,
                                "{}",
                                render_error_line(Some(id), "cancelled")
                            );
                            return Ok(());
                        }
                    }
                }
            }
            Err(e) => {
                writeln!(writer, "{}", render_error_line(None, &format!("{e:#}")))?;
            }
        }
    }
    Ok(())
}

/// Parse one request line. Strict about the prompt: every entry must be
/// an integer token id inside `[0, vocab)` — a hostile prompt must not
/// be able to index outside the embedding table, and silently dropping
/// bad entries (the old `filter_map`) hid client bugs.
pub fn parse_request(
    line: &str,
    ids: &AtomicU64,
    vocab: usize,
) -> crate::Result<(Request, Option<String>)> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e:#}"))?;
    let arr = v
        .req("prompt")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, el) in arr.iter().enumerate() {
        let n = el
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("prompt[{i}] is not a number"))?;
        if !n.is_finite() || n.fract() != 0.0 {
            anyhow::bail!("prompt[{i}] is not an integer token id: {n}");
        }
        if n < 0.0 || n >= vocab as f64 {
            anyhow::bail!("prompt[{i}] = {n} outside vocab [0, {vocab})");
        }
        prompt.push(n as i32);
    }
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = v.get("max_new").and_then(Value::as_usize).unwrap_or(16);
    let mut req = Request::new(ids.fetch_add(1, Ordering::Relaxed), prompt, max_new);
    if let Some(stop) = v.get("stop_token") {
        req.stop_token = match stop {
            Value::Null => None,
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i32),
            other => anyhow::bail!("stop_token must be an integer or null, got {other}"),
        };
    }
    req.echo_text = v.get("echo_text").and_then(Value::as_bool).unwrap_or(false);
    req.stream = v.get("stream").and_then(Value::as_bool).unwrap_or(false);
    if let Some(d) = v.get("deadline_ms") {
        let n = d
            .as_f64()
            .filter(|n| n.is_finite() && *n > 0.0 && n.fract() == 0.0)
            .ok_or_else(|| {
                anyhow::anyhow!("deadline_ms must be a positive integer, got {d}")
            })?;
        req.deadline = Some(std::time::Duration::from_millis(n as u64));
    }
    let mode = match v.get("mode") {
        None | Some(Value::Null) => None,
        Some(Value::Str(s)) => Some(s.clone()),
        Some(other) => anyhow::bail!("mode must be a string, got {other}"),
    };
    Ok((req, mode))
}

/// One stream line per generated token.
pub fn render_token_line(id: RequestId, token: i32, index: usize) -> String {
    json::obj(vec![
        ("id", json::num(id as f64)),
        ("token", json::num(token as f64)),
        ("index", json::num(index as f64)),
    ])
    .to_string()
}

/// An error line for a request that never got (or lost) an id.
pub fn render_error_line(id: Option<RequestId>, msg: &str) -> String {
    let mut kvs = Vec::new();
    if let Some(id) = id {
        kvs.push(("id", json::num(id as f64)));
    }
    kvs.push(("error", json::s(msg)));
    json::obj(kvs).to_string()
}

/// The request's final summary line. `tokenizer` enables the "text"
/// field for responses whose request set `echo_text`.
pub fn render_response(r: &Response, tokenizer: Option<&Tokenizer>) -> String {
    let mut kvs = vec![
        ("id", json::num(r.id as f64)),
        ("tokens", json::arr(r.tokens.iter().map(|&t| json::num(t as f64)))),
        ("finish", json::s(r.finished.as_str())),
        // Null, not 0.0, when the request never produced a token: a
        // rejection with "ttft_ms": 0.0 is indistinguishable from an
        // instant first token to any client-side SLO accounting.
        (
            "ttft_ms",
            match r.ttft {
                Some(t) => json::num(t * 1e3),
                None => Value::Null,
            },
        ),
        (
            "tpot_ms",
            json::num(crate::util::stats::mean(&r.tpot) * 1e3),
        ),
    ];
    if let super::request::FinishReason::Error(why) = &r.finished {
        kvs.push(("error", json::s(why)));
    }
    if r.echo_text {
        if let Some(tok) = tokenizer {
            kvs.push(("text", json::s(&tok.detokenize(&r.tokens))));
        }
    }
    json::obj(kvs).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    const VOCAB: usize = 512;

    #[test]
    fn parse_and_render() {
        let ids = AtomicU64::new(5);
        let (r, mode) =
            parse_request(r#"{"prompt": [0, 9, 12], "max_new": 4}"#, &ids, VOCAB).unwrap();
        assert_eq!(r.prompt, vec![0, 9, 12]);
        assert_eq!(r.max_new_tokens, 4);
        assert_eq!(r.stop_token, Some(crate::data::NL));
        assert!(!r.stream && !r.echo_text);
        assert!(mode.is_none());
        let resp = Response {
            id: r.id,
            tokens: vec![1, 2],
            ttft: Some(0.011),
            tpot: vec![0.004],
            finished: FinishReason::MaxTokens,
            echo_text: false,
        };
        let s = render_response(&resp, None);
        let v = json::parse(&s).unwrap();
        assert_eq!(v.req_usize("id").unwrap() as u64, r.id);
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.req_str("finish").unwrap(), "max_tokens");
        assert!(v.get("error").is_none());
        assert!(v.get("text").is_none());
        let ttft = v.get("ttft_ms").unwrap().as_f64().unwrap();
        assert!((ttft - 11.0).abs() < 1e-9, "served ttft_ms is numeric ms");
    }

    #[test]
    fn unserved_response_renders_null_ttft() {
        // a rejection never produced a token: ttft_ms must be null on
        // the wire, not a fake 0.0 "instant first token"
        let resp = Response::rejection(11, false, "queue full".into());
        let line = render_response(&resp, None);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.req_str("finish").unwrap(), "error");
        assert!(
            matches!(v.get("ttft_ms"), Some(Value::Null)),
            "expected null ttft_ms in {line}"
        );
        // ...and the raw wire text says null, not 0
        assert!(line.contains("\"ttft_ms\": null") || line.contains("\"ttft_ms\":null"),
            "wire form: {line}");
    }

    #[test]
    fn parse_request_options() {
        let ids = AtomicU64::new(1);
        let (r, mode) = parse_request(
            r#"{"prompt": [4], "stream": true, "echo_text": true,
                "stop_token": null, "mode": "pts"}"#,
            &ids,
            VOCAB,
        )
        .unwrap();
        assert!(r.stream && r.echo_text);
        assert_eq!(r.stop_token, None);
        assert_eq!(mode.as_deref(), Some("pts"));

        let (r, _) =
            parse_request(r#"{"prompt": [4], "stop_token": 7}"#, &ids, VOCAB).unwrap();
        assert_eq!(r.stop_token, Some(7));

        let (r, _) =
            parse_request(r#"{"prompt": [4], "deadline_ms": 250}"#, &ids, VOCAB)
                .unwrap();
        assert_eq!(r.deadline, Some(std::time::Duration::from_millis(250)));
        let (r, _) = parse_request(r#"{"prompt": [4]}"#, &ids, VOCAB).unwrap();
        assert!(r.deadline.is_none(), "deadline is opt-in");
    }

    #[test]
    fn bad_requests_rejected() {
        let ids = AtomicU64::new(1);
        assert!(parse_request("{}", &ids, VOCAB).is_err());
        assert!(parse_request(r#"{"prompt": []}"#, &ids, VOCAB).is_err());
        assert!(parse_request("not json", &ids, VOCAB).is_err());
        // non-integer entries must error, not be silently dropped
        assert!(parse_request(r#"{"prompt": [1, 2.5]}"#, &ids, VOCAB).is_err());
        assert!(parse_request(r#"{"prompt": [1, "x"]}"#, &ids, VOCAB).is_err());
        assert!(parse_request(r#"{"prompt": [1, null]}"#, &ids, VOCAB).is_err());
        // out-of-vocab token ids must be refused at the door
        assert!(parse_request(r#"{"prompt": [-1]}"#, &ids, VOCAB).is_err());
        assert!(parse_request(r#"{"prompt": [512]}"#, &ids, VOCAB).is_err());
        assert!(parse_request(r#"{"prompt": [4], "stop_token": "x"}"#, &ids, VOCAB)
            .is_err());
        assert!(parse_request(r#"{"prompt": [4], "mode": 3}"#, &ids, VOCAB).is_err());
        // a deadline must be a positive whole number of milliseconds
        assert!(parse_request(r#"{"prompt": [4], "deadline_ms": 0}"#, &ids, VOCAB)
            .is_err());
        assert!(parse_request(r#"{"prompt": [4], "deadline_ms": -5}"#, &ids, VOCAB)
            .is_err());
        assert!(
            parse_request(r#"{"prompt": [4], "deadline_ms": 1.5}"#, &ids, VOCAB)
                .is_err()
        );
        assert!(
            parse_request(r#"{"prompt": [4], "deadline_ms": "soon"}"#, &ids, VOCAB)
                .is_err()
        );
    }

    #[test]
    fn admin_lines_are_recognized() {
        assert_eq!(parse_admin(r#"{"cmd": "metrics"}"#).as_deref(), Some("metrics"));
        assert_eq!(parse_admin(r#"{"cmd": "trace"}"#).as_deref(), Some("trace"));
        // ordinary requests (no "cmd"), bad types, and junk fall through
        assert!(parse_admin(r#"{"prompt": [1, 2]}"#).is_none());
        assert!(parse_admin(r#"{"cmd": 3}"#).is_none());
        assert!(parse_admin("not json").is_none());
    }

    #[test]
    fn render_error_and_text() {
        let tok = Tokenizer::new(VOCAB);
        let resp = Response {
            id: 3,
            tokens: vec![4, 5, crate::data::DOT],
            ttft: None,
            tpot: vec![],
            finished: FinishReason::Error("prompt does not fit".into()),
            echo_text: true,
        };
        let v = json::parse(&render_response(&resp, Some(&tok))).unwrap();
        assert_eq!(v.req_str("finish").unwrap(), "error");
        assert_eq!(v.req_str("error").unwrap(), "prompt does not fit");
        let text = v.req_str("text").unwrap();
        assert!(text.contains('.'), "detokenized text missing: {text}");

        // without a tokenizer the text field is simply absent
        let v = json::parse(&render_response(&resp, None)).unwrap();
        assert!(v.get("text").is_none());
    }

    #[test]
    fn token_and_error_lines_are_valid_json() {
        let v = json::parse(&render_token_line(7, 42, 0)).unwrap();
        assert_eq!(v.req_usize("id").unwrap(), 7);
        assert_eq!(v.req_usize("token").unwrap(), 42);
        assert_eq!(v.req_usize("index").unwrap(), 0);
        let v = json::parse(&render_error_line(None, "json: bad \"escape\"")).unwrap();
        assert!(v.get("id").is_none());
        assert!(v.req_str("error").unwrap().contains("escape"));
        let v = json::parse(&render_error_line(Some(9), "overloaded")).unwrap();
        assert_eq!(v.req_usize("id").unwrap(), 9);
    }
}
