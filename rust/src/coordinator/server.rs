//! TCP line-protocol front end (JSON lines over std::net — the offline
//! vendor has no HTTP/tokio stack, and a line protocol keeps the demo
//! client trivial: `nc localhost 7199`).
//!
//! Request:  {"prompt": [1, 2, 3], "max_new": 16}\n
//! Response: {"id": 7, "tokens": [4, 5], "ttft_ms": 12.1, "text": "..."}\n
//!
//! One acceptor thread; per-connection reader threads submit into an
//! mpsc channel; the scheduler thread owns the engine and steps
//! continuously, pushing responses back through per-request channels.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::data::tokenizer::Tokenizer;
use crate::util::json::{self, Value};

use super::request::{Request, RequestId, Response};
use super::scheduler::Scheduler;

enum Inbound {
    Submit(Request, Sender<Response>),
    Shutdown,
}

pub struct Server {
    addr: String,
}

impl Server {
    pub fn new(addr: &str) -> Self {
        Self { addr: addr.to_string() }
    }

    /// Serve until `stop` flips. Blocks the calling thread.
    pub fn serve(&self, mut sched: Scheduler, stop: Arc<AtomicBool>) -> crate::Result<()> {
        let listener = TcpListener::bind(&self.addr)?;
        listener.set_nonblocking(true)?;
        log::info!("cushiond listening on {}", self.addr);
        let (tx, rx): (Sender<Inbound>, Receiver<Inbound>) = channel();
        let next_id = Arc::new(AtomicU64::new(1));
        let tokenizer = Tokenizer::new(sched.engine.session.manifest.vocab);

        // scheduler loop on this thread; acceptor inline (non-blocking)
        let mut waiters: HashMap<RequestId, Sender<Response>> = HashMap::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                sched.cancel_all();
                break;
            }
            // accept new connections
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::debug!("connection from {peer}");
                    let tx = tx.clone();
                    let ids = next_id.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, tx, ids) {
                            log::warn!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => log::warn!("accept: {e}"),
            }
            // drain inbound submissions
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Inbound::Submit(req, back) => {
                        waiters.insert(req.id, back);
                        sched.submit_request(req);
                    }
                    Inbound::Shutdown => {
                        stop.store(true, Ordering::Relaxed);
                    }
                }
            }
            // advance the engine
            if sched.has_work() {
                sched.step()?;
                for resp in sched.take_finished() {
                    if let Some(back) = waiters.remove(&resp.id) {
                        let _ = back.send(resp);
                    }
                }
            } else {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        let _ = tokenizer;
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<Inbound>,
               ids: Arc<AtomicU64>) -> crate::Result<()> {
    let peer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut writer = peer;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "quit" {
            let _ = tx.send(Inbound::Shutdown);
            break;
        }
        match parse_request(&line, &ids) {
            Ok(req) => {
                let (back_tx, back_rx) = channel();
                tx.send(Inbound::Submit(req, back_tx))
                    .map_err(|_| anyhow::anyhow!("scheduler gone"))?;
                match back_rx.recv() {
                    Ok(resp) => {
                        writeln!(writer, "{}", render_response(&resp))?;
                    }
                    Err(_) => {
                        writeln!(writer, "{{\"error\":\"cancelled\"}}")?;
                        break;
                    }
                }
            }
            Err(e) => {
                writeln!(writer, "{{\"error\":{}}}", json::s(&format!("{e:#}")))?;
            }
        }
    }
    Ok(())
}

pub fn parse_request(line: &str, ids: &AtomicU64) -> crate::Result<Request> {
    let v = json::parse(line)?;
    let prompt: Vec<i32> = v
        .req("prompt")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?
        .iter()
        .filter_map(Value::as_i64)
        .map(|t| t as i32)
        .collect();
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = v.get("max_new").and_then(Value::as_usize).unwrap_or(16);
    Ok(Request::new(ids.fetch_add(1, Ordering::Relaxed), prompt, max_new))
}

pub fn render_response(r: &Response) -> String {
    json::obj(vec![
        ("id", json::num(r.id as f64)),
        ("tokens", json::arr(r.tokens.iter().map(|&t| json::num(t as f64)))),
        ("ttft_ms", json::num(r.ttft * 1e3)),
        (
            "tpot_ms",
            json::num(crate::util::stats::mean(&r.tpot) * 1e3),
        ),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_render() {
        let ids = AtomicU64::new(5);
        let r = parse_request(r#"{"prompt": [0, 9, 12], "max_new": 4}"#, &ids).unwrap();
        assert_eq!(r.prompt, vec![0, 9, 12]);
        assert_eq!(r.max_new_tokens, 4);
        let resp = Response {
            id: r.id,
            tokens: vec![1, 2],
            ttft: 0.011,
            tpot: vec![0.004],
            finished: crate::coordinator::request::FinishReason::MaxTokens,
        };
        let s = render_response(&resp);
        let v = json::parse(&s).unwrap();
        assert_eq!(v.req_usize("id").unwrap() as u64, r.id);
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn bad_requests_rejected() {
        let ids = AtomicU64::new(1);
        assert!(parse_request("{}", &ids).is_err());
        assert!(parse_request(r#"{"prompt": []}"#, &ids).is_err());
        assert!(parse_request("not json", &ids).is_err());
    }
}
