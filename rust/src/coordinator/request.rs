//! Request/response types of the serving path.
//!
//! `FinishReason::Error` is the fault-isolation boundary: anything wrong
//! with a *single* request (oversized prompt, out-of-vocab token, a
//! prefill that fails on its input) is reported here, as a per-request
//! response, and must never surface as an engine/server error.

use std::time::{Duration, Instant};

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Stop generation at this token (besides max_new_tokens).
    pub stop_token: Option<i32>,
    /// Render the generated tokens as text in the summary line.
    pub echo_text: bool,
    /// Deliver each generated token as its own wire line before the
    /// summary (the server reads this; the scheduler ignores it).
    pub stream: bool,
    pub submitted: Instant,
    /// Per-request deadline, measured from `submitted` (`"deadline_ms"`
    /// on the wire). An expired request — queued, preempted, or running
    /// — finishes with `FinishReason::Error("deadline")` and its slot
    /// and pool blocks are freed.
    pub deadline: Option<Duration>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            stop_token: Some(crate::data::NL),
            echo_text: false,
            stream: false,
            submitted: Instant::now(),
            deadline: None,
        }
    }

    /// Whether this request's deadline has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline
            .is_some_and(|d| now.duration_since(self.submitted) > d)
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Time to first token, seconds. `None` for requests that never
    /// produced a token (rejections, pre-admission cancels, deadline
    /// expiry while queued) — rendered as `null` on the wire so an
    /// unserved request is distinguishable from an instant first token.
    pub ttft: Option<f64>,
    /// Per-output-token latencies (decode steps), seconds.
    pub tpot: Vec<f64>,
    pub finished: FinishReason,
    /// Carried over from the request so the renderer knows whether to
    /// detokenize into a "text" field.
    pub echo_text: bool,
}

impl Response {
    /// A generation-free response for a request rejected at admission.
    pub fn rejection(id: RequestId, echo_text: bool, why: String) -> Self {
        Self::unserved(id, echo_text, FinishReason::Error(why))
    }

    /// A generation-free response for a request cancelled while still
    /// queued (client disconnect / shutdown before admission).
    pub fn cancelled(id: RequestId, echo_text: bool) -> Self {
        Self::unserved(id, echo_text, FinishReason::Cancelled)
    }

    fn unserved(id: RequestId, echo_text: bool, finished: FinishReason) -> Self {
        Self {
            id,
            tokens: Vec::new(),
            ttft: None,
            tpot: Vec::new(),
            finished,
            echo_text,
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    /// The sequence was truncated by KV capacity — distinct from
    /// MaxTokens so capacity-bound truncation is observable. Fires when
    /// a sequence fills its per-sequence KV space (`cache_cap`),
    /// including the admission edge case of a prompt of exactly
    /// `cap - m_max` tokens (served its prefill token, finished with
    /// zero decode room), and in the last-resort scheduler case where
    /// the block pool is dry and the sequence can never be resumed
    /// (its re-prefill would exceed the prefill window).
    Length,
    Cancelled,
    /// Request-level failure (admission rejection or per-request
    /// execution failure). The request died; the engine did not.
    Error(String),
}

impl FinishReason {
    /// Wire label for the summary line's "finish" field.
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop_token",
            FinishReason::Length => "length",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Error(_) => "error",
        }
    }

    pub fn is_error(&self) -> bool {
        matches!(self, FinishReason::Error(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(1, vec![0, 5, 6], 16);
        assert_eq!(r.stop_token, Some(crate::data::NL));
        assert_eq!(r.max_new_tokens, 16);
        assert!(!r.echo_text);
        assert!(!r.stream);
        assert!(r.deadline.is_none());
        assert!(!r.expired(Instant::now()), "no deadline never expires");
    }

    #[test]
    fn deadline_expiry_is_relative_to_submission() {
        let mut r = Request::new(2, vec![0], 4);
        r.deadline = Some(Duration::from_millis(5));
        assert!(!r.expired(r.submitted));
        assert!(r.expired(r.submitted + Duration::from_millis(6)));
        assert!(!r.expired(r.submitted + Duration::from_millis(4)));
    }

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::MaxTokens.as_str(), "max_tokens");
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::Error("x".into()).as_str(), "error");
        assert!(FinishReason::Error("x".into()).is_error());
        assert!(!FinishReason::Cancelled.is_error());
    }

    #[test]
    fn rejection_is_empty_and_errored() {
        let r = Response::rejection(9, true, "too big".into());
        assert!(r.tokens.is_empty());
        assert!(r.echo_text);
        assert_eq!(r.finished, FinishReason::Error("too big".into()));
        assert!(r.ttft.is_none(), "unserved request has no first token");
    }

    #[test]
    fn cancelled_response_has_no_ttft() {
        let r = Response::cancelled(3, false);
        assert!(r.ttft.is_none());
        assert!(r.tpot.is_empty());
    }
}
