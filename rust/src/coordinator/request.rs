//! Request/response types of the serving path.

use std::time::Instant;

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Stop generation at this token (besides max_new_tokens).
    pub stop_token: Option<i32>,
    pub submitted: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            stop_token: Some(crate::data::NL),
            submitted: Instant::now(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Time to first token, seconds.
    pub ttft: f64,
    /// Per-output-token latencies (decode steps), seconds.
    pub tpot: Vec<f64>,
    pub finished: FinishReason,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    Cancelled,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(1, vec![0, 5, 6], 16);
        assert_eq!(r.stop_token, Some(crate::data::NL));
        assert_eq!(r.max_new_tokens, 16);
    }
}
