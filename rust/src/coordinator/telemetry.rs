//! Live metrics exposition: render the serving [`Metrics`] /
//! [`SloMetrics`] (plus pool, collective, fault-domain, and
//! quantization-health gauges) in Prometheus text format, on demand —
//! the `{"cmd":"metrics"}` wire command and the `--metrics-interval`
//! periodic snapshots — instead of only at shutdown.
//!
//! Label scheme (README "Observability"): every sample carries the
//! caller's base labels — `mode` (quantization scheme serving the
//! replica), `replica` (index within the router fleet), `shards`
//! (tensor-parallel width) — so a multi-replica exposition is the
//! concatenation of per-replica renders and stays aggregatable by any
//! Prometheus server. Values print via Rust's shortest-round-trip
//! float `Display`, so `parse_prometheus(render(..))` recovers every
//! gauge exactly (pinned by `testkit::prop::trace_props`).

use crate::util::stats;

use super::metrics::{Metrics, SloMetrics, DECODE_HIST_MS};

/// One parsed exposition sample: `name{labels} value`.
#[derive(Clone, Debug, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// Escape a label value per the Prometheus text format: backslash,
/// double-quote, and newline.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn unescape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    let mut it = v.chars();
    while let Some(c) = it.next() {
        if c == '\\' {
            match it.next() {
                Some('n') => out.push('\n'),
                Some(e) => out.push(e),
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

/// Append one `name{labels} value` line. Non-finite values render as
/// the Prometheus spellings `+Inf`/`-Inf`/`NaN`.
pub fn sample(
    out: &mut String,
    name: &str,
    labels: &[(&str, String)],
    value: f64,
) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape_label(v));
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    if value.is_nan() {
        out.push_str("NaN");
    } else if value == f64::INFINITY {
        out.push_str("+Inf");
    } else if value == f64::NEG_INFINITY {
        out.push_str("-Inf");
    } else {
        out.push_str(&value.to_string());
    }
    out.push('\n');
}

fn with_extra<'a>(
    base: &'a [(&'a str, String)],
    extra: (&'a str, String),
) -> Vec<(&'a str, String)> {
    let mut v = base.to_vec();
    v.push(extra);
    v
}

/// Render one replica's serving metrics as Prometheus text. `labels`
/// are attached to every sample (the caller supplies `mode`/`replica`/
/// `shards`); counter-style quantities still render as plain samples —
/// this is a point-in-time snapshot, not a scrape-forever endpoint, so
/// no `# TYPE` bookkeeping is attempted beyond `gauge`-like lines.
pub fn render_metrics(m: &Metrics, labels: &[(&str, String)]) -> String {
    let s = m.summary();
    let mut out = String::new();
    let g = |out: &mut String, name: &str, v: f64| sample(out, name, labels, v);

    // request outcomes
    g(&mut out, "cushion_requests_completed", s.completed as f64);
    g(&mut out, "cushion_requests_errored", s.errored as f64);
    g(&mut out, "cushion_requests_rejected", s.rejected as f64);
    g(&mut out, "cushion_requests_cancelled", s.cancelled as f64);
    g(&mut out, "cushion_deadline_expired", s.deadline_expired as f64);
    g(&mut out, "cushion_tokens_out", s.tokens_out as f64);
    g(&mut out, "cushion_tokens_per_second", s.tokens_per_second());

    // latency distributions (single-source percentiles: satellite fix —
    // the histogram below and these quantiles both derive from
    // Metrics::decode_seconds via the nearest-rank rule)
    g(&mut out, "cushion_ttft_seconds_mean", s.ttft_mean);
    g(&mut out, "cushion_ttft_seconds_p99", s.ttft_p99);
    g(&mut out, "cushion_tpot_seconds_mean", s.tpot_mean);
    g(&mut out, "cushion_tpot_seconds_p99", s.tpot_p99);
    g(&mut out, "cushion_decode_step_seconds_p50", s.decode_p50);
    g(&mut out, "cushion_decode_step_seconds_p99", s.decode_p99);
    g(&mut out, "cushion_prefill_seconds_mean", s.prefill_mean);
    g(&mut out, "cushion_decode_batch_mean", s.mean_batch);

    // decode-step latency histogram, cumulative le buckets
    let h = m.decode_histogram();
    let mut cum = 0usize;
    for (i, bound) in DECODE_HIST_MS.iter().enumerate() {
        cum += h[i];
        sample(
            &mut out,
            "cushion_decode_step_ms_bucket",
            &with_extra(labels, ("le", bound.to_string())),
            cum as f64,
        );
    }
    cum += h[DECODE_HIST_MS.len()];
    sample(
        &mut out,
        "cushion_decode_step_ms_bucket",
        &with_extra(labels, ("le", "+Inf".to_string())),
        cum as f64,
    );
    g(&mut out, "cushion_decode_step_count", cum as f64);

    // paged KV pool
    g(&mut out, "cushion_pool_blocks_total", s.pool_blocks_total as f64);
    g(&mut out, "cushion_pool_blocks_in_use", s.pool_blocks_in_use as f64);
    g(&mut out, "cushion_pool_blocks_peak", s.pool_blocks_peak as f64);
    g(&mut out, "cushion_pool_blocks_shared", s.pool_blocks_shared as f64);
    g(&mut out, "cushion_pool_blocks_saved", s.pool_blocks_saved as f64);
    g(&mut out, "cushion_preemptions", s.preempted as f64);

    // host-boundary + collective traffic
    g(&mut out, "cushion_bytes_uploaded", s.bytes_uploaded as f64);
    g(&mut out, "cushion_bytes_fetched", s.bytes_fetched as f64);
    g(&mut out, "cushion_decode_bytes_up_per_step", s.decode_bytes_up_per_step);
    g(
        &mut out,
        "cushion_decode_bytes_down_per_step",
        s.decode_bytes_down_per_step,
    );
    g(
        &mut out,
        "cushion_collective_bytes_gathered_per_step",
        s.decode_bytes_gathered_per_step,
    );
    g(
        &mut out,
        "cushion_collective_bytes_reduced_per_step",
        s.decode_bytes_reduced_per_step,
    );
    g(&mut out, "cushion_shard_skew_seconds_max", s.shard_skew_max);

    // fault recovery + fault domain
    for (cause, n) in [
        ("execute", s.retries_execute),
        ("upload", s.retries_upload),
        ("fetch", s.retries_fetch),
    ] {
        sample(
            &mut out,
            "cushion_retries",
            &with_extra(labels, ("cause", cause.to_string())),
            n as f64,
        );
    }
    g(&mut out, "cushion_downgrades", s.downgrades as f64);
    g(&mut out, "cushion_backend_rung", s.backend_rung as f64);
    g(&mut out, "cushion_faults_injected", s.faults_injected as f64);
    g(&mut out, "cushion_health_transitions", s.health_transitions as f64);
    g(&mut out, "cushion_breaker_opens", s.breaker_opens as f64);
    g(&mut out, "cushion_breaker_probes", s.breaker_probes as f64);
    g(&mut out, "cushion_failovers", s.failovers as f64);
    g(&mut out, "cushion_migrated_sequences", s.migrated_sequences as f64);
    g(&mut out, "cushion_reprefill_tokens", s.reprefill_tokens as f64);
    g(&mut out, "cushion_shed_requests", s.shed_requests as f64);
    g(&mut out, "cushion_ladder_floor_errors", s.ladder_floor_errors as f64);
    g(&mut out, "cushion_drain_seconds", s.drain_seconds);

    // quantization health (the paper loop-closer): serve-time
    // activation absmax and static-range clip rate, sampled every Nth
    // decode step. A missing/stale cushion shows up here as an absmax /
    // clip-rate excursion long before it shows up as perplexity.
    g(&mut out, "cushion_act_samples", s.act_samples as f64);
    g(&mut out, "cushion_act_absmax", s.act_absmax as f64);
    g(&mut out, "cushion_act_absmax_peak", s.act_absmax_peak as f64);
    g(&mut out, "cushion_act_clip_rate", s.act_clip_rate);
    out
}

/// Render per-class SLO percentiles/goodput (when a workload assigns
/// request classes), one `class` label per sample.
pub fn render_slo(slo: &SloMetrics, labels: &[(&str, String)]) -> String {
    let mut out = String::new();
    for c in slo.summary() {
        let l = with_extra(labels, ("class", c.class.clone()));
        sample(&mut out, "cushion_slo_requests_total", &l, c.total as f64);
        sample(&mut out, "cushion_slo_requests_good", &l, c.good as f64);
        sample(&mut out, "cushion_slo_good_tokens", &l, c.good_tokens as f64);
        sample(&mut out, "cushion_slo_goodput", &l, c.goodput());
        sample(&mut out, "cushion_slo_ttft_seconds_p50", &l, c.ttft_p50);
        sample(&mut out, "cushion_slo_ttft_seconds_p99", &l, c.ttft_p99);
        sample(&mut out, "cushion_slo_tpot_seconds_p50", &l, c.tpot_p50);
        sample(&mut out, "cushion_slo_tpot_seconds_p99", &l, c.tpot_p99);
    }
    out
}

/// Parse Prometheus text exposition back into samples. Comment (`#`)
/// and blank lines are skipped; malformed lines error — the round-trip
/// property and the wire-command tests both go through here.
pub fn parse_prometheus(text: &str) -> crate::Result<Vec<PromSample>> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| anyhow::anyhow!("prom line {ln}: no value: {line:?}"))?;
        let value = match value {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            "NaN" => f64::NAN,
            v => v
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("prom line {ln}: bad value {v:?}: {e}"))?,
        };
        let (name, labels) = match head.find('{') {
            None => (head.to_string(), Vec::new()),
            Some(b) => {
                let name = head[..b].to_string();
                let body = head[b + 1..]
                    .strip_suffix('}')
                    .ok_or_else(|| anyhow::anyhow!("prom line {ln}: unclosed labels"))?;
                let mut labels = Vec::new();
                let mut rest = body;
                while !rest.is_empty() {
                    let eq = rest.find("=\"").ok_or_else(|| {
                        anyhow::anyhow!("prom line {ln}: bad label in {body:?}")
                    })?;
                    let key = rest[..eq].to_string();
                    rest = &rest[eq + 2..];
                    // scan to the closing unescaped quote
                    let mut end = None;
                    let bytes = rest.as_bytes();
                    let mut i = 0;
                    while i < bytes.len() {
                        match bytes[i] {
                            b'\\' => i += 2,
                            b'"' => {
                                end = Some(i);
                                break;
                            }
                            _ => i += 1,
                        }
                    }
                    let end = end.ok_or_else(|| {
                        anyhow::anyhow!("prom line {ln}: unterminated label value")
                    })?;
                    labels.push((key, unescape_label(&rest[..end])));
                    rest = &rest[end + 1..];
                    rest = rest.strip_prefix(',').unwrap_or(rest);
                }
                (name, labels)
            }
        };
        if name.is_empty() {
            anyhow::bail!("prom line {ln}: empty metric name");
        }
        out.push(PromSample { name, labels, value });
    }
    Ok(out)
}

/// Convenience for tests: the value of the first sample matching
/// `name` (and every label in `want`), if present.
pub fn find_sample(
    samples: &[PromSample],
    name: &str,
    want: &[(&str, &str)],
) -> Option<f64> {
    samples
        .iter()
        .find(|s| {
            s.name == name
                && want.iter().all(|(k, v)| {
                    s.labels.iter().any(|(lk, lv)| lk == k && lv == v)
                })
        })
        .map(|s| s.value)
}

/// Worst-case TTFT p99 across classes formatted for the periodic
/// snapshot header line.
pub fn slo_headline(slo: &SloMetrics) -> String {
    format!(
        "slo ttft_p99={:.4}s tpot_p99={:.4}s goodput={:.3}",
        slo.ttft_p99(),
        slo.tpot_p99(),
        slo.goodput()
    )
}

/// Percentile of `xs` by the nearest-rank rule (an actual sample, not
/// an interpolation) — the shared quantile for exposition consumers
/// that must agree with bucketed histograms. Re-exported here so both
/// `Metrics::summary` and tests name one definition.
pub fn nearest_rank(xs: &[f64], p: f64) -> f64 {
    stats::percentile_nearest(xs, p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_lines_render_and_parse() {
        let mut out = String::new();
        sample(&mut out, "a_metric", &[], 1.5);
        sample(
            &mut out,
            "b_metric",
            &[("mode", "w8a8_pts".to_string()), ("replica", "3".to_string())],
            42.0,
        );
        sample(&mut out, "c_inf", &[], f64::INFINITY);
        let parsed = parse_prometheus(&out).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0].name, "a_metric");
        assert_eq!(parsed[0].value, 1.5);
        assert_eq!(
            parsed[1].labels,
            vec![
                ("mode".to_string(), "w8a8_pts".to_string()),
                ("replica".to_string(), "3".to_string())
            ]
        );
        assert_eq!(parsed[2].value, f64::INFINITY);
        assert_eq!(
            find_sample(&parsed, "b_metric", &[("replica", "3")]),
            Some(42.0)
        );
        assert_eq!(find_sample(&parsed, "b_metric", &[("replica", "9")]), None);
    }

    #[test]
    fn label_values_escape_round_trip() {
        let mut out = String::new();
        let odd = "quo\"te\\slash\nnewline".to_string();
        sample(&mut out, "m", &[("k", odd.clone())], 7.0);
        let parsed = parse_prometheus(&out).unwrap();
        assert_eq!(parsed[0].labels, vec![("k".to_string(), odd)]);
    }

    #[test]
    fn render_metrics_exposes_labeled_gauges() {
        let mut m = Metrics::new();
        m.record_preempted();
        m.record_floor_error();
        m.record_act_sample(crate::runtime::trace::ActSample {
            absmax: 2.5,
            clipped: 5,
            total: 100,
        });
        let labels = [("mode", "fp".to_string()), ("replica", "0".to_string())];
        let text = render_metrics(&m, &labels);
        let parsed = parse_prometheus(&text).unwrap();
        assert_eq!(
            find_sample(&parsed, "cushion_preemptions", &[("mode", "fp")]),
            Some(1.0)
        );
        assert_eq!(
            find_sample(&parsed, "cushion_ladder_floor_errors", &[("replica", "0")]),
            Some(1.0)
        );
        assert_eq!(
            find_sample(&parsed, "cushion_act_absmax", &[("mode", "fp")]),
            Some(2.5),
        );
        assert_eq!(
            find_sample(&parsed, "cushion_act_clip_rate", &[]),
            Some(0.05)
        );
        // histogram renders cumulative buckets ending at +Inf
        assert!(text.contains("cushion_decode_step_ms_bucket"));
        assert!(text.contains("le=\"+Inf\""));
        // every sample carries the caller's labels
        for s in &parsed {
            assert!(
                s.labels.iter().any(|(k, _)| k == "mode"),
                "{} missing mode label",
                s.name
            );
        }
    }

    #[test]
    fn render_slo_exposes_classes() {
        use crate::coordinator::request::{FinishReason, Response};
        let mut slo = SloMetrics::new();
        slo.record(
            "short",
            &Response {
                id: 1,
                tokens: vec![1, 2],
                ttft: Some(0.01),
                tpot: vec![0.002],
                finished: FinishReason::MaxTokens,
                echo_text: false,
            },
        );
        let text = render_slo(&slo, &[("replica", "1".to_string())]);
        let parsed = parse_prometheus(&text).unwrap();
        assert_eq!(
            find_sample(&parsed, "cushion_slo_goodput", &[("class", "short")]),
            Some(1.0)
        );
        assert!(slo_headline(&slo).starts_with("slo ttft_p99="));
    }
}
