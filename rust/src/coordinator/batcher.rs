//! Admission queue + continuous-batching bookkeeping.

use std::collections::VecDeque;
use std::time::Instant;

use super::request::{FinishReason, Request, RequestId, Response};

/// A request currently holding a KV slot.
#[derive(Debug)]
pub struct Running {
    pub request: Request,
    pub slot: usize,
    pub generated: Vec<i32>,
    pub first_token_at: Option<Instant>,
    pub last_token_at: Instant,
    pub tpot: Vec<f64>,
    /// Prefix-cache hashes this sequence donated to the index when it
    /// was preempted (`PagedKv::free_donating`). A cancel while the
    /// sequence waits for resume must drop exactly these entries —
    /// nothing else still accounts for them. Cleared on resume.
    pub donated: Vec<u64>,
}

impl Running {
    pub fn new(request: Request, slot: usize) -> Self {
        Self {
            request,
            slot,
            generated: Vec::new(),
            first_token_at: None,
            last_token_at: Instant::now(),
            tpot: Vec::new(),
            donated: Vec::new(),
        }
    }

    pub fn push_token(&mut self, tok: i32) {
        let now = Instant::now();
        if self.first_token_at.is_none() {
            self.first_token_at = Some(now);
        } else {
            self.tpot.push(now.duration_since(self.last_token_at).as_secs_f64());
        }
        self.last_token_at = now;
        self.generated.push(tok);
    }

    pub fn should_stop(&self, remaining_cache: usize) -> Option<FinishReason> {
        if let (Some(stop), Some(&last)) =
            (self.request.stop_token, self.generated.last())
        {
            if last == stop {
                return Some(FinishReason::StopToken);
            }
        }
        if self.generated.len() >= self.request.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if remaining_cache == 0 {
            return Some(FinishReason::Length);
        }
        None
    }

    /// The token sequence a resume-prefill must process: the original
    /// prompt plus everything generated so far (re-prefilling recomputes
    /// the KV the preemption freed; the next decode token falls out of
    /// the prefill's last position).
    pub fn resume_tokens(&self) -> Vec<i32> {
        let mut t = self.request.prompt.clone();
        t.extend_from_slice(&self.generated);
        t
    }

    /// Finalize with the real finish reason (from `should_stop`, or
    /// `Cancelled` on shutdown).
    pub fn into_response(self, finished: FinishReason) -> Response {
        // No first token → `None`, not 0.0: a preempted-then-expired
        // sequence that never decoded must not report an instant TTFT.
        let ttft = self
            .first_token_at
            .map(|t| t.duration_since(self.request.submitted).as_secs_f64());
        Response {
            id: self.request.id,
            tokens: self.generated,
            ttft,
            tpot: self.tpot,
            finished,
            echo_text: self.request.echo_text,
        }
    }
}

/// What admission should work on next: a fresh request or a preempted
/// sequence to resume.
#[derive(Debug)]
pub enum Admit {
    New(Request),
    Resume(Running),
}

/// FIFO waiting queue plus the resume queue of preempted sequences.
///
/// Anti-starvation is age-based: `pop_next` always yields the earliest-
/// *submitted* work across both queues, so a sequence the scheduler
/// preempted (which is, by the youngest-victim policy, younger than
/// every survivor) can never leapfrog an older fresh request, and a
/// fresh request can never starve a long-waiting preempted one.
#[derive(Debug, Default)]
pub struct Batcher {
    waiting: VecDeque<Request>,
    /// Preempted sequences awaiting re-prefill, oldest submission first.
    resumes: VecDeque<Running>,
    next_id: RequestId,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> RequestId {
        self.next_id += 1;
        let id = self.next_id;
        self.waiting.push_back(Request::new(id, prompt, max_new));
        id
    }

    pub fn submit_request(&mut self, r: Request) {
        self.next_id = self.next_id.max(r.id);
        self.waiting.push_back(r);
    }

    /// Pop the oldest *fresh* request only — a test/diagnostic accessor.
    /// Production admission must use `pop_next`, which is resume-aware:
    /// draining via `pop` would starve preempted sequences forever.
    pub fn pop(&mut self) -> Option<Request> {
        self.waiting.pop_front()
    }

    /// The next admission candidate by submission age (see the struct
    /// docs). Ties (same instant) prefer the resume — it already spent
    /// scheduler work.
    pub fn pop_next(&mut self) -> Option<Admit> {
        let take_new = match (self.waiting.front(), self.resumes.front()) {
            (Some(w), Some(r)) => {
                (w.submitted, w.id) < (r.request.submitted, r.request.id)
            }
            (Some(_), None) => true,
            (None, _) => false,
        };
        if take_new {
            self.waiting.pop_front().map(Admit::New)
        } else {
            self.resumes.pop_front().map(Admit::Resume)
        }
    }

    /// Return a popped request to the head of the queue (admission saw
    /// it but has no free slot yet; FIFO order is preserved).
    pub fn push_front(&mut self, r: Request) {
        self.waiting.push_front(r);
    }

    /// Queue a preempted sequence for resume, keeping the resume queue
    /// ordered oldest-submission-first.
    pub fn push_resume(&mut self, run: Running) {
        let key = (run.request.submitted, run.request.id);
        let pos = self
            .resumes
            .iter()
            .position(|r| (r.request.submitted, r.request.id) > key)
            .unwrap_or(self.resumes.len());
        self.resumes.insert(pos, run);
    }

    /// Remove a still-queued request (client disconnected before its
    /// prefill was admitted).
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        let pos = self.waiting.iter().position(|r| r.id == id)?;
        self.waiting.remove(pos)
    }

    /// Remove a preempted sequence awaiting resume (cancellation).
    pub fn remove_resume(&mut self, id: RequestId) -> Option<Running> {
        let pos = self.resumes.iter().position(|r| r.request.id == id)?;
        self.resumes.remove(pos)
    }

    /// Pluck every queued work item whose request matches `expired`
    /// (the scheduler's deadline sweep): returns the plucked fresh
    /// requests and preempted sequences.
    pub fn expire_where(
        &mut self,
        mut expired: impl FnMut(&Request) -> bool,
    ) -> (Vec<Request>, Vec<Running>) {
        let mut fresh = Vec::new();
        let mut i = 0;
        while i < self.waiting.len() {
            if expired(&self.waiting[i]) {
                fresh.push(self.waiting.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        let mut preempted = Vec::new();
        let mut i = 0;
        while i < self.resumes.len() {
            if expired(&self.resumes[i].request) {
                preempted.push(self.resumes.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        (fresh, preempted)
    }

    /// Pending work items: fresh requests plus preempted sequences.
    pub fn waiting(&self) -> usize {
        self.waiting.len() + self.resumes.len()
    }

    /// Preempted sequences awaiting resume.
    pub fn resume_count(&self) -> usize {
        self.resumes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new();
        let a = b.submit(vec![1], 4);
        let c = b.submit(vec![2], 4);
        assert!(a < c);
        assert_eq!(b.pop().unwrap().id, a);
        assert_eq!(b.pop().unwrap().id, c);
        assert!(b.pop().is_none());
    }

    #[test]
    fn remove_plucks_from_queue() {
        let mut b = Batcher::new();
        let a = b.submit(vec![1], 4);
        let c = b.submit(vec![2], 4);
        let d = b.submit(vec![3], 4);
        assert_eq!(b.remove(c).unwrap().id, c);
        assert!(b.remove(c).is_none());
        assert_eq!(b.waiting(), 2);
        assert_eq!(b.pop().unwrap().id, a);
        assert_eq!(b.pop().unwrap().id, d);
    }

    #[test]
    fn stop_conditions() {
        let mut r = Running::new(Request::new(1, vec![0], 2), 0);
        assert!(r.should_stop(10).is_none());
        r.push_token(5);
        assert!(r.should_stop(10).is_none());
        r.push_token(6);
        assert_eq!(r.should_stop(10), Some(FinishReason::MaxTokens));

        let mut r = Running::new(Request::new(2, vec![0], 50), 0);
        r.push_token(crate::data::NL);
        assert_eq!(r.should_stop(10), Some(FinishReason::StopToken));

        let mut r = Running::new(Request::new(3, vec![0], 50), 0);
        r.push_token(7);
        assert_eq!(r.should_stop(0), Some(FinishReason::Length));
        assert_eq!(r.resume_tokens(), vec![0, 7], "prompt ++ generated");
    }

    #[test]
    fn pop_next_is_age_ordered_across_queues() {
        // distinct submission instants even on coarse monotonic clocks
        let tick = || std::thread::sleep(std::time::Duration::from_millis(2));
        let mut b = Batcher::new();
        let old = b.submit(vec![1], 4); // oldest submission
        tick();
        let mid = b.submit(vec![2], 4);
        // `mid` gets admitted, then preempted back into the resume queue
        let mid_req = {
            let _ = b.pop(); // old (pretend admitted elsewhere)
            b.pop().unwrap()
        };
        tick();
        let young = b.submit(vec![3], 4);
        tick();
        b.push_resume(Running::new(mid_req, 0));
        b.push_front(Request::new(old, vec![1], 4)); // put old back… not aged
        assert_eq!(b.waiting(), 3);
        assert_eq!(b.resume_count(), 1);
        // old's re-pushed Request has a *new* submitted instant, so the
        // preempted `mid` (older submission) must come first
        match b.pop_next().unwrap() {
            Admit::Resume(r) => assert_eq!(r.request.id, mid),
            Admit::New(r) => panic!("resume starved by {:?}", r.id),
        }
        match b.pop_next().unwrap() {
            Admit::New(r) => assert_eq!(r.id, old),
            Admit::Resume(_) => panic!("unexpected resume"),
        }
        match b.pop_next().unwrap() {
            Admit::New(r) => assert_eq!(r.id, young),
            Admit::Resume(_) => panic!("unexpected resume"),
        }
        assert!(b.pop_next().is_none());
    }

    #[test]
    fn remove_resume_plucks_preempted() {
        let mut b = Batcher::new();
        let id = b.submit(vec![1], 4);
        let req = b.pop().unwrap();
        b.push_resume(Running::new(req, 0));
        assert!(b.remove(id).is_none(), "not in the fresh queue");
        assert_eq!(b.remove_resume(id).unwrap().request.id, id);
        assert!(b.remove_resume(id).is_none());
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn timing_accumulates() {
        let mut r = Running::new(Request::new(1, vec![0], 8), 0);
        r.push_token(1);
        r.push_token(2);
        r.push_token(3);
        assert_eq!(r.tpot.len(), 2); // first token counts toward TTFT
        let resp = r.into_response(FinishReason::StopToken);
        assert_eq!(resp.tokens, vec![1, 2, 3]);
        assert_eq!(resp.finished, FinishReason::StopToken);
        assert!(resp.ttft.expect("served request has a ttft") >= 0.0);

        let never_served = Running::new(Request::new(2, vec![0], 8), 1);
        let resp = never_served.into_response(FinishReason::Cancelled);
        assert!(resp.ttft.is_none(), "no token → no ttft");
    }
}
